"""AOT lowering: JAX (L2) + Pallas (L1) → HLO text artifacts for the rust
coordinator (L3).

Interchange is HLO *text*, not serialized ``HloModuleProto``: jax ≥ 0.5
emits protos with 64-bit instruction ids that the XLA 0.5.1 runtime inside
the rust ``xla`` crate rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts produced per (model preset, optimizer set):

* ``grad_step``            (tokens, params…) → (loss, grads…)
* ``eval_loss``            (tokens, params…) → (loss,)
* ``train_step_<opt>``     (tokens, lr, t, params…, state…) →
                           (loss, params'…, state'…)     — fused hot path
* ``refresh_<opt>``        (tokens, seed, params…, state…) → (state'…)
                           — the every-K-steps projection update
* ``opt_update_<opt>_<m>x<n>`` (g, lr, t, state…) → (w_delta, state'…)
                           — single-tensor update, exercises L1 kernels
                             standalone from rust

plus ``manifest.json`` pinning shapes, orderings, and hyperparameters.

Python runs ONCE (``make artifacts``); it is never on the request path.
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from . import optimizers as O

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _shape_list(a) -> list:
    return list(a.shape)


def _classify_init(a) -> str:
    """Describe an init array so the rust side can reproduce it without
    shipping the data: 'zeros' | 'eye' (identity prefix) | 'eye_scale:<c>'.
    Falls back to 'zeros' only if the array really is all-zero."""
    import numpy as np

    arr = np.asarray(a)
    if not arr.any():
        return "zeros"
    if arr.ndim == 2:
        m, n = arr.shape
        if np.array_equal(arr, np.eye(m, n, dtype=arr.dtype)):
            return "eye"
        if m == n:
            d = np.diagonal(arr)
            if np.allclose(arr, np.diag(d)) and np.allclose(d, d[0]):
                return f"eye_scale:{float(d[0])!r}"
    raise ValueError(f"unclassifiable state init (shape {arr.shape})")


class Bundle:
    """Accumulates artifacts + manifest entries for one preset."""

    def __init__(self, cfg: M.ModelConfig, hp: O.HP, out_dir: str,
                 last_layer_adam_fullrank: bool = True):
        self.cfg = cfg
        self.hp = hp
        self.out = out_dir
        self.entries: List[dict] = []
        self.specs = M.param_specs(cfg)
        self.last_layer_adam_fullrank = last_layer_adam_fullrank
        os.makedirs(out_dir, exist_ok=True)

    # ---------------------------------------------------------- helpers ---
    def _write(self, name: str, lowered, inputs: List[dict],
               outputs: List[dict], kind: str, extra: dict | None = None):
        path = os.path.join(self.out, f"{name}.hlo.txt")
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        entry = {"name": name, "file": os.path.basename(path), "kind": kind,
                 "inputs": inputs, "outputs": outputs}
        if extra:
            entry.update(extra)
        self.entries.append(entry)
        print(f"  wrote {name}.hlo.txt ({len(text) // 1024} KiB)")

    def _param_inputs(self) -> List[dict]:
        return [{"name": n, "dtype": "f32", "shape": list(s)}
                for n, s, _ in self.specs]

    def _tok_input(self) -> dict:
        return {"name": "tokens", "dtype": "i32",
                "shape": [self.cfg.batch, self.cfg.seq]}

    # ----------------------------------------------- model-level steps ---
    def emit_grad_step(self):
        cfg = self.cfg

        def fn(tokens, *params):
            loss, grads = M.grad_step(list(params), tokens, cfg)
            return (loss, *grads)

        lowered = jax.jit(fn).lower(
            _spec((cfg.batch, cfg.seq), I32),
            *[_spec(s) for _, s, _ in self.specs])
        outs = [{"name": "loss", "dtype": "f32", "shape": []}] + [
            {"name": f"grad.{n}", "dtype": "f32", "shape": list(s)}
            for n, s, _ in self.specs]
        self._write("grad_step", lowered,
                    [self._tok_input()] + self._param_inputs(), outs, "grad")

    def emit_eval_loss(self):
        cfg = self.cfg

        def fn(tokens, *params):
            return (M.loss_fn(list(params), tokens, cfg),)

        lowered = jax.jit(fn).lower(
            _spec((cfg.batch, cfg.seq), I32),
            *[_spec(s) for _, s, _ in self.specs])
        self._write("eval_loss", lowered,
                    [self._tok_input()] + self._param_inputs(),
                    [{"name": "loss", "dtype": "f32", "shape": []}], "eval")

    # -------------------------------------------------- fused optimizer ---
    def _routing(self, opt: str):
        """Per-param optimizer routing (paper App. F.2 protocol):
        matrix params → candidate; 1-D params → Adam; lm-head → Adam for
        full-rank candidates, candidate itself for low-rank ones."""
        low_rank = opt in ("galore", "fira", "alice", "alice0", "apollo_mini")
        routes = []
        for name, shape, _ in self.specs:
            if len(shape) < 2:
                routes.append("adam")
            elif name == "lm_head" and self.last_layer_adam_fullrank \
                    and not low_rank:
                routes.append("adam")
            else:
                routes.append(opt)
        return routes

    def _state_template(self, opt: str):
        """[(param_idx, route, state_dict_template)] in flat order."""
        out = []
        for idx, (name, shape, _) in enumerate(self.specs):
            route = self._routing(opt)[idx]
            if route == "adam" and len(shape) < 2:
                st = O.adam_init(shape, self.hp)
            elif route == "adam":
                st = O.adam_init(shape, self.hp)
            else:
                st = O.init_state(route, shape, self.hp)
            out.append((idx, route, st))
        return out

    def _flat_state_specs(self, opt: str) -> List[dict]:
        flat = []
        for idx, route, st in self._state_template(opt):
            pname = self.specs[idx][0]
            for k, a in st.items():
                flat.append({"name": f"state.{pname}.{k}", "dtype": "f32",
                             "shape": _shape_list(a), "param": pname,
                             "key": k, "route": route,
                             "init": _classify_init(a)})
        return flat

    def emit_train_step(self, opt: str):
        cfg, hp = self.cfg, self.hp
        tmpl = self._state_template(opt)
        routes = [r for _, r, _ in tmpl]
        keys = [list(st.keys()) for _, _, st in tmpl]

        def fn(tokens, lr, t, *flat):
            np_ = len(self.specs)
            params = list(flat[:np_])
            pos = np_
            states = []
            for ks in keys:
                states.append({k: flat[pos + i] for i, k in enumerate(ks)})
                pos += len(ks)
            loss, grads = M.grad_step(params, tokens, cfg)
            new_params, new_flat_states = [], []
            for p, g, st, route in zip(params, grads, states, routes):
                if route == "adam":
                    if p.ndim < 2:
                        m2 = hp.b1 * st["m"] + (1 - hp.b1) * g
                        v2 = hp.b2 * st["v"] + (1 - hp.b2) * g * g
                        bc1, bc2 = O._bc(hp, t)
                        delta = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + hp.eps)
                        st2 = {"m": m2, "v": v2}
                    else:
                        delta, st2 = O.adam_update(g, st, hp, t)
                else:
                    delta, st2 = O.update(route, g, st, hp, t)
                new_params.append(p - lr * delta)
                new_flat_states.extend(st2[k] for k in st2)
            return (loss, *new_params, *new_flat_states)

        state_specs = self._flat_state_specs(opt)
        in_specs = [_spec((cfg.batch, cfg.seq), I32), _spec((), F32),
                    _spec((), F32)]
        in_specs += [_spec(s) for _, s, _ in self.specs]
        in_specs += [_spec(e["shape"]) for e in state_specs]
        lowered = jax.jit(fn).lower(*in_specs)
        inputs = ([self._tok_input(),
                   {"name": "lr", "dtype": "f32", "shape": []},
                   {"name": "t", "dtype": "f32", "shape": []}]
                  + self._param_inputs() + state_specs)
        outputs = ([{"name": "loss", "dtype": "f32", "shape": []}]
                   + self._param_inputs() + state_specs)
        self._write(f"train_step_{opt}", lowered, inputs, outputs,
                    "train_step", {"optimizer": opt, "routes": routes})

    def emit_refresh(self, opt: str):
        if O.OPTIMIZERS[opt].refresh is None:
            return
        cfg, hp = self.cfg, self.hp
        tmpl = self._state_template(opt)
        routes = [r for _, r, _ in tmpl]
        keys = [list(st.keys()) for _, _, st in tmpl]

        def fn(tokens, seed, *flat):
            np_ = len(self.specs)
            params = list(flat[:np_])
            pos = np_
            states = []
            for ks in keys:
                states.append({k: flat[pos + i] for i, k in enumerate(ks)})
                pos += len(ks)
            _, grads = M.grad_step(params, tokens, cfg)
            new_flat = []
            for i, (g, st, route) in enumerate(zip(grads, states, routes)):
                if route == opt:
                    st = O.refresh(route, g, st, hp, seed + i)
                new_flat.extend(st[k] for k in st)
            return tuple(new_flat)

        state_specs = self._flat_state_specs(opt)
        in_specs = [_spec((cfg.batch, cfg.seq), I32), _spec((), I32)]
        in_specs += [_spec(s) for _, s, _ in self.specs]
        in_specs += [_spec(e["shape"]) for e in state_specs]
        lowered = jax.jit(fn).lower(*in_specs)
        inputs = ([self._tok_input(),
                   {"name": "seed", "dtype": "i32", "shape": []}]
                  + self._param_inputs() + state_specs)
        self._write(f"refresh_{opt}", lowered, inputs, state_specs,
                    "refresh", {"optimizer": opt})

    # ------------------------------------------- single-tensor updates ---
    def emit_opt_update(self, opt: str, shape):
        hp = self.hp
        st0 = O.init_state(opt, shape, hp)
        ks = list(st0.keys())

        def fn(g, lr, t, *flat):
            st = {k: flat[i] for i, k in enumerate(ks)}
            delta, st2 = O.update(opt, g, st, hp, t)
            return (lr * delta, *[st2[k] for k in ks])

        in_specs = [_spec(shape), _spec((), F32), _spec((), F32)]
        in_specs += [_spec(st0[k].shape) for k in ks]
        lowered = jax.jit(fn).lower(*in_specs)
        name = f"opt_update_{opt}_{shape[0]}x{shape[1]}"
        sspecs = [{"name": f"state.{k}", "dtype": "f32",
                   "shape": _shape_list(st0[k]), "key": k} for k in ks]
        inputs = ([{"name": "g", "dtype": "f32", "shape": list(shape)},
                   {"name": "lr", "dtype": "f32", "shape": []},
                   {"name": "t", "dtype": "f32", "shape": []}] + sspecs)
        outputs = ([{"name": "w_delta", "dtype": "f32",
                     "shape": list(shape)}] + sspecs)
        self._write(name, lowered, inputs, outputs, "opt_update",
                    {"optimizer": opt, "shape": list(shape)})

    # --------------------------------------------------------- manifest ---
    def manifest(self, opts: List[str]) -> dict:
        cfg = self.cfg
        return {
            "version": 1,
            "model": {"preset": cfg.name, "vocab": cfg.vocab,
                      "dim": cfg.dim, "inter": cfg.inter,
                      "heads": cfg.heads, "layers": cfg.layers,
                      "seq": cfg.seq, "batch": cfg.batch,
                      "num_params": M.num_params(cfg)},
            "params": [{"name": n, "shape": list(s), "init_std": std}
                       for n, s, std in self.specs],
            "optimizers": {
                o: {"states": self._flat_state_specs(o),
                    "routes": self._routing(o),
                    "has_refresh": O.OPTIMIZERS[o].refresh is not None}
                for o in opts},
            "hyperparams": {k: getattr(self.hp, k)
                            for k in self.hp.__dataclass_fields__},
            "artifacts": self.entries,
        }


def distinct_matrix_shapes(cfg: M.ModelConfig):
    seen, out = set(), []
    for _, s, _ in M.param_specs(cfg):
        if len(s) == 2 and s not in seen:
            seen.add(s)
            out.append(s)
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", default="tiny", choices=sorted(M.PRESETS))
    ap.add_argument("--opts", default="adam,racs,alice",
                    help="comma list for fused/refresh/update artifacts")
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--rank", type=int, default=32)
    ap.add_argument("--leading", type=int, default=10)
    ap.add_argument("--interval", type=int, default=100)
    ap.add_argument("--skip-fused", action="store_true")
    ap.add_argument("--ref-kernels", action="store_true",
                    help="lower with pure-jnp oracles instead of "
                         "interpret-mode Pallas (CPU perf; see "
                         "EXPERIMENTS.md §Perf L2-1)")
    ap.add_argument("--skip-updates", action="store_true")
    args = ap.parse_args()

    if args.ref_kernels:
        from . import kernels

        kernels.set_ref_mode(True)
        print("[aot] ref-kernel mode: Pallas bypassed in lowered HLO")
    cfg = M.PRESETS[args.preset]
    hp = O.HP(rank=args.rank, leading=args.leading, interval=args.interval,
              b2=0.9 if "alice" in args.opts else 0.999)
    opts = [o.strip() for o in args.opts.split(",") if o.strip()]
    for o in opts:
        if o not in O.OPTIMIZERS:
            raise SystemExit(f"unknown optimizer {o!r}")

    b = Bundle(cfg, hp, args.out)
    print(f"[aot] preset={cfg.name} ({M.num_params(cfg):,} params) "
          f"opts={opts}")
    b.emit_grad_step()
    b.emit_eval_loss()
    for o in opts:
        if not args.skip_fused:
            b.emit_train_step(o)
            b.emit_refresh(o)
        if not args.skip_updates:
            for shape in distinct_matrix_shapes(cfg):
                b.emit_opt_update(o, shape)
    man = b.manifest(opts)
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(man, f, indent=1)
    print(f"[aot] manifest.json with {len(b.entries)} artifacts")


if __name__ == "__main__":
    main()
