"""Layer-2 model: LLaMA-style decoder-only transformer in pure JAX.

Architecture follows Touvron et al. 2023 as used in the paper's experiments
(App. F.2, Table 10): RMSNorm pre-normalization, rotary position embeddings,
SwiGLU MLP, untied lm-head, next-token cross-entropy.

Parameters are a FLAT ORDERED LIST of named 2-D/1-D tensors
(``param_specs``) so the AOT manifest and the rust coordinator agree on
ordering without pytree introspection. Matrix parameters are exactly the
ones the paper's optimizers precondition; 1-D (norm) parameters are routed
to Adam by the coordinator, and the lm-head policy ("Ppl" vs "Ppl*",
Sec. 7.1) is a coordinator flag.

Presets scale the paper's Table 10 grid down to CPU-feasible sizes (see
DESIGN.md §Substitutions); `llama60m`/`llama130m`/`llama350m`/`llama1b` are
kept for the analytic memory tables (Table 3) even though they are not
trained here.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    dim: int
    inter: int           # SwiGLU intermediate size
    heads: int
    layers: int
    seq: int
    batch: int

    @property
    def head_dim(self) -> int:
        return self.dim // self.heads


PRESETS: Dict[str, ModelConfig] = {
    # CPU-trainable scale ladder (synthetic corpus; DESIGN.md §Substitutions)
    "nano": ModelConfig("nano", 256, 64, 176, 4, 2, 64, 8),
    "tiny": ModelConfig("tiny", 512, 128, 344, 4, 4, 64, 8),
    "small": ModelConfig("small", 1024, 256, 688, 8, 6, 128, 8),
    "mid": ModelConfig("mid", 2048, 512, 1376, 8, 8, 128, 8),
    "large": ModelConfig("large", 8192, 768, 2048, 12, 12, 128, 8),  # ~100M
    # Paper Table 10 shapes (memory accounting only on this testbed)
    "llama60m": ModelConfig("llama60m", 32000, 512, 1376, 8, 8, 256, 128),
    "llama130m": ModelConfig("llama130m", 32000, 768, 2048, 12, 12, 256, 128),
    "llama350m": ModelConfig("llama350m", 32000, 1024, 2736, 16, 24, 256, 128),
    # Table 10 lists 4096x32 for "1.3B" (typo — that is ~6.4B); GaLore-lineage 1B:
    "llama1b": ModelConfig("llama1b", 32000, 2048, 5461, 16, 24, 256, 256),
    "llama7b": ModelConfig("llama7b", 32000, 4096, 11008, 32, 32, 256, 512),
}


# ------------------------------------------------------------ parameters ---
def param_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...], float]]:
    """(name, shape, init_std) in the canonical flat order.

    Linear weights are stored (in_features, out_features): y = x @ W.
    """
    d, f, v = cfg.dim, cfg.inter, cfg.vocab
    specs: List[Tuple[str, Tuple[int, ...], float]] = [
        ("embed", (v, d), 0.02),
    ]
    for i in range(cfg.layers):
        p = f"layer{i}."
        specs += [
            (p + "attn_norm", (d,), 0.0),     # RMSNorm gain (init 1)
            (p + "wq", (d, d), 0.02),
            (p + "wk", (d, d), 0.02),
            (p + "wv", (d, d), 0.02),
            (p + "wo", (d, d), 0.02 / math.sqrt(2 * cfg.layers)),
            (p + "mlp_norm", (d,), 0.0),
            (p + "w_gate", (d, f), 0.02),
            (p + "w_up", (d, f), 0.02),
            (p + "w_down", (f, d), 0.02 / math.sqrt(2 * cfg.layers)),
        ]
    specs += [
        ("final_norm", (d,), 0.0),
        ("lm_head", (d, v), 0.02),
    ]
    return specs


def init_params(cfg: ModelConfig, seed: int = 0) -> List[jnp.ndarray]:
    key = jax.random.PRNGKey(seed)
    out = []
    for name, shape, std in param_specs(cfg):
        key, sub = jax.random.split(key)
        if std == 0.0:
            out.append(jnp.ones(shape, jnp.float32))
        else:
            out.append(std * jax.random.normal(sub, shape, jnp.float32))
    return out


def num_params(cfg: ModelConfig) -> int:
    return sum(int(jnp.prod(jnp.asarray(s))) for _, s, _ in param_specs(cfg))


# --------------------------------------------------------------- forward ---
def _rms_norm(x: jnp.ndarray, gain: jnp.ndarray, eps: float = 1e-5):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * gain


def _rotary(x: jnp.ndarray, base: float = 10000.0):
    """x: [B, T, H, Dh] -> rotary-embedded."""
    b, t, h, dh = x.shape
    half = dh // 2
    freqs = jnp.exp(-math.log(base) * jnp.arange(half, dtype=jnp.float32) / half)
    angles = jnp.arange(t, dtype=jnp.float32)[:, None] * freqs[None, :]
    cos = jnp.cos(angles)[None, :, None, :]
    sin = jnp.sin(angles)[None, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _attention(x, wq, wk, wv, wo, cfg: ModelConfig):
    b, t, d = x.shape
    h, dh = cfg.heads, cfg.head_dim
    q = (x @ wq).reshape(b, t, h, dh)
    k = (x @ wk).reshape(b, t, h, dh)
    v = (x @ wv).reshape(b, t, h, dh)
    q, k = _rotary(q), _rotary(k)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(dh)
    mask = jnp.tril(jnp.ones((t, t), bool))
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqk,bkhd->bqhd", probs, v).reshape(b, t, d)
    return ctx @ wo


def _mlp(x, w_gate, w_up, w_down):
    return (jax.nn.silu(x @ w_gate) * (x @ w_up)) @ w_down


def forward(params: List[jnp.ndarray], tokens: jnp.ndarray,
            cfg: ModelConfig) -> jnp.ndarray:
    """tokens [B, T] int32 -> logits [B, T, V]."""
    it = iter(params)
    nxt = lambda: next(it)
    embed = nxt()
    x = embed[tokens]
    for _ in range(cfg.layers):
        attn_norm, wq, wk, wv, wo = nxt(), nxt(), nxt(), nxt(), nxt()
        mlp_norm, w_gate, w_up, w_down = nxt(), nxt(), nxt(), nxt()
        x = x + _attention(_rms_norm(x, attn_norm), wq, wk, wv, wo, cfg)
        x = x + _mlp(_rms_norm(x, mlp_norm), w_gate, w_up, w_down)
    final_norm, lm_head = nxt(), nxt()
    return _rms_norm(x, final_norm) @ lm_head


def loss_fn(params: List[jnp.ndarray], tokens: jnp.ndarray,
            cfg: ModelConfig) -> jnp.ndarray:
    """Mean next-token cross entropy over [B, T-1]."""
    logits = forward(params, tokens[:, :-1], cfg)
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def grad_step(params: List[jnp.ndarray], tokens: jnp.ndarray,
              cfg: ModelConfig):
    """(loss, [grads...]) — what `grad_step.hlo` computes."""
    loss, grads = jax.value_and_grad(lambda ps: loss_fn(ps, tokens, cfg))(params)
    return loss, grads
