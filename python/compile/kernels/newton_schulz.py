"""Newton-Schulz square-root / inverse-square-root iteration (App. B.8).

Substrate for the whitening operator (Sec. 3.3) used by the Muon and SWAN
baselines and for Shampoo's inverse fourth roots — all expressed through the
blocked ``matmul`` kernel so the contraction work lands on the MXU tiling.
Five iterations suffice in practice (Huang et al. 2019).
"""

from __future__ import annotations

import jax.numpy as jnp

from .matmul import matmul

EPS = 1e-8


def ns_step(y: jnp.ndarray, z: jnp.ndarray):
    """One NS iteration; matches ``ref.ns_step``."""
    n = y.shape[0]
    t = 3.0 * jnp.eye(n, dtype=y.dtype) - matmul(z, y)
    return 0.5 * matmul(y, t), 0.5 * matmul(t, z)


def newton_schulz(a: jnp.ndarray, iters: int = 5):
    """(√A, A^-½) for SPD A; matches ``ref.newton_schulz``."""
    fro = jnp.sqrt(jnp.sum(a * a)) + EPS
    y = a / fro
    z = jnp.eye(a.shape[0], dtype=a.dtype)
    for _ in range(iters):
        y, z = ns_step(y, z)
    return y * jnp.sqrt(fro), z / jnp.sqrt(fro)


def whiten(g: jnp.ndarray, iters: int = 6) -> jnp.ndarray:
    """(GGᵀ)^-½ G; matches ``ref.whiten``. The Muon/SWAN orthogonalizer."""
    m = g.shape[0]
    a = matmul(g, g.T) + 1e-4 * jnp.eye(m, dtype=g.dtype)
    _, inv_sqrt = newton_schulz(a, iters)
    return matmul(inv_sqrt, g)


def inv_fourth_root(a: jnp.ndarray, iters: int = 6) -> jnp.ndarray:
    """A^-¼ for SPD A via two nested NS runs: A^-¼ = (A^½)^-½.

    Used by the Shampoo baseline (Alg. 5) to avoid LAPACK custom-calls that
    the XLA 0.5.1 runtime cannot load — see DESIGN.md §Substitutions.
    """
    sqrt_a, _ = newton_schulz(a, iters)
    m = a.shape[0]
    sqrt_a = 0.5 * (sqrt_a + sqrt_a.T) + 1e-6 * jnp.eye(m, dtype=a.dtype)
    _, inv_sqrt = newton_schulz(sqrt_a, iters)
    return inv_sqrt
