"""Fused Adam update as a single elementwise Pallas pass.

Adam is the purely-diagonal FIM structure (Proposition 1): the second moment
is the optimal Diag_v approximation of E[g g^T]. The fusion folds the two
EMA updates, the bias corrections, and the rsqrt-normalized direction into
one VMEM-resident pass — three HBM reads (g, m, v), three writes
(m', v', Δ) — instead of the six-pass unfused sequence.

Used standalone (plain Adam) and in rotated space for Eigen-Adam / Alice
(where g is σ = UᵀG).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import _util as U


def _adam_kernel(g_ref, m_ref, v_ref, sc_ref, m_out, v_out, d_out):
    b1, b2, eps, bc1, bc2 = (sc_ref[k] for k in range(5))
    g = g_ref[...]
    m2 = b1 * m_ref[...] + (1.0 - b1) * g
    v2 = b2 * v_ref[...] + (1.0 - b2) * g * g
    m_out[...] = m2
    v_out[...] = v2
    d_out[...] = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + eps)


def adam_fused(g: jnp.ndarray, m: jnp.ndarray, v: jnp.ndarray,
               b1: float, b2: float, eps: float, bc1, bc2):
    """One fused Adam step; matches ``ref.adam_fused``.

    bc1 = 1 - b1^t, bc2 = 1 - b2^t arrive as traced scalars (the step
    counter is owned by the rust coordinator and fed per step).
    """
    orig = g.shape
    g2 = g.reshape(orig) if g.ndim == 2 else g.reshape(1, -1)
    m2 = m.reshape(g2.shape)
    v2 = v.reshape(g2.shape)
    mm, nn = g2.shape
    bm, bn = U.pick_block(mm), U.pick_block(nn)
    gp, mp_, vp = U.pad2(g2, bm, bn), U.pad2(m2, bm, bn), U.pad2(v2, bm, bn)
    sc = jnp.stack([jnp.asarray(b1, g.dtype), jnp.asarray(b2, g.dtype),
                    jnp.asarray(eps, g.dtype),
                    jnp.asarray(bc1, g.dtype), jnp.asarray(bc2, g.dtype)])
    grid = (gp.shape[0] // bm, gp.shape[1] // bn)
    tile = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    svec = pl.BlockSpec((5,), lambda i, j: (0,))
    shape = jax.ShapeDtypeStruct(gp.shape, g.dtype)
    m_new, v_new, delta = pl.pallas_call(
        _adam_kernel,
        grid=grid,
        in_specs=[tile, tile, tile, svec],
        out_specs=(tile, tile, tile),
        out_shape=(shape, shape, shape),
        interpret=U.INTERPRET,
    )(gp, mp_, vp, sc)
    cut = lambda a: a[:mm, :nn].reshape(orig)
    return cut(m_new), cut(v_new), cut(delta)
