"""Pallas kernels for RACS (Row and Column Scaled SGD), Algorithm 1.

Three kernels:

* ``racs_col_stats``  — s_raw[j] = Σ_i G²ᵢⱼ qᵢ   (Eq. 16, right scaling)
* ``racs_row_stats``  — q_raw[i] = Σ_j G²ᵢⱼ sⱼ   (Eq. 16, left scaling)
* ``racs_apply``      — Q^-½ G S^-½ · scale      (Alg. 1 line 8, one pass)

The fixed-point loop itself (5 iterations per the paper) lives in
``racs_fixed_point`` below and alternates the two stats kernels; the
normalizations ‖q‖², ‖s‖² are O(m+n) and stay in plain jnp.

Tiling: the stats kernels walk the grid with the reduction dimension as the
*minor* (sequentially-iterated) axis and accumulate into a VMEM output block,
the standard TPU reduction pattern. Zero padding is exact for squared
reductions.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import _util as U

EPS = 1e-8


def _col_stats_kernel(g_ref, q_ref, o_ref):
    i = pl.program_id(1)  # reduction step over row-blocks

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    g = g_ref[...]
    o_ref[...] += jnp.sum(g * g * q_ref[...][:, None], axis=0)


def racs_col_stats(g: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """s_raw[j] = Σ_i G²ᵢⱼ qᵢ  — matches ``ref.racs_col_stats``."""
    m, n = g.shape
    bm, bn = U.pick_block(m), U.pick_block(n)
    gp, qp = U.pad2(g, bm, bn), U.pad1(q, bm)
    mp, np_ = gp.shape
    out = pl.pallas_call(
        _col_stats_kernel,
        grid=(np_ // bn, mp // bm),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda j, i: (i, j)),
            pl.BlockSpec((bm,), lambda j, i: (i,)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda j, i: (j,)),
        out_shape=jax.ShapeDtypeStruct((np_,), g.dtype),
        interpret=U.INTERPRET,
    )(gp, qp)
    return out[:n]


def _row_stats_kernel(g_ref, s_ref, o_ref):
    j = pl.program_id(1)  # reduction step over column-blocks

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    g = g_ref[...]
    o_ref[...] += jnp.sum(g * g * s_ref[...][None, :], axis=1)


def racs_row_stats(g: jnp.ndarray, s: jnp.ndarray) -> jnp.ndarray:
    """q_raw[i] = Σ_j G²ᵢⱼ sⱼ  — matches ``ref.racs_row_stats``."""
    m, n = g.shape
    bm, bn = U.pick_block(m), U.pick_block(n)
    gp, sp = U.pad2(g, bm, bn), U.pad1(s, bn)
    mp, np_ = gp.shape
    out = pl.pallas_call(
        _row_stats_kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((mp,), g.dtype),
        interpret=U.INTERPRET,
    )(gp, sp)
    return out[:m]


def _apply_kernel(g_ref, q_ref, s_ref, c_ref, o_ref):
    g = g_ref[...]
    q = q_ref[...][:, None]
    s = s_ref[...][None, :]
    o_ref[...] = c_ref[0] * g * jax.lax.rsqrt(q + EPS) * jax.lax.rsqrt(s + EPS)


def racs_apply(g: jnp.ndarray, q: jnp.ndarray, s: jnp.ndarray,
               scale=1.0) -> jnp.ndarray:
    """Two-sided scaling Q^-½ G S^-½ · scale in a single fused pass.

    Matches ``ref.racs_apply``. ``scale`` may fold in λ·η·α from Alg. 1.
    """
    m, n = g.shape
    bm, bn = U.pick_block(m), U.pick_block(n)
    gp = U.pad2(g, bm, bn)
    # Pad the scaling vectors with ONES so rsqrt stays finite in dead tiles.
    qp = jnp.concatenate([q, jnp.ones(gp.shape[0] - m, q.dtype)])
    sp = jnp.concatenate([s, jnp.ones(gp.shape[1] - n, s.dtype)])
    c = jnp.asarray([scale], dtype=g.dtype)
    mp, np_ = gp.shape
    out = pl.pallas_call(
        _apply_kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), g.dtype),
        interpret=U.INTERPRET,
    )(gp, qp, sp, c)
    return out[:m, :n]


def racs_fixed_point(g: jnp.ndarray, iters: int = 5):
    """Proposition 3 fixed point via the Pallas stats kernels.

    Matches ``ref.racs_fixed_point`` (q initialized to ones, 1-sample E[.]).
    """
    m, n = g.shape
    q = jnp.ones((m,), g.dtype)
    s = jnp.ones((n,), g.dtype)
    for _ in range(iters):
        s = racs_col_stats(g, q) / (jnp.sum(q * q) + EPS)
        q = racs_row_stats(g, s) / (jnp.sum(s * s) + EPS)
    return s, q
