"""Shared tiling utilities for the Pallas kernels.

Block sizes are chosen for TPU VMEM (see DESIGN.md §Hardware-Adaptation):
128x128 f32 tiles are 64 KiB per operand, so a 3-operand kernel with double
buffering stays well under the ~16 MiB VMEM budget. Kernels require
block-aligned shapes; the public wrappers pad with zeros (exact for the
squared-reduction and elementwise kernels used here) and slice back.

interpret=True everywhere: real-TPU lowering emits a Mosaic custom-call the
CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import jax.numpy as jnp

# Default VMEM tile. Rows x cols of an f32 tile = 64 KiB.
BLOCK = 128
INTERPRET = True


def ceil_to(x: int, b: int) -> int:
    return ((x + b - 1) // b) * b


def pad2(a: jnp.ndarray, bm: int, bn: int) -> jnp.ndarray:
    """Zero-pad a 2-D array up to (ceil(m/bm)*bm, ceil(n/bn)*bn)."""
    m, n = a.shape
    pm, pn = ceil_to(m, bm) - m, ceil_to(n, bn) - n
    if pm == 0 and pn == 0:
        return a
    return jnp.pad(a, ((0, pm), (0, pn)))


def pad1(a: jnp.ndarray, b: int) -> jnp.ndarray:
    n = a.shape[0]
    p = ceil_to(n, b) - n
    return a if p == 0 else jnp.pad(a, (0, p))


def pick_block(dim: int, pref: int = BLOCK) -> int:
    """Use the preferred tile unless the dim is smaller (tiny test shapes)."""
    return min(pref, max(8, 1 << (dim - 1).bit_length())) if dim < pref else pref
