"""Rotated-space second-moment kernel for Eigen-Adam / Alice (Eq. 12/13).

Fuses v' = β₂v + (1-β₂)σ⊙² with the normalized direction σ/√(v'+ε) in one
elementwise VMEM pass over the projected gradient σ = UᵀG. Combined with
``matmul.project`` / ``matmul.reconstruct`` this is the full Eigen-Adam
update Mat(F̃^-½ ḡ) = U · (UᵀG)/√E[(UᵀG)⊙²].
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import _util as U


def _second_moment_kernel(s_ref, v_ref, c_ref, v_out, d_out):
    b2, eps = c_ref[0], c_ref[1]
    s = s_ref[...]
    v2 = b2 * v_ref[...] + (1.0 - b2) * s * s
    v_out[...] = v2
    d_out[...] = s / (jnp.sqrt(v2) + eps)


def second_moment(sigma: jnp.ndarray, v: jnp.ndarray, b2: float, eps: float):
    """Matches ``ref.second_moment``: returns (v', σ/√(v'+ε))."""
    m, n = sigma.shape
    bm, bn = U.pick_block(m), U.pick_block(n)
    sp, vp = U.pad2(sigma, bm, bn), U.pad2(v, bm, bn)
    c = jnp.asarray([b2, eps], dtype=sigma.dtype)
    tile = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    shape = jax.ShapeDtypeStruct(sp.shape, sigma.dtype)
    v_new, d = pl.pallas_call(
        _second_moment_kernel,
        grid=(sp.shape[0] // bm, sp.shape[1] // bn),
        in_specs=[tile, tile, pl.BlockSpec((2,), lambda i, j: (0,))],
        out_specs=(tile, tile),
        out_shape=(shape, shape),
        interpret=U.INTERPRET,
    )(sp, vp, c)
    return v_new[:m, :n], d[:m, :n]
