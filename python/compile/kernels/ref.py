"""Pure-jnp oracles for every Pallas kernel in this package.

These are the CORE correctness signal: each kernel in `racs_scale.py`,
`adam_update.py`, `matmul.py`, `eigen_rotate.py`, `compensation.py` and
`newton_schulz.py` is checked against the function of the same name here by
`python/tests/test_kernels.py` (hypothesis sweeps over shapes / dtypes).

All formulas reference the paper: Gong et al. 2025, "Towards Efficient
Optimizer Design for LLM via Structured Fisher Approximation with a Low-Rank
Extension" — equation / algorithm numbers quoted inline.
"""

from __future__ import annotations

import jax.numpy as jnp

EPS = 1e-8


# ---------------------------------------------------------------- RACS ----
def racs_col_stats(g: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """s_raw[j] = sum_i G_ij^2 * q_i   (one half of the Eq. 16 fixed point).

    With P = G^{.2} this is P^T q; dividing by ||q||^2 outside the kernel
    gives the `s` update of Proposition 3.
    """
    return jnp.einsum("ij,i->j", g * g, q)


def racs_row_stats(g: jnp.ndarray, s: jnp.ndarray) -> jnp.ndarray:
    """q_raw[i] = sum_j G_ij^2 * s_j   (the other half of Eq. 16)."""
    return jnp.einsum("ij,j->i", g * g, s)


def racs_fixed_point(g: jnp.ndarray, iters: int = 5):
    """Proposition 3: iterate s,q to the principal singular pair of G^{.2}.

    Returns (s, q) normalized the way Algorithm 1 consumes them (q init 1,
    1-sample estimate of E[.]). Both stay strictly positive when G^{.2} is
    positive (Perron-Frobenius).
    """
    m, n = g.shape
    q = jnp.ones((m,), g.dtype)
    s = jnp.ones((n,), g.dtype)
    for _ in range(iters):
        s = racs_col_stats(g, q) / (jnp.sum(q * q) + EPS)
        q = racs_row_stats(g, s) / (jnp.sum(s * s) + EPS)
    return s, q


def racs_apply(g: jnp.ndarray, q: jnp.ndarray, s: jnp.ndarray,
               scale: float | jnp.ndarray = 1.0) -> jnp.ndarray:
    """Algorithm 1 line 8: G~ = Diag(q)^-1/2 G Diag(s)^-1/2, times a scale."""
    return scale * g * jnp.power(q[:, None] + EPS, -0.5) \
        * jnp.power(s[None, :] + EPS, -0.5)


# ---------------------------------------------------------------- Adam ----
def adam_fused(g, m, v, b1: float, b2: float, eps: float, bc1, bc2):
    """One fused Adam step: EMA moments + bias-corrected update direction.

    bc1 = 1 - b1^t and bc2 = 1 - b2^t are passed in (they depend on the step
    counter which lives in the coordinator). Returns (m', v', delta).
    """
    m2 = b1 * m + (1.0 - b1) * g
    v2 = b2 * v + (1.0 - b2) * g * g
    delta = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + eps)
    return m2, v2, delta


# -------------------------------------------------------------- matmul ----
def matmul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Plain contraction; the Pallas twin is the blocked/tiled version."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


# --------------------------------------------------- rotated 2nd moment ----
def second_moment(sigma: jnp.ndarray, v: jnp.ndarray, b2: float, eps: float):
    """Eigen-Adam / Alice second moment in the rotated space (Eq. 13):
    v' = b2 v + (1-b2) sigma^{.2};  out = sigma / sqrt(v' + eps).
    Returns (v', out)."""
    v2 = b2 * v + (1.0 - b2) * sigma * sigma
    return v2, sigma / (jnp.sqrt(v2) + eps)


# --------------------------------------------------------- compensation ----
def compensation(g: jnp.ndarray, p_proj: jnp.ndarray, p_vec: jnp.ndarray,
                 scale: float | jnp.ndarray) -> jnp.ndarray:
    """Algorithm 3 line 3 (Thm 5.1): C = scale * (G - U U^T G) diag(p)^-1/2.

    `p_proj` is U U^T G (computed by the matmul kernel), `p_vec` the EMA of
    1_m^T G^{.2} - 1_r^T (U^T G)^{.2}, `scale` is sqrt(m - r).
    """
    return scale * (g - p_proj) * jnp.power(p_vec[None, :] + EPS, -0.5)


def compensation_pvec(g: jnp.ndarray, sigma: jnp.ndarray) -> jnp.ndarray:
    """Algorithm 3 line 2 innards: 1_m^T G^{.2} - 1_r^T (U^T G)^{.2}  (>= 0)."""
    return jnp.sum(g * g, axis=0) - jnp.sum(sigma * sigma, axis=0)


# ------------------------------------------------------- Newton-Schulz ----
def ns_step(y: jnp.ndarray, z: jnp.ndarray):
    """One Newton-Schulz iteration (App. B.8):
    Y' = 0.5 * Y (3I - Z Y);  Z' = 0.5 * (3I - Z Y) Z."""
    n = y.shape[0]
    t = 3.0 * jnp.eye(n, dtype=y.dtype) - matmul(z, y)
    return 0.5 * matmul(y, t), 0.5 * matmul(t, z)


def newton_schulz(a: jnp.ndarray, iters: int = 5):
    """Full NS run on SPD `a`: returns (sqrt(a), inv_sqrt(a)) estimates."""
    fro = jnp.sqrt(jnp.sum(a * a)) + EPS
    y = a / fro
    z = jnp.eye(a.shape[0], dtype=a.dtype)
    for _ in range(iters):
        y, z = ns_step(y, z)
    return y * jnp.sqrt(fro), z / jnp.sqrt(fro)


def inv_fourth_root(a: jnp.ndarray, iters: int = 6) -> jnp.ndarray:
    """A^-1/4 via nested NS — oracle for ``newton_schulz.inv_fourth_root``."""
    sqrt_a, _ = newton_schulz(a, iters)
    m = a.shape[0]
    sqrt_a = 0.5 * (sqrt_a + sqrt_a.T) + 1e-6 * jnp.eye(m, dtype=a.dtype)
    _, inv_sqrt = newton_schulz(sqrt_a, iters)
    return inv_sqrt


def whiten(g: jnp.ndarray, iters: int = 6) -> jnp.ndarray:
    """Whitening operator (Sec. 3.3): (G G^T)^{-1/2} G via Newton-Schulz."""
    m = g.shape[0]
    a = matmul(g, g.T) + 1e-4 * jnp.eye(m, dtype=g.dtype)
    _, inv_sqrt = newton_schulz(a, iters)
    return matmul(inv_sqrt, g)


# ------------------------------------------------- norm-growth limiter ----
def limiter(delta_norm, phi_prev, gamma: float):
    """Norm-growth limiter of Chen et al. 2024a used by RACS (Alg. 1 l.9-10)
    and Alice compensation (Alg. 3 l.4-5): eta = gamma / max(dn/phi, gamma)
    when phi > 0 else 1; phi' = eta * dn. Returns (eta, phi')."""
    ratio = jnp.where(phi_prev > 0.0, delta_norm / (phi_prev + EPS), gamma)
    eta = jnp.where(phi_prev > 0.0,
                    gamma / jnp.maximum(ratio, gamma),
                    jnp.ones_like(delta_norm))
    return eta, eta * delta_norm
