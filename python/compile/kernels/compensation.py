"""Alice compensation kernel (Algorithm 3 / Theorem 5.1).

C = √(m−r) · (G − UUᵀG) · diag(p)^-½ — the optimal structured square-root
NGD on the complement FIM F̃_c. The projector residual G − UUᵀG arrives
precomputed (two ``matmul`` kernel calls); this kernel fuses the subtraction
and the per-column rsqrt scaling in one VMEM pass.

Also provides ``compensation_pvec``: the reduction
1ₘᵀG⊙² − 1ᵣᵀ(UᵀG)⊙² feeding the EMA `p` (Alg. 3 line 2), as a Pallas
column-reduction sharing the tiling of racs_col_stats.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import _util as U

EPS = 1e-8


def _comp_kernel(g_ref, pr_ref, p_ref, c_ref, o_ref):
    scale = c_ref[0]
    p = p_ref[...][None, :]
    o_ref[...] = scale * (g_ref[...] - pr_ref[...]) * jax.lax.rsqrt(p + EPS)


def compensation(g: jnp.ndarray, p_proj: jnp.ndarray, p_vec: jnp.ndarray,
                 scale) -> jnp.ndarray:
    """Matches ``ref.compensation``. `p_proj` = UUᵀG, `scale` = √(m−r)."""
    m, n = g.shape
    bm, bn = U.pick_block(m), U.pick_block(n)
    gp, prp = U.pad2(g, bm, bn), U.pad2(p_proj, bm, bn)
    pv = jnp.concatenate([p_vec, jnp.ones(gp.shape[1] - n, p_vec.dtype)])
    c = jnp.asarray([scale], dtype=g.dtype)
    tile = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    out = pl.pallas_call(
        _comp_kernel,
        grid=(gp.shape[0] // bm, gp.shape[1] // bn),
        in_specs=[tile, tile,
                  pl.BlockSpec((bn,), lambda i, j: (j,)),
                  pl.BlockSpec((1,), lambda i, j: (0,))],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct(gp.shape, g.dtype),
        interpret=U.INTERPRET,
    )(gp, prp, pv, c)
    return out[:m, :n]


def _pvec_kernel(g_ref, o_ref):
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    g = g_ref[...]
    o_ref[...] += jnp.sum(g * g, axis=0)


def _colsq(x: jnp.ndarray) -> jnp.ndarray:
    m, n = x.shape
    bm, bn = U.pick_block(m), U.pick_block(n)
    xp = U.pad2(x, bm, bn)
    out = pl.pallas_call(
        _pvec_kernel,
        grid=(xp.shape[1] // bn, xp.shape[0] // bm),
        in_specs=[pl.BlockSpec((bm, bn), lambda j, i: (i, j))],
        out_specs=pl.BlockSpec((bn,), lambda j, i: (j,)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[1],), x.dtype),
        interpret=U.INTERPRET,
    )(xp)
    return out[:n]


def compensation_pvec(g: jnp.ndarray, sigma: jnp.ndarray) -> jnp.ndarray:
    """Matches ``ref.compensation_pvec``: 1ₘᵀG⊙² − 1ᵣᵀσ⊙² per column."""
    return _colsq(g) - _colsq(sigma)
