"""Blocked matmul Pallas kernel — the MXU-shaped workhorse.

Every matrix-product hot spot of the optimizers routes through here:
projections UᵀG and U·ω (Eigen-Adam / Alice / GaLore), the reconstruction
UUᵀG for compensation, the Newton-Schulz iterations for whitening
(Muon / SWAN / Shampoo roots), and the subspace-iteration step A·U.

The grid is (M/bm, N/bn, K/bk) with K minor, so each output tile stays
resident in VMEM across the contraction — the Pallas analogue of the paper's
GPU threadblock accumulation. Zero padding is exact for matmul.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import _util as U


def _matmul_kernel(a_ref, b_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                          preferred_element_type=o_ref.dtype)


def matmul(a: jnp.ndarray, b: jnp.ndarray,
           bm: int | None = None, bn: int | None = None,
           bk: int | None = None) -> jnp.ndarray:
    """C = A @ B with VMEM tiling; matches ``ref.matmul``."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"contraction mismatch {a.shape} @ {b.shape}"
    bm = bm or U.pick_block(m)
    bn = bn or U.pick_block(n)
    bk = bk or U.pick_block(k)
    ap = U.pad2(a, bm, bk)
    bp = U.pad2(b, bk, bn)
    mp, kp = ap.shape
    _, np_ = bp.shape
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=U.INTERPRET,
    )(ap, bp)
    return out[:m, :n].astype(a.dtype)


def project(u: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    """σ = Uᵀ G  (Alg. 4 line 11)."""
    return matmul(u.T, g)


def reconstruct(u: jnp.ndarray, sigma: jnp.ndarray) -> jnp.ndarray:
    """G̃ = U σ — the low-rank reconstructed gradient / update."""
    return matmul(u, sigma)
