"""Layer-1 Pallas kernels for the paper's optimizer hot spots.

Every kernel has a pure-jnp oracle of the same name in ``ref`` and is
validated against it by ``python/tests/test_kernels.py``. All kernels lower
with ``interpret=True`` (CPU PJRT cannot execute Mosaic custom-calls); the
BlockSpec tiling is nevertheless written for TPU VMEM — see
DESIGN.md §Hardware-Adaptation.
"""

from . import ref  # noqa: F401
from .adam_update import adam_fused  # noqa: F401
from .compensation import compensation, compensation_pvec  # noqa: F401
from .eigen_rotate import second_moment  # noqa: F401
from .matmul import matmul, project, reconstruct  # noqa: F401
from .newton_schulz import (  # noqa: F401
    inv_fourth_root,
    newton_schulz,
    ns_step,
    whiten,
)
from .racs_scale import (  # noqa: F401
    racs_apply,
    racs_col_stats,
    racs_fixed_point,
    racs_row_stats,
)


# --------------------------------------------------------------------------
# Ref-mode switch (EXPERIMENTS.md §Perf L2-1): interpret-mode Pallas inside
# a fused train step costs ~3-10x on CPU PJRT (it exists for TPU tiling
# structure + correctness, not CPU speed). `set_ref_mode(True)` rebinds the
# exported kernel names to their pure-jnp oracles before AOT lowering;
# `aot.py --ref-kernels` uses it for CPU-production bundles. The Pallas
# versions stay the default and are always exercised by the standalone
# `opt_update_*` artifacts and the pytest suite.
_PALLAS_IMPLS = {
    "adam_fused": adam_fused,
    "compensation": compensation,
    "compensation_pvec": compensation_pvec,
    "second_moment": second_moment,
    "matmul": matmul,
    "newton_schulz": newton_schulz,
    "ns_step": ns_step,
    "whiten": whiten,
    "inv_fourth_root": inv_fourth_root,
    "racs_apply": racs_apply,
    "racs_col_stats": racs_col_stats,
    "racs_fixed_point": racs_fixed_point,
    "racs_row_stats": racs_row_stats,
}


def set_ref_mode(enabled: bool) -> None:
    """Swap the module-level kernel bindings between Pallas and ref."""
    import sys

    mod = sys.modules[__name__]
    src = ref if enabled else None
    for name, pallas_fn in _PALLAS_IMPLS.items():
        impl = getattr(ref, name) if enabled else pallas_fn
        setattr(mod, name, impl)
    # project/reconstruct are thin matmul wrappers
    if enabled:
        mod.project = lambda u, g: ref.matmul(u.T, g)
        mod.reconstruct = lambda u, s: ref.matmul(u, s)
    else:
        from .matmul import project as _p, reconstruct as _r
        mod.project = _p
        mod.reconstruct = _r
    del src
