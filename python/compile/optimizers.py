"""Every optimizer in the paper as a pure JAX function.

Each optimizer is three functions over a single 2-D parameter (the paper
treats each layer's matrix independently — Sec. 2.1):

* ``<name>_init(shape, hp)``            -> state: ``dict[str, jnp.ndarray]``
* ``<name>_update(g, state, hp, t)``    -> ``(delta, state')``
* ``<name>_refresh(g, state, hp, seed)``-> state'  (only projection-based
  optimizers; called every ``hp.interval`` steps by the coordinator — the
  paper's K-block amortization, Sec. 5 "Reduce computational cost")

``delta`` is the descent direction: the trainer applies W ← W − lr·delta.
Any paper-specific scale (α, α_c) is folded into delta so the trainer stays
optimizer-agnostic.

The registry ``OPTIMIZERS`` at the bottom is what ``aot.py`` lowers and what
``python/tests/test_optimizers.py`` sweeps. State dicts have deterministic
insertion order; the AOT manifest pins that order for the rust side.

Everything here must stay loadable by XLA 0.5.1 ⇒ no LAPACK
(``linalg.full_eigh`` / ``linalg.mgs_qr`` instead), randomness via
threefry (``jax.random`` with an explicit seed input).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from . import linalg
from . import kernels as _K


# Late-bound kernel dispatch so `kernels.set_ref_mode` (perf experiment
# L2-1, used by `aot.py --ref-kernels`) affects lowering without reimports.
def adam_fused(*a, **k):
    return _K.adam_fused(*a, **k)


def comp_kernel(*a, **k):
    return _K.compensation(*a, **k)


def compensation_pvec(*a, **k):
    return _K.compensation_pvec(*a, **k)


def inv_fourth_root(*a, **k):
    return _K.inv_fourth_root(*a, **k)


def racs_apply(*a, **k):
    return _K.racs_apply(*a, **k)


def racs_fixed_point(*a, **k):
    return _K.racs_fixed_point(*a, **k)


def second_moment(*a, **k):
    return _K.second_moment(*a, **k)


def whiten(*a, **k):
    return _K.whiten(*a, **k)


def matmul(*a, **k):
    return _K.matmul(*a, **k)

EPS = 1e-8


@dataclasses.dataclass(frozen=True)
class HP:
    """Hyperparameters (paper App. F.2 tables 7-11 defaults)."""

    b1: float = 0.9          # first moment
    b2: float = 0.999        # second moment (0.9 for Alice, Table 11)
    b3: float = 0.999        # GGᵀ tracking EMA
    eps: float = 1e-8
    rank: int = 32           # low-rank r (GaLore / Alice / Fira)
    leading: int = 10        # leading basis number l (Alice switching)
    interval: int = 200      # projection update interval K
    alpha: float = 1.0       # update scale α
    alpha_c: float = 0.4     # compensation scale α_c
    gamma: float = 1.01      # norm-growth limiter threshold
    beta_racs: float = 0.9   # RACS EMA β (Table 9)
    racs_iters: int = 5      # fixed-point iterations (Sec. 4)
    ns_iters: int = 6        # Newton-Schulz iterations
    eig_iters: int = 40      # orthogonal-iteration sweeps for full EVD
    sub_iters: int = 1       # subspace-iteration steps (paper: 1 suffices)
    switch: str = "switch"   # Alice: switch|evd|gaussian|gaussian_mix|full_basis
    compen: str = "optimal"  # Alice: optimal|none|fira|fira_plus
    racs_ema: bool = True    # Fig. 5(e) ablation
    bias_correction: bool = True


Array = jnp.ndarray
State = Dict[str, Array]


def _bc(hp: HP, t: Array):
    """Bias-correction denominators 1-βᵗ (or 1.0 when disabled)."""
    if not hp.bias_correction:
        one = jnp.asarray(1.0, jnp.float32)
        return one, one
    return 1.0 - jnp.power(hp.b1, t), 1.0 - jnp.power(hp.b2, t)


def _limiter(delta: Array, phi: Array, gamma: float):
    """Norm-growth limiter (Alg. 1 l.9-10 / Alg. 3 l.4-5)."""
    dn = jnp.sqrt(jnp.sum(delta * delta)) + EPS
    ratio = jnp.where(phi > 0.0, dn / (phi + EPS), gamma)
    eta = jnp.where(phi > 0.0, gamma / jnp.maximum(ratio, gamma), 1.0)
    return eta * delta, eta * dn


# =============================================================== SGD =======
def sgd_init(shape, hp: HP) -> State:
    del shape, hp
    return {}


def sgd_update(g, state, hp: HP, t):
    del t
    return hp.alpha * g, state


# ============================================================== Adam =======
def adam_init(shape, hp: HP) -> State:
    del hp
    z = jnp.zeros(shape, jnp.float32)
    return {"m": z, "v": z}


def adam_update(g, state, hp: HP, t):
    bc1, bc2 = _bc(hp, t)
    m, v, delta = adam_fused(g, state["m"], state["v"],
                             hp.b1, hp.b2, hp.eps, bc1, bc2)
    return hp.alpha * delta, {"m": m, "v": v}


# ========================================================== Adafactor ======
def adafactor_init(shape, hp: HP) -> State:
    del hp
    m, n = shape
    return {"r": jnp.zeros((m,), jnp.float32),
            "c": jnp.zeros((n,), jnp.float32)}


def adafactor_update(g, state, hp: HP, t):
    """Rank-1 factored second moment (Shazeer & Stern 2018, simplified:
    no update clipping / relative step)."""
    del t
    g2 = g * g
    r = hp.b2 * state["r"] + (1.0 - hp.b2) * jnp.mean(g2, axis=1)
    c = hp.b2 * state["c"] + (1.0 - hp.b2) * jnp.mean(g2, axis=0)
    vhat = r[:, None] * c[None, :] / (jnp.mean(r) + EPS)
    return hp.alpha * g / (jnp.sqrt(vhat) + hp.eps), {"r": r, "c": c}


# ============================================================== Lion =======
def lion_init(shape, hp: HP) -> State:
    del hp
    return {"m": jnp.zeros(shape, jnp.float32)}


def lion_update(g, state, hp: HP, t):
    del t
    delta = jnp.sign(hp.b1 * state["m"] + (1.0 - hp.b1) * g)
    m = hp.b2 * state["m"] + (1.0 - hp.b2) * g
    return hp.alpha * delta, {"m": m}


# ============================================================ Signum =======
def signum_init(shape, hp: HP) -> State:
    del hp
    return {"m": jnp.zeros(shape, jnp.float32)}


def signum_update(g, state, hp: HP, t):
    del t
    m = hp.b1 * state["m"] + (1.0 - hp.b1) * g
    return hp.alpha * jnp.sign(m), {"m": m}


# ============================================================== Muon =======
def muon_init(shape, hp: HP) -> State:
    del hp
    return {"m": jnp.zeros(shape, jnp.float32)}


def muon_update(g, state, hp: HP, t):
    """Whitened momentum (App. B.9): Δ = (mmᵀ)^-½ m via Newton-Schulz.
    Operates on the short side (whitening needs the m×m Gram)."""
    del t
    m = hp.b1 * state["m"] + (1.0 - hp.b1) * g
    rows, cols = m.shape
    w = whiten(m, hp.ns_iters) if rows <= cols else whiten(m.T, hp.ns_iters).T
    return hp.alpha * w, {"m": m}


# ============================================================== SWAN =======
def swan_init(shape, hp: HP) -> State:
    del shape, hp
    return {}


def swan_update(g, state, hp: HP, t):
    """Stateless: GradNorm then GradWhitening (App. B.7)."""
    del t
    mean = jnp.mean(g, axis=1, keepdims=True)
    std = jnp.std(g, axis=1, keepdims=True) + EPS
    gn = (g - mean) / std
    rows, cols = g.shape
    w = whiten(gn, hp.ns_iters) if rows <= cols else whiten(gn.T, hp.ns_iters).T
    return hp.alpha * w, state


# ============================================================== RACS =======
def racs_init(shape, hp: HP) -> State:
    del hp
    m, n = shape
    return {"s": jnp.zeros((n,), jnp.float32),
            "q": jnp.zeros((m,), jnp.float32),
            "phi": jnp.zeros((), jnp.float32)}


def racs_update(g, state, hp: HP, t):
    """Algorithm 1. State: s[n], q[m], limiter φ — memory m+n+1."""
    s_new, q_new = racs_fixed_point(g, hp.racs_iters)
    if hp.racs_ema:
        # EMA warm-start: treat the first step as a plain assignment.
        first = jnp.asarray(t <= 1.0, jnp.float32)
        b = hp.beta_racs * (1.0 - first)
        s = b * state["s"] + (1.0 - b) * s_new
        q = b * state["q"] + (1.0 - b) * q_new
    else:
        s, q = s_new, q_new
    delta = racs_apply(g, q, s, 1.0)
    delta, phi = _limiter(delta, state["phi"], hp.gamma)
    return hp.alpha * delta, {"s": s, "q": q, "phi": phi}


# ======================================================== Eigen-Adam =======
def eigen_adam_init(shape, hp: HP) -> State:
    del hp
    m, n = shape
    return {"q": jnp.zeros((m, m), jnp.float32),
            "u": jnp.eye(m, dtype=jnp.float32),
            "m": jnp.zeros((m, n), jnp.float32),
            "v": jnp.zeros((m, n), jnp.float32)}


def eigen_adam_update(g, state, hp: HP, t):
    """Algorithm 7 (Eigen-Adam / AdaDiag / one-sided SOAP), Eq. 13."""
    q = hp.b3 * state["q"] + (1.0 - hp.b3) * matmul(g, g.T)
    m = hp.b1 * state["m"] + (1.0 - hp.b1) * g
    u = state["u"]
    sigma = matmul(u.T, g)
    v, _ = second_moment(sigma, state["v"], hp.b2, hp.eps)
    bc1, bc2 = _bc(hp, t)
    m_rot = matmul(u.T, m) / bc1
    direction = m_rot / (jnp.sqrt(v / bc2) + hp.eps)
    delta = matmul(u, direction)
    return hp.alpha * delta, {"q": q, "u": u, "m": m, "v": v}


def eigen_adam_refresh(g, state, hp: HP, seed):
    """U ← EVD(Q) (Alg. 7 refresh branch)."""
    del g, seed
    u, _ = linalg.full_eigh(state["q"], hp.eig_iters)
    return {**state, "u": u}


# ============================================================ Shampoo ======
def shampoo_init(shape, hp: HP) -> State:
    del hp
    m, n = shape
    return {"l": 1e-4 * jnp.eye(m, dtype=jnp.float32),
            "r": 1e-4 * jnp.eye(n, dtype=jnp.float32),
            "li4": jnp.eye(m, dtype=jnp.float32),
            "ri4": jnp.eye(n, dtype=jnp.float32)}


def shampoo_update(g, state, hp: HP, t):
    """Algorithm 5 with the root computation amortized to refreshes
    (Anil et al. 2020 practice). Δ = L^-¼ G R^-¼ (Thm 3.1 / App. C.1)."""
    del t
    l = state["l"] + matmul(g, g.T)
    r = state["r"] + matmul(g.T, g)
    delta = matmul(matmul(state["li4"], g), state["ri4"])
    return hp.alpha * delta, {"l": l, "r": r,
                              "li4": state["li4"], "ri4": state["ri4"]}


def shampoo_refresh(g, state, hp: HP, seed):
    del g, seed
    li4 = inv_fourth_root(state["l"], hp.ns_iters)
    ri4 = inv_fourth_root(state["r"], hp.ns_iters)
    return {**state, "li4": li4, "ri4": ri4}


# =============================================================== SOAP ======
def soap_init(shape, hp: HP) -> State:
    del hp
    m, n = shape
    return {"l": jnp.zeros((m, m), jnp.float32),
            "r": jnp.zeros((n, n), jnp.float32),
            "ul": jnp.eye(m, dtype=jnp.float32),
            "ur": jnp.eye(n, dtype=jnp.float32),
            "m": jnp.zeros((m, n), jnp.float32),
            "v": jnp.zeros((m, n), jnp.float32)}


def soap_update(g, state, hp: HP, t):
    """Algorithm 6 (SOAP / AdaDiag++): Adam in the two-sided eigenbasis
    (Thm 3.3 structure)."""
    l = hp.b3 * state["l"] + (1.0 - hp.b3) * matmul(g, g.T)
    r = hp.b3 * state["r"] + (1.0 - hp.b3) * matmul(g.T, g)
    m = hp.b1 * state["m"] + (1.0 - hp.b1) * g
    ul, ur = state["ul"], state["ur"]
    g_rot = matmul(matmul(ul.T, g), ur)
    v, _ = second_moment(g_rot, state["v"], hp.b2, hp.eps)
    bc1, bc2 = _bc(hp, t)
    m_rot = matmul(matmul(ul.T, m), ur) / bc1
    direction = m_rot / (jnp.sqrt(v / bc2) + hp.eps)
    delta = matmul(matmul(ul, direction), ur.T)
    return hp.alpha * delta, {"l": l, "r": r, "ul": ul, "ur": ur,
                              "m": m, "v": v}


def soap_refresh(g, state, hp: HP, seed):
    del g, seed
    ul, _ = linalg.full_eigh(state["l"], hp.eig_iters)
    ur, _ = linalg.full_eigh(state["r"], hp.eig_iters)
    return {**state, "ul": ul, "ur": ur}


# ============================================================= GaLore ======
def _rank(shape, hp: HP) -> int:
    return max(1, min(hp.rank, min(shape)))


def galore_init(shape, hp: HP) -> State:
    m, n = shape
    r = _rank(shape, hp)
    u0 = jnp.eye(m, dtype=jnp.float32)[:, :r]
    return {"u": u0,
            "m": jnp.zeros((r, n), jnp.float32),
            "v": jnp.zeros((r, n), jnp.float32)}


def galore_update(g, state, hp: HP, t):
    """Algorithm 8: Adam on σ = UᵀG, Δ = α U Adam(σ)."""
    sigma = matmul(state["u"].T, g)
    bc1, bc2 = _bc(hp, t)
    m, v, omega = adam_fused(sigma, state["m"], state["v"],
                             hp.b1, hp.b2, hp.eps, bc1, bc2)
    delta = matmul(state["u"], omega)
    return hp.alpha * delta, {"u": state["u"], "m": m, "v": v}


def galore_refresh(g, state, hp: HP, seed):
    """U ← top-r left singular vectors of G = top-r eigvecs of GGᵀ,
    via subspace iteration warm-started at the previous U."""
    del seed
    q = matmul(g, g.T)
    u, _ = linalg.subspace_iter(q, state["u"], hp.sub_iters)
    return {**state, "u": u}


# =============================================================== Fira ======
def fira_init(shape, hp: HP) -> State:
    st = galore_init(shape, hp)
    st["phi"] = jnp.zeros((), jnp.float32)
    return st


def fira_update(g, state, hp: HP, t):
    """GaLore + Fira compensation (Chen et al. 2024a): the residual
    (G − UUᵀG) rescaled by ‖ω‖/‖σ‖, with the norm-growth limiter."""
    u = state["u"]
    sigma = matmul(u.T, g)
    bc1, bc2 = _bc(hp, t)
    m, v, omega = adam_fused(sigma, state["m"], state["v"],
                             hp.b1, hp.b2, hp.eps, bc1, bc2)
    low = matmul(u, omega)
    resid = g - matmul(u, sigma)
    scale = jnp.sqrt(jnp.sum(omega * omega)) / (jnp.sqrt(jnp.sum(sigma * sigma)) + EPS)
    comp, phi = _limiter(scale * resid, state["phi"], hp.gamma)
    return hp.alpha * (low + comp), {"u": u, "m": m, "v": v, "phi": phi}


fira_refresh = galore_refresh


# ======================================================== Apollo-mini ======
def apollo_mini_init(shape, hp: HP) -> State:
    m, n = shape
    del hp
    return {"u": jnp.zeros((m, 1), jnp.float32),
            "m": jnp.zeros((1, n), jnp.float32),
            "v": jnp.zeros((1, n), jnp.float32),
            "phi": jnp.zeros((), jnp.float32)}


def apollo_mini_update(g, state, hp: HP, t):
    """Algorithm 9 with rank 1: scale the *raw* gradient by the global
    norm ratio ‖Δ_GaLore‖/‖σ‖ estimated through a random rank-1 sketch."""
    sigma = matmul(state["u"].T, g)
    bc1, bc2 = _bc(hp, t)
    m, v, omega = adam_fused(sigma, state["m"], state["v"],
                             hp.b1, hp.b2, hp.eps, bc1, bc2)
    scale = jnp.sqrt(jnp.sum(omega * omega)) / (jnp.sqrt(jnp.sum(sigma * sigma)) + EPS)
    delta, phi = _limiter(scale * g, state["phi"], hp.gamma)
    return hp.alpha * delta, {"u": state["u"], "m": m, "v": v, "phi": phi}


def apollo_mini_refresh(g, state, hp: HP, seed):
    """Resample the rank-1 Gaussian sketch (Alg. 9 refresh branch)."""
    del g, hp
    key = jax.random.PRNGKey(seed)
    u = jax.random.normal(key, state["u"].shape, jnp.float32)
    return {**state, "u": u}


# ========================================================== Alice(-0) ======
def alice_init(shape, hp: HP) -> State:
    m, n = shape
    r = _rank(shape, hp)
    return {"u": jnp.eye(m, dtype=jnp.float32)[:, :r],
            "qt": jnp.zeros((r, r), jnp.float32),
            "m": jnp.zeros((r, n), jnp.float32),
            "v": jnp.zeros((r, n), jnp.float32),
            "p": jnp.zeros((n,), jnp.float32),
            "phi": jnp.zeros((), jnp.float32)}


def _alice_compensation(g, u, sigma, state, hp: HP, t):
    """Dispatch on hp.compen — the Fig. 5(c) ablation axis."""
    m_rows = g.shape[0]
    r = sigma.shape[0]
    if hp.compen == "none":
        return jnp.zeros_like(g), state["p"], state["phi"]
    resid = g - matmul(u, sigma)
    if hp.compen in ("fira", "fira_plus"):
        scale = jnp.sqrt(jnp.sum(sigma * sigma))
        # Fira uses ‖ω‖/‖σ‖; here ω is the caller's low-rank update norm —
        # approximated by ‖σ‖-normalized residual for fira, and rescaled to
        # the low-rank update norm for fira_plus (App. F.7 setup).
        c = resid / (scale + EPS)
        c, phi = _limiter(c, state["phi"], hp.gamma)
        return c, state["p"], phi
    # 'optimal' — Theorem 5.1 / Algorithm 3.
    pvec_now = compensation_pvec(g, sigma)
    first = jnp.asarray(t <= 1.0, jnp.float32)
    b = hp.b1 * (1.0 - first)
    p = b * state["p"] + (1.0 - b) * pvec_now
    scale = jnp.sqrt(jnp.asarray(max(m_rows - r, 1), jnp.float32))
    c = comp_kernel(g, matmul(u, sigma), jnp.maximum(p, 0.0), scale)
    c, phi = _limiter(c, state["phi"], hp.gamma)
    return c, p, phi


def alice_update(g, state, hp: HP, t):
    """Algorithm 4 inner step (lines 11-17)."""
    u = state["u"]
    sigma = matmul(u.T, g)
    qt = hp.b3 * state["qt"] + (1.0 - hp.b3) * matmul(sigma, sigma.T)
    m = hp.b1 * state["m"] + (1.0 - hp.b1) * sigma
    v, _ = second_moment(sigma, state["v"], hp.b2, hp.eps)
    bc1, bc2 = _bc(hp, t)
    omega = (m / bc1) / (jnp.sqrt(v / bc2) + hp.eps)
    comp, p, phi = _alice_compensation(g, u, sigma, state, hp, t)
    delta = hp.alpha * (matmul(u, omega) + hp.alpha_c * comp)
    return delta, {"u": u, "qt": qt, "m": m, "v": v, "p": p, "phi": phi}


def _switch(q_rec, u_prev, hp: HP, seed):
    """Algorithm 2 (subspace switching) + the Fig. 5(b) strategy ablations."""
    m = q_rec.shape[0]
    r = u_prev.shape[1]
    l = min(hp.leading, r)
    key = jax.random.PRNGKey(seed)

    if hp.switch == "gaussian":
        u = jax.random.normal(key, (m, r), jnp.float32)
        return u / (jnp.sqrt(jnp.sum(u * u, axis=0, keepdims=True)) + EPS)

    u_new, _ = linalg.subspace_iter(q_rec, u_prev, hp.sub_iters)
    if hp.switch == "evd" or r == l or m == r:
        return u_new

    top = u_new[:, :l]
    if hp.switch == "gaussian_mix":
        gs = jax.random.normal(key, (m, r - l), jnp.float32)
        gs = gs / (jnp.sqrt(jnp.sum(gs * gs, axis=0, keepdims=True)) + EPS)
        return jnp.concatenate([top, gs], axis=1)

    u_c = linalg.complete_basis(u_new)  # m x (m-r)
    if hp.switch == "full_basis":
        pool = jnp.concatenate([u_new[:, l:], u_c], axis=1)  # m x (m-l)
    else:  # 'switch' — the paper's strategy: sample only from the complement
        pool = u_c
    perm = jax.random.permutation(key, pool.shape[1])
    picked = jnp.take(pool, perm[: r - l], axis=1)
    return jnp.concatenate([top, picked], axis=1)


def alice_refresh(g, state, hp: HP, seed):
    """Algorithm 4 lines 6-7: reconstruct Q, switch the basis."""
    u = state["u"]
    q_rec = hp.b3 * matmul(matmul(u, state["qt"]), u.T) \
        + (1.0 - hp.b3) * matmul(g, g.T)
    u_new = _switch(q_rec, u, hp, seed)
    return {**state, "u": u_new}


def alice0_init(shape, hp: HP) -> State:
    st = alice_init(shape, hp)
    del st["qt"]  # no tracking state — the memory saving of Alice-0
    return st


def alice0_update(g, state, hp: HP, t):
    hp0 = dataclasses.replace(hp, b3=0.0)
    st = dict(state)
    st["qt"] = jnp.zeros((state["u"].shape[1],) * 2, jnp.float32)
    delta, out = alice_update(g, st, hp0, t)
    del out["qt"]
    return delta, out


def alice0_refresh(g, state, hp: HP, seed):
    """β₃ = 0: Q_rec = GGᵀ only."""
    q_rec = matmul(g, g.T)
    u_new = _switch(q_rec, state["u"], hp, seed)
    return {**state, "u": u_new}


# ============================================================ registry =====
@dataclasses.dataclass(frozen=True)
class OptDef:
    name: str
    init: Callable
    update: Callable
    refresh: Optional[Callable] = None
    # Wide matrices (m > n) are handled by transposition so the projection /
    # Gram side is always the short one, matching the paper's m <= n setup.
    transpose_wide: bool = True


OPTIMIZERS: Dict[str, OptDef] = {
    "sgd": OptDef("sgd", sgd_init, sgd_update),
    "adam": OptDef("adam", adam_init, adam_update, transpose_wide=False),
    "adafactor": OptDef("adafactor", adafactor_init, adafactor_update,
                        transpose_wide=False),
    "lion": OptDef("lion", lion_init, lion_update, transpose_wide=False),
    "signum": OptDef("signum", signum_init, signum_update,
                     transpose_wide=False),
    "muon": OptDef("muon", muon_init, muon_update, transpose_wide=False),
    "swan": OptDef("swan", swan_init, swan_update, transpose_wide=False),
    "racs": OptDef("racs", racs_init, racs_update, transpose_wide=False),
    "eigen_adam": OptDef("eigen_adam", eigen_adam_init, eigen_adam_update,
                         eigen_adam_refresh),
    "shampoo": OptDef("shampoo", shampoo_init, shampoo_update,
                      shampoo_refresh, transpose_wide=False),
    "soap": OptDef("soap", soap_init, soap_update, soap_refresh),
    "galore": OptDef("galore", galore_init, galore_update, galore_refresh),
    "fira": OptDef("fira", fira_init, fira_update, fira_refresh),
    "apollo_mini": OptDef("apollo_mini", apollo_mini_init,
                          apollo_mini_update, apollo_mini_refresh),
    "alice": OptDef("alice", alice_init, alice_update, alice_refresh),
    "alice0": OptDef("alice0", alice0_init, alice0_update, alice0_refresh),
}


# ------------------------------------------- transpose-wide wrapping -------
def eff_shape(name: str, shape) -> tuple:
    """Shape the optimizer actually sees (wide matrices transposed)."""
    od = OPTIMIZERS[name]
    m, n = shape
    if od.transpose_wide and m > n:
        return (n, m)
    return (m, n)


def init_state(name: str, shape, hp: HP) -> State:
    return OPTIMIZERS[name].init(eff_shape(name, shape), hp)


def update(name: str, g: Array, state: State, hp: HP, t: Array):
    od = OPTIMIZERS[name]
    if od.transpose_wide and g.shape[0] > g.shape[1]:
        delta, st = od.update(g.T, state, hp, t)
        return delta.T, st
    return od.update(g, state, hp, t)


def refresh(name: str, g: Array, state: State, hp: HP, seed) -> State:
    od = OPTIMIZERS[name]
    if od.refresh is None:
        return state
    if od.transpose_wide and g.shape[0] > g.shape[1]:
        return od.refresh(g.T, state, hp, seed)
    return od.refresh(g, state, hp, seed)


def state_keys(name: str, shape, hp: HP):
    """Deterministic state ordering for the AOT manifest."""
    return list(init_state(name, shape, hp).keys())
