"""LAPACK-free linear algebra in pure jnp.

jax ≥ 0.5 lowers ``jnp.linalg.{eigh,qr,svd}`` to LAPACK FFI custom-calls
that the XLA 0.5.1 runtime inside the rust ``xla`` crate cannot load, so
every eigen/QR operation that must survive AOT lowering is implemented here
with matmul/elementwise ops only (validated against numpy.linalg in
``python/tests/test_linalg.py``).

* ``mgs_qr``           — modified Gram-Schmidt orthonormalization
* ``subspace_iter``    — block power method (paper Alg. 10), the paper's own
                         recommended cheap EVD replacement ("1 step is
                         enough", Sec. 5 / App. B.13)
* ``full_eigh``        — orthogonal (QR-algorithm style) iteration for full
                         eigendecompositions of small SPD matrices
                         (Eigen-Adam / SOAP / Shampoo refreshes)
* ``complete_basis``   — extend an orthonormal U[m,r] to a full basis,
                         giving the complement U_c used by Alice switching
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

EPS = 1e-8


def mgs_qr(a: jnp.ndarray) -> jnp.ndarray:
    """Orthonormalize the columns of ``a`` (m x r, r <= m) by modified
    Gram-Schmidt (two projection passes for float32 robustness).

    Columns are processed by a ``fori_loop`` over a zero-initialized buffer
    Q: unfilled columns are zero, so projecting against *all* of Q projects
    exactly against the filled prefix — one matvec per column instead of a
    python-unrolled inner loop (keeps the traced HLO small for m ~ 10³).

    Degenerate columns fall back to a canonical direction so Q always has
    orthonormal columns.
    """
    m, r = a.shape
    dtype = a.dtype
    eye = jnp.eye(m, dtype=dtype)

    def body(j, q):
        v = jax.lax.dynamic_slice_in_dim(a, j, 1, axis=1)[:, 0]
        v = v - q @ (q.T @ v)
        v = v - q @ (q.T @ v)  # re-orthogonalize
        nrm = jnp.sqrt(jnp.sum(v * v))
        fb = eye[:, j % m]
        fb = fb - q @ (q.T @ fb)
        fb = fb / (jnp.sqrt(jnp.sum(fb * fb)) + EPS)
        v = jnp.where(nrm > 1e-6, v / (nrm + EPS), fb)
        return q.at[:, j].set(v)

    q0 = jnp.zeros((m, r), dtype)
    return jax.lax.fori_loop(0, r, body, q0)


def subspace_iter(a: jnp.ndarray, u0: jnp.ndarray, iters: int = 1):
    """Algorithm 10 (block power method): top-r eigenpairs of symmetric
    ``a`` starting from ``u0`` (m x r).

    Returns (U, eigvals) with U's columns ordered by descending Rayleigh
    quotient. The final small r x r problem is solved by orthogonal
    iteration (``full_eigh``) as in the paper's last two lines.
    """
    u = u0
    for _ in range(iters):
        u = mgs_qr(a @ u)
    v = u.T @ a @ u  # r x r
    w, s = full_eigh(v, iters=30)
    return u @ w, s


def full_eigh(a: jnp.ndarray, iters: int = 40):
    """Full eigendecomposition of a small symmetric matrix by orthogonal
    iteration: repeat V <- qr(A V). Converges for SPD matrices with
    separated spectra; EMA-accumulated GGᵀ matrices in this codebase are
    SPD + noise, which is the friendly case.

    Returns (V, diag) with columns sorted by descending eigenvalue.
    """
    n = a.shape[0]

    def body(_, v):
        return mgs_qr(a @ v)

    v = jax.lax.fori_loop(0, iters, body, jnp.eye(n, dtype=a.dtype))
    lam = jnp.diagonal(v.T @ a @ v)
    order = jnp.argsort(-lam)
    return v[:, order], lam[order]


def complete_basis(u: jnp.ndarray) -> jnp.ndarray:
    """Given orthonormal U (m x r), return U_c (m x (m-r)) spanning the
    orthogonal complement — the paper's ``QR(U)`` (Alg. 2 line 4).

    Deterministic: the canonical basis vectors e_0..e_{m-r-1} are projected
    off U and MGS-orthonormalized (with the mgs fallback covering any e_j
    that lies in span(U)).
    """
    m, r = u.shape
    cand = jnp.eye(m, dtype=u.dtype)[:, : m - r]
    cand = cand - u @ (u.T @ cand)
    return mgs_qr(cand)


def spectral_norm_sq_upper(a: jnp.ndarray) -> jnp.ndarray:
    """Cheap upper bound on the squared spectral norm (row-sum bound)."""
    return jnp.max(jnp.sum(jnp.abs(a), axis=1)) ** 2
