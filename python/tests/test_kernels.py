"""Pallas kernels vs pure-jnp oracles — the L1 correctness signal.

Hypothesis sweeps shapes (including non-tile-aligned and degenerate dims)
and dtypes; every kernel must match its `ref` twin to f32 tolerance.
"""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile import kernels as K
from compile.kernels import ref

hypothesis.settings.register_profile(
    "kernels", deadline=None, max_examples=25,
    suppress_health_check=[hypothesis.HealthCheck.too_slow],
)
hypothesis.settings.load_profile("kernels")

dims = st.integers(min_value=1, max_value=150)
seeds = st.integers(min_value=0, max_value=2**31 - 1)


def rand(seed, *shape, dtype=np.float32):
    return jnp.asarray(
        np.random.default_rng(seed).normal(size=shape).astype(dtype))


def close(a, b, rtol=1e-4, atol=1e-4):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=rtol, atol=atol)


# ------------------------------------------------------------------ RACS ---
@given(m=dims, n=dims, seed=seeds)
def test_racs_col_stats(m, n, seed):
    g = rand(seed, m, n)
    q = jnp.abs(rand(seed + 1, m)) + 0.1
    close(K.racs_col_stats(g, q), ref.racs_col_stats(g, q), rtol=1e-3)


@given(m=dims, n=dims, seed=seeds)
def test_racs_row_stats(m, n, seed):
    g = rand(seed, m, n)
    s = jnp.abs(rand(seed + 1, n)) + 0.1
    close(K.racs_row_stats(g, s), ref.racs_row_stats(g, s), rtol=1e-3)


@given(m=dims, n=dims, seed=seeds)
def test_racs_fixed_point_and_apply(m, n, seed):
    g = rand(seed, m, n)
    s, q = K.racs_fixed_point(g, 3)
    s_r, q_r = ref.racs_fixed_point(g, 3)
    close(s, s_r, rtol=1e-3)
    close(q, q_r, rtol=1e-3)
    close(K.racs_apply(g, q, s, 0.7), ref.racs_apply(g, q_r, s_r, 0.7),
          rtol=1e-3)


def test_racs_fixed_point_positivity():
    # Perron-Frobenius (Prop. 3): strictly positive scalings
    g = rand(0, 33, 77)
    s, q = K.racs_fixed_point(g, 5)
    assert np.all(np.asarray(s) > 0)
    assert np.all(np.asarray(q) > 0)


# ------------------------------------------------------------------ Adam ---
@given(m=dims, n=dims, seed=seeds,
       t=st.integers(min_value=1, max_value=1000))
def test_adam_fused(m, n, seed, t):
    g, mm, vv = rand(seed, m, n), rand(seed + 1, m, n), \
        jnp.abs(rand(seed + 2, m, n))
    bc1, bc2 = 1 - 0.9 ** t, 1 - 0.999 ** t
    out = K.adam_fused(g, mm, vv, 0.9, 0.999, 1e-8, bc1, bc2)
    want = ref.adam_fused(g, mm, vv, 0.9, 0.999, 1e-8, bc1, bc2)
    for a, b in zip(out, want):
        close(a, b, rtol=1e-3)


def test_adam_fused_1d():
    g = rand(3, 40)
    m = jnp.zeros_like(g)
    out = K.adam_fused(g, m, m, 0.9, 0.999, 1e-8, 0.1, 0.001)
    want = ref.adam_fused(g, m, m, 0.9, 0.999, 1e-8, 0.1, 0.001)
    for a, b in zip(out, want):
        close(a, b)


# ---------------------------------------------------------------- matmul ---
@given(m=dims, k=dims, n=dims, seed=seeds)
def test_matmul(m, k, n, seed):
    a, b = rand(seed, m, k), rand(seed + 1, k, n)
    close(K.matmul(a, b), ref.matmul(a, b), rtol=1e-3, atol=1e-3)


@given(seed=seeds)
def test_matmul_block_boundary_shapes(seed):
    # exactly at/around the 128 tile edge
    for m, k, n in [(128, 128, 128), (129, 127, 130), (1, 128, 1)]:
        a, b = rand(seed, m, k), rand(seed + 1, k, n)
        close(K.matmul(a, b), ref.matmul(a, b), rtol=1e-3, atol=1e-3)


def test_project_reconstruct():
    u, g = rand(0, 64, 8), rand(1, 64, 96)
    close(K.project(u, g), ref.matmul(u.T, g), rtol=1e-3, atol=1e-3)
    sig = K.project(u, g)
    close(K.reconstruct(u, sig), ref.matmul(u, sig), rtol=1e-3, atol=1e-3)


# ----------------------------------------------------------- 2nd moment ---
@given(r=st.integers(1, 64), n=dims, seed=seeds)
def test_second_moment(r, n, seed):
    sigma = rand(seed, r, n)
    v = jnp.abs(rand(seed + 1, r, n))
    out = K.second_moment(sigma, v, 0.9, 1e-8)
    want = ref.second_moment(sigma, v, 0.9, 1e-8)
    for a, b in zip(out, want):
        close(a, b, rtol=1e-3)


# ---------------------------------------------------------- compensation ---
@given(m=st.integers(2, 100), n=dims, seed=seeds)
def test_compensation(m, n, seed):
    r = max(1, m // 4)
    g = rand(seed, m, n)
    u = rand(seed + 1, m, r)
    sigma = ref.matmul(u.T, g)
    pv = ref.compensation_pvec(g, sigma)
    close(K.compensation_pvec(g, sigma), pv, rtol=1e-2, atol=1e-2)
    p_proj = ref.matmul(u, sigma)
    scale = float(np.sqrt(m - r))
    close(K.compensation(g, p_proj, jnp.abs(pv) + 0.5, scale),
          ref.compensation(g, p_proj, jnp.abs(pv) + 0.5, scale),
          rtol=1e-3, atol=1e-3)


def test_compensation_pvec_nonnegative_for_orthonormal_u():
    # Thm 5.1 quantity 1ₘᵀG⊙² − 1ᵣᵀ(UᵀG)⊙² ≥ 0 when U has orthonormal cols
    g = rand(0, 48, 64)
    q, _ = np.linalg.qr(np.asarray(rand(1, 48, 8)))
    pv = np.asarray(K.compensation_pvec(g, K.project(jnp.asarray(q), g)))
    assert (pv > -1e-3).all()


# ---------------------------------------------------------- Newton-Schulz ---
def test_ns_step_matches_ref():
    a = rand(0, 24, 24)
    spd = ref.matmul(a, a.T) + 0.5 * jnp.eye(24)
    y = spd / jnp.sqrt(jnp.sum(spd * spd))
    z = jnp.eye(24)
    out = K.ns_step(y, z)
    want = ref.ns_step(y, z)
    for x, w in zip(out, want):
        close(x, w, rtol=1e-3, atol=1e-3)


def test_newton_schulz_inverse_sqrt_property():
    a = rand(5, 16, 16)
    spd = ref.matmul(a, a.T) + 0.5 * jnp.eye(16)
    _, isq = K.newton_schulz(spd, 25)
    ident = ref.matmul(ref.matmul(isq, spd), isq)
    close(ident, jnp.eye(16), rtol=0.0, atol=5e-2)


@given(m=st.integers(2, 48), n=st.integers(2, 100), seed=seeds)
def test_whiten(m, n, seed):
    if m > n:
        m, n = n, m
    g = rand(seed, m, n)
    close(K.whiten(g, 8), ref.whiten(g, 8), rtol=1e-2, atol=1e-2)


def test_whiten_orthogonalizes():
    g = rand(2, 12, 80)
    w = np.asarray(K.whiten(g, 25))
    np.testing.assert_allclose(w @ w.T, np.eye(12), atol=0.1)


def test_inv_fourth_root_property():
    a = rand(7, 10, 10)
    spd = ref.matmul(a, a.T) + 0.5 * jnp.eye(10)
    r = np.asarray(K.inv_fourth_root(spd, 25))
    ident = np.linalg.matrix_power(r, 4) @ np.asarray(spd)
    np.testing.assert_allclose(ident, np.eye(10), atol=0.1)


# ----------------------------------------------------------- limiter ------
@given(dn=st.floats(0.01, 100.0),
       phi=st.one_of(st.just(0.0), st.floats(1e-3, 100.0)))
def test_limiter_bounds_growth(dn, phi):
    eta, phi2 = ref.limiter(jnp.asarray(dn), jnp.asarray(phi), 1.01)
    eta, phi2 = float(eta), float(phi2)
    if phi > 0:
        assert eta * dn <= 1.01 * phi + 1e-3
    else:
        assert eta == pytest.approx(1.0)
    assert phi2 == pytest.approx(eta * dn, rel=1e-4)
