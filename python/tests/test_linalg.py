"""Pure-jnp linalg (LAPACK-free) vs numpy.linalg."""

import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from compile import linalg

seeds = st.integers(min_value=0, max_value=2**31 - 1)


def spd(seed, n):
    b = np.random.default_rng(seed).normal(size=(n, n)).astype(np.float32)
    return jnp.asarray(b @ b.T + 0.2 * np.eye(n, dtype=np.float32))


@settings(deadline=None, max_examples=20)
@given(m=st.integers(2, 60), r=st.integers(1, 12), seed=seeds)
def test_mgs_qr_orthonormal(m, r, seed):
    r = min(r, m)
    a = jnp.asarray(
        np.random.default_rng(seed).normal(size=(m, r)).astype(np.float32))
    q = np.asarray(linalg.mgs_qr(a))
    np.testing.assert_allclose(q.T @ q, np.eye(r), atol=2e-4)


def test_mgs_qr_preserves_span():
    rng = np.random.default_rng(0)
    a = rng.normal(size=(20, 5)).astype(np.float32)
    q = np.asarray(linalg.mgs_qr(jnp.asarray(a)))
    # every original column is reproducible from Q
    proj = q @ (q.T @ a)
    np.testing.assert_allclose(proj, a, atol=1e-3)


def test_mgs_qr_rank_deficient_fallback():
    c = np.random.default_rng(1).normal(size=(16, 1)).astype(np.float32)
    a = jnp.asarray(np.concatenate([c, c, c], axis=1))
    q = np.asarray(linalg.mgs_qr(a))
    np.testing.assert_allclose(q.T @ q, np.eye(3), atol=1e-3)


@settings(deadline=None, max_examples=10)
@given(n=st.integers(3, 24), seed=seeds)
def test_full_eigh_matches_numpy(n, seed):
    a = spd(seed, n)
    v, lam = linalg.full_eigh(a, iters=150)
    lam = np.asarray(lam)
    want = np.linalg.eigvalsh(np.asarray(a))[::-1]
    np.testing.assert_allclose(lam, want, rtol=5e-2, atol=5e-2)
    # reconstruction
    v = np.asarray(v)
    rec = v @ np.diag(lam) @ v.T
    np.testing.assert_allclose(rec, np.asarray(a),
                               atol=5e-2 * np.abs(np.asarray(a)).max())


@settings(deadline=None, max_examples=10)
@given(seed=seeds)
def test_subspace_iter_finds_leading_eigs(seed):
    a = spd(seed, 20)
    u0 = jnp.asarray(
        np.random.default_rng(seed + 1).normal(size=(20, 5)).astype(np.float32))
    u, s = linalg.subspace_iter(a, linalg.mgs_qr(u0), iters=30)
    want = np.linalg.eigvalsh(np.asarray(a))[::-1][:5]
    # clustered eigenvalues can swap within the subspace — compare the sum
    # (trace of the projected problem) and the individual values loosely
    np.testing.assert_allclose(np.asarray(s).sum(), want.sum(), rtol=2e-2)
    np.testing.assert_allclose(np.asarray(s), want, rtol=0.2, atol=0.1)
    u = np.asarray(u)
    np.testing.assert_allclose(u.T @ u, np.eye(5), atol=1e-3)


def test_complete_basis_orthogonal_complement():
    rng = np.random.default_rng(3)
    u = np.asarray(linalg.mgs_qr(
        jnp.asarray(rng.normal(size=(18, 6)).astype(np.float32))))
    uc = np.asarray(linalg.complete_basis(jnp.asarray(u)))
    assert uc.shape == (18, 12)
    np.testing.assert_allclose(uc.T @ uc, np.eye(12), atol=1e-3)
    np.testing.assert_allclose(u.T @ uc, 0.0, atol=1e-3)


def test_paper_claim_one_subspace_iter_suffices():
    # Sec. 5 "we found that only 1 step of iteration is enough": after a
    # warm start near the true basis, 1 iteration keeps the subspace angle
    # small even when the matrix drifts.
    rng = np.random.default_rng(4)
    a = np.asarray(spd(5, 16))
    w, v = np.linalg.eigh(a)
    u_true = v[:, ::-1][:, :4].astype(np.float32)
    drift = a + 0.05 * np.eye(16, dtype=np.float32)
    u1, _ = linalg.subspace_iter(jnp.asarray(drift), jnp.asarray(u_true), 1)
    # principal angles via singular values of U_trueᵀ U₁
    sv = np.linalg.svd(u_true.T @ np.asarray(u1), compute_uv=False)
    assert sv.min() > 0.99
