"""L2 model: shapes, loss sanity, gradient flow, preset sync with rust."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


def toks(cfg, seed=0, batch=None):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        rng.integers(0, cfg.vocab, size=(batch or 2, cfg.seq)), jnp.int32)


def test_param_count_constants_shared_with_rust():
    # rust/src/config/presets.rs hard-codes these — keep in sync
    assert M.num_params(M.PRESETS["nano"]) == 133_440
    assert M.num_params(M.PRESETS["tiny"]) == 922_752
    assert M.num_params(M.PRESETS["small"]) == 5_270_784
    assert M.num_params(M.PRESETS["mid"]) == 27_402_752


def test_large_preset_is_about_100m():
    n = M.num_params(M.PRESETS["large"])
    assert 8e7 < n < 1.2e8, n


def test_forward_shapes():
    cfg = M.PRESETS["nano"]
    ps = M.init_params(cfg, 0)
    logits = M.forward(ps, toks(cfg), cfg)
    assert logits.shape == (2, cfg.seq, cfg.vocab)


def test_initial_loss_near_uniform_entropy():
    cfg = M.PRESETS["nano"]
    ps = M.init_params(cfg, 0)
    loss = float(M.loss_fn(ps, toks(cfg), cfg))
    assert abs(loss - np.log(cfg.vocab)) < 0.25


def test_grads_cover_every_param():
    cfg = M.PRESETS["nano"]
    ps = M.init_params(cfg, 1)
    loss, grads = M.grad_step(ps, toks(cfg, 1), cfg)
    assert len(grads) == len(ps)
    for (name, shape, _), g in zip(M.param_specs(cfg), grads):
        assert g.shape == tuple(shape), name
        assert bool(jnp.all(jnp.isfinite(g))), name
        # every tensor should receive some gradient signal
        assert float(jnp.abs(g).max()) > 0.0, name


def test_causality():
    # changing a future token must not affect earlier logits
    cfg = M.PRESETS["nano"]
    ps = M.init_params(cfg, 2)
    t1 = toks(cfg, 3, batch=1)
    t2 = t1.at[0, -1].set((t1[0, -1] + 1) % cfg.vocab)
    l1 = M.forward(ps, t1, cfg)
    l2 = M.forward(ps, t2, cfg)
    np.testing.assert_allclose(np.asarray(l1[0, :-1]), np.asarray(l2[0, :-1]),
                               atol=1e-5)
    assert np.abs(np.asarray(l1[0, -1]) - np.asarray(l2[0, -1])).max() > 1e-6


def test_one_sgd_step_reduces_loss():
    cfg = M.PRESETS["nano"]
    ps = M.init_params(cfg, 4)
    batch = toks(cfg, 5, batch=4)
    loss0, grads = M.grad_step(ps, batch, cfg)
    ps2 = [p - 0.5 * g for p, g in zip(ps, grads)]
    loss1 = float(M.loss_fn(ps2, batch, cfg))
    assert loss1 < float(loss0)


def test_rotary_preserves_norm():
    x = jnp.asarray(
        np.random.default_rng(6).normal(size=(1, 8, 2, 16)).astype(np.float32))
    y = M._rotary(x)
    np.testing.assert_allclose(
        np.asarray(jnp.sum(x * x, -1)), np.asarray(jnp.sum(y * y, -1)),
        rtol=1e-4)


def test_jit_lowering_has_no_custom_calls():
    # the whole point of the pure-jnp stack: XLA 0.5.1 must be able to load
    # the grad step — no LAPACK/FFI custom-calls allowed (DESIGN.md)
    from compile.aot import to_hlo_text
    cfg = M.PRESETS["nano"]

    def fn(tokens, *params):
        loss, grads = M.grad_step(list(params), tokens, cfg)
        return (loss, *grads)

    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((2, cfg.seq), jnp.int32),
        *[jax.ShapeDtypeStruct(s, jnp.float32)
          for _, s, _ in M.param_specs(cfg)])
    hlo = to_hlo_text(lowered)
    assert "custom-call" not in hlo, "grad_step must stay custom-call-free"
