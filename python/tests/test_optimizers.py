"""L2 optimizer semantics: shapes, finiteness, paper identities."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from compile import optimizers as O

HP = O.HP(rank=8, leading=3, eig_iters=40)
T1 = jnp.asarray(1.0)


def rand(seed, *shape):
    return jnp.asarray(
        np.random.default_rng(seed).normal(size=shape).astype(np.float32))


@pytest.mark.parametrize("name", sorted(O.OPTIMIZERS))
@pytest.mark.parametrize("shape", [(24, 40), (40, 24), (8, 8)])
def test_update_shape_and_finite(name, shape):
    g = rand(0, *shape)
    st = O.init_state(name, shape, HP)
    d, st2 = O.update(name, g, st, HP, T1)
    assert d.shape == g.shape
    assert bool(jnp.all(jnp.isfinite(d)))
    st3 = O.refresh(name, g, st2, HP, 11)
    d2, _ = O.update(name, g, st3, HP, T1 + 1)
    assert bool(jnp.all(jnp.isfinite(d2)))


def test_adam_first_step_signlike():
    g = jnp.asarray([[2.0, -0.5, 0.0]])
    st = O.init_state("adam", (1, 3), HP)
    d, _ = O.update("adam", g, st, HP, T1)
    np.testing.assert_allclose(np.asarray(d), [[1.0, -1.0, 0.0]], atol=1e-3)


def test_eigen_adam_equals_adam_before_refresh():
    # U = I initially ⇒ identical trajectories (Eq. 9 with U = I is Prop. 1)
    shape = (12, 20)
    st_e = O.init_state("eigen_adam", shape, HP)
    st_a = O.init_state("adam", shape, HP)
    for t in range(1, 4):
        g = rand(t, *shape)
        de, st_e = O.update("eigen_adam", g, st_e, HP, jnp.asarray(float(t)))
        da, st_a = O.update("adam", g, st_a, HP, jnp.asarray(float(t)))
        np.testing.assert_allclose(np.asarray(de), np.asarray(da),
                                   rtol=1e-4, atol=1e-5)


def test_soap_equals_adam_before_refresh():
    shape = (10, 14)
    st_s = O.init_state("soap", shape, HP)
    st_a = O.init_state("adam", shape, HP)
    g = rand(5, *shape)
    ds, _ = O.update("soap", g, st_s, HP, T1)
    da, _ = O.update("adam", g, st_a, HP, T1)
    np.testing.assert_allclose(np.asarray(ds), np.asarray(da),
                               rtol=1e-4, atol=1e-5)


def test_galore_update_in_span_u():
    shape = (16, 24)
    g = rand(7, *shape)
    st = O.init_state("galore", shape, HP)
    st = O.refresh("galore", g, st, HP, 0)
    d, st = O.update("galore", g, st, HP, T1)
    u = np.asarray(st["u"])
    d = np.asarray(d)
    resid = d - u @ (u.T @ d)
    assert np.abs(resid).max() < 1e-3


def test_fira_and_alice_updates_are_full_rank():
    shape = (16, 24)
    g = rand(8, *shape)
    for name in ["fira", "alice"]:
        st = O.init_state(name, shape, HP)
        st = O.refresh(name, g, st, HP, 0)
        d, st = O.update(name, g, st, HP, T1)
        u = np.asarray(st["u"])
        d = np.asarray(d)
        resid = d - u @ (u.T @ d)
        assert np.abs(resid).max() > 1e-4, name


def test_alice_none_compensation_is_galore_like():
    hp = dataclasses.replace(HP, compen="none")
    shape = (16, 24)
    g = rand(9, *shape)
    st = O.init_state("alice", shape, hp)
    st = O.refresh("alice", g, st, hp, 0)
    d, st = O.update("alice", g, st, hp, T1)
    u = np.asarray(st["u"])
    d = np.asarray(d)
    resid = d - u @ (u.T @ d)
    assert np.abs(resid).max() < 1e-3


@pytest.mark.parametrize("strategy",
                         ["switch", "evd", "gaussian", "gaussian_mix",
                          "full_basis"])
def test_alice_switch_strategies(strategy):
    hp = dataclasses.replace(HP, switch=strategy)
    shape = (20, 28)
    g = rand(10, *shape)
    st = O.init_state("alice", shape, hp)
    st = O.refresh("alice", g, st, hp, 3)
    u = np.asarray(st["u"])
    assert u.shape == (20, 8)
    if strategy in ("switch", "evd", "full_basis"):
        np.testing.assert_allclose(u.T @ u, np.eye(8), atol=1e-3)
    else:  # gaussian variants: unit columns only
        np.testing.assert_allclose((u * u).sum(0), 1.0, atol=1e-3)


def test_alice0_matches_alice_with_b3_zero():
    shape = (12, 16)
    hp0 = dataclasses.replace(HP, b3=0.0)
    st_a = O.init_state("alice", shape, hp0)
    st_0 = O.init_state("alice0", shape, HP)
    g = rand(11, *shape)
    da, _ = O.update("alice", g, st_a, hp0, T1)
    d0, _ = O.update("alice0", g, st_0, HP, T1)
    np.testing.assert_allclose(np.asarray(da), np.asarray(d0),
                               rtol=1e-5, atol=1e-6)


def test_racs_limiter_caps_step_growth():
    shape = (8, 12)
    st = O.init_state("racs", shape, HP)
    d1, st = O.update("racs", rand(1, *shape), st, HP, T1)
    n1 = float(jnp.sqrt(jnp.sum(d1 * d1)))
    # hit it with a 100x bigger gradient — limiter must cap ~gamma growth
    d2, st = O.update("racs", 100.0 * rand(2, *shape), st, HP, T1 + 1)
    n2 = float(jnp.sqrt(jnp.sum(d2 * d2)))
    assert n2 <= HP.gamma * n1 * 1.05, (n1, n2)


def test_muon_output_near_orthogonal():
    hp = dataclasses.replace(HP, b1=0.0, ns_iters=25)
    g = rand(12, 10, 40)
    st = O.init_state("muon", (10, 40), hp)
    d, _ = O.update("muon", g, st, hp, T1)
    d = np.asarray(d)
    np.testing.assert_allclose(d @ d.T, np.eye(10), atol=0.1)


def test_state_keys_deterministic():
    ks1 = O.state_keys("alice", (16, 24), HP)
    ks2 = O.state_keys("alice", (16, 24), HP)
    assert ks1 == ks2 == ["u", "qt", "m", "v", "p", "phi"]
    assert O.state_keys("alice0", (16, 24), HP) == ["u", "m", "v", "p", "phi"]
