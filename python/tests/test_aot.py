"""AOT bundle: manifest ↔ HLO consistency on a minimal nano bundle.

Lowers a small artifact set into a temp dir (adam only, no fused step to
keep the test fast) and checks the manifest contract the rust side relies
on: input/output ordering, init classification, shape agreement.
"""

import json
import subprocess
import sys
from pathlib import Path

import pytest

from compile import aot, model as M, optimizers as O


@pytest.fixture(scope="module")
def bundle(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    cfg = M.PRESETS["nano"]
    hp = O.HP(rank=8, leading=3, interval=20)
    b = aot.Bundle(cfg, hp, str(out))
    b.emit_grad_step()
    b.emit_eval_loss()
    b.emit_opt_update("adam", (64, 176))
    b.emit_opt_update("racs", (64, 176))
    man = b.manifest(["adam", "racs", "alice"])
    (out / "manifest.json").write_text(json.dumps(man))
    return out, man, cfg


def test_artifacts_written(bundle):
    out, man, _ = bundle
    for e in man["artifacts"]:
        f = out / e["file"]
        assert f.exists() and f.stat().st_size > 1000, e["name"]
        head = f.read_text()[:200]
        assert head.startswith("HloModule"), e["name"]


def test_grad_step_signature(bundle):
    _, man, cfg = bundle
    gs = next(a for a in man["artifacts"] if a["name"] == "grad_step")
    assert gs["inputs"][0]["name"] == "tokens"
    assert gs["inputs"][0]["shape"] == [cfg.batch, cfg.seq]
    # one grad output per param, in order, plus the loss
    assert gs["outputs"][0]["name"] == "loss"
    params = man["params"]
    assert len(gs["outputs"]) == 1 + len(params)
    for p, o in zip(params, gs["outputs"][1:]):
        assert o["name"] == f"grad.{p['name']}"
        assert o["shape"] == p["shape"]


def test_state_specs_have_valid_init(bundle):
    _, man, _ = bundle
    for opt, spec in man["optimizers"].items():
        for s in spec["states"]:
            init = s["init"]
            assert (
                init in ("zeros", "eye") or init.startswith("eye_scale:")
            ), (opt, s["name"], init)


def test_alice_states_follow_paper_memory_table(bundle):
    # Table 6: Alice = mn (weight) + 2nr + mr + n + r² (+φ scalar)
    _, man, _ = bundle
    spec = man["optimizers"]["alice"]
    by_param = {}
    for s in spec["states"]:
        by_param.setdefault(s["param"], []).append(s)
    # embed is (256, 64): wide→transposed to (64, 256), r = 8
    states = {s["key"]: s["shape"] for s in by_param["embed"]}
    m, n, r = 64, 256, 8
    assert states["u"] == [m, r]
    assert states["qt"] == [r, r]
    assert states["m"] == [r, n]
    assert states["v"] == [r, n]
    assert states["p"] == [n]
    assert states["phi"] == []


def test_routes_respect_last_layer_policy(bundle):
    _, man, _ = bundle
    params = [p["name"] for p in man["params"]]
    head = params.index("lm_head")
    # adam/racs are full-rank → lm_head routed to adam (paper protocol)
    assert man["optimizers"]["racs"]["routes"][head] == "adam"
    # alice is low-rank → lm_head trained by alice itself ("Ppl" column)
    assert man["optimizers"]["alice"]["routes"][head] == "alice"
    # 1-D params always adam
    for i, p in enumerate(man["params"]):
        if len(p["shape"]) == 1:
            assert man["optimizers"]["alice"]["routes"][i] == "adam"


def test_opt_update_roundtrip_shapes(bundle):
    _, man, _ = bundle
    upd = next(a for a in man["artifacts"]
               if a["name"] == "opt_update_adam_64x176")
    assert upd["inputs"][0]["shape"] == [64, 176]
    assert upd["outputs"][0]["name"] == "w_delta"
    # state inputs and outputs pair up
    assert [i["shape"] for i in upd["inputs"][3:]] == \
        [o["shape"] for o in upd["outputs"][1:]]


def test_classify_init_rules():
    import numpy as np
    assert aot._classify_init(np.zeros((3, 4))) == "zeros"
    assert aot._classify_init(np.eye(5, 2)) == "eye"
    assert aot._classify_init(1e-4 * np.eye(4)).startswith("eye_scale:")
    with pytest.raises(ValueError):
        aot._classify_init(np.ones((2, 2)))
