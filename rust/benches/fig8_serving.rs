//! Figure 8 (repo extension) — serving throughput and latency of the
//! continuous-batching forward path (`src/serve/`), in both arrival
//! modes:
//!
//! * **Closed-loop**: the whole request set is queued up front and the
//!   serve loop drains it — peak-throughput shape, swept over pool
//!   widths {1, 4} × max-batch {1, 3, 8}, with the live bitwise assert
//!   that every batched score equals the sequential score (batching is
//!   scheduling, never numerics).
//! * **Open-loop**: a producer thread submits with deterministic
//!   inter-arrival gaps and occasional bursts while the serve loop
//!   coalesces under its max-batch/max-wait policy — the latency-tail
//!   shape (p50/p95/p99 end to end), plus the obs batch-fill histogram.
//!
//! A third, artifact-gated section trains briefly, checkpoints, loads
//! the checkpoint through `Checkpoint::load_model` (no optimizer state —
//! the state-bytes gauge is asserted 0), and serves real `eval_loss`
//! scoring requests.
//!
//! Protocol notes live in EXPERIMENTS.md §fig8. `AR_BENCH_SMOKE=1`
//! shrinks the request counts for CI's bench-smoke job (every parity
//! assert stays live) and the summary lands in
//! `runs/bench/fig8_serving_summary.json`.

use std::time::Duration;

use alice_racs::bench::{artifacts_available, bench_cfg, smoke, write_summary, TablePrinter};
use alice_racs::coordinator::Trainer;
use alice_racs::obs;
use alice_racs::serve::{
    latency_summary, queue, score_batched, serve_loop, synthetic_requests, BatchPolicy,
    Request, ScoreSource, SyntheticScoreSource,
};
use alice_racs::util::json::{num, obj, s};
use alice_racs::util::{pool, trace, Json, Timer};

/// One measured drain of `reqs` through the continuous-batching queue.
fn drain(
    src: &dyn ScoreSource,
    reqs: &[Request],
    policy: &BatchPolicy,
) -> (f64, Vec<alice_racs::serve::Response>) {
    let (ingress, q) = queue();
    let t = Timer::start();
    for r in reqs {
        ingress.submit(r.id, r.tokens.clone()).expect("unbounded submit");
    }
    drop(ingress);
    let resps = serve_loop(src, policy, q).expect("serve loop");
    (t.secs(), resps)
}

fn closed_loop_section(src: &SyntheticScoreSource, reqs: &[Request]) -> Json {
    let direct: Vec<u32> = reqs
        .iter()
        .map(|r| src.score(r.id, &r.tokens).expect("direct").to_bits())
        .collect();
    println!("== closed-loop: {} requests pre-queued, widths x max-batch ==", reqs.len());
    let mut table = TablePrinter::new(&[
        "width",
        "max_batch",
        "req/s",
        "p50 ms",
        "p95 ms",
        "p99 ms",
    ]);
    let mut rows: Vec<Json> = Vec::new();
    for width in [1usize, 4] {
        for max_batch in [1usize, 3, 8] {
            let policy = BatchPolicy {
                max_batch,
                max_wait: Duration::from_millis(1),
                max_queue_depth: 0,
            };
            let (secs, resps) =
                pool::with_threads(width, || drain(src, reqs, &policy));
            assert_eq!(resps.len(), reqs.len());
            for r in &resps {
                // the live determinism contract: batched == sequential, bitwise
                assert_eq!(
                    r.score.to_bits(),
                    direct[r.id as usize],
                    "width {width}, max_batch {max_batch}, id {}",
                    r.id
                );
            }
            let lat = latency_summary(&resps);
            let rps = reqs.len() as f64 / secs.max(1e-9);
            table.row(vec![
                width.to_string(),
                max_batch.to_string(),
                format!("{rps:.0}"),
                format!("{:.3}", lat.p50 * 1e3),
                format!("{:.3}", lat.p95 * 1e3),
                format!("{:.3}", lat.p99 * 1e3),
            ]);
            rows.push(obj(vec![
                ("width", num(width as f64)),
                ("max_batch", num(max_batch as f64)),
                ("req_per_s", num(rps)),
                ("p50_ms", num(lat.p50 * 1e3)),
                ("p95_ms", num(lat.p95 * 1e3)),
                ("p99_ms", num(lat.p99 * 1e3)),
            ]));
        }
    }
    table.print();
    println!("(every row scored bitwise-identical to the sequential pass)");
    obj(vec![
        ("requests", num(reqs.len() as f64)),
        ("parity", s("batched == sequential bitwise, widths {1,4} x max-batch {1,3,8}")),
        ("rows", Json::Arr(rows)),
    ])
}

fn open_loop_section(src: &SyntheticScoreSource, reqs: &[Request]) -> Json {
    println!("\n== open-loop: producer thread, deterministic arrival gaps ==");
    let policy = BatchPolicy {
        max_batch: 8,
        max_wait: Duration::from_millis(2),
        max_queue_depth: 0,
    };
    let (ingress, q) = queue();
    let producer_reqs: Vec<Request> = reqs.to_vec();
    let producer = std::thread::spawn(move || {
        for (i, r) in producer_reqs.into_iter().enumerate() {
            // steady trickle with a burst every 16th request: exercises both
            // the max-wait timeout path and the batch-full path
            if i % 16 != 0 {
                std::thread::sleep(Duration::from_micros(200));
            }
            ingress.submit(r.id, r.tokens).expect("unbounded submit");
        }
    });
    let t = Timer::start();
    let resps = serve_loop(src, &policy, q).expect("serve loop");
    let secs = t.secs();
    producer.join().unwrap();
    assert_eq!(resps.len(), reqs.len(), "open loop must drain every request");
    for r in &resps {
        let direct = src.score(r.id, &reqs[r.id as usize].tokens).expect("direct");
        assert_eq!(r.score.to_bits(), direct.to_bits(), "id {}", r.id);
    }
    let lat = latency_summary(&resps);
    let rps = resps.len() as f64 / secs.max(1e-9);
    let fill = obs::serve_fill_snapshot();
    println!(
        "served={} req/s={rps:.0} p50={:.3}ms p95={:.3}ms p99={:.3}ms",
        resps.len(),
        lat.p50 * 1e3,
        lat.p95 * 1e3,
        lat.p99 * 1e3
    );
    println!("batch-fill histogram (eighths of max_batch): {fill:?}");
    obj(vec![
        ("requests", num(reqs.len() as f64)),
        ("req_per_s", num(rps)),
        ("p50_ms", num(lat.p50 * 1e3)),
        ("p95_ms", num(lat.p95 * 1e3)),
        ("p99_ms", num(lat.p99 * 1e3)),
        (
            "fill_histogram",
            Json::Arr(fill.iter().map(|&c| num(c as f64)).collect()),
        ),
    ])
}

fn model_section() -> Option<Json> {
    if !artifacts_available() {
        return None;
    }
    let steps = if smoke() { 6 } else { 20 };
    println!("\n== checkpoint-served model: train {steps} steps, load, score ==");
    let mut cfg = bench_cfg("adam", "fig8", steps);
    cfg.out_dir = "runs/bench/fig8".into();
    let mut trainer = Trainer::new(cfg).expect("trainer");
    for _ in 0..steps {
        trainer.train_step(0.01).expect("train step");
    }
    let ck = trainer.checkpoint();
    drop(trainer);
    obs::reset_all();
    let model = ck.load_model("artifacts").expect("load model");
    assert_eq!(obs::STATE_BYTES.get(), 0, "serving must allocate no optimizer state");
    let (b, sq) = model.block_shape();
    let vocab = model.manifest().model.vocab;
    let n = if smoke() { 8 } else { 32 };
    let reqs = synthetic_requests(n, b, sq, vocab, 0xf18);
    let direct: Vec<u32> = reqs
        .iter()
        .map(|r| model.score(r.id, &r.tokens).expect("direct").to_bits())
        .collect();
    let mut table = TablePrinter::new(&["width", "req/s", "mean score"]);
    let mut rows: Vec<Json> = Vec::new();
    for width in [1usize, 4] {
        let t = Timer::start();
        let scores =
            pool::with_threads(width, || score_batched(&*model, &reqs, 4)).expect("scores");
        let secs = t.secs();
        let bits: Vec<u32> = scores.iter().map(|x| x.to_bits()).collect();
        assert_eq!(bits, direct, "model scores must be width-invariant (width {width})");
        let mean_score =
            scores.iter().map(|&x| x as f64).sum::<f64>() / scores.len() as f64;
        let rps = n as f64 / secs.max(1e-9);
        table.row(vec![
            width.to_string(),
            format!("{rps:.1}"),
            format!("{mean_score:.4}"),
        ]);
        rows.push(obj(vec![
            ("width", num(width as f64)),
            ("req_per_s", num(rps)),
            ("mean_score", num(mean_score)),
        ]));
    }
    table.print();
    Some(obj(vec![
        ("train_steps", num(steps as f64)),
        ("requests", num(n as f64)),
        ("state_bytes", num(obs::STATE_BYTES.get() as f64)),
        ("rows", Json::Arr(rows)),
    ]))
}

fn main() {
    // AR_TRACE=1 (or =PATH) traces the whole bench; scheduling-only, so
    // every bitwise parity assert above stays live under tracing
    trace::init_resolved("");
    let n = if smoke() { 64 } else { 512 };
    let src = SyntheticScoreSource { work: if smoke() { 24 } else { 48 } };
    let reqs = synthetic_requests(n, 4, 32, 997, 0x5e1e);
    let closed = closed_loop_section(&src, &reqs);
    let open = open_loop_section(&src, &reqs);
    let mut fields = vec![
        ("smoke", Json::Bool(smoke())),
        ("closed_loop", closed),
        ("open_loop", open),
    ];
    if let Some(m) = model_section() {
        fields.push(("model", m));
    }
    match write_summary("fig8_serving", &obj(fields)) {
        Ok(path) => println!("summary → {path}"),
        Err(e) => eprintln!("could not write fig8 summary: {e:#}"),
    }
    match trace::finish() {
        Ok(Some(p)) => println!("trace → {}", p.display()),
        Ok(None) => {}
        Err(e) => eprintln!("trace write failed: {e:#}"),
    }
}
