//! Figure 4 — memory footprint per optimizer, plus the "-layerwise"
//! variant (only the live layer's gradient resident).
//!
//! Two views: (a) analytic bytes for the paper's llama presets (exact),
//! (b) measured optimizer-state elements held by a live trainer on the
//! AOT bundle (coordinator path), which must agree with the analytic
//! accounting for the same preset.

use alice_racs::bench::{artifacts_available, bench_cfg, TablePrinter};
use alice_racs::config::presets::{param_shapes, preset};
use alice_racs::coordinator::{estimate, Trainer};
use alice_racs::opt::Hyper;
use alice_racs::util::human_bytes;

fn main() {
    // (a) analytic, llama1b (the Fig. 4 model)
    let p = preset("llama1b").unwrap();
    let hp = Hyper { rank: 512, ..Hyper::default() };
    println!("== Fig. 4(a): analytic footprint, llama1b, BF16 ==");
    let mut table = TablePrinter::new(&["optimizer", "total", "weights", "opt state", "grad(full)", "grad(layerwise)"]);
    // full gradient = weights; layerwise = max single tensor
    let full_grad: u64 = param_shapes(p)
        .iter()
        .map(|(_, s)| s.iter().product::<usize>() as u64 * 2)
        .sum();
    let layerwise: u64 = param_shapes(p)
        .iter()
        .map(|(_, s)| s.iter().product::<usize>() as u64 * 2)
        .max()
        .unwrap();
    for opt in ["adam", "galore", "fira", "apollo_mini", "racs", "alice0", "alice"] {
        let e = estimate(p, opt, &hp, true).unwrap();
        table.row(vec![
            opt.into(),
            human_bytes(e.total_bytes + full_grad),
            human_bytes(e.weight_bytes),
            human_bytes(e.matrix_state_bytes + e.adam_side_bytes),
            human_bytes(full_grad),
            human_bytes(layerwise),
        ]);
    }
    table.print();

    // (b) measured on the live trainer
    if artifacts_available() {
        println!("\n== Fig. 4(b): measured optimizer-state elements (live trainer, AOT preset) ==");
        let mut table = TablePrinter::new(&["optimizer", "state elems (measured)"]);
        for opt in ["adam", "racs", "galore", "alice", "alice0"] {
            let cfg = bench_cfg(opt, "fig4", 1);
            match Trainer::new(cfg) {
                Ok(tr) => table.row(vec![opt.into(), tr.state_elems().to_string()]),
                Err(e) => eprintln!("{opt}: {e:#}"),
            }
        }
        table.print();
    }
    println!(
        "\nPaper shape: Adam ≈ 3x weights; RACS/Apollo ≈ weights + ε; \
         Alice ≈ GaLore + r² + n; layerwise shaves the full-gradient term."
    );
}
