//! Figure 3 — (a) absolute throughput (tokens/s) and (b) effective
//! throughput (Adam-referenced, speed-up-adjusted) per optimizer, plus the
//! serial-vs-parallel axis of the threaded execution backend.
//!
//! Four sections:
//! * **SIMD kernel speedup** (no artifacts needed): the matmul /
//!   elementwise / reduction families at pool width 1, scalar dispatch
//!   (`simd::with_scalar`) vs the feature's lane kernels — the direct
//!   measurement behind the "matmul-family ≥ 2x with `--features simd`"
//!   acceptance line. Every timed pair cross-checks its outputs
//!   (ulp-bounded), so a reported speedup can never come from diverging
//!   numerics; CI's bench-smoke job gates on exactly these asserts.
//! * **Native kernel speedup** (no artifacts needed): times one
//!   `Slot::refresh` + `Slot::step` round per matmul-heavy optimizer at
//!   pool width 1 vs all cores — the direct measurement behind the
//!   "≥1.5x on ≥4 cores" acceptance line.
//! * **Decomposition speedup** (no artifacts needed): `jacobi_eigh` and
//!   `mgs_qr` at refresh-dominating sizes, width 1 (serial baseline,
//!   bitwise identical output) vs all cores.
//! * **Blocked vs rounds** (no artifacts needed): the blocked two-sided
//!   Jacobi against the flat Brent-Luk path at n ∈ {1024, 2048} — the
//!   huge-n refresh axis, gated on spectral agreement between the paths.
//! * **Training throughput** (needs `make artifacts`): the Fig. 3 table,
//!   each optimizer run serial and parallel with the speedup column.
//!
//! `AR_BENCH_SMOKE=1` shrinks the no-artifact sections for CI; a
//! machine-readable summary lands in
//! `runs/bench/fig3_throughput_summary.json` either way.

use alice_racs::bench::{
    artifacts_available, bench_cfg, bench_opts, bench_steps, blocked_vs_rounds_table, run_one,
    smoke, time_fn, write_summary, TablePrinter,
};
use alice_racs::coordinator::Summary;
use alice_racs::linalg::{
    jacobi_eigh, jacobi_eigh_blocked, jacobi_eigh_rounds, jacobi_eigh_serial, mgs_qr, simd,
    Mat,
};
use alice_racs::opt::{build, Hyper, Slot};
use alice_racs::util::json::{num, obj, s};
use alice_racs::util::{pool, Json, Pcg};

fn bar(frac: f64, width: usize) -> String {
    let n = ((frac.clamp(0.0, 1.0)) * width as f64).round() as usize;
    "█".repeat(n)
}

/// Scalar-vs-SIMD dispatch speedup of the linalg kernel families at pool
/// width 1 (isolating the lane axis from the thread axis). Asserts
/// ulp-bounded agreement between the two dispatch paths for every timed
/// kernel; returns the section's JSON summary.
fn simd_kernel_section() -> Json {
    let (m, k, n, iters) = if smoke() { (96, 128, 80, 2) } else { (256, 512, 256, 5) };
    println!(
        "== simd kernel speedup: width 1, {}x{}x{}, feature {}, avx2 {} ==",
        m,
        k,
        n,
        if simd::compiled() { "on" } else { "off (speedups ~1x by construction)" },
        simd::avx2_available(),
    );
    let mut rng = Pcg::seeded(0x51fd);
    let a = Mat::from_vec(m, k, rng.normal_vec(m * k, 1.0));
    let b = Mat::from_vec(k, n, rng.normal_vec(k * n, 1.0));
    let at = Mat::from_vec(k, m, rng.normal_vec(k * m, 1.0)); // atᵀ @ b
    let bt = Mat::from_vec(n, k, rng.normal_vec(n * k, 1.0)); // a @ btᵀ
    let x = rng.normal_vec(k, 1.0);

    let mut table = TablePrinter::new(&["kernel", "scalar ms", "simd ms", "speedup"]);
    let mut rows: Vec<Json> = Vec::new();
    let mut family_min = f64::INFINITY;
    let mut case = |name: &str, matmul_family: bool, tol: f32, f: &dyn Fn() -> Vec<f32>| {
        let warm = 1;
        let (scalar_t, scalar_out) = pool::with_threads(1, || {
            simd::with_scalar(|| {
                let t = time_fn(name, warm, iters, || {
                    std::hint::black_box(f());
                });
                (t, f())
            })
        });
        let (fast_t, fast_out) = pool::with_threads(1, || {
            let t = time_fn(name, warm, iters, || {
                std::hint::black_box(f());
            });
            (t, f())
        });
        // the parity gate: a speedup from diverging numerics is a bug
        assert_eq!(scalar_out.len(), fast_out.len(), "{name}: shape drift");
        for (sv, fv) in scalar_out.iter().zip(&fast_out) {
            assert!(
                (sv - fv).abs() <= tol * (1.0 + sv.abs().max(fv.abs())),
                "{name}: scalar {sv} vs simd {fv} outside ulp bound"
            );
        }
        let speedup = scalar_t.mean_ms / fast_t.mean_ms.max(1e-9);
        if matmul_family {
            family_min = family_min.min(speedup);
        }
        table.row(vec![
            name.to_string(),
            format!("{:.2}", scalar_t.mean_ms),
            format!("{:.2}", fast_t.mean_ms),
            format!("{speedup:.2}x"),
        ]);
        rows.push(obj(vec![
            ("kernel", s(name)),
            ("scalar_ms", num(scalar_t.mean_ms)),
            ("simd_ms", num(fast_t.mean_ms)),
            ("speedup", num(speedup)),
        ]));
    };
    case("matmul", true, 1e-4, &|| a.matmul(&b).data);
    case("matmul_tn", true, 1e-4, &|| at.matmul_tn(&b).data);
    case("matmul_nt", true, 1e-4, &|| a.matmul_nt(&bt).data);
    case("matvec", true, 1e-4, &|| a.matvec(&x));
    case("ema_", false, 0.0, &|| {
        // vertical kernel: zero drift allowed
        let mut e = a.clone();
        e.ema_(0.9, &a, 0.1);
        e.data
    });
    case("add+scale", false, 0.0, &|| a.add(&a).scale(0.5).data);
    case("fro_norm_sq", false, 1e-4, &|| vec![a.fro_norm_sq()]);
    case("col_sq_norms", false, 0.0, &|| a.col_sq_norms());
    // iterative trajectory — ulp drift amplifies through the passes
    case("mgs_qr", false, 1e-3, &|| mgs_qr(&at).data);
    table.print();
    println!(
        "matmul-family min speedup: {family_min:.2}x \
         (acceptance: ≥ 2x with --features simd on AVX2 hosts)\n"
    );
    obj(vec![
        ("feature", Json::Bool(simd::compiled())),
        ("avx2", Json::Bool(simd::avx2_available())),
        ("shape", s(&format!("{m}x{k}x{n}"))),
        ("matmul_family_min_speedup", num(family_min)),
        ("kernels", Json::Arr(rows)),
    ])
}

/// Serial-vs-parallel micro-bench on the native optimizer kernels: one
/// refresh + `steps` update steps on a synthetic (rows x cols) gradient.
fn kernel_speedup_section() {
    let cores = pool::available();
    let (rows, cols, steps) = if smoke() { (96, 128, 2) } else { (256, 512, 4) };
    let iters = if smoke() { 1 } else { 3 };
    let hp = Hyper { rank: 32, leading: 10, ..Hyper::default() };
    println!("== native kernel speedup: {rows}x{cols} grads, width 1 vs {cores} ==");
    let mut table =
        TablePrinter::new(&["optimizer", "serial ms", "parallel ms", "speedup"]);
    for name in ["muon", "shampoo", "soap", "alice"] {
        let mut rng = Pcg::seeded(0xf16_3);
        let grads: Vec<Mat> = (0..steps)
            .map(|_| Mat::from_vec(rows, cols, rng.normal_vec(rows * cols, 0.1)))
            .collect();
        let measure = |width: usize| {
            pool::with_threads(width, || {
                time_fn(name, 1, iters, || {
                    let opt = build(name, &hp).expect("registry");
                    let mut slot = Slot::new(opt, rows, cols);
                    for (t, g) in grads.iter().enumerate() {
                        if t == 0 {
                            slot.refresh(g, 7);
                        }
                        std::hint::black_box(slot.step(g, t as u64 + 1));
                    }
                })
            })
        };
        let serial = measure(1);
        let parallel = measure(cores);
        table.row(vec![
            name.to_string(),
            format!("{:.1}", serial.mean_ms),
            format!("{:.1}", parallel.mean_ms),
            format!("{:.2}x", serial.mean_ms / parallel.mean_ms.max(1e-9)),
        ]);
    }
    table.print();
    println!();
}

/// Serial-vs-parallel axis for the decomposition kernels: the periodic
/// subspace refreshes are eigendecomposition + QR, which dominate wall
/// clock at lm-head scale, so this is the speedup that matters for the
/// refresh phase. Width 1 is the serial baseline — same bytes out, by the
/// width-invariance contract (`rust/tests/decomp_parity.rs`).
fn decomp_speedup_section() {
    let cores = pool::available();
    let mut rng = Pcg::seeded(0xdec0);
    let n = if smoke() { 96 } else { 192 };
    let iters = if smoke() { 1 } else { 3 };
    let b = Mat::from_vec(n, n, rng.normal_vec(n * n, 1.0));
    let spd = b.matmul_nt(&b);
    let (qm, qr) = if smoke() { (192, 48) } else { (512, 96) };
    let tall = Mat::from_vec(qm, qr, rng.normal_vec(qm * qr, 1.0));
    println!("== decomposition speedup: width 1 vs {cores} ==");
    let mut table = TablePrinter::new(&[
        "kernel", "serial ms", "historical serial", "parallel ms", "speedup",
    ]);
    let eigh = || {
        std::hint::black_box(jacobi_eigh(&spd, 10));
    };
    let eigh_cyclic = || {
        std::hint::black_box(jacobi_eigh_serial(&spd, 10));
    };
    let qr_f = || {
        std::hint::black_box(mgs_qr(&tall));
    };
    // `historical serial` times the pre-pool kernel where one survives
    // (the cyclic Jacobi sweep); for the others, width 1 of the current
    // algorithm is the serial baseline (identical bytes out).
    let eigh_name = format!("jacobi_eigh {n}x{n} (10 sweeps)");
    let qr_name = format!("mgs_qr {qm}x{qr} (MGS2)");
    let cases: [(&str, &dyn Fn(), Option<&dyn Fn()>); 2] = [
        (&eigh_name, &eigh, Some(&eigh_cyclic)),
        (&qr_name, &qr_f, None),
    ];
    for (name, f, cyclic) in cases {
        let serial = pool::with_threads(1, || time_fn(name, 1, iters, || f()));
        let parallel = pool::with_threads(cores, || time_fn(name, 1, iters, || f()));
        let hist = cyclic
            .map(|c| pool::with_threads(1, || time_fn(name, 1, iters, || c())))
            .map(|t| format!("{:.1}", t.mean_ms))
            .unwrap_or_else(|| "= serial".into());
        table.row(vec![
            name.to_string(),
            format!("{:.1}", serial.mean_ms),
            hist,
            format!("{:.1}", parallel.mean_ms),
            format!("{:.2}x", serial.mean_ms / parallel.mean_ms.max(1e-9)),
        ]);
    }
    table.print();
    println!();
}

/// Blocked-vs-rounds axis for the huge-n refreshes (ISSUE 5 tentpole):
/// `jacobi_eigh_blocked` against the flat Brent-Luk `jacobi_eigh_rounds`
/// at n ∈ {1024, 2048} (smoke sizes via `bench::blocked_vs_rounds_table`,
/// shared with fig6). Spectral agreement between the two paths is
/// asserted at a convergence-sized n before any timing row is reported —
/// a speedup from a diverging decomposition is a bug, same policy as the
/// SIMD section.
fn blocked_vs_rounds_section() -> Json {
    // agreement gate: converged spectra must match across the two paths
    let mut rng = Pcg::seeded(0xb10c);
    let gate_n = 160;
    let b = Mat::from_vec(gate_n, gate_n, rng.normal_vec(gate_n * gate_n, 1.0));
    let gate = b.matmul_nt(&b);
    let (_, lam_r) = jacobi_eigh_rounds(&gate, 30);
    let (_, lam_b) = jacobi_eigh_blocked(&gate, 30);
    let scale = lam_r[0].abs().max(1.0);
    for (r, bl) in lam_r.iter().zip(&lam_b) {
        assert!(
            (r - bl).abs() < 1e-2 * scale,
            "blocked vs rounds spectra diverge: {r} vs {bl}"
        );
    }
    // timing table: the bench:: helper shared with fig6 (one sizing
    // policy, so the two summary artifacts cannot drift)
    blocked_vs_rounds_table()
}

fn main() {
    let simd_json = simd_kernel_section();
    kernel_speedup_section();
    decomp_speedup_section();
    let blocked_json = blocked_vs_rounds_section();
    let summary = obj(vec![
        ("smoke", Json::Bool(smoke())),
        ("simd", simd_json),
        ("blocked_eigh", blocked_json),
    ]);
    match write_summary("fig3_throughput", &summary) {
        Ok(path) => println!("summary → {path}"),
        Err(e) => eprintln!("could not write fig3 summary: {e:#}"),
    }
    if !artifacts_available() {
        return;
    }
    let steps = bench_steps(120);
    let cores = pool::available();
    let opts = bench_opts(&["adam", "galore", "fira", "apollo_mini", "racs", "alice0", "alice"]);
    println!(
        "== Fig. 3 analogue: throughput / effective throughput \
         ({steps} steps, serial vs {cores} threads) =="
    );
    let mut results: Vec<Summary> = Vec::new();
    let mut serial_tps: Vec<(String, f64)> = Vec::new();
    for opt in &opts {
        let mut cfg_serial = bench_cfg(opt, "fig3_serial", steps);
        cfg_serial.threads = 1;
        match run_one(cfg_serial) {
            Ok(s) => serial_tps.push((opt.clone(), s.tokens_per_sec)),
            Err(e) => eprintln!("{opt} (serial): {e:#}"),
        }
        let mut cfg = bench_cfg(opt, "fig3", steps);
        cfg.threads = 0; // all cores
        match run_one(cfg) {
            Ok(s) => results.push(s),
            Err(e) => eprintln!("{opt}: {e:#}"),
        }
    }
    let adam = results.iter().find(|s| s.optimizer == "adam").cloned();
    let max_tp = results
        .iter()
        .map(|s| s.tokens_per_sec)
        .fold(1.0f64, f64::max);
    let mut table = TablePrinter::new(&[
        "optimizer", "TP tok/s", "", "serial TP", "par speedup", "effective TP", "",
    ]);
    let mut max_etp = 1.0f64;
    let etps: Vec<f64> = results
        .iter()
        .map(|s| adam.as_ref().map(|a| s.effective_tokens_per_sec(a)).unwrap_or(0.0))
        .collect();
    for &e in &etps {
        max_etp = max_etp.max(e);
    }
    for (s, &etp) in results.iter().zip(&etps) {
        let stp = serial_tps
            .iter()
            .find(|(name, _)| *name == s.optimizer)
            .map(|&(_, tp)| tp)
            .unwrap_or(f64::NAN);
        table.row(vec![
            s.optimizer.clone(),
            format!("{:.0}", s.tokens_per_sec),
            bar(s.tokens_per_sec / max_tp, 20),
            format!("{stp:.0}"),
            format!("{:.2}x", s.tokens_per_sec / stp.max(1e-9)),
            format!("{etp:.0}"),
            bar(etp / max_etp, 20),
        ]);
    }
    table.print();
    println!(
        "\nPaper shape: Alice/RACS absolute TP within ~15% of Adam; \
         effective TP of Alice/RACS ≥ 2x Adam's. Baselines that never \
         reach Adam's final loss print effective TP 0 (as in Fig. 3b). \
         `par speedup` compares --threads 1 against all cores; the \
         grad_exec phase is PJRT-bound, so the end-to-end ratio is \
         smaller than the native-kernel ratio above."
    );
}
