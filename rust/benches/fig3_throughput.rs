//! Figure 3 — (a) absolute throughput (tokens/s) and (b) effective
//! throughput (Adam-referenced, speed-up-adjusted) per optimizer.

use alice_racs::bench::{artifacts_available, bench_cfg, bench_opts, bench_steps, run_one, TablePrinter};
use alice_racs::coordinator::Summary;

fn bar(frac: f64, width: usize) -> String {
    let n = ((frac.clamp(0.0, 1.0)) * width as f64).round() as usize;
    "█".repeat(n)
}

fn main() {
    if !artifacts_available() {
        return;
    }
    let steps = bench_steps(120);
    let opts = bench_opts(&["adam", "galore", "fira", "apollo_mini", "racs", "alice0", "alice"]);
    println!("== Fig. 3 analogue: throughput / effective throughput ({steps} steps) ==");
    let mut results: Vec<Summary> = Vec::new();
    for opt in &opts {
        match run_one(bench_cfg(opt, "fig3", steps)) {
            Ok(s) => results.push(s),
            Err(e) => eprintln!("{opt}: {e:#}"),
        }
    }
    let adam = results.iter().find(|s| s.optimizer == "adam").cloned();
    let max_tp = results
        .iter()
        .map(|s| s.tokens_per_sec)
        .fold(1.0f64, f64::max);
    let mut table = TablePrinter::new(&["optimizer", "TP tok/s", "", "effective TP", ""]);
    let mut max_etp = 1.0f64;
    let etps: Vec<f64> = results
        .iter()
        .map(|s| adam.as_ref().map(|a| s.effective_tokens_per_sec(a)).unwrap_or(0.0))
        .collect();
    for &e in &etps {
        max_etp = max_etp.max(e);
    }
    for (s, &etp) in results.iter().zip(&etps) {
        table.row(vec![
            s.optimizer.clone(),
            format!("{:.0}", s.tokens_per_sec),
            bar(s.tokens_per_sec / max_tp, 20),
            format!("{etp:.0}"),
            bar(etp / max_etp, 20),
        ]);
    }
    table.print();
    println!(
        "\nPaper shape: Alice/RACS absolute TP within ~15% of Adam; \
         effective TP of Alice/RACS ≥ 2x Adam's. Baselines that never \
         reach Adam's final loss print effective TP 0 (as in Fig. 3b)."
    );
}
