//! Figure 3 — (a) absolute throughput (tokens/s) and (b) effective
//! throughput (Adam-referenced, speed-up-adjusted) per optimizer, plus the
//! serial-vs-parallel axis of the threaded execution backend.
//!
//! Three sections:
//! * **Native kernel speedup** (no artifacts needed): times one
//!   `Slot::refresh` + `Slot::step` round per matmul-heavy optimizer at
//!   pool width 1 vs all cores — the direct measurement behind the
//!   "≥1.5x on ≥4 cores" acceptance line.
//! * **Decomposition speedup** (no artifacts needed): `jacobi_eigh` and
//!   `mgs_qr` at refresh-dominating sizes, width 1 (serial baseline,
//!   bitwise identical output) vs all cores.
//! * **Training throughput** (needs `make artifacts`): the Fig. 3 table,
//!   each optimizer run serial and parallel with the speedup column.

use alice_racs::bench::{
    artifacts_available, bench_cfg, bench_opts, bench_steps, run_one, time_fn, TablePrinter,
};
use alice_racs::coordinator::Summary;
use alice_racs::linalg::{jacobi_eigh, jacobi_eigh_serial, mgs_qr, Mat};
use alice_racs::opt::{build, Hyper, Slot};
use alice_racs::util::{pool, Pcg};

fn bar(frac: f64, width: usize) -> String {
    let n = ((frac.clamp(0.0, 1.0)) * width as f64).round() as usize;
    "█".repeat(n)
}

/// Serial-vs-parallel micro-bench on the native optimizer kernels: one
/// refresh + `steps` update steps on a synthetic (rows x cols) gradient.
fn kernel_speedup_section() {
    let cores = pool::available();
    let (rows, cols, steps) = (256, 512, 4);
    let hp = Hyper { rank: 32, leading: 10, ..Hyper::default() };
    println!("== native kernel speedup: {rows}x{cols} grads, width 1 vs {cores} ==");
    let mut table =
        TablePrinter::new(&["optimizer", "serial ms", "parallel ms", "speedup"]);
    for name in ["muon", "shampoo", "soap", "alice"] {
        let mut rng = Pcg::seeded(0xf16_3);
        let grads: Vec<Mat> = (0..steps)
            .map(|_| Mat::from_vec(rows, cols, rng.normal_vec(rows * cols, 0.1)))
            .collect();
        let measure = |width: usize| {
            pool::with_threads(width, || {
                time_fn(name, 1, 3, || {
                    let opt = build(name, &hp).expect("registry");
                    let mut slot = Slot::new(opt, rows, cols);
                    for (t, g) in grads.iter().enumerate() {
                        if t == 0 {
                            slot.refresh(g, 7);
                        }
                        std::hint::black_box(slot.step(g, t as u64 + 1));
                    }
                })
            })
        };
        let serial = measure(1);
        let parallel = measure(cores);
        table.row(vec![
            name.to_string(),
            format!("{:.1}", serial.mean_ms),
            format!("{:.1}", parallel.mean_ms),
            format!("{:.2}x", serial.mean_ms / parallel.mean_ms.max(1e-9)),
        ]);
    }
    table.print();
    println!();
}

/// Serial-vs-parallel axis for the decomposition kernels: the periodic
/// subspace refreshes are eigendecomposition + QR, which dominate wall
/// clock at lm-head scale, so this is the speedup that matters for the
/// refresh phase. Width 1 is the serial baseline — same bytes out, by the
/// width-invariance contract (`rust/tests/decomp_parity.rs`).
fn decomp_speedup_section() {
    let cores = pool::available();
    let mut rng = Pcg::seeded(0xdec0);
    let n = 192;
    let b = Mat::from_vec(n, n, rng.normal_vec(n * n, 1.0));
    let spd = b.matmul_nt(&b);
    let (qm, qr) = (512, 96);
    let tall = Mat::from_vec(qm, qr, rng.normal_vec(qm * qr, 1.0));
    println!("== decomposition speedup: width 1 vs {cores} ==");
    let mut table = TablePrinter::new(&[
        "kernel", "serial ms", "historical serial", "parallel ms", "speedup",
    ]);
    let eigh = || {
        std::hint::black_box(jacobi_eigh(&spd, 10));
    };
    let eigh_cyclic = || {
        std::hint::black_box(jacobi_eigh_serial(&spd, 10));
    };
    let qr_f = || {
        std::hint::black_box(mgs_qr(&tall));
    };
    // `historical serial` times the pre-pool kernel where one survives
    // (the cyclic Jacobi sweep); for the others, width 1 of the current
    // algorithm is the serial baseline (identical bytes out).
    let cases: [(&str, &dyn Fn(), Option<&dyn Fn()>); 2] = [
        ("jacobi_eigh 192x192 (10 sweeps)", &eigh, Some(&eigh_cyclic)),
        ("mgs_qr 512x96 (MGS2)", &qr_f, None),
    ];
    for (name, f, cyclic) in cases {
        let serial = pool::with_threads(1, || time_fn(name, 1, 3, || f()));
        let parallel = pool::with_threads(cores, || time_fn(name, 1, 3, || f()));
        let hist = cyclic
            .map(|c| pool::with_threads(1, || time_fn(name, 1, 3, || c())))
            .map(|t| format!("{:.1}", t.mean_ms))
            .unwrap_or_else(|| "= serial".into());
        table.row(vec![
            name.to_string(),
            format!("{:.1}", serial.mean_ms),
            hist,
            format!("{:.1}", parallel.mean_ms),
            format!("{:.2}x", serial.mean_ms / parallel.mean_ms.max(1e-9)),
        ]);
    }
    table.print();
    println!();
}

fn main() {
    kernel_speedup_section();
    decomp_speedup_section();
    if !artifacts_available() {
        return;
    }
    let steps = bench_steps(120);
    let cores = pool::available();
    let opts = bench_opts(&["adam", "galore", "fira", "apollo_mini", "racs", "alice0", "alice"]);
    println!(
        "== Fig. 3 analogue: throughput / effective throughput \
         ({steps} steps, serial vs {cores} threads) =="
    );
    let mut results: Vec<Summary> = Vec::new();
    let mut serial_tps: Vec<(String, f64)> = Vec::new();
    for opt in &opts {
        let mut cfg_serial = bench_cfg(opt, "fig3_serial", steps);
        cfg_serial.threads = 1;
        match run_one(cfg_serial) {
            Ok(s) => serial_tps.push((opt.clone(), s.tokens_per_sec)),
            Err(e) => eprintln!("{opt} (serial): {e:#}"),
        }
        let mut cfg = bench_cfg(opt, "fig3", steps);
        cfg.threads = 0; // all cores
        match run_one(cfg) {
            Ok(s) => results.push(s),
            Err(e) => eprintln!("{opt}: {e:#}"),
        }
    }
    let adam = results.iter().find(|s| s.optimizer == "adam").cloned();
    let max_tp = results
        .iter()
        .map(|s| s.tokens_per_sec)
        .fold(1.0f64, f64::max);
    let mut table = TablePrinter::new(&[
        "optimizer", "TP tok/s", "", "serial TP", "par speedup", "effective TP", "",
    ]);
    let mut max_etp = 1.0f64;
    let etps: Vec<f64> = results
        .iter()
        .map(|s| adam.as_ref().map(|a| s.effective_tokens_per_sec(a)).unwrap_or(0.0))
        .collect();
    for &e in &etps {
        max_etp = max_etp.max(e);
    }
    for (s, &etp) in results.iter().zip(&etps) {
        let stp = serial_tps
            .iter()
            .find(|(name, _)| *name == s.optimizer)
            .map(|&(_, tp)| tp)
            .unwrap_or(f64::NAN);
        table.row(vec![
            s.optimizer.clone(),
            format!("{:.0}", s.tokens_per_sec),
            bar(s.tokens_per_sec / max_tp, 20),
            format!("{stp:.0}"),
            format!("{:.2}x", s.tokens_per_sec / stp.max(1e-9)),
            format!("{etp:.0}"),
            bar(etp / max_etp, 20),
        ]);
    }
    table.print();
    println!(
        "\nPaper shape: Alice/RACS absolute TP within ~15% of Adam; \
         effective TP of Alice/RACS ≥ 2x Adam's. Baselines that never \
         reach Adam's final loss print effective TP 0 (as in Fig. 3b). \
         `par speedup` compares --threads 1 against all cores; the \
         grad_exec phase is PJRT-bound, so the end-to-end ratio is \
         smaller than the native-kernel ratio above."
    );
}
