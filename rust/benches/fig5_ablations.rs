//! Figure 5 — the five ablation panels (paper Sec. 7.2 / App. F.7):
//! (a) tracking × switching        (b) switching strategies
//! (c) compensation strategies     (d) last-layer effect
//! (e) RACS with/without EMA
//!
//! Each panel = a family of short runs; curves land in
//! runs/bench/fig5/<panel>/<variant>/eval.csv, final points printed here.

use alice_racs::bench::{artifacts_available, bench_cfg, bench_steps, run_one, TablePrinter};
use alice_racs::config::RunConfig;
use alice_racs::opt::{Compen, Switch};

fn show(panel: &str, rows: &[(String, anyhow::Result<f32>)]) {
    println!("\n-- Fig. 5({panel}) --");
    let mut table = TablePrinter::new(&["variant", "final eval ppl"]);
    for (label, res) in rows {
        match res {
            Ok(l) => table.row(vec![label.clone(), format!("{:.2}", (*l as f64).exp())]),
            Err(e) => table.row(vec![label.clone(), format!("FAILED: {e}")]),
        }
    }
    table.print();
}

fn run(cfg: RunConfig) -> anyhow::Result<f32> {
    Ok(run_one(cfg)?.final_eval_loss.unwrap_or(f32::NAN))
}

fn main() {
    if !artifacts_available() {
        return;
    }
    let steps = bench_steps(100);
    println!("== Fig. 5 ablations ({steps} steps each) ==");

    // (a) tracking x switching, compensation disabled
    let mut rows = Vec::new();
    for (label, tracking, switch) in [
        ("tracking+switch", true, Switch::Switch),
        ("tracking, no switch", true, Switch::Evd),
        ("no tracking, switch", false, Switch::Switch),
        ("no tracking, no switch", false, Switch::Evd),
    ] {
        let mut cfg = bench_cfg("alice", "fig5/a", steps);
        cfg.out_dir = format!("runs/bench/fig5/a/{}", label.replace([' ', ','], "_"));
        cfg.hp.tracking = tracking;
        cfg.hp.switch = switch;
        cfg.hp.compen = Compen::None;
        rows.push((label.to_string(), run(cfg)));
    }
    show("a: tracking x switch, compen off", &rows);

    // (b) switching strategies
    let mut rows = Vec::new();
    for (label, sw) in [
        ("switch (paper)", Switch::Switch),
        ("gaussian", Switch::Gaussian),
        ("gaussian_mix", Switch::GaussianMix),
        ("full_basis", Switch::FullBasis),
    ] {
        let mut cfg = bench_cfg("alice", "fig5/b", steps);
        cfg.out_dir = format!("runs/bench/fig5/b/{}", label.replace([' ', '(', ')'], "_"));
        cfg.hp.switch = sw;
        rows.push((label.to_string(), run(cfg)));
    }
    show("b: switching strategies", &rows);

    // (c) compensation strategies
    let mut rows = Vec::new();
    for (label, c) in [
        ("optimal (Thm 5.1)", Compen::Optimal),
        ("fira", Compen::Fira),
        ("fira+", Compen::FiraPlus),
        ("none", Compen::None),
    ] {
        let mut cfg = bench_cfg("alice", "fig5/c", steps);
        cfg.out_dir = format!("runs/bench/fig5/c/{}", label.replace([' ', '(', ')', '.', '+'], "_"));
        cfg.hp.compen = c;
        rows.push((label.to_string(), run(cfg)));
    }
    show("c: compensation strategies", &rows);

    // (d) last-layer effect (GaLore vs Alice, ± Adam lm-head)
    let mut rows = Vec::new();
    for opt in ["galore", "alice"] {
        for head in [true, false] {
            let mut cfg = bench_cfg(opt, "fig5/d", steps);
            cfg.out_dir = format!("runs/bench/fig5/d/{opt}_head{head}");
            cfg.last_layer_adam = head;
            rows.push((format!("{opt} (+lm head: {head})"), run(cfg)));
        }
    }
    show("d: last-layer effect", &rows);

    // (e) RACS EMA
    let mut rows = Vec::new();
    for ema in [true, false] {
        let mut cfg = bench_cfg("racs", "fig5/e", steps);
        cfg.out_dir = format!("runs/bench/fig5/e/ema{ema}");
        cfg.hp.racs_ema = ema;
        rows.push((format!("racs (ema: {ema})"), run(cfg)));
    }
    show("e: RACS EMA", &rows);

    println!(
        "\nPaper shapes: (a) tracking needs switching; (b) paper switch \
         beats gaussian variants; (c) optimal > fira+ > fira > none; \
         (d) GaLore degrades without the Adam lm-head far more than Alice; \
         (e) EMA is necessary for RACS."
    );
}
