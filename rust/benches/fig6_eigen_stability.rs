//! Figure 6 — cosine similarity of the leading eigenbasis before/after
//! each projection refresh, with tracking on vs off.
//!
//! The paper's claim: tracking stabilizes the leading basis (high cos for
//! small indices), which is precisely why switching is needed to keep
//! exploring. Data comes from the Alice refresh instrumentation
//! (`diag_cos`), aggregated here per index.
//!
//! A preamble section (no artifacts needed) pins the eigendecomposition
//! itself: the parallel-ordered and blocked Jacobi paths must agree with
//! the serial cyclic baseline on the spectrum, reproduce the width-1
//! bytes exactly, and report their speedups. A second no-artifact
//! section measures the n ≥ 2k refresh axis — blocked two-sided vs flat
//! Brent-Luk rounds at n ∈ {1024, 2048} (smoke: shrunk). A third
//! (ISSUE 6) times the randomized sketched refresh against the exact
//! eigendecomposition at the same sizes, asserts the sketch's bitwise
//! width-parity, and reports the principal-angle agreement of the two
//! bases. All sections land in
//! `runs/bench/fig6_eigen_stability_summary.json`, which CI's
//! bench-smoke job uploads next to the fig3/fig7 summaries.

use alice_racs::bench::{
    artifacts_available, bench_cfg, bench_steps, blocked_vs_rounds_table, smoke, time_fn,
    write_summary, TablePrinter,
};
use alice_racs::coordinator::{run_with, Trainer};
use alice_racs::linalg::{
    jacobi_eigh, jacobi_eigh_blocked, jacobi_eigh_serial, sketched_eigh_mat, Mat,
    SketchSpec,
};
use alice_racs::util::json::{num, obj};
use alice_racs::util::{pool, Json, Pcg};

fn spd(n: usize, seed: u64) -> Mat {
    let mut rng = Pcg::seeded(seed);
    let b = Mat::from_vec(n, n, rng.normal_vec(n * n, 1.0));
    b.matmul_nt(&b)
}

/// Eigendecomposition stability + speedup axis: width 1 vs all cores
/// (bitwise-identical spectra by the width-invariance contract) and the
/// parallel-ordered / blocked paths vs the historical cyclic sweep
/// (algorithmic agreement, tolerance-level — asserted, not just printed).
fn decomp_stability_section() -> Json {
    let cores = pool::available();
    let n = if smoke() { 96 } else { 160 };
    let iters = if smoke() { 1 } else { 3 };
    let a = spd(n, 0xf16_6);
    let (_, lam_w1) = pool::with_threads(1, || jacobi_eigh(&a, 30));
    let (_, lam_wn) = pool::with_threads(cores, || jacobi_eigh(&a, 30));
    let (_, lam_cyc) = jacobi_eigh_serial(&a, 30);
    let (_, lam_blk) = jacobi_eigh_blocked(&a, 30);
    let max_dev_width = lam_w1
        .iter()
        .zip(&lam_wn)
        .map(|(s, p)| (s - p).abs())
        .fold(0.0f32, f32::max);
    assert_eq!(max_dev_width, 0.0, "width-invariance contract violated");
    let scale = lam_cyc[0].abs().max(1.0);
    let rel_dev = |lam: &[f32]| {
        lam.iter()
            .zip(&lam_cyc)
            .map(|(s, c)| (s - c).abs() / scale)
            .fold(0.0f32, f32::max)
    };
    let max_dev_algo = rel_dev(&lam_w1);
    let max_dev_blocked = rel_dev(&lam_blk);
    assert!(max_dev_algo < 1e-2, "rounds vs cyclic spectra diverge: {max_dev_algo}");
    assert!(max_dev_blocked < 1e-2, "blocked vs cyclic spectra diverge: {max_dev_blocked}");
    let run = || {
        std::hint::black_box(jacobi_eigh(&a, 30));
    };
    let run_cyclic = || {
        std::hint::black_box(jacobi_eigh_serial(&a, 30));
    };
    let run_blocked = || {
        std::hint::black_box(jacobi_eigh_blocked(&a, 30));
    };
    let serial = pool::with_threads(1, || time_fn("eigh", 1, iters, run));
    let parallel = pool::with_threads(cores, || time_fn("eigh", 1, iters, run));
    let cyclic = pool::with_threads(1, || time_fn("eigh", 1, iters, run_cyclic));
    let blocked = pool::with_threads(cores, || time_fn("eigh", 1, iters, run_blocked));
    println!("== eigendecomposition stability ({n}x{n}, width 1 vs {cores}) ==");
    let mut table = TablePrinter::new(&["axis", "value"]);
    table.row(vec![
        "max |Δλ| width 1 vs parallel (must be 0)".into(),
        format!("{max_dev_width:.1e}"),
    ]);
    table.row(vec![
        "max rel |Δλ| rounds vs cyclic".into(),
        format!("{max_dev_algo:.1e}"),
    ]);
    table.row(vec![
        "max rel |Δλ| blocked vs cyclic".into(),
        format!("{max_dev_blocked:.1e}"),
    ]);
    table.row(vec!["serial ms (rounds, width 1)".into(), format!("{:.1}", serial.mean_ms)]);
    table.row(vec![
        "historical cyclic ms".into(),
        format!("{:.1}", cyclic.mean_ms),
    ]);
    table.row(vec!["parallel ms".into(), format!("{:.1}", parallel.mean_ms)]);
    table.row(vec![
        "blocked ms (parallel)".into(),
        format!("{:.1}", blocked.mean_ms),
    ]);
    table.row(vec![
        "decomposition speedup".into(),
        format!("{:.2}x", serial.mean_ms / parallel.mean_ms.max(1e-9)),
    ]);
    table.row(vec![
        "speedup vs historical cyclic".into(),
        format!("{:.2}x", cyclic.mean_ms / parallel.mean_ms.max(1e-9)),
    ]);
    table.print();
    println!();
    obj(vec![
        ("n", num(n as f64)),
        ("max_rel_dev_rounds", num(max_dev_algo as f64)),
        ("max_rel_dev_blocked", num(max_dev_blocked as f64)),
        ("rounds_w1_ms", num(serial.mean_ms)),
        ("cyclic_ms", num(cyclic.mean_ms)),
        ("rounds_par_ms", num(parallel.mean_ms)),
        ("blocked_par_ms", num(blocked.mean_ms)),
    ])
}

/// ISSUE 6 — sketched vs exact refresh at the n ≥ 2k refresh sizes:
/// wall-time for one full refresh each way, principal-angle agreement of
/// the two leading bases (asserted, not just printed), and the sketch's
/// bitwise width-parity. Operators are planted low-rank-plus-noise —
/// the gradient-covariance shape the refresh actually sees — so the
/// exact reference is meaningful at a modest sweep budget.
fn sketch_vs_exact_section() -> Json {
    let cores = pool::available();
    let sizes: Vec<usize> = if smoke() { vec![192, 256] } else { vec![1024, 2048] };
    // full-size exact refreshes are O(sweeps·n³); 8 sweeps converge the
    // well-separated planted spectrum, smoke sizes can afford 30
    let exact_sweeps = if smoke() { 30 } else { 8 };
    let iters = if smoke() { 1 } else { 2 };
    let r = 16usize;
    let spec = SketchSpec { rank: r, oversample: 8, power_iters: 2, sweeps: 30 };
    println!(
        "== sketched vs exact refresh: rank {r} + {p} oversample, q = {q}, width {cores} ==",
        p = spec.oversample,
        q = spec.power_iters
    );
    let mut table =
        TablePrinter::new(&["n", "exact ms", "sketch ms", "speedup", "min cos²"]);
    let mut rows: Vec<Json> = Vec::new();
    for &n in &sizes {
        let mut rng = Pcg::seeded(0x5ce7 + n as u64);
        let b = Mat::from_vec(n, r, rng.normal_vec(n * r, 1.0));
        let e = Mat::from_vec(n, n, rng.normal_vec(n * n, 1.0));
        let a = b.matmul_nt(&b).scale(4.0).add(&e.matmul_nt(&e).scale(1e-3 / n as f32));
        let exact = pool::with_threads(cores, || {
            time_fn("exact", 0, iters, || {
                std::hint::black_box(jacobi_eigh(&a, exact_sweeps));
            })
        });
        let sketch = pool::with_threads(cores, || {
            time_fn("sketch", 0, iters, || {
                std::hint::black_box(sketched_eigh_mat(&a, None, &spec, 11));
            })
        });
        // quality: min principal-angle cos² between the two leading bases
        let ue = pool::with_threads(cores, || jacobi_eigh(&a, exact_sweeps).0).take_cols(r);
        let us = pool::with_threads(cores, || sketched_eigh_mat(&a, None, &spec, 11).0);
        let m = ue.matmul_tn(&us);
        let (_, ang) = jacobi_eigh_serial(&m.matmul_tn(&m), 30);
        let min_cos2 = *ang.last().unwrap();
        assert!(
            min_cos2 > 0.9,
            "sketch lost the leading subspace at n = {n}: min cos² = {min_cos2}"
        );
        // width-parity: the sketch is part of the bitwise contract
        let w1 = pool::with_threads(1, || sketched_eigh_mat(&a, None, &spec, 11));
        assert_eq!(w1.0.data, us.data, "sketch width-parity violated at n = {n}");
        let speedup = exact.mean_ms / sketch.mean_ms.max(1e-9);
        table.row(vec![
            n.to_string(),
            format!("{:.1}", exact.mean_ms),
            format!("{:.1}", sketch.mean_ms),
            format!("{speedup:.2}x"),
            format!("{min_cos2:.4}"),
        ]);
        rows.push(obj(vec![
            ("n", num(n as f64)),
            ("exact_ms", num(exact.mean_ms)),
            ("sketch_ms", num(sketch.mean_ms)),
            ("speedup", num(speedup)),
            ("min_cos2", num(min_cos2 as f64)),
        ]));
    }
    table.print();
    println!(
        "\nCost model: exact = O(sweeps·n³) Jacobi over the materialized \
         operator; sketch = (q + 2) thin products + one (r+p)² Jacobi, \
         O(n²·(r+p)·(q+2)) here — and O(n·m·(r+p)·(q+2)) with no GGᵀ at \
         all on Alice's operator form. Record full-size numbers in \
         EXPERIMENTS §PR-6.\n"
    );
    obj(vec![
        ("rank", num(r as f64)),
        ("oversample", num(spec.oversample as f64)),
        ("power_iters", num(spec.power_iters as f64)),
        ("exact_sweeps", num(exact_sweeps as f64)),
        ("sizes", Json::Arr(rows)),
    ])
}

fn main() {
    let stability = decomp_stability_section();
    // the n ≥ 2k refresh axis — agreement between the paths was just
    // asserted above at a convergence-sized n; the timing table itself
    // is the bench:: helper shared with fig3 (one sizing policy)
    let blocked = blocked_vs_rounds_table();
    let sketch = sketch_vs_exact_section();
    let summary = obj(vec![
        ("smoke", Json::Bool(smoke())),
        ("stability", stability),
        ("blocked_vs_rounds", blocked),
        ("sketch_vs_exact", sketch),
    ]);
    match write_summary("fig6_eigen_stability", &summary) {
        Ok(path) => println!("summary → {path}"),
        Err(e) => eprintln!("could not write fig6 summary: {e:#}"),
    }
    if !artifacts_available() {
        return;
    }
    let steps = bench_steps(120);
    println!("== Fig. 6 analogue: eigenbasis cosine similarity across refreshes ==");
    let mut table = TablePrinter::new(&[
        "variant", "refreshes", "mean cos idx 0-1 (leading)", "mean cos tail",
    ]);
    for tracking in [true, false] {
        let mut cfg = bench_cfg("alice", "fig6", steps);
        cfg.out_dir = format!("runs/bench/fig6/tracking_{tracking}");
        cfg.hp.tracking = tracking;
        cfg.hp.interval = (steps / 6).max(2); // several refreshes per run
        let mut tr = Trainer::new(cfg).expect("trainer");
        run_with(&mut tr).expect("run");
        // aggregate cos per index over all refreshes after the first
        let mut lead = Vec::new();
        let mut tail = Vec::new();
        for (_, _, cos) in tr.cos_log.iter().skip(1) {
            for (i, &c) in cos.iter().enumerate() {
                if i < 2 {
                    lead.push(c as f64);
                } else {
                    tail.push(c as f64);
                }
            }
        }
        let refreshes = tr.cos_log.len();
        table.row(vec![
            format!("tracking = {tracking}"),
            refreshes.to_string(),
            format!("{:.3}", alice_racs::util::mean(&lead)),
            format!("{:.3}", alice_racs::util::mean(&tail)),
        ]);
        // per-run CSV already written by the trainer (eigen_cos.csv)
    }
    table.print();
    println!(
        "\nPaper shape: with tracking the leading indices stay near cos 1 \
         across refreshes (stability of the leading basis, Fig. 6), the \
         tail churns; without tracking the leading basis churns more. Raw \
         per-refresh data: runs/bench/fig6/*/eigen_cos.csv"
    );
}
