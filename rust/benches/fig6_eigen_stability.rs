//! Figure 6 — cosine similarity of the leading eigenbasis before/after
//! each projection refresh, with tracking on vs off.
//!
//! The paper's claim: tracking stabilizes the leading basis (high cos for
//! small indices), which is precisely why switching is needed to keep
//! exploring. Data comes from the Alice refresh instrumentation
//! (`diag_cos`), aggregated here per index.

use alice_racs::bench::{artifacts_available, bench_cfg, bench_steps, TablePrinter};
use alice_racs::coordinator::{run_with, Trainer};

fn main() {
    if !artifacts_available() {
        return;
    }
    let steps = bench_steps(120);
    println!("== Fig. 6 analogue: eigenbasis cosine similarity across refreshes ==");
    let mut table = TablePrinter::new(&[
        "variant", "refreshes", "mean cos idx 0-1 (leading)", "mean cos tail",
    ]);
    for tracking in [true, false] {
        let mut cfg = bench_cfg("alice", "fig6", steps);
        cfg.out_dir = format!("runs/bench/fig6/tracking_{tracking}");
        cfg.hp.tracking = tracking;
        cfg.hp.interval = (steps / 6).max(2); // several refreshes per run
        let mut tr = Trainer::new(cfg).expect("trainer");
        run_with(&mut tr).expect("run");
        // aggregate cos per index over all refreshes after the first
        let mut lead = Vec::new();
        let mut tail = Vec::new();
        for (_, _, cos) in tr.cos_log.iter().skip(1) {
            for (i, &c) in cos.iter().enumerate() {
                if i < 2 {
                    lead.push(c as f64);
                } else {
                    tail.push(c as f64);
                }
            }
        }
        let refreshes = tr.cos_log.len();
        table.row(vec![
            format!("tracking = {tracking}"),
            refreshes.to_string(),
            format!("{:.3}", alice_racs::util::mean(&lead)),
            format!("{:.3}", alice_racs::util::mean(&tail)),
        ]);
        // per-run CSV already written by the trainer (eigen_cos.csv)
    }
    table.print();
    println!(
        "\nPaper shape: with tracking the leading indices stay near cos 1 \
         across refreshes (stability of the leading basis, Fig. 6), the \
         tail churns; without tracking the leading basis churns more. Raw \
         per-refresh data: runs/bench/fig6/*/eigen_cos.csv"
    );
}
