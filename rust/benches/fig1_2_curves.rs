//! Figures 1 & 2 — eval-perplexity-vs-steps curves for all optimizers,
//! with and without the Adam-trained lm-head ("+lm head").
//!
//! Emits CSV series under runs/bench/fig1_2/ (one train.csv + eval.csv per
//! run — the figure is the eval.csv family) and prints the final points.

use alice_racs::bench::{artifacts_available, bench_cfg, bench_opts, bench_steps, run_one, TablePrinter};

fn main() {
    if !artifacts_available() {
        return;
    }
    let steps = bench_steps(150);
    let opts = bench_opts(&["adam", "galore", "fira", "racs", "alice"]);
    println!("== Fig. 1/2 analogue: eval curves, {steps} steps ==");
    let mut table = TablePrinter::new(&["run", "final eval ppl", "curve file"]);
    for opt in &opts {
        for head_adam in [true, false] {
            // full-rank methods only have the +lm-head protocol (paper)
            if !head_adam && matches!(opt.as_str(), "adam" | "racs") {
                continue;
            }
            let tag = if head_adam { "lmhead_adam" } else { "lmhead_self" };
            let mut cfg = bench_cfg(opt, "fig1_2", steps);
            cfg.out_dir = format!("runs/bench/fig1_2/{opt}_{tag}");
            cfg.last_layer_adam = head_adam;
            cfg.eval_every = (steps / 15).max(1); // dense curve
            match run_one(cfg.clone()) {
                Ok(s) => table.row(vec![
                    format!("{opt} ({tag})"),
                    format!("{:.2}", (s.final_eval_loss.unwrap_or(f32::NAN) as f64).exp()),
                    format!("{}/eval.csv", cfg.out_dir),
                ]),
                Err(e) => eprintln!("{opt}/{tag}: {e:#}"),
            }
        }
    }
    table.print();
    println!(
        "\nPlot eval.csv (step vs eval_ppl) per run to reproduce the \
         figures; paper shape: Alice/RACS curves sit strictly below Adam, \
         GaLore benefits most from '+lm head'."
    );
}
