//! Table 5 — effectiveness of each Alice component (130M in the paper):
//! none → tracking → tracking+switch → tracking+switch+compensation.
//!
//! Runs the native Alice with components toggled on the AOT preset.

use alice_racs::bench::{artifacts_available, bench_cfg, bench_steps, run_one, TablePrinter};
use alice_racs::opt::{Compen, Switch};

fn main() {
    if !artifacts_available() {
        return;
    }
    let steps = bench_steps(120);
    println!("== Table 5 analogue: Alice component ablation ({steps} steps) ==");

    // (label, tracking, switch, compen)
    let variants: [(&str, bool, Switch, Compen); 4] = [
        ("no tracking/switch/compen (≈GaLore)", false, Switch::Evd, Compen::None),
        ("tracking", true, Switch::Evd, Compen::None),
        ("tracking+switch", true, Switch::Switch, Compen::None),
        ("tracking+switch+compen (Alice)", true, Switch::Switch, Compen::Optimal),
    ];

    let mut table = TablePrinter::new(&["components", "eval loss", "eval ppl"]);
    for (label, tracking, switch, compen) in variants {
        let mut cfg = bench_cfg("alice", "table5", steps);
        cfg.out_dir = format!(
            "runs/bench/table5/{}",
            label.replace([' ', '/', '(', ')', '≈', '+'], "_")
        );
        cfg.hp.tracking = tracking;
        cfg.hp.switch = switch;
        cfg.hp.compen = compen;
        match run_one(cfg) {
            Ok(s) => {
                let l = s.final_eval_loss.unwrap_or(f32::NAN);
                table.row(vec![
                    label.into(),
                    format!("{l:.4}"),
                    format!("{:.2}", (l as f64).exp()),
                ]);
            }
            Err(e) => eprintln!("{label}: {e:#}"),
        }
    }
    table.print();
    println!(
        "\nPaper ordering (Table 5): full Alice best (21.95), \
         tracking+switch next (25.11), bare variants worst (26.96/27.35)."
    );
}
