//! Table 1 — structure ↔ optimizer summary: per-step update cost and
//! optimizer-state memory, measured on representative layer shapes.
//!
//! The paper's Table 1 lists asymptotic compute and exact state element
//! counts; this bench reports measured per-step wallclock of the native
//! implementations plus exact state elements (which must equal the
//! closed-form formulas — also asserted in the opt unit tests).

use alice_racs::bench::{time_fn, TablePrinter};
use alice_racs::coordinator::memory::table1_formula;
use alice_racs::linalg::Mat;
use alice_racs::opt::{build, Hyper, Slot};
use alice_racs::util::Pcg;

fn main() {
    let shapes = [(256usize, 1024usize), (512, 2048)];
    let opts = [
        "sgd", "adam", "adafactor", "lion", "muon", "racs", "eigen_adam",
        "shampoo", "soap", "galore", "fira", "apollo_mini", "alice", "alice0",
    ];
    for (m, n) in shapes {
        let r = (m / 8).max(1);
        let hp = Hyper { rank: r, leading: r / 3 + 1, ..Hyper::default() };
        println!("\n== Table 1 @ layer {m}x{n}, rank r = {r} ==");
        let mut table = TablePrinter::new(&[
            "optimizer",
            "step mean",
            "state elems",
            "state formula (paper)",
        ]);
        for name in opts {
            let opt = build(name, &hp).unwrap();
            let mut slot = Slot::new(opt, m, n);
            let mut rng = Pcg::seeded(1);
            let g = Mat::from_vec(m, n, rng.normal_vec(m * n, 0.1));
            slot.refresh(&g, 1);
            let mut t = 0u64;
            let timing = time_fn(name, 1, 5, || {
                t += 1;
                std::hint::black_box(slot.step(&g, t));
            });
            let formula = table1_formula(name, m as u64, n as u64, r as u64)
                .map(|f| {
                    // the paper's totals include the mn weight; state-only
                    // is formula - mn (printed raw for transparency)
                    format!("{f} (incl. weight mn)")
                })
                .unwrap_or_else(|| "-".into());
            table.row(vec![
                name.into(),
                format!("{:.2} ms", timing.mean_ms),
                format!("{}", slot.state_elems()),
                formula,
            ]);
        }
        table.print();
    }
    println!(
        "\nExpected ordering (paper Table 1): SGD < RACS/Apollo ≈ Adafactor \
         < Adam/low-rank < Eigen-Adam < Shampoo/SOAP in state;\n\
         per-step cost grows with structural generality (O(mn) diag → \
         O(m³+n³) Kronecker EVD amortized into refreshes)."
    );
}
