//! Table 3 — estimated optimizer memory per model size (exact arithmetic
//! over the paper's LLaMA presets; BF16, paper App. F.4 accounting).
//! "Mem" = candidate trains the lm-head; "Mem*" = Adam trains it.

use alice_racs::bench::TablePrinter;
use alice_racs::config::presets::{num_params, preset};
use alice_racs::coordinator::estimate;
use alice_racs::opt::Hyper;

fn gib(b: u64) -> String {
    format!("{:.2}G", b as f64 / (1024.0 * 1024.0 * 1024.0))
}

fn main() {
    // paper rank choices: 128 / 256 / 256 / 512 for 60M..1.3B
    let sizes = [
        ("llama60m", 128usize),
        ("llama130m", 256),
        ("llama350m", 256),
        ("llama1b", 512),
    ];
    let opts = ["adam", "galore", "fira", "apollo_mini", "racs", "alice0", "alice"];
    let mut table = TablePrinter::new(&[
        "optimizer", "60M Mem/Mem*", "130M Mem/Mem*", "350M Mem/Mem*", "1.3B Mem/Mem*",
    ]);
    for opt in opts {
        let mut cells = vec![opt.to_string()];
        for (name, rank) in sizes {
            let p = preset(name).unwrap();
            let hp = Hyper { rank, ..Hyper::default() };
            let mem = estimate(p, opt, &hp, false).unwrap().total_bytes;
            let mem_star = estimate(p, opt, &hp, true).unwrap().total_bytes;
            cells.push(format!("{}/{}", gib(mem), gib(mem_star)));
        }
        table.row(cells);
    }
    println!("== Table 3: estimated memory (weights + optimizer states, BF16) ==");
    for (name, _) in sizes {
        let p = preset(name).unwrap();
        println!("  {name}: {} params", num_params(p));
    }
    table.print();
    println!(
        "\nPaper anchors: Adam 0.75G @130M*, 7.48G @1.3B*; RACS 0.43G/2.98G; \
         Alice 0.59G/4.6G; GaLore/Fira 0.57G/4.43G."
    );
}
