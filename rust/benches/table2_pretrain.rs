//! Table 2 — pre-training performance: eval perplexity per optimizer,
//! speed-up in steps vs Adam, throughput (TP) and effective TP.
//!
//! Substituted workload (DESIGN.md): synthetic Zipf×Markov corpus on the
//! AOT-lowered preset instead of C4 on LLaMA-60M..1.3B. The reproduction
//! target is the *ordering* and the ≥2× step-speed-up of Alice over Adam.
//!
//! Scale with AR_BENCH_STEPS (default 120) and AR_BENCH_OPTS.

use alice_racs::bench::{
    artifacts_available, bench_cfg, bench_opts, bench_steps, bench_threads, run_one, TablePrinter,
};
use alice_racs::coordinator::Summary;

fn main() {
    if !artifacts_available() {
        return;
    }
    let steps = bench_steps(120);
    let threads = bench_threads(0);
    let opts = bench_opts(&[
        "adam", "galore", "fira", "apollo_mini", "racs", "alice0", "alice",
    ]);
    println!(
        "== Table 2 analogue: {steps} steps per optimizer, {} pool threads ==",
        if threads == 0 { alice_racs::util::pool::available() } else { threads }
    );

    let mut results: Vec<Summary> = Vec::new();
    for opt in &opts {
        // Ppl/Ppl* lm-head protocol comes from the optimizer registry
        // inside bench_cfg (paper Sec. 7.1): full-rank candidates get an
        // Adam-trained lm-head, low-rank candidates train it themselves.
        let cfg = bench_cfg(opt, "table2", steps);
        match run_one(cfg) {
            Ok(s) => {
                println!(
                    "  {:<12} eval_loss {:.4}  ppl {:.2}  tp {:.0} tok/s",
                    opt,
                    s.final_eval_loss.unwrap_or(f32::NAN),
                    (s.final_eval_loss.unwrap_or(f32::NAN) as f64).exp(),
                    s.tokens_per_sec
                );
                results.push(s);
            }
            Err(e) => eprintln!("  {opt}: FAILED: {e:#}"),
        }
    }

    let adam = results.iter().find(|s| s.optimizer == "adam").cloned();
    let mut table = TablePrinter::new(&[
        "optimizer",
        "eval ppl",
        "steps-to-Adam-final",
        "speed-up",
        "TP tok/s",
        "effective TP",
    ]);
    for s in &results {
        let (steps_to, speedup, etp) = match &adam {
            Some(a) => {
                let target = a.final_eval_loss.unwrap_or(f32::NEG_INFINITY);
                let st = s.steps_to_reach(target);
                let sp = st
                    .map(|x| steps as f64 / x as f64)
                    .map(|x| format!("{x:.2}x"))
                    .unwrap_or_else(|| "-".into());
                (
                    st.map(|x| x.to_string()).unwrap_or_else(|| "-".into()),
                    sp,
                    format!("{:.0}", s.effective_tokens_per_sec(a)),
                )
            }
            None => ("-".into(), "-".into(), "-".into()),
        };
        table.row(vec![
            s.optimizer.clone(),
            format!("{:.2}", (s.final_eval_loss.unwrap_or(f32::NAN) as f64).exp()),
            steps_to,
            speedup,
            format!("{:.0}", s.tokens_per_sec),
            etp,
        ]);
    }
    table.print();
    println!(
        "\nPaper shape to verify: Alice ≈ Alice-0 < RACS < Apollo/Fira < \
         GaLore ≤ Adam in final ppl; Alice ≥ 2x fewer steps than Adam."
    );
}
