//! Figure 7 (repo extension) — grad-phase scaling of the simulated
//! data-parallel cluster: wall-clock speedup of the round coordinator's
//! worker fan-out vs. `dp_workers`, with the bitwise-parity check that
//! makes the comparison meaningful (every worker count reduces to the
//! *same* gradient, so speedup is free of numerical drift).
//!
//! Three sections:
//! * **Synthetic rounds** (no artifacts needed): the dist pipeline over a
//!   `SyntheticGradSource` whose per-microbatch cost is a fixed dense
//!   matmul — a clean stand-in for `grad_step`. Reports per-round time,
//!   speedup, and imbalance at dp ∈ {1, 2, 4} (plus `AR_DP_WORKERS`).
//! * **Round overlap** (no artifacts needed): the same synthetic rounds
//!   driven phased vs pipelined — eager segment reduce plus the fused
//!   per-parameter fold/optimizer fan-out — with live bitwise asserts
//!   that both modes step to identical losses *and* weights. Reports
//!   per-mode wall clock, speedup, and the hidden reduce time
//!   (`EagerRound::reduce_overlap_secs`).
//! * **Trainer rounds** (needs `make artifacts`): full coordinator-path
//!   training with `[dist] sim = true`, reporting the `dp_grad_exec`
//!   profile phase and tokens/s per worker count.
//!
//! Protocol notes live in EXPERIMENTS.md §fig7. `AR_BENCH_SMOKE=1`
//! shrinks the synthetic section for CI's bench-smoke job (the bitwise
//! parity assert stays live) and the summary lands in
//! `runs/bench/fig7_dp_scaling_summary.json`.

use alice_racs::bench::{
    artifacts_available, bench_cfg, bench_steps, dp_sweep, smoke, write_summary, TablePrinter,
};
use alice_racs::coordinator::{run_with, Trainer};
use alice_racs::dist::{
    run_round, run_round_pipelined, transport, DistConfig, SyntheticGradSource,
};
use alice_racs::linalg::Mat;
use alice_racs::opt::{build, Hyper, Slot};
use alice_racs::runtime::HostTensor;
use alice_racs::util::json::{num, obj, s};
use alice_racs::util::{mean, pool, trace, Json, Pcg, Timer};

fn synthetic_section() -> Json {
    let cores = pool::available();
    let micro = 8;
    let rounds = if smoke() { 3 } else { 6 };
    // model-ish gradient geometry + a busywork matmul that dominates cost
    let shapes = if smoke() {
        vec![(128, 64), (64, 128), (1, 128)]
    } else {
        vec![(256, 128), (128, 256), (1, 256), (64, 512)]
    };
    let work = if smoke() { 64 } else { 160 };
    println!(
        "== synthetic DP rounds: {micro} microbatches/round, {rounds} rounds, \
         work n={work}, pool width {cores} =="
    );
    let mut rng = Pcg::seeded(0xf177);
    let tokens: Vec<HostTensor> = (0..micro)
        .map(|_| HostTensor::i32(vec![32], (0..32).map(|_| rng.below(997) as i32).collect()))
        .collect();
    let src = SyntheticGradSource { shapes, work };

    let mut table =
        TablePrinter::new(&["dp_workers", "round ms", "speedup", "imbalance", "loss bits"]);
    let mut base_ms = 0.0f64;
    let mut base_bits: Option<u32> = None;
    let mut json_rows: Vec<Json> = Vec::new();
    for dp in dp_sweep() {
        let dist = DistConfig { dp_workers: dp, ..DistConfig::default() };
        let mut coord = dist.coordinator();
        let mut times = Vec::new();
        let mut loss_bits = 0u32;
        for r in 0..rounds {
            let t = Timer::start();
            let out = run_round(&mut coord, &src, &tokens).expect("synthetic round");
            if r > 0 {
                times.push(t.millis()); // round 0 is warmup
            }
            loss_bits = out.loss.to_bits();
            // round-end telemetry: same witness line the TCP workers log,
            // so CI's bench-smoke artifact carries a loopback witness.jsonl
            if let Some(w) = coord.witness() {
                transport::append_witness_line(
                    std::path::Path::new("runs/witness.jsonl"),
                    &w,
                );
            }
        }
        let ms = mean(&times);
        if dp == 1 {
            base_ms = ms;
            base_bits = Some(loss_bits);
        }
        assert_eq!(
            Some(loss_bits),
            base_bits,
            "tree all-reduce must be bitwise invariant across dp_workers"
        );
        let imb = coord.log.last().map(|l| l.imbalance).unwrap_or(1.0);
        table.row(vec![
            dp.to_string(),
            format!("{ms:.2}"),
            format!("{:.2}x", base_ms / ms.max(1e-9)),
            format!("{imb:.2}"),
            format!("{loss_bits:08x}"),
        ]);
        json_rows.push(obj(vec![
            ("dp_workers", num(dp as f64)),
            ("round_ms", num(ms)),
            ("speedup", num(base_ms / ms.max(1e-9))),
            ("imbalance", num(imb)),
            ("loss_bits", s(&format!("{loss_bits:08x}"))),
        ]));
    }
    table.print();
    println!("(loss bits equal on every row: same reduced gradient, only faster)");
    obj(vec![
        ("smoke", Json::Bool(smoke())),
        ("pool_width", num(cores as f64)),
        ("parity", s("bitwise loss equality asserted across dp_workers")),
        ("rounds", Json::Arr(json_rows)),
    ])
}

/// Phased vs pipelined round loop on the synthetic source, with a real
/// optimizer fan-out after every round (adam slots on the same gradient
/// geometry). Both modes are timed end to end — round + optimizer — and
/// every round's loss bits and every final weight bit are asserted equal:
/// overlap is scheduling, never merge order.
fn overlap_section() -> Json {
    let micro = 8;
    let rounds = if smoke() { 3 } else { 6 };
    let shapes = if smoke() {
        vec![(128usize, 64usize), (64, 128), (1, 128)]
    } else {
        vec![(256, 128), (128, 256), (1, 256), (64, 512)]
    };
    let work = if smoke() { 64 } else { 160 };
    println!(
        "\n== round overlap: phased vs pipelined, {micro} microbatches/round, \
         {rounds} rounds, work n={work} =="
    );
    let mut rng = Pcg::seeded(0xf177);
    let tokens: Vec<HostTensor> = (0..micro)
        .map(|_| HostTensor::i32(vec![32], (0..32).map(|_| rng.below(997) as i32).collect()))
        .collect();
    let src = SyntheticGradSource { shapes: shapes.clone(), work };
    let hp = Hyper::default();
    let new_slots = || -> Vec<Slot> {
        shapes
            .iter()
            .map(|&(r, c)| Slot::new(build("adam", &hp).expect("registry"), r, c))
            .collect()
    };

    let mut table = TablePrinter::new(&[
        "dp_workers",
        "phased ms",
        "pipelined ms",
        "speedup",
        "reduce ovl ms",
        "loss bits",
    ]);
    let mut json_rows: Vec<Json> = Vec::new();
    for dp in dp_sweep() {
        let dist = DistConfig { dp_workers: dp, ..DistConfig::default() };

        // phased reference: monolithic reduce, then a serial slot loop
        let mut coord = dist.coordinator();
        let mut slots = new_slots();
        let mut weights: Vec<Mat> = shapes.iter().map(|&(r, c)| Mat::zeros(r, c)).collect();
        let mut times = Vec::new();
        let mut loss_bits = Vec::new();
        for r in 0..rounds {
            let t = (r + 1) as u64;
            let tm = Timer::start();
            let out = run_round(&mut coord, &src, &tokens).expect("phased round");
            for ((slot, w), g) in slots.iter_mut().zip(weights.iter_mut()).zip(&out.grads) {
                if t == 1 {
                    slot.refresh(g, 0xf177 ^ t);
                }
                let delta = slot.step(g, t);
                w.ema_(1.0, &delta, -0.01);
            }
            if r > 0 {
                times.push(tm.millis()); // round 0 is warmup
            }
            loss_bits.push(out.loss.to_bits());
        }
        let phased_ms = mean(&times);
        let phased_w: Vec<Vec<u32>> = weights
            .iter()
            .map(|w| w.data.iter().map(|x| x.to_bits()).collect())
            .collect();

        // pipelined twin: eager reduce + fused per-parameter fan-out
        let mut coord = dist.coordinator();
        let mut slots = new_slots();
        let mut weights: Vec<Mat> = shapes.iter().map(|&(r, c)| Mat::zeros(r, c)).collect();
        let mut times = Vec::new();
        let mut ovl = Vec::new();
        for r in 0..rounds {
            let t = (r + 1) as u64;
            let tm = Timer::start();
            let round =
                run_round_pipelined(&mut coord, &src, &tokens).expect("pipelined round");
            assert_eq!(
                round.fold_loss().to_bits(),
                loss_bits[r],
                "pipelined loss bits diverged at dp={dp}, round {r}"
            );
            let slots_ptr = pool::SendPtr(slots.as_mut_ptr());
            let weights_ptr = pool::SendPtr(weights.as_mut_ptr());
            pool::run(slots.len(), |p| {
                let g = round.fold_param(p);
                // SAFETY: the region hands each index to exactly one task,
                // so these are the only live references to slots[p] /
                // weights[p].
                let slot = unsafe { &mut *slots_ptr.0.add(p) };
                let w = unsafe { &mut *weights_ptr.0.add(p) };
                if t == 1 {
                    slot.refresh(&g, 0xf177 ^ t);
                }
                let delta = slot.step(&g, t);
                w.ema_(1.0, &delta, -0.01);
            });
            if r > 0 {
                times.push(tm.millis());
                ovl.push(round.reduce_overlap_secs * 1e3);
            }
        }
        let pipelined_ms = mean(&times);
        let pipelined_w: Vec<Vec<u32>> = weights
            .iter()
            .map(|w| w.data.iter().map(|x| x.to_bits()).collect())
            .collect();
        assert_eq!(
            pipelined_w, phased_w,
            "pipelined weights diverged from phased at dp={dp}"
        );

        let ovl_ms = mean(&ovl);
        let bits = *loss_bits.last().expect("rounds ran");
        table.row(vec![
            dp.to_string(),
            format!("{phased_ms:.2}"),
            format!("{pipelined_ms:.2}"),
            format!("{:.2}x", phased_ms / pipelined_ms.max(1e-9)),
            format!("{ovl_ms:.2}"),
            format!("{bits:08x}"),
        ]);
        json_rows.push(obj(vec![
            ("dp_workers", num(dp as f64)),
            ("phased_ms", num(phased_ms)),
            ("pipelined_ms", num(pipelined_ms)),
            ("speedup", num(phased_ms / pipelined_ms.max(1e-9))),
            ("reduce_overlap_ms", num(ovl_ms)),
            ("loss_bits", s(&format!("{bits:08x}"))),
        ]));
    }
    table.print();
    println!("(losses and weights bitwise equal per row: overlap is scheduling only)");
    obj(vec![
        ("parity", s("pipelined == phased bitwise (losses and weights) per dp_workers")),
        ("rounds", Json::Arr(json_rows)),
    ])
}

fn trainer_section() {
    if !artifacts_available() {
        return;
    }
    let steps = bench_steps(40);
    println!("\n== trainer rounds (coordinator path, [dist] sim): {steps} steps ==");
    let mut table = TablePrinter::new(&[
        "dp_workers",
        "grad phase s",
        "speedup",
        "tokens/s",
        "final loss",
    ]);
    let mut base_grad = 0.0f64;
    for dp in dp_sweep() {
        let mut cfg = bench_cfg("adam", "fig7", steps);
        cfg.out_dir = format!("runs/bench/fig7/dp{dp}");
        cfg.grad_accum = 4;
        cfg.dist.dp_workers = dp;
        cfg.dist.sim = true;
        let mut trainer = Trainer::new(cfg).expect("trainer");
        let summary = run_with(&mut trainer).expect("run");
        let grad_secs = trainer.profile.total("dp_grad_exec");
        if dp == 1 {
            base_grad = grad_secs;
        }
        table.row(vec![
            dp.to_string(),
            format!("{grad_secs:.2}"),
            format!("{:.2}x", base_grad / grad_secs.max(1e-9)),
            format!("{:.0}", summary.tokens_per_sec),
            format!("{:.4}", summary.last_train_loss),
        ]);
    }
    table.print();
}

fn main() {
    // AR_TRACE=1 (or =PATH) turns on the span tracer for the whole bench;
    // scheduling-only, so every parity assert above stays bitwise live
    trace::init_resolved("");
    let synthetic = synthetic_section();
    let overlap = overlap_section();
    let summary = obj(vec![
        ("smoke", Json::Bool(smoke())),
        ("synthetic", synthetic),
        ("overlap", overlap),
    ]);
    match write_summary("fig7_dp_scaling", &summary) {
        Ok(path) => println!("summary → {path}"),
        Err(e) => eprintln!("could not write fig7 summary: {e:#}"),
    }
    trainer_section();
    match trace::finish() {
        Ok(Some(p)) => println!("trace → {}", p.display()),
        Ok(None) => {}
        Err(e) => eprintln!("trace write failed: {e:#}"),
    }
}
