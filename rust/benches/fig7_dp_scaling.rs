//! Figure 7 (repo extension) — grad-phase scaling of the simulated
//! data-parallel cluster: wall-clock speedup of the round coordinator's
//! worker fan-out vs. `dp_workers`, with the bitwise-parity check that
//! makes the comparison meaningful (every worker count reduces to the
//! *same* gradient, so speedup is free of numerical drift).
//!
//! Two sections:
//! * **Synthetic rounds** (no artifacts needed): the dist pipeline over a
//!   `SyntheticGradSource` whose per-microbatch cost is a fixed dense
//!   matmul — a clean stand-in for `grad_step`. Reports per-round time,
//!   speedup, and imbalance at dp ∈ {1, 2, 4} (plus `AR_DP_WORKERS`).
//! * **Trainer rounds** (needs `make artifacts`): full coordinator-path
//!   training with `[dist] sim = true`, reporting the `dp_grad_exec`
//!   profile phase and tokens/s per worker count.
//!
//! Protocol notes live in EXPERIMENTS.md §fig7. `AR_BENCH_SMOKE=1`
//! shrinks the synthetic section for CI's bench-smoke job (the bitwise
//! parity assert stays live) and the summary lands in
//! `runs/bench/fig7_dp_scaling_summary.json`.

use alice_racs::bench::{
    artifacts_available, bench_cfg, bench_steps, dp_sweep, smoke, write_summary, TablePrinter,
};
use alice_racs::coordinator::{run_with, Trainer};
use alice_racs::dist::{run_round, transport, DistConfig, SyntheticGradSource};
use alice_racs::runtime::HostTensor;
use alice_racs::util::json::{num, obj, s};
use alice_racs::util::{mean, pool, trace, Json, Pcg, Timer};

fn synthetic_section() -> Json {
    let cores = pool::available();
    let micro = 8;
    let rounds = if smoke() { 3 } else { 6 };
    // model-ish gradient geometry + a busywork matmul that dominates cost
    let shapes = if smoke() {
        vec![(128, 64), (64, 128), (1, 128)]
    } else {
        vec![(256, 128), (128, 256), (1, 256), (64, 512)]
    };
    let work = if smoke() { 64 } else { 160 };
    println!(
        "== synthetic DP rounds: {micro} microbatches/round, {rounds} rounds, \
         work n={work}, pool width {cores} =="
    );
    let mut rng = Pcg::seeded(0xf177);
    let tokens: Vec<HostTensor> = (0..micro)
        .map(|_| HostTensor::i32(vec![32], (0..32).map(|_| rng.below(997) as i32).collect()))
        .collect();
    let src = SyntheticGradSource { shapes, work };

    let mut table =
        TablePrinter::new(&["dp_workers", "round ms", "speedup", "imbalance", "loss bits"]);
    let mut base_ms = 0.0f64;
    let mut base_bits: Option<u32> = None;
    let mut json_rows: Vec<Json> = Vec::new();
    for dp in dp_sweep() {
        let dist = DistConfig { dp_workers: dp, ..DistConfig::default() };
        let mut coord = dist.coordinator();
        let mut times = Vec::new();
        let mut loss_bits = 0u32;
        for r in 0..rounds {
            let t = Timer::start();
            let out = run_round(&mut coord, &src, &tokens).expect("synthetic round");
            if r > 0 {
                times.push(t.millis()); // round 0 is warmup
            }
            loss_bits = out.loss.to_bits();
            // round-end telemetry: same witness line the TCP workers log,
            // so CI's bench-smoke artifact carries a loopback witness.jsonl
            if let Some(w) = coord.witness() {
                transport::append_witness_line(
                    std::path::Path::new("runs/witness.jsonl"),
                    &w,
                );
            }
        }
        let ms = mean(&times);
        if dp == 1 {
            base_ms = ms;
            base_bits = Some(loss_bits);
        }
        assert_eq!(
            Some(loss_bits),
            base_bits,
            "tree all-reduce must be bitwise invariant across dp_workers"
        );
        let imb = coord.log.last().map(|l| l.imbalance).unwrap_or(1.0);
        table.row(vec![
            dp.to_string(),
            format!("{ms:.2}"),
            format!("{:.2}x", base_ms / ms.max(1e-9)),
            format!("{imb:.2}"),
            format!("{loss_bits:08x}"),
        ]);
        json_rows.push(obj(vec![
            ("dp_workers", num(dp as f64)),
            ("round_ms", num(ms)),
            ("speedup", num(base_ms / ms.max(1e-9))),
            ("imbalance", num(imb)),
            ("loss_bits", s(&format!("{loss_bits:08x}"))),
        ]));
    }
    table.print();
    println!("(loss bits equal on every row: same reduced gradient, only faster)");
    obj(vec![
        ("smoke", Json::Bool(smoke())),
        ("pool_width", num(cores as f64)),
        ("parity", s("bitwise loss equality asserted across dp_workers")),
        ("rounds", Json::Arr(json_rows)),
    ])
}

fn trainer_section() {
    if !artifacts_available() {
        return;
    }
    let steps = bench_steps(40);
    println!("\n== trainer rounds (coordinator path, [dist] sim): {steps} steps ==");
    let mut table = TablePrinter::new(&[
        "dp_workers",
        "grad phase s",
        "speedup",
        "tokens/s",
        "final loss",
    ]);
    let mut base_grad = 0.0f64;
    for dp in dp_sweep() {
        let mut cfg = bench_cfg("adam", "fig7", steps);
        cfg.out_dir = format!("runs/bench/fig7/dp{dp}");
        cfg.grad_accum = 4;
        cfg.dist.dp_workers = dp;
        cfg.dist.sim = true;
        let mut trainer = Trainer::new(cfg).expect("trainer");
        let summary = run_with(&mut trainer).expect("run");
        let grad_secs = trainer.profile.total("dp_grad_exec");
        if dp == 1 {
            base_grad = grad_secs;
        }
        table.row(vec![
            dp.to_string(),
            format!("{grad_secs:.2}"),
            format!("{:.2}x", base_grad / grad_secs.max(1e-9)),
            format!("{:.0}", summary.tokens_per_sec),
            format!("{:.4}", summary.last_train_loss),
        ]);
    }
    table.print();
}

fn main() {
    // AR_TRACE=1 (or =PATH) turns on the span tracer for the whole bench;
    // scheduling-only, so every parity assert above stays bitwise live
    trace::init_resolved("");
    let summary = synthetic_section();
    match write_summary("fig7_dp_scaling", &summary) {
        Ok(path) => println!("summary → {path}"),
        Err(e) => eprintln!("could not write fig7 summary: {e:#}"),
    }
    trainer_section();
    match trace::finish() {
        Ok(Some(p)) => println!("trace → {}", p.display()),
        Ok(None) => {}
        Err(e) => eprintln!("trace write failed: {e:#}"),
    }
}
