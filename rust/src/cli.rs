//! Hand-rolled CLI (no `clap` offline — DESIGN.md §Substitutions).
//!
//! ```text
//! alice-racs train   [--config run.toml] [--opt alice] [--steps N] ...
//! alice-racs serve   --ckpt FILE [--artifacts DIR] [--max-batch N] ...
//! alice-racs eval    --artifacts DIR --ckpt FILE
//! alice-racs memory  [--preset llama1b] [--opt racs] [--rank 512]
//! alice-racs inspect [--artifacts DIR]
//! ```

use anyhow::{anyhow, bail, Result};

use crate::config::{ExecPath, RunConfig};
use crate::coordinator;
use crate::dist::{self, demo, DistConfig, RoundMode, TcpCoordinator, TransportKind, WorkerCfg};
use crate::opt;
use crate::runtime::Engine;
use crate::serve;
use crate::util::{log, trace, Timer};

/// Parsed `--key value` / `--flag` arguments after the subcommand.
pub struct Args {
    pub cmd: String,
    pairs: Vec<(String, String)>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        // flags-only argv (the examples) has no subcommand
        let (cmd, mut i) = match argv.first() {
            Some(a) if a.starts_with("--") => ("".to_string(), 0),
            Some(a) => (a.clone(), 1),
            None => ("help".to_string(), 1),
        };
        let mut pairs = Vec::new();
        while i < argv.len() {
            let a = &argv[i];
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("expected --flag, got {a:?}"))?;
            if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                pairs.push((key.to_string(), argv[i + 1].clone()));
                i += 2;
            } else {
                pairs.push((key.to_string(), "true".to_string()));
                i += 1;
            }
        }
        Ok(Args { cmd, pairs })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.pairs
            .iter()
            .rev()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => v.parse().map_err(|e| anyhow!("--{key}: {e}")),
            None => Ok(default),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            Some(v) => v.parse().map_err(|e| anyhow!("--{key}: {e}")),
            None => Ok(default),
        }
    }
}

const HELP: &str = "\
alice-racs — structured-Fisher optimizers (RACS / Alice) training coordinator

USAGE:
  alice-racs train   [--config FILE] [--opt NAME] [--steps N] [--lr F]
                     [--artifacts DIR] [--out DIR] [--path coordinator|fused]
                     [--rank N] [--interval N] [--seed N] [--tuned]
                     [--refresh exact|sketch] (eigen-refresh dispatch;
                                      sketch = randomized range finder)
                     [--sketch-oversample N] [--sketch-power-iters N]
                     [--anchor-every N] (every N-th sketch refresh runs
                                      the exact path as a drift anchor)
                     [--threads N]   (1 = serial; 0 = AR_BENCH_THREADS if
                                      set, else all cores; default 0)
                     [--pool-warmup] (pre-spawn pool workers before step 1)
                     [--dp-workers N] (simulated data-parallel workers; > 1
                                      shards microbatches over the round
                                      coordinator with a tree all-reduce)
                     [--dist-sim]    (round-coordinator path even at
                                      dp-workers 1 — bitwise comparable to
                                      any dp-workers count)
                     [--transport loopback|tcp] [--listen HOST:PORT]
                     [--connect HOST:PORT] [--run-id ID]
                                     (tcp = this process coordinates real
                                      worker processes over sockets; see
                                      `dist-demo` for the worker side)
                     [--round phased|pipelined]
                                     (pipelined = overlap shard compute,
                                      segment reduce and per-layer
                                      optimizer fan-out; scheduling only —
                                      bitwise identical to phased)
                     [--log-level error|warn|info|debug|trace]
                                     (ALICE_RACS_LOG still wins)
                     [--trace [PATH]] (Chrome trace-event JSON; bare flag
                                      writes runs/trace.json; AR_TRACE=1
                                      or AR_TRACE=PATH also enables it)
  alice-racs dist-demo [--role loopback|coordinator|worker]
                     (synthetic-gradient transport demo / parity harness;
                      prints one `demo digest=...` line for bitwise
                      comparison across transports)
                     loopback:    [--dp-workers N] [--threads N]
                     coordinator: [--listen HOST:PORT] [--run-id ID]
                                  [--min-workers N] [--tick-ms N]
                                  [--join-timeout-s F] [--round-timeout-s F]
                                  (prints `listening HOST:PORT` once bound)
                     worker:      --connect HOST:PORT [--run-id ID]
                                  [--fail-after-micro N] (drop the
                                   connection mid-shard, for requeue tests)
                     shared:      [--micro N] [--steps N]
                                  [--round phased|pipelined]
                                  [--trace [PATH]] [--log-level LEVEL]
                                  [--witness PATH] (append per-round
                                   witness telemetry as JSON lines;
                                   workers default to runs/witness.jsonl)
  alice-racs serve   [--role loopback|server|client]
                     (forward-only scoring service on a checkpoint — no
                      optimizer state, no trainer; prints one
                      `serve digest=...` line for bitwise comparison
                      across batching policies and transports)
                     shared:   [--ckpt FILE] [--artifacts DIR] |
                               [--synthetic] [--synthetic-work N]
                               [--max-batch N] [--max-wait-ms N]
                               [--max-queue-depth N] (bound the ingress
                                queue; over-bound requests are shed with
                                a typed reject; 0 = unbounded, default)
                               [--requests N] [--batch N] [--seq N]
                               [--vocab N] [--seed N] [--run-id ID]
                               [--trace [PATH]] [--log-level LEVEL]
                     loopback: in-process queue → continuous-batching
                               serve loop (default role)
                     server:   [--listen HOST:PORT] [--idle-timeout-s F]
                               (--requests 0 = serve until every client
                                departs; prints `listening HOST:PORT`
                                once bound)
                     client:   --connect HOST:PORT (pipelines a
                               deterministic synthetic request stream,
                               prints its own digest line)
  alice-racs eval    [--artifacts DIR] --ckpt FILE [--batches N]
  alice-racs memory  [--preset NAME] [--opt NAME] [--rank N] [--no-head-adam]
  alice-racs inspect [--artifacts DIR]
  alice-racs help

Optimizers: sgd adam adafactor lion signum muon swan racs eigen_adam
            shampoo soap galore fira apollo_mini alice alice0
";

pub fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    match args.cmd.as_str() {
        "train" => cmd_train(&args),
        "serve" => cmd_serve(&args),
        "dist-demo" => cmd_dist_demo(&args),
        "eval" => cmd_eval(&args),
        "memory" => cmd_memory(&args),
        "inspect" => cmd_inspect(&args),
        _ => {
            print!("{HELP}");
            Ok(())
        }
    }
}

pub fn config_from_args(args: &Args) -> Result<RunConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => RunConfig::from_file(path)?,
        None => RunConfig::default(),
    };
    if let Some(opt) = args.get("opt") {
        if args.get("tuned").is_some() {
            cfg = cfg.tuned_for(opt);
        } else {
            cfg.optimizer = opt.to_string();
        }
    }
    if let Some(a) = args.get("artifacts") {
        cfg.artifacts = a.to_string();
    }
    if let Some(o) = args.get("out") {
        cfg.out_dir = o.to_string();
    }
    cfg.steps = args.usize_or("steps", cfg.steps)?;
    cfg.lr = args.f64_or("lr", cfg.lr as f64)? as f32;
    cfg.seed = args.usize_or("seed", cfg.seed as usize)? as u64;
    cfg.threads = args.usize_or("threads", cfg.threads)?;
    if args.get("pool-warmup").is_some() {
        cfg.pool_warmup = true;
    }
    cfg.dist.dp_workers = args.usize_or("dp-workers", cfg.dist.dp_workers)?.max(1);
    if args.get("dist-sim").is_some() {
        cfg.dist.sim = true;
    }
    if let Some(t) = args.get("transport") {
        cfg.dist.transport = TransportKind::parse(t)?;
    }
    if let Some(r) = args.get("round") {
        cfg.dist.round = RoundMode::parse(r)?;
    }
    if let Some(l) = args.get("listen") {
        cfg.dist.listen = l.to_string();
    }
    if let Some(c) = args.get("connect") {
        cfg.dist.connect = c.to_string();
    }
    if let Some(r) = args.get("run-id") {
        cfg.dist.run_id = r.to_string();
    }
    cfg.hp.rank = args.usize_or("rank", cfg.hp.rank)?;
    cfg.hp.interval = args.usize_or("interval", cfg.hp.interval)?;
    if let Some(r) = args.get("refresh") {
        cfg.hp.refresh = opt::Refresh::parse(r)?;
    }
    cfg.hp.sketch_oversample =
        args.usize_or("sketch-oversample", cfg.hp.sketch_oversample)?;
    cfg.hp.sketch_power_iters =
        args.usize_or("sketch-power-iters", cfg.hp.sketch_power_iters)?;
    cfg.hp.refresh_anchor_every =
        args.usize_or("anchor-every", cfg.hp.refresh_anchor_every)?;
    cfg.eval_every = args.usize_or("eval-every", cfg.eval_every)?;
    if let Some(l) = args.get("log-level") {
        cfg.log_level = l.to_string();
    }
    if let Some(t) = trace_arg(args) {
        cfg.trace_path = t;
    }
    if let Some(p) = args.get("path") {
        cfg.path = match p {
            "fused" => ExecPath::Fused,
            "coordinator" => ExecPath::Coordinator,
            other => bail!("--path must be coordinator|fused, got {other:?}"),
        };
    }
    Ok(cfg)
}

/// `--trace` is value-optional: the bare flag means "default path".
fn trace_arg(args: &Args) -> Option<String> {
    args.get("trace").map(|v| {
        if v == "true" { "runs/trace.json".to_string() } else { v.to_string() }
    })
}

/// Write the trace file (if tracing was on) and say where it went —
/// shared epilogue of every traced subcommand.
fn finish_trace() {
    match trace::finish() {
        Ok(Some(p)) => println!("trace written {}", p.display()),
        Ok(None) => {}
        Err(e) => eprintln!("trace write failed: {e}"),
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = config_from_args(args)?;
    log::init_str(&cfg.log_level);
    trace::init_resolved(&cfg.trace_path);
    let summary = coordinator::run(cfg)?;
    println!(
        "final: train_loss={:.4} eval_loss={:?} tokens/s={:.0}",
        summary.last_train_loss, summary.final_eval_loss, summary.tokens_per_sec
    );
    finish_trace();
    Ok(())
}

/// The scoring backend a serve role runs against: a checkpoint-loaded
/// [`serve::Model`] or the artifact-free synthetic stand-in.
enum ServeSrc {
    Model(std::sync::Arc<serve::Model>),
    Synth(serve::SyntheticScoreSource),
}

impl ServeSrc {
    fn as_dyn(&self) -> &dyn serve::ScoreSource {
        match self {
            ServeSrc::Model(m) => &**m,
            ServeSrc::Synth(s) => s,
        }
    }
}

/// Build the score source plus the `(batch, seq, vocab)` defaults the
/// synthetic request stream should use (the model's own block shape when
/// a checkpoint is loaded, CLI fallbacks otherwise).
fn serve_source(args: &Args) -> Result<(ServeSrc, (usize, usize, usize))> {
    if let Some(ckpt) = args.get("ckpt") {
        let ck = coordinator::Checkpoint::load(ckpt)?;
        let model = ck.load_model(args.get("artifacts").unwrap_or("artifacts"))?;
        let (b, s) = model.block_shape();
        let v = model.manifest().model.vocab;
        println!(
            "model loaded: step={} preset={} state_bytes={}",
            model.step,
            model.manifest().model.preset,
            crate::obs::STATE_BYTES.get()
        );
        Ok((ServeSrc::Model(model), (b, s, v)))
    } else if args.get("synthetic").is_some() {
        let src = serve::SyntheticScoreSource {
            work: args.usize_or("synthetic-work", 0)?,
        };
        Ok((ServeSrc::Synth(src), (4, 32, 997)))
    } else {
        bail!("serve needs --ckpt FILE (with --artifacts DIR) or --synthetic")
    }
}

/// The serving subcommand: score requests against a checkpoint-loaded
/// model (or the synthetic source) through the continuous-batching
/// queue — in-process (`loopback`), or over TCP (`server`/`client`).
/// The digest lines are bitwise-comparable across roles and policies:
/// batching and transport are scheduling, never numerics.
fn cmd_serve(args: &Args) -> Result<()> {
    use std::io::Write as _;
    use std::time::Duration;

    if let Some(l) = args.get("log-level") {
        log::init_str(l);
    }
    trace::init_resolved(&trace_arg(args).unwrap_or_default());
    let policy = serve::BatchPolicy {
        max_batch: args.usize_or("max-batch", 8)?.max(1),
        max_wait: Duration::from_millis(args.usize_or("max-wait-ms", 2)? as u64),
        max_queue_depth: args.usize_or("max-queue-depth", 0)?,
    };
    let run_id = args.get("run-id").unwrap_or("serve").to_string();
    let seed = args.usize_or("seed", 0x5eed)? as u64;
    match args.get("role").unwrap_or("loopback") {
        "loopback" => {
            let (src, (db, ds, dv)) = serve_source(args)?;
            let n = args.usize_or("requests", 64)?.max(1);
            let reqs = serve::synthetic_requests(
                n,
                args.usize_or("batch", db)?,
                args.usize_or("seq", ds)?,
                args.usize_or("vocab", dv)?,
                seed,
            );
            let (ingress, q) = serve::queue_bounded(policy.max_queue_depth);
            let t = Timer::start();
            let mut rejected = 0usize;
            for r in &reqs {
                // closed-loop driver: a bounded queue sheds the overflow
                // visibly; the digest still covers every scored request
                if ingress.submit(r.id, r.tokens.clone()).is_err() {
                    rejected += 1;
                }
            }
            drop(ingress); // closed-loop: everything queued, let it drain
            let resps = serve::serve_loop(src.as_dyn(), &policy, q)?;
            let secs = t.secs();
            let lat = serve::latency_summary(&resps);
            println!(
                "serve digest={:016x} served={} batches={} rejected={} state_bytes={}",
                serve::score_digest(&resps),
                resps.len(),
                crate::obs::SERVE_BATCHES.get(),
                rejected,
                crate::obs::STATE_BYTES.get()
            );
            println!(
                "throughput={:.0} req/s p50={:.3}ms p95={:.3}ms p99={:.3}ms",
                resps.len() as f64 / secs.max(1e-9),
                lat.p50 * 1e3,
                lat.p95 * 1e3,
                lat.p99 * 1e3
            );
        }
        "server" => {
            let (src, _) = serve_source(args)?;
            let mut server =
                serve::TcpServer::bind(args.get("listen").unwrap_or("127.0.0.1:0"), &run_id)?;
            // client launchers parse this line for the bound port, so it
            // must hit the pipe before the serve loop starts
            println!("listening {}", server.local_addr());
            std::io::stdout().flush()?;
            let report = server.serve(
                src.as_dyn(),
                &policy,
                args.usize_or("requests", 0)?,
                Duration::from_secs_f64(args.f64_or("idle-timeout-s", 30.0)?),
            )?;
            println!(
                "served={} batches={} rejected={} p50={:.3}ms p95={:.3}ms p99={:.3}ms",
                report.served,
                report.batches,
                report.rejected,
                crate::util::percentile(&report.latencies_s, 0.50) * 1e3,
                crate::util::percentile(&report.latencies_s, 0.95) * 1e3,
                crate::util::percentile(&report.latencies_s, 0.99) * 1e3
            );
        }
        "client" => {
            let connect = args
                .get("connect")
                .ok_or_else(|| anyhow!("--connect HOST:PORT required"))?;
            let reqs = serve::synthetic_requests(
                args.usize_or("requests", 32)?.max(1),
                args.usize_or("batch", 4)?,
                args.usize_or("seq", 32)?,
                args.usize_or("vocab", 997)?,
                seed,
            );
            let resps = serve::run_client(connect, &run_id, &reqs)?;
            println!(
                "client responses={} digest={:016x}",
                resps.len(),
                serve::score_digest(&resps)
            );
        }
        other => bail!("--role must be loopback|server|client, got {other:?}"),
    }
    finish_trace();
    Ok(())
}

/// The synthetic-gradient transport demo: the same miniature training
/// loop as `rust/tests/dist_parity.rs`, runnable as an in-process
/// loopback cluster, a TCP coordinator, or a TCP worker — the output
/// `demo digest=...` line must match bitwise across all of them
/// (`rust/tests/transport_e2e.rs` drives exactly this subcommand).
fn cmd_dist_demo(args: &Args) -> Result<()> {
    use std::io::Write as _;

    if let Some(l) = args.get("log-level") {
        log::init_str(l);
    }
    trace::init_resolved(&trace_arg(args).unwrap_or_default());
    let cfg = demo::DemoCfg {
        micro: args.usize_or("micro", 8)?.max(1),
        steps: args.usize_or("steps", 4)?.max(1) as u64,
        witness_path: args.get("witness").map(std::path::PathBuf::from),
        round: match args.get("round") {
            Some(r) => RoundMode::parse(r)?,
            None => RoundMode::Phased,
        },
    };
    let print_demo = |out: &demo::DemoOut| {
        let losses: Vec<String> =
            out.loss_bits.iter().map(|b| format!("{b:08x}")).collect();
        println!(
            "demo digest={:016x} losses={} rounds={} requeues={}",
            out.weight_digest,
            losses.join(","),
            out.rounds,
            out.requeues
        );
    };
    match args.get("role").unwrap_or("loopback") {
        "loopback" => {
            let dp = args.usize_or("dp-workers", 2)?.max(1);
            let width = args.usize_or("threads", 1)?.max(1);
            print_demo(&demo::run_loopback(&cfg, dp, width)?);
        }
        "coordinator" => {
            let min = args.usize_or("min-workers", 1)?.max(1);
            let d = DistConfig::default();
            let dist_cfg = DistConfig {
                transport: TransportKind::Tcp,
                listen: args.get("listen").unwrap_or("127.0.0.1:0").to_string(),
                run_id: args.get("run-id").unwrap_or("demo").to_string(),
                // round_cfg clamps min_workers to dp_workers, so mirror it
                dp_workers: min,
                min_workers: min,
                tick_ms: args.usize_or("tick-ms", d.tick_ms as usize)? as u64,
                join_timeout_s: args.f64_or("join-timeout-s", d.join_timeout_s)?,
                round_timeout_s: args.f64_or("round-timeout-s", d.round_timeout_s)?,
                ..d
            };
            let mut tcp = TcpCoordinator::bind(&dist_cfg.listen, dist_cfg.wire_cfg())?;
            // worker launchers parse this line for the bound port, so it
            // must hit the pipe before the join wait starts
            println!("listening {}", tcp.local_addr());
            std::io::stdout().flush()?;
            let mut coord = dist_cfg.empty_coordinator();
            print_demo(&demo::drive(&mut tcp, &mut coord, &cfg)?);
        }
        "worker" => {
            let wc = WorkerCfg {
                connect: args
                    .get("connect")
                    .ok_or_else(|| anyhow!("--connect HOST:PORT required"))?
                    .to_string(),
                run_id: args.get("run-id").unwrap_or("demo").to_string(),
                fail_after_micro: match args.get("fail-after-micro") {
                    Some(v) => {
                        Some(v.parse().map_err(|e| anyhow!("--fail-after-micro: {e}"))?)
                    }
                    None => None,
                },
                witness_path: Some(
                    args.get("witness").unwrap_or("runs/witness.jsonl").into(),
                ),
            };
            let report = dist::transport::run_worker(&wc, &demo::demo_src())?;
            println!(
                "worker member={} shards={} micro={} joined_step={} witnesses={}",
                report.member,
                report.shards,
                report.micro,
                report
                    .joined_state
                    .as_ref()
                    .map(|s| s.0 as i64)
                    .unwrap_or(-1),
                report.witnesses.len()
            );
        }
        other => bail!("--role must be loopback|coordinator|worker, got {other:?}"),
    }
    finish_trace();
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let cfg = config_from_args(args)?;
    let ckpt_path = args
        .get("ckpt")
        .ok_or_else(|| anyhow!("--ckpt FILE required"))?;
    let mut trainer = coordinator::Trainer::new(cfg)?;
    let ck = coordinator::Checkpoint::load(ckpt_path)?;
    trainer.restore(&ck)?;
    let batches = args.usize_or("batches", 8)?;
    let loss = trainer.eval(batches)?;
    println!("eval_loss={loss:.4} ppl={:.3} (step {})", (loss as f64).exp(), ck.step);
    Ok(())
}

fn cmd_memory(args: &Args) -> Result<()> {
    let preset_name = args.get("preset").unwrap_or("llama1b");
    let p = crate::config::presets::preset(preset_name)
        .ok_or_else(|| anyhow!("unknown preset {preset_name:?}"))?;
    let mut hp = opt::Hyper::default();
    hp.rank = args.usize_or("rank", 512)?;
    let head_adam = args.get("no-head-adam").is_none();
    let opts: Vec<&str> = match args.get("opt") {
        Some(o) => vec![o],
        None => opt::ALL.to_vec(),
    };
    println!("memory estimate — preset {preset_name}, rank {}, lm-head adam: {head_adam}", hp.rank);
    println!("{:<12} {:>12} {:>14} {:>12} {:>12}", "optimizer", "weights", "matrix-state", "adam-side", "total");
    for o in opts {
        let e = coordinator::estimate(p, o, &hp, head_adam)?;
        println!(
            "{:<12} {:>12} {:>14} {:>12} {:>12}",
            o,
            crate::util::human_bytes(e.weight_bytes),
            crate::util::human_bytes(e.matrix_state_bytes),
            crate::util::human_bytes(e.adam_side_bytes),
            crate::util::human_bytes(e.total_bytes),
        );
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let dir = args.get("artifacts").unwrap_or("artifacts");
    let engine = Engine::new(dir)?;
    let m = &engine.manifest;
    println!(
        "preset {} — {} params in {} tensors; platform {}",
        m.model.preset,
        m.model.num_params,
        m.params.len(),
        engine.platform()
    );
    println!("artifacts:");
    for a in m.artifacts.values() {
        println!(
            "  {:<30} kind={:<10} inputs={} outputs={}",
            a.name,
            a.kind,
            a.inputs.len(),
            a.outputs.len()
        );
    }
    println!("optimizers with artifacts: {:?}", m.optimizers.keys().collect::<Vec<_>>());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_flags_and_values() {
        let a = Args::parse(&argv(&["train", "--opt", "racs", "--steps", "50", "--tuned"])).unwrap();
        assert_eq!(a.cmd, "train");
        assert_eq!(a.get("opt"), Some("racs"));
        assert_eq!(a.usize_or("steps", 0).unwrap(), 50);
        assert_eq!(a.get("tuned"), Some("true"));
        assert_eq!(a.get("missing"), None);
    }

    #[test]
    fn rejects_positional_garbage() {
        assert!(Args::parse(&argv(&["train", "oops"])).is_err());
    }

    #[test]
    fn config_overrides() {
        let a = Args::parse(&argv(&[
            "train", "--opt", "racs", "--tuned", "--steps", "7", "--path", "fused",
            "--threads", "2", "--pool-warmup", "--dp-workers", "4", "--dist-sim",
        ]))
        .unwrap();
        let cfg = config_from_args(&a).unwrap();
        assert_eq!(cfg.optimizer, "racs");
        assert_eq!(cfg.steps, 7);
        assert_eq!(cfg.path, ExecPath::Fused);
        assert_eq!(cfg.threads, 2);
        assert!(cfg.pool_warmup);
        assert_eq!(cfg.dist.dp_workers, 4);
        assert!(cfg.dist.sim);
        assert!(cfg.dist.enabled());
        assert!((cfg.hp.alpha - 0.2).abs() < 1e-6); // tuned racs alpha
    }

    #[test]
    fn refresh_overrides() {
        let a = Args::parse(&argv(&[
            "train", "--opt", "alice", "--refresh", "sketch",
            "--sketch-oversample", "4", "--sketch-power-iters", "1",
            "--anchor-every", "3",
        ]))
        .unwrap();
        let cfg = config_from_args(&a).unwrap();
        assert_eq!(cfg.hp.refresh, opt::Refresh::Sketch);
        assert_eq!(cfg.hp.sketch_oversample, 4);
        assert_eq!(cfg.hp.sketch_power_iters, 1);
        assert_eq!(cfg.hp.refresh_anchor_every, 3);
        // default stays exact
        let d = Args::parse(&argv(&["train", "--opt", "alice"])).unwrap();
        assert_eq!(config_from_args(&d).unwrap().hp.refresh, opt::Refresh::Exact);
        // and garbage is rejected
        let bad = Args::parse(&argv(&["train", "--refresh", "approx"])).unwrap();
        assert!(config_from_args(&bad).is_err());
    }

    #[test]
    fn dist_defaults_stay_disabled() {
        let a = Args::parse(&argv(&["train", "--opt", "adam"])).unwrap();
        let cfg = config_from_args(&a).unwrap();
        assert!(!cfg.dist.enabled());
        assert_eq!(cfg.dist.transport, TransportKind::Loopback);
    }

    #[test]
    fn transport_flags_override() {
        let a = Args::parse(&argv(&[
            "train", "--dp-workers", "2", "--transport", "tcp",
            "--listen", "127.0.0.1:7402", "--run-id", "pr7",
        ]))
        .unwrap();
        let cfg = config_from_args(&a).unwrap();
        assert_eq!(cfg.dist.transport, TransportKind::Tcp);
        assert_eq!(cfg.dist.listen, "127.0.0.1:7402");
        assert_eq!(cfg.dist.run_id, "pr7");
        let bad = Args::parse(&argv(&["train", "--transport", "smoke-signal"])).unwrap();
        assert!(config_from_args(&bad).is_err());
    }

    #[test]
    fn round_flag_overrides() {
        let a = Args::parse(&argv(&[
            "train", "--dp-workers", "2", "--round", "pipelined",
        ]))
        .unwrap();
        let cfg = config_from_args(&a).unwrap();
        assert_eq!(cfg.dist.round, RoundMode::Pipelined);
        // default stays the phased reference schedule
        let d = Args::parse(&argv(&["train", "--dp-workers", "2"])).unwrap();
        assert_eq!(config_from_args(&d).unwrap().dist.round, RoundMode::Phased);
        let bad = Args::parse(&argv(&["train", "--round", "overlapped"])).unwrap();
        assert!(config_from_args(&bad).is_err());
    }

    #[test]
    fn serve_loopback_bounded_queue_runs() {
        // closed-loop loopback with a tiny bound: overflow is shed
        // visibly, the admitted requests still score
        let a = Args::parse(&argv(&[
            "serve", "--synthetic", "--requests", "8", "--max-batch", "2",
            "--max-queue-depth", "4",
        ]))
        .unwrap();
        cmd_serve(&a).unwrap();
    }

    #[test]
    fn dist_demo_rejects_bad_role_and_missing_connect() {
        let a = Args::parse(&argv(&["dist-demo", "--role", "spectator"])).unwrap();
        assert!(cmd_dist_demo(&a).is_err());
        let w = Args::parse(&argv(&["dist-demo", "--role", "worker"])).unwrap();
        assert!(cmd_dist_demo(&w).is_err(), "worker without --connect must fail");
    }

    #[test]
    fn serve_rejects_bad_role_missing_source_and_missing_connect() {
        let bad = Args::parse(&argv(&["serve", "--role", "oracle"])).unwrap();
        assert!(cmd_serve(&bad).is_err());
        let nosrc = Args::parse(&argv(&["serve"])).unwrap();
        assert!(cmd_serve(&nosrc).is_err(), "loopback without --ckpt/--synthetic must fail");
        let c = Args::parse(&argv(&["serve", "--role", "client"])).unwrap();
        assert!(cmd_serve(&c).is_err(), "client without --connect must fail");
    }

    #[test]
    fn serve_loopback_synthetic_runs() {
        let a = Args::parse(&argv(&[
            "serve", "--synthetic", "--requests", "8", "--max-batch", "3",
        ]))
        .unwrap();
        cmd_serve(&a).unwrap();
    }

    #[test]
    fn bad_path_rejected() {
        let a = Args::parse(&argv(&["train", "--path", "warp"])).unwrap();
        assert!(config_from_args(&a).is_err());
    }
}
