//! Structured FIM approximation — the paper's framework (Sec. 3-5) as a
//! standalone, testable library.
//!
//! Everything revolves around Eq. (2):  min_{F̃ ∈ H} ‖F̃ − F‖_F²  with
//! F = E[ḡ ḡᵀ] the layer-wise empirical Fisher. Each `Structure` variant is
//! one family H from the paper; `solve` returns the paper's analytic /
//! fixed-point solution; `assemble` materializes the (mn × mn) matrix for
//! small shapes so tests can check optimality against brute force and
//! random perturbations.

pub mod empirical;

use crate::linalg::{block_diag, diag_v, jacobi_eigh, kron, Mat};
use crate::opt::racs::fixed_point;

pub use empirical::EmpiricalFim;

const EPS: f32 = 1e-8;

/// The structural families of Table 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Structure {
    /// H = {Diag_v(v)} — Adam (Proposition 1).
    Diag,
    /// H = {Iₙ ⊗ M}, SPD M — whitening (Proposition 2).
    Whitening,
    /// H = {S ⊗ Iₘ}, positive diagonal S — normalization (Proposition 2).
    Normalization,
    /// H = {S ⊗ Q}, positive diagonals — RACS (Proposition 3).
    TwoSidedDiag,
    /// H = {Rₙ^½ ⊗ Lₘ^½}, SPD — Shampoo (Theorem 3.1 upper bound).
    KronSqrt,
    /// H = {Diag_B(U Dᵢ Uᵀ)} shared eigenspace — Eigen-Adam (Theorem 3.2).
    BlockDiagSharedEig,
}

/// A solved structured approximation, with enough pieces to assemble the
/// dense F̃ and to derive the corresponding square-root NGD update.
#[derive(Debug, Clone)]
pub enum Solution {
    Diag { v: Vec<f32> },
    Whitening { m: Mat },
    Normalization { s: Vec<f32> },
    TwoSidedDiag { s: Vec<f32>, q: Vec<f32> },
    KronSqrt { r: Mat, l: Mat },
    BlockDiagSharedEig { u: Mat, d: Mat },
}

/// Solve Eq. (2) for the given structure from gradient samples (each an
/// m×n matrix; E[·] is the sample mean, as the paper estimates with EMA).
/// Uses the configured default eigensolver budget — callers with their
/// own `Hyper` should go through [`solve_with`].
pub fn solve(structure: Structure, grads: &[Mat]) -> Solution {
    solve_with(structure, grads, crate::opt::Hyper::default().eig_sweeps)
}

/// [`solve`] with an explicit Jacobi sweep budget for the eigensolving
/// structures (`BlockDiagSharedEig`) — previously hardcoded at 40
/// sweeps, ignoring the `eig_sweeps` every other refresh honors.
pub fn solve_with(structure: Structure, grads: &[Mat], eig_sweeps: usize) -> Solution {
    assert!(!grads.is_empty());
    let (m, n) = (grads[0].rows, grads[0].cols);
    let k = grads.len() as f32;
    match structure {
        Structure::Diag => {
            // Prop. 1: v = E[ḡ²] (column-stacked order)
            let mut v = vec![0.0f32; m * n];
            for g in grads {
                for j in 0..n {
                    for i in 0..m {
                        v[j * m + i] += g.at(i, j) * g.at(i, j) / k;
                    }
                }
            }
            Solution::Diag { v }
        }
        Structure::Whitening => {
            // Prop. 2: M* = E[GGᵀ]/n
            let mut acc = Mat::zeros(m, m);
            for g in grads {
                acc.ema_(1.0, &g.matmul_nt(g), 1.0 / (k * n as f32));
            }
            Solution::Whitening { m: acc }
        }
        Structure::Normalization => {
            // Prop. 2: S* = E[diag(gᵢᵀgᵢ)]/m
            let mut s = vec![0.0f32; n];
            for g in grads {
                for (sj, c) in s.iter_mut().zip(g.col_sq_norms()) {
                    *sj += c / (k * m as f32);
                }
            }
            Solution::Normalization { s }
        }
        Structure::TwoSidedDiag => {
            // Prop. 3 fixed point on E[G⊙²] — realized by stacking the
            // samples into one √-mean-square matrix (fixed_point squares).
            let mut p = Mat::zeros(m, n);
            for g in grads {
                for (pi, &gi) in p.data.iter_mut().zip(&g.data) {
                    *pi += gi * gi / k;
                }
            }
            let sqrt_p = p.map(|x| x.sqrt());
            let (s, q) = fixed_point(&sqrt_p, 30);
            Solution::TwoSidedDiag { s, q }
        }
        Structure::KronSqrt => {
            // Thm 3.1: Rₙ = E[GᵀG]/m, Lₘ = E[GGᵀ]/n
            let mut r = Mat::zeros(n, n);
            let mut l = Mat::zeros(m, m);
            for g in grads {
                r.ema_(1.0, &g.matmul_tn(g), 1.0 / (k * m as f32));
                l.ema_(1.0, &g.matmul_nt(g), 1.0 / (k * n as f32));
            }
            Solution::KronSqrt { r, l }
        }
        Structure::BlockDiagSharedEig => {
            // Thm 3.2: U = EVD(E[GGᵀ]); D̃ = Diag_M(E[(UᵀG)⊙²]). The EVD
            // goes through the size-dispatched `jacobi_eigh` (serial /
            // Brent-Luk rounds / blocked two-sided at m ≥ 1024), with the
            // solver's non-finite guard and relative pivot thresholds —
            // the same robustness contract the optimizer refreshes get.
            let mut q = Mat::zeros(m, m);
            for g in grads {
                q.ema_(1.0, &g.matmul_nt(g), 1.0 / k);
            }
            let (u, _) = jacobi_eigh(&q, eig_sweeps.max(1));
            let mut d = Mat::zeros(m, n);
            for g in grads {
                let rot = u.matmul_tn(g);
                for (di, &ri) in d.data.iter_mut().zip(&rot.data) {
                    *di += ri * ri / k;
                }
            }
            Solution::BlockDiagSharedEig { u, d }
        }
    }
}

impl Solution {
    /// Materialize the dense (mn × mn) F̃ — small shapes only (tests).
    pub fn assemble(&self, m: usize, n: usize) -> Mat {
        match self {
            Solution::Diag { v } => diag_v(v),
            Solution::Whitening { m: mat } => kron(&Mat::eye(n), mat),
            Solution::Normalization { s } => kron(&diag_v(s), &Mat::eye(m)),
            Solution::TwoSidedDiag { s, q } => kron(&diag_v(s), &diag_v(q)),
            Solution::KronSqrt { r, l } => {
                let rs = sqrt_spd(r);
                let ls = sqrt_spd(l);
                kron(&rs, &ls)
            }
            Solution::BlockDiagSharedEig { u, d } => {
                // Diag_B(U Dᵢ Uᵀ) with Dᵢ = diag(column i of d)
                let blocks: Vec<Mat> = (0..n)
                    .map(|j| {
                        let di = diag_v(&d.col_vec(j));
                        u.matmul(&di).matmul_nt(u)
                    })
                    .collect();
                block_diag(&blocks)
            }
        }
    }

    /// The square-root NGD update Mat(F̃^-½ ḡ) for this structure
    /// (App. C derivations) applied to a gradient G.
    pub fn sqrt_ngd(&self, g: &Mat) -> Mat {
        match self {
            Solution::Diag { v } => {
                let m = g.rows;
                Mat::from_fn(g.rows, g.cols, |i, j| {
                    g.at(i, j) / (v[j * m + i].sqrt() + EPS)
                })
            }
            Solution::Whitening { m: mat } => {
                // App. C.2: √n · M^-½ G (with M = E[GGᵀ]/n)
                let (_, inv_sqrt) = crate::linalg::newton_schulz(mat, 25);
                inv_sqrt.matmul(g)
            }
            Solution::Normalization { s } => Mat::from_fn(g.rows, g.cols, |i, j| {
                g.at(i, j) / (s[j].sqrt() + EPS)
            }),
            Solution::TwoSidedDiag { s, q } => {
                crate::opt::racs::apply_scaling(g, q, s)
            }
            Solution::KronSqrt { r, l } => {
                // App. C.1: L^-¼ G R^-¼
                let li = crate::linalg::inv_fourth_root(l, 25);
                let ri = crate::linalg::inv_fourth_root(r, 25);
                li.matmul(g).matmul(&ri)
            }
            Solution::BlockDiagSharedEig { u, d } => {
                // Eq. 12: U (UᵀG) / √E[(UᵀG)⊙²]
                let rot = u.matmul_tn(g);
                let dir = Mat::from_fn(rot.rows, rot.cols, |i, j| {
                    rot.at(i, j) / (d.at(i, j).sqrt() + EPS)
                });
                u.matmul(&dir)
            }
        }
    }
}

fn sqrt_spd(a: &Mat) -> Mat {
    let (sq, _) = crate::linalg::newton_schulz(a, 30);
    sq
}

/// Frobenius objective of Eq. (2): ‖F̃ − F‖²_F for dense matrices.
pub fn objective(f_tilde: &Mat, f: &Mat) -> f32 {
    f_tilde.sub(f).fro_norm_sq()
}

/// Theorem 5.1: optimal compensation scaling
/// Diag(S) = √(m−r) / √E[1ₘᵀG⊙² − 1ᵣᵀ(UᵀG)⊙²].
pub fn optimal_compensation_scale(grads: &[Mat], u: &Mat) -> Vec<f32> {
    let (m, r) = (u.rows, u.cols);
    let n = grads[0].cols;
    let k = grads.len() as f32;
    let mut p = vec![0.0f32; n];
    for g in grads {
        let sigma = u.matmul_tn(g);
        for ((pj, gc), sc) in
            p.iter_mut().zip(g.col_sq_norms()).zip(sigma.col_sq_norms())
        {
            *pj += (gc - sc) / k;
        }
    }
    let scale = ((m - r).max(1) as f32).sqrt();
    p.iter().map(|&x| scale / (x.max(0.0).sqrt() + EPS)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{random_orthonormal, vec_cols};
    use crate::util::Pcg;

    fn samples(m: usize, n: usize, k: usize, seed: u64) -> Vec<Mat> {
        let mut rng = Pcg::seeded(seed);
        (0..k)
            .map(|_| Mat::from_vec(m, n, rng.normal_vec(m * n, 1.0)))
            .collect()
    }

    fn dense_fim(grads: &[Mat]) -> Mat {
        let mn = grads[0].rows * grads[0].cols;
        let mut f = Mat::zeros(mn, mn);
        for g in grads {
            let v = vec_cols(g);
            for i in 0..mn {
                for j in 0..mn {
                    f.data[i * mn + j] += v[i] * v[j] / grads.len() as f32;
                }
            }
        }
        f
    }

    /// The analytic solution must beat random perturbations of itself —
    /// a local-optimality probe of Props. 1-3.
    fn check_local_optimality(structure: Structure, seed: u64) {
        let grads = samples(4, 5, 12, seed);
        let f = dense_fim(&grads);
        let sol = solve(structure, &grads);
        let base = objective(&sol.assemble(4, 5), &f);
        let mut rng = Pcg::seeded(seed + 1);
        for _ in 0..20 {
            let perturbed = match &sol {
                Solution::Diag { v } => Solution::Diag {
                    v: v.iter().map(|&x| x * (1.0 + 0.1 * rng.normal())).collect(),
                },
                Solution::Normalization { s } => Solution::Normalization {
                    s: s.iter().map(|&x| x * (1.0 + 0.1 * rng.normal())).collect(),
                },
                Solution::TwoSidedDiag { s, q } => Solution::TwoSidedDiag {
                    s: s.iter().map(|&x| (x * (1.0 + 0.1 * rng.normal())).max(1e-6)).collect(),
                    q: q.iter().map(|&x| (x * (1.0 + 0.1 * rng.normal())).max(1e-6)).collect(),
                },
                Solution::Whitening { m } => {
                    let noise = rng.normal_vec(m.rows * m.cols, 0.05);
                    let mut pm = m.clone();
                    for (x, n) in pm.data.iter_mut().zip(noise) {
                        *x *= 1.0 + n;
                    }
                    pm.symmetrize_();
                    Solution::Whitening { m: pm }
                }
                other => other.clone(),
            };
            let obj = objective(&perturbed.assemble(4, 5), &f);
            assert!(
                obj + 1e-4 >= base,
                "{structure:?}: perturbation improved objective {base} -> {obj}"
            );
        }
    }

    #[test]
    fn prop1_diag_is_locally_optimal() {
        check_local_optimality(Structure::Diag, 50);
    }

    #[test]
    fn prop2_normalization_is_locally_optimal() {
        check_local_optimality(Structure::Normalization, 51);
    }

    #[test]
    fn prop2_whitening_is_locally_optimal() {
        check_local_optimality(Structure::Whitening, 52);
    }

    #[test]
    fn prop3_two_sided_is_locally_optimal() {
        check_local_optimality(Structure::TwoSidedDiag, 53);
    }

    #[test]
    fn prop1_diag_matches_brute_force() {
        // Purely diagonal: the optimum is elementwise, so brute force is
        // exact: v_i = F_ii.
        let grads = samples(3, 4, 10, 54);
        let f = dense_fim(&grads);
        if let Solution::Diag { v } = solve(Structure::Diag, &grads) {
            for (i, &vi) in v.iter().enumerate() {
                assert!((vi - f.at(i, i)).abs() < 1e-4, "v[{i}]");
            }
        } else {
            panic!("wrong variant");
        }
    }

    #[test]
    fn generality_ordering_of_objectives() {
        // More general structures achieve lower (or equal) Frobenius error:
        // Diag vs Normalization vs TwoSidedDiag; Eigen-Adam ≤ Diag.
        let grads = samples(4, 5, 16, 55);
        let f = dense_fim(&grads);
        let obj = |s: Structure| objective(&solve(s, &grads).assemble(4, 5), &f);
        let diag = obj(Structure::Diag);
        let norm = obj(Structure::Normalization);
        let two = obj(Structure::TwoSidedDiag);
        let eig = obj(Structure::BlockDiagSharedEig);
        assert!(two <= norm + 1e-3, "two-sided {two} vs norm {norm}");
        assert!(eig <= diag + 1e-3, "eigen {eig} vs diag {diag}");
        // and normalization can't beat the strictly more general two-sided
        assert!(diag > 0.0 && norm > 0.0);
    }

    #[test]
    fn solve_with_honors_the_sweep_budget() {
        // 1 sweep vs converged: both finite/orthonormal (the solver
        // normalizes either way), but the bases must differ — proof the
        // budget actually reaches the eigensolver instead of the old
        // hardcoded 40
        let grads = samples(8, 6, 10, 60);
        let one = solve_with(Structure::BlockDiagSharedEig, &grads, 1);
        let full = solve_with(Structure::BlockDiagSharedEig, &grads, 40);
        let (Solution::BlockDiagSharedEig { u: u1, .. },
             Solution::BlockDiagSharedEig { u: u40, .. }) = (one, full)
        else {
            panic!("wrong variant");
        };
        assert!(u1.is_finite() && u40.is_finite());
        assert_ne!(u1.data, u40.data, "sweep budget must reach jacobi_eigh");
        // and the default entry follows Hyper::default().eig_sweeps
        let via_default = solve(Structure::BlockDiagSharedEig, &grads);
        let via_explicit = solve_with(
            Structure::BlockDiagSharedEig,
            &grads,
            crate::opt::Hyper::default().eig_sweeps,
        );
        let (Solution::BlockDiagSharedEig { u: ud, .. },
             Solution::BlockDiagSharedEig { u: ue, .. }) = (via_default, via_explicit)
        else {
            panic!("wrong variant");
        };
        assert_eq!(ud.data, ue.data);
    }

    #[test]
    fn sqrt_ngd_matches_adam_shape() {
        let grads = samples(4, 5, 8, 56);
        let sol = solve(Structure::Diag, &grads);
        let upd = sol.sqrt_ngd(&grads[0]);
        assert_eq!((upd.rows, upd.cols), (4, 5));
        assert!(upd.is_finite());
    }

    #[test]
    fn proposition4_decomposition() {
        // Construct gradients sharing a fixed eigenbasis; verify
        // Q* = Σ G̃G̃ᵀ + U_c Σ U_cᵀ (Prop. 4).
        let m = 6;
        let r = 3;
        let mut rng = Pcg::seeded(57);
        let basis = random_orthonormal(m, m, &mut rng);
        let u = basis.take_cols(r);
        let uc = Mat::from_fn(m, m - r, |i, j| basis.at(i, j + r));
        let mut q_true = Mat::zeros(m, m);
        let mut q_low = Mat::zeros(m, m);
        let mut sigma_acc = Mat::zeros(m - r, m - r);
        for _ in 0..5 {
            // G with the shared eigenbasis: G Gᵀ = basis Λ basisᵀ
            let lam: Vec<f32> = (0..m).map(|_| rng.f32() + 0.1).collect();
            // G = basis diag(sqrt(lam)) Wᵀ for any orthonormal W (n = m)
            let w = random_orthonormal(m, m, &mut rng);
            let mut bs = basis.clone();
            for i in 0..m {
                for j in 0..m {
                    *bs.at_mut(i, j) *= lam[j].sqrt();
                }
            }
            let g = bs.matmul_nt(&w);
            q_true.ema_(1.0, &g.matmul_nt(&g), 1.0);
            let gt = u.matmul(&u.matmul_tn(&g)); // G̃ = U Uᵀ G
            q_low.ema_(1.0, &gt.matmul_nt(&gt), 1.0);
            // Σ contribution: U_cᵀ G Gᵀ U_c (diagonal in exact arithmetic)
            let proj = uc.matmul_tn(&g);
            sigma_acc.ema_(1.0, &proj.matmul_nt(&proj), 1.0);
        }
        let rhs = q_low.add(&uc.matmul(&sigma_acc).matmul_nt(&uc));
        assert!(
            q_true.sub(&rhs).max_abs() < 1e-3 * q_true.max_abs(),
            "Prop. 4 decomposition violated: {}",
            q_true.sub(&rhs).max_abs()
        );
    }

    #[test]
    fn thm51_compensation_beats_uniform_scaling() {
        // The Thm 5.1 scaling must achieve a lower complement-FIM
        // reconstruction loss than uniform scalings.
        let grads = samples(6, 8, 10, 58);
        let mut rng = Pcg::seeded(59);
        let u = random_orthonormal(6, 2, &mut rng);
        let s_opt = optimal_compensation_scale(&grads, &u);
        assert!(s_opt.iter().all(|&x| x > 0.0));
        // reconstruction loss ‖(S^-2 ⊗ U_cU_cᵀ) − F̃_c‖² via the paper's
        // derivation reduces to Σⱼ [(m−r)·Oⱼⱼ² − 2·Oⱼⱼ·pⱼ] + C with
        // Oⱼⱼ = 1/sⱼ² — check optimality of the analytic Oⱼⱼ = pⱼ/(m−r).
        let m = 6usize;
        let r = 2usize;
        let k = grads.len() as f32;
        let mut p = vec![0.0f32; 8];
        for g in &grads {
            let sg = u.matmul_tn(g);
            for ((pj, gc), sc) in
                p.iter_mut().zip(g.col_sq_norms()).zip(sg.col_sq_norms())
            {
                *pj += (gc - sc) / k;
            }
        }
        let loss = |o: &[f32]| -> f32 {
            o.iter()
                .zip(&p)
                .map(|(&oj, &pj)| (m - r) as f32 * oj * oj - 2.0 * oj * pj)
                .sum()
        };
        let o_opt: Vec<f32> =
            s_opt.iter().map(|&s| 1.0 / (s * s)).collect();
        let base = loss(&o_opt);
        for _ in 0..20 {
            let o_rand: Vec<f32> = o_opt
                .iter()
                .map(|&x| (x * (1.0 + 0.2 * rng.normal())).max(1e-6))
                .collect();
            assert!(loss(&o_rand) + 1e-5 >= base);
        }
    }
}
