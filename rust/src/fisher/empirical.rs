//! Streaming empirical-FIM statistics with EMA — the practical estimator
//! the paper uses in place of E[·] (Sec. 2.1 note).
//!
//! Tracks, per layer: E[GGᵀ], E[GᵀG], E[G⊙²] under a β-EMA, from which any
//! of the `Structure` solutions can be extracted online. Used by the
//! structure-comparison bench (Table 1) and the fisher tests.

use crate::linalg::Mat;

#[derive(Debug, Clone)]
pub struct EmpiricalFim {
    pub beta: f32,
    pub ggt: Mat,
    pub gtg: Mat,
    pub g2: Mat,
    pub count: u64,
}

impl EmpiricalFim {
    pub fn new(m: usize, n: usize, beta: f32) -> Self {
        EmpiricalFim {
            beta,
            ggt: Mat::zeros(m, m),
            gtg: Mat::zeros(n, n),
            g2: Mat::zeros(m, n),
            count: 0,
        }
    }

    /// Fold one gradient sample into the EMAs (bias-corrected on read).
    pub fn update(&mut self, g: &Mat) {
        let b = self.beta;
        self.ggt.ema_(b, &g.matmul_nt(g), 1.0 - b);
        self.gtg.ema_(b, &g.matmul_tn(g), 1.0 - b);
        for (x, &gi) in self.g2.data.iter_mut().zip(&g.data) {
            *x = b * *x + (1.0 - b) * gi * gi;
        }
        self.count += 1;
    }

    fn corr(&self) -> f32 {
        1.0 - self.beta.powi(self.count as i32)
    }

    /// Bias-corrected E[GGᵀ].
    pub fn e_ggt(&self) -> Mat {
        self.ggt.scale(1.0 / self.corr().max(1e-12))
    }

    /// Bias-corrected E[GᵀG].
    pub fn e_gtg(&self) -> Mat {
        self.gtg.scale(1.0 / self.corr().max(1e-12))
    }

    /// Bias-corrected E[G⊙²] — the matrix whose principal singular pair is
    /// the RACS fixed point (Prop. 3).
    pub fn e_g2(&self) -> Mat {
        self.g2.scale(1.0 / self.corr().max(1e-12))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg;

    #[test]
    fn ema_converges_to_mean_for_constant_input() {
        let mut fim = EmpiricalFim::new(3, 4, 0.9);
        let g = Mat::from_fn(3, 4, |i, j| (i + j) as f32 * 0.1);
        for _ in 0..200 {
            fim.update(&g);
        }
        let want = g.matmul_nt(&g);
        assert!(fim.e_ggt().sub(&want).max_abs() < 1e-3);
        let g2 = g.map(|x| x * x);
        assert!(fim.e_g2().sub(&g2).max_abs() < 1e-4);
    }

    #[test]
    fn bias_correction_early_steps() {
        let mut fim = EmpiricalFim::new(2, 2, 0.99);
        let g = Mat::from_vec(2, 2, vec![1.0, 0.0, 0.0, 1.0]);
        fim.update(&g);
        // after one update the corrected estimate equals the sample itself
        assert!(fim.e_ggt().sub(&g.matmul_nt(&g)).max_abs() < 1e-5);
    }

    #[test]
    fn symmetric_accumulators() {
        let mut rng = Pcg::seeded(60);
        let mut fim = EmpiricalFim::new(4, 6, 0.9);
        for _ in 0..10 {
            fim.update(&Mat::from_vec(4, 6, rng.normal_vec(24, 1.0)));
        }
        let a = fim.e_ggt();
        assert!(a.sub(&a.transpose()).max_abs() < 1e-5);
        let b = fim.e_gtg();
        assert!(b.sub(&b.transpose()).max_abs() < 1e-5);
    }
}
