//! Benchmark harness shared by `rust/benches/*` — criterion-style timing
//! (warmup + measured iterations, mean ± σ) plus the training-run drivers
//! that regenerate the paper's tables and figures.
//!
//! Scaling: the benches honor three env vars so the same binaries serve
//! both CI smoke runs and full reproductions:
//! * `AR_BENCH_STEPS`   — optimizer steps per training run (default 120)
//! * `AR_BENCH_OPTS`    — comma list overriding the optimizer sweep
//! * `AR_BENCH_THREADS` — pool width for the runs (0 = all cores, the
//!   default; `fig3_throughput` additionally sweeps serial vs parallel)
//! * `AR_BENCH_SMOKE`   — `1` shrinks the no-artifact sections to a CI
//!   smoke run (parity asserts stay live; summaries land in
//!   `runs/bench/*_summary.json` via [`write_summary`])

use anyhow::Result;

use crate::config::RunConfig;
use crate::coordinator::{self, Summary, Trainer};
use crate::linalg::{jacobi_eigh_blocked, jacobi_eigh_rounds, Mat};
use crate::opt;
use crate::util::json::{num, obj};
use crate::util::{mean, pool, std_dev, Json, Pcg, Timer};

/// Measured wallclock stats for one micro-bench.
#[derive(Debug, Clone)]
pub struct Timing {
    pub name: String,
    pub mean_ms: f64,
    pub std_ms: f64,
    pub iters: usize,
}

impl Timing {
    pub fn row(&self) -> String {
        format!(
            "{:<34} {:>10.3} ms ± {:>7.3} ({} iters)",
            self.name, self.mean_ms, self.std_ms, self.iters
        )
    }
}

/// Criterion-style measurement: warm up, then time `iters` runs.
pub fn time_fn(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Timer::start();
        f();
        samples.push(t.millis());
    }
    Timing {
        name: name.to_string(),
        mean_ms: mean(&samples),
        std_ms: std_dev(&samples),
        iters,
    }
}

/// Steps per bench training run (env-scalable).
pub fn bench_steps(default: usize) -> usize {
    std::env::var("AR_BENCH_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Optimizer sweep for the table benches (env-overridable).
pub fn bench_opts(default: &[&str]) -> Vec<String> {
    match std::env::var("AR_BENCH_OPTS") {
        Ok(v) => v.split(',').map(|s| s.trim().to_string()).collect(),
        Err(_) => default.iter().map(|s| s.to_string()).collect(),
    }
}

/// Pool width for bench runs (env-overridable; 0 = all cores).
pub fn bench_threads(default: usize) -> usize {
    std::env::var("AR_BENCH_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Smoke mode for CI's bench-smoke job (`AR_BENCH_SMOKE=1`): the figure
/// benches shrink their no-artifact sections from minutes to seconds
/// while keeping every internal parity assert live — the job gates on
/// the asserts, the uploaded summaries record the (smoke-sized) numbers.
pub fn smoke() -> bool {
    std::env::var("AR_BENCH_SMOKE").map(|v| v.trim() == "1").unwrap_or(false)
}

/// Write a bench's machine-readable summary to
/// `runs/bench/<name>_summary.json`. CI's bench-smoke job uploads these
/// as workflow artifacts — the first rung of a perf-trajectory gate
/// (compare summaries across commits before an in-CI threshold exists).
/// Returns the path written.
pub fn write_summary(name: &str, summary: &Json) -> Result<String> {
    let dir = "runs/bench";
    std::fs::create_dir_all(dir)?;
    let path = format!("{dir}/{name}_summary.json");
    std::fs::write(&path, summary.to_string())?;
    Ok(path)
}

/// Eigen-refresh mode for bench/e2e runs (the CI matrix sets
/// `AR_REFRESH=sketch` on the sketch cell so training-path coverage of
/// the randomized range finder rides the existing jobs; unset/other =
/// the exact default).
pub fn bench_refresh() -> opt::Refresh {
    match std::env::var("AR_REFRESH") {
        Ok(v) if v.trim() == "sketch" => opt::Refresh::Sketch,
        _ => opt::Refresh::Exact,
    }
}

/// Simulated DP worker count for the dist benches/tests (the CI matrix
/// sets `AR_DP_WORKERS=8` on the dist cell — 8 workers oversubscribing a
/// width-4 pool, past the {1, 2, 4} base sweep; 0/unset = the default).
pub fn bench_dp_workers(default: usize) -> usize {
    match std::env::var("AR_DP_WORKERS").ok().and_then(|v| v.parse().ok()) {
        Some(0) | None => default,
        Some(n) => n,
    }
}

/// Transport for the dist tests (the CI matrix sets `AR_TRANSPORT=tcp`
/// on one dist cell so the wire path — real sockets, framing, requeue on
/// disconnect — rides the same parity suite as the loopback cell;
/// unset/other = the in-process loopback default).
pub fn bench_transport() -> crate::dist::TransportKind {
    match std::env::var("AR_TRANSPORT") {
        Ok(v) if v.trim() == "tcp" => crate::dist::TransportKind::Tcp,
        _ => crate::dist::TransportKind::Loopback,
    }
}

/// Round-loop schedule for the dist tests (the CI matrix sets
/// `AR_ROUND=pipelined` on one dist cell so the overlapped schedule —
/// eager segment reduce, per-layer optimizer fan-out, double-buffered
/// rounds — rides the same parity suites as the phased cells;
/// unset/other = the phased reference default).
pub fn bench_round() -> crate::dist::RoundMode {
    match std::env::var("AR_ROUND") {
        Ok(v) if v.trim() == "pipelined" => crate::dist::RoundMode::Pipelined,
        _ => crate::dist::RoundMode::Phased,
    }
}

/// The dist dp-worker sweep shared by `fig7_dp_scaling` and
/// `tests/dist_parity.rs`: {1, 2, 4} ∪ {`AR_DP_WORKERS`} — one place, so
/// what CI tests and what the bench reports cannot diverge.
pub fn dp_sweep() -> Vec<usize> {
    let mut dps = vec![1, 2, 4];
    let extra = bench_dp_workers(4);
    if !dps.contains(&extra) {
        dps.push(extra);
    }
    dps
}

/// Blocked-vs-rounds eigh timing table shared by `fig3_throughput` and
/// `fig6_eigen_stability` — one implementation, one sizing policy, so
/// the two summary artifacts cannot drift (same dedup rationale as
/// [`dp_sweep`]). Times `jacobi_eigh_rounds` vs `jacobi_eigh_blocked`
/// at the huge-n refresh sizes (n ∈ {1024, 2048}; smoke: {192, 256})
/// with 2 sweeps per measurement — timing needs the full rotation
/// schedule, not convergence — prints the table, and returns the
/// section JSON. Callers assert spectral agreement between the two
/// paths at a convergence-sized n *before* invoking, so a reported
/// speedup can never come from a diverging decomposition.
pub fn blocked_vs_rounds_table() -> Json {
    let cores = pool::available();
    let sizes: Vec<usize> = if smoke() { vec![192, 256] } else { vec![1024, 2048] };
    let (sweeps, iters) = (2usize, if smoke() { 1 } else { 2 });
    println!("== blocked vs rounds: n ≥ 2k eigen-refresh axis ({sweeps} sweeps, width {cores}) ==");
    let mut table = TablePrinter::new(&["n", "rounds ms", "blocked ms", "speedup"]);
    let mut rows: Vec<Json> = Vec::new();
    for &n in &sizes {
        let mut rng = Pcg::seeded(0xb10c + n as u64);
        let src = Mat::from_vec(n, n, rng.normal_vec(n * n, 1.0));
        let a = src.matmul_nt(&src);
        let rounds = pool::with_threads(cores, || {
            time_fn("rounds", 1, iters, || {
                std::hint::black_box(jacobi_eigh_rounds(&a, sweeps));
            })
        });
        let blocked = pool::with_threads(cores, || {
            time_fn("blocked", 1, iters, || {
                std::hint::black_box(jacobi_eigh_blocked(&a, sweeps));
            })
        });
        let speedup = rounds.mean_ms / blocked.mean_ms.max(1e-9);
        table.row(vec![
            n.to_string(),
            format!("{:.1}", rounds.mean_ms),
            format!("{:.1}", blocked.mean_ms),
            format!("{speedup:.2}x"),
        ]);
        rows.push(obj(vec![
            ("n", num(n as f64)),
            ("rounds_ms", num(rounds.mean_ms)),
            ("blocked_ms", num(blocked.mean_ms)),
            ("speedup", num(speedup)),
        ]));
    }
    table.print();
    println!(
        "\nMemory-traffic argument: the flat rounds stream the whole n² \
         working set once per rotation round; the blocked path touches \
         O(n·b) per tile rotation with the 2b x 2b pivot solves hot in \
         cache (b = 64). Record full-size numbers in EXPERIMENTS \
         §n ≥ 2k refresh protocol.\n"
    );
    obj(vec![("sweeps", num(sweeps as f64)), ("sizes", Json::Arr(rows))])
}

/// A standard bench run config against the default artifact bundle.
pub fn bench_cfg(opt: &str, tag: &str, steps: usize) -> RunConfig {
    let mut cfg = RunConfig::default().tuned_for(opt);
    cfg.artifacts = "artifacts".into();
    cfg.out_dir = format!("runs/bench/{tag}/{opt}");
    cfg.steps = steps;
    cfg.eval_every = (steps / 8).max(1);
    cfg.eval_batches = 4;
    cfg.log_every = usize::MAX;
    cfg.threads = bench_threads(0);
    // Paper Sec. 7.1 lm-head protocol from the registry: full-rank
    // candidates report Ppl* (Adam-trained head), low-rank candidates
    // train it themselves (Ppl).
    cfg.last_layer_adam = !opt::is_low_rank(opt, &cfg.hp).unwrap_or(false);
    // artifact bundle is lowered with rank 16 / interval 50 (Makefile
    // defaults); the native path follows the same geometry
    cfg.hp.rank = 16;
    cfg.hp.leading = 6;
    cfg.hp.interval = 50;
    cfg.hp.refresh = bench_refresh();
    cfg
}

/// Train one optimizer and return its summary.
pub fn run_one(cfg: RunConfig) -> Result<Summary> {
    let mut trainer = Trainer::new(cfg)?;
    coordinator::run_with(&mut trainer)
}

pub fn artifacts_available() -> bool {
    let ok = std::path::Path::new("artifacts/manifest.json").exists();
    if !ok {
        eprintln!("bench requires artifacts: run `make artifacts` first");
    }
    ok
}

/// Markdown-ish table printer shared by the table benches.
pub struct TablePrinter {
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl TablePrinter {
    pub fn new(headers: &[&str]) -> Self {
        TablePrinter {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate().take(ncol) {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate().take(ncol) {
                s.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            s
        };
        println!("{}", line(&self.headers));
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        println!("{}", line(&sep));
        for row in &self.rows {
            println!("{}", line(row));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_fn_measures() {
        let t = time_fn("noop", 1, 5, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(t.iters, 5);
        assert!(t.mean_ms < 10.0);
        assert!(t.row().contains("noop"));
    }

    #[test]
    fn table_printer_aligns() {
        let mut t = TablePrinter::new(&["a", "bbbb"]);
        t.row(vec!["xx".into(), "y".into()]);
        t.print(); // smoke — no panic, alignment covered by width logic
        assert_eq!(t.rows.len(), 1);
    }

    #[test]
    fn env_scaling_defaults() {
        std::env::remove_var("AR_BENCH_STEPS");
        std::env::remove_var("AR_BENCH_THREADS");
        std::env::remove_var("AR_DP_WORKERS");
        std::env::remove_var("AR_BENCH_SMOKE");
        assert_eq!(bench_steps(120), 120);
        assert_eq!(bench_opts(&["adam", "racs"]), vec!["adam", "racs"]);
        assert_eq!(bench_threads(0), 0);
        assert_eq!(bench_dp_workers(4), 4, "unset env falls back to the default");
        assert!(!smoke(), "smoke mode requires AR_BENCH_SMOKE=1");
        // AR_REFRESH is read per-call; no other test mutates it, so
        // exercising both arms here is race-free under the env-var lock
        // convention of this suite (all env tests live in this one fn)
        std::env::remove_var("AR_REFRESH");
        assert_eq!(bench_refresh(), opt::Refresh::Exact);
        std::env::set_var("AR_REFRESH", "sketch");
        assert_eq!(bench_refresh(), opt::Refresh::Sketch);
        std::env::remove_var("AR_REFRESH");
        std::env::remove_var("AR_TRANSPORT");
        assert_eq!(bench_transport(), crate::dist::TransportKind::Loopback);
        std::env::set_var("AR_TRANSPORT", "tcp");
        assert_eq!(bench_transport(), crate::dist::TransportKind::Tcp);
        std::env::remove_var("AR_TRANSPORT");
        std::env::remove_var("AR_ROUND");
        assert_eq!(bench_round(), crate::dist::RoundMode::Phased);
        std::env::set_var("AR_ROUND", "pipelined");
        assert_eq!(bench_round(), crate::dist::RoundMode::Pipelined);
        std::env::remove_var("AR_ROUND");
    }

    #[test]
    fn write_summary_emits_valid_json() {
        let j = crate::util::json::obj(vec![("x", crate::util::json::num(1.5))]);
        let path = write_summary("selftest", &j).expect("write");
        let txt = std::fs::read_to_string(&path).expect("read back");
        let parsed = Json::parse(&txt).expect("parse");
        assert!((parsed.f64_of("x").unwrap() - 1.5).abs() < 1e-12);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn dp_sweep_covers_the_base_grid() {
        let dps = dp_sweep();
        for base in [1usize, 2, 4] {
            assert!(dps.contains(&base), "sweep {dps:?} must include {base}");
        }
        assert!(dps.len() <= 4, "at most one env-extra entry: {dps:?}");
    }

    #[test]
    fn bench_cfg_lm_head_protocol_from_registry() {
        // Ppl* (Adam head) for full-rank candidates, Ppl for low-rank
        assert!(bench_cfg("adam", "t", 10).last_layer_adam);
        assert!(bench_cfg("racs", "t", 10).last_layer_adam);
        assert!(!bench_cfg("galore", "t", 10).last_layer_adam);
        assert!(!bench_cfg("alice", "t", 10).last_layer_adam);
    }
}
