//! # alice-racs
//!
//! Production-style reproduction of *"Towards Efficient Optimizer Design
//! for LLM via Structured Fisher Approximation with a Low-Rank Extension"*
//! (Gong, Scetbon, Ma, Meeds 2025): the structured-FIM optimizer framework,
//! the RACS and Alice optimizers, every baseline the paper compares
//! against, and the benchmark harness regenerating each table and figure.
//!
//! Three-layer architecture (see DESIGN.md):
//! * **L1** Pallas kernels + **L2** JAX model/optimizers live in `python/`
//!   and are AOT-lowered to HLO text by `make artifacts`.
//! * **L3** (this crate) is the training coordinator: it owns config, data,
//!   the training loop, optimizer state, the K-interval refresh schedule,
//!   metrics, and executes the AOT artifacts through the PJRT CPU client
//!   (`runtime`). Python is never on the training path.
//!
//! Native Rust implementations of all optimizers (`opt`) and of the FIM
//! approximation theory (`fisher`) serve as baselines, enable ablations
//! without re-lowering, and cross-validate the HLO path in `rust/tests/`.

pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod dist;
pub mod fisher;
pub mod linalg;
pub mod obs;
pub mod opt;
pub mod runtime;
pub mod serve;
pub mod testing;
pub mod util;
