//! Native Rust implementations of every optimizer in the paper.
//!
//! These serve three roles (DESIGN.md §1 L3):
//! 1. the coordinator's default per-layer update path (grads come from the
//!    AOT `grad_step` executable, updates happen here);
//! 2. the baselines required to regenerate Tables 1-5 / Figures 1-6 without
//!    a new AOT artifact per variant;
//! 3. an independent reference cross-checked against the HLO optimizer
//!    artifacts in `rust/tests/parity.rs` (same gradients → same update).
//!
//! Semantics mirror `python/compile/optimizers.py` exactly (same EPS, same
//! warm-start rules, same limiter) so parity holds to f32 tolerance.

pub mod alice;
pub mod eigen;
pub mod lowrank;
pub mod racs;
pub mod simple;
pub mod whiten_ops;

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::linalg::{Mat, SketchSpec};

pub const EPS: f32 = 1e-8;

/// Hyperparameters — mirrors `optimizers.HP` (paper App. F.2 defaults).
#[derive(Debug, Clone)]
pub struct Hyper {
    pub b1: f32,
    pub b2: f32,
    pub b3: f32,
    pub eps: f32,
    pub rank: usize,
    pub leading: usize,
    pub interval: usize,
    pub alpha: f32,
    pub alpha_c: f32,
    pub gamma: f32,
    pub beta_racs: f32,
    pub racs_iters: usize,
    pub ns_iters: usize,
    pub eig_sweeps: usize,
    pub sub_iters: usize,
    pub switch: Switch,
    pub compen: Compen,
    pub racs_ema: bool,
    pub bias_correction: bool,
    /// Alice tracking (β₃ EMA of the projected Q̃) — false for Alice-0.
    pub tracking: bool,
    /// Eigen-refresh dispatch: exact Jacobi vs randomized sketch (ISSUE 6).
    pub refresh: Refresh,
    /// Extra sketch columns p beyond the target rank.
    pub sketch_oversample: usize,
    /// Power iterations q of the randomized range finder.
    pub sketch_power_iters: usize,
    /// Every k-th refresh runs the exact path as a drift anchor
    /// (0 = never anchor; the first refresh is always an anchor).
    pub refresh_anchor_every: usize,
}

impl Default for Hyper {
    fn default() -> Self {
        Hyper {
            b1: 0.9,
            b2: 0.999,
            b3: 0.999,
            eps: 1e-8,
            rank: 32,
            leading: 10,
            interval: 200,
            alpha: 1.0,
            alpha_c: 0.4,
            gamma: 1.01,
            beta_racs: 0.9,
            racs_iters: 5,
            ns_iters: 6,
            eig_sweeps: 20,
            sub_iters: 1,
            switch: Switch::Switch,
            compen: Compen::Optimal,
            racs_ema: true,
            bias_correction: true,
            tracking: true,
            refresh: Refresh::Exact,
            sketch_oversample: 8,
            sketch_power_iters: 2,
            refresh_anchor_every: 8,
        }
    }
}

impl Hyper {
    /// Paper Table 11 Alice defaults (β₂ = 0.9).
    pub fn alice_defaults() -> Self {
        Hyper { b2: 0.9, ..Default::default() }
    }

    /// Range-finder geometry for a sketched refresh over an n-dimensional
    /// operator: target rank from `rank` (clamped like [`lowrank::eff_rank`]),
    /// oversampling / power iterations from the sketch knobs, and the
    /// projected eigenproblem reusing `eig_sweeps`.
    pub fn sketch_spec(&self, n: usize) -> SketchSpec {
        SketchSpec {
            rank: self.rank.clamp(1, n.max(1)),
            oversample: self.sketch_oversample,
            power_iters: self.sketch_power_iters,
            sweeps: self.eig_sweeps,
        }
    }
}

/// Eigen-refresh dispatch (ISSUE 6): `Exact` runs the size-dispatched
/// `jacobi_eigh` over the full operator; `Sketch` runs the randomized
/// range finder (`linalg::rangefinder`) warm-started from the previous
/// basis, anchored back to exact every `refresh_anchor_every`-th refresh.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Refresh {
    Exact,
    Sketch,
}

impl Refresh {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "exact" => Refresh::Exact,
            "sketch" => Refresh::Sketch,
            _ => return Err(anyhow!("unknown refresh mode {s:?}")),
        })
    }
}

/// Shared anchor bookkeeping for the sketch path: bump the per-slot
/// refresh counter (`"rc"`, installed by `init` in sketch mode) and
/// report whether this refresh is an exact drift anchor. Refresh 0 —
/// the very first, where the stored basis is still the identity/zero
/// placeholder — always anchors, so the sketch warm-start begins from a
/// genuine eigenbasis; `anchor_every == 0` never anchors again.
pub(crate) fn sketch_anchor_due(state: &mut State, anchor_every: usize) -> bool {
    let c = state.scalar("rc");
    state.scalars.insert("rc", c + 1.0);
    let anchor = c == 0.0 || (anchor_every > 0 && (c as u64) % (anchor_every as u64) == 0);
    // cost-ledger accounting only — never read back into control flow
    if anchor {
        crate::obs::REFRESH_ANCHOR.incr();
    } else {
        crate::obs::REFRESH_SKETCH.incr();
    }
    anchor
}

/// Subspace-switching strategies — Fig. 5(b) ablation axis (Alg. 2 = Switch).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Switch {
    Switch,
    Evd,
    Gaussian,
    GaussianMix,
    FullBasis,
}

impl Switch {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "switch" => Switch::Switch,
            "evd" => Switch::Evd,
            "gaussian" => Switch::Gaussian,
            "gaussian_mix" => Switch::GaussianMix,
            "full_basis" => Switch::FullBasis,
            _ => return Err(anyhow!("unknown switch strategy {s:?}")),
        })
    }
}

/// Compensation strategies — Fig. 5(c) ablation axis (Thm 5.1 = Optimal).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Compen {
    Optimal,
    None,
    Fira,
    FiraPlus,
}

impl Compen {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "optimal" => Compen::Optimal,
            "none" => Compen::None,
            "fira" => Compen::Fira,
            "fira_plus" => Compen::FiraPlus,
            _ => return Err(anyhow!("unknown compensation strategy {s:?}")),
        })
    }
}

/// Generic optimizer state: named matrices / vectors / scalars.
/// Byte accounting over the actual contents drives Table 3 and Fig. 4.
#[derive(Debug, Clone, Default)]
pub struct State {
    pub mats: BTreeMap<&'static str, Mat>,
    pub vecs: BTreeMap<&'static str, Vec<f32>>,
    pub scalars: BTreeMap<&'static str, f32>,
}

impl State {
    pub fn mat(&self, k: &str) -> &Mat {
        self.mats.get(k).unwrap_or_else(|| panic!("state mat {k:?} missing"))
    }

    pub fn vec(&self, k: &str) -> &[f32] {
        self.vecs.get(k).unwrap_or_else(|| panic!("state vec {k:?} missing"))
    }

    pub fn scalar(&self, k: &str) -> f32 {
        *self.scalars.get(k).unwrap_or(&0.0)
    }

    /// Optimizer-state footprint in elements (the paper counts elements;
    /// bytes = elements * dtype size — Table 3 uses BF16 = 2 bytes).
    /// `diag_*` entries are instrumentation (Fig. 6) and not counted.
    pub fn elems(&self) -> u64 {
        let m: u64 = self
            .mats
            .iter()
            .filter(|(k, _)| !k.starts_with("diag"))
            .map(|(_, m)| (m.rows * m.cols) as u64)
            .sum();
        let v: u64 = self
            .vecs
            .iter()
            .filter(|(k, _)| !k.starts_with("diag"))
            .map(|(_, v)| v.len() as u64)
            .sum();
        m + v + self.scalars.len() as u64
    }
}

/// The norm-growth limiter shared by RACS / Fira / Alice compensation
/// (Alg. 1 lines 9-10). Returns (scaled delta, new phi).
pub fn limiter(delta: Mat, phi: f32, gamma: f32) -> (Mat, f32) {
    let dn = delta.fro_norm() + EPS;
    let (eta, phi2) = if phi > 0.0 {
        let ratio = dn / (phi + EPS);
        let eta = gamma / ratio.max(gamma);
        (eta, eta * dn)
    } else {
        (1.0, dn)
    };
    (delta.scale(eta), phi2)
}

/// Per-column variant of [`limiter`] — the FiraPlus compensation arm
/// (Fig. 5(c) ablation, per the Fira paper's column-wise norm limiter):
/// each column's norm growth is capped at `gamma` independently, with
/// one φ slot per column, so a single exploding column can no longer
/// throttle (or unleash) every other column the way the global limiter
/// does. Per column the recurrence mirrors [`limiter`] exactly: first
/// sight passes through and records φⱼ, later steps cap growth at
/// `gamma · φⱼ`. Updates `phi` in place and returns the scaled delta.
pub fn limiter_cols(delta: &Mat, phi: &mut [f32], gamma: f32) -> Mat {
    assert_eq!(phi.len(), delta.cols, "one phi slot per column");
    let etas: Vec<f32> = delta
        .col_sq_norms()
        .iter()
        .zip(phi.iter_mut())
        .map(|(&sq, p)| {
            let dn = sq.sqrt() + EPS;
            if *p > 0.0 {
                let ratio = dn / (*p + EPS);
                let eta = gamma / ratio.max(gamma);
                *p = eta * dn;
                eta
            } else {
                *p = dn;
                1.0
            }
        })
        .collect();
    Mat::from_fn(delta.rows, delta.cols, |i, j| delta.at(i, j) * etas[j])
}

/// Bias-correction denominators (1 - βᵗ).
pub fn bias_corr(hp: &Hyper, t: u64) -> (f32, f32) {
    if !hp.bias_correction {
        return (1.0, 1.0);
    }
    let t = t as f32;
    (1.0 - hp.b1.powf(t), 1.0 - hp.b2.powf(t))
}

/// Optimizer interface over a single 2-D parameter.
pub trait Optimizer: Send + Sync {
    fn name(&self) -> &'static str;

    /// Fresh state for an (already orientation-normalized) rows x cols
    /// parameter.
    fn init(&self, rows: usize, cols: usize) -> State;

    /// One step: gradient → descent direction (trainer applies W -= lr·Δ).
    /// `t` is the 1-based step counter.
    fn step(&self, g: &Mat, state: &mut State, t: u64) -> Mat;

    /// Projection / eigenbasis refresh — called by the coordinator every
    /// `interval` steps (and at t == 1). Default: no-op.
    fn refresh(&self, _g: &Mat, _state: &mut State, _seed: u64) {}

    fn has_refresh(&self) -> bool {
        false
    }

    /// Whether wide matrices (rows > cols) should be transposed before
    /// `init`/`step` so the projection side is the short one (paper m ≤ n).
    fn transpose_wide(&self) -> bool {
        false
    }

    /// Whether this is a low-rank method (GaLore lineage / Alice). The
    /// single source of truth for the paper's Ppl vs Ppl* lm-head
    /// protocol — routing and the benches query the registry instead of
    /// keeping name lists.
    fn low_rank(&self) -> bool {
        false
    }

    /// Analytic state-size in elements for Table 1 / Table 3 (must agree
    /// with `State::elems()` of `init` — property-tested).
    fn state_elems(&self, rows: usize, cols: usize) -> u64;
}

/// Orientation-aware wrapper: handles the transpose_wide protocol.
///
/// **Per-parameter independence contract:** a `Slot` owns *all* mutable
/// state its optimizer touches — `step`/`refresh` read the passed
/// gradient and this slot's `State`, and nothing else (randomness enters
/// only through the caller-supplied refresh seed). Updates to different
/// parameters therefore commute bitwise: the trainer's per-layer fan-out
/// and the pipelined fold+update fusion (`[dist] round = "pipelined"`)
/// may run slots in any order, on any thread, and produce the exact bits
/// of the parameter-ordered serial loop. Pinned by
/// `slot_updates_commute_across_parameters` below.
pub struct Slot {
    pub opt: Box<dyn Optimizer>,
    pub state: State,
    transposed: bool,
}

impl Slot {
    pub fn new(opt: Box<dyn Optimizer>, rows: usize, cols: usize) -> Self {
        let transposed = opt.transpose_wide() && rows > cols;
        let (r, c) = if transposed { (cols, rows) } else { (rows, cols) };
        let state = opt.init(r, c);
        Slot { opt, state, transposed }
    }

    pub fn step(&mut self, g: &Mat, t: u64) -> Mat {
        if self.transposed {
            let gt = g.transpose();
            self.opt.step(&gt, &mut self.state, t).transpose()
        } else {
            self.opt.step(g, &mut self.state, t)
        }
    }

    pub fn refresh(&mut self, g: &Mat, seed: u64) {
        if !self.opt.has_refresh() {
            return;
        }
        if self.transposed {
            let gt = g.transpose();
            self.opt.refresh(&gt, &mut self.state, seed);
        } else {
            self.opt.refresh(g, &mut self.state, seed);
        }
    }

    pub fn state_elems(&self) -> u64 {
        self.state.elems()
    }
}

/// Factory: name → optimizer instance. The single registry shared by the
/// trainer, the benches, and the CLI.
pub fn build(name: &str, hp: &Hyper) -> Result<Box<dyn Optimizer>> {
    let hp = hp.clone();
    Ok(match name {
        "sgd" => Box::new(simple::Sgd { hp }),
        "adam" => Box::new(simple::Adam { hp }),
        "adafactor" => Box::new(simple::Adafactor { hp }),
        "lion" => Box::new(simple::Lion { hp }),
        "signum" => Box::new(simple::Signum { hp }),
        "muon" => Box::new(whiten_ops::Muon { hp }),
        "swan" => Box::new(whiten_ops::Swan { hp }),
        "racs" => Box::new(racs::Racs { hp }),
        "eigen_adam" => Box::new(eigen::EigenAdam { hp }),
        "shampoo" => Box::new(eigen::Shampoo { hp }),
        "soap" => Box::new(eigen::Soap { hp }),
        "galore" => Box::new(lowrank::GaLore { hp }),
        "fira" => Box::new(lowrank::Fira { hp }),
        "apollo_mini" => Box::new(lowrank::ApolloMini { hp }),
        // "alice" honors hp.tracking (default true) so the Table 5 /
        // Fig. 5(a) / Fig. 6 ablations can toggle it; "alice0" pins it off.
        "alice" => Box::new(alice::Alice { hp }),
        "alice0" => Box::new(alice::Alice { hp: Hyper { tracking: false, ..hp } }),
        _ => return Err(anyhow!("unknown optimizer {name:?}")),
    })
}

/// All registry names (bench sweeps iterate this).
pub const ALL: [&str; 16] = [
    "sgd", "adam", "adafactor", "lion", "signum", "muon", "swan", "racs",
    "eigen_adam", "shampoo", "soap", "galore", "fira", "apollo_mini",
    "alice", "alice0",
];

/// Registry query: is `name` a low-rank method? (See
/// [`Optimizer::low_rank`].)
pub fn is_low_rank(name: &str, hp: &Hyper) -> Result<bool> {
    Ok(build(name, hp)?.low_rank())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg;

    #[test]
    fn registry_builds_all() {
        let hp = Hyper::default();
        for name in ALL {
            let opt = build(name, &hp).unwrap();
            assert_eq!(opt.name(), name);
        }
        assert!(build("nope", &hp).is_err());
    }

    #[test]
    fn low_rank_flag_matches_lineage() {
        let hp = Hyper::default();
        for name in ["galore", "fira", "apollo_mini", "alice", "alice0"] {
            assert!(is_low_rank(name, &hp).unwrap(), "{name}");
        }
        for name in ["sgd", "adam", "muon", "racs", "shampoo", "soap", "eigen_adam"] {
            assert!(!is_low_rank(name, &hp).unwrap(), "{name}");
        }
        assert!(is_low_rank("nope", &hp).is_err());
    }

    #[test]
    fn every_optimizer_runs_and_matches_state_accounting() {
        let hp = Hyper { rank: 8, leading: 3, interval: 10, ..Hyper::default() };
        let mut rng = Pcg::seeded(42);
        for name in ALL {
            for (r, c) in [(24, 40), (40, 24)] {
                let opt = build(name, &hp).unwrap();
                let mut slot = Slot::new(opt, r, c);
                let g = Mat::from_vec(r, c, rng.normal_vec(r * c, 0.1));
                slot.refresh(&g, 1);
                let d = slot.step(&g, 1);
                assert_eq!((d.rows, d.cols), (r, c), "{name}");
                assert!(d.is_finite(), "{name} produced non-finite update");
                let (er, ec) = if slot.transposed { (c, r) } else { (r, c) };
                assert_eq!(
                    slot.state.elems(),
                    slot.opt.state_elems(er, ec),
                    "{name}: state_elems formula disagrees with actual state"
                );
            }
        }
    }

    #[test]
    fn refresh_parse_roundtrip() {
        assert_eq!(Refresh::parse("exact").unwrap(), Refresh::Exact);
        assert_eq!(Refresh::parse("sketch").unwrap(), Refresh::Sketch);
        assert!(Refresh::parse("approx").is_err());
    }

    #[test]
    fn sketch_anchor_cadence() {
        let mut st = State::default();
        st.scalars.insert("rc", 0.0);
        // anchor_every = 2: refreshes 0, 2, 4 anchor; 1, 3 sketch
        let due: Vec<bool> = (0..5).map(|_| sketch_anchor_due(&mut st, 2)).collect();
        assert_eq!(due, [true, false, true, false, true]);
        assert_eq!(st.scalar("rc"), 5.0);
        // anchor_every = 0: only the very first refresh anchors
        let mut st0 = State::default();
        st0.scalars.insert("rc", 0.0);
        let due0: Vec<bool> = (0..4).map(|_| sketch_anchor_due(&mut st0, 0)).collect();
        assert_eq!(due0, [true, false, false, false]);
    }

    #[test]
    fn sketch_mode_runs_and_matches_state_accounting() {
        // the sketch-capable registry entries, through the Slot
        // orientation wrapper, past the first (anchor) refresh and onto
        // the sketch path proper
        let hp = Hyper {
            rank: 8,
            leading: 3,
            interval: 10,
            refresh: Refresh::Sketch,
            refresh_anchor_every: 2,
            ..Hyper::default()
        };
        let mut rng = Pcg::seeded(43);
        for name in ["alice", "alice0", "eigen_adam", "soap"] {
            for (r, c) in [(24, 40), (40, 24)] {
                let opt = build(name, &hp).unwrap();
                let mut slot = Slot::new(opt, r, c);
                for t in 1..=2 {
                    let g = Mat::from_vec(r, c, rng.normal_vec(r * c, 0.1));
                    slot.refresh(&g, t as u64);
                    let d = slot.step(&g, t as u64);
                    assert_eq!((d.rows, d.cols), (r, c), "{name}");
                    assert!(d.is_finite(), "{name} t={t} non-finite update");
                }
                let (er, ec) = if slot.transposed { (c, r) } else { (r, c) };
                assert_eq!(
                    slot.state.elems(),
                    slot.opt.state_elems(er, ec),
                    "{name}: sketch-mode accounting disagrees"
                );
            }
        }
    }

    #[test]
    fn slot_updates_commute_across_parameters() {
        // the independence contract the pipelined fan-out rests on:
        // updating slots in a scrambled order must reproduce the ordered
        // loop bit for bit, for a stateful low-rank method with refresh
        let hp = Hyper { rank: 4, leading: 2, interval: 10, ..Hyper::default() };
        let geoms = [(10usize, 6usize), (6, 12), (3, 8)];
        let mut rng = Pcg::seeded(7);
        let grads: Vec<Mat> = geoms
            .iter()
            .map(|&(r, c)| Mat::from_vec(r, c, rng.normal_vec(r * c, 0.1)))
            .collect();
        let run = |order: &[usize]| -> Vec<Vec<u32>> {
            let mut slots: Vec<Slot> = geoms
                .iter()
                .map(|&(r, c)| Slot::new(build("alice", &hp).unwrap(), r, c))
                .collect();
            let mut deltas: Vec<Vec<u32>> = vec![Vec::new(); geoms.len()];
            for t in 1..=3u64 {
                for &p in order {
                    if t == 1 {
                        slots[p].refresh(&grads[p], 0xfeed ^ p as u64);
                    }
                    let d = slots[p].step(&grads[p], t);
                    deltas[p] = d.data.iter().map(|x| x.to_bits()).collect();
                }
            }
            deltas
        };
        assert_eq!(run(&[0, 1, 2]), run(&[2, 0, 1]));
    }

    #[test]
    fn limiter_caps_growth() {
        let big = Mat::from_vec(1, 2, vec![30.0, 40.0]); // norm 50
        let (d1, phi) = limiter(big.clone(), 0.0, 1.01);
        assert!((phi - 50.0).abs() < 1e-3);
        assert_eq!(d1.data, big.data); // first step passes through
        let bigger = Mat::from_vec(1, 2, vec![60.0, 80.0]); // norm 100
        let (d2, phi2) = limiter(bigger, phi, 1.01);
        // capped to gamma * previous phi
        assert!((d2.fro_norm() - 1.01 * 50.0).abs() < 0.5);
        assert!(phi2 <= 1.01 * 50.0 + 0.5);
    }

    #[test]
    fn limiter_cols_caps_each_column_independently() {
        // col 0 norm 50, col 1 norm 3: first step passes both through
        let d1 = Mat::from_vec(2, 2, vec![30.0, 3.0, 40.0, 0.0]);
        let mut phi = vec![0.0f32; 2];
        let out1 = limiter_cols(&d1, &mut phi, 1.01);
        assert_eq!(out1.data, d1.data, "first sight passes through");
        assert!((phi[0] - 50.0).abs() < 1e-2 && (phi[1] - 3.0).abs() < 1e-2);
        // col 0 doubles (capped at gamma·φ₀), col 1 shrinks (passes) —
        // the global limiter would have scaled both by one factor
        let d2 = Mat::from_vec(2, 2, vec![60.0, 1.0, 80.0, 0.0]);
        let out2 = limiter_cols(&d2, &mut phi, 1.01);
        let n0 = (out2.at(0, 0).powi(2) + out2.at(1, 0).powi(2)).sqrt();
        let n1 = (out2.at(0, 1).powi(2) + out2.at(1, 1).powi(2)).sqrt();
        assert!((n0 - 1.01 * 50.0).abs() < 0.5, "col 0 capped, got {n0}");
        assert!((n1 - 1.0).abs() < 1e-3, "col 1 must pass untouched, got {n1}");
        assert!(phi[0] <= 1.01 * 50.0 + 0.5);
        assert!((phi[1] - 1.0).abs() < 1e-2);
    }

    #[test]
    fn bias_corr_values() {
        let hp = Hyper::default();
        let (a, b) = bias_corr(&hp, 1);
        assert!((a - 0.1).abs() < 1e-6);
        assert!((b - 0.001).abs() < 1e-7);
        let hp2 = Hyper { bias_correction: false, ..hp };
        assert_eq!(bias_corr(&hp2, 5), (1.0, 1.0));
    }
}
