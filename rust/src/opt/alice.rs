//! Alice / Alice-0 — the paper's low-rank extension of Eigen-Adam
//! (Sec. 5, Algorithm 4), built from the three framework steps:
//!
//! * **tracking**   — Q̃ EMA of the projected σσᵀ (Eq. 17), r² state
//!   (disabled for Alice-0 via `hp.tracking = false`);
//! * **switching**  — Algorithm 2: mix the leading eigenbasis with columns
//!   sampled from the orthogonal complement (Prop. 4 motivates why);
//! * **compensation** — Theorem 5.1's optimal column scaling of the
//!   projector residual (Algorithm 3), turning the low-rank update
//!   full-rank. The Fig. 5(c) ablation arms are all distinct: `None`,
//!   `Fira` (global norm limiter), `FiraPlus` (per-column norm limiter,
//!   per the Fira paper), `Optimal` (Thm 5.1).
//!
//! Instrumentation: each refresh records per-index cosine similarity
//! between old and new basis columns into `state.vecs["diag_cos"]` — the
//! data behind Fig. 6.

use crate::linalg::{complete_basis, simd, sketched_eigh, subspace_iter, Mat};
use crate::util::Pcg;

use super::{
    bias_corr, limiter, limiter_cols, lowrank::eff_rank, sketch_anchor_due, Compen,
    Hyper, Optimizer, Refresh, State, Switch, EPS,
};

pub struct Alice {
    pub hp: Hyper,
}

impl Alice {
    fn compensation(
        &self,
        g: &Mat,
        u: &Mat,
        sigma: &Mat,
        state: &mut State,
        t: u64,
    ) -> Mat {
        let hp = &self.hp;
        match hp.compen {
            Compen::None => Mat::zeros(g.rows, g.cols),
            Compen::Fira => {
                let resid = g.sub(&u.matmul(sigma));
                let scale = 1.0 / (sigma.fro_norm() + EPS);
                let (c, phi) =
                    limiter(resid.scale(scale), state.scalar("phi"), hp.gamma);
                state.scalars.insert("phi", phi);
                c
            }
            Compen::FiraPlus => {
                // Fira's norm-based scaling applied per column (the Fira
                // paper's column-wise limiter): column j of the residual
                // is scaled by 1/‖σⱼ‖ and growth-capped independently —
                // previously this arm collapsed onto Fira, flattening the
                // Fig. 5(c) ablation axis (ISSUE 5).
                let resid = g.sub(&u.matmul(sigma));
                let s_col = sigma.col_sq_norms();
                let scaled = Mat::from_fn(resid.rows, resid.cols, |i, j| {
                    resid.at(i, j) / (s_col[j].sqrt() + EPS)
                });
                let mut phi = state.vecs.remove("phi_col").expect("fira_plus phi_col state");
                let c = limiter_cols(&scaled, &mut phi, hp.gamma);
                state.vecs.insert("phi_col", phi);
                c
            }
            Compen::Optimal => {
                // Alg. 3: p ← β₁ p + (1-β₁)(1ₘᵀG⊙² − 1ᵣᵀσ⊙²)
                let g_col = g.col_sq_norms();
                let s_col = sigma.col_sq_norms();
                let b = if t <= 1 { 0.0 } else { hp.b1 };
                let p = state.vecs.get_mut("p").unwrap();
                for ((pi, &gc), &sc) in p.iter_mut().zip(&g_col).zip(&s_col) {
                    *pi = b * *pi + (1.0 - b) * (gc - sc);
                }
                let p = p.clone();
                let m_rows = g.rows;
                let r = sigma.rows;
                let scale = ((m_rows - r).max(1) as f32).sqrt();
                let resid = g.sub(&u.matmul(sigma));
                let c = Mat::from_fn(g.rows, g.cols, |i, j| {
                    scale * resid.at(i, j)
                        / (p[j].max(0.0) + EPS).sqrt()
                });
                let (c, phi) = limiter(c, state.scalar("phi"), hp.gamma);
                state.scalars.insert("phi", phi);
                c
            }
        }
    }

    /// Per-refresh RNG — one stream per (seed), drawn serially on the
    /// refreshing thread so both refresh modes stay width-invariant.
    fn switch_rng(seed: u64) -> Pcg {
        Pcg::seeded(seed.wrapping_mul(0x2545f491).wrapping_add(7))
    }

    /// m×k Gaussian block with unit column norms (paper's Gaussian
    /// ablation setup, App. F.7) — also the GaussianMix tail.
    fn gaussian_cols(m: usize, k: usize, rng: &mut Pcg) -> Mat {
        let mut u = Mat::from_vec(m, k, rng.normal_vec(m * k, 1.0));
        for j in 0..k {
            let nrm: f32 =
                (0..m).map(|i| u.at(i, j).powi(2)).sum::<f32>().sqrt() + EPS;
            for i in 0..m {
                *u.at_mut(i, j) /= nrm;
            }
        }
        u
    }

    /// Algorithm 2's mixing step over an already-refreshed leading basis:
    /// keep the `leading` columns, resample the tail per the Fig. 5(b)
    /// strategy. Shared verbatim by the exact and sketch refresh paths
    /// (same RNG draw order, so the exact path is bitwise unchanged).
    fn mix_switched(&self, u_new: Mat, rng: &mut Pcg) -> Mat {
        let hp = &self.hp;
        let m = u_new.rows;
        let r = u_new.cols;
        let l = hp.leading.min(r);
        if hp.switch == Switch::Evd || r == l || m == r {
            return u_new;
        }
        let top = u_new.take_cols(l);
        match hp.switch {
            Switch::GaussianMix => top.hcat(&Self::gaussian_cols(m, r - l, rng)),
            Switch::FullBasis => {
                let u_c = complete_basis(&u_new);
                let tail = Mat::from_fn(m, r - l, |i, j| u_new.at(i, j + l));
                let pool = tail.hcat(&u_c); // m x (m - l)
                let mut idx: Vec<usize> = (0..pool.cols).collect();
                rng.shuffle(&mut idx);
                let picked =
                    Mat::from_fn(m, r - l, |i, j| pool.at(i, idx[j]));
                top.hcat(&picked)
            }
            _ => {
                // the paper's strategy: sample ONLY from the complement
                let u_c = complete_basis(&u_new);
                let mut idx: Vec<usize> = (0..u_c.cols).collect();
                rng.shuffle(&mut idx);
                let picked =
                    Mat::from_fn(m, r - l, |i, j| u_c.at(i, idx[j]));
                top.hcat(&picked)
            }
        }
    }

    /// Algorithm 2 + the Fig. 5(b) strategy ablations (exact path).
    fn switch(&self, q_rec: &Mat, u_prev: &Mat, seed: u64) -> Mat {
        let hp = &self.hp;
        let mut rng = Self::switch_rng(seed);
        if hp.switch == Switch::Gaussian {
            return Self::gaussian_cols(q_rec.rows, u_prev.cols, &mut rng);
        }
        let (u_new, _) = subspace_iter(q_rec, u_prev, hp.sub_iters);
        self.mix_switched(u_new, &mut rng)
    }

    /// Sketched refresh (ISSUE 6): the reconstruction is applied as an
    /// operator X ↦ β₃·U(Q̃(UᵀX)) + (1−β₃)·G(GᵀX) on n×s blocks — no
    /// GGᵀ, no m×m reconstruction, ever. Cost O(m·n·s·(q+2)) against the
    /// exact path's O(m²·n + sweeps·m³).
    fn sketch_switch(&self, g: &Mat, u_prev: &Mat, qt: Option<&Mat>, seed: u64) -> Mat {
        let hp = &self.hp;
        let mut rng = Self::switch_rng(seed);
        if hp.switch == Switch::Gaussian {
            return Self::gaussian_cols(g.rows, u_prev.cols, &mut rng);
        }
        let apply = |x: &Mat| -> Mat {
            let low = g.matmul(&g.matmul_tn(x));
            match qt {
                Some(qt) => u_prev
                    .matmul(&qt.matmul(&u_prev.matmul_tn(x)))
                    .scale(hp.b3)
                    .add(&low.scale(1.0 - hp.b3)),
                None => low,
            }
        };
        // rank pinned to the stored basis width (eff_rank may clamp on
        // the column side, which sketch_spec's n-only clamp cannot see)
        let spec = crate::linalg::SketchSpec {
            rank: u_prev.cols,
            ..hp.sketch_spec(g.rows)
        };
        let (u_new, _) = sketched_eigh(g.rows, &apply, Some(u_prev), &spec, seed);
        self.mix_switched(u_new, &mut rng)
    }
}

impl Optimizer for Alice {
    fn name(&self) -> &'static str {
        if self.hp.tracking {
            "alice"
        } else {
            "alice0"
        }
    }

    fn init(&self, rows: usize, cols: usize) -> State {
        let r = eff_rank(&self.hp, rows, cols);
        let mut st = State::default();
        st.mats.insert(
            "u",
            Mat::from_fn(rows, r, |i, j| if i == j { 1.0 } else { 0.0 }),
        );
        if self.hp.tracking {
            st.mats.insert("qt", Mat::zeros(r, r));
        }
        st.mats.insert("m", Mat::zeros(r, cols));
        st.mats.insert("v", Mat::zeros(r, cols));
        st.vecs.insert("p", vec![0.0; cols]);
        st.scalars.insert("phi", 0.0);
        if self.hp.compen == Compen::FiraPlus {
            // per-column limiter state (one φ per column)
            st.vecs.insert("phi_col", vec![0.0; cols]);
        }
        if self.hp.refresh == Refresh::Sketch {
            // per-slot refresh counter driving the exact-anchor cadence
            st.scalars.insert("rc", 0.0);
        }
        st
    }

    /// Algorithm 4 lines 11-17.
    fn step(&self, g: &Mat, state: &mut State, t: u64) -> Mat {
        let hp = &self.hp;
        let u = state.mat("u").clone();
        let sigma = u.matmul_tn(g);
        if hp.tracking {
            let sst = sigma.matmul_nt(&sigma);
            state.mats.get_mut("qt").unwrap().ema_(hp.b3, &sst, 1.0 - hp.b3);
        }
        state.mats.get_mut("m").unwrap().ema_(hp.b1, &sigma, 1.0 - hp.b1);
        let v = state.mats.get_mut("v").unwrap();
        for (vi, &si) in v.data.iter_mut().zip(&sigma.data) {
            *vi = hp.b2 * *vi + (1.0 - hp.b2) * si * si;
        }
        let (bc1, bc2) = bias_corr(hp, t);
        let m = state.mat("m");
        let v = state.mat("v");
        let omega = Mat::from_fn(sigma.rows, sigma.cols, |i, j| {
            (m.at(i, j) / bc1) / ((v.at(i, j) / bc2).sqrt() + hp.eps)
        });
        let comp = self.compensation(g, &u, &sigma, state, t);
        u.matmul(&omega)
            .add(&comp.scale(hp.alpha_c))
            .scale(hp.alpha)
    }

    /// Algorithm 4 lines 6-7: reconstruct Q, switch basis. In sketch mode
    /// (ISSUE 6) the reconstruction stays an operator — no GGᵀ is formed —
    /// except on the `refresh_anchor_every`-th anchor refreshes, which run
    /// the exact path to pin accumulated sketch drift. Records Fig. 6
    /// cosine diagnostics either way.
    fn refresh(&self, g: &Mat, state: &mut State, seed: u64) {
        let hp = &self.hp;
        let u = state.mat("u").clone();
        let sketch = hp.refresh == Refresh::Sketch
            && !sketch_anchor_due(state, hp.refresh_anchor_every);
        let u_new = if sketch {
            let qt = if hp.tracking { Some(state.mat("qt").clone()) } else { None };
            self.sketch_switch(g, &u, qt.as_ref(), seed)
        } else {
            let ggt = g.matmul_nt(g);
            let q_rec = if hp.tracking {
                // β₃ U Q̃ Uᵀ + (1-β₃) G Gᵀ
                let uq = u.matmul(state.mat("qt"));
                let rec = uq.matmul_nt(&u);
                rec.scale(hp.b3).add(&ggt.scale(1.0 - hp.b3))
            } else {
                ggt
            };
            self.switch(&q_rec, &u, seed)
        };
        // Fig. 6 instrumentation: cos∠(uᵢ, uᵢ') per index, through the
        // simd strided-gather + dot/sum_sq kernels.
        let r = u.cols.min(u_new.cols);
        let cos: Vec<f32> = (0..r)
            .map(|j| {
                let a = u.col_vec(j);
                let b = u_new.col_vec(j);
                let dot = simd::dot(&a, &b);
                let na = simd::sum_sq(&a).sqrt();
                let nb = simd::sum_sq(&b).sqrt();
                (dot / (na * nb + EPS)).abs()
            })
            .collect();
        state.vecs.insert("diag_cos", cos);
        state.mats.insert("u", u_new);
    }

    fn has_refresh(&self) -> bool {
        true
    }

    fn transpose_wide(&self) -> bool {
        true
    }

    fn low_rank(&self) -> bool {
        true
    }

    fn state_elems(&self, rows: usize, cols: usize) -> u64 {
        let r = eff_rank(&self.hp, rows, cols);
        let tracking = if self.hp.tracking { (r * r) as u64 } else { 0 };
        // FiraPlus carries one φ slot per column instead of the scalar
        let fira_plus =
            if self.hp.compen == Compen::FiraPlus { cols as u64 } else { 0 };
        // sketch mode carries the anchor-cadence refresh counter
        let sketch = if self.hp.refresh == Refresh::Sketch { 1 } else { 0 };
        // u + m + v + p + phi (+ Q̃) (+ phi_col) (+ rc); diag_cos only
        // exists post-refresh
        (rows * r + 2 * r * cols + cols + 1) as u64 + tracking + fira_plus + sketch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grad(seed: u64, m: usize, n: usize) -> Mat {
        let mut rng = Pcg::seeded(seed);
        Mat::from_vec(m, n, rng.normal_vec(m * n, 1.0))
    }

    fn alice(hp: Hyper) -> Alice {
        Alice { hp }
    }

    #[test]
    fn update_is_full_rank_with_compensation() {
        let hp = Hyper { rank: 4, leading: 2, ..Hyper::alice_defaults() };
        let a = alice(hp);
        let mut st = a.init(12, 16);
        let g = grad(40, 12, 16);
        a.refresh(&g, &mut st, 1);
        let d = a.step(&g, &mut st, 1);
        let u = st.mat("u");
        let resid = d.sub(&u.matmul(&u.matmul_tn(&d)));
        assert!(resid.fro_norm() > 1e-4, "compensation must add rank");
    }

    #[test]
    fn no_compensation_stays_in_subspace() {
        let hp = Hyper {
            rank: 4,
            leading: 2,
            compen: Compen::None,
            ..Hyper::alice_defaults()
        };
        let a = alice(hp);
        let mut st = a.init(12, 16);
        let g = grad(41, 12, 16);
        a.refresh(&g, &mut st, 1);
        let d = a.step(&g, &mut st, 1);
        let u = st.mat("u");
        let resid = d.sub(&u.matmul(&u.matmul_tn(&d)));
        assert!(resid.max_abs() < 1e-3);
    }

    #[test]
    fn switching_output_is_orthonormal_for_every_strategy() {
        // Orthogonal-by-construction strategies must give exactly
        // orthonormal bases; the Gaussian ones only guarantee unit columns
        // (that overlap is the paper's explanation for their worse
        // performance, Sec. 7.2).
        for sw in [Switch::Switch, Switch::Evd, Switch::FullBasis] {
            let hp = Hyper { rank: 5, leading: 2, switch: sw,
                             ..Hyper::alice_defaults() };
            let a = alice(hp);
            let mut st = a.init(14, 18);
            let g = grad(42, 14, 18);
            a.refresh(&g, &mut st, 9);
            let u = st.mat("u");
            let err = u.matmul_tn(u).sub(&Mat::eye(u.cols)).max_abs();
            assert!(err < 1e-3, "{sw:?}: orthonormality err {err}");
        }
        for sw in [Switch::Gaussian, Switch::GaussianMix] {
            let hp = Hyper { rank: 5, leading: 2, switch: sw,
                             ..Hyper::alice_defaults() };
            let a = alice(hp);
            let mut st = a.init(14, 18);
            let g = grad(42, 14, 18);
            a.refresh(&g, &mut st, 9);
            let u = st.mat("u");
            for j in 0..u.cols {
                let nrm: f32 =
                    (0..u.rows).map(|i| u.at(i, j).powi(2)).sum::<f32>();
                assert!((nrm - 1.0).abs() < 1e-3, "{sw:?}: column norm {nrm}");
            }
        }
    }

    #[test]
    fn every_compensation_variant_is_distinct() {
        // the Fig. 5(c) axis: all four arms must produce different
        // updates on the same gradient (Fira and FiraPlus used to share
        // one arm — ISSUE 5)
        let variants =
            [Compen::None, Compen::Fira, Compen::FiraPlus, Compen::Optimal];
        let g = grad(77, 12, 16);
        let updates: Vec<Mat> = variants
            .iter()
            .map(|&compen| {
                let a = alice(Hyper {
                    rank: 4,
                    leading: 2,
                    compen,
                    ..Hyper::alice_defaults()
                });
                let mut st = a.init(12, 16);
                a.refresh(&g, &mut st, 1); // same seed → same basis for all
                a.step(&g, &mut st, 1)
            })
            .collect();
        for i in 0..variants.len() {
            for j in (i + 1)..variants.len() {
                let diff = updates[i].sub(&updates[j]).max_abs();
                assert!(
                    diff > 1e-5,
                    "{:?} vs {:?} produced identical updates (diff {diff})",
                    variants[i],
                    variants[j]
                );
            }
        }
    }

    #[test]
    fn fira_plus_state_accounting_and_capping() {
        let hp = Hyper {
            rank: 4,
            leading: 2,
            compen: Compen::FiraPlus,
            ..Hyper::alice_defaults()
        };
        let a = alice(hp);
        let mut st = a.init(12, 16);
        assert_eq!(st.vec("phi_col").len(), 16);
        assert_eq!(st.elems(), a.state_elems(12, 16));
        // per-column phi fills in on the first step and caps afterwards
        let g = grad(78, 12, 16);
        a.refresh(&g, &mut st, 1);
        a.step(&g, &mut st, 1);
        assert!(st.vec("phi_col").iter().all(|&p| p > 0.0));
        let d2 = a.step(&g.scale(100.0), &mut st, 2);
        assert!(d2.is_finite(), "capped compensation must stay finite");
    }

    #[test]
    fn refresh_records_cosine_diagnostics() {
        let hp = Hyper { rank: 4, leading: 2, ..Hyper::alice_defaults() };
        let a = alice(hp);
        let mut st = a.init(10, 12);
        let g = grad(43, 10, 12);
        a.step(&g, &mut st, 1);
        a.refresh(&g, &mut st, 5);
        let cos = st.vec("diag_cos");
        assert_eq!(cos.len(), 4);
        assert!(cos.iter().all(|c| (0.0..=1.0 + 1e-4).contains(c)));
    }

    #[test]
    fn alice0_has_no_tracking_state() {
        let hp = Hyper { rank: 4, tracking: false, ..Hyper::alice_defaults() };
        let a = alice(hp);
        let st = a.init(10, 12);
        assert!(!st.mats.contains_key("qt"));
        assert_eq!(a.name(), "alice0");
    }

    #[test]
    fn sketch_refresh_is_orthonormal_and_accounts_state() {
        for tracking in [true, false] {
            let hp = Hyper {
                rank: 5,
                leading: 2,
                tracking,
                refresh: Refresh::Sketch,
                refresh_anchor_every: 4,
                ..Hyper::alice_defaults()
            };
            let a = alice(hp);
            let mut st = a.init(14, 18);
            assert_eq!(st.elems(), a.state_elems(14, 18), "rc must be counted");
            for t in 1..=3 {
                let g = grad(300 + t, 14, 18);
                a.refresh(&g, &mut st, t); // t=1 anchors, 2-3 sketch
                a.step(&g, &mut st, t);
                let u = st.mat("u");
                let err = u.matmul_tn(u).sub(&Mat::eye(u.cols)).max_abs();
                assert!(err < 1e-3, "tracking={tracking} t={t}: ortho err {err}");
            }
            assert_eq!(st.scalar("rc"), 3.0, "refresh counter must advance");
            assert_eq!(st.elems(), a.state_elems(14, 18));
        }
    }

    #[test]
    fn anchor_every_refresh_reproduces_exact_path_bitwise() {
        // anchor_every = 1 → every refresh is an exact anchor, so the
        // sketch configuration must match the exact configuration bitwise
        let mk = |refresh, anchor| {
            alice(Hyper {
                rank: 4,
                leading: 2,
                refresh,
                refresh_anchor_every: anchor,
                ..Hyper::alice_defaults()
            })
        };
        let (ax, ask) = (mk(Refresh::Exact, 8), mk(Refresh::Sketch, 1));
        let mut sx = ax.init(12, 16);
        let mut ss = ask.init(12, 16);
        for t in 1..=3 {
            let g = grad(400 + t, 12, 16);
            ax.refresh(&g, &mut sx, t);
            ask.refresh(&g, &mut ss, t);
            assert_eq!(
                sx.mat("u").data,
                ss.mat("u").data,
                "anchored refresh must be the exact path, t={t}"
            );
            ax.step(&g, &mut sx, t);
            ask.step(&g, &mut ss, t);
        }
        // while anchor_every = 4 diverges onto the sketch path at t = 2
        let ask2 = mk(Refresh::Sketch, 4);
        let mut s2 = ask2.init(12, 16);
        let mut sx2 = ax.init(12, 16);
        for t in 1..=2 {
            let g = grad(400 + t, 12, 16);
            ax.refresh(&g, &mut sx2, t);
            ask2.refresh(&g, &mut s2, t);
            ax.step(&g, &mut sx2, t);
            ask2.step(&g, &mut s2, t);
        }
        assert_ne!(
            sx2.mat("u").data,
            s2.mat("u").data,
            "second refresh must take the sketch path"
        );
    }

    #[test]
    fn tracking_changes_refresh_basis() {
        // With tracking, the reconstructed Q mixes history ⇒ different U
        // than Alice-0's pure GGᵀ refresh (the Fig. 5(a) mechanism).
        let mk = |tracking| {
            Alice { hp: Hyper { rank: 4, leading: 4, switch: Switch::Evd,
                                tracking, ..Hyper::alice_defaults() } }
        };
        let (a1, a0) = (mk(true), mk(false));
        let mut s1 = a1.init(10, 12);
        let mut s0 = a0.init(10, 12);
        for t in 1..=6 {
            let g = grad(100 + t, 10, 12);
            a1.step(&g, &mut s1, t);
            a0.step(&g, &mut s0, t);
        }
        let g = grad(200, 10, 12);
        a1.refresh(&g, &mut s1, 3);
        a0.refresh(&g, &mut s0, 3);
        let diff = s1.mat("u").sub(s0.mat("u")).max_abs();
        assert!(diff > 1e-4, "tracking should alter the refreshed basis");
    }
}
