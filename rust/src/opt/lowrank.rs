//! Low-rank optimizers: GaLore (App. B.11 / Alg. 8), Fira (its
//! compensated extension), Apollo-mini (App. B.12 / Alg. 9).
//!
//! The paper's Sec. 5.4 observation — GaLore is Alice without tracking,
//! switching, and compensation — is validated as an integration test
//! (`rust/tests/optimizer_semantics.rs`).

use crate::linalg::{subspace_iter, Mat};
use crate::util::Pcg;

use super::{bias_corr, limiter, Hyper, Optimizer, State, EPS};

pub(crate) fn eff_rank(hp: &Hyper, rows: usize, cols: usize) -> usize {
    hp.rank.clamp(1, rows.min(cols))
}

fn adam_on(
    sigma: &Mat,
    m: &mut Mat,
    v: &mut Mat,
    hp: &Hyper,
    t: u64,
) -> Mat {
    m.ema_(hp.b1, sigma, 1.0 - hp.b1);
    for (vi, &si) in v.data.iter_mut().zip(&sigma.data) {
        *vi = hp.b2 * *vi + (1.0 - hp.b2) * si * si;
    }
    let (bc1, bc2) = bias_corr(hp, t);
    Mat::from_fn(sigma.rows, sigma.cols, |i, j| {
        (m.at(i, j) / bc1) / ((v.at(i, j) / bc2).sqrt() + hp.eps)
    })
}

/// Identity-prefix initial projection (matches the python twin: the first
/// refresh at t == 1 replaces it with the data-driven basis).
fn init_proj(rows: usize, r: usize) -> Mat {
    Mat::from_fn(rows, r, |i, j| if i == j { 1.0 } else { 0.0 })
}

// --------------------------------------------------------------- GaLore ----
pub struct GaLore {
    pub hp: Hyper,
}

impl Optimizer for GaLore {
    fn name(&self) -> &'static str {
        "galore"
    }

    fn init(&self, rows: usize, cols: usize) -> State {
        let r = eff_rank(&self.hp, rows, cols);
        let mut st = State::default();
        st.mats.insert("u", init_proj(rows, r));
        st.mats.insert("m", Mat::zeros(r, cols));
        st.mats.insert("v", Mat::zeros(r, cols));
        st
    }

    fn step(&self, g: &Mat, state: &mut State, t: u64) -> Mat {
        let hp = &self.hp;
        let u = state.mat("u").clone();
        let sigma = u.matmul_tn(g);
        let mut m = state.mats.remove("m").unwrap();
        let mut v = state.mats.remove("v").unwrap();
        let omega = adam_on(&sigma, &mut m, &mut v, hp, t);
        state.mats.insert("m", m);
        state.mats.insert("v", v);
        u.matmul(&omega).scale(hp.alpha)
    }

    fn refresh(&self, g: &Mat, state: &mut State, _seed: u64) {
        let q = g.matmul_nt(g);
        let (u, _) = subspace_iter(&q, state.mat("u"), self.hp.sub_iters);
        state.mats.insert("u", u);
    }

    fn has_refresh(&self) -> bool {
        true
    }

    fn transpose_wide(&self) -> bool {
        true
    }

    fn low_rank(&self) -> bool {
        true
    }

    fn state_elems(&self, rows: usize, cols: usize) -> u64 {
        let r = eff_rank(&self.hp, rows, cols);
        (rows * r + 2 * r * cols) as u64
    }
}

// ----------------------------------------------------------------- Fira ----
pub struct Fira {
    pub hp: Hyper,
}

impl Optimizer for Fira {
    fn name(&self) -> &'static str {
        "fira"
    }

    fn init(&self, rows: usize, cols: usize) -> State {
        let r = eff_rank(&self.hp, rows, cols);
        let mut st = State::default();
        st.mats.insert("u", init_proj(rows, r));
        st.mats.insert("m", Mat::zeros(r, cols));
        st.mats.insert("v", Mat::zeros(r, cols));
        st.scalars.insert("phi", 0.0);
        st
    }

    fn step(&self, g: &Mat, state: &mut State, t: u64) -> Mat {
        let hp = &self.hp;
        let u = state.mat("u").clone();
        let sigma = u.matmul_tn(g);
        let mut m = state.mats.remove("m").unwrap();
        let mut v = state.mats.remove("v").unwrap();
        let omega = adam_on(&sigma, &mut m, &mut v, hp, t);
        state.mats.insert("m", m);
        state.mats.insert("v", v);
        let low = u.matmul(&omega);
        let resid = g.sub(&u.matmul(&sigma));
        let scale = omega.fro_norm() / (sigma.fro_norm() + EPS);
        let (comp, phi) = limiter(resid.scale(scale), state.scalar("phi"), hp.gamma);
        state.scalars.insert("phi", phi);
        low.add(&comp).scale(hp.alpha)
    }

    fn refresh(&self, g: &Mat, state: &mut State, _seed: u64) {
        let q = g.matmul_nt(g);
        let (u, _) = subspace_iter(&q, state.mat("u"), self.hp.sub_iters);
        state.mats.insert("u", u);
    }

    fn has_refresh(&self) -> bool {
        true
    }

    fn transpose_wide(&self) -> bool {
        true
    }

    fn low_rank(&self) -> bool {
        true
    }

    fn state_elems(&self, rows: usize, cols: usize) -> u64 {
        let r = eff_rank(&self.hp, rows, cols);
        (rows * r + 2 * r * cols + 1) as u64
    }
}

// ---------------------------------------------------------- Apollo-mini ----
/// Rank-1 random sketch; the Adam-in-subspace norm ratio scales the RAW
/// gradient (SGD-like memory: 1·m + 2·n + 1).
pub struct ApolloMini {
    pub hp: Hyper,
}

impl Optimizer for ApolloMini {
    fn name(&self) -> &'static str {
        "apollo_mini"
    }

    fn init(&self, rows: usize, cols: usize) -> State {
        let mut st = State::default();
        st.mats.insert("u", Mat::zeros(rows, 1));
        st.mats.insert("m", Mat::zeros(1, cols));
        st.mats.insert("v", Mat::zeros(1, cols));
        st.scalars.insert("phi", 0.0);
        st
    }

    fn step(&self, g: &Mat, state: &mut State, t: u64) -> Mat {
        let hp = &self.hp;
        let u = state.mat("u").clone();
        let sigma = u.matmul_tn(g); // 1 x n
        let mut m = state.mats.remove("m").unwrap();
        let mut v = state.mats.remove("v").unwrap();
        let omega = adam_on(&sigma, &mut m, &mut v, hp, t);
        state.mats.insert("m", m);
        state.mats.insert("v", v);
        let scale = omega.fro_norm() / (sigma.fro_norm() + EPS);
        let (delta, phi) = limiter(g.scale(scale), state.scalar("phi"), hp.gamma);
        state.scalars.insert("phi", phi);
        delta.scale(hp.alpha)
    }

    fn refresh(&self, _g: &Mat, state: &mut State, seed: u64) {
        let rows = state.mat("u").rows;
        let mut rng = Pcg::seeded(seed.wrapping_mul(0x9e3779b9).wrapping_add(1));
        state
            .mats
            .insert("u", Mat::from_vec(rows, 1, rng.normal_vec(rows, 1.0)));
    }

    fn has_refresh(&self) -> bool {
        true
    }

    fn transpose_wide(&self) -> bool {
        true
    }

    fn low_rank(&self) -> bool {
        true
    }

    fn state_elems(&self, rows: usize, cols: usize) -> u64 {
        (rows + 2 * cols + 1) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn galore_projects_to_rank_r() {
        let hp = Hyper { rank: 4, ..Hyper::default() };
        let gl = GaLore { hp };
        let mut st = gl.init(12, 20);
        assert_eq!(st.mat("m").rows, 4);
        let mut rng = Pcg::seeded(30);
        let g = Mat::from_vec(12, 20, rng.normal_vec(240, 1.0));
        gl.refresh(&g, &mut st, 0);
        let d = gl.step(&g, &mut st, 1);
        // the update lies in span(U): (I - UUᵀ) Δ == 0
        let u = st.mat("u");
        let proj = u.matmul(&u.matmul_tn(&d));
        assert!(d.sub(&proj).max_abs() < 1e-3);
    }

    #[test]
    fn fira_is_full_rank_update() {
        let hp = Hyper { rank: 4, ..Hyper::default() };
        let fira = Fira { hp };
        let mut st = fira.init(12, 20);
        let mut rng = Pcg::seeded(31);
        let g = Mat::from_vec(12, 20, rng.normal_vec(240, 1.0));
        fira.refresh(&g, &mut st, 0);
        let d = fira.step(&g, &mut st, 1);
        let u = st.mat("u");
        let resid = d.sub(&u.matmul(&u.matmul_tn(&d)));
        // Fira adds energy OUTSIDE span(U) — that's the point
        assert!(resid.fro_norm() > 1e-3);
    }

    #[test]
    fn apollo_scales_raw_gradient() {
        let ap = ApolloMini { hp: Hyper::default() };
        let mut st = ap.init(8, 10);
        ap.refresh(&Mat::zeros(8, 10), &mut st, 3);
        let mut rng = Pcg::seeded(32);
        let g = Mat::from_vec(8, 10, rng.normal_vec(80, 1.0));
        let d = ap.step(&g, &mut st, 1);
        // direction is proportional to g (global scaling only)
        let ratio0 = d.data[0] / g.data[0];
        for (di, gi) in d.data.iter().zip(&g.data) {
            if gi.abs() > 1e-4 {
                assert!((di / gi - ratio0).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn rank_clamped_to_short_side() {
        let hp = Hyper { rank: 1000, ..Hyper::default() };
        assert_eq!(eff_rank(&hp, 12, 20), 12);
        let gl = GaLore { hp };
        let st = gl.init(12, 20);
        assert_eq!(st.mat("u").cols, 12);
    }
}
