//! Whitening-operator optimizers: Muon (App. B.9) and SWAN (App. B.7).
//!
//! Sec. 3.3 of the paper shows both are square-root NGD under simple
//! block-diagonal structures: whitening ↔ H = {Iₙ ⊗ M}, normalization ↔
//! H = {S ⊗ Iₘ} (Proposition 2), with 1-sample estimates of E[·].

use crate::linalg::{simd, whiten, Mat};

use super::{Hyper, Optimizer, State};

fn whiten_short_side(x: &Mat, iters: usize) -> Mat {
    if x.rows <= x.cols {
        whiten(x, iters)
    } else {
        whiten(&x.transpose(), iters).transpose()
    }
}

// ---------------------------------------------------------------- Muon ----
pub struct Muon {
    pub hp: Hyper,
}

impl Optimizer for Muon {
    fn name(&self) -> &'static str {
        "muon"
    }

    fn init(&self, rows: usize, cols: usize) -> State {
        let mut st = State::default();
        st.mats.insert("m", Mat::zeros(rows, cols));
        st
    }

    fn step(&self, g: &Mat, state: &mut State, _t: u64) -> Mat {
        let hp = &self.hp;
        let m = state.mats.get_mut("m").unwrap();
        m.ema_(hp.b1, g, 1.0 - hp.b1);
        whiten_short_side(&m.clone(), hp.ns_iters).scale(hp.alpha)
    }

    fn state_elems(&self, rows: usize, cols: usize) -> u64 {
        (rows * cols) as u64
    }
}

// ---------------------------------------------------------------- SWAN ----
/// Stateless: row-wise GradNorm then GradWhitening (Eq. 30-32).
pub struct Swan {
    pub hp: Hyper,
}

impl Optimizer for Swan {
    fn name(&self) -> &'static str {
        "swan"
    }

    fn init(&self, _rows: usize, _cols: usize) -> State {
        State::default()
    }

    fn step(&self, g: &Mat, _state: &mut State, _t: u64) -> Mat {
        let hp = &self.hp;
        let n = g.cols as f32;
        // GradNorm: per-row mean/std across columns (row sums and the
        // normalization run on the simd kernels; scalar dispatch is the
        // historical loop bit for bit)
        let gn = {
            let mut out = g.clone();
            for row in out.data.chunks_mut(g.cols.max(1)) {
                let mean = simd::sum(row) / n;
                let var = simd::sse_about(row, mean) / n;
                let std = var.sqrt() + super::EPS;
                simd::normalize(row, mean, std);
            }
            out
        };
        whiten_short_side(&gn, hp.ns_iters).scale(hp.alpha)
    }

    fn state_elems(&self, _rows: usize, _cols: usize) -> u64 {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg;

    #[test]
    fn muon_output_is_orthogonal_like() {
        let muon = Muon { hp: Hyper { b1: 0.0, ns_iters: 30, ..Hyper::default() } };
        let mut st = muon.init(6, 20);
        let mut rng = Pcg::seeded(8);
        let g = Mat::from_vec(6, 20, rng.normal_vec(120, 1.0));
        let d = muon.step(&g, &mut st, 1);
        let ddt = d.matmul_nt(&d);
        assert!(ddt.sub(&Mat::eye(6)).max_abs() < 0.1,
                "whitened momentum should be near-orthogonal");
    }

    #[test]
    fn swan_is_stateless_and_finite() {
        let swan = Swan { hp: Hyper { ns_iters: 20, ..Hyper::default() } };
        let mut st = swan.init(10, 14);
        assert_eq!(st.elems(), 0);
        let mut rng = Pcg::seeded(9);
        let g = Mat::from_vec(10, 14, rng.normal_vec(140, 2.0));
        let d = swan.step(&g, &mut st, 1);
        assert!(d.is_finite());
    }

    #[test]
    fn whiten_wide_and_tall_agree() {
        let mut rng = Pcg::seeded(10);
        let g = Mat::from_vec(5, 12, rng.normal_vec(60, 1.0));
        let a = whiten_short_side(&g, 25);
        let b = whiten_short_side(&g.transpose(), 25).transpose();
        assert!(a.sub(&b).max_abs() < 1e-3);
    }
}
