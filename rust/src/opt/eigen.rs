//! Full-rank structured optimizers: Eigen-Adam (Thm 3.2 / Alg. 7),
//! Shampoo (Thm 3.1 / Alg. 5), SOAP (Thm 3.3 / Alg. 6).
//!
//! These are the "general structure" end of the paper's
//! generality-vs-efficiency trade-off (Table 1): better FIM approximations,
//! O(m²) – O(m²+n²) state. Eigen-basis refreshes are amortized to the
//! coordinator's K-interval schedule and route through the size-dispatched
//! `jacobi_eigh` (serial < 96 ≤ Brent-Luk rounds < 1024 ≤ blocked
//! two-sided — the lm-head-scale Kron factors take the blocked path), so
//! refresh cost tracks the `linalg::decomp` dispatch table; the solver's
//! entry guard keeps a blown-up GGᵀ EMA from panicking a refresh.

use crate::linalg::{complete_basis, inv_fourth_root, jacobi_eigh, sketched_eigh_mat, Mat};

use super::{bias_corr, sketch_anchor_due, Hyper, Optimizer, Refresh, State};

/// Sketched full-rank eigenbasis refresh (ISSUE 6) for the optimizers
/// whose step() rotates through a *square* n×n U (Eigen-Adam, SOAP):
/// the randomized range finder delivers the r+p leading eigenvectors of
/// the stored EMA in O(n²·s·(q+2)), and one [`complete_basis`] QR pass
/// fills the trailing directions — a single O(n³)-class pass replacing
/// `eig_sweeps` full Jacobi sweeps, each itself O(n³). The trailing
/// block is an arbitrary orthonormal complement rather than the exact
/// minor eigenvectors; Adam's per-coordinate second moment in the
/// rotated space absorbs the difference, and the anchor cadence pins
/// any accumulated drift.
fn sketched_full_basis(q_ema: &Mat, u_prev: &Mat, hp: &Hyper, seed: u64) -> Mat {
    let n = q_ema.rows;
    let (u_s, _) = sketched_eigh_mat(q_ema, Some(u_prev), &hp.sketch_spec(n), seed);
    if u_s.cols == n {
        return u_s;
    }
    u_s.hcat(&complete_basis(&u_s))
}

// ---------------------------------------------------------- Eigen-Adam ----
/// Structure: Diag_B(U D₁ Uᵀ, …, U Dₙ Uᵀ) with shared full-rank eigenspace
/// (Eq. 9). Update: Adam in the rotated space (Eq. 12/13).
pub struct EigenAdam {
    pub hp: Hyper,
}

impl Optimizer for EigenAdam {
    fn name(&self) -> &'static str {
        "eigen_adam"
    }

    fn init(&self, rows: usize, cols: usize) -> State {
        let mut st = State::default();
        st.mats.insert("q", Mat::zeros(rows, rows));
        st.mats.insert("u", Mat::eye(rows));
        st.mats.insert("m", Mat::zeros(rows, cols));
        st.mats.insert("v", Mat::zeros(rows, cols));
        if self.hp.refresh == Refresh::Sketch {
            st.scalars.insert("rc", 0.0);
        }
        st
    }

    fn step(&self, g: &Mat, state: &mut State, t: u64) -> Mat {
        let hp = &self.hp;
        let ggt = g.matmul_nt(g);
        state.mats.get_mut("q").unwrap().ema_(hp.b3, &ggt, 1.0 - hp.b3);
        state.mats.get_mut("m").unwrap().ema_(hp.b1, g, 1.0 - hp.b1);
        let u = state.mat("u").clone();
        let sigma = u.matmul_tn(g); // Uᵀ G
        let v = state.mats.get_mut("v").unwrap();
        for (vi, &si) in v.data.iter_mut().zip(&sigma.data) {
            *vi = hp.b2 * *vi + (1.0 - hp.b2) * si * si;
        }
        let (bc1, bc2) = bias_corr(hp, t);
        let m_rot = u.matmul_tn(state.mat("m"));
        let v = state.mat("v");
        let direction = Mat::from_fn(m_rot.rows, m_rot.cols, |i, j| {
            (m_rot.at(i, j) / bc1) / ((v.at(i, j) / bc2).sqrt() + hp.eps)
        });
        u.matmul(&direction).scale(hp.alpha)
    }

    fn refresh(&self, _g: &Mat, state: &mut State, seed: u64) {
        let hp = &self.hp;
        let u = if hp.refresh == Refresh::Sketch
            && !sketch_anchor_due(state, hp.refresh_anchor_every)
        {
            sketched_full_basis(state.mat("q"), state.mat("u"), hp, seed)
        } else {
            jacobi_eigh(state.mat("q"), hp.eig_sweeps).0
        };
        state.mats.insert("u", u);
    }

    fn has_refresh(&self) -> bool {
        true
    }

    fn transpose_wide(&self) -> bool {
        true
    }

    fn state_elems(&self, rows: usize, cols: usize) -> u64 {
        let sketch = if self.hp.refresh == Refresh::Sketch { 1 } else { 0 };
        (2 * rows * rows + 2 * rows * cols) as u64 + sketch
    }
}

// -------------------------------------------------------------- Shampoo ----
/// Structure: Rₙ^½ ⊗ Lₘ^½ (Thm 3.1). Accumulators L += GGᵀ, R += GᵀG;
/// update Δ = L^-¼ G R^-¼; roots recomputed at refreshes (Anil et al.).
pub struct Shampoo {
    pub hp: Hyper,
}

impl Optimizer for Shampoo {
    fn name(&self) -> &'static str {
        "shampoo"
    }

    fn init(&self, rows: usize, cols: usize) -> State {
        let mut st = State::default();
        st.mats.insert("l", Mat::eye(rows).scale(1e-4));
        st.mats.insert("r", Mat::eye(cols).scale(1e-4));
        st.mats.insert("li4", Mat::eye(rows));
        st.mats.insert("ri4", Mat::eye(cols));
        st
    }

    fn step(&self, g: &Mat, state: &mut State, _t: u64) -> Mat {
        let hp = &self.hp;
        let ggt = g.matmul_nt(g);
        let gtg = g.matmul_tn(g);
        state.mats.get_mut("l").unwrap().ema_(1.0, &ggt, 1.0);
        state.mats.get_mut("r").unwrap().ema_(1.0, &gtg, 1.0);
        state
            .mat("li4")
            .matmul(g)
            .matmul(state.mat("ri4"))
            .scale(hp.alpha)
    }

    fn refresh(&self, _g: &Mat, state: &mut State, _seed: u64) {
        let li4 = inv_fourth_root(state.mat("l"), self.hp.ns_iters);
        let ri4 = inv_fourth_root(state.mat("r"), self.hp.ns_iters);
        state.mats.insert("li4", li4);
        state.mats.insert("ri4", ri4);
    }

    fn has_refresh(&self) -> bool {
        true
    }

    fn state_elems(&self, rows: usize, cols: usize) -> u64 {
        2 * (rows * rows + cols * cols) as u64
    }
}

// ----------------------------------------------------------------- SOAP ----
/// Structure: (U_R ⊗ U_L) D̃ (U_R ⊗ U_L)ᵀ (Eq. 14) — Adam in Shampoo's
/// two-sided eigenbasis (Alg. 6).
pub struct Soap {
    pub hp: Hyper,
}

impl Optimizer for Soap {
    fn name(&self) -> &'static str {
        "soap"
    }

    fn init(&self, rows: usize, cols: usize) -> State {
        let mut st = State::default();
        st.mats.insert("l", Mat::zeros(rows, rows));
        st.mats.insert("r", Mat::zeros(cols, cols));
        st.mats.insert("ul", Mat::eye(rows));
        st.mats.insert("ur", Mat::eye(cols));
        st.mats.insert("m", Mat::zeros(rows, cols));
        st.mats.insert("v", Mat::zeros(rows, cols));
        if self.hp.refresh == Refresh::Sketch {
            st.scalars.insert("rc", 0.0);
        }
        st
    }

    fn step(&self, g: &Mat, state: &mut State, t: u64) -> Mat {
        let hp = &self.hp;
        let ggt = g.matmul_nt(g);
        let gtg = g.matmul_tn(g);
        state.mats.get_mut("l").unwrap().ema_(hp.b3, &ggt, 1.0 - hp.b3);
        state.mats.get_mut("r").unwrap().ema_(hp.b3, &gtg, 1.0 - hp.b3);
        state.mats.get_mut("m").unwrap().ema_(hp.b1, g, 1.0 - hp.b1);
        let (ul, ur) = (state.mat("ul").clone(), state.mat("ur").clone());
        let g_rot = ul.matmul_tn(g).matmul(&ur); // U_Lᵀ G U_R
        let v = state.mats.get_mut("v").unwrap();
        for (vi, &gi) in v.data.iter_mut().zip(&g_rot.data) {
            *vi = hp.b2 * *vi + (1.0 - hp.b2) * gi * gi;
        }
        let (bc1, bc2) = bias_corr(hp, t);
        let m_rot = ul.matmul_tn(state.mat("m")).matmul(&ur);
        let v = state.mat("v");
        let dir = Mat::from_fn(m_rot.rows, m_rot.cols, |i, j| {
            (m_rot.at(i, j) / bc1) / ((v.at(i, j) / bc2).sqrt() + hp.eps)
        });
        ul.matmul(&dir).matmul_nt(&ur).scale(hp.alpha)
    }

    fn refresh(&self, _g: &Mat, state: &mut State, seed: u64) {
        let hp = &self.hp;
        let (ul, ur) = if hp.refresh == Refresh::Sketch
            && !sketch_anchor_due(state, hp.refresh_anchor_every)
        {
            // decorrelated streams for the two Kron sides
            let seed_r = seed ^ 0xa5a5_5a5a_1234_5678;
            (
                sketched_full_basis(state.mat("l"), state.mat("ul"), hp, seed),
                sketched_full_basis(state.mat("r"), state.mat("ur"), hp, seed_r),
            )
        } else {
            (
                jacobi_eigh(state.mat("l"), hp.eig_sweeps).0,
                jacobi_eigh(state.mat("r"), hp.eig_sweeps).0,
            )
        };
        state.mats.insert("ul", ul);
        state.mats.insert("ur", ur);
    }

    fn has_refresh(&self) -> bool {
        true
    }

    fn transpose_wide(&self) -> bool {
        true
    }

    fn state_elems(&self, rows: usize, cols: usize) -> u64 {
        let sketch = if self.hp.refresh == Refresh::Sketch { 1 } else { 0 };
        (2 * rows * rows + 2 * cols * cols + 2 * rows * cols) as u64 + sketch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg;

    #[test]
    fn eigen_adam_with_identity_u_is_adam() {
        // Before any refresh U = I, so Eigen-Adam must equal Adam exactly.
        let hp = Hyper::default();
        let ea = EigenAdam { hp: hp.clone() };
        let adam = super::super::simple::Adam { hp };
        let mut st_e = ea.init(6, 9);
        let mut st_a = adam.init(6, 9);
        let mut rng = Pcg::seeded(20);
        for t in 1..=4 {
            let g = Mat::from_vec(6, 9, rng.normal_vec(54, 1.0));
            let de = ea.step(&g, &mut st_e, t);
            let da = adam.step(&g, &mut st_a, t);
            assert!(de.sub(&da).max_abs() < 1e-5, "t={t}");
        }
    }

    #[test]
    fn eigen_adam_refresh_survives_non_finite_accumulator() {
        // a gradient blowup poisons the GGᵀ EMA; the refresh must not
        // panic and must keep U orthonormal (solver entry guard, ISSUE 5)
        let ea = EigenAdam { hp: Hyper { eig_sweeps: 30, ..Hyper::default() } };
        let mut st = ea.init(8, 12);
        let mut rng = Pcg::seeded(24);
        let g = Mat::from_vec(8, 12, rng.normal_vec(96, 1.0));
        ea.step(&g, &mut st, 1);
        *st.mats.get_mut("q").unwrap().at_mut(3, 5) = f32::NAN;
        *st.mats.get_mut("q").unwrap().at_mut(1, 2) = f32::INFINITY;
        ea.refresh(&g, &mut st, 0);
        let u = st.mat("u");
        assert!(u.is_finite());
        let err = u.matmul_tn(u).sub(&Mat::eye(8)).max_abs();
        assert!(err < 1e-3, "U not orthonormal after sanitized refresh: {err}");
    }

    #[test]
    fn eigen_adam_rotation_is_orthonormal_after_refresh() {
        let ea = EigenAdam { hp: Hyper { eig_sweeps: 30, ..Hyper::default() } };
        let mut st = ea.init(8, 12);
        let mut rng = Pcg::seeded(21);
        for t in 1..=5 {
            let g = Mat::from_vec(8, 12, rng.normal_vec(96, 1.0));
            ea.step(&g, &mut st, t);
        }
        let g = Mat::from_vec(8, 12, rng.normal_vec(96, 1.0));
        ea.refresh(&g, &mut st, 0);
        let u = st.mat("u");
        let err = u.matmul_tn(u).sub(&Mat::eye(8)).max_abs();
        assert!(err < 1e-3, "U not orthonormal: {err}");
    }

    #[test]
    fn eigen_adam_sketch_refresh_keeps_square_orthonormal_u() {
        let hp = Hyper {
            rank: 4,
            eig_sweeps: 30,
            refresh: Refresh::Sketch,
            refresh_anchor_every: 4,
            ..Hyper::default()
        };
        let ea = EigenAdam { hp };
        let mut st = ea.init(10, 14);
        assert_eq!(st.elems(), ea.state_elems(10, 14), "rc must be counted");
        let mut rng = Pcg::seeded(30);
        for t in 1..=3 {
            let g = Mat::from_vec(10, 14, rng.normal_vec(140, 1.0));
            ea.step(&g, &mut st, t);
            ea.refresh(&g, &mut st, t); // t=1 anchors, 2-3 take the sketch
            let u = st.mat("u");
            assert_eq!((u.rows, u.cols), (10, 10), "step needs a square U");
            let err = u.matmul_tn(u).sub(&Mat::eye(10)).max_abs();
            assert!(err < 1e-3, "t={t}: sketched U not orthonormal: {err}");
            let d = ea.step(&g, &mut st, t);
            assert!(d.is_finite());
        }
        assert_eq!(st.scalar("rc"), 3.0);
        assert_eq!(st.elems(), ea.state_elems(10, 14));
    }

    #[test]
    fn soap_sketch_refresh_keeps_both_bases_orthonormal() {
        let hp = Hyper {
            rank: 3,
            eig_sweeps: 30,
            refresh: Refresh::Sketch,
            refresh_anchor_every: 4,
            ..Hyper::default()
        };
        let soap = Soap { hp };
        let mut st = soap.init(8, 11);
        assert_eq!(st.elems(), soap.state_elems(8, 11));
        let mut rng = Pcg::seeded(31);
        for t in 1..=3 {
            let g = Mat::from_vec(8, 11, rng.normal_vec(88, 1.0));
            soap.step(&g, &mut st, t);
            soap.refresh(&g, &mut st, t);
            for (key, n) in [("ul", 8usize), ("ur", 11usize)] {
                let u = st.mat(key);
                assert_eq!((u.rows, u.cols), (n, n), "{key} must stay square");
                let err = u.matmul_tn(u).sub(&Mat::eye(n)).max_abs();
                assert!(err < 1e-3, "t={t}: {key} not orthonormal: {err}");
            }
            assert!(soap.step(&g, &mut st, t).is_finite());
        }
        assert_eq!(st.elems(), soap.state_elems(8, 11));
    }

    #[test]
    fn shampoo_update_uses_roots() {
        let sh = Shampoo { hp: Hyper { ns_iters: 25, ..Hyper::default() } };
        let mut st = sh.init(6, 6);
        let mut rng = Pcg::seeded(22);
        for t in 1..=6 {
            let g = Mat::from_vec(6, 6, rng.normal_vec(36, 1.0));
            sh.step(&g, &mut st, t);
        }
        let g = Mat::from_vec(6, 6, rng.normal_vec(36, 1.0));
        sh.refresh(&g, &mut st, 0);
        let d = sh.step(&g, &mut st, 7);
        assert!(d.is_finite());
        // preconditioned step differs from raw gradient
        assert!(d.sub(&g).max_abs() > 1e-3);
    }

    #[test]
    fn soap_with_identity_bases_is_adam() {
        let hp = Hyper::default();
        let soap = Soap { hp: hp.clone() };
        let adam = super::super::simple::Adam { hp };
        let mut st_s = soap.init(5, 7);
        let mut st_a = adam.init(5, 7);
        let mut rng = Pcg::seeded(23);
        for t in 1..=3 {
            let g = Mat::from_vec(5, 7, rng.normal_vec(35, 1.0));
            let ds = soap.step(&g, &mut st_s, t);
            let da = adam.step(&g, &mut st_a, t);
            assert!(ds.sub(&da).max_abs() < 1e-5);
        }
    }
}
