//! Full-rank structured optimizers: Eigen-Adam (Thm 3.2 / Alg. 7),
//! Shampoo (Thm 3.1 / Alg. 5), SOAP (Thm 3.3 / Alg. 6).
//!
//! These are the "general structure" end of the paper's
//! generality-vs-efficiency trade-off (Table 1): better FIM approximations,
//! O(m²) – O(m²+n²) state. Eigen-basis refreshes are amortized to the
//! coordinator's K-interval schedule and route through the size-dispatched
//! `jacobi_eigh` (serial < 96 ≤ Brent-Luk rounds < 1024 ≤ blocked
//! two-sided — the lm-head-scale Kron factors take the blocked path), so
//! refresh cost tracks the `linalg::decomp` dispatch table; the solver's
//! entry guard keeps a blown-up GGᵀ EMA from panicking a refresh.

use crate::linalg::{inv_fourth_root, jacobi_eigh, Mat};

use super::{bias_corr, Hyper, Optimizer, State};

// ---------------------------------------------------------- Eigen-Adam ----
/// Structure: Diag_B(U D₁ Uᵀ, …, U Dₙ Uᵀ) with shared full-rank eigenspace
/// (Eq. 9). Update: Adam in the rotated space (Eq. 12/13).
pub struct EigenAdam {
    pub hp: Hyper,
}

impl Optimizer for EigenAdam {
    fn name(&self) -> &'static str {
        "eigen_adam"
    }

    fn init(&self, rows: usize, cols: usize) -> State {
        let mut st = State::default();
        st.mats.insert("q", Mat::zeros(rows, rows));
        st.mats.insert("u", Mat::eye(rows));
        st.mats.insert("m", Mat::zeros(rows, cols));
        st.mats.insert("v", Mat::zeros(rows, cols));
        st
    }

    fn step(&self, g: &Mat, state: &mut State, t: u64) -> Mat {
        let hp = &self.hp;
        let ggt = g.matmul_nt(g);
        state.mats.get_mut("q").unwrap().ema_(hp.b3, &ggt, 1.0 - hp.b3);
        state.mats.get_mut("m").unwrap().ema_(hp.b1, g, 1.0 - hp.b1);
        let u = state.mat("u").clone();
        let sigma = u.matmul_tn(g); // Uᵀ G
        let v = state.mats.get_mut("v").unwrap();
        for (vi, &si) in v.data.iter_mut().zip(&sigma.data) {
            *vi = hp.b2 * *vi + (1.0 - hp.b2) * si * si;
        }
        let (bc1, bc2) = bias_corr(hp, t);
        let m_rot = u.matmul_tn(state.mat("m"));
        let v = state.mat("v");
        let direction = Mat::from_fn(m_rot.rows, m_rot.cols, |i, j| {
            (m_rot.at(i, j) / bc1) / ((v.at(i, j) / bc2).sqrt() + hp.eps)
        });
        u.matmul(&direction).scale(hp.alpha)
    }

    fn refresh(&self, _g: &Mat, state: &mut State, _seed: u64) {
        let (u, _) = jacobi_eigh(state.mat("q"), self.hp.eig_sweeps);
        state.mats.insert("u", u);
    }

    fn has_refresh(&self) -> bool {
        true
    }

    fn transpose_wide(&self) -> bool {
        true
    }

    fn state_elems(&self, rows: usize, cols: usize) -> u64 {
        (2 * rows * rows + 2 * rows * cols) as u64
    }
}

// -------------------------------------------------------------- Shampoo ----
/// Structure: Rₙ^½ ⊗ Lₘ^½ (Thm 3.1). Accumulators L += GGᵀ, R += GᵀG;
/// update Δ = L^-¼ G R^-¼; roots recomputed at refreshes (Anil et al.).
pub struct Shampoo {
    pub hp: Hyper,
}

impl Optimizer for Shampoo {
    fn name(&self) -> &'static str {
        "shampoo"
    }

    fn init(&self, rows: usize, cols: usize) -> State {
        let mut st = State::default();
        st.mats.insert("l", Mat::eye(rows).scale(1e-4));
        st.mats.insert("r", Mat::eye(cols).scale(1e-4));
        st.mats.insert("li4", Mat::eye(rows));
        st.mats.insert("ri4", Mat::eye(cols));
        st
    }

    fn step(&self, g: &Mat, state: &mut State, _t: u64) -> Mat {
        let hp = &self.hp;
        let ggt = g.matmul_nt(g);
        let gtg = g.matmul_tn(g);
        state.mats.get_mut("l").unwrap().ema_(1.0, &ggt, 1.0);
        state.mats.get_mut("r").unwrap().ema_(1.0, &gtg, 1.0);
        state
            .mat("li4")
            .matmul(g)
            .matmul(state.mat("ri4"))
            .scale(hp.alpha)
    }

    fn refresh(&self, _g: &Mat, state: &mut State, _seed: u64) {
        let li4 = inv_fourth_root(state.mat("l"), self.hp.ns_iters);
        let ri4 = inv_fourth_root(state.mat("r"), self.hp.ns_iters);
        state.mats.insert("li4", li4);
        state.mats.insert("ri4", ri4);
    }

    fn has_refresh(&self) -> bool {
        true
    }

    fn state_elems(&self, rows: usize, cols: usize) -> u64 {
        2 * (rows * rows + cols * cols) as u64
    }
}

// ----------------------------------------------------------------- SOAP ----
/// Structure: (U_R ⊗ U_L) D̃ (U_R ⊗ U_L)ᵀ (Eq. 14) — Adam in Shampoo's
/// two-sided eigenbasis (Alg. 6).
pub struct Soap {
    pub hp: Hyper,
}

impl Optimizer for Soap {
    fn name(&self) -> &'static str {
        "soap"
    }

    fn init(&self, rows: usize, cols: usize) -> State {
        let mut st = State::default();
        st.mats.insert("l", Mat::zeros(rows, rows));
        st.mats.insert("r", Mat::zeros(cols, cols));
        st.mats.insert("ul", Mat::eye(rows));
        st.mats.insert("ur", Mat::eye(cols));
        st.mats.insert("m", Mat::zeros(rows, cols));
        st.mats.insert("v", Mat::zeros(rows, cols));
        st
    }

    fn step(&self, g: &Mat, state: &mut State, t: u64) -> Mat {
        let hp = &self.hp;
        let ggt = g.matmul_nt(g);
        let gtg = g.matmul_tn(g);
        state.mats.get_mut("l").unwrap().ema_(hp.b3, &ggt, 1.0 - hp.b3);
        state.mats.get_mut("r").unwrap().ema_(hp.b3, &gtg, 1.0 - hp.b3);
        state.mats.get_mut("m").unwrap().ema_(hp.b1, g, 1.0 - hp.b1);
        let (ul, ur) = (state.mat("ul").clone(), state.mat("ur").clone());
        let g_rot = ul.matmul_tn(g).matmul(&ur); // U_Lᵀ G U_R
        let v = state.mats.get_mut("v").unwrap();
        for (vi, &gi) in v.data.iter_mut().zip(&g_rot.data) {
            *vi = hp.b2 * *vi + (1.0 - hp.b2) * gi * gi;
        }
        let (bc1, bc2) = bias_corr(hp, t);
        let m_rot = ul.matmul_tn(state.mat("m")).matmul(&ur);
        let v = state.mat("v");
        let dir = Mat::from_fn(m_rot.rows, m_rot.cols, |i, j| {
            (m_rot.at(i, j) / bc1) / ((v.at(i, j) / bc2).sqrt() + hp.eps)
        });
        ul.matmul(&dir).matmul_nt(&ur).scale(hp.alpha)
    }

    fn refresh(&self, _g: &Mat, state: &mut State, _seed: u64) {
        let (ul, _) = jacobi_eigh(state.mat("l"), self.hp.eig_sweeps);
        let (ur, _) = jacobi_eigh(state.mat("r"), self.hp.eig_sweeps);
        state.mats.insert("ul", ul);
        state.mats.insert("ur", ur);
    }

    fn has_refresh(&self) -> bool {
        true
    }

    fn transpose_wide(&self) -> bool {
        true
    }

    fn state_elems(&self, rows: usize, cols: usize) -> u64 {
        (2 * rows * rows + 2 * cols * cols + 2 * rows * cols) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg;

    #[test]
    fn eigen_adam_with_identity_u_is_adam() {
        // Before any refresh U = I, so Eigen-Adam must equal Adam exactly.
        let hp = Hyper::default();
        let ea = EigenAdam { hp: hp.clone() };
        let adam = super::super::simple::Adam { hp };
        let mut st_e = ea.init(6, 9);
        let mut st_a = adam.init(6, 9);
        let mut rng = Pcg::seeded(20);
        for t in 1..=4 {
            let g = Mat::from_vec(6, 9, rng.normal_vec(54, 1.0));
            let de = ea.step(&g, &mut st_e, t);
            let da = adam.step(&g, &mut st_a, t);
            assert!(de.sub(&da).max_abs() < 1e-5, "t={t}");
        }
    }

    #[test]
    fn eigen_adam_refresh_survives_non_finite_accumulator() {
        // a gradient blowup poisons the GGᵀ EMA; the refresh must not
        // panic and must keep U orthonormal (solver entry guard, ISSUE 5)
        let ea = EigenAdam { hp: Hyper { eig_sweeps: 30, ..Hyper::default() } };
        let mut st = ea.init(8, 12);
        let mut rng = Pcg::seeded(24);
        let g = Mat::from_vec(8, 12, rng.normal_vec(96, 1.0));
        ea.step(&g, &mut st, 1);
        *st.mats.get_mut("q").unwrap().at_mut(3, 5) = f32::NAN;
        *st.mats.get_mut("q").unwrap().at_mut(1, 2) = f32::INFINITY;
        ea.refresh(&g, &mut st, 0);
        let u = st.mat("u");
        assert!(u.is_finite());
        let err = u.matmul_tn(u).sub(&Mat::eye(8)).max_abs();
        assert!(err < 1e-3, "U not orthonormal after sanitized refresh: {err}");
    }

    #[test]
    fn eigen_adam_rotation_is_orthonormal_after_refresh() {
        let ea = EigenAdam { hp: Hyper { eig_sweeps: 30, ..Hyper::default() } };
        let mut st = ea.init(8, 12);
        let mut rng = Pcg::seeded(21);
        for t in 1..=5 {
            let g = Mat::from_vec(8, 12, rng.normal_vec(96, 1.0));
            ea.step(&g, &mut st, t);
        }
        let g = Mat::from_vec(8, 12, rng.normal_vec(96, 1.0));
        ea.refresh(&g, &mut st, 0);
        let u = st.mat("u");
        let err = u.matmul_tn(u).sub(&Mat::eye(8)).max_abs();
        assert!(err < 1e-3, "U not orthonormal: {err}");
    }

    #[test]
    fn shampoo_update_uses_roots() {
        let sh = Shampoo { hp: Hyper { ns_iters: 25, ..Hyper::default() } };
        let mut st = sh.init(6, 6);
        let mut rng = Pcg::seeded(22);
        for t in 1..=6 {
            let g = Mat::from_vec(6, 6, rng.normal_vec(36, 1.0));
            sh.step(&g, &mut st, t);
        }
        let g = Mat::from_vec(6, 6, rng.normal_vec(36, 1.0));
        sh.refresh(&g, &mut st, 0);
        let d = sh.step(&g, &mut st, 7);
        assert!(d.is_finite());
        // preconditioned step differs from raw gradient
        assert!(d.sub(&g).max_abs() > 1e-3);
    }

    #[test]
    fn soap_with_identity_bases_is_adam() {
        let hp = Hyper::default();
        let soap = Soap { hp: hp.clone() };
        let adam = super::super::simple::Adam { hp };
        let mut st_s = soap.init(5, 7);
        let mut st_a = adam.init(5, 7);
        let mut rng = Pcg::seeded(23);
        for t in 1..=3 {
            let g = Mat::from_vec(5, 7, rng.normal_vec(35, 1.0));
            let ds = soap.step(&g, &mut st_s, t);
            let da = adam.step(&g, &mut st_a, t);
            assert!(ds.sub(&da).max_abs() < 1e-5);
        }
    }
}
