//! RACS — Row and Column Scaled SGD (paper Sec. 4, Algorithm 1).
//!
//! The structure is H = {S ⊗ Q} with positive diagonal S, Q (Eq. 15); the
//! Frobenius-optimal solution is the Proposition 3 fixed point, whose
//! iterates converge to the principal singular pair of E[G⊙²]
//! (Perron-Frobenius ⇒ strictly positive, so the square-root inverse
//! scaling is always well-defined — property-tested in `fisher`).
//!
//! Memory: s[n] + q[m] + limiter scalar = m + n + 1 — "SGD-like".

use crate::linalg::Mat;

use super::{limiter, Hyper, Optimizer, State, EPS};

/// Proposition 3 fixed point on P = G⊙²: s ∝ Pᵀq/‖q‖², q ∝ Ps/‖s‖².
/// Returns (s, q) after `iters` sweeps starting from q = 1 (the paper's
/// practical initialization).
pub fn fixed_point(g: &Mat, iters: usize) -> (Vec<f32>, Vec<f32>) {
    let (m, n) = (g.rows, g.cols);
    let mut q = vec![1.0f32; m];
    let mut s = vec![1.0f32; n];
    for _ in 0..iters {
        // s = Pᵀ q / ||q||²
        let qn: f32 = q.iter().map(|x| x * x).sum::<f32>() + EPS;
        for sj in s.iter_mut() {
            *sj = 0.0;
        }
        for i in 0..m {
            let qi = q[i];
            let row = g.row(i);
            for (sj, &gij) in s.iter_mut().zip(row) {
                *sj += gij * gij * qi;
            }
        }
        for sj in s.iter_mut() {
            *sj /= qn;
        }
        // q = P s / ||s||²
        let sn: f32 = s.iter().map(|x| x * x).sum::<f32>() + EPS;
        for (i, qi) in q.iter_mut().enumerate() {
            let row = g.row(i);
            let mut acc = 0.0f32;
            for (&gij, &sj) in row.iter().zip(&s) {
                acc += gij * gij * sj;
            }
            *qi = acc / sn;
        }
    }
    (s, q)
}

/// Two-sided scaling Q^-½ G S^-½ (Alg. 1 line 8).
pub fn apply_scaling(g: &Mat, q: &[f32], s: &[f32]) -> Mat {
    let qr: Vec<f32> = q.iter().map(|&x| 1.0 / (x + EPS).sqrt()).collect();
    let sr: Vec<f32> = s.iter().map(|&x| 1.0 / (x + EPS).sqrt()).collect();
    Mat::from_fn(g.rows, g.cols, |i, j| g.at(i, j) * qr[i] * sr[j])
}

pub struct Racs {
    pub hp: Hyper,
}

impl Optimizer for Racs {
    fn name(&self) -> &'static str {
        "racs"
    }

    fn init(&self, rows: usize, cols: usize) -> State {
        let mut st = State::default();
        st.vecs.insert("s", vec![0.0; cols]);
        st.vecs.insert("q", vec![0.0; rows]);
        st.scalars.insert("phi", 0.0);
        st
    }

    fn step(&self, g: &Mat, state: &mut State, t: u64) -> Mat {
        let hp = &self.hp;
        let (s_new, q_new) = fixed_point(g, hp.racs_iters);
        let (s, q) = if hp.racs_ema {
            // EMA warm start: plain assignment at t == 1 (python twin).
            let b = if t <= 1 { 0.0 } else { hp.beta_racs };
            let s_st = state.vecs.get_mut("s").unwrap();
            for (x, &y) in s_st.iter_mut().zip(&s_new) {
                *x = b * *x + (1.0 - b) * y;
            }
            let s = s_st.clone();
            let q_st = state.vecs.get_mut("q").unwrap();
            for (x, &y) in q_st.iter_mut().zip(&q_new) {
                *x = b * *x + (1.0 - b) * y;
            }
            (s, q_st.clone())
        } else {
            (s_new, q_new)
        };
        let delta = apply_scaling(g, &q, &s);
        let phi = state.scalar("phi");
        let (delta, phi2) = limiter(delta, phi, hp.gamma);
        state.scalars.insert("phi", phi2);
        delta.scale(hp.alpha)
    }

    fn state_elems(&self, rows: usize, cols: usize) -> u64 {
        (rows + cols + 1) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg;

    #[test]
    fn fixed_point_is_positive() {
        // Perron-Frobenius: with positive G⊙², s and q stay positive.
        let mut rng = Pcg::seeded(13);
        let g = Mat::from_vec(12, 20, rng.normal_vec(240, 1.0));
        let (s, q) = fixed_point(&g, 5);
        assert!(s.iter().all(|&x| x > 0.0));
        assert!(q.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn fixed_point_matches_rank1_structure() {
        // If G⊙² = q₀ s₀ᵀ exactly (rank 1), the fixed point recovers the
        // factors up to scale after one sweep.
        let q0 = [1.0f32, 4.0, 0.25];
        let s0 = [2.0f32, 0.5, 1.0, 3.0];
        let g = Mat::from_fn(3, 4, |i, j| (q0[i] * s0[j]).sqrt());
        let (s, q) = fixed_point(&g, 6);
        // ratios must match
        for j in 1..4 {
            let want = s0[j] / s0[0];
            let got = s[j] / s[0];
            assert!((want - got).abs() < 1e-4, "{want} vs {got}");
        }
        for i in 1..3 {
            let want = q0[i] / q0[0];
            let got = q[i] / q[0];
            assert!((want - got).abs() < 1e-4);
        }
    }

    #[test]
    fn scaling_normalizes_rank1() {
        // On exact rank-1 |G|, the scaled matrix has constant magnitude.
        let q0 = [1.0f32, 9.0];
        let s0 = [4.0f32, 1.0, 16.0];
        let g = Mat::from_fn(2, 3, |i, j| (q0[i] * s0[j]).sqrt());
        let (s, q) = fixed_point(&g, 8);
        let scaled = apply_scaling(&g, &q, &s);
        let mags: Vec<f32> = scaled.data.iter().map(|x| x.abs()).collect();
        for w in mags.windows(2) {
            assert!((w[0] - w[1]).abs() < 1e-3, "{mags:?}");
        }
    }

    #[test]
    fn racs_step_finite_and_limited() {
        let racs = Racs { hp: Hyper::default() };
        let mut st = racs.init(10, 16);
        let mut rng = Pcg::seeded(14);
        for t in 1..=5 {
            let g = Mat::from_vec(10, 16, rng.normal_vec(160, 1.0));
            let d = racs.step(&g, &mut st, t);
            assert!(d.is_finite());
        }
        // limiter phi must be positive after steps
        assert!(st.scalar("phi") > 0.0);
    }

    #[test]
    fn ema_vs_no_ema_differ_after_two_steps() {
        let mk = |ema| Racs { hp: Hyper { racs_ema: ema, ..Hyper::default() } };
        let (r1, r2) = (mk(true), mk(false));
        let mut s1 = r1.init(6, 8);
        let mut s2 = r2.init(6, 8);
        let mut rng = Pcg::seeded(15);
        let g1 = Mat::from_vec(6, 8, rng.normal_vec(48, 1.0));
        let g2 = Mat::from_vec(6, 8, rng.normal_vec(48, 1.0));
        r1.step(&g1, &mut s1, 1);
        r2.step(&g1, &mut s2, 1);
        let d1 = r1.step(&g2, &mut s1, 2);
        let d2 = r2.step(&g2, &mut s2, 2);
        assert!(d1.sub(&d2).max_abs() > 1e-6);
    }
}
