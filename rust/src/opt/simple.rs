//! Diagonal-structure optimizers: SGD, Adam (Prop. 1), Adafactor, Lion,
//! Signum. These are the memory/quality anchors of Table 2.

use crate::linalg::Mat;

use super::{bias_corr, Hyper, Optimizer, State};

// ----------------------------------------------------------------- SGD ----
pub struct Sgd {
    pub hp: Hyper,
}

impl Optimizer for Sgd {
    fn name(&self) -> &'static str {
        "sgd"
    }

    fn init(&self, _rows: usize, _cols: usize) -> State {
        State::default()
    }

    fn step(&self, g: &Mat, _state: &mut State, _t: u64) -> Mat {
        g.scale(self.hp.alpha)
    }

    fn state_elems(&self, _rows: usize, _cols: usize) -> u64 {
        0
    }
}

// ---------------------------------------------------------------- Adam ----
/// Proposition 1: the optimal purely-diagonal FIM approximation is
/// Diag_v(E[ḡ²]) — Adam's second moment. State 2mn (paper Table 1: 3mn
/// including the weight).
pub struct Adam {
    pub hp: Hyper,
}

impl Optimizer for Adam {
    fn name(&self) -> &'static str {
        "adam"
    }

    fn init(&self, rows: usize, cols: usize) -> State {
        let mut st = State::default();
        st.mats.insert("m", Mat::zeros(rows, cols));
        st.mats.insert("v", Mat::zeros(rows, cols));
        st
    }

    fn step(&self, g: &Mat, state: &mut State, t: u64) -> Mat {
        let hp = &self.hp;
        let (bc1, bc2) = bias_corr(hp, t);
        let m = state.mats.get_mut("m").unwrap();
        m.ema_(hp.b1, g, 1.0 - hp.b1);
        let m = m.clone();
        let v = state.mats.get_mut("v").unwrap();
        for (vi, &gi) in v.data.iter_mut().zip(&g.data) {
            *vi = hp.b2 * *vi + (1.0 - hp.b2) * gi * gi;
        }
        let mut delta = m;
        for (di, &vi) in delta.data.iter_mut().zip(&state.mat("v").data) {
            *di = (*di / bc1) / ((vi / bc2).sqrt() + hp.eps) * hp.alpha;
        }
        delta
    }

    fn state_elems(&self, rows: usize, cols: usize) -> u64 {
        2 * (rows * cols) as u64
    }
}

// ----------------------------------------------------------- Adafactor ----
/// Rank-1 factored second moment (Shazeer & Stern 2018, simplified —
/// matches the python twin). State m + n.
pub struct Adafactor {
    pub hp: Hyper,
}

impl Optimizer for Adafactor {
    fn name(&self) -> &'static str {
        "adafactor"
    }

    fn init(&self, rows: usize, cols: usize) -> State {
        let mut st = State::default();
        st.vecs.insert("r", vec![0.0; rows]);
        st.vecs.insert("c", vec![0.0; cols]);
        st
    }

    fn step(&self, g: &Mat, state: &mut State, _t: u64) -> Mat {
        let hp = &self.hp;
        let (rows, cols) = (g.rows, g.cols);
        let row_mean: Vec<f32> = (0..rows)
            .map(|i| g.row(i).iter().map(|x| x * x).sum::<f32>() / cols as f32)
            .collect();
        let mut col_mean = vec![0.0f32; cols];
        for i in 0..rows {
            for (cm, &x) in col_mean.iter_mut().zip(g.row(i)) {
                *cm += x * x;
            }
        }
        for cm in &mut col_mean {
            *cm /= rows as f32;
        }
        let r = state.vecs.get_mut("r").unwrap();
        for (ri, &nm) in r.iter_mut().zip(&row_mean) {
            *ri = hp.b2 * *ri + (1.0 - hp.b2) * nm;
        }
        let r = r.clone();
        let c = state.vecs.get_mut("c").unwrap();
        for (ci, &nm) in c.iter_mut().zip(&col_mean) {
            *ci = hp.b2 * *ci + (1.0 - hp.b2) * nm;
        }
        let r_mean = r.iter().sum::<f32>() / rows as f32 + super::EPS;
        let c = state.vec("c").to_vec();
        Mat::from_fn(rows, cols, |i, j| {
            let vhat = r[i] * c[j] / r_mean;
            hp.alpha * g.at(i, j) / (vhat.sqrt() + hp.eps)
        })
    }

    fn state_elems(&self, rows: usize, cols: usize) -> u64 {
        (rows + cols) as u64
    }
}

// ---------------------------------------------------------------- Lion ----
pub struct Lion {
    pub hp: Hyper,
}

impl Optimizer for Lion {
    fn name(&self) -> &'static str {
        "lion"
    }

    fn init(&self, rows: usize, cols: usize) -> State {
        let mut st = State::default();
        st.mats.insert("m", Mat::zeros(rows, cols));
        st
    }

    fn step(&self, g: &Mat, state: &mut State, _t: u64) -> Mat {
        let hp = &self.hp;
        let m = state.mat("m");
        let delta = Mat::from_fn(g.rows, g.cols, |i, j| {
            hp.alpha * (hp.b1 * m.at(i, j) + (1.0 - hp.b1) * g.at(i, j)).signum()
        });
        state.mats.get_mut("m").unwrap().ema_(hp.b2, g, 1.0 - hp.b2);
        delta
    }

    fn state_elems(&self, rows: usize, cols: usize) -> u64 {
        (rows * cols) as u64
    }
}

// -------------------------------------------------------------- Signum ----
pub struct Signum {
    pub hp: Hyper,
}

impl Optimizer for Signum {
    fn name(&self) -> &'static str {
        "signum"
    }

    fn init(&self, rows: usize, cols: usize) -> State {
        let mut st = State::default();
        st.mats.insert("m", Mat::zeros(rows, cols));
        st
    }

    fn step(&self, g: &Mat, state: &mut State, _t: u64) -> Mat {
        let hp = &self.hp;
        let m = state.mats.get_mut("m").unwrap();
        m.ema_(hp.b1, g, 1.0 - hp.b1);
        m.map(|x| hp.alpha * x.signum())
    }

    fn state_elems(&self, rows: usize, cols: usize) -> u64 {
        (rows * cols) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg;

    #[test]
    fn adam_first_step_is_sign_like() {
        // with bias correction, step 1 gives g/|g| (+eps fuzz)
        let hp = Hyper::default();
        let adam = Adam { hp };
        let mut st = adam.init(1, 3);
        let g = Mat::from_vec(1, 3, vec![0.5, -2.0, 0.0]);
        let d = adam.step(&g, &mut st, 1);
        assert!((d.data[0] - 1.0).abs() < 1e-3);
        assert!((d.data[1] + 1.0).abs() < 1e-3);
        assert_eq!(d.data[2], 0.0);
    }

    #[test]
    fn adam_moments_accumulate() {
        let adam = Adam { hp: Hyper::default() };
        let mut st = adam.init(2, 2);
        let g = Mat::from_vec(2, 2, vec![1.0; 4]);
        for t in 1..=10 {
            adam.step(&g, &mut st, t);
        }
        // m -> 1 - 0.9^10
        let want = 1.0 - 0.9f32.powi(10);
        assert!((st.mat("m").data[0] - want).abs() < 1e-5);
    }

    #[test]
    fn lion_is_sign_bounded() {
        let lion = Lion { hp: Hyper::default() };
        let mut st = lion.init(4, 4);
        let mut rng = Pcg::seeded(2);
        let g = Mat::from_vec(4, 4, rng.normal_vec(16, 3.0));
        let d = lion.step(&g, &mut st, 1);
        assert!(d.data.iter().all(|&x| x.abs() <= 1.0 + 1e-6));
    }

    #[test]
    fn adafactor_scales_by_factored_rms() {
        let af = Adafactor { hp: Hyper { b2: 0.0, ..Hyper::default() } };
        let mut st = af.init(2, 2);
        // rank-1 magnitude structure: v reconstructs exactly
        let g = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 4.0]);
        let d = af.step(&g, &mut st, 1);
        // all entries should normalize to roughly the same magnitude
        let mags: Vec<f32> = d.data.iter().map(|x| x.abs()).collect();
        for w in mags.windows(2) {
            assert!((w[0] - w[1]).abs() < 0.15, "{mags:?}");
        }
    }

    #[test]
    fn sgd_passthrough() {
        let s = Sgd { hp: Hyper { alpha: 2.0, ..Hyper::default() } };
        let g = Mat::from_vec(1, 2, vec![3.0, -1.0]);
        let d = s.step(&g, &mut State::default(), 1);
        assert_eq!(d.data, vec![6.0, -2.0]);
    }
}
