//! TOML-subset parser (no `toml` crate offline).
//!
//! Supports what the run configs need: `[section]` headers, `key = value`
//! with string / integer / float / bool / flat array values, `#` comments.
//! Nested tables beyond one level and multi-line values are rejected with
//! a clear error.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// section -> key -> value. Top-level (pre-section) keys live under "".
pub type Table = BTreeMap<String, BTreeMap<String, Value>>;

pub fn parse(text: &str) -> Result<Table> {
    let mut out: Table = BTreeMap::new();
    let mut section = String::new();
    out.entry(section.clone()).or_default();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') {
            if !line.ends_with(']') {
                bail!("line {}: unterminated section header", lineno + 1);
            }
            section = line[1..line.len() - 1].trim().to_string();
            if section.contains('[') || section.contains('.') {
                bail!("line {}: nested tables are not supported", lineno + 1);
            }
            out.entry(section.clone()).or_default();
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
        let key = line[..eq].trim().to_string();
        let val = parse_value(line[eq + 1..].trim())
            .map_err(|e| anyhow!("line {}: {e}", lineno + 1))?;
        out.get_mut(&section).unwrap().insert(key, val);
    }
    Ok(out)
}

fn strip_comment(line: &str) -> &str {
    // a '#' outside of quotes starts a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    if s.is_empty() {
        bail!("empty value");
    }
    if let Some(body) = s.strip_prefix('"') {
        let end = body
            .find('"')
            .ok_or_else(|| anyhow!("unterminated string"))?;
        if body[end + 1..].trim() != "" {
            bail!("trailing characters after string");
        }
        return Ok(Value::Str(body[..end].to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(body) = s.strip_prefix('[') {
        let body = body
            .strip_suffix(']')
            .ok_or_else(|| anyhow!("unterminated array"))?;
        let mut items = Vec::new();
        for part in body.split(',') {
            let part = part.trim();
            if !part.is_empty() {
                items.push(parse_value(part)?);
            }
        }
        return Ok(Value::Arr(items));
    }
    if !s.contains('.') && !s.contains('e') && !s.contains('E') {
        if let Ok(i) = s.parse::<i64>() {
            return Ok(Value::Int(i));
        }
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("cannot parse value {s:?}")
}

/// Typed lookup helpers over a parsed table.
pub struct View<'a> {
    pub table: &'a Table,
}

impl<'a> View<'a> {
    pub fn new(table: &'a Table) -> Self {
        View { table }
    }

    fn get(&self, section: &str, key: &str) -> Option<&'a Value> {
        self.table.get(section).and_then(|s| s.get(key))
    }

    pub fn str_or(&self, section: &str, key: &str, default: &str) -> String {
        self.get(section, key)
            .and_then(Value::as_str)
            .unwrap_or(default)
            .to_string()
    }

    pub fn f64_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(Value::as_f64).unwrap_or(default)
    }

    pub fn usize_or(&self, section: &str, key: &str, default: usize) -> usize {
        self.get(section, key)
            .and_then(Value::as_i64)
            .map(|i| i as usize)
            .unwrap_or(default)
    }

    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key).and_then(Value::as_bool).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# run config
title = "demo"

[train]
steps = 500
lr = 0.02          # cosine-decayed
optimizer = "alice"
last_layer_adam = true
sizes = [60, 130, 350]
"#;

    #[test]
    fn parses_sections_and_types() {
        let t = parse(SAMPLE).unwrap();
        let v = View::new(&t);
        assert_eq!(v.str_or("", "title", "?"), "demo");
        assert_eq!(v.usize_or("train", "steps", 0), 500);
        assert!((v.f64_or("train", "lr", 0.0) - 0.02).abs() < 1e-12);
        assert_eq!(v.str_or("train", "optimizer", "?"), "alice");
        assert!(v.bool_or("train", "last_layer_adam", false));
        match &t["train"]["sizes"] {
            Value::Arr(a) => assert_eq!(a.len(), 3),
            _ => panic!("expected array"),
        }
    }

    #[test]
    fn defaults_apply() {
        let t = parse("").unwrap();
        let v = View::new(&t);
        assert_eq!(v.usize_or("train", "steps", 7), 7);
    }

    #[test]
    fn rejects_nested_tables() {
        assert!(parse("[a.b]\nx = 1").is_err());
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(parse("just words").is_err());
        assert!(parse("x = ").is_err());
        assert!(parse("s = \"unterminated").is_err());
    }

    #[test]
    fn comment_inside_string_kept() {
        let t = parse("x = \"a # b\"").unwrap();
        assert_eq!(t[""]["x"], Value::Str("a # b".into()));
    }
}
