//! Typed run configuration: TOML file → `RunConfig`, plus the paper's
//! model-size presets used by the analytic memory tables.

pub mod presets;
pub mod toml;

use std::path::Path;

use anyhow::{Context, Result};

use crate::dist::{DistConfig, RoundMode, TransportKind};
use crate::opt::{Compen, Hyper, Refresh, Switch};
use toml::View;

/// Which execution path the trainer uses (DESIGN.md §1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecPath {
    /// grad_step HLO + native Rust per-layer optimizers (default).
    Coordinator,
    /// fully fused train_step_<opt> HLO (perf hot path).
    Fused,
}

#[derive(Debug, Clone)]
pub struct RunConfig {
    pub artifacts: String,
    pub out_dir: String,
    pub optimizer: String,
    pub steps: usize,
    pub lr: f32,
    pub warmup_frac: f32,
    pub min_lr_frac: f32,
    pub seed: u64,
    pub grad_accum: usize,
    /// Simulated data-parallel workers (grads averaged = all-reduce).
    pub workers: usize,
    /// Parallel execution backend width (`util::pool`): 0 = all available
    /// cores (default), 1 = exact historical serial behavior, N = N
    /// worker threads for the linalg kernels and the per-layer fan-out.
    pub threads: usize,
    /// Pre-spawn the persistent pool workers at trainer construction
    /// instead of lazily at the first parallel region (keeps the one-off
    /// spawn cost out of step 1's timing; default false = lazy).
    pub pool_warmup: bool,
    pub eval_every: usize,
    pub eval_batches: usize,
    /// Train the lm-head with full-rank Adam (the paper's "Ppl*" setup).
    pub last_layer_adam: bool,
    pub path: ExecPath,
    pub hp: Hyper,
    /// Corpus knobs.
    pub corpus_mix: f64,
    pub corpus_seed: u64,
    /// Log every N steps.
    pub log_every: usize,
    /// Checkpoint every N steps (0 = only at end).
    pub ckpt_every: usize,
    /// Simulated data-parallel cluster (`[dist]` section): when enabled
    /// the trainer routes each step through the round coordinator and
    /// shards the microbatch stream over `dp_workers` logical workers.
    pub dist: DistConfig,
    /// `[log] level` — stderr log threshold name (`--log-level`; the
    /// `ALICE_RACS_LOG` env var still wins, see `util::log::init_str`).
    pub log_level: String,
    /// `[log] trace_path` — Chrome trace-event JSON output (`--trace`).
    /// Empty = tracing off; the `AR_TRACE` env var still wins
    /// (`util::trace::resolve_path`).
    pub trace_path: String,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            artifacts: "artifacts".into(),
            out_dir: "runs/default".into(),
            optimizer: "alice".into(),
            steps: 300,
            lr: 0.02,
            warmup_frac: 0.1,
            min_lr_frac: 0.1,
            seed: 42,
            grad_accum: 1,
            workers: 1,
            threads: 0,
            pool_warmup: false,
            eval_every: 50,
            eval_batches: 4,
            last_layer_adam: true,
            path: ExecPath::Coordinator,
            hp: Hyper::default(),
            corpus_mix: 0.65,
            corpus_seed: 0x5eed,
            log_every: 10,
            ckpt_every: 0,
            dist: DistConfig::default(),
            log_level: "info".into(),
            trace_path: String::new(),
        }
    }
}

impl RunConfig {
    pub fn from_file(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::from_toml(&text)
    }

    pub fn from_toml(text: &str) -> Result<Self> {
        let table = toml::parse(text)?;
        let v = View::new(&table);
        let d = RunConfig::default();
        let hp_d = Hyper::default();
        let hp = Hyper {
            b1: v.f64_or("optimizer", "b1", hp_d.b1 as f64) as f32,
            b2: v.f64_or("optimizer", "b2", hp_d.b2 as f64) as f32,
            b3: v.f64_or("optimizer", "b3", hp_d.b3 as f64) as f32,
            eps: v.f64_or("optimizer", "eps", hp_d.eps as f64) as f32,
            rank: v.usize_or("optimizer", "rank", hp_d.rank),
            leading: v.usize_or("optimizer", "leading", hp_d.leading),
            interval: v.usize_or("optimizer", "interval", hp_d.interval),
            alpha: v.f64_or("optimizer", "alpha", hp_d.alpha as f64) as f32,
            alpha_c: v.f64_or("optimizer", "alpha_c", hp_d.alpha_c as f64) as f32,
            gamma: v.f64_or("optimizer", "gamma", hp_d.gamma as f64) as f32,
            beta_racs: v.f64_or("optimizer", "beta_racs", hp_d.beta_racs as f64) as f32,
            racs_iters: v.usize_or("optimizer", "racs_iters", hp_d.racs_iters),
            ns_iters: v.usize_or("optimizer", "ns_iters", hp_d.ns_iters),
            eig_sweeps: v.usize_or("optimizer", "eig_sweeps", hp_d.eig_sweeps),
            sub_iters: v.usize_or("optimizer", "sub_iters", hp_d.sub_iters),
            switch: Switch::parse(&v.str_or("optimizer", "switch", "switch"))?,
            compen: Compen::parse(&v.str_or("optimizer", "compen", "optimal"))?,
            racs_ema: v.bool_or("optimizer", "racs_ema", hp_d.racs_ema),
            bias_correction: v.bool_or("optimizer", "bias_correction", true),
            tracking: v.bool_or("optimizer", "tracking", true),
            refresh: Refresh::parse(&v.str_or("optimizer", "refresh", "exact"))?,
            sketch_oversample: v.usize_or(
                "optimizer",
                "sketch_oversample",
                hp_d.sketch_oversample,
            ),
            sketch_power_iters: v.usize_or(
                "optimizer",
                "sketch_power_iters",
                hp_d.sketch_power_iters,
            ),
            refresh_anchor_every: v.usize_or(
                "optimizer",
                "refresh_anchor_every",
                hp_d.refresh_anchor_every,
            ),
        };
        let path = match v.str_or("train", "path", "coordinator").as_str() {
            "fused" => ExecPath::Fused,
            _ => ExecPath::Coordinator,
        };
        let dist_d = DistConfig::default();
        let dist = DistConfig {
            dp_workers: v.usize_or("dist", "dp_workers", dist_d.dp_workers).max(1),
            sim: v.bool_or("dist", "sim", dist_d.sim),
            min_workers: v.usize_or("dist", "min_workers", dist_d.min_workers),
            warmup_ticks: v.usize_or("dist", "warmup_ticks", dist_d.warmup_ticks as usize)
                as u32,
            cooldown_ticks: v
                .usize_or("dist", "cooldown_ticks", dist_d.cooldown_ticks as usize)
                as u32,
            straggler_factor: v.f64_or("dist", "straggler_factor", dist_d.straggler_factor),
            transport: TransportKind::parse(&v.str_or("dist", "transport", "loopback"))?,
            round: RoundMode::parse(&v.str_or("dist", "round", "phased"))?,
            listen: v.str_or("dist", "listen", &dist_d.listen),
            connect: v.str_or("dist", "connect", &dist_d.connect),
            run_id: v.str_or("dist", "run_id", &dist_d.run_id),
            tick_ms: v.usize_or("dist", "tick_ms", dist_d.tick_ms as usize) as u64,
            join_timeout_s: v.f64_or("dist", "join_timeout_s", dist_d.join_timeout_s),
            round_timeout_s: v.f64_or("dist", "round_timeout_s", dist_d.round_timeout_s),
        };
        Ok(RunConfig {
            artifacts: v.str_or("", "artifacts", &d.artifacts),
            out_dir: v.str_or("", "out_dir", &d.out_dir),
            optimizer: v.str_or("train", "optimizer", &d.optimizer),
            steps: v.usize_or("train", "steps", d.steps),
            lr: v.f64_or("train", "lr", d.lr as f64) as f32,
            warmup_frac: v.f64_or("train", "warmup_frac", d.warmup_frac as f64) as f32,
            min_lr_frac: v.f64_or("train", "min_lr_frac", d.min_lr_frac as f64) as f32,
            seed: v.usize_or("train", "seed", d.seed as usize) as u64,
            grad_accum: v.usize_or("train", "grad_accum", d.grad_accum).max(1),
            workers: v.usize_or("train", "workers", d.workers).max(1),
            threads: v.usize_or("train", "threads", d.threads),
            pool_warmup: v.bool_or("train", "pool_warmup", d.pool_warmup),
            eval_every: v.usize_or("train", "eval_every", d.eval_every),
            eval_batches: v.usize_or("train", "eval_batches", d.eval_batches),
            last_layer_adam: v.bool_or("train", "last_layer_adam", d.last_layer_adam),
            path,
            hp,
            corpus_mix: v.f64_or("data", "mix", d.corpus_mix),
            corpus_seed: v.usize_or("data", "seed", d.corpus_seed as usize) as u64,
            log_every: v.usize_or("train", "log_every", d.log_every),
            ckpt_every: v.usize_or("train", "ckpt_every", d.ckpt_every),
            dist,
            log_level: v.str_or("log", "level", &d.log_level),
            trace_path: v.str_or("log", "trace_path", &d.trace_path),
        })
    }

    /// Paper-faithful per-optimizer defaults (App. F.2 tables 7-11),
    /// applied when the config doesn't override.
    pub fn tuned_for(mut self, optimizer: &str) -> Self {
        self.optimizer = optimizer.to_string();
        match optimizer {
            "adam" => {
                self.lr = 0.001;
            }
            "racs" => {
                self.lr = 0.02;
                // paper Table 9 uses α = 0.05 at 131k-token batches; on
                // this testbed's 512-token batches α = 0.2 is the sweep
                // optimum (EXPERIMENTS.md §Tuning)
                self.hp.alpha = 0.2;
                self.hp.beta_racs = 0.9;
            }
            "alice" | "alice0" => {
                self.lr = 0.02;
                self.hp.alpha = 0.3;
                self.hp.alpha_c = 0.4;
                self.hp.b2 = 0.9;
                self.hp.b3 = 0.999;
                self.hp.tracking = optimizer == "alice";
            }
            "galore" | "fira" => {
                self.lr = 0.02;
                self.hp.alpha = 0.3;
            }
            "apollo_mini" => {
                self.lr = 0.02;
                self.hp.alpha = 0.3;
            }
            "muon" | "swan" => {
                self.lr = 0.02;
                self.hp.alpha = 0.2;
            }
            "sgd" => {
                self.lr = 0.1;
            }
            "lion" | "signum" => {
                self.lr = 0.003;
            }
            "shampoo" | "soap" | "eigen_adam" => {
                self.lr = 0.003;
            }
            "adafactor" => {
                self.lr = 0.005;
            }
            _ => {}
        }
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_roundtrip() {
        let c = RunConfig::from_toml("").unwrap();
        assert_eq!(c.optimizer, "alice");
        assert_eq!(c.steps, 300);
        assert_eq!(c.path, ExecPath::Coordinator);
        assert_eq!(c.threads, 0, "default = auto (all cores)");
        assert!(!c.pool_warmup, "default = lazy worker spawn");
        assert!(!c.dist.enabled(), "dist simulation is opt-in");
        assert_eq!(c.dist.dp_workers, 1);
    }

    #[test]
    fn parses_dist_section() {
        let c = RunConfig::from_toml(
            "[dist]\ndp_workers = 4\nmin_workers = 2\nwarmup_ticks = 3\nsim = true\n",
        )
        .unwrap();
        assert!(c.dist.enabled());
        assert_eq!(c.dist.dp_workers, 4);
        assert_eq!(c.dist.min_workers, 2);
        assert_eq!(c.dist.warmup_ticks, 3);
        assert!(c.dist.sim);
        // dp_workers = 0 is clamped to 1, and sim alone enables the path
        let z = RunConfig::from_toml("[dist]\ndp_workers = 0\nsim = true\n").unwrap();
        assert_eq!(z.dist.dp_workers, 1);
        assert!(z.dist.enabled());
        // wire keys ride in the same section; loopback is the default,
        // and the round loop defaults to the phased reference schedule
        assert_eq!(z.dist.transport, TransportKind::Loopback);
        assert_eq!(z.dist.round, RoundMode::Phased);
        let p = RunConfig::from_toml("[dist]\ndp_workers = 2\nround = \"pipelined\"\n").unwrap();
        assert_eq!(p.dist.round, RoundMode::Pipelined);
        assert!(RunConfig::from_toml("[dist]\nround = \"overlapped\"\n").is_err());
        let w = RunConfig::from_toml(
            "[dist]\ndp_workers = 2\ntransport = \"tcp\"\nlisten = \"127.0.0.1:7401\"\n\
             run_id = \"exp9\"\ntick_ms = 2\njoin_timeout_s = 5.5\nround_timeout_s = 60\n",
        )
        .unwrap();
        assert_eq!(w.dist.transport, TransportKind::Tcp);
        assert_eq!(w.dist.listen, "127.0.0.1:7401");
        assert_eq!(w.dist.run_id, "exp9");
        assert_eq!(w.dist.tick_ms, 2);
        assert_eq!(w.dist.join_timeout_s, 5.5);
        assert_eq!(w.dist.round_timeout_s, 60.0);
        assert!(RunConfig::from_toml("[dist]\ntransport = \"carrier-pigeon\"\n").is_err());
    }

    #[test]
    fn parses_full_config() {
        let c = RunConfig::from_toml(
            r#"
artifacts = "artifacts"
out_dir = "runs/x"
[train]
optimizer = "racs"
steps = 100
lr = 0.01
path = "fused"
last_layer_adam = false
workers = 4
threads = 3
pool_warmup = true
[optimizer]
rank = 16
switch = "gaussian_mix"
compen = "fira"
[data]
mix = 0.5
"#,
        )
        .unwrap();
        assert_eq!(c.optimizer, "racs");
        assert_eq!(c.path, ExecPath::Fused);
        assert_eq!(c.workers, 4);
        assert_eq!(c.threads, 3);
        assert!(c.pool_warmup);
        assert_eq!(c.hp.rank, 16);
        assert_eq!(c.hp.switch, crate::opt::Switch::GaussianMix);
        assert_eq!(c.hp.compen, crate::opt::Compen::Fira);
        assert!((c.corpus_mix - 0.5).abs() < 1e-12);
        assert!(!c.last_layer_adam);
    }

    #[test]
    fn tuned_defaults_follow_paper() {
        let c = RunConfig::default().tuned_for("racs");
        assert!((c.lr - 0.02).abs() < 1e-6);
        assert!((c.hp.alpha - 0.2).abs() < 1e-6);
        let a = RunConfig::default().tuned_for("alice0");
        assert!(!a.hp.tracking);
        assert!((a.hp.b2 - 0.9).abs() < 1e-6);
    }

    #[test]
    fn bad_switch_rejected() {
        assert!(RunConfig::from_toml("[optimizer]\nswitch = \"bogus\"").is_err());
    }

    #[test]
    fn parses_refresh_section() {
        let c = RunConfig::from_toml(
            "[optimizer]\nrefresh = \"sketch\"\nsketch_oversample = 4\n\
             sketch_power_iters = 1\nrefresh_anchor_every = 5\n",
        )
        .unwrap();
        assert_eq!(c.hp.refresh, Refresh::Sketch);
        assert_eq!(c.hp.sketch_oversample, 4);
        assert_eq!(c.hp.sketch_power_iters, 1);
        assert_eq!(c.hp.refresh_anchor_every, 5);
        // defaults: exact refresh, paper-scale sketch geometry
        let d = RunConfig::from_toml("").unwrap();
        assert_eq!(d.hp.refresh, Refresh::Exact);
        assert_eq!(d.hp.sketch_oversample, 8);
        assert_eq!(d.hp.sketch_power_iters, 2);
        assert_eq!(d.hp.refresh_anchor_every, 8);
    }

    #[test]
    fn bad_refresh_rejected() {
        assert!(RunConfig::from_toml("[optimizer]\nrefresh = \"approx\"").is_err());
    }

    #[test]
    fn parses_log_section() {
        let c = RunConfig::from_toml(
            "[log]\nlevel = \"debug\"\ntrace_path = \"runs/t.json\"\n",
        )
        .unwrap();
        assert_eq!(c.log_level, "debug");
        assert_eq!(c.trace_path, "runs/t.json");
        // defaults: info, tracing off
        let d = RunConfig::from_toml("").unwrap();
        assert_eq!(d.log_level, "info");
        assert_eq!(d.trace_path, "");
    }
}
