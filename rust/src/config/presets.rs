//! Model-size presets: the paper's Table 10 LLaMA grid plus the local
//! CPU-trainable ladder (must stay in sync with `python/compile/model.py`).
//!
//! Used by the analytic memory accounting (Table 3 / Table 6 / Fig. 4) —
//! those tables are exact arithmetic over these shapes, so the paper's
//! llama* rows are reproduced verbatim even though only the local presets
//! are trained on this testbed.

/// Mirror of `model.ModelConfig`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModelPreset {
    pub name: &'static str,
    pub vocab: usize,
    pub dim: usize,
    pub inter: usize,
    pub heads: usize,
    pub layers: usize,
    pub seq: usize,
    pub batch: usize,
}

pub const PRESETS: &[ModelPreset] = &[
    ModelPreset { name: "nano", vocab: 256, dim: 64, inter: 176, heads: 4, layers: 2, seq: 64, batch: 8 },
    ModelPreset { name: "tiny", vocab: 512, dim: 128, inter: 344, heads: 4, layers: 4, seq: 64, batch: 8 },
    ModelPreset { name: "small", vocab: 1024, dim: 256, inter: 688, heads: 8, layers: 6, seq: 128, batch: 8 },
    ModelPreset { name: "mid", vocab: 2048, dim: 512, inter: 1376, heads: 8, layers: 8, seq: 128, batch: 8 },
    ModelPreset { name: "large", vocab: 8192, dim: 768, inter: 2048, heads: 12, layers: 12, seq: 128, batch: 8 },
    ModelPreset { name: "llama60m", vocab: 32000, dim: 512, inter: 1376, heads: 8, layers: 8, seq: 256, batch: 128 },
    ModelPreset { name: "llama130m", vocab: 32000, dim: 768, inter: 2048, heads: 12, layers: 12, seq: 256, batch: 128 },
    ModelPreset { name: "llama350m", vocab: 32000, dim: 1024, inter: 2736, heads: 16, layers: 24, seq: 256, batch: 128 },
    // paper Table 10 lists 4096x32 for "1.3B" (a typo: that is ~6.4B);
    // the GaLore-lineage 1B config is used instead (2048 hidden, 24 layers).
    ModelPreset { name: "llama1b", vocab: 32000, dim: 2048, inter: 5461, heads: 16, layers: 24, seq: 256, batch: 256 },
    ModelPreset { name: "llama7b", vocab: 32000, dim: 4096, inter: 11008, heads: 32, layers: 32, seq: 256, batch: 512 },
];

pub fn preset(name: &str) -> Option<&'static ModelPreset> {
    PRESETS.iter().find(|p| p.name == name)
}

/// Parameter shapes in canonical order — mirrors `model.param_specs`.
pub fn param_shapes(p: &ModelPreset) -> Vec<(String, Vec<usize>)> {
    let (d, f, v) = (p.dim, p.inter, p.vocab);
    let mut out: Vec<(String, Vec<usize>)> = vec![("embed".into(), vec![v, d])];
    for i in 0..p.layers {
        let pre = format!("layer{i}.");
        out.push((pre.clone() + "attn_norm", vec![d]));
        out.push((pre.clone() + "wq", vec![d, d]));
        out.push((pre.clone() + "wk", vec![d, d]));
        out.push((pre.clone() + "wv", vec![d, d]));
        out.push((pre.clone() + "wo", vec![d, d]));
        out.push((pre.clone() + "mlp_norm", vec![d]));
        out.push((pre.clone() + "w_gate", vec![d, f]));
        out.push((pre.clone() + "w_up", vec![d, f]));
        out.push((pre + "w_down", vec![f, d]));
    }
    out.push(("final_norm".into(), vec![d]));
    out.push(("lm_head".into(), vec![d, v]));
    out
}

pub fn num_params(p: &ModelPreset) -> u64 {
    param_shapes(p)
        .iter()
        .map(|(_, s)| s.iter().product::<usize>() as u64)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preset_lookup() {
        assert!(preset("tiny").is_some());
        assert!(preset("llama1b").is_some());
        assert!(preset("nope").is_none());
    }

    #[test]
    fn param_counts_match_python_model() {
        // values printed by python/compile/model.py (kept in sync by
        // python/tests/test_model.py on the other side)
        assert_eq!(num_params(preset("nano").unwrap()), 133_440);
        assert_eq!(num_params(preset("tiny").unwrap()), 922_752);
        assert_eq!(num_params(preset("small").unwrap()), 5_270_784);
        assert_eq!(num_params(preset("mid").unwrap()), 27_402_752);
    }

    #[test]
    fn llama_param_counts_in_paper_ballpark() {
        // paper's sizes are nominal (60M/130M/350M/1.3B); architecture
        // arithmetic should land within ~35% of nominal
        let check = |name: &str, nominal: f64| {
            let n = num_params(preset(name).unwrap()) as f64;
            assert!(
                (n / nominal - 1.0).abs() < 0.35,
                "{name}: {n} vs nominal {nominal}"
            );
        };
        check("llama60m", 60e6);
        check("llama130m", 130e6);
        check("llama350m", 350e6);
        check("llama1b", 1.3e9);
    }

    #[test]
    fn shapes_cover_all_layers() {
        let p = preset("tiny").unwrap();
        let shapes = param_shapes(p);
        assert_eq!(shapes.len(), 1 + 9 * p.layers + 2);
        assert_eq!(shapes[0].1, vec![512, 128]);
        assert_eq!(shapes.last().unwrap().1, vec![128, 512]);
    }
}
