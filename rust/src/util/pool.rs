//! Persistent worker pool — the parallel execution backend behind the
//! `linalg` kernels, the decompositions, and the trainer's per-layer /
//! eval / checkpoint fan-outs (no `rayon` offline — DESIGN.md
//! §Substitutions).
//!
//! # Lifecycle
//!
//! Workers are **long-lived parked threads**, spawned lazily the first
//! time a parallel region needs them (or eagerly via [`warmup`], wired to
//! the `[train] pool_warmup` / `--pool-warmup` knob) and sized by the
//! effective width at that moment. The pool only ever grows — up to the
//! largest `width - 1` any region has requested — and is shut down by
//! process exit: parked workers hold no resources beyond their stacks, so
//! there is deliberately no explicit teardown. Region submission is a
//! queue push + wake (~µs), replacing the per-region `std::thread::scope`
//! spawn (~100 µs) of the previous backend; the work-size thresholds in
//! `linalg` are tuned to that cheaper dispatch.
//!
//! # Thread-count resolution
//!
//! Effective width = thread-local override (set by [`with_threads`], and
//! propagated into workers per region so nested code sees the caller's
//! width) → else the global knob (set by [`set_threads`], wired from
//! `RunConfig.threads` / `--threads`) → else the `AR_BENCH_THREADS` env
//! var (read once; the CI matrix runs the test suite at widths 1 and 4
//! through it) → else all available cores. `0` always means "no opinion
//! at this level".
//!
//! # Nested regions
//!
//! A task may itself open a parallel region: the sub-region's helper jobs
//! go through the same global queue and are picked up by parked workers
//! (or reclaimed by the submitting task, which always participates in its
//! own region). This replaces the old "workers pin themselves to width 1"
//! fallback — decomposition sweeps inside the trainer's per-layer fan-out
//! now actually fan out. There is no deadlock: a region's caller runs its
//! own tasks inline, and unclaimed helper jobs are removed from the queue
//! (not waited on) when the caller finds the region drained.
//!
//! # Root-region thread budget
//!
//! Every *root* region (one opened by a thread not already inside a pool
//! region) creates a helper-permit budget of `width - 1`, threaded through
//! TLS to every task it transitively spawns. Any region — root or nested —
//! only pushes as many helper jobs as it can acquire permits for, and runs
//! the rest of its tasks inline on its caller; permits return when the
//! region retires. The knob is therefore a **hard cap**: a computation
//! rooted at width N never occupies more than N threads, even when the
//! pool holds more parked workers from an earlier, wider run (previously,
//! concurrent nested sibling regions could together exceed a lowered
//! knob — the ROADMAP thread-budget bug; pinned by
//! `tests/pool_lifecycle.rs::lowered_knob_is_a_hard_cap_for_nested_regions`).
//! Budget exhaustion only affects *scheduling* (how many helpers serve a
//! region), never partitioning — so it cannot change results (see the
//! determinism contract below).
//!
//! # Context bits and scratch
//!
//! [`with_context`] pins an opaque u32 of per-computation bits that
//! follows work into workers per region, exactly like the width override
//! — `linalg::simd` uses bit 0 to force scalar kernel dispatch for
//! baseline measurements, and the guarantee that workers see the
//! submitting computation's bits is what keeps a forced-scalar
//! measurement from silently mixing SIMD tiles on helper threads.
//! [`with_scratch`] hands out a reusable per-thread f32 workspace so
//! per-task buffers (packed matmul panels, blocked-Jacobi tile gathers)
//! skip the allocator — one reused buffer per nesting depth, so
//! re-entrant borrows compose instead of degrading to fresh temporaries.
//!
//! # Panic propagation
//!
//! A panic in any task aborts the region early (remaining indices are
//! skipped), is carried back to the submitting thread, and re-raised
//! there with the original payload once every in-flight helper has
//! stopped touching the region. Workers survive task panics and return to
//! the queue.
//!
//! # Determinism contract
//!
//! * Work partitioning is always a pure function of the *input sizes*,
//!   never of the thread count; combination of partial results happens on
//!   the calling thread in partition order. Results are therefore
//!   deterministic for a given thread count — and for every kernel whose
//!   per-partition float-op order matches the serial loop (the matmul
//!   family, transpose, all elementwise ops, the parallel decompositions
//!   in `linalg::decomp`) they are bitwise identical across *all* thread
//!   counts.
//! * Width 1 executes the caller's closures inline, in order, on the
//!   calling thread: exactly the pre-pool serial behavior.

use std::any::Any;
use std::cell::{Cell, RefCell};
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard, OnceLock};

/// Global width knob: 0 = auto (env var, then all available cores).
static GLOBAL_THREADS: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// Per-thread override: 0 = none. Workers run each region's tasks
    /// with this set to the submitting thread's effective width, so
    /// nested regions resolve the same width on any thread.
    static LOCAL_THREADS: Cell<usize> = const { Cell::new(0) };

    /// Helper-permit budget of the enclosing *root* region (null when the
    /// current thread is not inside a region). Propagated into workers per
    /// region, like the width, so nested regions draw from their root's
    /// budget instead of conjuring fresh threads.
    static LOCAL_BUDGET: Cell<*const Budget> = const { Cell::new(std::ptr::null()) };

    /// Opaque per-computation context bits (see [`with_context`]).
    static LOCAL_CTX: Cell<u32> = const { Cell::new(0) };

    /// Per-thread stack of f32 scratch buffers, indexed by borrow depth
    /// (see [`with_scratch`]).
    static SCRATCH: RefCell<ScratchStack> =
        const { RefCell::new(ScratchStack { bufs: Vec::new(), depth: 0 }) };
}

/// Depth-indexed scratch buffers: slot d serves the d-th nested
/// [`with_scratch`] borrow on this thread, so re-entrant borrows (a tile
/// gather feeding the packed-matmul panel packing, say) reuse their own
/// long-lived allocation instead of falling back to a fresh temporary.
struct ScratchStack {
    bufs: Vec<Vec<f32>>,
    depth: usize,
}

/// Root-region helper-permit counter. Lives on the root region's stack
/// frame; validity for nested regions follows from region nesting being
/// strictly within the root's dynamic extent (a nested region retires —
/// and releases its permits — before the root task that opened it
/// returns).
struct Budget {
    permits: AtomicUsize,
}

impl Budget {
    /// Take up to `want` permits; returns how many were granted (0 when
    /// the root's thread budget is exhausted — the region then runs
    /// inline on its caller).
    fn try_acquire(&self, want: usize) -> usize {
        let mut cur = self.permits.load(Ordering::Relaxed);
        loop {
            let take = want.min(cur);
            if take == 0 {
                return 0;
            }
            match self.permits.compare_exchange_weak(
                cur,
                cur - take,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return take,
                Err(seen) => cur = seen,
            }
        }
    }

    fn release(&self, n: usize) {
        self.permits.fetch_add(n, Ordering::Relaxed);
    }
}

/// Lock a mutex, ignoring poisoning: every critical section below is a
/// few plain loads/stores (no user code runs under a lock), so a poisoned
/// mutex still guards consistent data.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Number of hardware threads (1 if it cannot be determined). Cached —
/// `threads()` sits on every kernel call path and
/// `available_parallelism` is a syscall on Linux.
pub fn available() -> usize {
    static AVAILABLE: OnceLock<usize> = OnceLock::new();
    *AVAILABLE.get_or_init(|| {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}

/// `AR_BENCH_THREADS` fallback width (0 = unset/invalid). Read once: the
/// CI width matrix sets it for a whole process, and re-reading per call
/// would put `env::var` on the kernel dispatch path.
fn env_threads() -> usize {
    static ENV: OnceLock<usize> = OnceLock::new();
    *ENV.get_or_init(|| {
        std::env::var("AR_BENCH_THREADS")
            .ok()
            .and_then(|v| v.trim().parse().ok())
            .unwrap_or(0)
    })
}

/// Set the global pool width. `0` restores the default
/// (`AR_BENCH_THREADS`, else all cores).
pub fn set_threads(n: usize) {
    GLOBAL_THREADS.store(n, Ordering::Relaxed);
}

/// Effective pool width for the current thread (always ≥ 1).
pub fn threads() -> usize {
    let local = LOCAL_THREADS.with(|c| c.get());
    if local != 0 {
        return local;
    }
    let global = GLOBAL_THREADS.load(Ordering::Relaxed);
    if global != 0 {
        return global;
    }
    let env = env_threads();
    if env != 0 {
        env
    } else {
        available()
    }
}

/// Run `f` with the pool width pinned to `n` on this thread (`0` clears
/// the override). Scoped, re-entrant, and unwind-safe — the primary test
/// hook. The override follows the work into pool workers: regions opened
/// inside `f` tag their jobs with the effective width, so nested regions
/// resolve it on any thread.
pub fn with_threads<R>(n: usize, f: impl FnOnce() -> R) -> R {
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            LOCAL_THREADS.with(|c| c.set(self.0));
        }
    }
    let prev = LOCAL_THREADS.with(|c| {
        let p = c.get();
        c.set(n);
        p
    });
    let _restore = Restore(prev);
    f()
}

/// Per-computation context bits for the current thread. Like the width
/// override, the context follows work into pool workers per region, so a
/// kernel running on a helper thread sees the bits of the computation that
/// submitted it — never a stale value from an unrelated earlier region.
/// `linalg::simd` claims bit 0 (force-scalar dispatch for baseline
/// measurements); further layers may claim further bits.
pub fn context() -> u32 {
    LOCAL_CTX.with(|c| c.get())
}

/// Run `f` with the context word pinned to `bits` on this thread. Scoped,
/// re-entrant, and unwind-safe, mirroring [`with_threads`]; regions opened
/// inside `f` propagate the bits to every worker that serves them.
pub fn with_context<R>(bits: u32, f: impl FnOnce() -> R) -> R {
    struct Restore(u32);
    impl Drop for Restore {
        fn drop(&mut self) {
            LOCAL_CTX.with(|c| c.set(self.0));
        }
    }
    let prev = LOCAL_CTX.with(|c| {
        let p = c.get();
        c.set(bits);
        p
    });
    let _restore = Restore(prev);
    f()
}

/// RAII guard for [`scoped_context`]; restores the previous context word
/// on drop.
pub struct CtxGuard {
    prev: u32,
}

impl Drop for CtxGuard {
    fn drop(&mut self) {
        LOCAL_CTX.with(|c| c.set(self.prev));
    }
}

/// Replace the `mask` slice of this thread's context word with `bits`
/// (other bits untouched) until the returned guard drops. Guard-style
/// sibling of [`with_context`] for callers that can't wrap a closure —
/// the span tracer stamps its region token this way (`util::trace`
/// claims the upper 16 bits; bit 0 remains `linalg::simd`'s).
pub fn scoped_context(mask: u32, bits: u32) -> CtxGuard {
    LOCAL_CTX.with(|c| {
        let prev = c.get();
        c.set((prev & !mask) | (bits & mask));
        CtxGuard { prev }
    })
}

/// Borrow a thread-local f32 scratch buffer of at least `len` elements.
/// Contents are **unspecified** on entry (stale bytes from earlier
/// borrows) — callers must overwrite everything they read. One allocation
/// per thread *per nesting depth* is reused across tasks: the first
/// borrow always sees the same buffer, and a re-entrant borrow (a task
/// needing scratch while its caller holds it — the blocked-Jacobi tile
/// gather feeding the packed matmul's panel packing) gets its own reused
/// slot one depth down instead of a throwaway allocation. Unwind-safe:
/// the depth and buffer are restored even when `f` panics.
pub fn with_scratch<R>(len: usize, f: impl FnOnce(&mut [f32]) -> R) -> R {
    struct Restore {
        buf: Vec<f32>,
        depth: usize,
    }
    impl Drop for Restore {
        fn drop(&mut self) {
            SCRATCH.with(|cell| {
                let mut st = cell.borrow_mut();
                st.bufs[self.depth] = std::mem::take(&mut self.buf);
                st.depth = self.depth;
            });
        }
    }
    let mut restore = SCRATCH.with(|cell| {
        let mut st = cell.borrow_mut();
        let d = st.depth;
        if st.bufs.len() <= d {
            st.bufs.push(Vec::new());
        }
        st.depth = d + 1;
        Restore { buf: std::mem::take(&mut st.bufs[d]), depth: d }
    });
    if restore.buf.len() < len {
        restore.buf.resize(len, 0.0);
    }
    f(&mut restore.buf[..len])
}

// ------------------------------------------------------------ the pool ---

/// One queued helper job: a type-erased pointer pair into the submitting
/// thread's stack frame. Validity is guaranteed by the region protocol —
/// the submitting call does not return until every pushed job has either
/// run to completion or been removed from the queue unclaimed.
#[derive(Clone, Copy)]
struct Job {
    header: *const RegionHeader,
    task: *const (),
    entry: unsafe fn(*const RegionHeader, *const ()),
}

// SAFETY: the raw pointers are only dereferenced while the owning region
// is alive (see Job doc comment); the pointees are Sync.
unsafe impl Send for Job {}

struct Pool {
    queue: Mutex<VecDeque<Job>>,
    /// Wakes parked workers when jobs are pushed.
    work_cv: Condvar,
    /// Workers spawned so far (monotonic — the pool never shrinks).
    spawned: Mutex<usize>,
}

fn pool() -> &'static Pool {
    static POOL: OnceLock<Pool> = OnceLock::new();
    POOL.get_or_init(|| Pool {
        queue: Mutex::new(VecDeque::new()),
        work_cv: Condvar::new(),
        spawned: Mutex::new(0),
    })
}

/// Shared per-region state, allocated on the submitting thread's stack.
struct RegionHeader {
    /// Next unclaimed task index (dynamic work stealing).
    next: AtomicUsize,
    n: usize,
    /// The submitting thread's effective width — workers adopt it while
    /// running this region's tasks so nested regions resolve identically.
    nested_width: usize,
    /// The submitting thread's context bits — adopted alongside the width.
    nested_ctx: u32,
    /// The enclosing root region's helper budget — workers adopt it too,
    /// so regions they open draw from the same cap.
    budget: *const Budget,
    /// Helper jobs pushed and not yet finished or reclaimed.
    pending: Mutex<usize>,
    done_cv: Condvar,
    /// First panic payload raised by any task in this region.
    panic: Mutex<Option<Box<dyn Any + Send>>>,
}

/// Claim-and-run loop shared by the submitting thread and the workers.
/// Panics are captured into the header and abort the region early.
fn claim_loop<F: Fn(usize) + Sync>(h: &RegionHeader, f: &F) {
    let result = catch_unwind(AssertUnwindSafe(|| loop {
        let i = h.next.fetch_add(1, Ordering::Relaxed);
        if i >= h.n {
            break;
        }
        f(i);
    }));
    if let Err(payload) = result {
        // abort: park the claim counter at the end so other claimers stop
        h.next.store(h.n, Ordering::Relaxed);
        let mut slot = lock(&h.panic);
        if slot.is_none() {
            *slot = Some(payload);
        }
    }
}

/// Monomorphized worker-side entry for a helper job.
///
/// SAFETY (caller): `header` and `task` must point at a live
/// `RegionHeader` and the matching `F` of the same region.
unsafe fn helper_entry<F: Fn(usize) + Sync>(header: *const RegionHeader, task: *const ()) {
    let h = unsafe { &*header };
    let f = unsafe { &*(task as *const F) };
    let prev = LOCAL_THREADS.with(|c| {
        let p = c.get();
        c.set(h.nested_width);
        p
    });
    let prev_ctx = LOCAL_CTX.with(|c| {
        let p = c.get();
        c.set(h.nested_ctx);
        p
    });
    let prev_budget = LOCAL_BUDGET.with(|c| {
        let p = c.get();
        c.set(h.budget);
        p
    });
    claim_loop(h, f);
    // Persistent workers never run TLS destructors between regions, so
    // hand any spans this region recorded to the tracer sink now (one
    // atomic load when tracing is off).
    crate::util::trace::flush_thread();
    LOCAL_BUDGET.with(|c| c.set(prev_budget));
    LOCAL_CTX.with(|c| c.set(prev_ctx));
    LOCAL_THREADS.with(|c| c.set(prev));
    // Completion handshake: decrement-and-notify under the lock, then
    // never touch `h` again — the submitting thread may free the region
    // the moment it observes pending == 0.
    let mut pending = lock(&h.pending);
    *pending -= 1;
    if *pending == 0 {
        h.done_cv.notify_all();
    }
}

/// One completed task's result en route to the consuming caller of a
/// [`map_consume`] region, or the abort signal that unblocks the caller
/// when a task panicked (the payload travels via `RegionHeader::panic`).
enum Delivery<T> {
    Done(usize, T),
    Aborted,
}

/// Region-local delivery queue for [`map_consume`]: helpers push, the
/// submitting thread drains. Lives on the submitting frame next to the
/// `RegionHeader`, valid for the same region lifetime.
struct ConsumeQueue<T> {
    q: Mutex<VecDeque<Delivery<T>>>,
    cv: Condvar,
}

/// Type-erased pointer pair a [`map_consume`] job carries: the task
/// closure plus the delivery queue, both on the submitting thread's
/// frame (same validity argument as [`Job`]).
struct ConsumeTask<T> {
    f: *const (),
    q: *const ConsumeQueue<T>,
}

/// Monomorphized worker-side entry for a [`map_consume`] job: claim
/// tasks, run them, push each result to the region's delivery queue.
/// Mirrors [`helper_entry`]'s TLS adoption, panic capture, and completion
/// handshake.
///
/// SAFETY (caller): `header` must point at a live `RegionHeader` and
/// `task` at the matching `ConsumeTask<T>` of the same region, whose `f`
/// points at an `F`.
unsafe fn consume_entry<T: Send, F: Fn(usize) -> T + Sync>(
    header: *const RegionHeader,
    task: *const (),
) {
    let h = unsafe { &*header };
    let ct = unsafe { &*(task as *const ConsumeTask<T>) };
    let f = unsafe { &*(ct.f as *const F) };
    let queue = unsafe { &*ct.q };
    let prev = LOCAL_THREADS.with(|c| {
        let p = c.get();
        c.set(h.nested_width);
        p
    });
    let prev_ctx = LOCAL_CTX.with(|c| {
        let p = c.get();
        c.set(h.nested_ctx);
        p
    });
    let prev_budget = LOCAL_BUDGET.with(|c| {
        let p = c.get();
        c.set(h.budget);
        p
    });
    let result = catch_unwind(AssertUnwindSafe(|| loop {
        let i = h.next.fetch_add(1, Ordering::Relaxed);
        if i >= h.n {
            break;
        }
        let v = f(i);
        lock(&queue.q).push_back(Delivery::Done(i, v));
        queue.cv.notify_all();
    }));
    if let Err(payload) = result {
        // abort: park the claim counter, store the payload, and unblock
        // the consuming caller so it can proceed to the retire protocol
        h.next.store(h.n, Ordering::Relaxed);
        {
            let mut slot = lock(&h.panic);
            if slot.is_none() {
                *slot = Some(payload);
            }
        }
        lock(&queue.q).push_back(Delivery::Aborted);
        queue.cv.notify_all();
    }
    crate::util::trace::flush_thread();
    LOCAL_BUDGET.with(|c| c.set(prev_budget));
    LOCAL_CTX.with(|c| c.set(prev_ctx));
    LOCAL_THREADS.with(|c| c.set(prev));
    let mut pending = lock(&h.pending);
    *pending -= 1;
    if *pending == 0 {
        h.done_cv.notify_all();
    }
}

fn worker_loop() {
    let p = pool();
    loop {
        let job = {
            let mut q = lock(&p.queue);
            loop {
                if let Some(j) = q.pop_front() {
                    break j;
                }
                q = p.work_cv.wait(q).unwrap_or_else(|e| e.into_inner());
            }
        };
        // SAFETY: queued jobs are valid until their region retires them —
        // see the Job invariant.
        unsafe { (job.entry)(job.header, job.task) };
    }
}

/// Grow the pool to at least `target` parked workers.
fn ensure_workers(target: usize) {
    let p = pool();
    let mut count = lock(&p.spawned);
    while *count < target {
        *count += 1;
        std::thread::Builder::new()
            .name(format!("ar-pool-{count}"))
            .spawn(worker_loop)
            .expect("spawning pool worker");
    }
}

/// Pre-spawn the workers for the current effective width. Purely an
/// optimization — the first parallel region spawns lazily otherwise.
pub fn warmup() {
    let w = threads();
    if w > 1 {
        ensure_workers(w - 1);
    }
}

/// Number of persistent workers spawned so far. Monotonic (the pool
/// never shrinks) — the lifecycle tests use it to pin down reuse.
pub fn worker_count() -> usize {
    *lock(&pool().spawned)
}

/// Execute `f(0), f(1), …, f(n-1)` across the pool.
///
/// Tasks are claimed dynamically (atomic counter), so callers may hand in
/// tasks of very different cost — the trainer's per-layer fan-out relies
/// on this. `f` must only touch data disjoint per index (shared reads are
/// fine). With an effective width of 1 the tasks run inline, in order.
pub fn run(n: usize, f: impl Fn(usize) + Sync) {
    run_ref(n, &f)
}

fn run_ref<F: Fn(usize) + Sync>(n: usize, f: &F) {
    let width = threads().min(n);
    if width <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    // Resolve the root budget: inherit the enclosing region's (nested
    // case) or open one sized by this thread's effective width (root
    // case). `root_storage` keeps the root budget alive on this frame for
    // the whole region, including every nested region inside it.
    let inherited = LOCAL_BUDGET.with(|c| c.get());
    let root_storage;
    let budget: &Budget = if inherited.is_null() {
        root_storage = Budget { permits: AtomicUsize::new(threads() - 1) };
        &root_storage
    } else {
        // SAFETY: a non-null TLS budget points at the root region's stack
        // frame, which outlives every region nested inside it (see Budget).
        unsafe { &*inherited }
    };
    let helpers = budget.try_acquire(width - 1);
    if helpers == 0 {
        // root thread budget exhausted: the region still runs — inline,
        // on its caller, in order (partitioning is unchanged; only the
        // helper count is)
        for i in 0..n {
            f(i);
        }
        return;
    }
    crate::obs::POOL_DISPATCHES.incr();
    let header = RegionHeader {
        next: AtomicUsize::new(0),
        n,
        nested_width: threads(),
        nested_ctx: context(),
        budget: budget as *const Budget,
        pending: Mutex::new(helpers),
        done_cv: Condvar::new(),
        panic: Mutex::new(None),
    };
    ensure_workers(helpers);
    let p = pool();
    {
        let mut q = lock(&p.queue);
        for _ in 0..helpers {
            q.push_back(Job {
                header: &header,
                task: f as *const F as *const (),
                entry: helper_entry::<F>,
            });
        }
    }
    p.work_cv.notify_all();
    // The submitting thread is always worker 0 of its own region; it
    // carries the root budget in TLS so regions opened by *its* tasks
    // share the cap (workers get it via the header).
    let prev_budget = LOCAL_BUDGET.with(|c| {
        let p = c.get();
        c.set(budget as *const Budget);
        p
    });
    claim_loop(&header, f);
    LOCAL_BUDGET.with(|c| c.set(prev_budget));
    // Retire the region: reclaim helper jobs nobody picked up, then wait
    // out the in-flight ones. After this block no pointer to `header` or
    // `f` exists outside this frame.
    {
        let mut q = lock(&p.queue);
        let before = q.len();
        let me: *const RegionHeader = &header;
        q.retain(|j| !std::ptr::eq(j.header, me));
        let removed = before - q.len();
        drop(q);
        if removed > 0 {
            *lock(&header.pending) -= removed;
        }
    }
    let mut pending = lock(&header.pending);
    while *pending > 0 {
        pending = header.done_cv.wait(pending).unwrap_or_else(|e| e.into_inner());
    }
    drop(pending);
    // Every helper has stopped touching the region — give its permits
    // back to the root budget before re-raising any captured panic.
    budget.release(helpers);
    if let Some(payload) = lock(&header.panic).take() {
        resume_unwind(payload);
    }
}

/// Like [`run`], collecting each task's result; the returned vector is in
/// task order regardless of which worker ran what.
pub fn map<T: Send>(n: usize, f: impl Fn(usize) -> T + Sync) -> Vec<T> {
    let width = threads().min(n);
    if width <= 1 {
        return (0..n).map(f).collect();
    }
    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let base = SendPtr(slots.as_mut_ptr());
    let task = move |i: usize| {
        // SAFETY: `run_ref` hands each index to exactly one task, so this
        // is the only writer of slots[i]; i < n = slots.len().
        unsafe { *base.0.add(i) = Some(f(i)) };
    };
    run_ref(n, &task);
    slots.into_iter().map(|o| o.expect("pool task not executed")).collect()
}

/// Mutate each item of `items` across the pool, collecting one result per
/// item (in item order). Each task gets exclusive `&mut` access to its
/// item; `f` sees the item index alongside.
pub fn map_mut<T: Send, R: Send>(
    items: &mut [T],
    f: impl Fn(usize, &mut T) -> R + Sync,
) -> Vec<R> {
    let n = items.len();
    let width = threads().min(n);
    if width <= 1 {
        return items.iter_mut().enumerate().map(|(i, it)| f(i, it)).collect();
    }
    let base = SendPtr(items.as_mut_ptr());
    map(n, move |i| {
        // SAFETY: `map` hands each index to exactly one task, so this is
        // the only live reference to items[i]; i < n = items.len().
        let item = unsafe { &mut *base.0.add(i) };
        f(i, item)
    })
}

/// Completion-notification fan-out: run `f(0), …, f(n-1)` across the pool
/// like [`map`], but hand each task's result to `consume` **as soon as it
/// is available** instead of collecting a vector — the primitive behind
/// the pipelined DP round (shard results feed the eager tree reduce while
/// other shards are still computing).
///
/// Contract:
///
/// * `consume` always runs on the **calling thread** — single-threaded
///   sinks need no locks, and trace spans recorded inside it attribute to
///   the submitting computation.
/// * Every index is consumed exactly once (unless a task panics, which
///   aborts the region and re-raises on the caller, like [`run`]).
/// * Consumption *order* follows completion and is nondeterministic at
///   width > 1; at width ≤ 1 (or an exhausted root budget) tasks run
///   inline, interleaved `f(i)` then `consume(i, ·)` in index order.
///   Callers needing deterministic results must use an order-insensitive
///   sink — scheduling-only, never merge order.
pub fn map_consume<T: Send, F: Fn(usize) -> T + Sync>(
    n: usize,
    f: F,
    mut consume: impl FnMut(usize, T),
) {
    let width = threads().min(n);
    if width <= 1 {
        for i in 0..n {
            let v = f(i);
            consume(i, v);
        }
        return;
    }
    // Root-budget resolution, exactly as in `run_ref`.
    let inherited = LOCAL_BUDGET.with(|c| c.get());
    let root_storage;
    let budget: &Budget = if inherited.is_null() {
        root_storage = Budget { permits: AtomicUsize::new(threads() - 1) };
        &root_storage
    } else {
        // SAFETY: a non-null TLS budget points at the root region's stack
        // frame, which outlives every region nested inside it (see Budget).
        unsafe { &*inherited }
    };
    let helpers = budget.try_acquire(width - 1);
    if helpers == 0 {
        for i in 0..n {
            let v = f(i);
            consume(i, v);
        }
        return;
    }
    crate::obs::POOL_DISPATCHES.incr();
    let header = RegionHeader {
        next: AtomicUsize::new(0),
        n,
        nested_width: threads(),
        nested_ctx: context(),
        budget: budget as *const Budget,
        pending: Mutex::new(helpers),
        done_cv: Condvar::new(),
        panic: Mutex::new(None),
    };
    let queue: ConsumeQueue<T> =
        ConsumeQueue { q: Mutex::new(VecDeque::new()), cv: Condvar::new() };
    let ct = ConsumeTask::<T> {
        f: &f as *const _ as *const (),
        q: &queue as *const ConsumeQueue<T>,
    };
    ensure_workers(helpers);
    let p = pool();
    {
        let mut q = lock(&p.queue);
        for _ in 0..helpers {
            q.push_back(Job {
                header: &header,
                task: &ct as *const ConsumeTask<T> as *const (),
                entry: consume_entry::<T, F>,
            });
        }
    }
    p.work_cv.notify_all();
    let prev_budget = LOCAL_BUDGET.with(|c| {
        let pb = c.get();
        c.set(budget as *const Budget);
        pb
    });
    // The caller is worker 0 of its own region: claim tasks, consume its
    // own results inline, opportunistically drain helper deliveries
    // between claims, then block for the stragglers. Every claimed index
    // produces exactly one delivery (inline or queued), so `n` consumed
    // means the region's work is fully accounted for.
    let caller_result = catch_unwind(AssertUnwindSafe(|| {
        let mut consumed = 0usize;
        loop {
            let i = header.next.fetch_add(1, Ordering::Relaxed);
            if i >= n {
                break;
            }
            let v = f(i);
            consume(i, v);
            consumed += 1;
            loop {
                let d = lock(&queue.q).pop_front();
                match d {
                    Some(Delivery::Done(j, v)) => {
                        consume(j, v);
                        consumed += 1;
                    }
                    Some(Delivery::Aborted) => return,
                    None => break,
                }
            }
        }
        while consumed < n {
            let d = {
                let mut q = lock(&queue.q);
                loop {
                    if let Some(d) = q.pop_front() {
                        break d;
                    }
                    q = queue.cv.wait(q).unwrap_or_else(|e| e.into_inner());
                }
            };
            match d {
                Delivery::Done(j, v) => {
                    consume(j, v);
                    consumed += 1;
                }
                Delivery::Aborted => return,
            }
        }
    }));
    LOCAL_BUDGET.with(|c| c.set(prev_budget));
    if let Err(payload) = caller_result {
        header.next.store(n, Ordering::Relaxed);
        let mut slot = lock(&header.panic);
        if slot.is_none() {
            *slot = Some(payload);
        }
    }
    // Retire the region exactly as `run_ref` does: reclaim unclaimed
    // helper jobs, wait out the in-flight ones, release the permits, then
    // re-raise any captured panic. Undelivered queue entries (abort
    // paths) drop with this frame.
    {
        let mut q = lock(&p.queue);
        let before = q.len();
        let me: *const RegionHeader = &header;
        q.retain(|j| !std::ptr::eq(j.header, me));
        let removed = before - q.len();
        drop(q);
        if removed > 0 {
            *lock(&header.pending) -= removed;
        }
    }
    let mut pending = lock(&header.pending);
    while *pending > 0 {
        pending = header.done_cv.wait(pending).unwrap_or_else(|e| e.into_inner());
    }
    drop(pending);
    budget.release(helpers);
    if let Some(payload) = lock(&header.panic).take() {
        resume_unwind(payload);
    }
}

/// Split `data` into contiguous chunks of `chunk_len` elements (the last
/// may be short) and run `f(chunk_index, chunk)` across the pool. The
/// chunk geometry depends only on `data.len()` and `chunk_len`, keeping
/// results deterministic for any pool width.
pub fn for_each_chunk_mut<T: Send>(
    data: &mut [T],
    chunk_len: usize,
    f: impl Fn(usize, &mut [T]) + Sync,
) {
    assert!(chunk_len > 0, "chunk_len must be positive");
    let len = data.len();
    if len == 0 {
        return;
    }
    let n = len.div_ceil(chunk_len);
    let base = SendPtr(data.as_mut_ptr());
    run(n, move |i| {
        let lo = i * chunk_len;
        let hi = (lo + chunk_len).min(len);
        // SAFETY: [lo, hi) ranges are disjoint across chunk indices and
        // within bounds; `run` gives each index to exactly one task.
        let chunk = unsafe { std::slice::from_raw_parts_mut(base.0.add(lo), hi - lo) };
        f(i, chunk);
    });
}

/// Raw-pointer wrapper so disjoint-range writers can cross the closure
/// `Sync` bound. Soundness is argued at each use site: the caller must
/// guarantee every task index touches a disjoint element/range (the
/// [`run`]/[`map_consume`] contract of one task per index makes that
/// easy). Public because external drivers (benches, the dist demo) use
/// the same disjoint-index fan-out idiom as the in-crate kernels.
pub struct SendPtr<T>(pub *mut T);

unsafe impl<T> Send for SendPtr<T> {}

unsafe impl<T> Sync for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn run_covers_every_index_once() {
        let hits: Vec<AtomicU32> = (0..100).map(|_| AtomicU32::new(0)).collect();
        with_threads(4, || {
            run(100, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn map_preserves_order() {
        for width in [1, 2, 5] {
            let out = with_threads(width, || map(37, |i| i * i));
            assert_eq!(out, (0..37).map(|i| i * i).collect::<Vec<_>>());
        }
    }

    #[test]
    fn map_mut_touches_each_item() {
        let mut items: Vec<usize> = (0..50).collect();
        let doubled = with_threads(3, || map_mut(&mut items, |i, it| {
            *it += 1;
            i * 2
        }));
        assert_eq!(items, (1..=50).collect::<Vec<_>>());
        assert_eq!(doubled, (0..50).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn map_consume_covers_every_index_once_on_the_caller_thread() {
        for width in [1, 2, 4, 7] {
            let caller = std::thread::current().id();
            let mut seen = vec![0u32; 53];
            let mut on_caller = true;
            with_threads(width, || {
                map_consume(
                    53,
                    |i| i * 3,
                    |i, v| {
                        assert_eq!(v, i * 3);
                        seen[i] += 1;
                        on_caller &= std::thread::current().id() == caller;
                    },
                );
            });
            assert!(seen.iter().all(|&c| c == 1), "width {width}: {seen:?}");
            assert!(on_caller, "consume must run on the calling thread");
        }
    }

    #[test]
    fn map_consume_is_index_ordered_at_width_one() {
        let mut order = Vec::new();
        with_threads(1, || map_consume(9, |i| i, |i, _| order.push(i)));
        assert_eq!(order, (0..9).collect::<Vec<_>>());
    }

    #[test]
    fn map_consume_propagates_task_panics() {
        let caught = catch_unwind(AssertUnwindSafe(|| {
            with_threads(4, || {
                map_consume(64, |i| {
                    if i == 23 {
                        panic!("boom at 23");
                    }
                    i
                }, |_, _| {});
            });
        }));
        let payload = caught.expect_err("task panic must propagate");
        let msg = payload
            .downcast_ref::<&str>()
            .copied()
            .or_else(|| payload.downcast_ref::<String>().map(|s| s.as_str()))
            .unwrap_or("");
        assert!(msg.contains("boom at 23"), "payload preserved, got {msg:?}");
        // the pool survives and keeps serving regions
        let out = with_threads(4, || map(16, |i| i + 1));
        assert_eq!(out, (1..=16).collect::<Vec<_>>());
    }

    #[test]
    fn map_consume_nests_inside_regions_and_respects_the_budget() {
        // opened inside a width-2 root whose budget is already partly
        // spent, the inner map_consume must still consume every index
        // (serial-inline fallback when no permits remain)
        let hits: Vec<AtomicUsize> = (0..4 * 16).map(|_| AtomicUsize::new(0)).collect();
        with_threads(2, || {
            run(4, |outer| {
                let mut local = 0;
                map_consume(16, |i| i, |i, _| {
                    hits[outer * 16 + i].fetch_add(1, Ordering::SeqCst);
                    local += 1;
                });
                assert_eq!(local, 16);
            });
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn chunks_are_exact_and_ragged_tail_works() {
        let mut data = vec![0u32; 103];
        with_threads(4, || {
            for_each_chunk_mut(&mut data, 10, |ci, chunk| {
                assert_eq!(chunk.len(), if ci == 10 { 3 } else { 10 });
                for x in chunk.iter_mut() {
                    *x = ci as u32;
                }
            });
        });
        for (i, &x) in data.iter().enumerate() {
            assert_eq!(x, (i / 10) as u32);
        }
    }

    #[test]
    fn nested_regions_share_the_callers_width() {
        // workers adopt the submitting thread's effective width, so a
        // nested region fans out instead of degrading to serial
        let widths: Vec<AtomicU32> = (0..8).map(|_| AtomicU32::new(0)).collect();
        let inner: Vec<AtomicU32> = (0..64).map(|_| AtomicU32::new(0)).collect();
        with_threads(4, || {
            run(8, |i| {
                widths[i].store(threads() as u32, Ordering::Relaxed);
                run(8, |j| {
                    inner[i * 8 + j].fetch_add(1, Ordering::Relaxed);
                });
            });
        });
        assert!(widths.iter().all(|t| t.load(Ordering::Relaxed) == 4));
        assert!(inner.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn workers_persist_across_regions() {
        // grow past anything the sibling unit tests ask for, then verify
        // that further regions reuse the parked workers instead of
        // spawning new ones
        let w = available().max(8);
        with_threads(w, || run(4 * w, |_| {}));
        let settled = worker_count();
        assert!(settled >= w - 1);
        for _ in 0..32 {
            with_threads(4, || run(64, |_| {}));
        }
        assert_eq!(worker_count(), settled, "regions must reuse parked workers");
    }

    #[test]
    fn panics_propagate_to_the_submitter() {
        let caught = catch_unwind(|| {
            with_threads(4, || {
                run(64, |i| {
                    if i == 17 {
                        panic!("boom at 17");
                    }
                });
            });
        });
        let payload = caught.expect_err("worker panic must propagate");
        let msg = payload
            .downcast_ref::<&str>()
            .copied()
            .or_else(|| payload.downcast_ref::<String>().map(|s| s.as_str()))
            .unwrap_or("");
        assert!(msg.contains("boom at 17"), "payload preserved, got {msg:?}");
        // the pool survives and keeps serving regions
        let out = with_threads(4, || map(32, |i| i + 1));
        assert_eq!(out, (1..=32).collect::<Vec<_>>());
    }

    #[test]
    fn with_threads_restores_previous_width() {
        with_threads(3, || {
            assert_eq!(threads(), 3);
            with_threads(1, || assert_eq!(threads(), 1));
            assert_eq!(threads(), 3);
        });
    }

    #[test]
    fn zero_length_inputs_are_noops() {
        with_threads(4, || {
            run(0, |_| panic!("must not run"));
            assert!(map(0, |i| i).is_empty());
            let mut empty: [f32; 0] = [];
            for_each_chunk_mut(&mut empty, 8, |_, _| panic!("must not run"));
        });
    }

    #[test]
    fn warmup_prespawns_for_the_effective_width() {
        with_threads(5, warmup);
        assert!(worker_count() >= 4);
    }

    #[test]
    fn context_bits_follow_work_into_workers() {
        with_context(0b101, || {
            assert_eq!(context(), 0b101);
            with_threads(4, || {
                let seen = map(16, |_| context());
                assert!(seen.iter().all(|&c| c == 0b101), "workers saw {seen:?}");
                // nested regions too
                run(4, |_| {
                    assert_eq!(context(), 0b101);
                    run(4, |_| assert_eq!(context(), 0b101));
                });
            });
            // re-entrant override and restore
            with_context(0b10, || assert_eq!(context(), 0b10));
            assert_eq!(context(), 0b101);
        });
        assert_eq!(context(), 0);
    }

    #[test]
    fn scratch_is_reused_and_reentrant() {
        let cap = with_scratch(100, |buf| {
            assert_eq!(buf.len(), 100);
            for x in buf.iter_mut() {
                *x = 7.0;
            }
            buf.as_ptr() as usize
        });
        // second borrow on the same thread reuses the allocation (same
        // base pointer for a fit-sized request) and exposes stale bytes
        with_scratch(50, |buf| {
            assert_eq!(buf.as_ptr() as usize, cap);
            assert_eq!(buf[49], 7.0, "scratch contents are unspecified, not zeroed");
            // re-entrant borrow must not alias the outer one — and its
            // depth-1 slot is itself reused across nested borrows
            let nested = with_scratch(10, |inner| {
                inner[0] = 1.0;
                assert_ne!(inner.as_ptr() as usize, cap);
                inner.as_ptr() as usize
            });
            with_scratch(10, |inner| {
                assert_eq!(inner.as_ptr() as usize, nested, "nested slot must be reused");
                assert_eq!(inner[0], 1.0, "nested slot keeps stale contents too");
            });
        });
        // depth restored: the outer slot serves top-level borrows again
        with_scratch(50, |buf| assert_eq!(buf.as_ptr() as usize, cap));
        // works inside pool tasks: each worker has its own buffer
        with_threads(4, || {
            run(16, |i| {
                with_scratch(64, |buf| {
                    buf[i] = i as f32;
                    assert_eq!(buf[i], i as f32);
                });
            });
        });
    }

    #[test]
    fn scratch_depth_unwinds_after_a_panic() {
        let _ = catch_unwind(AssertUnwindSafe(|| {
            with_scratch(8, |_| panic!("boom in scratch"));
        }));
        // the guard restored depth 0: top-level borrows reuse one slot
        let p1 = with_scratch(8, |b| b.as_ptr() as usize);
        let p2 = with_scratch(8, |b| b.as_ptr() as usize);
        assert_eq!(p1, p2, "depth must unwind back to the top-level slot");
    }

    #[test]
    fn nested_regions_share_the_root_budget() {
        // grow the pool well past width 2 first, as a wider earlier run
        // would have
        with_threads(6, || run(32, |_| {}));
        assert!(worker_count() >= 5);
        // width 2 root: at most 2 threads may ever run tasks at once,
        // even though the pool has ≥ 5 parked workers and the nested
        // regions would previously have recruited them
        let active = AtomicUsize::new(0);
        let high = AtomicUsize::new(0);
        let enter = || {
            let a = active.fetch_add(1, Ordering::SeqCst) + 1;
            high.fetch_max(a, Ordering::SeqCst);
        };
        let exit = || {
            active.fetch_sub(1, Ordering::SeqCst);
        };
        with_threads(2, || {
            run(4, |_| {
                run(6, |_| {
                    enter();
                    std::thread::sleep(std::time::Duration::from_millis(2));
                    exit();
                });
            });
        });
        let peak = high.load(Ordering::SeqCst);
        assert!(peak <= 2, "width-2 root must cap the computation at 2 threads, saw {peak}");
    }
}
