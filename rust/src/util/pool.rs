//! Scoped worker pool — the parallel execution backend behind the `linalg`
//! kernels and the trainer's per-layer fan-out (no `rayon` offline —
//! DESIGN.md §Substitutions).
//!
//! # Thread-count resolution
//!
//! Effective width = thread-local override (set by [`with_threads`], and
//! pinned to 1 inside pool workers so nested kernels never oversubscribe)
//! → else the global knob (set by [`set_threads`], wired from
//! `RunConfig.threads` / `--threads`) → else all available cores.
//! `0` always means "no opinion at this level".
//!
//! # Determinism contract
//!
//! * Work partitioning is always a pure function of the *input sizes*,
//!   never of the thread count; combination of partial results happens on
//!   the calling thread in partition order. Results are therefore
//!   deterministic for a given thread count — and for every kernel whose
//!   per-partition float-op order matches the serial loop (the matmul
//!   family, transpose, all elementwise ops) they are bitwise identical
//!   across *all* thread counts.
//! * Width 1 executes the caller's closures inline, in order, on the
//!   calling thread: exactly the pre-pool serial behavior.
//!
//! Workers are spawned per parallel region via [`std::thread::scope`] —
//! spawn cost (~tens of µs) is amortized by the work-size thresholds the
//! kernels apply before fanning out.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Global width knob: 0 = auto (all available cores).
static GLOBAL_THREADS: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// Per-thread override: 0 = none. Pool workers run with 1 so nested
    /// parallel regions degrade to serial instead of oversubscribing.
    static LOCAL_THREADS: Cell<usize> = const { Cell::new(0) };
}

/// Number of hardware threads (1 if it cannot be determined). Cached —
/// `threads()` sits on every kernel call path and
/// `available_parallelism` is a syscall on Linux.
pub fn available() -> usize {
    static AVAILABLE: OnceLock<usize> = OnceLock::new();
    *AVAILABLE.get_or_init(|| {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}

/// Set the global pool width. `0` restores the default (all cores).
pub fn set_threads(n: usize) {
    GLOBAL_THREADS.store(n, Ordering::Relaxed);
}

/// Effective pool width for the current thread (always ≥ 1).
pub fn threads() -> usize {
    let local = LOCAL_THREADS.with(|c| c.get());
    if local != 0 {
        return local;
    }
    let global = GLOBAL_THREADS.load(Ordering::Relaxed);
    if global != 0 {
        global
    } else {
        available()
    }
}

/// Run `f` with the pool width pinned to `n` on this thread (`0` clears
/// the override). Scoped, re-entrant, and unwind-safe — the primary test
/// hook.
pub fn with_threads<R>(n: usize, f: impl FnOnce() -> R) -> R {
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            LOCAL_THREADS.with(|c| c.set(self.0));
        }
    }
    let prev = LOCAL_THREADS.with(|c| {
        let p = c.get();
        c.set(n);
        p
    });
    let _restore = Restore(prev);
    f()
}

/// Execute `f(0), f(1), …, f(n-1)` across the pool.
///
/// Tasks are claimed dynamically (atomic counter), so callers may hand in
/// tasks of very different cost — the trainer's per-layer fan-out relies
/// on this. `f` must only touch data disjoint per index (shared reads are
/// fine). With an effective width of 1 the tasks run inline, in order.
pub fn run(n: usize, f: impl Fn(usize) + Sync) {
    let width = threads().min(n);
    if width <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    let f = &f;
    let next = &next;
    std::thread::scope(|s| {
        for _ in 0..width {
            s.spawn(move || {
                LOCAL_THREADS.with(|c| c.set(1));
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    f(i);
                }
            });
        }
    });
}

/// Like [`run`], collecting each task's result; the returned vector is in
/// task order regardless of which worker ran what.
pub fn map<T: Send>(n: usize, f: impl Fn(usize) -> T + Sync) -> Vec<T> {
    let width = threads().min(n);
    if width <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let f = &f;
    let next = &next;
    let parts: Vec<Vec<(usize, T)>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..width)
            .map(|_| {
                s.spawn(move || {
                    LOCAL_THREADS.with(|c| c.set(1));
                    let mut got = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        got.push((i, f(i)));
                    }
                    got
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("pool worker panicked")).collect()
    });
    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    for part in parts {
        for (i, v) in part {
            slots[i] = Some(v);
        }
    }
    slots.into_iter().map(|o| o.expect("pool task not executed")).collect()
}

/// Mutate each item of `items` across the pool, collecting one result per
/// item (in item order). Each task gets exclusive `&mut` access to its
/// item; `f` sees the item index alongside.
pub fn map_mut<T: Send, R: Send>(
    items: &mut [T],
    f: impl Fn(usize, &mut T) -> R + Sync,
) -> Vec<R> {
    let n = items.len();
    let width = threads().min(n);
    if width <= 1 {
        return items.iter_mut().enumerate().map(|(i, it)| f(i, it)).collect();
    }
    let base = SendPtr(items.as_mut_ptr());
    map(n, move |i| {
        // SAFETY: `map` hands each index to exactly one task, so this is
        // the only live reference to items[i]; i < n = items.len().
        let item = unsafe { &mut *base.0.add(i) };
        f(i, item)
    })
}

/// Split `data` into contiguous chunks of `chunk_len` elements (the last
/// may be short) and run `f(chunk_index, chunk)` across the pool. The
/// chunk geometry depends only on `data.len()` and `chunk_len`, keeping
/// results deterministic for any pool width.
pub fn for_each_chunk_mut<T: Send>(
    data: &mut [T],
    chunk_len: usize,
    f: impl Fn(usize, &mut [T]) + Sync,
) {
    assert!(chunk_len > 0, "chunk_len must be positive");
    let len = data.len();
    if len == 0 {
        return;
    }
    let n = len.div_ceil(chunk_len);
    let base = SendPtr(data.as_mut_ptr());
    run(n, move |i| {
        let lo = i * chunk_len;
        let hi = (lo + chunk_len).min(len);
        // SAFETY: [lo, hi) ranges are disjoint across chunk indices and
        // within bounds; `run` gives each index to exactly one task.
        let chunk = unsafe { std::slice::from_raw_parts_mut(base.0.add(lo), hi - lo) };
        f(i, chunk);
    });
}

/// Raw-pointer wrapper so disjoint-range writers can cross the closure
/// `Sync` bound. Soundness is argued at each use site.
struct SendPtr<T>(*mut T);

unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn run_covers_every_index_once() {
        let hits: Vec<AtomicU32> = (0..100).map(|_| AtomicU32::new(0)).collect();
        with_threads(4, || {
            run(100, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn map_preserves_order() {
        for width in [1, 2, 5] {
            let out = with_threads(width, || map(37, |i| i * i));
            assert_eq!(out, (0..37).map(|i| i * i).collect::<Vec<_>>());
        }
    }

    #[test]
    fn map_mut_touches_each_item() {
        let mut items: Vec<usize> = (0..50).collect();
        let doubled = with_threads(3, || map_mut(&mut items, |i, it| {
            *it += 1;
            i * 2
        }));
        assert_eq!(items, (1..=50).collect::<Vec<_>>());
        assert_eq!(doubled, (0..50).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn chunks_are_exact_and_ragged_tail_works() {
        let mut data = vec![0u32; 103];
        with_threads(4, || {
            for_each_chunk_mut(&mut data, 10, |ci, chunk| {
                assert_eq!(chunk.len(), if ci == 10 { 3 } else { 10 });
                for x in chunk.iter_mut() {
                    *x = ci as u32;
                }
            });
        });
        for (i, &x) in data.iter().enumerate() {
            assert_eq!(x, (i / 10) as u32);
        }
    }

    #[test]
    fn nested_regions_run_serial_in_workers() {
        let serial_inside: Vec<AtomicU32> = (0..8).map(|_| AtomicU32::new(0)).collect();
        with_threads(4, || {
            run(8, |i| {
                serial_inside[i].store(threads() as u32, Ordering::Relaxed);
            });
        });
        assert!(serial_inside.iter().all(|t| t.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn with_threads_restores_previous_width() {
        with_threads(3, || {
            assert_eq!(threads(), 3);
            with_threads(1, || assert_eq!(threads(), 1));
            assert_eq!(threads(), 3);
        });
    }

    #[test]
    fn zero_length_inputs_are_noops() {
        with_threads(4, || {
            run(0, |_| panic!("must not run"));
            assert!(map(0, |i| i).is_empty());
            let mut empty: [f32; 0] = [];
            for_each_chunk_mut(&mut empty, 8, |_, _| panic!("must not run"));
        });
    }
}
