//! Span tracer with Chrome-trace-event export (no `tracing` offline).
//!
//! A process-global, **off-by-default** tracer: instrumented call sites
//! open a [`span`] (RAII guard) and the guard records a complete event —
//! name, category, start, duration, thread lane — into a lock-free
//! per-thread buffer when it drops. Buffers drain into a shared sink,
//! and [`finish`] writes the sink as Chrome trace-event JSON loadable in
//! `chrome://tracing` / [Perfetto](https://ui.perfetto.dev) (`ph:"X"`
//! complete events; `pid` is the OS process id so the coordinator and
//! worker traces of a 2-process TCP run can be merged side by side).
//!
//! ## Enabling
//!
//! Off by default; resolution order (first hit wins):
//! 1. `AR_TRACE` env var — `1` means the default `runs/trace.json`,
//!    any other non-empty value is the output path, `0`/empty disables.
//! 2. `--trace [path]` CLI flag (see `cli.rs`).
//! 3. `[log] trace_path` config key.
//!
//! ## Disabled cost
//!
//! When disabled every instrumented site costs one relaxed atomic load
//! plus a branch ([`enabled`]) — no clock read, no TLS access, no
//! allocation. The contract pinned by `tests/trace_obs.rs` is stronger:
//! tracing **on or off never changes numerics** — spans only read the
//! clock and append to buffers, they never reorder float ops or consume
//! RNG draws, so every parity suite passes bitwise-unchanged either way.
//!
//! ## Span nesting across pool workers
//!
//! Same-thread nesting is positional (Chrome nests same-`tid` events by
//! time containment). Cross-thread attribution rides the
//! [`pool::context`](crate::util::pool::context) word: a [`region`]
//! claims the upper 16 bits ([`CTX_MASK`]) for a fresh region token, and
//! `pool::run` propagates the caller's context word into its workers, so
//! spans recorded *inside* pool workers carry the dispatching region's
//! token in their `args.ctx` — the trace viewer (or a script over the
//! JSON) can fold worker lanes under the region that dispatched them.
//! Bit 0 stays with `linalg::simd` per the pool's context-word doc.
//!
//! Identifiers passed as span names/categories must be plain
//! `&'static str` literals without `"` or `\` — the writer does not
//! escape (it never needs to for compile-time identifiers).

use std::cell::RefCell;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::util::pool;

/// Upper-16-bit slice of the `pool::context` word claimed for region
/// tokens (bit 0 belongs to `linalg::simd`'s force-scalar flag).
pub const CTX_MASK: u32 = 0xffff_0000;
const CTX_SHIFT: u32 = 16;

/// Per-thread events buffered before draining into the shared sink.
const FLUSH_AT: usize = 256;

static ENABLED: AtomicBool = AtomicBool::new(false);
static NEXT_TID: AtomicU32 = AtomicU32::new(1);
static NEXT_REGION: AtomicU32 = AtomicU32::new(1);
static SINK: Mutex<Option<Sink>> = Mutex::new(None);

/// Is tracing live? One relaxed load + branch — the whole disabled-path
/// cost of any instrumented site.
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

#[inline]
fn now_us() -> f64 {
    epoch().elapsed().as_secs_f64() * 1e6
}

#[derive(Clone, Debug)]
struct Event {
    name: &'static str,
    cat: &'static str,
    ts_us: f64,
    dur_us: f64,
    tid: u32,
    ctx: u32,
}

struct Sink {
    path: PathBuf,
    events: Vec<Event>,
}

struct ThreadBuf {
    tid: u32,
    buf: Vec<Event>,
}

impl Drop for ThreadBuf {
    fn drop(&mut self) {
        drain_into_sink(&mut self.buf);
    }
}

thread_local! {
    static TLS: RefCell<ThreadBuf> = RefCell::new(ThreadBuf {
        tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
        buf: Vec::new(),
    });
}

fn drain_into_sink(buf: &mut Vec<Event>) {
    if buf.is_empty() {
        return;
    }
    if let Ok(mut g) = SINK.lock() {
        if let Some(sink) = g.as_mut() {
            sink.events.append(buf);
        }
    }
    // sink gone (tracing finished mid-flight): drop the stragglers
    buf.clear();
}

fn record(mut ev: Event) {
    TLS.with(|t| {
        let mut t = t.borrow_mut();
        ev.tid = t.tid;
        t.buf.push(ev);
        if t.buf.len() >= FLUSH_AT {
            let tb = &mut *t;
            drain_into_sink(&mut tb.buf);
        }
    });
}

/// Flush this thread's buffered events into the shared sink. The pool
/// calls it at region end for its persistent workers (whose TLS never
/// drops); long-lived non-pool threads (TCP readers) call it after each
/// frame so [`finish`] on another thread misses nothing.
pub fn flush_thread() {
    if !enabled() {
        return;
    }
    TLS.with(|t| {
        let mut t = t.borrow_mut();
        let tb = &mut *t;
        drain_into_sink(&mut tb.buf);
    });
}

struct SpanOpen {
    t0: f64,
    cat: &'static str,
    name: &'static str,
    /// Context word frozen at open (regions); `None` reads
    /// `pool::context()` at drop, which inherits the dispatching
    /// region's token inside pool workers.
    ctx: Option<u32>,
}

/// RAII span guard: records one complete event on drop. Zero-sized work
/// when tracing is off (no clock read, `start` stays `None`).
pub struct Span {
    start: Option<SpanOpen>,
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(open) = self.start.take() {
            let ctx = open.ctx.unwrap_or_else(pool::context);
            record(Event {
                name: open.name,
                cat: open.cat,
                ts_us: open.t0,
                dur_us: now_us() - open.t0,
                tid: 0,
                ctx,
            });
        }
    }
}

/// Open a span; the returned guard records `[open, drop)` as one event.
#[inline]
pub fn span(cat: &'static str, name: &'static str) -> Span {
    if !enabled() {
        return Span { start: None };
    }
    Span { start: Some(SpanOpen { t0: now_us(), cat, name, ctx: None }) }
}

/// Zero-duration marker event (state-machine transitions and the like).
#[inline]
pub fn instant(cat: &'static str, name: &'static str) {
    if !enabled() {
        return;
    }
    record(Event { name, cat, ts_us: now_us(), dur_us: 0.0, tid: 0, ctx: pool::context() });
}

/// A span that also stamps a fresh region token into the upper 16 bits
/// of the thread's `pool::context` word for its lifetime, so spans
/// recorded in pool workers dispatched from inside it attribute back to
/// it (`args.ctx` equality). The token is restored on drop.
pub struct Region {
    span: Span,
    _ctx: Option<pool::CtxGuard>,
}

/// Open a region span (see [`Region`]).
#[inline]
pub fn region(cat: &'static str, name: &'static str) -> Region {
    if !enabled() {
        return Region { span: Span { start: None }, _ctx: None };
    }
    // 16-bit wrapping token, skipping 0 ("no region")
    let mut token = NEXT_REGION.fetch_add(1, Ordering::Relaxed) & 0xffff;
    if token == 0 {
        token = NEXT_REGION.fetch_add(1, Ordering::Relaxed) & 0xffff;
    }
    let word = (pool::context() & !CTX_MASK) | (token << CTX_SHIFT);
    let guard = pool::scoped_context(CTX_MASK, token << CTX_SHIFT);
    Region {
        span: Span { start: Some(SpanOpen { t0: now_us(), cat, name, ctx: Some(word) }) },
        _ctx: Some(guard),
    }
}

/// Region token (0 = none) carried by the current thread's context word.
pub fn current_region() -> u32 {
    (pool::context() & CTX_MASK) >> CTX_SHIFT
}

/// Start tracing into `path` (creates parent dirs at write time). Any
/// previously buffered-but-undrained sink is replaced.
pub fn init(path: &Path) {
    let mut g = SINK.lock().unwrap();
    *g = Some(Sink { path: path.to_path_buf(), events: Vec::new() });
    drop(g);
    ENABLED.store(true, Ordering::Relaxed);
}

/// Resolve the trace output path from env + config (see module doc):
/// `AR_TRACE` wins, then the (CLI-merged) `[log] trace_path` value;
/// empty means disabled.
pub fn resolve_path(cfg_trace_path: &str) -> Option<PathBuf> {
    match std::env::var("AR_TRACE") {
        Ok(v) if v.is_empty() || v == "0" => None,
        Ok(v) if v == "1" => Some(PathBuf::from("runs/trace.json")),
        Ok(v) => Some(PathBuf::from(v)),
        Err(_) if cfg_trace_path.is_empty() => None,
        Err(_) => Some(PathBuf::from(cfg_trace_path)),
    }
}

/// Convenience: [`resolve_path`] + [`init`]; returns the chosen path.
pub fn init_resolved(cfg_trace_path: &str) -> Option<PathBuf> {
    let path = resolve_path(cfg_trace_path)?;
    init(&path);
    Some(path)
}

/// Stop tracing, drain this thread's buffer, and write the sink as
/// Chrome trace-event JSON. Returns the written path, or `None` if
/// tracing was never [`init`]ialized. Idempotent.
pub fn finish() -> std::io::Result<Option<PathBuf>> {
    ENABLED.store(false, Ordering::Relaxed);
    // flush the calling thread before taking the sink (pool workers
    // flushed at their last region end, readers after their last frame)
    TLS.with(|t| {
        let mut t = t.borrow_mut();
        let tb = &mut *t;
        drain_into_sink(&mut tb.buf);
    });
    let sink = SINK.lock().unwrap().take();
    let Some(sink) = sink else { return Ok(None) };
    write_chrome_json(&sink)?;
    Ok(Some(sink.path))
}

fn write_chrome_json(sink: &Sink) -> std::io::Result<()> {
    if let Some(dir) = sink.path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let pid = std::process::id();
    let mut w = BufWriter::new(File::create(&sink.path)?);
    write!(w, "{{\"displayTimeUnit\":\"ms\",\"traceEvents\":[")?;
    for (i, e) in sink.events.iter().enumerate() {
        let sep = if i == 0 { "" } else { "," };
        write!(
            w,
            "{sep}\n{{\"ph\":\"X\",\"pid\":{pid},\"tid\":{},\"ts\":{:.3},\"dur\":{:.3},\
             \"cat\":\"{}\",\"name\":\"{}\",\"args\":{{\"ctx\":{}}}}}",
            e.tid, e.ts_us, e.dur_us, e.cat, e.name, e.ctx
        )?;
    }
    writeln!(w, "\n]}}")?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    // Process-global tracer: tests that toggle it serialize here.
    static LOCK: Mutex<()> = Mutex::new(());

    fn tmp(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("alice_trace_{}_{}", std::process::id(), name));
        p
    }

    #[test]
    fn disabled_span_is_inert() {
        let _g = LOCK.lock().unwrap();
        assert!(!enabled());
        let s = span("t", "noop");
        assert!(s.start.is_none());
        drop(s);
        instant("t", "noop");
        assert!(finish().unwrap().is_none(), "no sink → Ok(None)");
    }

    #[test]
    fn spans_written_as_valid_chrome_json() {
        let _g = LOCK.lock().unwrap();
        let path = tmp("basic.json");
        init(&path);
        {
            let _r = region("t", "outer");
            assert_ne!(current_region(), 0);
            let _s = span("t", "inner");
            instant("t", "mark");
        }
        assert_eq!(current_region(), 0);
        let out = finish().unwrap().expect("sink written");
        assert_eq!(out, path);
        let txt = std::fs::read_to_string(&path).unwrap();
        let j = crate::util::json::Json::parse(&txt).expect("parses");
        let evs = j.arr_of("traceEvents").unwrap();
        let names: Vec<&str> = evs.iter().filter_map(|e| e.str_of("name").ok()).collect();
        assert!(names.contains(&"outer"));
        assert!(names.contains(&"inner"));
        assert!(names.contains(&"mark"));
        // inner/mark carry the outer region token in args.ctx
        let ctx_of = |n: &str| -> f64 {
            evs.iter()
                .find(|e| e.str_of("name").ok() == Some(n))
                .and_then(|e| e.get("args"))
                .and_then(|a| a.f64_of("ctx").ok())
                .unwrap()
        };
        let outer_ctx = ctx_of("outer");
        assert!(outer_ctx >= (1u32 << 16) as f64);
        assert_eq!(ctx_of("inner"), outer_ctx, "inner attributes to outer");
        assert_eq!(ctx_of("mark"), outer_ctx, "mark attributes to outer");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn resolve_path_precedence() {
        let _g = LOCK.lock().unwrap();
        // env unset in tests: config value decides
        if std::env::var("AR_TRACE").is_err() {
            assert_eq!(resolve_path(""), None);
            assert_eq!(resolve_path("x.json"), Some(PathBuf::from("x.json")));
        }
    }
}
