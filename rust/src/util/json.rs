//! Minimal JSON parser / writer.
//!
//! Substrate module: the offline registry carries no `serde`/`serde_json`
//! (DESIGN.md §Substitutions), and the coordinator needs JSON for the AOT
//! `manifest.json` and for metrics output. Supports the full JSON grammar
//! minus `\u` surrogate pairs (the manifest is ASCII).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value. Object keys are sorted (BTreeMap) for deterministic output.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn str_of(&self, key: &str) -> Result<&str> {
        self.req(key)?.as_str().ok_or_else(|| anyhow!("{key:?} not a string"))
    }

    pub fn f64_of(&self, key: &str) -> Result<f64> {
        self.req(key)?.as_f64().ok_or_else(|| anyhow!("{key:?} not a number"))
    }

    pub fn usize_of(&self, key: &str) -> Result<usize> {
        Ok(self.f64_of(key)? as usize)
    }

    pub fn arr_of(&self, key: &str) -> Result<&[Json]> {
        self.req(key)?.as_arr().ok_or_else(|| anyhow!("{key:?} not an array"))
    }

    /// `[1, 2, 3]` -> `vec![1, 2, 3]`.
    pub fn usize_vec_of(&self, key: &str) -> Result<Vec<usize>> {
        self.arr_of(key)?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow!("{key:?}: non-numeric")))
            .collect()
    }

    // ---- writer ----------------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors used by metrics / checkpoint writers.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            m.insert(k, self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected ',' or ']', got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                &self.b[self.i..self.i + 4],
                            )?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| anyhow!("bad \\u escape"))?,
                            );
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // continue collecting UTF-8 bytes verbatim
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(txt.parse::<f64>()?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalar_types() {
        for src in ["null", "true", "false", "3", "-2.5", "\"hi\""] {
            let v = Json::parse(src).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x\ny"}"#).unwrap();
        assert_eq!(v.arr_of("a").unwrap().len(), 3);
        assert_eq!(v.str_of("c").unwrap(), "x\ny");
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"shape":[4,8],"name":"w","std":0.02}"#).unwrap();
        assert_eq!(v.usize_vec_of("shape").unwrap(), vec![4, 8]);
        assert_eq!(v.str_of("name").unwrap(), "w");
        assert!((v.f64_of("std").unwrap() - 0.02).abs() < 1e-12);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn writer_escapes() {
        let v = Json::Str("a\"b\\c\nd".into());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn handles_unicode_passthrough() {
        let v = Json::parse("\"héllo ∆\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ∆");
    }
}
