//! Deterministic PCG64-family RNG.
//!
//! Substrate module: no `rand` crate offline. Used by the synthetic corpus
//! generator, parameter initialization, Alice's switching sampler, and the
//! property-testing harness. PCG-XSH-RR 64/32 with independent streams.

/// PCG32 core (64-bit state, 32-bit output), Melissa O'Neill's constants.
#[derive(Debug, Clone)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

const MUL: u64 = 6364136223846793005;

impl Pcg {
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut r = Pcg { state: 0, inc: (stream << 1) | 1 };
        r.next_u32();
        r.state = r.state.wrapping_add(seed);
        r.next_u32();
        r
    }

    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    /// Raw (state, inc) words — the checkpointing hook that makes
    /// resumed runs replay the exact stream an uninterrupted run draws.
    pub fn state_words(&self) -> (u64, u64) {
        (self.state, self.inc)
    }

    /// Rebuild from words captured by [`state_words`] (no warm-up draws:
    /// the words already encode a mid-stream position).
    pub fn from_words(state: u64, inc: u64) -> Self {
        Pcg { state, inc }
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(MUL).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [0, 1) with f64 resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) (Lemire-style rejection-free for our use).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.f64() * n as f64) as usize % n
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.f32();
            if u1 > 1e-7 {
                let u2 = self.f32();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f32::consts::PI * u2).cos();
            }
        }
    }

    /// Fill with N(0, std).
    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * std).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Sample from unnormalized weights (linear scan; corpus vocab ≤ 64k).
    pub fn weighted(&mut self, cum: &[f64]) -> usize {
        let total = *cum.last().expect("empty weights");
        let x = self.f64() * total;
        match cum.binary_search_by(|c| c.partial_cmp(&x).unwrap()) {
            Ok(i) => (i + 1).min(cum.len() - 1),
            Err(i) => i.min(cum.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Pcg::seeded(42);
        let mut b = Pcg::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg::seeded(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_range() {
        let mut r = Pcg::seeded(7);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg::seeded(1);
        let xs = r.normal_vec(200_000, 1.0);
        let mean: f32 = xs.iter().sum::<f32>() / xs.len() as f32;
        let var: f32 =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>()
                / xs.len() as f32;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Pcg::seeded(9);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg::seeded(3);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Pcg::seeded(5);
        let cum = vec![1.0, 1.5, 101.5]; // weights 1, 0.5, 100
        let mut counts = [0usize; 3];
        for _ in 0..5000 {
            counts[r.weighted(&cum)] += 1;
        }
        assert!(counts[2] > 4500, "{counts:?}");
    }
}
