//! Tiny leveled logger (no `tracing`/`env_logger` offline).
//!
//! Level comes from `ALICE_RACS_LOG` (error|warn|info|debug|trace),
//! defaulting to `info`. Timestamps are seconds since process start so logs
//! are diff-able across runs.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(255);

fn start() -> Instant {
    use std::sync::OnceLock;
    static START: OnceLock<Instant> = OnceLock::new();
    *START.get_or_init(Instant::now)
}

pub fn level() -> Level {
    let raw = LEVEL.load(Ordering::Relaxed);
    if raw != 255 {
        return unsafe { std::mem::transmute::<u8, Level>(raw) };
    }
    let lv = match std::env::var("ALICE_RACS_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    };
    LEVEL.store(lv as u8, Ordering::Relaxed);
    lv
}

pub fn set_level(lv: Level) {
    LEVEL.store(lv as u8, Ordering::Relaxed);
}

pub fn log(lv: Level, args: std::fmt::Arguments<'_>) {
    if lv <= level() {
        let tag = match lv {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{:9.3}s {tag}] {args}", start().elapsed().as_secs_f64());
    }
}

#[macro_export]
macro_rules! info {
    ($($t:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info,
                               format_args!($($t)*))
    };
}

#[macro_export]
macro_rules! warn_ {
    ($($t:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn,
                               format_args!($($t)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($($t:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug,
                               format_args!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Info < Level::Trace);
    }

    #[test]
    fn set_and_get() {
        set_level(Level::Debug);
        assert_eq!(level(), Level::Debug);
        set_level(Level::Info);
    }
}
