//! Tiny leveled logger (no `tracing`/`env_logger` offline).
//!
//! Level resolution, first hit wins: `ALICE_RACS_LOG` env var →
//! `--log-level` flag / `[log] level` config key (merged by the CLI into
//! [`init_str`]) → `info`. Values are `error|warn|info|debug|trace`; an
//! unrecognized value warns **once** to stderr and falls back to `info`
//! instead of silently dropping to the default. Timestamps are seconds
//! since process start so logs are diff-able across runs.

use std::sync::atomic::{AtomicBool, AtomicU8, Ordering};
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(255);

fn start() -> Instant {
    use std::sync::OnceLock;
    static START: OnceLock<Instant> = OnceLock::new();
    *START.get_or_init(Instant::now)
}

static WARNED_BAD: AtomicBool = AtomicBool::new(false);

impl Level {
    /// Parse a level name; `None` for anything unrecognized. Shared by
    /// the env var, the `[log] level` config key, and `--log-level`.
    pub fn parse(s: &str) -> Option<Level> {
        match s {
            "error" => Some(Level::Error),
            "warn" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }
}

/// Warn once per process about an unrecognized level value, then fall
/// back to `info` — pre-fix this fell through silently (ISSUE 8).
fn bad_value(source: &str, v: &str) -> Level {
    if !WARNED_BAD.swap(true, Ordering::Relaxed) {
        eprintln!("[log] unrecognized {source} value {v:?}; valid: error|warn|info|debug|trace — defaulting to info");
    }
    Level::Info
}

pub fn level() -> Level {
    let raw = LEVEL.load(Ordering::Relaxed);
    if raw != 255 {
        return unsafe { std::mem::transmute::<u8, Level>(raw) };
    }
    let lv = match std::env::var("ALICE_RACS_LOG") {
        Ok(v) => Level::parse(&v).unwrap_or_else(|| bad_value("ALICE_RACS_LOG", &v)),
        Err(_) => Level::Info,
    };
    LEVEL.store(lv as u8, Ordering::Relaxed);
    lv
}

pub fn set_level(lv: Level) {
    LEVEL.store(lv as u8, Ordering::Relaxed);
}

/// Apply the config/CLI-resolved level name. The env var still wins: if
/// `ALICE_RACS_LOG` is set (even to garbage, which warns), `name` is
/// ignored. An unrecognized `name` warns once and keeps `info`.
pub fn init_str(name: &str) {
    if let Ok(v) = std::env::var("ALICE_RACS_LOG") {
        set_level(Level::parse(&v).unwrap_or_else(|| bad_value("ALICE_RACS_LOG", &v)));
        return;
    }
    set_level(Level::parse(name).unwrap_or_else(|| bad_value("log level", name)));
}

pub fn log(lv: Level, args: std::fmt::Arguments<'_>) {
    if lv <= level() {
        let tag = match lv {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{:9.3}s {tag}] {args}", start().elapsed().as_secs_f64());
    }
}

#[macro_export]
macro_rules! info {
    ($($t:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info,
                               format_args!($($t)*))
    };
}

#[macro_export]
macro_rules! warn_ {
    ($($t:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn,
                               format_args!($($t)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($($t:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug,
                               format_args!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    // LEVEL is process-global; tests that write it serialize here.
    static TLOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    #[test]
    fn levels_order() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Info < Level::Trace);
    }

    #[test]
    fn set_and_get() {
        let _g = TLOCK.lock().unwrap();
        set_level(Level::Debug);
        assert_eq!(level(), Level::Debug);
        set_level(Level::Info);
    }

    #[test]
    fn parse_all_names() {
        assert_eq!(Level::parse("error"), Some(Level::Error));
        assert_eq!(Level::parse("warn"), Some(Level::Warn));
        assert_eq!(Level::parse("info"), Some(Level::Info));
        assert_eq!(Level::parse("debug"), Some(Level::Debug));
        assert_eq!(Level::parse("trace"), Some(Level::Trace));
        assert_eq!(Level::parse("verbose"), None);
        assert_eq!(Level::parse("INFO"), None); // names are lowercase
        assert_eq!(Level::parse(""), None);
    }

    #[test]
    fn init_str_applies_config_level() {
        let _g = TLOCK.lock().unwrap();
        // tests run without ALICE_RACS_LOG in CI; guard so a local
        // override doesn't produce a confusing failure
        if std::env::var("ALICE_RACS_LOG").is_err() {
            init_str("trace");
            assert_eq!(level(), Level::Trace);
            init_str("no-such-level"); // warns once, falls back
            assert_eq!(level(), Level::Info);
        }
        set_level(Level::Info);
    }
}
