//! Shared substrates: JSON, RNG, logging, timing, worker pool.
//!
//! These exist because the offline crate registry only carries the `xla`
//! dependency tree (DESIGN.md §Substitutions) — no serde, rand, or
//! tracing. Each is small, unit-tested, and used across the coordinator.

pub mod json;
pub mod log;
pub mod pool;
pub mod rng;
pub mod timer;
pub mod trace;

pub use json::Json;
pub use rng::Pcg;
pub use timer::Timer;

/// Human-readable byte count (used by the Table 3 / Fig. 4 reports).
pub fn human_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut x = b as f64;
    let mut u = 0;
    while x >= 1024.0 && u < UNITS.len() - 1 {
        x /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{x:.2} {}", UNITS[u])
    }
}

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64)
        .sqrt()
}

/// Median (sorts a copy). Total order per the PR-5 comparator policy:
/// `total_cmp` sorts NaNs to the ends instead of panicking, so one bad
/// sample degrades the statistic rather than the process.
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Nearest-rank percentile, `q` in `[0, 1]` (sorts a copy; 0.0 for an
/// empty slice). Same `total_cmp` comparator policy as [`median`], so a
/// NaN sample degrades the tail statistic instead of panicking. `q = 0.5`
/// is the nearest-rank median (not the interpolated [`median`]); the
/// serving latency report uses p50/p95/p99.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let rank = (q.clamp(0.0, 1.0) * v.len() as f64).ceil() as usize;
    v[rank.clamp(1, v.len()) - 1]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn stats() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
        assert!((std_dev(&xs) - 1.118033988).abs() < 1e-6);
        assert_eq!(median(&[5.0, 1.0, 3.0]), 3.0);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.50), 50.0);
        assert_eq!(percentile(&xs, 0.95), 95.0);
        assert_eq!(percentile(&xs, 0.99), 99.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 100.0);
        assert_eq!(percentile(&[7.0], 0.99), 7.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
        // out-of-range q clamps rather than panicking
        assert_eq!(percentile(&xs, 2.0), 100.0);
    }

    #[test]
    fn median_survives_nan() {
        // pre-fix this panicked inside sort_by(partial_cmp().unwrap());
        // total_cmp orders NaN after +inf, so finite medians stay sane
        let m = median(&[1.0, f64::NAN, 3.0]);
        assert_eq!(m, 3.0);
        assert!(median(&[f64::NAN]).is_nan());
    }
}
