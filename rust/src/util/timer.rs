//! Scoped timers and a streaming duration recorder for the bench harness.

use std::collections::BTreeMap;
use std::time::Instant;

/// Simple stopwatch.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn millis(&self) -> f64 {
        self.secs() * 1e3
    }
}

impl Default for Timer {
    fn default() -> Self {
        Self::start()
    }
}

/// Accumulates named durations — the coordinator's per-phase profile
/// (grad exec / optimizer update / data gen / host copies), printed at the
/// end of a run and consumed by EXPERIMENTS.md §Perf.
#[derive(Debug, Default)]
pub struct Profile {
    acc: BTreeMap<&'static str, (f64, u64)>,
}

impl Profile {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, name: &'static str, secs: f64) {
        let e = self.acc.entry(name).or_insert((0.0, 0));
        e.0 += secs;
        e.1 += 1;
    }

    pub fn time<T>(&mut self, name: &'static str, f: impl FnOnce() -> T) -> T {
        let t = Timer::start();
        let out = f();
        self.add(name, t.secs());
        out
    }

    /// Merge another profile into this one (totals and call counts sum
    /// per phase). Worker-side profiles recorded inside pool fan-outs
    /// are absorbed at region end so per-layer timings are no longer
    /// dropped on the worker threads (ISSUE 8).
    pub fn absorb(&mut self, other: &Profile) {
        for (name, (secs, calls)) in &other.acc {
            let e = self.acc.entry(name).or_insert((0.0, 0));
            e.0 += secs;
            e.1 += calls;
        }
    }

    /// Phase names recorded so far (sorted — `acc` is a BTreeMap).
    pub fn phases(&self) -> Vec<&'static str> {
        self.acc.keys().copied().collect()
    }

    pub fn total(&self, name: &str) -> f64 {
        self.acc.get(name).map(|e| e.0).unwrap_or(0.0)
    }

    pub fn count(&self, name: &str) -> u64 {
        self.acc.get(name).map(|e| e.1).unwrap_or(0)
    }

    pub fn report(&self) -> String {
        let mut rows: Vec<_> = self.acc.iter().collect();
        // total_cmp per the PR-5 comparator policy: one NaN sample must
        // degrade the report ordering, not panic it
        rows.sort_by(|a, b| b.1 .0.total_cmp(&a.1 .0));
        let mut out = String::from("phase                          total_s   calls   mean_ms\n");
        for (name, (total, calls)) in rows {
            out.push_str(&format!(
                "{name:<30} {total:>8.3} {calls:>7} {:>9.3}\n",
                1e3 * total / *calls as f64
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profile_accumulates() {
        let mut p = Profile::new();
        p.add("a", 0.5);
        p.add("a", 0.25);
        p.add("b", 1.0);
        assert!((p.total("a") - 0.75).abs() < 1e-12);
        assert_eq!(p.count("a"), 2);
        assert!(p.report().contains("a"));
    }

    #[test]
    fn time_closure() {
        let mut p = Profile::new();
        let v = p.time("x", || 41 + 1);
        assert_eq!(v, 42);
        assert_eq!(p.count("x"), 1);
    }

    #[test]
    fn absorb_merges_totals_and_counts() {
        let mut a = Profile::new();
        a.add("shared", 1.0);
        a.add("only_a", 0.5);
        let mut b = Profile::new();
        b.add("shared", 2.0);
        b.add("shared", 1.0);
        b.add("only_b", 0.25);
        a.absorb(&b);
        assert!((a.total("shared") - 4.0).abs() < 1e-12);
        assert_eq!(a.count("shared"), 3);
        assert!((a.total("only_b") - 0.25).abs() < 1e-12);
        assert_eq!(a.phases(), vec!["only_a", "only_b", "shared"]);
        // b is unchanged
        assert_eq!(b.count("shared"), 2);
    }
}
