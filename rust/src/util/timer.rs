//! Scoped timers and a streaming duration recorder for the bench harness.

use std::collections::BTreeMap;
use std::time::Instant;

/// Simple stopwatch.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn millis(&self) -> f64 {
        self.secs() * 1e3
    }
}

impl Default for Timer {
    fn default() -> Self {
        Self::start()
    }
}

/// Accumulates named durations — the coordinator's per-phase profile
/// (grad exec / optimizer update / data gen / host copies), printed at the
/// end of a run and consumed by EXPERIMENTS.md §Perf.
#[derive(Debug, Default)]
pub struct Profile {
    acc: BTreeMap<&'static str, (f64, u64)>,
}

impl Profile {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, name: &'static str, secs: f64) {
        let e = self.acc.entry(name).or_insert((0.0, 0));
        e.0 += secs;
        e.1 += 1;
    }

    pub fn time<T>(&mut self, name: &'static str, f: impl FnOnce() -> T) -> T {
        let t = Timer::start();
        let out = f();
        self.add(name, t.secs());
        out
    }

    pub fn total(&self, name: &str) -> f64 {
        self.acc.get(name).map(|e| e.0).unwrap_or(0.0)
    }

    pub fn count(&self, name: &str) -> u64 {
        self.acc.get(name).map(|e| e.1).unwrap_or(0)
    }

    pub fn report(&self) -> String {
        let mut rows: Vec<_> = self.acc.iter().collect();
        rows.sort_by(|a, b| b.1 .0.partial_cmp(&a.1 .0).unwrap());
        let mut out = String::from("phase                          total_s   calls   mean_ms\n");
        for (name, (total, calls)) in rows {
            out.push_str(&format!(
                "{name:<30} {total:>8.3} {calls:>7} {:>9.3}\n",
                1e3 * total / *calls as f64
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profile_accumulates() {
        let mut p = Profile::new();
        p.add("a", 0.5);
        p.add("a", 0.25);
        p.add("b", 1.0);
        assert!((p.total("a") - 0.75).abs() < 1e-12);
        assert_eq!(p.count("a"), 2);
        assert!(p.report().contains("a"));
    }

    #[test]
    fn time_closure() {
        let mut p = Profile::new();
        let v = p.time("x", || 41 + 1);
        assert_eq!(v, 42);
        assert_eq!(p.count("x"), 1);
    }
}
