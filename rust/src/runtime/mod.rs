//! PJRT runtime: manifest loading + HLO-text compilation + execution.
//!
//! Pattern follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! HLO *text* is the interchange format (jax ≥ 0.5 protos carry 64-bit ids
//! that XLA 0.5.1 rejects).

pub mod engine;
pub mod manifest;

pub use engine::{Engine, HostTensor};
pub use manifest::{ArtifactSpec, Manifest, ModelInfo, OptimizerSpec, ParamSpec, StateSpec, TensorSpec};
