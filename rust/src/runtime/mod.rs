//! PJRT runtime: manifest loading + HLO-text compilation + execution.
//!
//! Pattern follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! HLO *text* is the interchange format (jax ≥ 0.5 protos carry 64-bit ids
//! that XLA 0.5.1 rejects).

pub mod engine;
pub mod manifest;
// Several stub types exist only to satisfy engine.rs's signatures and are
// never constructed without a real backend — hence the dead_code allow.
#[cfg(not(feature = "pjrt"))]
#[allow(dead_code)]
pub(crate) mod xla_stub;

pub use engine::{Engine, HostTensor};
pub use manifest::{ArtifactSpec, Manifest, ModelInfo, OptimizerSpec, ParamSpec, StateSpec, TensorSpec};
