//! Drop-in stand-in for the `xla` crate, used when the crate is built
//! without the `pjrt` feature (the offline registry does not carry the
//! real dependency — see Cargo.toml header).
//!
//! The surface mirrors exactly what `engine.rs` touches. `Literal` is a
//! real host-side container, so tensor <-> literal round trips (and the
//! unit tests that exercise them) work without XLA. Anything that would
//! need an actual PJRT client — `PjRtClient::cpu()` and everything
//! downstream — returns a descriptive error instead, and the artifact-
//! backed tests and benches self-skip long before reaching it.

use std::fmt;

/// Error type matching the real crate's role: `Display` for the
/// `map_err(|e| anyhow!(..{e}))` call sites, `std::error::Error` for `?`.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: built without the `pjrt` feature (the `xla` crate is not \
         in the offline registry); rebuild with `--features pjrt` after \
         adding the dependency — see rust/Cargo.toml"
    ))
}

/// Element types the engine understands (plus the other common PJRT dtypes
/// so downstream `match` arms keep a reachable wildcard, as with the real
/// crate's larger enum).
#[allow(dead_code)]
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    S64,
    U32,
    F32,
    F64,
    Bf16,
}

/// Shape of a non-tuple literal.
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Scalar element types `Literal` can hold — mirrors the real crate's
/// sealed native-type trait.
pub trait NativeType: Copy {
    fn to_literal(data: &[Self]) -> Literal;
    fn from_literal(lit: &Literal) -> Result<Vec<Self>, Error>;
}

impl NativeType for f32 {
    fn to_literal(data: &[Self]) -> Literal {
        Literal::F32 { dims: vec![data.len() as i64], data: data.to_vec() }
    }

    fn from_literal(lit: &Literal) -> Result<Vec<Self>, Error> {
        match lit {
            Literal::F32 { data, .. } => Ok(data.clone()),
            _ => Err(Error("literal is not f32".into())),
        }
    }
}

impl NativeType for i32 {
    fn to_literal(data: &[Self]) -> Literal {
        Literal::I32 { dims: vec![data.len() as i64], data: data.to_vec() }
    }

    fn from_literal(lit: &Literal) -> Result<Vec<Self>, Error> {
        match lit {
            Literal::I32 { data, .. } => Ok(data.clone()),
            _ => Err(Error("literal is not i32".into())),
        }
    }
}

/// Host-side literal: a shaped f32/i32 buffer or a tuple of literals.
#[derive(Debug, Clone)]
pub enum Literal {
    F32 { dims: Vec<i64>, data: Vec<f32> },
    I32 { dims: Vec<i64>, data: Vec<i32> },
    Tuple(Vec<Literal>),
}

impl Literal {
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        T::to_literal(data)
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, Error> {
        let want: i64 = dims.iter().product();
        let have = match self {
            Literal::F32 { data, .. } => data.len() as i64,
            Literal::I32 { data, .. } => data.len() as i64,
            Literal::Tuple(_) => return Err(Error("cannot reshape a tuple literal".into())),
        };
        if want != have {
            return Err(Error(format!("reshape {dims:?} wants {want} elems, literal has {have}")));
        }
        Ok(match self {
            Literal::F32 { data, .. } => Literal::F32 { dims: dims.to_vec(), data: data.clone() },
            Literal::I32 { data, .. } => Literal::I32 { dims: dims.to_vec(), data: data.clone() },
            Literal::Tuple(_) => unreachable!(),
        })
    }

    pub fn array_shape(&self) -> Result<ArrayShape, Error> {
        match self {
            Literal::F32 { dims, .. } => Ok(ArrayShape { dims: dims.clone(), ty: ElementType::F32 }),
            Literal::I32 { dims, .. } => Ok(ArrayShape { dims: dims.clone(), ty: ElementType::S32 }),
            Literal::Tuple(_) => Err(Error("tuple literal has no array shape".into())),
        }
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        T::from_literal(self)
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        match self {
            Literal::Tuple(parts) => Ok(parts.clone()),
            _ => Err(Error("literal is not a tuple".into())),
        }
    }
}

/// Parsed HLO module. Construction requires XLA's parser, so the stub only
/// ever errors — but the type must exist for `engine.rs` to compile.
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self, Error> {
        Err(unavailable("parsing HLO text"))
    }
}

pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation { _priv: () }
    }
}

/// Device-side buffer handle. Never constructed by the stub.
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable("fetching buffer"))
    }
}

pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable("executing"))
    }
}

pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self, Error> {
        Err(unavailable("creating PJRT CPU client"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable("compiling"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let r = l.reshape(&[2, 3]).unwrap();
        let shape = r.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 3]);
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(r.to_vec::<i32>().is_err());
    }

    #[test]
    fn literal_scalar_reshape() {
        let l = Literal::vec1(&[7i32]);
        let s = l.reshape(&[]).unwrap();
        assert_eq!(s.array_shape().unwrap().dims(), &[] as &[i64]);
        assert_eq!(s.to_vec::<i32>().unwrap(), vec![7]);
    }

    #[test]
    fn reshape_checks_elems() {
        let l = Literal::vec1(&[1.0f32, 2.0]);
        assert!(l.reshape(&[3]).is_err());
    }

    #[test]
    fn client_reports_missing_feature() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(err.to_string().contains("pjrt"));
    }

    #[test]
    fn tuple_ops() {
        let t = Literal::Tuple(vec![Literal::vec1(&[1.0f32])]);
        assert_eq!(t.to_tuple().unwrap().len(), 1);
        assert!(t.array_shape().is_err());
        assert!(Literal::vec1(&[1.0f32]).to_tuple().is_err());
    }
}
