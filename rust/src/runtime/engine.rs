//! PJRT execution engine: loads HLO-text artifacts, compiles them once on
//! the CPU PJRT client, and executes them with host tensors.
//!
//! This is the only module that touches the `xla` crate. Everything above
//! it (coordinator, benches, examples) speaks `HostTensor`.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{anyhow, bail, Context, Result};

// Without the `pjrt` feature the real `xla` crate is absent; every
// `xla::` path below resolves to the stub instead (see Cargo.toml header).
#[cfg(not(feature = "pjrt"))]
use super::xla_stub as xla;

use crate::util::Timer;

use super::manifest::Manifest;

/// A host-side tensor: either f32 or i32, with explicit shape.
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>().max(1), data.len());
        HostTensor::F32 { shape, data }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>().max(1), data.len());
        HostTensor::I32 { shape, data }
    }

    pub fn scalar_f32(v: f32) -> Self {
        HostTensor::F32 { shape: vec![], data: vec![v] }
    }

    pub fn scalar_i32(v: i32) -> Self {
        HostTensor::I32 { shape: vec![], data: vec![v] }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        HostTensor::F32 {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product::<usize>().max(1)],
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => shape,
        }
    }

    pub fn elems(&self) -> usize {
        self.shape().iter().product::<usize>().max(1)
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is i32, expected f32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is i32, expected f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is f32, expected i32"),
        }
    }

    pub fn scalar(&self) -> Result<f32> {
        let d = self.as_f32()?;
        if d.len() != 1 {
            bail!("expected scalar, got {} elems", d.len());
        }
        Ok(d[0])
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            HostTensor::F32 { shape, data } => {
                let l = xla::Literal::vec1(data.as_slice());
                if shape.is_empty() {
                    l.reshape(&[])?
                } else {
                    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                    l.reshape(&dims)?
                }
            }
            HostTensor::I32 { shape, data } => {
                let l = xla::Literal::vec1(data.as_slice());
                if shape.is_empty() {
                    l.reshape(&[])?
                } else {
                    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                    l.reshape(&dims)?
                }
            }
        };
        Ok(lit)
    }

    fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => {
                Ok(HostTensor::F32 { shape: dims, data: lit.to_vec::<f32>()? })
            }
            xla::ElementType::S32 => {
                Ok(HostTensor::I32 { shape: dims, data: lit.to_vec::<i32>()? })
            }
            ty => bail!("unsupported output element type {ty:?}"),
        }
    }
}

/// Compiled-executable cache keyed by artifact name.
///
/// Execution is splittable across threads: [`Engine::execute`] takes
/// `&self` (the PJRT CPU client executes concurrently; the stub types are
/// plain data), which is what lets `Trainer::eval` and the serving batcher
/// fan batches out over `util::pool` against one shared engine.
/// Compilation ([`Engine::prepare`]) stays `&mut self`.
pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    exes: BTreeMap<String, xla::PjRtLoadedExecutable>,
    /// Cumulative seconds spent compiling (reported once per run).
    pub compile_secs: f64,
    /// Cumulative seconds spent in execute + host transfers (f64 bits —
    /// atomic so shared-reference execution can account too).
    exec_secs_bits: AtomicU64,
    exec_calls: AtomicU64,
}

impl Engine {
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Self> {
        let manifest = Manifest::load(&artifact_dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("creating PJRT CPU client: {e}"))?;
        Ok(Engine {
            client,
            manifest,
            exes: BTreeMap::new(),
            compile_secs: 0.0,
            exec_secs_bits: AtomicU64::new(0.0f64.to_bits()),
            exec_calls: AtomicU64::new(0),
        })
    }

    /// Cumulative (execute + host-transfer seconds, execute calls).
    pub fn exec_stats(&self) -> (f64, u64) {
        (
            f64::from_bits(self.exec_secs_bits.load(Ordering::Relaxed)),
            self.exec_calls.load(Ordering::Relaxed),
        )
    }

    fn add_exec(&self, secs: f64) {
        self.exec_calls.fetch_add(1, Ordering::Relaxed);
        let mut cur = self.exec_secs_bits.load(Ordering::Relaxed);
        loop {
            let new = (f64::from_bits(cur) + secs).to_bits();
            match self.exec_secs_bits.compare_exchange_weak(
                cur,
                new,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (and cache) an artifact by manifest name.
    pub fn prepare(&mut self, name: &str) -> Result<()> {
        if self.exes.contains_key(name) {
            return Ok(());
        }
        let path = self.manifest.artifact_path(name)?;
        let t = Timer::start();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing HLO text {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e}"))?;
        self.compile_secs += t.secs();
        self.exes.insert(name.to_string(), exe);
        Ok(())
    }

    /// Deprecated forwarder: owned-input convenience over the canonical
    /// [`Engine::execute`] (prepares on the fly, copies nothing extra but
    /// forces exclusive access). New code should call [`Engine::prepare`]
    /// once and [`Engine::execute`] per call; kept so historical call
    /// sites compile.
    pub fn run(&mut self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let refs: Vec<&HostTensor> = inputs.iter().collect();
        self.prepare(name)?;
        self.execute(name, &refs)
    }

    /// Deprecated forwarder: the pre-redesign borrowed-input entry point
    /// (EXPERIMENTS.md §Perf L3-1) — now just [`Engine::prepare`] +
    /// [`Engine::execute`]. Kept so historical call sites compile.
    pub fn run_refs(&mut self, name: &str, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        self.prepare(name)?;
        self.execute(name, inputs)
    }

    /// Deprecated forwarder: the pre-redesign name of [`Engine::execute`].
    /// Kept so historical call sites compile.
    pub fn run_prepared(&self, name: &str, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        self.execute(name, inputs)
    }

    /// Execute an already-[`prepare`]d artifact — **the** canonical
    /// execution entry point. Shared-reference (`&self`), so trainer
    /// fan-outs and the serving pool dispatch batches concurrently
    /// against one engine. Inputs must match the manifest signature
    /// order; outputs come back in manifest order (the lowered module
    /// returns a tuple — `return_tuple=True` — which is decomposed
    /// here). Errors if the artifact was never compiled.
    ///
    /// [`prepare`]: Engine::prepare
    pub fn execute(&self, name: &str, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        let spec = self.manifest.artifact(name)?;
        if inputs.len() != spec.inputs.len() {
            bail!(
                "{name}: expected {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            );
        }
        for (ht, ts) in inputs.iter().zip(&spec.inputs) {
            if ht.shape() != ts.shape.as_slice() {
                bail!(
                    "{name}: input {:?} shape mismatch: manifest {:?}, got {:?}",
                    ts.name,
                    ts.shape,
                    ht.shape()
                );
            }
        }
        let t = Timer::start();
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|ht| ht.to_literal())
            .collect::<Result<_>>()?;
        let exe = self.exes.get(name).ok_or_else(|| {
            anyhow!("artifact {name:?} not prepared — call Engine::prepare first")
        })?;
        let bufs = exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("executing {name}: {e}"))?;
        let out_lit = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} output: {e}"))?;
        let parts = out_lit
            .to_tuple()
            .map_err(|e| anyhow!("untupling {name} output: {e}"))?;
        let outs: Vec<HostTensor> = parts
            .iter()
            .map(HostTensor::from_literal)
            .collect::<Result<_>>()
            .with_context(|| format!("converting {name} outputs"))?;
        if outs.len() != spec.outputs.len() {
            bail!(
                "{name}: manifest declares {} outputs, module returned {}",
                spec.outputs.len(),
                outs.len()
            );
        }
        self.add_exec(t.secs());
        Ok(outs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_roundtrip() {
        let t = HostTensor::f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.elems(), 6);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn scalar_tensor() {
        let t = HostTensor::scalar_f32(2.5);
        assert_eq!(t.shape(), &[] as &[usize]);
        assert_eq!(t.scalar().unwrap(), 2.5);
        let lit = t.to_literal().unwrap();
        assert_eq!(HostTensor::from_literal(&lit).unwrap(), t);
    }

    #[test]
    fn i32_tensor() {
        let t = HostTensor::i32(vec![4], vec![1, -2, 3, -4]);
        let lit = t.to_literal().unwrap();
        assert_eq!(HostTensor::from_literal(&lit).unwrap(), t);
        assert!(t.as_f32().is_err());
    }

    #[test]
    fn zeros_shape() {
        let t = HostTensor::zeros(&[3, 5]);
        assert_eq!(t.elems(), 15);
        assert!(t.as_f32().unwrap().iter().all(|&x| x == 0.0));
    }
}
