//! Typed view of `artifacts/manifest.json` produced by `python/compile/aot.py`.
//!
//! The manifest pins everything the coordinator must agree on with the AOT
//! side: parameter ordering and shapes, optimizer state layouts, per-param
//! routing (candidate optimizer vs Adam — the paper's App. F.2 protocol),
//! and the input/output signature of every HLO artifact.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::Json;

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: String,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    fn parse(v: &Json) -> Result<Self> {
        Ok(TensorSpec {
            name: v.str_of("name")?.to_string(),
            dtype: v.str_of("dtype")?.to_string(),
            shape: v.usize_vec_of("shape")?,
        })
    }
}

#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub init_std: f32,
}

#[derive(Debug, Clone)]
pub struct StateSpec {
    pub name: String,
    pub shape: Vec<usize>,
    /// Which parameter this state tensor belongs to.
    pub param: String,
    /// Key within the optimizer's state dict ("m", "v", "u", ...).
    pub key: String,
    /// Optimizer that owns it ("adam" for Adam-routed params).
    pub route: String,
    /// Init rule: "zeros" | "eye" | "eye_scale:<c>".
    pub init: String,
}

impl StateSpec {
    /// Materialize the initial state tensor per the init rule.
    pub fn init_data(&self) -> Result<Vec<f32>> {
        let elems: usize = self.shape.iter().product::<usize>().max(1);
        match self.init.as_str() {
            "zeros" => Ok(vec![0.0; elems]),
            "eye" => {
                let (m, n) = (self.shape[0], self.shape[1]);
                let mut v = vec![0.0; m * n];
                for i in 0..m.min(n) {
                    v[i * n + i] = 1.0;
                }
                Ok(v)
            }
            s if s.starts_with("eye_scale:") => {
                let c: f32 = s["eye_scale:".len()..]
                    .parse()
                    .map_err(|e| anyhow!("bad eye_scale: {e}"))?;
                let (m, n) = (self.shape[0], self.shape[1]);
                let mut v = vec![0.0; m * n];
                for i in 0..m.min(n) {
                    v[i * n + i] = c;
                }
                Ok(v)
            }
            other => Err(anyhow!("unknown state init rule {other:?}")),
        }
    }
}

#[derive(Debug, Clone)]
pub struct OptimizerSpec {
    pub states: Vec<StateSpec>,
    pub routes: Vec<String>,
    pub has_refresh: bool,
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub preset: String,
    pub vocab: usize,
    pub dim: usize,
    pub inter: usize,
    pub heads: usize,
    pub layers: usize,
    pub seq: usize,
    pub batch: usize,
    pub num_params: usize,
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelInfo,
    pub params: Vec<ParamSpec>,
    pub optimizers: BTreeMap<String, OptimizerSpec>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub hyperparams: BTreeMap<String, f64>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Self> {
        let v = Json::parse(text).context("parsing manifest.json")?;

        let m = v.req("model")?;
        let model = ModelInfo {
            preset: m.str_of("preset")?.to_string(),
            vocab: m.usize_of("vocab")?,
            dim: m.usize_of("dim")?,
            inter: m.usize_of("inter")?,
            heads: m.usize_of("heads")?,
            layers: m.usize_of("layers")?,
            seq: m.usize_of("seq")?,
            batch: m.usize_of("batch")?,
            num_params: m.usize_of("num_params")?,
        };

        let params = v
            .arr_of("params")?
            .iter()
            .map(|p| {
                Ok(ParamSpec {
                    name: p.str_of("name")?.to_string(),
                    shape: p.usize_vec_of("shape")?,
                    init_std: p.f64_of("init_std")? as f32,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let mut optimizers = BTreeMap::new();
        if let Some(Json::Obj(objs)) = v.get("optimizers") {
            for (name, spec) in objs {
                let states = spec
                    .arr_of("states")?
                    .iter()
                    .map(|s| {
                        Ok(StateSpec {
                            name: s.str_of("name")?.to_string(),
                            shape: s.usize_vec_of("shape")?,
                            param: s.str_of("param")?.to_string(),
                            key: s.str_of("key")?.to_string(),
                            route: s.str_of("route")?.to_string(),
                            init: s
                                .get("init")
                                .and_then(Json::as_str)
                                .unwrap_or("zeros")
                                .to_string(),
                        })
                    })
                    .collect::<Result<Vec<_>>>()?;
                let routes = spec
                    .arr_of("routes")?
                    .iter()
                    .map(|r| {
                        r.as_str()
                            .map(str::to_string)
                            .ok_or_else(|| anyhow!("route not a string"))
                    })
                    .collect::<Result<Vec<_>>>()?;
                let has_refresh = spec
                    .get("has_refresh")
                    .and_then(Json::as_bool)
                    .unwrap_or(false);
                optimizers.insert(
                    name.clone(),
                    OptimizerSpec { states, routes, has_refresh },
                );
            }
        }

        let mut artifacts = BTreeMap::new();
        for a in v.arr_of("artifacts")? {
            let spec = ArtifactSpec {
                name: a.str_of("name")?.to_string(),
                file: a.str_of("file")?.to_string(),
                kind: a.str_of("kind")?.to_string(),
                inputs: a
                    .arr_of("inputs")?
                    .iter()
                    .map(TensorSpec::parse)
                    .collect::<Result<Vec<_>>>()?,
                outputs: a
                    .arr_of("outputs")?
                    .iter()
                    .map(TensorSpec::parse)
                    .collect::<Result<Vec<_>>>()?,
            };
            artifacts.insert(spec.name.clone(), spec);
        }

        let mut hyperparams = BTreeMap::new();
        if let Some(Json::Obj(h)) = v.get("hyperparams") {
            for (k, val) in h {
                if let Some(n) = val.as_f64() {
                    hyperparams.insert(k.clone(), n);
                } else if let Some(b) = val.as_bool() {
                    hyperparams.insert(k.clone(), if b { 1.0 } else { 0.0 });
                }
            }
        }

        Ok(Manifest { dir, model, params, optimizers, artifacts, hyperparams })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest (have: {:?})",
                self.artifacts.keys().collect::<Vec<_>>()))
    }

    pub fn artifact_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.artifact(name)?.file))
    }

    pub fn optimizer(&self, name: &str) -> Result<&OptimizerSpec> {
        self.optimizers
            .get(name)
            .ok_or_else(|| anyhow!("optimizer {name:?} has no artifacts"))
    }

    pub fn param_index(&self, name: &str) -> Option<usize> {
        self.params.iter().position(|p| p.name == name)
    }

    /// Total parameter element count (cross-check against model.num_params).
    pub fn param_elems(&self) -> usize {
        self.params
            .iter()
            .map(|p| p.shape.iter().product::<usize>())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "model": {"preset":"nano","vocab":256,"dim":64,"inter":176,"heads":4,
                "layers":2,"seq":64,"batch":8,"num_params":133440},
      "params": [{"name":"embed","shape":[256,64],"init_std":0.02}],
      "optimizers": {"adam": {"states":[{"name":"state.embed.m","shape":[256,64],
          "param":"embed","key":"m","route":"adam"}],
          "routes":["adam"],"has_refresh":false}},
      "hyperparams": {"b1":0.9,"bias_correction":true},
      "artifacts": [{"name":"grad_step","file":"grad_step.hlo.txt","kind":"grad",
        "inputs":[{"name":"tokens","dtype":"i32","shape":[8,64]}],
        "outputs":[{"name":"loss","dtype":"f32","shape":[]}]}]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert_eq!(m.model.preset, "nano");
        assert_eq!(m.params[0].shape, vec![256, 64]);
        assert_eq!(m.param_elems(), 256 * 64);
        assert!(m.optimizer("adam").unwrap().states[0].key == "m");
        assert!((m.hyperparams["b1"] - 0.9).abs() < 1e-12);
        assert_eq!(m.hyperparams["bias_correction"], 1.0);
        let a = m.artifact("grad_step").unwrap();
        assert_eq!(a.inputs[0].dtype, "i32");
        assert!(m.artifact("nope").is_err());
    }

    #[test]
    fn param_lookup() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert_eq!(m.param_index("embed"), Some(0));
        assert_eq!(m.param_index("missing"), None);
    }
}
