//! Double-buffered batch iterator: generation happens on a background
//! thread so token synthesis never sits on the training hot path (the
//! coordinator-side analogue of an async input pipeline; std threads —
//! no tokio offline).

use std::sync::mpsc;
use std::thread;

use super::corpus::{Corpus, CorpusConfig};
use crate::util::Pcg;

/// Streaming [batch, seq] i32 token blocks.
pub struct Batcher {
    rx: mpsc::Receiver<Vec<i32>>,
    pub batch: usize,
    pub seq: usize,
    _worker: thread::JoinHandle<()>,
}

impl Batcher {
    /// `depth` controls how many batches may be prefetched (bounded queue =
    /// backpressure: the generator blocks when the trainer lags).
    pub fn spawn(cfg: CorpusConfig, batch: usize, seq: usize, seed: u64, depth: usize) -> Self {
        let (tx, rx) = mpsc::sync_channel(depth.max(1));
        let worker = thread::spawn(move || {
            let corpus = Corpus::new(cfg);
            let mut rng = Pcg::new(seed, 0xbeef);
            let mut buf = Vec::new();
            loop {
                corpus.fill_batch(batch, seq, &mut rng, &mut buf);
                if tx.send(std::mem::take(&mut buf)).is_err() {
                    return; // trainer dropped the receiver — shut down
                }
            }
        });
        Batcher { rx, batch, seq, _worker: worker }
    }

    /// Blocking fetch of the next token block (row-major [batch, seq]).
    pub fn next(&self) -> Vec<i32> {
        self.rx.recv().expect("batch generator thread died")
    }
}

/// Deterministic single-threaded variant for eval sets and tests: the same
/// seed always yields the same sequence of batches.
pub struct SyncBatcher {
    corpus: Corpus,
    rng: Pcg,
    pub batch: usize,
    pub seq: usize,
}

impl SyncBatcher {
    pub fn new(cfg: CorpusConfig, batch: usize, seq: usize, seed: u64) -> Self {
        SyncBatcher { corpus: Corpus::new(cfg), rng: Pcg::new(seed, 0xe7a1), batch, seq }
    }

    pub fn next(&mut self) -> Vec<i32> {
        let mut buf = Vec::new();
        self.corpus.fill_batch(self.batch, self.seq, &mut self.rng, &mut buf);
        buf
    }

    /// Raw RNG words — the stream *is* the batcher's only mutable state
    /// (`Corpus` is immutable), so capturing them checkpoints the exact
    /// position in the batch sequence.
    pub fn rng_words(&self) -> (u64, u64) {
        self.rng.state_words()
    }

    /// Restore a stream position captured by [`rng_words`].
    pub fn set_rng_words(&mut self, words: (u64, u64)) {
        self.rng = Pcg::from_words(words.0, words.1);
    }
}

/// Width-bucketed batch assembly: split `0..total` into consecutive
/// `(lo, len)` spans of at most `width` items, covering every index
/// exactly once — the final span is ragged iff `width` does not divide
/// `total`. The one slicing helper behind both `Trainer::eval`'s bounded
/// fan-out and the serving batcher (`serve::score_batched`), so the
/// ragged-tail arithmetic lives in exactly one place.
pub fn bucket_spans(total: usize, width: usize) -> Vec<(usize, usize)> {
    let width = width.max(1);
    let mut spans = Vec::with_capacity(total.div_ceil(width));
    let mut lo = 0;
    while lo < total {
        let len = width.min(total - lo);
        spans.push((lo, len));
        lo += len;
    }
    spans
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_spans_cover_every_index_exactly_once() {
        for (total, width) in [(0, 4), (1, 4), (4, 4), (5, 4), (8, 3), (9, 3), (7, 100), (6, 0)] {
            let spans = bucket_spans(total, width);
            let mut seen = Vec::new();
            for &(lo, len) in &spans {
                assert!(len >= 1 && len <= width.max(1), "({total},{width}): span len {len}");
                seen.extend(lo..lo + len);
            }
            assert_eq!(seen, (0..total).collect::<Vec<_>>(), "({total},{width})");
        }
    }

    #[test]
    fn bucket_spans_final_span_is_ragged() {
        assert_eq!(bucket_spans(10, 4), vec![(0, 4), (4, 4), (8, 2)]);
        assert_eq!(bucket_spans(8, 4), vec![(0, 4), (4, 4)]);
        assert_eq!(bucket_spans(3, 1), vec![(0, 1), (1, 1), (2, 1)]);
        assert!(bucket_spans(0, 4).is_empty());
        // width 0 is clamped to 1 rather than looping forever
        assert_eq!(bucket_spans(2, 0), vec![(0, 1), (1, 1)]);
    }

    #[test]
    fn async_and_sync_agree() {
        let cfg = CorpusConfig::default();
        let b = Batcher::spawn(cfg.clone(), 2, 16, 7, 2);
        let mut s = SyncBatcher::new(cfg, 2, 16, 7);
        // different internal stream tags → both deterministic, but compare
        // shape/vocab only
        let ab = b.next();
        let sb = s.next();
        assert_eq!(ab.len(), sb.len());
        assert!(ab.iter().all(|&t| t >= 0));
    }

    #[test]
    fn sync_batcher_is_reproducible() {
        let cfg = CorpusConfig::default();
        let mut a = SyncBatcher::new(cfg.clone(), 2, 16, 9);
        let mut b = SyncBatcher::new(cfg, 2, 16, 9);
        for _ in 0..3 {
            assert_eq!(a.next(), b.next());
        }
    }

    #[test]
    fn rng_words_roundtrip_resumes_the_stream() {
        let cfg = CorpusConfig::default();
        let mut a = SyncBatcher::new(cfg.clone(), 2, 16, 11);
        let _ = a.next();
        let words = a.rng_words();
        let expect = a.next();
        let mut b = SyncBatcher::new(cfg, 2, 16, 11);
        b.set_rng_words(words);
        assert_eq!(b.next(), expect, "restored stream must continue exactly");
    }

    #[test]
    fn backpressure_does_not_deadlock() {
        let b = Batcher::spawn(CorpusConfig::default(), 1, 8, 1, 1);
        for _ in 0..10 {
            let _ = b.next();
        }
    }
}
