//! Double-buffered batch iterator: generation happens on a background
//! thread so token synthesis never sits on the training hot path (the
//! coordinator-side analogue of an async input pipeline; std threads —
//! no tokio offline).

use std::sync::mpsc;
use std::thread;

use super::corpus::{Corpus, CorpusConfig};
use crate::util::Pcg;

/// Streaming [batch, seq] i32 token blocks.
pub struct Batcher {
    rx: mpsc::Receiver<Vec<i32>>,
    pub batch: usize,
    pub seq: usize,
    _worker: thread::JoinHandle<()>,
}

impl Batcher {
    /// `depth` controls how many batches may be prefetched (bounded queue =
    /// backpressure: the generator blocks when the trainer lags).
    pub fn spawn(cfg: CorpusConfig, batch: usize, seq: usize, seed: u64, depth: usize) -> Self {
        let (tx, rx) = mpsc::sync_channel(depth.max(1));
        let worker = thread::spawn(move || {
            let corpus = Corpus::new(cfg);
            let mut rng = Pcg::new(seed, 0xbeef);
            let mut buf = Vec::new();
            loop {
                corpus.fill_batch(batch, seq, &mut rng, &mut buf);
                if tx.send(std::mem::take(&mut buf)).is_err() {
                    return; // trainer dropped the receiver — shut down
                }
            }
        });
        Batcher { rx, batch, seq, _worker: worker }
    }

    /// Blocking fetch of the next token block (row-major [batch, seq]).
    pub fn next(&self) -> Vec<i32> {
        self.rx.recv().expect("batch generator thread died")
    }
}

/// Deterministic single-threaded variant for eval sets and tests: the same
/// seed always yields the same sequence of batches.
pub struct SyncBatcher {
    corpus: Corpus,
    rng: Pcg,
    pub batch: usize,
    pub seq: usize,
}

impl SyncBatcher {
    pub fn new(cfg: CorpusConfig, batch: usize, seq: usize, seed: u64) -> Self {
        SyncBatcher { corpus: Corpus::new(cfg), rng: Pcg::new(seed, 0xe7a1), batch, seq }
    }

    pub fn next(&mut self) -> Vec<i32> {
        let mut buf = Vec::new();
        self.corpus.fill_batch(self.batch, self.seq, &mut self.rng, &mut buf);
        buf
    }

    /// Raw RNG words — the stream *is* the batcher's only mutable state
    /// (`Corpus` is immutable), so capturing them checkpoints the exact
    /// position in the batch sequence.
    pub fn rng_words(&self) -> (u64, u64) {
        self.rng.state_words()
    }

    /// Restore a stream position captured by [`rng_words`].
    pub fn set_rng_words(&mut self, words: (u64, u64)) {
        self.rng = Pcg::from_words(words.0, words.1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn async_and_sync_agree() {
        let cfg = CorpusConfig::default();
        let b = Batcher::spawn(cfg.clone(), 2, 16, 7, 2);
        let mut s = SyncBatcher::new(cfg, 2, 16, 7);
        // different internal stream tags → both deterministic, but compare
        // shape/vocab only
        let ab = b.next();
        let sb = s.next();
        assert_eq!(ab.len(), sb.len());
        assert!(ab.iter().all(|&t| t >= 0));
    }

    #[test]
    fn sync_batcher_is_reproducible() {
        let cfg = CorpusConfig::default();
        let mut a = SyncBatcher::new(cfg.clone(), 2, 16, 9);
        let mut b = SyncBatcher::new(cfg, 2, 16, 9);
        for _ in 0..3 {
            assert_eq!(a.next(), b.next());
        }
    }

    #[test]
    fn rng_words_roundtrip_resumes_the_stream() {
        let cfg = CorpusConfig::default();
        let mut a = SyncBatcher::new(cfg.clone(), 2, 16, 11);
        let _ = a.next();
        let words = a.rng_words();
        let expect = a.next();
        let mut b = SyncBatcher::new(cfg, 2, 16, 11);
        b.set_rng_words(words);
        assert_eq!(b.next(), expect, "restored stream must continue exactly");
    }

    #[test]
    fn backpressure_does_not_deadlock() {
        let b = Batcher::spawn(CorpusConfig::default(), 1, 8, 1, 1);
        for _ in 0..10 {
            let _ = b.next();
        }
    }
}
