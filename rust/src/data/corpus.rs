//! Zipf × order-2 Markov token-stream generator.
//!
//! Token t is drawn from
//!   p(t | a, b) = (1 − mix) · Zipf(s)  +  mix · Markov₂(a, b)
//! where the Markov₂ table is itself random but *fixed per seed*, giving a
//! stationary, learnable language. A transformer's achievable loss floor is
//! the conditional entropy of this process; SGD vs Adam vs Alice separate
//! cleanly on the approach to that floor (Table 2 analogue).

use crate::util::Pcg;

#[derive(Debug, Clone)]
pub struct CorpusConfig {
    pub vocab: usize,
    /// Zipf exponent (≈1.1 is natural-language-ish).
    pub zipf_s: f64,
    /// Weight of the Markov component in [0, 1].
    pub mix: f64,
    /// Markov order: 1 (context = previous token — learnable by small
    /// models) or 2 (context = previous two tokens, hashed).
    pub order: usize,
    /// Number of context rows with a sharpened Markov distribution;
    /// order-1 uses `vocab` rows directly, order-2 hashes into these.
    pub contexts: usize,
    /// Sparsity of each Markov row (successors per context).
    pub branch: usize,
    pub seed: u64,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        CorpusConfig {
            vocab: 512,
            zipf_s: 1.1,
            mix: 0.65,
            order: 1,
            contexts: 4096,
            branch: 8,
            seed: 0x5eed,
        }
    }
}

/// The generator: owns the Zipf CDF and the sparse Markov table.
pub struct Corpus {
    pub cfg: CorpusConfig,
    zipf_cum: Vec<f64>,
    /// contexts x branch: successor token ids.
    succ: Vec<u32>,
    /// contexts x branch cumulative weights.
    succ_cum: Vec<f64>,
}

impl Corpus {
    pub fn new(cfg: CorpusConfig) -> Self {
        let mut rng = Pcg::new(cfg.seed, 0xc0ffee);
        // Zipf CDF over ranks 1..=vocab
        let mut cum = Vec::with_capacity(cfg.vocab);
        let mut acc = 0.0f64;
        for k in 1..=cfg.vocab {
            acc += 1.0 / (k as f64).powf(cfg.zipf_s);
            cum.push(acc);
        }
        // sparse Markov rows: `branch` successors with Zipf-ish weights
        let mut succ = Vec::with_capacity(cfg.contexts * cfg.branch);
        let mut succ_cum = Vec::with_capacity(cfg.contexts * cfg.branch);
        for _ in 0..cfg.contexts {
            let mut acc = 0.0f64;
            for j in 0..cfg.branch {
                succ.push(rng.below(cfg.vocab) as u32);
                acc += 1.0 / (j + 1) as f64;
                succ_cum.push(acc);
            }
        }
        Corpus { cfg, zipf_cum: cum, succ, succ_cum }
    }

    #[inline]
    fn ctx_row(&self, a: u32, b: u32) -> usize {
        if self.cfg.order == 1 {
            // order-1: direct row per previous token — a 2-layer model can
            // learn this table, so training loss approaches the process
            // entropy and optimizers separate (DESIGN.md §Substitutions)
            return b as usize % self.cfg.contexts;
        }
        // order-2: fast 2-token hash into the context table
        let h = (a as u64)
            .wrapping_mul(0x9e3779b97f4a7c15)
            .wrapping_add((b as u64).wrapping_mul(0xbf58476d1ce4e5b9));
        (h >> 17) as usize % self.cfg.contexts
    }

    /// Next token given context (a, b).
    pub fn next_token(&self, a: u32, b: u32, rng: &mut Pcg) -> u32 {
        if rng.f64() < self.cfg.mix {
            let row = self.ctx_row(a, b);
            let base = row * self.cfg.branch;
            let cum = &self.succ_cum[base..base + self.cfg.branch];
            let j = rng.weighted(cum);
            self.succ[base + j]
        } else {
            rng.weighted(&self.zipf_cum) as u32
        }
    }

    /// Generate a [batch, seq] token block into `out` (i32 for the i32
    /// `tokens` input of the HLO artifacts).
    pub fn fill_batch(&self, batch: usize, seq: usize, rng: &mut Pcg, out: &mut Vec<i32>) {
        out.clear();
        out.reserve(batch * seq);
        for _ in 0..batch {
            let mut a = rng.weighted(&self.zipf_cum) as u32;
            let mut b = rng.weighted(&self.zipf_cum) as u32;
            out.push(a as i32);
            out.push(b as i32);
            for _ in 2..seq {
                let c = self.next_token(a, b, rng);
                out.push(c as i32);
                a = b;
                b = c;
            }
        }
    }

    /// Empirical unigram entropy of a generated stream (nats) — used by
    /// tests and by the e2e example to report the loss floor context.
    pub fn empirical_unigram_entropy(&self, tokens: &[i32]) -> f64 {
        let mut counts = vec![0u64; self.cfg.vocab];
        for &t in tokens {
            counts[t as usize] += 1;
        }
        let n = tokens.len() as f64;
        counts
            .iter()
            .filter(|&&c| c > 0)
            .map(|&c| {
                let p = c as f64 / n;
                -p * p.ln()
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let c = Corpus::new(CorpusConfig::default());
        let mut r1 = Pcg::seeded(1);
        let mut r2 = Pcg::seeded(1);
        let (mut b1, mut b2) = (Vec::new(), Vec::new());
        c.fill_batch(2, 32, &mut r1, &mut b1);
        c.fill_batch(2, 32, &mut r2, &mut b2);
        assert_eq!(b1, b2);
    }

    #[test]
    fn tokens_in_vocab() {
        let cfg = CorpusConfig { vocab: 100, ..Default::default() };
        let c = Corpus::new(cfg);
        let mut rng = Pcg::seeded(2);
        let mut b = Vec::new();
        c.fill_batch(4, 64, &mut rng, &mut b);
        assert_eq!(b.len(), 4 * 64);
        assert!(b.iter().all(|&t| (0..100).contains(&t)));
    }

    #[test]
    fn zipf_marginals_are_heavy_tailed() {
        let c = Corpus::new(CorpusConfig { mix: 0.0, ..Default::default() });
        let mut rng = Pcg::seeded(3);
        let mut b = Vec::new();
        c.fill_batch(64, 256, &mut rng, &mut b);
        let mut counts = vec![0u64; c.cfg.vocab];
        for &t in &b {
            counts[t as usize] += 1;
        }
        // token 0 (rank 1) must dominate token 100 heavily
        assert!(counts[0] > 10 * counts[100].max(1));
    }

    #[test]
    fn markov_structure_lowers_conditional_entropy() {
        // With mix = 0.9 the next token is mostly a function of (a, b):
        // repeated contexts should produce repeated successors far more
        // often than under the pure unigram model.
        let c = Corpus::new(CorpusConfig { mix: 0.9, ..Default::default() });
        let mut rng = Pcg::seeded(4);
        let (a, b) = (5u32, 9u32);
        let mut counts = std::collections::BTreeMap::new();
        for _ in 0..2000 {
            *counts.entry(c.next_token(a, b, &mut rng)).or_insert(0u32) += 1;
        }
        let max = counts.values().max().copied().unwrap();
        // concentrated: the top successor takes a large share
        assert!(max > 300, "top successor share too small: {max}");
    }

    #[test]
    fn entropy_estimate_sane() {
        let c = Corpus::new(CorpusConfig::default());
        let mut rng = Pcg::seeded(5);
        let mut b = Vec::new();
        c.fill_batch(32, 128, &mut rng, &mut b);
        let h = c.empirical_unigram_entropy(&b);
        assert!(h > 1.0 && h < (c.cfg.vocab as f64).ln());
    }
}
