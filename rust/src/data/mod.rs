//! Synthetic pre-training corpus (DESIGN.md §Substitutions: replaces C4).
//!
//! A Zipf(1.1) unigram distribution mixed with an order-2 Markov chain over
//! the model vocabulary: the unigram part gives realistic heavy-tailed
//! marginals, the Markov part gives learnable sequential structure so the
//! cross-entropy actually *decreases* with training and separates
//! optimizers. Fully deterministic given the seed.

pub mod batcher;
pub mod corpus;

pub use batcher::{bucket_spans, Batcher, SyncBatcher};
pub use corpus::{Corpus, CorpusConfig};
