//! Forward-only serving subsystem — the ROADMAP "inference/serving stack"
//! item: open the millions-of-users workload the training substrate was
//! built for (ISSUE 9).
//!
//! Three pieces, smallest to largest:
//!
//! * [`Model`] — a read-only handle built by [`Checkpoint::load_model`]:
//!   weights + manifest + a prepared engine, shared as `Arc<Model>`. No
//!   optimizer state, no `Trainer` — the obs state-bytes gauge reads 0 in
//!   a serve process.
//! * [`score_batched`] / the [`queue`]-fed [`serve_loop`] — the
//!   continuous-batching front end: arrivals coalesce into width-bucketed
//!   batches under a [`BatchPolicy`] (max-batch / max-wait), each batch
//!   fans out over the persistent `util::pool`, and every request's
//!   enqueue→scored latency is tracked end to end. Ingress is optionally
//!   bounded (`max_queue_depth` / [`queue_bounded`]): past the bound,
//!   submissions shed with a typed [`SubmitError`] and an obs counter —
//!   never a silent drop.
//! * [`TcpServer`] / [`run_client`] — the networked driver: serving-plane
//!   `Request`/`Response` frames over the `dist/transport.rs` frame
//!   machinery (same handshake, validation, and obs wire accounting).
//!
//! # Determinism contract
//!
//! Batching is scheduling, never numerics: a batched score is bitwise
//! identical to scoring the same request alone, at every pool width and
//! bucket size. The contract holds because each request gets its own
//! [`ScoreSource::score`] call — the batcher only decides *when* and *on
//! which thread* that call runs. `tests/serve_parity.rs` pins it at
//! widths {1, 4}, across bucket sizes, through the in-process queue and
//! over TCP. Trace spans and obs counters on this path are observational
//! only, like everywhere else in the repo.
//!
//! [`Checkpoint::load_model`]: crate::coordinator::Checkpoint::load_model

pub mod model;
pub mod net;
pub mod queue;

use anyhow::Result;

use crate::linalg::Mat;
use crate::runtime::HostTensor;
use crate::util::{pool, Pcg};

pub use model::Model;
pub use net::{run_client, ServeReport, TcpServer};
pub use queue::{
    latency_summary, queue, queue_bounded, score_batched, score_digest, serve_loop,
    BatchPolicy, Ingress, LatencySummary, Request, Response, ServeQueue, SubmitError,
};

/// Produces one request's score. Implementations must be pure in
/// `(id, tokens)` — the serving determinism contract (batching is
/// scheduling, never numerics) rests on a score being independent of
/// which batch carried the request, and when it was dispatched.
pub trait ScoreSource: Sync {
    fn score(&self, id: u64, tokens: &HostTensor) -> Result<f32>;
}

/// Deterministic stand-in for the engine-backed [`Model`] (the serving
/// analogue of `dist::SyntheticGradSource`): the score is a pure function
/// of `(id, tokens)` via FNV-1a + Pcg, so parity tests and benches run
/// with no artifacts at all.
pub struct SyntheticScoreSource {
    /// Side length of a busywork matmul emulating forward cost (0 = none).
    pub work: usize,
}

impl ScoreSource for SyntheticScoreSource {
    fn score(&self, id: u64, tokens: &HostTensor) -> Result<f32> {
        // FNV-1a over the token block: the score depends on the data, not
        // just the id, like a real forward pass would
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for &t in tokens.as_i32()? {
            h = (h ^ t as u64).wrapping_mul(0x0100_0000_01b3);
        }
        let mut rng = Pcg::new(h ^ id.wrapping_mul(0x9e37_79b9), 0x5c0e);
        let mut cost = 0.0f32;
        if self.work > 0 {
            let n = self.work;
            // serial inner matmul: the busywork stays inside this request's
            // task, so batch cost is a clean function of batch size
            let a = Mat::from_vec(n, n, rng.normal_vec(n * n, 1.0));
            let prod = pool::with_threads(1, || a.matmul(&a));
            cost = std::hint::black_box(prod.data[0]) * 1e-30;
        }
        Ok(2.0 + rng.f32() + cost)
    }
}

/// Deterministic request stream: `n` token blocks of shape
/// `[batch, seq]` with ids `0..n`, drawn from a seeded Pcg — request `i`
/// is a pure function of `(seed, i)`, so every driver (loopback CLI, TCP
/// client, parity tests, fig8) can regenerate the identical stream.
pub fn synthetic_requests(
    n: usize,
    batch: usize,
    seq: usize,
    vocab: usize,
    seed: u64,
) -> Vec<Request> {
    let mut rng = Pcg::new(seed, 0x5e4e);
    (0..n as u64)
        .map(|id| {
            let data: Vec<i32> = (0..batch * seq)
                .map(|_| rng.below(vocab.max(1)) as i32)
                .collect();
            Request { id, tokens: HostTensor::i32(vec![batch, seq], data) }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_requests_are_reproducible_and_shaped() {
        let a = synthetic_requests(3, 2, 4, 997, 0x5eed);
        let b = synthetic_requests(3, 2, 4, 997, 0x5eed);
        assert_eq!(a.len(), 3);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.tokens, y.tokens);
            assert_eq!(x.tokens.shape(), &[2, 4]);
            assert!(x.tokens.as_i32().unwrap().iter().all(|&t| (0..997).contains(&t)));
        }
        let c = synthetic_requests(3, 2, 4, 997, 0x5eee);
        assert_ne!(a[0].tokens, c[0].tokens, "seed must matter");
    }

    #[test]
    fn synthetic_score_is_pure_in_id_and_tokens() {
        let src = SyntheticScoreSource { work: 0 };
        let reqs = synthetic_requests(2, 1, 8, 97, 9);
        let s0 = src.score(reqs[0].id, &reqs[0].tokens).unwrap();
        let again = src.score(reqs[0].id, &reqs[0].tokens).unwrap();
        assert_eq!(s0.to_bits(), again.to_bits());
        let other_id = src.score(reqs[1].id, &reqs[0].tokens).unwrap();
        assert_ne!(s0.to_bits(), other_id.to_bits(), "id must matter");
        let other_toks = src.score(reqs[0].id, &reqs[1].tokens).unwrap();
        assert_ne!(s0.to_bits(), other_toks.to_bits(), "tokens must matter");
    }
}
