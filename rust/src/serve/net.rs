//! Networked serving driver: the serving-plane `Request`/`Response`
//! frames (wire proto v3) over the exact `dist/transport.rs` machinery —
//! same length-prefixed codec, same `Hello`/`Welcome`/`Reject` handshake
//! and run-id validation, same per-connection reader threads feeding one
//! event channel, same obs wire accounting per frame kind.
//!
//! The server is the TCP face of `queue::serve_loop`: arrivals from any
//! connection coalesce into one continuous-batching queue under a
//! [`BatchPolicy`], each batch fans out over `util::pool`, and every
//! response is routed back to the connection that asked. Batching across
//! connections is still scheduling only — scores stay bitwise identical
//! to scoring alone (`tests/serve_parity.rs` pins the TCP path too).

use std::collections::{HashMap, VecDeque};
use std::net::{IpAddr, Ipv4Addr, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::dist::transport::{
    enc_done, enc_hello, enc_reject, enc_request, enc_response, enc_welcome, read_frame,
    reader_loop, send_frame, Event, Frame, PROTO_VERSION,
};
use crate::obs;
use crate::util::{pool, trace};

use super::{BatchPolicy, Request, Response, ScoreSource};

/// One queued request with its origin connection and arrival stamp.
struct Q {
    conn: u64,
    id: u64,
    tokens: crate::runtime::HostTensor,
    at: Instant,
}

/// What a serve run did (returned for tests / the CLI summary line).
#[derive(Debug, Default)]
pub struct ServeReport {
    /// Requests scored and answered.
    pub served: usize,
    /// Batches dispatched across the pool.
    pub batches: usize,
    /// Per-request enqueue→scored latency, dispatch order.
    pub latencies_s: Vec<f64>,
    /// Requests shed at ingress because the queue sat at the policy's
    /// `max_queue_depth` (each also bumps `obs::SERVE_REJECTS`). A shed
    /// TCP request is never answered — clients opting into a bounded
    /// server should bound their reads.
    pub rejected: usize,
}

/// Server side of the serving plane: owns the listener, one reader
/// thread per connection (the same [`reader_loop`] the dist coordinator
/// uses), and the write halves keyed by connection id.
pub struct TcpServer {
    addr: SocketAddr,
    rx: Receiver<Event>,
    /// Kept so the channel never disconnects while readers come and go.
    _tx: Sender<Event>,
    conns: HashMap<u64, TcpStream>,
    run_id: String,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

impl TcpServer {
    /// Bind `listen` (e.g. `127.0.0.1:0`) and start accepting clients.
    /// Clients are admitted lazily as [`TcpServer::serve`] pumps events.
    pub fn bind(listen: &str, run_id: &str) -> Result<Self> {
        let listener =
            TcpListener::bind(listen).with_context(|| format!("binding {listen}"))?;
        let addr = listener.local_addr()?;
        let (tx, rx) = mpsc::channel();
        let stop = Arc::new(AtomicBool::new(false));
        let accept = {
            let stop = stop.clone();
            let tx = tx.clone();
            std::thread::Builder::new()
                .name("ar-serve-accept".to_string())
                .spawn(move || {
                    let next = AtomicUsize::new(0);
                    loop {
                        let stream = match listener.accept() {
                            Ok((s, _)) => s,
                            Err(_) => {
                                if stop.load(Ordering::SeqCst) {
                                    break;
                                }
                                continue;
                            }
                        };
                        if stop.load(Ordering::SeqCst) {
                            break;
                        }
                        let conn = next.fetch_add(1, Ordering::SeqCst) as u64;
                        let tx = tx.clone();
                        let _ = std::thread::Builder::new()
                            .name(format!("ar-serve-conn-{conn}"))
                            .spawn(move || reader_loop(conn, stream, tx));
                    }
                })
                .context("spawning serve accept thread")?
        };
        Ok(TcpServer {
            addr,
            rx,
            _tx: tx,
            conns: HashMap::new(),
            run_id: run_id.to_string(),
            stop,
            accept: Some(accept),
        })
    }

    /// The bound address (port resolved when binding `:0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Handle one reader event: handshake validation (proto + run-id,
    /// same policy as the dist coordinator's `admit`), request intake,
    /// or departure (a dead connection's queued requests are voided —
    /// nobody is left to answer them).
    fn handle_event(
        &mut self,
        ev: Event,
        joined: &mut usize,
        pending: &mut VecDeque<Q>,
        max_queue_depth: usize,
        rejected: &mut usize,
    ) {
        match ev {
            Event::Hello { conn, mut stream, proto, run_id } => {
                if proto != PROTO_VERSION || run_id != self.run_id {
                    let _ = send_frame(
                        &mut stream,
                        &enc_reject(&format!(
                            "handshake mismatch: proto {proto} (want {PROTO_VERSION}), \
                             run-id {run_id:?} (want {:?})",
                            self.run_id
                        )),
                    );
                    return;
                }
                if send_frame(&mut stream, &enc_welcome(conn, 0)).is_ok() {
                    self.conns.insert(conn, stream);
                    *joined += 1;
                }
            }
            Event::Frame { conn, frame: Frame::Request { id, tokens } } => {
                if max_queue_depth > 0 && pending.len() >= max_queue_depth {
                    // ingress bound: shed visibly, never enqueue past the cap
                    obs::SERVE_REJECTS.incr();
                    *rejected += 1;
                    return;
                }
                obs::SERVE_REQUESTS.incr();
                obs::SERVE_REQ_BYTES.add((tokens.elems() * 4) as u64);
                pending.push_back(Q { conn, id, tokens, at: Instant::now() });
                obs::SERVE_QUEUE_DEPTH.set(pending.len() as u64);
            }
            Event::Frame { .. } => {}
            Event::Closed { conn } => {
                self.conns.remove(&conn);
                pending.retain(|q| q.conn != conn);
            }
        }
    }

    /// Run the continuous-batching serve loop over every connection:
    /// admit clients, coalesce their requests under `policy`, score each
    /// batch across the pool, and answer each request on the connection
    /// it arrived on. Returns when `max_requests` have been served
    /// (`0` = unbounded), or when at least one client joined and every
    /// connection has since departed with the queue drained. Errors if
    /// no client joins within `idle_timeout`.
    pub fn serve(
        &mut self,
        src: &dyn ScoreSource,
        policy: &BatchPolicy,
        max_requests: usize,
        idle_timeout: Duration,
    ) -> Result<ServeReport> {
        let _reg = trace::region("serve", "tcp_serve");
        let max_batch = policy.max_batch.max(1);
        let start = Instant::now();
        let mut joined = 0usize;
        let mut pending: VecDeque<Q> = VecDeque::new();
        let mut report = ServeReport::default();
        loop {
            if max_requests > 0 && report.served >= max_requests {
                break;
            }
            if joined > 0 && self.conns.is_empty() && pending.is_empty() {
                break;
            }
            if pending.is_empty() {
                if joined == 0 && start.elapsed() > idle_timeout {
                    bail!("no client joined {} within {idle_timeout:?}", self.addr);
                }
                // idle tick: short enough that the exit/timeout conditions
                // above are re-checked promptly
                match self.rx.recv_timeout(Duration::from_millis(25)) {
                    Ok(ev) => self.handle_event(
                        ev,
                        &mut joined,
                        &mut pending,
                        policy.max_queue_depth,
                        &mut report.rejected,
                    ),
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => break,
                }
                continue;
            }
            // coalesce until the batch fills or the head request's wait is up
            let deadline = pending[0].at + policy.max_wait;
            while pending.len() < max_batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match self.rx.recv_timeout(deadline - now) {
                    Ok(ev) => self.handle_event(
                        ev,
                        &mut joined,
                        &mut pending,
                        policy.max_queue_depth,
                        &mut report.rejected,
                    ),
                    Err(_) => break,
                }
            }
            let take = pending.len().min(max_batch);
            let batch: Vec<Q> = pending.drain(..take).collect();
            obs::SERVE_QUEUE_DEPTH.set(pending.len() as u64);
            let scores = {
                let _sp = trace::span("serve", "dispatch");
                obs::serve_fill(batch.len(), max_batch);
                pool::map(batch.len(), |j| src.score(batch[j].id, &batch[j].tokens))
            };
            let mut dead = Vec::new();
            for (q, s) in batch.iter().zip(scores) {
                let score = s?;
                let lat = q.at.elapsed().as_secs_f64();
                report.served += 1;
                report.latencies_s.push(lat);
                if let Some(stream) = self.conns.get_mut(&q.conn) {
                    if send_frame(stream, &enc_response(q.id, score, lat)).is_err() {
                        dead.push(q.conn);
                    }
                }
            }
            report.batches += 1;
            for c in dead {
                self.conns.remove(&c);
                pending.retain(|q| q.conn != c);
            }
        }
        obs::SERVE_QUEUE_DEPTH.set(0);
        Ok(report)
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let done = enc_done();
        for s in self.conns.values_mut() {
            let _ = send_frame(s, &done);
            let _ = s.shutdown(std::net::Shutdown::Both);
        }
        self.conns.clear();
        // wake the blocking accept() so its thread can observe `stop`
        let mut wake = self.addr;
        if wake.ip().is_unspecified() {
            wake.set_ip(IpAddr::V4(Ipv4Addr::LOCALHOST));
        }
        let _ = TcpStream::connect(wake);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for TcpServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Client side: handshake, pipeline every request, then collect exactly
/// `reqs.len()` responses (arrival order — the server's batching may
/// reorder relative to submission across connections, but a single
/// pipelined connection gets its answers in dispatch order). Fails loudly
/// on rejection or early server departure — never a silent short count.
pub fn run_client(connect: &str, run_id: &str, reqs: &[Request]) -> Result<Vec<Response>> {
    let _reg = trace::region("serve", "client");
    let mut stream =
        TcpStream::connect(connect).with_context(|| format!("connecting to {connect}"))?;
    let _ = stream.set_nodelay(true);
    send_frame(&mut stream, &enc_hello(run_id))?;
    // Bound every read: a server that never answers fails the client
    // instead of hanging it.
    let _ = stream.set_read_timeout(Some(Duration::from_secs(60)));
    match read_frame(&mut stream)? {
        Some(Frame::Welcome { .. }) => {}
        Some(Frame::Reject { reason }) => bail!("server rejected join: {reason}"),
        other => bail!("expected Welcome, got {other:?}"),
    }
    // pipeline everything up front: the server's continuous batcher is
    // what coalesces, the client never waits request-by-request
    for r in reqs {
        send_frame(&mut stream, &enc_request(r.id, &r.tokens))?;
    }
    let mut out = Vec::with_capacity(reqs.len());
    while out.len() < reqs.len() {
        match read_frame(&mut stream)? {
            Some(Frame::Response { id, score, latency_s }) => {
                out.push(Response { id, score, latency_s })
            }
            Some(Frame::Done) | None => {
                bail!("server closed after {}/{} responses", out.len(), reqs.len())
            }
            Some(other) => bail!("unexpected frame {other:?}"),
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::super::{synthetic_requests, SyntheticScoreSource};
    use super::*;

    #[test]
    fn tcp_roundtrip_scores_bitwise() {
        let mut server = TcpServer::bind("127.0.0.1:0", "net-test").unwrap();
        let addr = server.local_addr().to_string();
        let n = 6;
        let handle = std::thread::spawn(move || {
            let src = SyntheticScoreSource { work: 0 };
            let policy = BatchPolicy {
                max_batch: 3,
                max_wait: Duration::from_millis(1),
                max_queue_depth: 0,
            };
            server.serve(&src, &policy, n, Duration::from_secs(10)).unwrap()
        });
        let reqs = synthetic_requests(n, 1, 8, 97, 0xabc);
        let resps = run_client(&addr, "net-test", &reqs).unwrap();
        let report = handle.join().unwrap();
        assert_eq!(report.served, n);
        assert_eq!(resps.len(), n);
        let src = SyntheticScoreSource { work: 0 };
        for r in &resps {
            let direct = src.score(r.id, &reqs[r.id as usize].tokens).unwrap();
            assert_eq!(r.score.to_bits(), direct.to_bits());
            assert!(r.latency_s >= 0.0);
        }
    }

    #[test]
    fn bounded_server_sheds_past_queue_depth() {
        let mut server = TcpServer::bind("127.0.0.1:0", "shed-test").unwrap();
        let addr = server.local_addr().to_string();
        let handle = std::thread::spawn(move || {
            let src = SyntheticScoreSource { work: 0 };
            // depth 1 + a batch that never fills: the first request is
            // admitted, the other three pipelined ones hit the bound
            // inside the (long) coalesce window and are shed
            let policy = BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(500),
                max_queue_depth: 1,
            };
            server.serve(&src, &policy, 1, Duration::from_secs(10)).unwrap()
        });
        let reqs = synthetic_requests(4, 1, 8, 97, 0xdef);
        let err = run_client(&addr, "shed-test", &reqs).unwrap_err();
        assert!(
            err.to_string().contains("closed after 1/4"),
            "shed requests go unanswered, got: {err}"
        );
        let report = handle.join().unwrap();
        assert_eq!(report.served, 1);
        assert_eq!(report.rejected, 3, "every over-bound request is counted");
    }

    #[test]
    fn wrong_run_id_is_rejected() {
        let mut server = TcpServer::bind("127.0.0.1:0", "right-id").unwrap();
        let addr = server.local_addr().to_string();
        let handle = std::thread::spawn(move || {
            let src = SyntheticScoreSource { work: 0 };
            server.serve(&src, &BatchPolicy::default(), 1, Duration::from_millis(300))
        });
        let reqs = synthetic_requests(1, 1, 4, 97, 1);
        let err = run_client(&addr, "wrong-id", &reqs).unwrap_err();
        assert!(err.to_string().contains("rejected"), "got: {err}");
        // the server saw no valid join, so it times out with an error too
        assert!(handle.join().unwrap().is_err());
    }
}
