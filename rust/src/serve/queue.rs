//! Continuous-batching request queue: arrivals coalesce into
//! width-bucketed batches under a [`BatchPolicy`], dispatch fans out over
//! the persistent `util::pool`, and per-request latency is tracked from
//! enqueue to scored.
//!
//! Two entry points share one dispatch path:
//!
//! * [`score_batched`] — closed-loop: a request slice already in hand,
//!   scored bucket by bucket ([`crate::data::bucket_spans`] — the same
//!   ragged-tail arithmetic `Trainer::eval` uses).
//! * [`serve_loop`] — open-loop: a [`queue`] of timestamped arrivals,
//!   coalesced until the batch fills (`max_batch`) or the head request
//!   has waited `max_wait`, then dispatched. Returns every response once
//!   all [`Ingress`] handles are dropped and the queue is drained — no
//!   request is ever dropped or duplicated (`tests/serve_parity.rs`
//!   pins it under a multi-producer chaos burst).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::data::bucket_spans;
use crate::obs;
use crate::runtime::HostTensor;
use crate::util::{percentile, pool, trace};

use super::ScoreSource;

/// One scoring request: an id chosen by the producer plus the `[batch,
/// seq]` token block to score.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub tokens: HostTensor,
}

/// One scored response. The score is bitwise what scoring the request
/// alone would produce; the latency is enqueue→scored wall clock (zero
/// queue wait on the direct [`score_batched`] path).
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub score: f32,
    pub latency_s: f64,
}

/// Continuous-batching policy: coalesce arrivals until the batch fills
/// (`max_batch` requests) or the head request has waited `max_wait`.
/// Policy changes move latency/throughput trade-offs only — never scores.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Ingress bound: requests waiting (queued but not yet dispatched)
    /// may not exceed this; further submissions are shed with a typed
    /// [`SubmitError::QueueFull`]. `0` = unbounded (the default — the
    /// closed-loop drivers queue everything up front).
    pub max_queue_depth: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            max_queue_depth: 0,
        }
    }
}

/// Typed ingress rejection — shedding is always the caller's to observe,
/// never a silent drop (every shed also bumps `obs::SERVE_REJECTS`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// The queue already holds `max_queue_depth` waiting requests.
    QueueFull { depth: usize, max: usize },
    /// The serve loop is gone (its queue receiver was dropped).
    Closed,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull { depth, max } => {
                write!(f, "serve queue full ({depth} waiting, max {max})")
            }
            SubmitError::Closed => write!(f, "serve loop is gone"),
        }
    }
}

impl std::error::Error for SubmitError {}

struct Queued {
    req: Request,
    at: Instant,
}

/// Producer handle for [`serve_loop`]: clone one per producer thread;
/// drop every clone to let the loop drain and return.
#[derive(Clone)]
pub struct Ingress {
    tx: Sender<Queued>,
    /// Requests admitted but not yet dispatched (shared with the loop).
    depth: Arc<AtomicUsize>,
    /// Shed threshold (0 = unbounded).
    max_depth: usize,
}

impl Ingress {
    /// Enqueue one request, stamping the arrival instant its end-to-end
    /// latency is measured from. A full queue or a departed serve loop is
    /// a typed [`SubmitError`] — the request is shed *visibly*, never
    /// silently ([`obs::SERVE_REJECTS`] counts queue-full sheds).
    pub fn submit(&self, id: u64, tokens: HostTensor) -> Result<(), SubmitError> {
        if self.max_depth > 0 {
            // reserve a slot first so concurrent producers can't overshoot
            let mut cur = self.depth.load(Ordering::Relaxed);
            loop {
                if cur >= self.max_depth {
                    obs::SERVE_REJECTS.incr();
                    return Err(SubmitError::QueueFull { depth: cur, max: self.max_depth });
                }
                match self.depth.compare_exchange_weak(
                    cur,
                    cur + 1,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => break,
                    Err(seen) => cur = seen,
                }
            }
        } else {
            self.depth.fetch_add(1, Ordering::Relaxed);
        }
        obs::SERVE_REQUESTS.incr();
        obs::SERVE_REQ_BYTES.add((tokens.elems() * 4) as u64);
        let sent =
            self.tx.send(Queued { req: Request { id, tokens }, at: Instant::now() });
        if sent.is_err() {
            self.depth.fetch_sub(1, Ordering::Relaxed);
            return Err(SubmitError::Closed);
        }
        Ok(())
    }
}

/// Consumer end of the request channel (fed to [`serve_loop`]).
pub struct ServeQueue {
    rx: Receiver<Queued>,
    depth: Arc<AtomicUsize>,
}

/// Create the ingress/queue pair wiring producers to [`serve_loop`],
/// with unbounded ingress (every submission is admitted).
pub fn queue() -> (Ingress, ServeQueue) {
    queue_bounded(0)
}

/// [`queue`] with an ingress bound: at most `max_queue_depth` requests
/// may wait undispatched; beyond that [`Ingress::submit`] sheds with
/// [`SubmitError::QueueFull`]. `0` = unbounded.
pub fn queue_bounded(max_queue_depth: usize) -> (Ingress, ServeQueue) {
    let (tx, rx) = mpsc::channel();
    let depth = Arc::new(AtomicUsize::new(0));
    (
        Ingress { tx, depth: depth.clone(), max_depth: max_queue_depth },
        ServeQueue { rx, depth },
    )
}

/// Dispatch one coalesced batch across the pool and stamp responses.
/// Scheduling only: each request gets its own [`ScoreSource::score`]
/// call, so every score is bitwise identical to scoring alone.
fn dispatch(
    src: &dyn ScoreSource,
    batch: &[Queued],
    max_batch: usize,
) -> Result<Vec<Response>> {
    let _sp = trace::span("serve", "dispatch");
    obs::serve_fill(batch.len(), max_batch);
    let scores = pool::map(batch.len(), |j| src.score(batch[j].req.id, &batch[j].req.tokens));
    batch
        .iter()
        .zip(scores)
        .map(|(q, s)| {
            Ok(Response {
                id: q.req.id,
                score: s?,
                latency_s: q.at.elapsed().as_secs_f64(),
            })
        })
        .collect()
}

/// Closed-loop batched scoring of a request slice: width-bucketed spans,
/// one pool fan-out per bucket, scores returned in request order. The
/// direct path for "score this eval set now" callers (fig8 closed-loop,
/// the serve-vs-eval parity test).
pub fn score_batched(
    src: &dyn ScoreSource,
    reqs: &[Request],
    max_batch: usize,
) -> Result<Vec<f32>> {
    let _sp = trace::region("serve", "score_batched");
    let mut out = Vec::with_capacity(reqs.len());
    for (lo, len) in bucket_spans(reqs.len(), max_batch) {
        let _bsp = trace::span("serve", "bucket");
        obs::serve_fill(len, max_batch.max(1));
        let scores = pool::map(len, |j| {
            let r = &reqs[lo + j];
            src.score(r.id, &r.tokens)
        });
        for s in scores {
            out.push(s?);
        }
    }
    Ok(out)
}

/// The continuous-batching serve loop: block for the first arrival,
/// coalesce follow-ups under `policy`, dispatch the batch across the
/// pool, repeat. Returns every response (dispatch order) once all
/// [`Ingress`] handles are dropped and the queue has drained.
pub fn serve_loop(
    src: &dyn ScoreSource,
    policy: &BatchPolicy,
    q: ServeQueue,
) -> Result<Vec<Response>> {
    let _sp = trace::region("serve", "serve_loop");
    let max_batch = policy.max_batch.max(1);
    let mut out = Vec::new();
    let mut pending: Vec<Queued> = Vec::new();
    let mut open = true;
    while open || !pending.is_empty() {
        if pending.is_empty() {
            match q.rx.recv() {
                Ok(item) => pending.push(item),
                Err(_) => {
                    open = false;
                    continue;
                }
            }
        }
        // coalesce until the batch fills or the head request's wait is up
        let deadline = pending[0].at + policy.max_wait;
        while open && pending.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match q.rx.recv_timeout(deadline - now) {
                Ok(item) => pending.push(item),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => open = false,
            }
        }
        let take = pending.len().min(max_batch);
        let batch: Vec<Queued> = pending.drain(..take).collect();
        // free the dispatched requests' ingress slots before the (slow)
        // scoring fan-out, so bounded producers can refill meanwhile
        q.depth.fetch_sub(take, Ordering::Relaxed);
        obs::SERVE_QUEUE_DEPTH.set(pending.len() as u64);
        out.extend(dispatch(src, &batch, max_batch)?);
    }
    obs::SERVE_QUEUE_DEPTH.set(0);
    Ok(out)
}

/// Latency tail summary of a response set (seconds).
#[derive(Debug, Clone, Copy, Default)]
pub struct LatencySummary {
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub mean: f64,
}

/// p50/p95/p99/mean over the responses' end-to-end latencies.
pub fn latency_summary(resps: &[Response]) -> LatencySummary {
    let lat: Vec<f64> = resps.iter().map(|r| r.latency_s).collect();
    LatencySummary {
        p50: percentile(&lat, 0.50),
        p95: percentile(&lat, 0.95),
        p99: percentile(&lat, 0.99),
        mean: crate::util::mean(&lat),
    }
}

/// Order-independent digest of a response set: FNV-1a over `(id, score
/// bits)` in id order. The `digest=` line the loopback and TCP CLI
/// drivers print — equal digests mean bitwise-equal scores for the same
/// request stream, whatever batching or transport carried them.
pub fn score_digest(resps: &[Response]) -> u64 {
    let mut rows: Vec<(u64, u32)> = resps.iter().map(|r| (r.id, r.score.to_bits())).collect();
    rows.sort_unstable();
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for (id, bits) in rows {
        for b in id.to_le_bytes().into_iter().chain(bits.to_le_bytes()) {
            h = (h ^ b as u64).wrapping_mul(0x0100_0000_01b3);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::super::{synthetic_requests, SyntheticScoreSource};
    use super::*;

    #[test]
    fn score_batched_matches_direct_and_handles_ragged_tail() {
        let src = SyntheticScoreSource { work: 0 };
        let reqs = synthetic_requests(7, 1, 8, 97, 3);
        let direct: Vec<u32> = reqs
            .iter()
            .map(|r| src.score(r.id, &r.tokens).unwrap().to_bits())
            .collect();
        for bucket in [1, 3, 7, 100] {
            let got = score_batched(&src, &reqs, bucket).unwrap();
            let bits: Vec<u32> = got.iter().map(|s| s.to_bits()).collect();
            assert_eq!(bits, direct, "bucket {bucket}");
        }
        assert!(score_batched(&src, &[], 4).unwrap().is_empty());
    }

    #[test]
    fn serve_loop_drains_everything_submitted() {
        let src = SyntheticScoreSource { work: 0 };
        let reqs = synthetic_requests(5, 1, 4, 97, 4);
        let (ingress, q) = queue();
        for r in &reqs {
            ingress.submit(r.id, r.tokens.clone()).unwrap();
        }
        drop(ingress);
        let policy = BatchPolicy {
            max_batch: 2,
            max_wait: Duration::from_millis(1),
            max_queue_depth: 0,
        };
        let resps = serve_loop(&src, &policy, q).unwrap();
        assert_eq!(resps.len(), 5);
        let mut ids: Vec<u64> = resps.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
        for r in &resps {
            let direct = src.score(r.id, &reqs[r.id as usize].tokens).unwrap();
            assert_eq!(r.score.to_bits(), direct.to_bits());
            assert!(r.latency_s >= 0.0);
        }
        let s = latency_summary(&resps);
        assert!(s.p99 >= s.p50 && s.p50 >= 0.0);
    }

    #[test]
    fn bounded_ingress_sheds_visibly_then_recovers() {
        let src = SyntheticScoreSource { work: 0 };
        let reqs = synthetic_requests(5, 1, 4, 97, 8);
        let rejects_before = crate::obs::SERVE_REJECTS.get();
        let (ingress, q) = queue_bounded(3);
        for r in reqs.iter().take(3) {
            ingress.submit(r.id, r.tokens.clone()).unwrap();
        }
        // 4th submission finds the queue at its bound: typed shed
        let err = ingress.submit(reqs[3].id, reqs[3].tokens.clone()).unwrap_err();
        assert_eq!(err, SubmitError::QueueFull { depth: 3, max: 3 });
        assert!(crate::obs::SERVE_REJECTS.get() >= rejects_before + 1);
        // the loop drains the admitted three; their slots free up, so a
        // fresh bounded queue accepts again after dispatch
        drop(ingress);
        let policy = BatchPolicy { max_queue_depth: 3, ..BatchPolicy::default() };
        let resps = serve_loop(&src, &policy, q).unwrap();
        assert_eq!(resps.len(), 3, "only admitted requests are scored");
        let mut ids: Vec<u64> = resps.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2]);
        // scores of admitted requests are untouched by the shed
        for r in &resps {
            let direct = src.score(r.id, &reqs[r.id as usize].tokens).unwrap();
            assert_eq!(r.score.to_bits(), direct.to_bits());
        }
        // a departed loop is the other typed error
        let (ingress2, q2) = queue_bounded(1);
        drop(q2);
        assert_eq!(
            ingress2.submit(0, reqs[0].tokens.clone()).unwrap_err(),
            SubmitError::Closed
        );
    }

    #[test]
    fn digest_is_order_independent_and_score_sensitive() {
        let a = vec![
            Response { id: 0, score: 1.5, latency_s: 0.1 },
            Response { id: 1, score: 2.5, latency_s: 0.2 },
        ];
        let b = vec![a[1].clone(), a[0].clone()];
        assert_eq!(score_digest(&a), score_digest(&b));
        let mut c = a.clone();
        c[0].score = 1.25;
        assert_ne!(score_digest(&a), score_digest(&c));
    }
}
