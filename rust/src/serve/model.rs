//! Read-only servable model: weights + manifest + a prepared engine.
//!
//! Built by [`Checkpoint::load_model`] — the serving half of the
//! checkpoint split. `Trainer::restore` rebuilds *everything* (params,
//! optimizer state, RNG stream); this loader decodes *only* the
//! `param.*` blobs, through the same shape-checked
//! [`Checkpoint::decode_params`] decoder, so the two paths cannot
//! drift. No optimizer state is ever materialized: the obs state-bytes
//! gauge reads 0 for the lifetime of a serve process
//! (`tests/serve_parity.rs` pins it).
//!
//! Scoring goes through [`Engine::execute`] — the canonical `&self`
//! execution entry point — against the `eval_loss` artifact prepared
//! once at load. `&self` scoring is what lets a single `Arc<Model>` be
//! shared across the pool and every server connection without locks.

use std::path::Path;
use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::Checkpoint;
use crate::runtime::{Engine, HostTensor, Manifest};
use crate::util::trace;

use super::ScoreSource;

/// An immutable, forward-only model: checkpoint weights bound to a
/// prepared engine. Construction is the only `&mut` moment; after that
/// every method is `&self`.
pub struct Model {
    engine: Engine,
    params: Vec<HostTensor>,
    /// Training step the weights were checkpointed at.
    pub step: u64,
}

impl Model {
    /// Bind checkpoint weights to `engine`: decode the `param.*` blobs
    /// (manifest order, shape-checked) and prepare the `eval_loss`
    /// artifact so [`Model::score_block`] needs no mutable access.
    pub fn new(ck: &Checkpoint, mut engine: Engine) -> Result<Self> {
        let params = ck.decode_params(&engine.manifest.params)?;
        engine.prepare("eval_loss")?;
        Ok(Model { engine, params, step: ck.step })
    }

    /// The artifact manifest the model was loaded against.
    pub fn manifest(&self) -> &Manifest {
        &self.engine.manifest
    }

    /// Token-block shape `(batch, seq)` every request must match.
    pub fn block_shape(&self) -> (usize, usize) {
        let m = &self.engine.manifest.model;
        (m.batch, m.seq)
    }

    /// Score one `[batch, seq]` token block: mean eval loss, bitwise
    /// identical to `Trainer::eval` on the same block (same artifact,
    /// same params, same engine path).
    pub fn score_block(&self, tokens: &HostTensor) -> Result<f32> {
        let _sp = trace::span("serve", "score");
        let mut inputs: Vec<&HostTensor> = Vec::with_capacity(1 + self.params.len());
        inputs.push(tokens);
        inputs.extend(self.params.iter());
        let outs = self.engine.execute("eval_loss", &inputs)?;
        outs[0].scalar()
    }
}

impl ScoreSource for Model {
    fn score(&self, _id: u64, tokens: &HostTensor) -> Result<f32> {
        self.score_block(tokens)
    }
}

impl Checkpoint {
    /// Load a servable [`Model`] from this checkpoint: weights only, no
    /// optimizer state, no `Trainer`. The `param.*` blobs are decoded
    /// through [`Checkpoint::decode_params`] — the same shape-checked
    /// decoder `Trainer::restore` uses — while `state.*`,
    /// `trainer.stream`, and dist blobs are never touched, so the obs
    /// state-bytes gauge stays 0 in a serve process.
    pub fn load_model(&self, artifacts: impl AsRef<Path>) -> Result<Arc<Model>> {
        let engine = Engine::new(artifacts)?;
        Ok(Arc::new(Model::new(self, engine)?))
    }
}
