//! Psyche-style round state machine for the simulated data-parallel
//! cluster (SNIPPETS §1): explicit membership, a tick-driven phase cycle,
//! and per-round worker health / straggler accounting.
//!
//! ```text
//!              join ≥ min_workers            warmup_ticks elapse
//! WaitingForMembers ────────────▶ Warmup ────────────────▶ RoundTrain
//!        ▲                          │ members < min            │ all
//!        │                          ▼                          │ shards
//!        │◀───────────────── WaitingForMembers                 │ done
//!        │                                                     ▼
//!        │   members < min   Cooldown ◀──────────────────── Reduce
//!        └───────────────────── │        reduce finished
//!                               │ cooldown_ticks elapse
//!                               ▼
//!                          RoundTrain (next round)
//! ```
//!
//! Ticks are *logical* (the trainer ticks between phases of one optimizer
//! step; a real deployment would tick on a timer), so the machine is fully
//! deterministic and unit-testable. Departing mid-round requeues the
//! worker's unfinished microbatch indices to the survivors — the tree
//! reduce in [`super::reduce`] is global-index aligned, so a requeue never
//! changes the reduced bits.
//!
//! The whole machine serializes to a flat f32 blob ([`snapshot`] /
//! [`restore`]) so checkpoints can carry round state next to the RNG /
//! data-stream position, including mid-round (assignments + completion
//! flags survive).
//!
//! [`snapshot`]: RoundCoordinator::snapshot
//! [`restore`]: RoundCoordinator::restore

use anyhow::{bail, Result};

use crate::coordinator::checkpoint::{chunks_to_u64, u64_to_chunks};
use crate::util::json::{self, Json};
use crate::util::{median, trace};

/// Phase of the current round (the Psyche lifecycle; round-end witness
/// broadcast lives in `transport`/`demo`, fed by [`WitnessReport`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    WaitingForMembers,
    Warmup,
    RoundTrain,
    Reduce,
    Cooldown,
}

impl Phase {
    fn index(self) -> u32 {
        match self {
            Phase::WaitingForMembers => 0,
            Phase::Warmup => 1,
            Phase::RoundTrain => 2,
            Phase::Reduce => 3,
            Phase::Cooldown => 4,
        }
    }

    fn from_index(i: u32) -> Result<Self> {
        Ok(match i {
            0 => Phase::WaitingForMembers,
            1 => Phase::Warmup,
            2 => Phase::RoundTrain,
            3 => Phase::Reduce,
            4 => Phase::Cooldown,
            _ => bail!("invalid phase index {i}"),
        })
    }

    /// Static display name (trace markers need `&'static str`).
    pub fn name(self) -> &'static str {
        match self {
            Phase::WaitingForMembers => "WaitingForMembers",
            Phase::Warmup => "Warmup",
            Phase::RoundTrain => "RoundTrain",
            Phase::Reduce => "Reduce",
            Phase::Cooldown => "Cooldown",
        }
    }
}

/// Tunables for the round machine (from `[dist]` via `DistConfig`).
#[derive(Debug, Clone)]
pub struct RoundCfg {
    /// Members required to enter / stay in the training cycle.
    pub min_workers: usize,
    /// Logical ticks spent in Warmup before the first round.
    pub warmup_ticks: u32,
    /// Logical ticks spent in Cooldown between rounds.
    pub cooldown_ticks: u32,
    /// A worker is logged as a straggler when its shard wall-clock exceeds
    /// this multiple of the round's median shard time.
    pub straggler_factor: f64,
}

impl Default for RoundCfg {
    fn default() -> Self {
        RoundCfg {
            min_workers: 1,
            warmup_ticks: 1,
            cooldown_ticks: 1,
            straggler_factor: 3.0,
        }
    }
}

/// Per-member health ledger, kept across rounds.
#[derive(Debug, Clone)]
pub struct WorkerHealth {
    pub id: usize,
    pub alive: bool,
    /// Round counter at join time (0 = founding member).
    pub joined_round: u64,
    pub rounds_done: u64,
    pub micro_done: u64,
    /// Microbatches this worker picked up from departed members.
    pub requeued: u64,
    /// Rounds where this worker exceeded the straggler threshold.
    pub straggles: u64,
}

/// One finished round, surfaced in `Summary.rounds`.
#[derive(Debug, Clone)]
pub struct RoundRecord {
    pub round: u64,
    /// Members that executed a non-empty shard.
    pub workers: usize,
    pub micro: usize,
    /// Microbatches moved to survivors by mid-round departures.
    pub requeues: u64,
    pub stragglers: u64,
    /// Gradient-phase wall clock (slowest shard).
    pub grad_secs: f64,
    pub reduce_secs: f64,
    /// Slowest ÷ mean shard time over non-empty shards (1.0 = balanced).
    pub imbalance: f64,
    /// Median shard wall-clock over non-empty finite shards — the
    /// straggler baseline, carried so the witness broadcast (and the
    /// metrics CSV) can surface it without re-deriving.
    pub median_secs: f64,
}

/// Per-member entry of the witness health ledger.
#[derive(Debug, Clone, PartialEq)]
pub struct WitnessMember {
    pub id: u64,
    pub alive: bool,
    pub micro_done: u64,
    /// Microbatches picked up from departed members, cumulative.
    pub requeued: u64,
    pub straggles: u64,
}

/// Round-end telemetry broadcast to every connected worker (Psyche's
/// witness model): the finished round's record plus the per-member
/// health ledger, so clients can surface straggler/requeue state the
/// coordinator already tracks. Serialized as a `Witness` wire frame by
/// `transport` and appended to `runs/witness.jsonl` by demo workers.
#[derive(Debug, Clone, PartialEq)]
pub struct WitnessReport {
    pub round: u64,
    pub workers: u64,
    pub micro: u64,
    pub requeues: u64,
    pub stragglers: u64,
    pub grad_secs: f64,
    pub reduce_secs: f64,
    pub imbalance: f64,
    pub median_secs: f64,
    pub members: Vec<WitnessMember>,
}

impl WitnessReport {
    /// One `witness.jsonl` line (sorted keys, see `util::json`).
    pub fn to_json(&self) -> Json {
        let members: Vec<Json> = self
            .members
            .iter()
            .map(|m| {
                json::obj(vec![
                    ("id", json::num(m.id as f64)),
                    ("alive", Json::Bool(m.alive)),
                    ("micro_done", json::num(m.micro_done as f64)),
                    ("requeued", json::num(m.requeued as f64)),
                    ("straggles", json::num(m.straggles as f64)),
                ])
            })
            .collect();
        json::obj(vec![
            ("round", json::num(self.round as f64)),
            ("workers", json::num(self.workers as f64)),
            ("micro", json::num(self.micro as f64)),
            ("requeues", json::num(self.requeues as f64)),
            ("stragglers", json::num(self.stragglers as f64)),
            ("grad_secs", json::num(self.grad_secs)),
            ("reduce_secs", json::num(self.reduce_secs)),
            ("imbalance", json::num(self.imbalance)),
            ("median_secs", json::num(self.median_secs)),
            ("members", Json::Arr(members)),
        ])
    }
}

#[derive(Debug)]
pub struct RoundCoordinator {
    pub cfg: RoundCfg,
    pub phase: Phase,
    /// 1-based once training starts; 0 while waiting/warming up.
    pub round: u64,
    ticks_in_phase: u32,
    pub members: Vec<WorkerHealth>,
    /// Per-member global microbatch indices for the active round (empty
    /// between rounds and for dead / late-joining members).
    assignment: Vec<Vec<usize>>,
    shard_done: Vec<bool>,
    shard_secs: Vec<f64>,
    round_micro: usize,
    requeues_this_round: u64,
    reduce_done: bool,
    reduce_secs: f64,
    /// Per-segment delivery ledger for the pipelined (eager) reduce path:
    /// aligned `(lo, len)` spans already handed to the eager reducer.
    /// Transient — never serialized, because a mid-round checkpoint
    /// restores into full re-execution of every shard ([`resume_round`]
    /// clears it along with the completion flags).
    ///
    /// [`resume_round`]: Self::resume_round
    delivered: Vec<(usize, usize)>,
    pub log: Vec<RoundRecord>,
}

impl RoundCoordinator {
    pub fn new(cfg: RoundCfg) -> Self {
        RoundCoordinator {
            cfg,
            phase: Phase::WaitingForMembers,
            round: 0,
            ticks_in_phase: 0,
            members: Vec::new(),
            assignment: Vec::new(),
            shard_done: Vec::new(),
            shard_secs: Vec::new(),
            round_micro: 0,
            requeues_this_round: 0,
            reduce_done: false,
            reduce_secs: 0.0,
            delivered: Vec::new(),
            log: Vec::new(),
        }
    }

    /// Register a worker. Joining mid-round is allowed but the member only
    /// receives a shard from the next `begin_round` on.
    pub fn join(&mut self, id: usize) {
        if self.members.iter().any(|m| m.id == id && m.alive) {
            return;
        }
        self.members.push(WorkerHealth {
            id,
            alive: true,
            joined_round: self.round,
            rounds_done: 0,
            micro_done: 0,
            requeued: 0,
            straggles: 0,
        });
        self.assignment.push(Vec::new());
        self.shard_done.push(true);
        self.shard_secs.push(0.0);
    }

    /// Remove a worker. If it departs mid-`RoundTrain` with an unfinished
    /// shard, its indices are requeued round-robin (member order, index
    /// order) to the surviving members — deterministically, and without
    /// changing the reduced bits (tree reduce is index-aligned).
    pub fn leave(&mut self, id: usize) {
        self.leave_undelivered(id, 0);
    }

    /// [`leave`](Self::leave) for the pipelined (eager-delivery) path: the
    /// departing member already streamed its first `delivered` assigned
    /// microbatches into the eager reducer, so only the undelivered suffix
    /// `assignment[idx][delivered..]` is requeued — the delivered prefix
    /// stays assigned (its leaves are merged and must not re-execute).
    /// `delivered = 0` is exactly the phased `leave`.
    pub fn leave_undelivered(&mut self, id: usize, delivered: usize) {
        let Some(idx) = self.members.iter().position(|m| m.id == id && m.alive) else {
            return;
        };
        self.members[idx].alive = false;
        if self.phase == Phase::RoundTrain && !self.shard_done[idx] {
            assert!(
                delivered <= self.assignment[idx].len(),
                "member {id} delivered {delivered} > assigned {}",
                self.assignment[idx].len()
            );
            if !self.members.iter().any(|m| m.alive) {
                // No survivor to take the shard: keep it assigned and not
                // done, so the round visibly stalls (all_done stays false)
                // instead of reducing a silent subset of the microbatches.
                return;
            }
            let orphaned = self.assignment[idx].split_off(delivered);
            self.shard_done[idx] = true;
            self.requeue_orphans(orphaned);
        }
    }

    /// Distribute a dead member's unexecuted indices: round-robin over the
    /// still-running survivors, else merged onto the first alive member.
    fn requeue_orphans(&mut self, orphaned: Vec<usize>) {
        if orphaned.is_empty() {
            return;
        }
        let survivors: Vec<usize> = self
            .members
            .iter()
            .enumerate()
            .filter(|(i, m)| m.alive && !self.shard_done[*i])
            .map(|(i, _)| i)
            .collect();
        if survivors.is_empty() {
            // everyone else already finished: hand the orphans to the
            // first alive member (it re-runs a second, merged shard —
            // reverse its earlier credit so complete() counts the
            // round and its own microbatches exactly once)
            if let Some(w) = self.members.iter().position(|m| m.alive) {
                if self.shard_done[w] && !self.assignment[w].is_empty() {
                    self.members[w].rounds_done -= 1;
                    self.members[w].micro_done -= self.assignment[w].len() as u64;
                }
                self.requeues_this_round += orphaned.len() as u64;
                self.members[w].requeued += orphaned.len() as u64;
                crate::obs::REQUEUES.add(orphaned.len() as u64);
                self.assignment[w].extend(&orphaned);
                self.shard_done[w] = false;
            }
        } else {
            for (k, &mi) in orphaned.iter().enumerate() {
                let w = survivors[k % survivors.len()];
                self.requeues_this_round += 1;
                self.members[w].requeued += 1;
                crate::obs::REQUEUES.incr();
                self.assignment[w].push(mi);
            }
        }
    }

    pub fn alive(&self) -> usize {
        self.members.iter().filter(|m| m.alive).count()
    }

    /// Advance the state machine one logical tick. Phase-exit conditions
    /// are re-checked every tick; the new (possibly unchanged) phase is
    /// returned.
    pub fn tick(&mut self) -> Phase {
        self.ticks_in_phase += 1;
        match self.phase {
            Phase::WaitingForMembers => {
                if self.alive() >= self.cfg.min_workers {
                    self.enter(Phase::Warmup);
                }
            }
            Phase::Warmup => {
                if self.alive() < self.cfg.min_workers {
                    self.enter(Phase::WaitingForMembers);
                } else if self.ticks_in_phase >= self.cfg.warmup_ticks {
                    self.round += 1;
                    self.enter(Phase::RoundTrain);
                }
            }
            Phase::RoundTrain => {
                if self.round_micro > 0 && self.shard_done.iter().all(|&d| d) {
                    self.enter(Phase::Reduce);
                }
            }
            Phase::Reduce => {
                if self.reduce_done {
                    self.record_round();
                    self.enter(Phase::Cooldown);
                }
            }
            Phase::Cooldown => {
                if self.ticks_in_phase >= self.cfg.cooldown_ticks {
                    if self.alive() < self.cfg.min_workers {
                        self.enter(Phase::WaitingForMembers);
                    } else {
                        self.round += 1;
                        self.enter(Phase::RoundTrain);
                    }
                }
            }
        }
        self.phase
    }

    fn enter(&mut self, phase: Phase) {
        trace::instant("round", phase.name());
        self.phase = phase;
        self.ticks_in_phase = 0;
    }

    /// Witness for the most recently recorded round: the last
    /// [`RoundRecord`] joined with the current per-member health ledger.
    /// `None` until a first round completes.
    pub fn witness(&self) -> Option<WitnessReport> {
        let rec = self.log.last()?;
        Some(WitnessReport {
            round: rec.round,
            workers: rec.workers as u64,
            micro: rec.micro as u64,
            requeues: rec.requeues,
            stragglers: rec.stragglers,
            grad_secs: rec.grad_secs,
            reduce_secs: rec.reduce_secs,
            imbalance: rec.imbalance,
            median_secs: rec.median_secs,
            members: self
                .members
                .iter()
                .map(|m| WitnessMember {
                    id: m.id as u64,
                    alive: m.alive,
                    micro_done: m.micro_done,
                    requeued: m.requeued,
                    straggles: m.straggles,
                })
                .collect(),
        })
    }

    /// Tick until the machine sits in `RoundTrain` with no active
    /// assignment (ready for `begin_round`). Errors when membership can't
    /// satisfy `min_workers` (the machine would spin in waiting forever).
    pub fn advance_to_train(&mut self) -> Result<()> {
        for _ in 0..(self.cfg.warmup_ticks + self.cfg.cooldown_ticks + 4) {
            if self.phase == Phase::RoundTrain && self.round_micro == 0 {
                return Ok(());
            }
            if self.phase == Phase::WaitingForMembers
                && self.alive() < self.cfg.min_workers
            {
                bail!(
                    "round {}: {} alive worker(s) < min_workers {}",
                    self.round,
                    self.alive(),
                    self.cfg.min_workers
                );
            }
            self.tick();
        }
        bail!("round machine failed to reach RoundTrain (phase {:?})", self.phase)
    }

    /// Partition `micro` global microbatch indices contiguously over the
    /// alive members (member order) and arm the round.
    pub fn begin_round(&mut self, micro: usize) -> Result<()> {
        if self.phase != Phase::RoundTrain {
            bail!("begin_round in phase {:?}", self.phase);
        }
        if self.round_micro != 0 {
            bail!("round {} already armed", self.round);
        }
        if micro == 0 {
            bail!("a round needs at least one microbatch");
        }
        let alive: Vec<usize> = self
            .members
            .iter()
            .enumerate()
            .filter(|(_, m)| m.alive)
            .map(|(i, _)| i)
            .collect();
        if alive.is_empty() {
            bail!("no alive members");
        }
        let w = alive.len();
        for (k, &mi) in alive.iter().enumerate() {
            let (lo, hi) = (k * micro / w, (k + 1) * micro / w);
            self.assignment[mi] = (lo..hi).collect();
            self.shard_done[mi] = lo == hi;
            self.shard_secs[mi] = 0.0;
        }
        for (i, m) in self.members.iter().enumerate() {
            if !m.alive {
                self.assignment[i].clear();
                self.shard_done[i] = true;
                self.shard_secs[i] = 0.0;
            }
        }
        self.round_micro = micro;
        self.requeues_this_round = 0;
        self.reduce_done = false;
        self.reduce_secs = 0.0;
        self.delivered.clear();
        Ok(())
    }

    /// Whether the machine holds an armed, unfinished round (the state a
    /// mid-round checkpoint restores into).
    pub fn mid_round(&self) -> bool {
        self.phase == Phase::RoundTrain && self.round_micro != 0
    }

    /// Re-arm a restored mid-round coordinator for re-execution
    /// (`run_round` calls this instead of `begin_round` when
    /// [`mid_round`](Self::mid_round) is true). Shard *assignments* —
    /// including any requeue adjustments — survive a checkpoint, but the
    /// executed gradients do not, so every shard re-runs: members already
    /// credited for this round have that credit reversed (they will be
    /// credited again on completion), and shards stranded on dead members
    /// are requeued to the first alive member.
    pub fn resume_round(&mut self, micro: usize) -> Result<()> {
        if !self.mid_round() {
            bail!("resume_round outside an armed round (phase {:?})", self.phase);
        }
        if micro != self.round_micro {
            bail!(
                "resume_round with {micro} microbatches, round {} was armed with {}",
                self.round,
                self.round_micro
            );
        }
        let mut orphaned: Vec<usize> = Vec::new();
        for i in 0..self.members.len() {
            if self.assignment[i].is_empty() {
                continue;
            }
            if self.members[i].alive {
                if self.shard_done[i] {
                    self.members[i].rounds_done -= 1;
                    self.members[i].micro_done -= self.assignment[i].len() as u64;
                }
                self.shard_done[i] = false;
                self.shard_secs[i] = 0.0;
            } else {
                // completed-then-departed before the snapshot: its leaves
                // must be recomputed by a survivor (its ledger keeps the
                // pre-snapshot execution — that did happen)
                orphaned.extend(std::mem::take(&mut self.assignment[i]));
                self.shard_done[i] = true;
            }
        }
        if !orphaned.is_empty() {
            let Some(w) = self
                .members
                .iter()
                .position(|m| m.alive)
            else {
                bail!("round {}: no alive member to resume onto", self.round);
            };
            self.requeues_this_round += orphaned.len() as u64;
            self.members[w].requeued += orphaned.len() as u64;
            crate::obs::REQUEUES.add(orphaned.len() as u64);
            self.assignment[w].extend(&orphaned);
            self.shard_done[w] = false;
        }
        self.reduce_done = false;
        self.delivered.clear();
        Ok(())
    }

    /// Active-round shard per member (parallel to `members`).
    pub fn assignments(&self) -> &[Vec<usize>] {
        &self.assignment
    }

    /// Mark member `idx`'s shard executed (updates the health ledger).
    pub fn complete(&mut self, idx: usize, secs: f64) {
        if self.shard_done[idx] && self.assignment[idx].is_empty() {
            return; // idle member this round
        }
        self.shard_done[idx] = true;
        self.shard_secs[idx] = secs;
        self.members[idx].rounds_done += 1;
        self.members[idx].micro_done += self.assignment[idx].len() as u64;
    }

    pub fn all_done(&self) -> bool {
        self.shard_done.iter().all(|&d| d)
    }

    // ------------------------------------------- eager-delivery ledger ---

    /// Record aligned `(lo, len)` spans handed to the eager reducer. The
    /// pipelined path calls this once per shard delivery; the asserts pin
    /// the exactly-once contract (aligned spans, no overlap) that makes
    /// out-of-order merging bitwise-legal.
    pub fn deliver_segments(&mut self, spans: &[(usize, usize)]) {
        for &(lo, len) in spans {
            assert!(
                len.is_power_of_two() && lo % len == 0,
                "delivered span [{lo}, {}) is not an aligned segment",
                lo + len
            );
            for &(plo, plen) in &self.delivered {
                assert!(
                    lo + len <= plo || plo + plen <= lo,
                    "span [{lo}, {}) overlaps already-delivered [{plo}, {})",
                    lo + len,
                    plo + plen
                );
            }
            self.delivered.push((lo, len));
        }
    }

    /// Microbatches covered by delivered segments so far this round.
    pub fn delivered_micro(&self) -> usize {
        self.delivered.iter().map(|&(_, len)| len).sum()
    }

    /// Whether every microbatch of the armed round has been delivered to
    /// the eager reducer (the pipelined analogue of [`all_done`]).
    ///
    /// [`all_done`]: Self::all_done
    pub fn segments_complete(&self) -> bool {
        self.round_micro > 0 && self.delivered_micro() == self.round_micro
    }

    /// Mark the tree reduce finished (ticking then leaves `Reduce`).
    pub fn finish_reduce(&mut self, secs: f64) {
        self.reduce_done = true;
        self.reduce_secs = secs;
    }

    /// Close the books on the finished round: straggler detection against
    /// the median shard time, imbalance, and the log entry. Non-finite
    /// shard times (a clock gone wrong, or a remote worker reporting
    /// garbage over the wire) are excluded from every statistic — they
    /// must never poison the median or flag honest workers as stragglers.
    fn record_round(&mut self) {
        let workers = (0..self.members.len())
            .filter(|&i| !self.assignment[i].is_empty())
            .count();
        let times: Vec<f64> = (0..self.members.len())
            .filter(|&i| !self.assignment[i].is_empty() && self.shard_secs[i].is_finite())
            .map(|i| self.shard_secs[i])
            .collect();
        let med = median(&times);
        let mut stragglers = 0u64;
        for i in 0..self.members.len() {
            if !self.assignment[i].is_empty()
                && med > 0.0
                && self.shard_secs[i].is_finite()
                && self.shard_secs[i] > self.cfg.straggler_factor * med
            {
                self.members[i].straggles += 1;
                stragglers += 1;
            }
        }
        let max = times.iter().cloned().fold(0.0f64, f64::max);
        let mean = if times.is_empty() {
            0.0
        } else {
            times.iter().sum::<f64>() / times.len() as f64
        };
        self.log.push(RoundRecord {
            round: self.round,
            workers,
            micro: self.round_micro,
            requeues: self.requeues_this_round,
            stragglers,
            grad_secs: max,
            reduce_secs: self.reduce_secs,
            imbalance: if mean > 0.0 { max / mean } else { 1.0 },
            median_secs: med,
        });
        for a in self.assignment.iter_mut() {
            a.clear();
        }
        for d in self.shard_done.iter_mut() {
            *d = true;
        }
        self.round_micro = 0;
        self.delivered.clear();
    }

    // ------------------------------------------------ checkpoint codec ---

    const SNAP_VERSION: f32 = 2.0;
    const SNAP_VERSION_V1: f32 = 1.0;

    /// Flatten the machine (phase, round counter, membership ledger, and —
    /// mid-round — assignments + completion flags) into small exact-f32
    /// integers, the same container the `trainer.stream` blob uses. The
    /// round log is *not* carried: it is run telemetry, surfaced through
    /// `Summary`, and a resumed run starts a fresh log.
    ///
    /// v2 codec: every integer (member ids, assignment lengths, global
    /// microbatch indices, tick counters) goes through `u64_to_chunks`
    /// instead of a raw `x as f32` — indices ≥ 2²⁴ would silently round
    /// otherwise — and `shard_secs` travels as the f64 bit pattern split
    /// into the same chunks, so post-resume straggler accounting is
    /// bit-identical to an uninterrupted run.
    pub fn snapshot(&self) -> Vec<f32> {
        let mut out = vec![
            Self::SNAP_VERSION,
            self.phase.index() as f32,
            if self.reduce_done { 1.0 } else { 0.0 },
        ];
        for w in [
            self.ticks_in_phase as u64,
            self.round_micro as u64,
            self.requeues_this_round,
            self.members.len() as u64,
            self.round,
        ] {
            out.extend_from_slice(&u64_to_chunks(w));
        }
        for (i, m) in self.members.iter().enumerate() {
            out.extend_from_slice(&u64_to_chunks(m.id as u64));
            out.push(if m.alive { 1.0 } else { 0.0 });
            for w in [m.joined_round, m.rounds_done, m.micro_done, m.requeued, m.straggles] {
                out.extend_from_slice(&u64_to_chunks(w));
            }
            out.extend_from_slice(&u64_to_chunks(self.assignment[i].len() as u64));
            for &x in &self.assignment[i] {
                out.extend_from_slice(&u64_to_chunks(x as u64));
            }
            out.push(if self.shard_done[i] { 1.0 } else { 0.0 });
            out.extend_from_slice(&u64_to_chunks(self.shard_secs[i].to_bits()));
        }
        out
    }

    /// Rebuild from a [`snapshot`](Self::snapshot) blob. Accepts the
    /// current v2 codec and the legacy v1 layout (raw-f32 integers), so
    /// checkpoints written before the codec fix stay loadable.
    pub fn restore(cfg: RoundCfg, data: &[f32]) -> Result<Self> {
        let mut cur = Cursor { data, pos: 0 };
        let ver = cur.f()?;
        let v1 = if ver == Self::SNAP_VERSION {
            false
        } else if ver == Self::SNAP_VERSION_V1 {
            true
        } else {
            bail!("unsupported dist snapshot version {ver}");
        };
        let phase = Phase::from_index(cur.f()? as u32)?;
        // v1 field order: ticks, reduce_done, micro, requeues, nmembers,
        // round-as-chunks. v2 hoists the flag and chunks every counter.
        let (ticks_in_phase, reduce_done, round_micro, requeues_this_round, nmembers, round);
        if v1 {
            ticks_in_phase = cur.f()? as u32;
            reduce_done = cur.f()? != 0.0;
            round_micro = cur.f()? as usize;
            requeues_this_round = cur.f()? as u64;
            nmembers = cur.f()? as usize;
            round = cur.u()?;
        } else {
            reduce_done = cur.f()? != 0.0;
            ticks_in_phase = cur.u()? as u32;
            round_micro = cur.u()? as usize;
            requeues_this_round = cur.u()?;
            nmembers = cur.u()? as usize;
            round = cur.u()?;
        }
        let mut coord = RoundCoordinator::new(cfg);
        coord.phase = phase;
        coord.round = round;
        coord.ticks_in_phase = ticks_in_phase;
        coord.reduce_done = reduce_done;
        coord.round_micro = round_micro;
        coord.requeues_this_round = requeues_this_round;
        for _ in 0..nmembers {
            let id = if v1 { cur.f()? as usize } else { cur.u()? as usize };
            let alive = cur.f()? != 0.0;
            coord.members.push(WorkerHealth {
                id,
                alive,
                joined_round: cur.u()?,
                rounds_done: cur.u()?,
                micro_done: cur.u()?,
                requeued: cur.u()?,
                straggles: cur.u()?,
            });
            let alen = if v1 { cur.f()? as usize } else { cur.u()? as usize };
            // each index consumes ≥ 1 word — bound the allocation by the
            // remaining blob so a corrupted length errors instead of
            // attempting a huge Vec::with_capacity
            if alen > cur.data.len() - cur.pos {
                bail!(
                    "dist snapshot assignment length {alen} exceeds remaining {} words",
                    cur.data.len() - cur.pos
                );
            }
            let mut assign = Vec::with_capacity(alen);
            for _ in 0..alen {
                assign.push(if v1 { cur.f()? as usize } else { cur.u()? as usize });
            }
            coord.assignment.push(assign);
            coord.shard_done.push(cur.f()? != 0.0);
            if v1 {
                coord.shard_secs.push(cur.f()? as f64);
            } else {
                coord.shard_secs.push(f64::from_bits(cur.u()?));
            }
        }
        Ok(coord)
    }
}

/// Forward reader over a snapshot blob.
struct Cursor<'a> {
    data: &'a [f32],
    pos: usize,
}

impl Cursor<'_> {
    fn f(&mut self) -> Result<f32> {
        let Some(&x) = self.data.get(self.pos) else {
            bail!("truncated dist snapshot at word {}", self.pos);
        };
        self.pos += 1;
        Ok(x)
    }

    fn u(&mut self) -> Result<u64> {
        let c = [self.f()?, self.f()?, self.f()?, self.f()?];
        Ok(chunks_to_u64(&c))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn training_coord(workers: usize) -> RoundCoordinator {
        let mut c = RoundCoordinator::new(RoundCfg {
            min_workers: workers.min(2),
            warmup_ticks: 2,
            cooldown_ticks: 1,
            straggler_factor: 3.0,
        });
        for w in 0..workers {
            c.join(w);
        }
        c
    }

    #[test]
    fn lifecycle_reaches_train_and_cycles() {
        let mut c = training_coord(3);
        assert_eq!(c.phase, Phase::WaitingForMembers);
        c.advance_to_train().unwrap();
        assert_eq!(c.phase, Phase::RoundTrain);
        assert_eq!(c.round, 1);

        c.begin_round(8).unwrap();
        let total: usize = c.assignments().iter().map(|a| a.len()).sum();
        assert_eq!(total, 8);
        // contiguous cover of [0, 8)
        let mut all: Vec<usize> = c.assignments().iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..8).collect::<Vec<_>>());

        for i in 0..3 {
            c.complete(i, 0.01);
        }
        assert_eq!(c.tick(), Phase::Reduce);
        c.finish_reduce(0.001);
        assert_eq!(c.tick(), Phase::Cooldown);
        assert_eq!(c.log.len(), 1);
        assert_eq!(c.log[0].round, 1);
        assert_eq!(c.log[0].micro, 8);
        assert_eq!(c.log[0].workers, 3);

        // next round
        c.advance_to_train().unwrap();
        assert_eq!(c.round, 2);
        c.begin_round(4).unwrap();
        assert!(!c.all_done());
    }

    #[test]
    fn membership_below_min_gates_training() {
        let mut c = RoundCoordinator::new(RoundCfg {
            min_workers: 2,
            ..RoundCfg::default()
        });
        c.join(0);
        assert!(c.advance_to_train().is_err(), "1 < min_workers must error");
        c.join(1);
        c.advance_to_train().unwrap();
        // losing a member during warmup of the *next* epoch falls back
        let mut c2 = training_coord(2);
        c2.tick(); // -> Warmup
        assert_eq!(c2.phase, Phase::Warmup);
        c2.leave(1);
        assert_eq!(c2.tick(), Phase::WaitingForMembers);
    }

    #[test]
    fn departure_mid_round_requeues_deterministically() {
        let mut c = training_coord(3);
        c.advance_to_train().unwrap();
        c.begin_round(9).unwrap();
        let before: Vec<Vec<usize>> = c.assignments().to_vec();
        assert_eq!(before, vec![vec![0, 1, 2], vec![3, 4, 5], vec![6, 7, 8]]);
        // worker 0 finishes, worker 1 dies: its shard round-robins to the
        // only still-running member (worker 2)
        c.complete(0, 0.01);
        c.leave(1);
        assert_eq!(c.assignments()[1], Vec::<usize>::new());
        assert_eq!(c.assignments()[2], vec![6, 7, 8, 3, 4, 5]);
        assert_eq!(c.members[2].requeued, 3);
        c.complete(2, 0.05);
        assert!(c.all_done());
        assert_eq!(c.tick(), Phase::Reduce);
        c.finish_reduce(0.0);
        c.tick();
        assert_eq!(c.log[0].requeues, 3);
    }

    #[test]
    fn straggler_accounting_uses_median_threshold() {
        let mut c = training_coord(4);
        c.advance_to_train().unwrap();
        c.begin_round(8).unwrap();
        for (i, secs) in [(0, 0.010), (1, 0.011), (2, 0.009), (3, 0.200)] {
            c.complete(i, secs);
        }
        c.tick();
        c.finish_reduce(0.0);
        c.tick();
        assert_eq!(c.log[0].stragglers, 1);
        assert_eq!(c.members[3].straggles, 1);
        assert!(c.log[0].imbalance > 2.0, "imbalance {}", c.log[0].imbalance);
        assert_eq!(c.members[0].straggles, 0);
    }

    #[test]
    fn snapshot_roundtrips_mid_round() {
        let mut c = training_coord(3);
        c.advance_to_train().unwrap();
        c.begin_round(7).unwrap();
        c.complete(0, 0.02);
        c.leave(2); // requeue into the running member 1
        let snap = c.snapshot();

        let mut r = RoundCoordinator::restore(c.cfg.clone(), &snap).unwrap();
        assert_eq!(r.phase, Phase::RoundTrain);
        assert_eq!(r.round, c.round);
        assert_eq!(r.assignments(), c.assignments());
        assert_eq!(r.alive(), 2);
        assert_eq!(r.members[1].requeued, c.members[1].requeued);

        // both twins finish the round identically
        let finish = |m: &mut RoundCoordinator| {
            m.complete(1, 0.04);
            m.tick();
            m.finish_reduce(0.0);
            m.tick();
            (m.phase, m.round, m.log.last().map(|l| (l.micro, l.requeues)))
        };
        // the restored twin starts a fresh log, so compare the new entry
        let a = finish(&mut c);
        let b = finish(&mut r);
        assert_eq!(a, b);
    }

    #[test]
    fn leave_with_no_survivor_stalls_instead_of_dropping_work() {
        let mut c = RoundCoordinator::new(RoundCfg::default());
        c.join(0);
        c.advance_to_train().unwrap();
        c.begin_round(4).unwrap();
        c.leave(0);
        // the shard must NOT be silently discarded: the round stalls
        // visibly rather than reducing a subset of the microbatches
        assert_eq!(c.assignments()[0], vec![0, 1, 2, 3]);
        assert!(!c.all_done());
        assert_eq!(c.tick(), Phase::RoundTrain);
    }

    #[test]
    fn requeue_onto_completed_member_credits_the_ledger_once() {
        let mut c = training_coord(2);
        c.advance_to_train().unwrap();
        c.begin_round(6).unwrap();
        c.complete(0, 0.01);
        assert_eq!((c.members[0].rounds_done, c.members[0].micro_done), (1, 3));
        // the only other member dies: its shard merges onto the already-
        // completed member 0, whose earlier credit is reversed so the
        // re-completion counts exactly once
        c.leave(1);
        assert_eq!((c.members[0].rounds_done, c.members[0].micro_done), (0, 0));
        assert_eq!(c.assignments()[0], vec![0, 1, 2, 3, 4, 5]);
        c.complete(0, 0.03);
        assert_eq!((c.members[0].rounds_done, c.members[0].micro_done), (1, 6));
        assert_eq!(c.tick(), Phase::Reduce);
    }

    #[test]
    fn resume_round_rearms_and_reverses_credit() {
        let mut c = training_coord(2);
        c.advance_to_train().unwrap();
        c.begin_round(6).unwrap();
        c.complete(0, 0.01);
        let snap = c.snapshot();
        let mut r = RoundCoordinator::restore(c.cfg.clone(), &snap).unwrap();
        assert!(r.mid_round());
        // wrong microbatch count is rejected
        assert!(r.resume_round(5).is_err());
        r.resume_round(6).unwrap();
        // every shard re-runs; member 0's pre-snapshot credit is reversed
        assert_eq!((r.members[0].rounds_done, r.members[0].micro_done), (0, 0));
        assert!(!r.all_done());
        r.complete(0, 0.01);
        r.complete(1, 0.01);
        assert_eq!((r.members[0].rounds_done, r.members[0].micro_done), (1, 3));
        assert_eq!(r.tick(), Phase::Reduce);
        // resume outside an armed round is rejected
        assert!(r.resume_round(6).is_err());
    }

    #[test]
    fn snapshot_rejects_garbage() {
        assert!(RoundCoordinator::restore(RoundCfg::default(), &[9.0, 1.0]).is_err());
        assert!(RoundCoordinator::restore(RoundCfg::default(), &[1.0]).is_err());
    }

    #[test]
    fn snapshot_roundtrip_exact_above_2_pow_24() {
        // global microbatch indices past 2^24 are not representable in f32;
        // the v1 codec silently rounded them. v2 must round-trip exactly,
        // and shard_secs must come back bit-identical (f64, not via f32).
        let mut c = training_coord(2);
        c.advance_to_train().unwrap();
        c.begin_round(4).unwrap();
        let big = (1usize << 24) + 3;
        c.assignment[1] = vec![big, big + 1, big + 5];
        c.complete(0, 0.123_456_789_012_345);
        let snap = c.snapshot();
        let r = RoundCoordinator::restore(c.cfg.clone(), &snap).unwrap();
        assert_eq!(r.assignments()[1], vec![big, big + 1, big + 5]);
        assert_eq!(
            r.shard_secs[0].to_bits(),
            0.123_456_789_012_345_f64.to_bits(),
            "shard_secs must survive bit-exactly for post-resume straggler accounting"
        );
        assert_eq!(r.assignments(), c.assignments());
    }

    #[test]
    fn restore_accepts_legacy_v1_blob() {
        // hand-built v1 layout (raw-f32 integers): header, round chunks,
        // one alive member with an empty assignment
        let mut blob = vec![1.0f32, 2.0, 0.0, 0.0, 0.0, 0.0, 1.0];
        blob.extend_from_slice(&u64_to_chunks(3));
        blob.push(0.0); // id
        blob.push(1.0); // alive
        for w in [0u64, 2, 8, 0, 0] {
            blob.extend_from_slice(&u64_to_chunks(w));
        }
        blob.push(0.0); // assignment len
        blob.push(1.0); // shard_done
        blob.push(0.25); // shard_secs (f32 in v1)
        let c = RoundCoordinator::restore(RoundCfg::default(), &blob).unwrap();
        assert_eq!(c.round, 3);
        assert_eq!(c.phase, Phase::RoundTrain);
        assert_eq!(c.members[0].micro_done, 8);
        assert_eq!(c.shard_secs[0], 0.25);
    }

    #[test]
    fn non_finite_shard_time_ignored_in_straggler_accounting() {
        // one NaN shard time used to panic median() inside record_round;
        // now it is excluded from median/max/imbalance and never flagged
        let mut c = training_coord(4);
        c.advance_to_train().unwrap();
        c.begin_round(8).unwrap();
        for (i, secs) in [(0, 0.010), (1, 0.011), (2, 0.009), (3, f64::NAN)] {
            c.complete(i, secs);
        }
        c.tick();
        c.finish_reduce(0.0);
        c.tick();
        assert_eq!(c.log[0].stragglers, 0);
        assert_eq!(c.log[0].workers, 4, "worker count still reflects assignment");
        assert!((c.log[0].grad_secs - 0.011).abs() < 1e-12);
        assert!(c.log[0].imbalance.is_finite());

        let mut c2 = training_coord(3);
        c2.advance_to_train().unwrap();
        c2.begin_round(6).unwrap();
        for (i, secs) in [(0, 0.010), (1, f64::INFINITY), (2, 0.009)] {
            c2.complete(i, secs);
        }
        c2.tick();
        c2.finish_reduce(0.0);
        c2.tick();
        assert_eq!(c2.log[0].stragglers, 0);
        assert_eq!(c2.members[1].straggles, 0);
        assert!(c2.log[0].grad_secs.is_finite());
    }

    #[test]
    fn delivery_ledger_tracks_exactly_once_coverage() {
        let mut c = training_coord(2);
        c.advance_to_train().unwrap();
        c.begin_round(6).unwrap();
        assert!(!c.segments_complete());
        c.deliver_segments(&[(0, 2), (2, 1)]);
        assert_eq!(c.delivered_micro(), 3);
        assert!(!c.segments_complete());
        c.deliver_segments(&[(4, 2), (3, 1)]);
        assert_eq!(c.delivered_micro(), 6);
        assert!(c.segments_complete());
        // begin_round of the next round clears the ledger
        c.complete(0, 0.01);
        c.complete(1, 0.01);
        c.tick();
        c.finish_reduce(0.0);
        c.tick();
        c.advance_to_train().unwrap();
        c.begin_round(4).unwrap();
        assert_eq!(c.delivered_micro(), 0);
    }

    #[test]
    #[should_panic(expected = "overlaps already-delivered")]
    fn delivery_ledger_rejects_double_delivery() {
        let mut c = training_coord(2);
        c.advance_to_train().unwrap();
        c.begin_round(4).unwrap();
        c.deliver_segments(&[(0, 2)]);
        c.deliver_segments(&[(1, 1)]);
    }

    #[test]
    #[should_panic(expected = "not an aligned segment")]
    fn delivery_ledger_rejects_unaligned_spans() {
        let mut c = training_coord(1);
        c.advance_to_train().unwrap();
        c.begin_round(4).unwrap();
        c.deliver_segments(&[(1, 2)]);
    }

    #[test]
    fn resume_round_clears_the_delivery_ledger() {
        let mut c = training_coord(2);
        c.advance_to_train().unwrap();
        c.begin_round(6).unwrap();
        c.deliver_segments(&[(0, 2), (2, 1)]);
        let snap = c.snapshot();
        let mut r = RoundCoordinator::restore(c.cfg.clone(), &snap).unwrap();
        // the ledger is transient: a restored round re-executes every
        // shard, so nothing counts as delivered yet
        assert_eq!(r.delivered_micro(), 0);
        r.resume_round(6).unwrap();
        r.deliver_segments(&[(0, 2), (2, 1)]);
        assert_eq!(r.delivered_micro(), 3);
    }

    #[test]
    fn leave_undelivered_requeues_only_the_suffix() {
        let mut c = training_coord(3);
        c.advance_to_train().unwrap();
        c.begin_round(9).unwrap();
        assert_eq!(c.assignments()[1], vec![3, 4, 5]);
        // worker 1 streamed [3, 4] into the eager reducer, then died: only
        // index 5 moves; the delivered prefix stays assigned (merged bits
        // must not re-execute)
        c.leave_undelivered(1, 2);
        assert_eq!(c.assignments()[1], vec![3, 4]);
        let requeued: usize = c.assignments()[0]
            .iter()
            .chain(&c.assignments()[2])
            .filter(|&&i| i == 5)
            .count();
        assert_eq!(requeued, 1);
        assert_eq!(c.members[0].requeued + c.members[2].requeued, 1);
        c.complete(0, 0.01);
        c.complete(2, 0.01);
        assert!(c.all_done());
        assert_eq!(c.tick(), Phase::Reduce);
        c.finish_reduce(0.0);
        c.tick();
        assert_eq!(c.log[0].requeues, 1);
    }

    #[test]
    fn leave_undelivered_everything_delivered_requeues_nothing() {
        let mut c = training_coord(2);
        c.advance_to_train().unwrap();
        c.begin_round(4).unwrap();
        // worker 1 delivered its whole shard but its complete() was still
        // in flight when it died: nothing to requeue, round can finish
        c.leave_undelivered(1, 2);
        assert_eq!(c.assignments()[1], vec![2, 3]);
        assert_eq!(c.assignments()[0], vec![0, 1]);
        assert_eq!(c.members[0].requeued, 0);
    }

    #[test]
    fn late_joiner_waits_for_next_round() {
        let mut c = training_coord(2);
        c.advance_to_train().unwrap();
        c.begin_round(4).unwrap();
        c.join(7);
        assert_eq!(c.assignments()[2], Vec::<usize>::new(), "no shard mid-round");
        c.complete(0, 0.01);
        c.complete(1, 0.01);
        c.tick();
        c.finish_reduce(0.0);
        c.tick();
        c.advance_to_train().unwrap();
        c.begin_round(6).unwrap();
        assert_eq!(c.assignments()[2].len(), 2, "joiner shares the next round");
        assert_eq!(c.members[2].joined_round, 1);
    }
}
