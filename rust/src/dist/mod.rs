//! Simulated data-parallel training cluster — the subsystem that replaces
//! the serial `grad_accum × workers` microbatch loop with N logical DP
//! workers, a fixed-topology tree all-reduce, and a Psyche-style round
//! state machine (SNIPPETS §1).
//!
//! * [`worker`] — logical workers over disjoint microbatch shards,
//!   executed concurrently on the persistent `util::pool`; gradient
//!   production is pluggable ([`worker::GradSource`]) so the subsystem
//!   runs against the PJRT engine *and* artifact-free synthetic sources.
//! * [`reduce`] — the order-deterministic binary-tree all-reduce:
//!   accumulation is bitwise identical for every worker count and pool
//!   width (the blocker ROADMAP named for fanning out the grad path).
//! * [`round`] — tick-driven round lifecycle (`WaitingForMembers →
//!   Warmup → RoundTrain → Reduce → Cooldown`) with membership, straggler
//!   accounting, mid-round requeue, and a checkpointable snapshot.
//! * [`transport`] — how a round crosses (or doesn't cross) a process
//!   boundary: the in-process [`Loopback`] and a wall-clock-ticking TCP
//!   coordinator/worker pair with a run-id handshake and late-joiner
//!   state streaming ([`transport::TcpCoordinator`] /
//!   [`transport::run_worker`]).
//! * [`demo`] — the shared synthetic-training driver behind the
//!   `dist-demo` CLI subcommand and the transport parity/e2e tests.
//!
//! The trainer enables it via the `[dist]` config section /
//! `--dp-workers` / `--dist-sim` (plus `--transport tcp --listen ...` for
//! the wire); `rust/tests/dist_parity.rs` pins the bitwise contract,
//! `rust/tests/transport_parity.rs` extends it across the wire, and
//! `benches/fig7_dp_scaling.rs` measures the grad-phase speedup.

pub mod demo;
pub mod reduce;
pub mod round;
pub mod transport;
pub mod worker;

use anyhow::{anyhow, Result};

use crate::linalg::Mat;
use crate::runtime::HostTensor;
use crate::util::{trace, Timer};

pub use round::{
    Phase, RoundCfg, RoundCoordinator, RoundRecord, WitnessMember, WitnessReport, WorkerHealth,
};
pub use transport::{Loopback, TcpCoordinator, Transport, WireCfg, WorkerCfg};
pub use worker::{GradSource, SyntheticGradSource};

/// `[dist]` config section: the simulated data-parallel cluster.
#[derive(Debug, Clone)]
pub struct DistConfig {
    /// Logical DP workers sharding each round's microbatch stream.
    pub dp_workers: usize,
    /// Force the round-coordinator path even at `dp_workers = 1` (the
    /// `--dist-sim` flag) — that makes dp=1 runs bitwise comparable to
    /// dp>1 runs, which use the same tree reduce.
    pub sim: bool,
    /// Members required before training starts (≤ dp_workers).
    pub min_workers: usize,
    pub warmup_ticks: u32,
    pub cooldown_ticks: u32,
    /// Straggler threshold: shard time > factor × round median.
    pub straggler_factor: f64,
    /// Which [`Transport`] carries the rounds.
    pub transport: TransportKind,
    /// Round scheduling: barriered reference phases, or the pipelined
    /// dataflow (eager reduce + per-layer optimizer fan-out). Scheduling
    /// only — both modes produce bitwise-identical losses and weights.
    pub round: RoundMode,
    /// Coordinator bind address (TCP transport; `:0` picks a free port).
    pub listen: String,
    /// Coordinator address a worker process connects to.
    pub connect: String,
    /// Run identity for the join handshake.
    pub run_id: String,
    /// Wall-clock milliseconds per state-machine tick (TCP transport).
    pub tick_ms: u64,
    pub join_timeout_s: f64,
    pub round_timeout_s: f64,
}

/// Transport selector for the `[dist]` section / `--transport` flag.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransportKind {
    /// In-process simulated cluster (the default; bitwise reference).
    Loopback,
    /// Real sockets: this process coordinates, workers join over TCP.
    Tcp,
}

impl TransportKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "loopback" => TransportKind::Loopback,
            "tcp" => TransportKind::Tcp,
            _ => return Err(anyhow!("unknown transport {s:?} (want loopback|tcp)")),
        })
    }
}

/// Round-loop scheduling selector for the `[dist]` section / `--round`
/// flag. Phased is the default and the bitwise reference; pipelined
/// overlaps segment reduce and optimizer fan-out with shard compute and
/// must match it bit for bit (`tests/dist_parity.rs`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoundMode {
    /// Three barriered phases: all shards → tree reduce → optimizer step.
    Phased,
    /// Eager dataflow: siblings merge as shards land, each parameter's
    /// optimizer update launches as soon as its gradient is folded.
    Pipelined,
}

impl RoundMode {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "phased" => RoundMode::Phased,
            "pipelined" => RoundMode::Pipelined,
            _ => return Err(anyhow!("unknown round mode {s:?} (want phased|pipelined)")),
        })
    }
}

impl Default for DistConfig {
    fn default() -> Self {
        DistConfig {
            dp_workers: 1,
            sim: false,
            min_workers: 1,
            warmup_ticks: 1,
            cooldown_ticks: 1,
            straggler_factor: 3.0,
            transport: TransportKind::Loopback,
            round: RoundMode::Phased,
            listen: "127.0.0.1:0".to_string(),
            connect: String::new(),
            run_id: "run".to_string(),
            tick_ms: 5,
            join_timeout_s: 30.0,
            round_timeout_s: 120.0,
        }
    }
}

impl DistConfig {
    /// Whether the trainer routes steps through the round coordinator.
    pub fn enabled(&self) -> bool {
        self.sim || self.dp_workers > 1
    }

    pub fn round_cfg(&self) -> RoundCfg {
        RoundCfg {
            min_workers: self.min_workers.clamp(1, self.dp_workers.max(1)),
            warmup_ticks: self.warmup_ticks,
            cooldown_ticks: self.cooldown_ticks,
            straggler_factor: self.straggler_factor,
        }
    }

    /// A fresh coordinator with workers `0..dp_workers` joined (still in
    /// `WaitingForMembers`; the first round ticks through Warmup).
    pub fn coordinator(&self) -> RoundCoordinator {
        let mut c = RoundCoordinator::new(self.round_cfg());
        for w in 0..self.dp_workers.max(1) {
            c.join(w);
        }
        c
    }

    /// A fresh coordinator with *no* members — the TCP transport starts
    /// empty and admits members over the wire as they join.
    pub fn empty_coordinator(&self) -> RoundCoordinator {
        RoundCoordinator::new(self.round_cfg())
    }

    pub fn wire_cfg(&self) -> WireCfg {
        WireCfg {
            run_id: self.run_id.clone(),
            tick_ms: self.tick_ms,
            join_timeout_s: self.join_timeout_s,
            round_timeout_s: self.round_timeout_s,
        }
    }

    /// Build the configured transport (binds the listener for TCP).
    pub fn make_transport(&self) -> Result<Box<dyn Transport>> {
        Ok(match self.transport {
            TransportKind::Loopback => Box::new(Loopback),
            TransportKind::Tcp => Box::new(TcpCoordinator::bind(&self.listen, self.wire_cfg())?),
        })
    }
}

/// One finished round's reduced result + timing.
#[derive(Debug)]
pub struct RoundOutput {
    /// Mean microbatch loss.
    pub loss: f32,
    /// Mean gradients, one per parameter.
    pub grads: Vec<Mat>,
    /// Gradient-phase wall clock (the worker fan-out).
    pub grad_secs: f64,
    pub reduce_secs: f64,
    /// Merge wall clock that ran *while shards were still executing* —
    /// the pipelined win. Always 0.0 on the phased path, where every
    /// merge waits for the slowest shard.
    pub reduce_overlap_secs: f64,
}

/// Drive one full data-parallel round over an explicit [`Transport`]:
/// advance the state machine to `RoundTrain` (the transport decides how —
/// logical ticks in-process, wall-clock ticks with live joins over TCP),
/// shard `tokens` over the alive members, execute the shards wherever the
/// transport puts them, tree-reduce the results, and walk the machine
/// through `Reduce → Cooldown`.
///
/// This is the one round implementation — the trainer, the parity tests,
/// and the fig7 bench all call it (with different [`GradSource`]s and
/// transports), so the determinism contract is pinned on exactly the code
/// that trains: the reduce runs over the transport-returned node set, and
/// node sets are a pure function of the global microbatch indices.
pub fn run_round_via(
    transport: &mut dyn Transport,
    coord: &mut RoundCoordinator,
    src: &dyn GradSource,
    tokens: &[HostTensor],
) -> Result<RoundOutput> {
    let _sp = trace::region("round", "dp_round");
    if coord.mid_round() {
        // restored from a mid-round checkpoint: assignments (with any
        // requeue adjustments) survived; gradients did not, so re-arm and
        // re-execute the same round
        coord.resume_round(tokens.len())?;
    } else {
        transport.advance_to_train(coord)?;
        coord.begin_round(tokens.len())?;
    }

    let (nodes, grad_secs) = transport.execute_round(coord, src, tokens)?;
    coord.tick(); // RoundTrain → Reduce

    let t1 = Timer::start();
    let root = {
        let _sp = trace::span("dist", "tree_reduce");
        reduce::combine(nodes).ok_or_else(|| anyhow!("round produced no gradient nodes"))?
    };
    let reduce_secs = t1.secs();
    coord.finish_reduce(reduce_secs);
    coord.tick(); // Reduce → Cooldown

    let scale = 1.0 / tokens.len() as f32;
    Ok(RoundOutput {
        loss: root.loss * scale,
        grads: root.grads.into_iter().map(|g| g.scale(scale)).collect(),
        grad_secs,
        reduce_secs,
        reduce_overlap_secs: 0.0,
    })
}

/// [`run_round_via`] on the in-process [`Loopback`] transport — the PR-3
/// entry point, unchanged for every existing caller.
pub fn run_round<S: GradSource>(
    coord: &mut RoundCoordinator,
    src: &S,
    tokens: &[HostTensor],
) -> Result<RoundOutput> {
    run_round_via(&mut Loopback, coord, src, tokens)
}

/// A pipelined round's result with the final ragged fold still deferred:
/// the maximal aligned blocks (binary decomposition of the microbatch
/// count), so the caller can fold **per parameter** inside its optimizer
/// fan-out instead of waiting for one monolithic root. `fold_loss` /
/// `fold_param` / [`EagerRound::into_output`] all reproduce exactly the
/// grouping `reduce::fold_blocks` (hence the phased path) uses.
#[derive(Debug)]
pub struct EagerRound {
    /// Maximal merged blocks in index order (`reduce::EagerReduce::finish`).
    pub blocks: Vec<reduce::Node<reduce::GradNode>>,
    /// Microbatches in the round (the mean-gradient scale is `1/micro`).
    pub micro: usize,
    pub grad_secs: f64,
    /// Total sibling-merge wall clock (the pipelined `reduce_secs`).
    pub reduce_secs: f64,
    /// Merge time that overlapped still-running shards (every delivery's
    /// merge except the last — that one, by definition, had nothing left
    /// to hide behind).
    pub reduce_overlap_secs: f64,
}

impl EagerRound {
    /// Scalar mean loss: the per-block losses folded right-to-left with
    /// the left operand as accumulator — `GradNode::merge`'s loss chain,
    /// bitwise — then scaled by `1/micro`.
    pub fn fold_loss(&self) -> f32 {
        let k = self.blocks.len();
        let mut acc = self.blocks[k - 1].value.loss;
        for j in (0..k - 1).rev() {
            acc = self.blocks[j].value.loss + acc;
        }
        acc * (1.0 / self.micro as f32)
    }

    /// One parameter's mean gradient: that parameter's slice of each
    /// block folded right-to-left via `ema_(1.0, ·, 1.0)` with the left
    /// operand as accumulator — the identical additions in the identical
    /// grouping as `GradNode::merge` under `fold_blocks` — then scaled.
    pub fn fold_param(&self, param: usize) -> Mat {
        let k = self.blocks.len();
        let mut acc = self.blocks[k - 1].value.grads[param].clone();
        for j in (0..k - 1).rev() {
            let mut left = self.blocks[j].value.grads[param].clone();
            left.ema_(1.0, &acc, 1.0);
            acc = left;
        }
        acc.scale(1.0 / self.micro as f32)
    }

    /// Collapse to the phased [`RoundOutput`] (bitwise identical): the
    /// whole-node fold the phased `combine` tail runs, then the same
    /// mean scaling.
    pub fn into_output(self) -> RoundOutput {
        let scale = 1.0 / self.micro as f32;
        let root = reduce::fold_blocks(self.blocks).expect("non-empty round");
        RoundOutput {
            loss: root.loss * scale,
            grads: root.grads.into_iter().map(|g| g.scale(scale)).collect(),
            grad_secs: self.grad_secs,
            reduce_secs: self.reduce_secs,
            reduce_overlap_secs: self.reduce_overlap_secs,
        }
    }
}

/// Pipelined analogue of [`run_round_via`]: identical coordinator phase
/// discipline (resume / advance+begin, `RoundTrain → Reduce → Cooldown`),
/// but shard results stream into an [`reduce::EagerReduce`] as they land
/// — sibling merges overlap the still-running shards — and the final
/// ragged fold is deferred to the returned [`EagerRound`] so the caller
/// can run it per parameter inside its optimizer fan-out.
///
/// Scheduling-only by construction: the eager closure performs the same
/// additions in the same grouping as `reduce::combine`, so every bit of
/// loss, gradient, and checkpoint matches the phased path.
pub fn run_round_pipelined_via(
    transport: &mut dyn Transport,
    coord: &mut RoundCoordinator,
    src: &dyn GradSource,
    tokens: &[HostTensor],
) -> Result<EagerRound> {
    let _sp = trace::region("round", "dp_round_pipelined");
    if coord.mid_round() {
        coord.resume_round(tokens.len())?;
    } else {
        transport.advance_to_train(coord)?;
        coord.begin_round(tokens.len())?;
    }

    let mut er = reduce::EagerReduce::new();
    let mut merge_secs = 0.0f64;
    let mut last_merge = 0.0f64;
    let grad_secs = {
        let sink = &mut |nodes: Vec<reduce::Node<reduce::GradNode>>| {
            let _sp = trace::span("dist", "eager_merge");
            let t = Timer::start();
            er.offer_all(nodes);
            last_merge = t.secs();
            merge_secs += last_merge;
        };
        transport.execute_round_eager(coord, src, tokens, sink)?
    };
    coord.tick(); // RoundTrain → Reduce
    if !coord.segments_complete() {
        return Err(anyhow!(
            "pipelined round delivered {} of {} microbatches",
            coord.delivered_micro(),
            tokens.len()
        ));
    }
    let blocks = er.finish();
    if blocks.is_empty() {
        return Err(anyhow!("round produced no gradient nodes"));
    }
    coord.finish_reduce(merge_secs);
    coord.tick(); // Reduce → Cooldown

    // every merge before the final delivery ran under still-executing
    // shards; surface that hidden time in the obs ledger
    let reduce_overlap_secs = (merge_secs - last_merge).max(0.0);
    crate::obs::REDUCE_OVERLAP_US.add((reduce_overlap_secs * 1e6) as u64);
    Ok(EagerRound {
        blocks,
        micro: tokens.len(),
        grad_secs,
        reduce_secs: merge_secs,
        reduce_overlap_secs,
    })
}

/// [`run_round_pipelined_via`] on the in-process [`Loopback`] transport.
pub fn run_round_pipelined<S: GradSource>(
    coord: &mut RoundCoordinator,
    src: &S,
    tokens: &[HostTensor],
) -> Result<EagerRound> {
    run_round_pipelined_via(&mut Loopback, coord, src, tokens)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_enable_logic() {
        let mut c = DistConfig::default();
        assert!(!c.enabled(), "defaults must leave the serial path alone");
        c.dp_workers = 4;
        assert!(c.enabled());
        c.dp_workers = 1;
        c.sim = true;
        assert!(c.enabled(), "--dist-sim forces the coordinator path");
    }

    #[test]
    fn round_cfg_clamps_min_workers() {
        let c = DistConfig { dp_workers: 2, min_workers: 9, ..DistConfig::default() };
        assert_eq!(c.round_cfg().min_workers, 2);
        let c = DistConfig { dp_workers: 4, min_workers: 0, ..DistConfig::default() };
        assert_eq!(c.round_cfg().min_workers, 1);
    }

    #[test]
    fn round_mode_parse() {
        assert_eq!(RoundMode::parse("phased").unwrap(), RoundMode::Phased);
        assert_eq!(RoundMode::parse("pipelined").unwrap(), RoundMode::Pipelined);
        assert!(RoundMode::parse("eager").is_err());
        assert_eq!(DistConfig::default().round, RoundMode::Phased, "phased stays the default");
    }

    #[test]
    fn pipelined_round_matches_phased_bitwise() {
        let src = SyntheticGradSource { shapes: vec![(4, 4), (2, 3)], work: 0 };
        for dp in [1usize, 2, 3, 4] {
            for micro in [1usize, 5, 8, 13] {
                if micro < dp {
                    continue;
                }
                let cfg = DistConfig { dp_workers: dp, sim: true, ..DistConfig::default() };
                let tokens: Vec<HostTensor> = (0..micro)
                    .map(|i| HostTensor::i32(vec![2], vec![i as i32, 2 * i as i32 + 1]))
                    .collect();
                let phased = {
                    let mut coord = cfg.coordinator();
                    run_round(&mut coord, &src, &tokens).unwrap()
                };
                let mut coord = cfg.coordinator();
                let eager = run_round_pipelined(&mut coord, &src, &tokens).unwrap();
                // the deferred per-param folds must equal the monolithic fold
                assert_eq!(
                    eager.fold_loss().to_bits(),
                    phased.loss.to_bits(),
                    "dp={dp} micro={micro} loss"
                );
                for (p, want) in phased.grads.iter().enumerate() {
                    assert_eq!(
                        eager.fold_param(p).data,
                        want.data,
                        "dp={dp} micro={micro} param {p}"
                    );
                }
                let out = eager.into_output();
                assert_eq!(out.loss.to_bits(), phased.loss.to_bits());
                for (a, b) in out.grads.iter().zip(&phased.grads) {
                    assert_eq!(a.data, b.data);
                }
                // both modes drive the round machine identically
                assert_eq!(coord.round, 1);
                assert_eq!(coord.log.len(), 1);
                assert_eq!(coord.log[0].micro, micro);
            }
        }
    }

    #[test]
    fn run_round_cycles_the_machine_and_logs() {
        let cfg = DistConfig { dp_workers: 3, ..DistConfig::default() };
        let mut coord = cfg.coordinator();
        let src = SyntheticGradSource { shapes: vec![(4, 4)], work: 0 };
        let tokens: Vec<HostTensor> =
            (0..6).map(|i| HostTensor::i32(vec![2], vec![i, i + 1])).collect();
        let out1 = run_round(&mut coord, &src, &tokens).unwrap();
        let out2 = run_round(&mut coord, &src, &tokens).unwrap();
        assert_eq!(coord.round, 2);
        assert_eq!(coord.log.len(), 2);
        assert_eq!(coord.log[0].micro, 6);
        assert_eq!(coord.log[0].workers, 3);
        // same tokens → same reduced bits, round after round
        assert_eq!(out1.loss.to_bits(), out2.loss.to_bits());
        assert_eq!(out1.grads[0].data, out2.grads[0].data);
    }
}
