//! Shared synthetic-training driver for the transport layer: the same
//! miniature loop `rust/tests/dist_parity.rs` pins (synthetic microbatch
//! gradients → round pipeline → real optimizer slots), parameterized by a
//! [`Transport`] so one binary can run it as a loopback cluster, a TCP
//! coordinator, or compare the two.
//!
//! The `dist-demo` CLI subcommand and the `transport_parity` /
//! `transport_e2e` tests all call [`drive`]; bitwise identity across
//! transports is checked on the per-step loss bits and an FNV-1a digest
//! of the final weight bits.
//!
//! [`DemoCfg::round`] selects the round scheduling: the phased reference
//! loop, or the pipelined dataflow (eager segment reduce + fused per-
//! parameter fold/optimizer fan-out). On the loopback transport the
//! pipelined driver additionally **double-buffers gradients**: round
//! `t+1`'s shard compute shares one pool region with round `t`'s
//! optimizer fan-out — legal here because the synthetic gradients are
//! pure in `(index, tokens)` and independent of the weights being
//! updated. All of it is scheduling only: the merge and fold arithmetic
//! is identical, so every mode and transport produces the same bits.

use anyhow::{anyhow, Result};

use crate::linalg::Mat;
use crate::opt::{build, Hyper, Slot};
use crate::runtime::HostTensor;
use crate::util::{pool, Timer};

use super::reduce::EagerReduce;
use super::worker::{run_shard, ShardOut, SyntheticGradSource};
use super::{
    run_round_pipelined_via, run_round_via, DistConfig, EagerRound, Loopback, RoundCoordinator,
    RoundMode, Transport,
};

/// Deterministic token blocks, exactly the `dist_parity` formula — any
/// process that agrees on `(micro, step)` regenerates identical data.
pub fn token_block(micro: usize, seed: i32) -> Vec<HostTensor> {
    (0..micro)
        .map(|i| {
            let base = seed + i as i32 * 31;
            HostTensor::i32(vec![8], (0..8).map(|k| (base + k * 7) % 997).collect())
        })
        .collect()
}

/// The `dist_parity` gradient geometry (three ragged parameters).
pub fn demo_src() -> SyntheticGradSource {
    SyntheticGradSource { shapes: vec![(6, 10), (8, 4), (1, 12)], work: 0 }
}

/// Demo run shape.
#[derive(Debug, Clone)]
pub struct DemoCfg {
    /// Microbatches per optimizer step (global, sharded over members).
    pub micro: usize,
    pub steps: u64,
    /// Round scheduling: phased reference or the pipelined dataflow.
    pub round: RoundMode,
    /// Where the *driver* appends one witness JSON line per round (the
    /// coordinator/loopback-side `witness.jsonl`; TCP workers write their
    /// own copy via `WorkerCfg::witness_path`). `None` = no file.
    pub witness_path: Option<std::path::PathBuf>,
}

impl Default for DemoCfg {
    fn default() -> Self {
        DemoCfg { micro: 8, steps: 4, round: RoundMode::Phased, witness_path: None }
    }
}

/// What a demo run produced — everything needed for bitwise comparison.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DemoOut {
    /// Per-step reduced loss bits.
    pub loss_bits: Vec<u32>,
    /// FNV-1a over the final weight bit patterns (order: parameter, then
    /// row-major element) — one line to compare across processes.
    pub weight_digest: u64,
    pub rounds: u64,
    pub requeues: u64,
}

fn fnv1a(h: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        *h = (*h ^ b as u64).wrapping_mul(0x0100_0000_01b3);
    }
}

/// Flatten the weights to little-endian f32 bytes (the `State` blob the
/// coordinator streams to late joiners — real content, so the tests can
/// assert a joiner received a non-trivial checkpoint).
fn weight_blob(weights: &[Mat]) -> Vec<u8> {
    let mut out = Vec::new();
    for w in weights {
        for &x in &w.data {
            out.extend_from_slice(&x.to_bits().to_le_bytes());
        }
    }
    out
}

fn demo_slots(s: &SyntheticGradSource) -> Result<Vec<Slot>> {
    let hp = Hyper::default();
    s.shapes
        .iter()
        .map(|&(r, c)| -> Result<Slot> { Ok(Slot::new(build("adam", &hp)?, r, c)) })
        .collect()
}

fn demo_out(
    weights: &[Mat],
    loss_bits: Vec<u32>,
    coord: &RoundCoordinator,
) -> DemoOut {
    let mut digest = 0xcbf2_9ce4_8422_2325u64;
    fnv1a(&mut digest, &weight_blob(weights));
    DemoOut {
        loss_bits,
        weight_digest: digest,
        rounds: coord.round,
        requeues: coord.log.iter().map(|l| l.requeues).sum(),
    }
}

/// Fused per-parameter fold + optimizer update (the pipelined opt
/// fan-out): task `p` folds its own mean gradient out of the round's
/// maximal blocks and immediately refreshes/steps/applies it, so early
/// parameters' optimizer work overlaps later parameters' folds. The
/// per-parameter arithmetic is exactly the phased loop's
/// (`EagerRound::fold_param` reproduces the monolithic fold's grouping).
fn opt_fanout(round: &EagerRound, slots: &mut [Slot], weights: &mut [Mat], t: u64) {
    let slots_ptr = pool::SendPtr(slots.as_mut_ptr());
    let weights_ptr = pool::SendPtr(weights.as_mut_ptr());
    pool::run(slots.len(), |p| {
        let g = round.fold_param(p);
        // SAFETY: the region hands each index to exactly one task, so
        // these are the only live references to slots[p] / weights[p].
        let slot = unsafe { &mut *slots_ptr.0.add(p) };
        let w = unsafe { &mut *weights_ptr.0.add(p) };
        if t == 1 {
            slot.refresh(&g, 0xd157 ^ t);
        }
        let delta = slot.step(&g, t);
        w.ema_(1.0, &delta, -0.01);
    });
}

/// Run `cfg.steps` optimizer steps of the synthetic training loop over
/// `transport`, publishing the weight blob after every step (so late
/// joiners always receive the newest state). The transport is shut down
/// before returning. `cfg.round` picks the per-step scheduling; both
/// modes return identical bits.
pub fn drive(
    transport: &mut dyn Transport,
    coord: &mut RoundCoordinator,
    cfg: &DemoCfg,
) -> Result<DemoOut> {
    let s = demo_src();
    let mut slots = demo_slots(&s)?;
    let mut weights: Vec<Mat> = s.shapes.iter().map(|&(r, c)| Mat::zeros(r, c)).collect();
    let mut loss_bits = Vec::new();
    for t in 1..=cfg.steps {
        let toks = token_block(cfg.micro, 1000 * t as i32);
        match cfg.round {
            RoundMode::Phased => {
                let out = run_round_via(transport, coord, &s, &toks)?;
                loss_bits.push(out.loss.to_bits());
                for ((slot, w), g) in slots.iter_mut().zip(&mut weights).zip(&out.grads) {
                    if t == 1 {
                        slot.refresh(g, 0xd157 ^ t);
                    }
                    let delta = slot.step(g, t);
                    w.ema_(1.0, &delta, -0.01);
                }
            }
            RoundMode::Pipelined => {
                let round = run_round_pipelined_via(transport, coord, &s, &toks)?;
                loss_bits.push(round.fold_loss().to_bits());
                opt_fanout(&round, &mut slots, &mut weights, t);
            }
        }
        // round-end telemetry: broadcast the health ledger to the workers
        // and (optionally) append it to the driver-side witness.jsonl.
        // Observational only — nothing below reads it back.
        if let Some(w) = coord.witness() {
            transport.publish_witness(&w)?;
            if let Some(path) = &cfg.witness_path {
                super::transport::append_witness_line(path, &w);
            }
        }
        if transport.wants_state() {
            transport.publish_state(t, &coord.snapshot(), &weight_blob(&weights))?;
        }
    }
    transport.shutdown();
    Ok(demo_out(&weights, loss_bits, coord))
}

/// Double-buffered pipelined loopback driver: one pool region per step
/// runs round `t`'s shards **and** round `t-1`'s per-parameter optimizer
/// fan-out side by side; shard results stream into the eager reduce at
/// consume time (on this thread), exactly like the loopback transport's
/// pipelined round. The synthetic gradients never read the weights, so
/// starting round `t`'s compute before round `t-1`'s update has drained
/// changes nothing but the schedule — the bits match the phased drive.
fn drive_loopback_pipelined(
    coord: &mut RoundCoordinator,
    cfg: &DemoCfg,
) -> Result<DemoOut> {
    let s = demo_src();
    let mut slots = demo_slots(&s)?;
    let mut weights: Vec<Mat> = s.shapes.iter().map(|&(r, c)| Mat::zeros(r, c)).collect();
    let np = weights.len();
    let mut loss_bits = Vec::new();
    // the previous round's folded-deferred blocks, optimizer work pending
    let mut pend: Option<(u64, EagerRound)> = None;
    let mut lb = Loopback;
    for t in 1..=cfg.steps {
        let toks = token_block(cfg.micro, 1000 * t as i32);
        if coord.mid_round() {
            coord.resume_round(toks.len())?;
        } else {
            lb.advance_to_train(coord)?;
            coord.begin_round(toks.len())?;
        }
        let assignments = coord.assignments().to_vec();
        let dp = assignments.len();
        let k = if pend.is_some() { np } else { 0 };

        enum Out {
            Shard(Result<ShardOut>),
            Opt,
        }
        let mut er = EagerReduce::new();
        let mut merge_secs = 0.0f64;
        let mut failed: Option<anyhow::Error> = None;
        let t0 = Timer::start();
        let slots_ptr = pool::SendPtr(slots.as_mut_ptr());
        let weights_ptr = pool::SendPtr(weights.as_mut_ptr());
        let pend_ref = &pend;
        pool::map_consume(
            dp + k,
            |i| {
                if i < dp {
                    return Out::Shard(run_shard(&s, &assignments[i], &toks));
                }
                let p = i - dp;
                let (pt, round) = pend_ref.as_ref().expect("pending opt work present");
                let g = round.fold_param(p);
                // SAFETY: the region hands each index to exactly one
                // task, so these are the only live references to
                // slots[p] / weights[p].
                let slot = unsafe { &mut *slots_ptr.0.add(p) };
                let w = unsafe { &mut *weights_ptr.0.add(p) };
                if *pt == 1 {
                    slot.refresh(&g, 0xd157 ^ *pt);
                }
                let delta = slot.step(&g, *pt);
                w.ema_(1.0, &delta, -0.01);
                Out::Opt
            },
            |i, out| {
                if let Out::Shard(res) = out {
                    match res {
                        Ok(o) => {
                            coord.complete(i, o.secs);
                            let spans: Vec<(usize, usize)> =
                                o.nodes.iter().map(|n| (n.lo, n.len)).collect();
                            coord.deliver_segments(&spans);
                            let tm = Timer::start();
                            er.offer_all(o.nodes);
                            merge_secs += tm.secs();
                        }
                        Err(e) => {
                            if failed.is_none() {
                                failed = Some(e.context(format!("dp worker {i}")));
                            }
                        }
                    }
                }
            },
        );
        if let Some(e) = failed {
            return Err(e);
        }
        let grad_secs = t0.secs();
        coord.tick(); // RoundTrain → Reduce
        if !coord.segments_complete() {
            return Err(anyhow!(
                "pipelined round delivered {} of {} microbatches",
                coord.delivered_micro(),
                toks.len()
            ));
        }
        let blocks = er.finish();
        if blocks.is_empty() {
            return Err(anyhow!("round produced no gradient nodes"));
        }
        coord.finish_reduce(merge_secs);
        coord.tick(); // Reduce → Cooldown
        if let Some(w) = coord.witness() {
            lb.publish_witness(&w)?;
            if let Some(path) = &cfg.witness_path {
                super::transport::append_witness_line(path, &w);
            }
        }
        let round = EagerRound {
            blocks,
            micro: toks.len(),
            grad_secs,
            reduce_secs: merge_secs,
            reduce_overlap_secs: 0.0,
        };
        loss_bits.push(round.fold_loss().to_bits());
        pend = Some((t, round));
    }
    // drain the final round's optimizer work — no next round to overlap
    if let Some((t, round)) = pend.take() {
        opt_fanout(&round, &mut slots, &mut weights, t);
    }
    lb.shutdown();
    Ok(demo_out(&weights, loss_bits, coord))
}

/// The in-process reference run: `dp` simulated workers on the loopback
/// transport at pool width `width`. `cfg.round = pipelined` routes to the
/// double-buffered driver.
pub fn run_loopback(cfg: &DemoCfg, dp: usize, width: usize) -> Result<DemoOut> {
    pool::with_threads(width, || {
        let dist = DistConfig { dp_workers: dp, ..DistConfig::default() };
        let mut coord = dist.coordinator();
        match cfg.round {
            RoundMode::Phased => drive(&mut Loopback, &mut coord, cfg),
            RoundMode::Pipelined => drive_loopback_pipelined(&mut coord, cfg),
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loopback_demo_is_dp_invariant() {
        let cfg = DemoCfg { micro: 6, steps: 3, ..DemoCfg::default() };
        let a = run_loopback(&cfg, 1, 1).unwrap();
        let b = run_loopback(&cfg, 3, 2).unwrap();
        assert_eq!(a.loss_bits, b.loss_bits);
        assert_eq!(a.weight_digest, b.weight_digest);
        assert_eq!(b.rounds, 3);
        assert_eq!(b.requeues, 0);
    }

    #[test]
    fn double_buffered_loopback_matches_phased_bitwise() {
        let phased =
            run_loopback(&DemoCfg { micro: 6, steps: 3, ..DemoCfg::default() }, 2, 2).unwrap();
        for (dp, width) in [(1usize, 1usize), (2, 2), (3, 4)] {
            let cfg = DemoCfg {
                micro: 6,
                steps: 3,
                round: RoundMode::Pipelined,
                ..DemoCfg::default()
            };
            let got = run_loopback(&cfg, dp, width).unwrap();
            assert_eq!(got.loss_bits, phased.loss_bits, "dp={dp} width={width}");
            assert_eq!(got.weight_digest, phased.weight_digest, "dp={dp} width={width}");
            assert_eq!(got.rounds, 3);
        }
    }

    #[test]
    fn pipelined_drive_matches_phased_over_any_transport_shape() {
        // the generic (transport-driven) pipelined arm, pinned on
        // loopback so the TCP parity tests inherit a known-good base
        let base = DemoCfg { micro: 5, steps: 2, ..DemoCfg::default() };
        let phased = run_loopback(&base, 2, 2).unwrap();
        let cfg = DemoCfg { round: RoundMode::Pipelined, ..base };
        let got = pool::with_threads(2, || {
            let dist = DistConfig { dp_workers: 2, ..DistConfig::default() };
            let mut coord = dist.coordinator();
            drive(&mut Loopback, &mut coord, &cfg)
        })
        .unwrap();
        assert_eq!(got.loss_bits, phased.loss_bits);
        assert_eq!(got.weight_digest, phased.weight_digest);
    }
}
