//! Shared synthetic-training driver for the transport layer: the same
//! miniature loop `rust/tests/dist_parity.rs` pins (synthetic microbatch
//! gradients → round pipeline → real optimizer slots), parameterized by a
//! [`Transport`] so one binary can run it as a loopback cluster, a TCP
//! coordinator, or compare the two.
//!
//! The `dist-demo` CLI subcommand and the `transport_parity` /
//! `transport_e2e` tests all call [`drive`]; bitwise identity across
//! transports is checked on the per-step loss bits and an FNV-1a digest
//! of the final weight bits.

use anyhow::Result;

use crate::linalg::Mat;
use crate::opt::{build, Hyper, Slot};
use crate::runtime::HostTensor;
use crate::util::pool;

use super::worker::SyntheticGradSource;
use super::{run_round_via, DistConfig, Loopback, RoundCoordinator, Transport};

/// Deterministic token blocks, exactly the `dist_parity` formula — any
/// process that agrees on `(micro, step)` regenerates identical data.
pub fn token_block(micro: usize, seed: i32) -> Vec<HostTensor> {
    (0..micro)
        .map(|i| {
            let base = seed + i as i32 * 31;
            HostTensor::i32(vec![8], (0..8).map(|k| (base + k * 7) % 997).collect())
        })
        .collect()
}

/// The `dist_parity` gradient geometry (three ragged parameters).
pub fn demo_src() -> SyntheticGradSource {
    SyntheticGradSource { shapes: vec![(6, 10), (8, 4), (1, 12)], work: 0 }
}

/// Demo run shape.
#[derive(Debug, Clone)]
pub struct DemoCfg {
    /// Microbatches per optimizer step (global, sharded over members).
    pub micro: usize,
    pub steps: u64,
    /// Where the *driver* appends one witness JSON line per round (the
    /// coordinator/loopback-side `witness.jsonl`; TCP workers write their
    /// own copy via `WorkerCfg::witness_path`). `None` = no file.
    pub witness_path: Option<std::path::PathBuf>,
}

impl Default for DemoCfg {
    fn default() -> Self {
        DemoCfg { micro: 8, steps: 4, witness_path: None }
    }
}

/// What a demo run produced — everything needed for bitwise comparison.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DemoOut {
    /// Per-step reduced loss bits.
    pub loss_bits: Vec<u32>,
    /// FNV-1a over the final weight bit patterns (order: parameter, then
    /// row-major element) — one line to compare across processes.
    pub weight_digest: u64,
    pub rounds: u64,
    pub requeues: u64,
}

fn fnv1a(h: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        *h = (*h ^ b as u64).wrapping_mul(0x0100_0000_01b3);
    }
}

/// Flatten the weights to little-endian f32 bytes (the `State` blob the
/// coordinator streams to late joiners — real content, so the tests can
/// assert a joiner received a non-trivial checkpoint).
fn weight_blob(weights: &[Mat]) -> Vec<u8> {
    let mut out = Vec::new();
    for w in weights {
        for &x in &w.data {
            out.extend_from_slice(&x.to_bits().to_le_bytes());
        }
    }
    out
}

/// Run `cfg.steps` optimizer steps of the synthetic training loop over
/// `transport`, publishing the weight blob after every step (so late
/// joiners always receive the newest state). The transport is shut down
/// before returning.
pub fn drive(
    transport: &mut dyn Transport,
    coord: &mut RoundCoordinator,
    cfg: &DemoCfg,
) -> Result<DemoOut> {
    let s = demo_src();
    let hp = Hyper::default();
    let mut slots: Vec<Slot> = s
        .shapes
        .iter()
        .map(|&(r, c)| -> Result<Slot> { Ok(Slot::new(build("adam", &hp)?, r, c)) })
        .collect::<Result<_>>()?;
    let mut weights: Vec<Mat> = s.shapes.iter().map(|&(r, c)| Mat::zeros(r, c)).collect();
    let mut loss_bits = Vec::new();
    for t in 1..=cfg.steps {
        let toks = token_block(cfg.micro, 1000 * t as i32);
        let out = run_round_via(transport, coord, &s, &toks)?;
        // round-end telemetry: broadcast the health ledger to the workers
        // and (optionally) append it to the driver-side witness.jsonl.
        // Observational only — nothing below reads it back.
        if let Some(w) = coord.witness() {
            transport.publish_witness(&w)?;
            if let Some(path) = &cfg.witness_path {
                super::transport::append_witness_line(path, &w);
            }
        }
        loss_bits.push(out.loss.to_bits());
        for ((slot, w), g) in slots.iter_mut().zip(&mut weights).zip(&out.grads) {
            if t == 1 {
                slot.refresh(g, 0xd157 ^ t);
            }
            let delta = slot.step(g, t);
            w.ema_(1.0, &delta, -0.01);
        }
        if transport.wants_state() {
            transport.publish_state(t, &coord.snapshot(), &weight_blob(&weights))?;
        }
    }
    transport.shutdown();
    let mut digest = 0xcbf2_9ce4_8422_2325u64;
    fnv1a(&mut digest, &weight_blob(&weights));
    Ok(DemoOut {
        loss_bits,
        weight_digest: digest,
        rounds: coord.round,
        requeues: coord.log.iter().map(|l| l.requeues).sum(),
    })
}

/// The in-process reference run: `dp` simulated workers on the loopback
/// transport at pool width `width`.
pub fn run_loopback(cfg: &DemoCfg, dp: usize, width: usize) -> Result<DemoOut> {
    pool::with_threads(width, || {
        let dist = DistConfig { dp_workers: dp, ..DistConfig::default() };
        let mut coord = dist.coordinator();
        drive(&mut Loopback, &mut coord, cfg)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loopback_demo_is_dp_invariant() {
        let cfg = DemoCfg { micro: 6, steps: 3, ..DemoCfg::default() };
        let a = run_loopback(&cfg, 1, 1).unwrap();
        let b = run_loopback(&cfg, 3, 2).unwrap();
        assert_eq!(a.loss_bits, b.loss_bits);
        assert_eq!(a.weight_digest, b.weight_digest);
        assert_eq!(b.rounds, 3);
        assert_eq!(b.requeues, 0);
    }
}
