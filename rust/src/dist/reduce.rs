//! Order-deterministic tree all-reduce for gradients and losses.
//!
//! # The problem
//!
//! Floating-point addition is commutative but not associative: summing the
//! same microbatch gradients in a different *grouping* produces different
//! bits. The serial accumulation loop fixes one grouping (left fold in
//! microbatch order); a data-parallel fan-out that let each worker fold
//! its own shard and then folded the shard sums would fix a *different*
//! grouping per worker count — exactly the blocker ROADMAP named for
//! fanning out the gradient path.
//!
//! # The fix: one canonical tree, independent of the sharding
//!
//! Reduction is defined over **global microbatch indices**, not workers.
//! The canonical tree is the segment-tree bracketing of `[0, M)`: a node
//! covers an aligned span `[lo, lo + 2^k)` with `lo % 2^k == 0`, and its
//! value is (left half) ⊕ (right half). Every worker builds the maximal
//! aligned subtrees that fit inside the indices it executed
//! ([`TreeAccum`], an incremental binary-counter merge), and the
//! coordinator completes the upper levels ([`combine`]): closure under
//! aligned-sibling merges, then a right-to-left fold of the remaining
//! maximal blocks (the binary decomposition of `M`).
//!
//! Because alignment is a pure function of the global index, **any**
//! contiguous-or-not partition of `[0, M)` across any number of workers
//! produces the identical node set, hence the identical additions in the
//! identical grouping, hence a bitwise-identical root — including under
//! mid-round straggler requeues (`rust/tests/dist_parity.rs`). Per-element
//! merges go through `Mat::ema_(1.0, ·, 1.0)` (one addition per element,
//! width-invariant per the `linalg` determinism contract), so the result
//! is also bitwise identical at every pool width.
//!
//! Memory: a worker holds at most `log2(shard) + 1` in-flight nodes — each
//! a full gradient set — instead of one node per microbatch.

use std::collections::BTreeMap;

use crate::linalg::Mat;

/// Payload that can be summed pairwise into tree nodes.
pub trait Merge {
    /// `self ← self ⊕ other` (left operand stays `self`).
    fn merge(&mut self, other: Self);
}

impl Merge for f32 {
    fn merge(&mut self, other: Self) {
        *self += other;
    }
}

/// One microbatch's contribution: scalar loss + per-parameter gradients.
#[derive(Debug, Clone)]
pub struct GradNode {
    pub loss: f32,
    pub grads: Vec<Mat>,
}

impl Merge for GradNode {
    fn merge(&mut self, other: Self) {
        assert_eq!(
            self.grads.len(),
            other.grads.len(),
            "gradient sets must have the same arity"
        );
        self.loss += other.loss;
        for (g, o) in self.grads.iter_mut().zip(&other.grads) {
            // 1.0*x + 1.0*y is exactly x + y in IEEE-754; elementwise, so
            // bitwise width-invariant (README §Determinism contract)
            g.ema_(1.0, o, 1.0);
        }
    }
}

/// A reduced subtree: the sum of leaves `[lo, lo + len)`.
#[derive(Debug, Clone)]
pub struct Node<T> {
    pub lo: usize,
    pub len: usize,
    pub value: T,
}

impl<T> Node<T> {
    /// Whether `self` and `right` are the two children of an aligned
    /// parent node (same size, adjacent, parent-aligned start).
    fn sibling_of(&self, right: &Node<T>) -> bool {
        self.len == right.len
            && self.lo + self.len == right.lo
            && self.lo % (2 * self.len) == 0
    }
}

/// Incremental aligned-subtree builder: push leaves in increasing global
/// index order; adjacent aligned siblings merge eagerly, so the stack
/// never holds more than `log2(pushed) + 1` nodes.
#[derive(Debug)]
pub struct TreeAccum<T> {
    nodes: Vec<Node<T>>,
}

impl<T: Merge> Default for TreeAccum<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T: Merge> TreeAccum<T> {
    pub fn new() -> Self {
        TreeAccum { nodes: Vec::new() }
    }

    /// Add leaf `idx`. Indices must be strictly increasing per accumulator
    /// (a worker sorts its shard before executing — `worker::run_shard`).
    pub fn push(&mut self, idx: usize, value: T) {
        if let Some(last) = self.nodes.last() {
            assert!(
                last.lo + last.len <= idx,
                "tree leaves must arrive in increasing index order"
            );
        }
        self.push_node(Node { lo: idx, len: 1, value });
    }

    /// Add an already-reduced aligned subtree (the coordinator feeds the
    /// workers' nodes through this in [`combine`]).
    fn push_node(&mut self, node: Node<T>) {
        self.nodes.push(node);
        while self.nodes.len() >= 2 {
            let k = self.nodes.len();
            if !self.nodes[k - 2].sibling_of(&self.nodes[k - 1]) {
                break;
            }
            let right = self.nodes.pop().expect("len >= 2");
            let left = self.nodes.last_mut().expect("len >= 1");
            left.value.merge(right.value);
            left.len *= 2;
        }
    }

    /// The maximal aligned subtree roots built so far, in index order.
    pub fn into_nodes(self) -> Vec<Node<T>> {
        self.nodes
    }
}

/// Coordinator side: complete the canonical tree from every worker's
/// subtree roots and return the root value.
///
/// The parts may arrive in any order and any grouping (they are sorted
/// here); the stack merge reaches the unique closure — the binary
/// decomposition of `[0, M)` — and the final right-to-left fold over those
/// maximal blocks is fixed by `M` alone. Returns `None` for an empty
/// round.
pub fn combine<T: Merge>(mut parts: Vec<Node<T>>) -> Option<T> {
    parts.sort_by_key(|n| n.lo);
    let mut acc = TreeAccum::new();
    for part in parts {
        if let Some(last) = acc.nodes.last() {
            assert!(
                last.lo + last.len <= part.lo,
                "worker subtrees must cover disjoint index spans"
            );
        }
        acc.push_node(part);
    }
    fold_blocks(acc.nodes)
}

/// Right-to-left fold of the leftover maximal blocks of a ragged `M`:
/// `b0 ⊕ (b1 ⊕ (b2 ⊕ …))` — one fixed grouping, a pure function of `M`.
/// Shared tail of [`combine`] and the pipelined round's deferred fold.
pub fn fold_blocks<T: Merge>(mut blocks: Vec<Node<T>>) -> Option<T> {
    while blocks.len() >= 2 {
        let right = blocks.pop().expect("len >= 2");
        blocks.last_mut().expect("len >= 1").value.merge(right.value);
    }
    blocks.pop().map(|n| n.value)
}

/// Out-of-order sibling closure for the pipelined round: workers' subtree
/// roots are offered **as each shard finishes** (any arrival order), and
/// every aligned-sibling merge runs the moment both halves are present —
/// the upper tree levels overlap the still-running shards instead of
/// waiting for the last one.
///
/// Bitwise-legal by the same argument as [`combine`]: each canonical tree
/// node's value is a fixed recursive function of its span — (left half) ⊕
/// (right half), with the left operand as the accumulator — so the unique
/// sibling closure is reached through the identical additions in the
/// identical grouping regardless of *when* the siblings became available.
/// [`EagerReduce::finish`] yields the same maximal blocks [`combine`]'s
/// stack would, ready for the same [`fold_blocks`] tail.
#[derive(Debug)]
pub struct EagerReduce<T> {
    /// Maximal merged spans so far, keyed by `lo` (disjoint, sorted).
    spans: BTreeMap<usize, Node<T>>,
}

impl<T: Merge> Default for EagerReduce<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T: Merge> EagerReduce<T> {
    pub fn new() -> Self {
        EagerReduce { spans: BTreeMap::new() }
    }

    /// Offer one reduced subtree root. Spans must be disjoint across all
    /// offers of a round (each leaf delivered exactly once) — the
    /// per-segment ledger on the round coordinator enforces this upstream,
    /// and it is asserted again here.
    pub fn offer(&mut self, mut node: Node<T>) {
        loop {
            // merge with the left neighbor while it is our sibling
            if let Some((&llo, left)) = self.spans.range(..node.lo).next_back() {
                assert!(
                    left.lo + left.len <= node.lo,
                    "eager offers must cover disjoint index spans"
                );
                if left.sibling_of(&node) {
                    let mut left = self.spans.remove(&llo).expect("present");
                    left.value.merge(node.value);
                    left.len *= 2;
                    node = left;
                    continue;
                }
            }
            // merge with the right neighbor while we are its left sibling
            if let Some((&rlo, right)) = self.spans.range(node.lo..).next() {
                assert!(
                    node.lo + node.len <= rlo,
                    "eager offers must cover disjoint index spans"
                );
                if node.sibling_of(right) {
                    let right = self.spans.remove(&rlo).expect("present");
                    node.value.merge(right.value);
                    node.len *= 2;
                    continue;
                }
            }
            break;
        }
        self.spans.insert(node.lo, node);
    }

    /// Offer every node of one shard's output (arrival order within the
    /// batch is irrelevant — each cascades independently).
    pub fn offer_all(&mut self, nodes: Vec<Node<T>>) {
        for n in nodes {
            self.offer(n);
        }
    }

    /// Number of leaves covered so far.
    pub fn covered(&self) -> usize {
        self.spans.values().map(|n| n.len).sum()
    }

    /// The maximal merged blocks, in index order — identical to what
    /// [`combine`]'s stack holds before its fold, so
    /// `fold_blocks(er.finish())` ≡ `combine(parts)` bitwise. The fold is
    /// left to the caller so the pipelined round can run it per-parameter
    /// inside the optimizer fan-out.
    pub fn finish(self) -> Vec<Node<T>> {
        self.spans.into_values().collect()
    }
}

/// Canonical tree sum of a dense slice — the serial reference the
/// distributed path must match bitwise (also used by the unit tests).
pub fn tree_sum_f32(xs: &[f32]) -> Option<f32> {
    let mut acc = TreeAccum::new();
    for (i, &x) in xs.iter().enumerate() {
        acc.push(i, x);
    }
    combine(acc.into_nodes())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg;

    /// Split [0, m) into `w` contiguous shards (the worker assignment
    /// geometry) and reduce through the two-level worker/coordinator path.
    fn sharded_sum(xs: &[f32], w: usize) -> f32 {
        let m = xs.len();
        let mut parts = Vec::new();
        for s in 0..w {
            let (lo, hi) = (s * m / w, (s + 1) * m / w);
            let mut acc = TreeAccum::new();
            for i in lo..hi {
                acc.push(i, xs[i]);
            }
            parts.extend(acc.into_nodes());
        }
        combine(parts).expect("non-empty")
    }

    #[test]
    fn bitwise_invariant_across_worker_counts() {
        // values at wildly different magnitudes expose any grouping change
        let mut rng = Pcg::seeded(0xd157_0001);
        for m in [1usize, 2, 3, 5, 7, 8, 12, 16, 23, 64, 100] {
            let xs: Vec<f32> = (0..m)
                .map(|i| rng.normal() * 10f32.powi((i % 9) as i32 - 4))
                .collect();
            let reference = tree_sum_f32(&xs).unwrap();
            for w in 1..=m.min(9) {
                let got = sharded_sum(&xs, w);
                assert_eq!(
                    got.to_bits(),
                    reference.to_bits(),
                    "m={m} w={w}: {got} vs {reference}"
                );
            }
        }
    }

    #[test]
    fn invariant_under_non_contiguous_requeue() {
        // worker 0 drops after 2 leaves; its remainder is requeued to the
        // others — node set (and root bits) must not change
        let xs: Vec<f32> = (0..11).map(|i| (i as f32 + 0.5) * 1e3).collect();
        let reference = tree_sum_f32(&xs).unwrap();
        let shards: Vec<Vec<usize>> = vec![
            vec![0, 1],          // worker 0 before dropping
            vec![4, 5, 6, 2],    // worker 1 + requeued index 2
            vec![7, 8, 9, 10, 3], // worker 2 + requeued index 3
        ];
        let mut parts = Vec::new();
        for shard in &shards {
            let mut order = shard.clone();
            order.sort_unstable();
            let mut acc = TreeAccum::new();
            for &i in &order {
                acc.push(i, xs[i]);
            }
            parts.extend(acc.into_nodes());
        }
        let got = combine(parts).unwrap();
        assert_eq!(got.to_bits(), reference.to_bits());
    }

    #[test]
    fn accumulator_stack_stays_logarithmic() {
        let mut acc = TreeAccum::new();
        for i in 0..1024 {
            acc.push(i, 1.0f32);
            assert!(acc.nodes.len() <= 11, "stack grew to {}", acc.nodes.len());
        }
        let nodes = acc.into_nodes();
        assert_eq!(nodes.len(), 1, "power-of-two input must fully collapse");
        assert_eq!(nodes[0].len, 1024);
    }

    #[test]
    fn ragged_tail_decomposes_into_binary_blocks() {
        let mut acc = TreeAccum::new();
        for i in 0..13 {
            acc.push(i, 0.0f32);
        }
        let spans: Vec<(usize, usize)> =
            acc.into_nodes().iter().map(|n| (n.lo, n.len)).collect();
        assert_eq!(spans, vec![(0, 8), (8, 4), (12, 1)], "13 = 8 + 4 + 1");
    }

    #[test]
    fn grad_nodes_merge_losses_and_mats() {
        let a = GradNode {
            loss: 1.5,
            grads: vec![Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0])],
        };
        let b = GradNode {
            loss: 0.5,
            grads: vec![Mat::from_vec(2, 2, vec![10.0, 20.0, 30.0, 40.0])],
        };
        let mut m = a;
        m.merge(b);
        assert_eq!(m.loss, 2.0);
        assert_eq!(m.grads[0].data, vec![11.0, 22.0, 33.0, 44.0]);
    }

    #[test]
    fn empty_round_is_none() {
        assert_eq!(tree_sum_f32(&[]), None);
        assert!(combine::<f32>(Vec::new()).is_none());
        assert!(fold_blocks::<f32>(Vec::new()).is_none());
        assert_eq!(EagerReduce::<f32>::new().covered(), 0);
        assert!(EagerReduce::<f32>::new().finish().is_empty());
    }

    /// Build each shard's maximal subtree roots, as a worker would.
    fn shard_nodes(xs: &[f32], shard: &[usize]) -> Vec<Node<f32>> {
        let mut order = shard.to_vec();
        order.sort_unstable();
        let mut acc = TreeAccum::new();
        for &i in &order {
            acc.push(i, xs[i]);
        }
        acc.into_nodes()
    }

    #[test]
    fn eager_matches_combine_for_every_arrival_order() {
        let mut rng = Pcg::seeded(0xd157_0002);
        for m in [1usize, 2, 3, 5, 8, 11, 13, 16, 23] {
            let xs: Vec<f32> = (0..m)
                .map(|i| rng.normal() * 10f32.powi((i % 9) as i32 - 4))
                .collect();
            let reference = tree_sum_f32(&xs).unwrap();
            for w in 1..=m.min(5) {
                let shards: Vec<Vec<usize>> =
                    (0..w).map(|s| (s * m / w..(s + 1) * m / w).collect()).collect();
                // every shard-arrival permutation must produce the same bits
                let mut orders: Vec<Vec<usize>> = vec![(0..w).collect()];
                for rot in 1..w {
                    let mut o: Vec<usize> = (0..w).collect();
                    o.rotate_left(rot);
                    orders.push(o);
                }
                orders.push((0..w).rev().collect());
                for order in orders {
                    let mut er = EagerReduce::new();
                    for &s in &order {
                        er.offer_all(shard_nodes(&xs, &shards[s]));
                    }
                    assert_eq!(er.covered(), m);
                    let got = fold_blocks(er.finish()).unwrap();
                    assert_eq!(
                        got.to_bits(),
                        reference.to_bits(),
                        "m={m} w={w} order={order:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn eager_blocks_equal_combines_blocks() {
        // the deferred-fold contract: finish() must yield exactly the
        // maximal blocks combine's stack folds (binary decomposition of M)
        let xs: Vec<f32> = (0..13).map(|i| i as f32).collect();
        let shards: Vec<Vec<usize>> = vec![(0..5).collect(), (5..13).collect()];
        let mut er = EagerReduce::new();
        for s in shards.iter().rev() {
            er.offer_all(shard_nodes(&xs, s));
        }
        let spans: Vec<(usize, usize)> =
            er.finish().iter().map(|n| (n.lo, n.len)).collect();
        assert_eq!(spans, vec![(0, 8), (8, 4), (12, 1)], "13 = 8 + 4 + 1");
    }

    #[test]
    fn eager_handles_requeued_non_contiguous_shards() {
        let xs: Vec<f32> = (0..11).map(|i| (i as f32 + 0.5) * 1e3).collect();
        let reference = tree_sum_f32(&xs).unwrap();
        // the same churn partition as invariant_under_non_contiguous_requeue,
        // delivered in reverse completion order
        let shards: Vec<Vec<usize>> =
            vec![vec![0, 1], vec![4, 5, 6, 2], vec![7, 8, 9, 10, 3]];
        let mut er = EagerReduce::new();
        for s in shards.iter().rev() {
            er.offer_all(shard_nodes(&xs, s));
        }
        let got = fold_blocks(er.finish()).unwrap();
        assert_eq!(got.to_bits(), reference.to_bits());
    }

    #[test]
    #[should_panic(expected = "disjoint index spans")]
    fn eager_rejects_double_delivery() {
        let mut er = EagerReduce::new();
        er.offer(Node { lo: 0, len: 2, value: 1.0f32 });
        er.offer(Node { lo: 1, len: 1, value: 1.0f32 });
    }

    #[test]
    #[should_panic(expected = "increasing index order")]
    fn out_of_order_leaves_are_rejected() {
        let mut acc = TreeAccum::new();
        acc.push(3, 1.0f32);
        acc.push(1, 1.0f32);
    }
}
