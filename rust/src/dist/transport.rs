//! Process-boundary transport for the elastic round machine: the piece
//! that promotes `dist/` from a simulated cluster to real networked
//! training (the ROADMAP "networked elastic training" item).
//!
//! A [`Transport`] answers the two questions [`super::run_round_via`]
//! cannot answer generically — *how does the machine reach `RoundTrain`*
//! (logical ticks vs wall-clock ticks with live joins) and *who executes
//! the shards* (the in-process pool vs remote workers over sockets):
//!
//! * [`Loopback`] — the PR-3 simulated cluster, verbatim: ticks are
//!   logical, shards fan out over `util::pool`. Every `dist_parity` /
//!   `trainer_e2e` bit is pinned on this path.
//! * [`TcpCoordinator`] — a coordinator serving a `TcpListener`: ticks on
//!   wall-clock time, admits joins by run-id handshake, ships each member
//!   its shard (indices + token blocks), collects per-shard subtree nodes,
//!   and streams the latest checkpoint + round snapshot to late joiners.
//!   [`run_worker`] is the matching client loop.
//!
//! # Determinism contract
//!
//! The tree reduce is defined over **global microbatch indices**
//! ([`super::reduce`]), so the coordinator never needs worker results in
//! any particular order: any shard partition — including mid-round requeues
//! after a disconnect — produces the identical node set, hence identical
//! reduced bits. A TCP run is therefore bitwise identical to the loopback
//! run (pinned by `rust/tests/transport_parity.rs`), and a dropped
//! connection is handled by the *same* `RoundCoordinator::leave` requeue
//! arithmetic as the simulated departure: the coordinator diffs the
//! assignments around `leave()` and ships each survivor exactly the suffix
//! it gained.
//!
//! # Wire protocol
//!
//! Little-endian, length-prefixed frames over plain TCP:
//!
//! ```text
//! frame     := len:u32 | kind:u8 | payload          (len counts kind+payload)
//! Hello     := proto:u32 | run_id:str               worker → coordinator
//! Welcome   := member:u64 | round:u64               coordinator → worker
//! Reject    := reason:str
//! State     := step:u64 | snap:[f32] | blob:[u8]    checkpoint broadcast
//! Shard     := round:u64 | seq:u64 | {index:u64, tensor}*
//! ShardDone := round:u64 | seq:u64 | secs:f64 | {lo,len,loss,grads}*
//! Done      := (empty)                              orderly shutdown
//! Witness   := round:u64 | workers:u64 | micro:u64 | requeues:u64 |
//!              stragglers:u64 | grad_secs:f64 | reduce_secs:f64 |
//!              imbalance:f64 | median_secs:f64 |
//!              {id:u64, alive:u8, micro_done:u64,   coordinator → worker,
//!               requeued:u64, straggles:u64}*       round-end telemetry
//! Request   := id:u64 | tensor                      serve client → server
//! Response  := id:u64 | score:f32 | latency:f64     serve server → client
//! str/[T]   := count:u64 | elements
//! tensor    := tag:u8 (0=f32, 1=i32) | rank:u64 | dims:u64* | data
//! ```
//!
//! The serving plane (`crate::serve::net`) rides the same frame machinery:
//! its `Request`/`Response` kinds share the handshake, the length/count
//! validation, and the per-kind obs wire accounting with the training
//! frames.
//!
//! Every frame written or read is accounted in the `obs` wire-byte
//! counters (per kind, in/out), and frame I/O opens `wire` trace spans —
//! both observational only, never on the decode path's control flow.
//!
//! The handshake (`Hello` → `Welcome`/`Reject`) carries a protocol version
//! and the run id, so a worker can never silently join the wrong run. All
//! counts are validated against the remaining frame bytes before any
//! allocation; frames are capped at [`MAX_FRAME`].

use std::collections::{HashMap, VecDeque};
use std::io::{Read, Write};
use std::net::{IpAddr, Ipv4Addr, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::linalg::Mat;
use crate::obs;
use crate::runtime::HostTensor;
use crate::util::{trace, Timer};

use super::reduce::{GradNode, Node, TreeAccum};
use super::round::{Phase, RoundCoordinator, WitnessMember, WitnessReport};
use super::worker::{self, GradSource};

/// Handshake protocol version — bumped on any frame-layout change
/// (v2: the round-end `Witness` telemetry frame, ISSUE 8; v3: the
/// serving-plane `Request`/`Response` frames, ISSUE 9).
pub const PROTO_VERSION: u32 = 3;

/// Upper bound on one frame body (guards `Vec` allocation from the wire).
pub const MAX_FRAME: usize = 1 << 30;

/// How a round crosses (or doesn't cross) a process boundary. Object-safe
/// so the trainer can hold `Box<dyn Transport>` chosen at config time.
pub trait Transport {
    /// Walk the state machine to an unarmed `RoundTrain`. The loopback
    /// ticks logically; the TCP impl ticks on wall-clock time, admitting
    /// joins and departures between ticks.
    fn advance_to_train(&mut self, coord: &mut RoundCoordinator) -> Result<()>;

    /// Execute every member's shard for the armed round and return the
    /// collected subtree nodes plus the gradient-phase wall clock. Must
    /// call `coord.complete(...)` for each member exactly as the
    /// simulated path would.
    fn execute_round(
        &mut self,
        coord: &mut RoundCoordinator,
        src: &dyn GradSource,
        tokens: &[HostTensor],
    ) -> Result<(Vec<Node<GradNode>>, f64)>;

    /// Pipelined variant of [`execute_round`](Self::execute_round): each
    /// member's subtree nodes are pushed into `sink` (on the calling
    /// thread) the moment that member's shard completes, after recording
    /// their spans in the coordinator's delivery ledger — so the caller
    /// merges early shards while later ones still run. Returns the
    /// gradient-phase wall clock; the nodes all went through `sink`.
    ///
    /// The default is the phased fallback — execute everything, then one
    /// delivery — so any transport is pipelined-correct before it is
    /// pipelined-fast.
    fn execute_round_eager(
        &mut self,
        coord: &mut RoundCoordinator,
        src: &dyn GradSource,
        tokens: &[HostTensor],
        sink: &mut dyn FnMut(Vec<Node<GradNode>>),
    ) -> Result<f64> {
        let (nodes, grad_secs) = self.execute_round(coord, src, tokens)?;
        let spans: Vec<(usize, usize)> = nodes.iter().map(|n| (n.lo, n.len)).collect();
        coord.deliver_segments(&spans);
        sink(nodes);
        Ok(grad_secs)
    }

    /// Broadcast the latest checkpoint (round snapshot + opaque blob) and
    /// cache it for late joiners. No-op on the loopback.
    fn publish_state(&mut self, _step: u64, _snap: &[f32], _blob: &[u8]) -> Result<()> {
        Ok(())
    }

    /// Whether this transport wants `publish_state` calls (lets the
    /// trainer skip checkpoint encoding on the loopback).
    fn wants_state(&self) -> bool {
        false
    }

    /// Broadcast the round-end witness telemetry (round record + health
    /// ledger) to every connected worker. No-op on the loopback — the
    /// caller already holds the `RoundCoordinator` the report came from.
    fn publish_witness(&mut self, _w: &WitnessReport) -> Result<()> {
        Ok(())
    }

    /// Orderly teardown (broadcast `Done`, close sockets). No-op on the
    /// loopback.
    fn shutdown(&mut self) {}
}

/// The in-process transport: the PR-3 simulated cluster, unchanged.
/// Shards fan out as tasks on the persistent `util::pool`.
pub struct Loopback;

impl Transport for Loopback {
    fn advance_to_train(&mut self, coord: &mut RoundCoordinator) -> Result<()> {
        coord.advance_to_train()
    }

    fn execute_round(
        &mut self,
        coord: &mut RoundCoordinator,
        src: &dyn GradSource,
        tokens: &[HostTensor],
    ) -> Result<(Vec<Node<GradNode>>, f64)> {
        let _sp = trace::region("round", "loopback_execute_round");
        let assignments = coord.assignments().to_vec();
        let t0 = Timer::start();
        let outs = worker::run_workers(src, &assignments, tokens);
        let grad_secs = t0.secs();
        let mut nodes = Vec::new();
        for (w, out) in outs.into_iter().enumerate() {
            let out = out.with_context(|| format!("dp worker {w}"))?;
            coord.complete(w, out.secs);
            nodes.extend(out.nodes);
        }
        Ok((nodes, grad_secs))
    }

    /// Genuinely eager: shards fan out via `pool::map_consume`, so each
    /// finished shard is completed, ledgered, and sunk while the remaining
    /// shards still run on the pool helpers. At width ≤ 1 delivery is
    /// worker-order serial — bitwise the same either way (the sink's eager
    /// closure is arrival-order-invariant).
    fn execute_round_eager(
        &mut self,
        coord: &mut RoundCoordinator,
        src: &dyn GradSource,
        tokens: &[HostTensor],
        sink: &mut dyn FnMut(Vec<Node<GradNode>>),
    ) -> Result<f64> {
        let _sp = trace::region("round", "loopback_execute_round_eager");
        let assignments = coord.assignments().to_vec();
        let t0 = Timer::start();
        let mut failed: Option<(usize, anyhow::Error)> = None;
        worker::run_workers_eager(src, &assignments, tokens, |w, out| match out {
            Ok(out) => {
                coord.complete(w, out.secs);
                let spans: Vec<(usize, usize)> =
                    out.nodes.iter().map(|n| (n.lo, n.len)).collect();
                coord.deliver_segments(&spans);
                sink(out.nodes);
            }
            Err(e) => {
                if failed.is_none() {
                    failed = Some((w, e));
                }
            }
        });
        if let Some((w, e)) = failed {
            return Err(e.context(format!("dp worker {w}")));
        }
        Ok(t0.secs())
    }
}

// ------------------------------------------------------------ wire codec ---

const K_HELLO: u8 = 1;
const K_WELCOME: u8 = 2;
const K_REJECT: u8 = 3;
const K_STATE: u8 = 4;
const K_SHARD: u8 = 5;
const K_SHARD_DONE: u8 = 6;
const K_DONE: u8 = 7;
const K_WITNESS: u8 = 8;
const K_REQUEST: u8 = 9;
const K_RESPONSE: u8 = 10;

/// Static tx/rx span names per frame kind (trace spans need `&'static str`).
fn span_name(kind: u8, tx: bool) -> &'static str {
    match (kind, tx) {
        (K_HELLO, true) => "tx_hello",
        (K_WELCOME, true) => "tx_welcome",
        (K_REJECT, true) => "tx_reject",
        (K_STATE, true) => "tx_state",
        (K_SHARD, true) => "tx_shard",
        (K_SHARD_DONE, true) => "tx_shard_done",
        (K_DONE, true) => "tx_done",
        (K_WITNESS, true) => "tx_witness",
        (K_REQUEST, true) => "tx_request",
        (K_RESPONSE, true) => "tx_response",
        (K_HELLO, false) => "rx_hello",
        (K_WELCOME, false) => "rx_welcome",
        (K_REJECT, false) => "rx_reject",
        (K_STATE, false) => "rx_state",
        (K_SHARD, false) => "rx_shard",
        (K_SHARD_DONE, false) => "rx_shard_done",
        (K_DONE, false) => "rx_done",
        (K_WITNESS, false) => "rx_witness",
        (K_REQUEST, false) => "rx_request",
        (K_RESPONSE, false) => "rx_response",
        (_, true) => "tx_unknown",
        (_, false) => "rx_unknown",
    }
}

/// Write one encoded frame, accounting its bytes per kind and opening a
/// `wire` tx span (the frame layout puts the kind byte at offset 4).
/// Crate-visible so the serving plane (`crate::serve::net`) shares the
/// accounting path.
pub(crate) fn send_frame(s: &mut TcpStream, buf: &[u8]) -> std::io::Result<()> {
    let kind = buf[4];
    let _sp = trace::span("wire", span_name(kind, true));
    obs::wire_out(kind, buf.len());
    s.write_all(buf)
}

/// Little-endian frame builder; `frame()` prepends the length word.
struct W {
    b: Vec<u8>,
}

impl W {
    fn new(kind: u8) -> Self {
        W { b: vec![kind] }
    }

    fn u8(&mut self, x: u8) {
        self.b.push(x);
    }

    fn u32(&mut self, x: u32) {
        self.b.extend_from_slice(&x.to_le_bytes());
    }

    fn u64(&mut self, x: u64) {
        self.b.extend_from_slice(&x.to_le_bytes());
    }

    fn f32(&mut self, x: f32) {
        self.u32(x.to_bits());
    }

    fn f64(&mut self, x: f64) {
        self.u64(x.to_bits());
    }

    fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.b.extend_from_slice(s.as_bytes());
    }

    fn frame(self) -> Vec<u8> {
        assert!(self.b.len() <= MAX_FRAME, "frame body exceeds MAX_FRAME");
        let mut out = (self.b.len() as u32).to_le_bytes().to_vec();
        out.extend_from_slice(&self.b);
        out
    }
}

/// Bounds-checked little-endian reader over one frame body.
struct R<'a> {
    d: &'a [u8],
    pos: usize,
}

impl<'a> R<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if n > self.d.len() - self.pos {
            bail!("truncated frame at byte {} (want {n} more)", self.pos);
        }
        let s = &self.d[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// Read an element count and validate it against the bytes left in
    /// the frame (each element occupies ≥ `min_bytes`), so a corrupted
    /// count errors instead of attempting a huge allocation.
    fn count(&mut self, min_bytes: usize) -> Result<usize> {
        let n = self.u64()? as usize;
        let rem = self.d.len() - self.pos;
        if n.saturating_mul(min_bytes.max(1)) > rem {
            bail!("frame count {n} exceeds remaining {rem} bytes");
        }
        Ok(n)
    }

    fn str(&mut self) -> Result<String> {
        let n = self.count(1)?;
        String::from_utf8(self.take(n)?.to_vec()).context("non-utf8 string on the wire")
    }
}

fn enc_tensor(w: &mut W, t: &HostTensor) {
    match t {
        HostTensor::F32 { shape, data } => {
            w.u8(0);
            w.u64(shape.len() as u64);
            for &d in shape {
                w.u64(d as u64);
            }
            w.u64(data.len() as u64);
            for &x in data {
                w.f32(x);
            }
        }
        HostTensor::I32 { shape, data } => {
            w.u8(1);
            w.u64(shape.len() as u64);
            for &d in shape {
                w.u64(d as u64);
            }
            w.u64(data.len() as u64);
            for &x in data {
                w.u32(x as u32);
            }
        }
    }
}

fn dec_tensor(r: &mut R) -> Result<HostTensor> {
    let tag = r.u8()?;
    let rank = r.count(8)?;
    let mut shape = Vec::with_capacity(rank);
    for _ in 0..rank {
        shape.push(r.u64()? as usize);
    }
    let n = r.count(4)?;
    let elems: usize = shape.iter().product();
    if elems != n {
        bail!("tensor shape {shape:?} disagrees with {n} data elements");
    }
    Ok(match tag {
        0 => {
            let mut data = Vec::with_capacity(n);
            for _ in 0..n {
                data.push(r.f32()?);
            }
            HostTensor::F32 { shape, data }
        }
        1 => {
            let mut data = Vec::with_capacity(n);
            for _ in 0..n {
                data.push(r.u32()? as i32);
            }
            HostTensor::I32 { shape, data }
        }
        t => bail!("unknown tensor tag {t}"),
    })
}

fn enc_node(w: &mut W, n: &Node<GradNode>) {
    w.u64(n.lo as u64);
    w.u64(n.len as u64);
    w.f32(n.value.loss);
    w.u64(n.value.grads.len() as u64);
    for g in &n.value.grads {
        w.u64(g.rows as u64);
        w.u64(g.cols as u64);
        w.u64(g.data.len() as u64);
        for &x in &g.data {
            w.f32(x);
        }
    }
}

fn dec_node(r: &mut R) -> Result<Node<GradNode>> {
    let lo = r.u64()? as usize;
    let len = r.u64()? as usize;
    let loss = r.f32()?;
    let ng = r.count(20)?;
    let mut grads = Vec::with_capacity(ng);
    for _ in 0..ng {
        let rows = r.u64()? as usize;
        let cols = r.u64()? as usize;
        let n = r.count(4)?;
        if rows.saturating_mul(cols) != n {
            bail!("gradient shape {rows}x{cols} disagrees with {n} elements");
        }
        let mut data = Vec::with_capacity(n);
        for _ in 0..n {
            data.push(r.f32()?);
        }
        grads.push(Mat::from_vec(rows, cols, data));
    }
    Ok(Node { lo, len, value: GradNode { loss, grads } })
}

/// One parsed frame (coordinator-, worker-, and serve-side). Crate-visible
/// so `crate::serve::net` speaks the same frames without re-implementing
/// the codec.
#[derive(Debug)]
pub(crate) enum Frame {
    Hello { proto: u32, run_id: String },
    Welcome { member: u64, round: u64 },
    Reject { reason: String },
    State { step: u64, snap: Vec<f32>, blob: Vec<u8> },
    Shard { round: u64, seq: u64, items: Vec<(usize, HostTensor)> },
    ShardDone { round: u64, seq: u64, secs: f64, nodes: Vec<Node<GradNode>> },
    Done,
    Witness(WitnessReport),
    Request { id: u64, tokens: HostTensor },
    Response { id: u64, score: f32, latency_s: f64 },
}

pub(crate) fn enc_hello(run_id: &str) -> Vec<u8> {
    let mut w = W::new(K_HELLO);
    w.u32(PROTO_VERSION);
    w.str(run_id);
    w.frame()
}

pub(crate) fn enc_welcome(member: u64, round: u64) -> Vec<u8> {
    let mut w = W::new(K_WELCOME);
    w.u64(member);
    w.u64(round);
    w.frame()
}

pub(crate) fn enc_reject(reason: &str) -> Vec<u8> {
    let mut w = W::new(K_REJECT);
    w.str(reason);
    w.frame()
}

fn enc_state(step: u64, snap: &[f32], blob: &[u8]) -> Vec<u8> {
    let mut w = W::new(K_STATE);
    w.u64(step);
    w.u64(snap.len() as u64);
    for &x in snap {
        w.f32(x);
    }
    w.u64(blob.len() as u64);
    w.b.extend_from_slice(blob);
    w.frame()
}

fn enc_shard(round: u64, seq: u64, indices: &[usize], tokens: &[HostTensor]) -> Vec<u8> {
    let mut w = W::new(K_SHARD);
    w.u64(round);
    w.u64(seq);
    w.u64(indices.len() as u64);
    for &i in indices {
        w.u64(i as u64);
        enc_tensor(&mut w, &tokens[i]);
    }
    w.frame()
}

fn enc_shard_done(round: u64, seq: u64, secs: f64, nodes: &[Node<GradNode>]) -> Vec<u8> {
    let mut w = W::new(K_SHARD_DONE);
    w.u64(round);
    w.u64(seq);
    w.f64(secs);
    w.u64(nodes.len() as u64);
    for n in nodes {
        enc_node(&mut w, n);
    }
    w.frame()
}

pub(crate) fn enc_done() -> Vec<u8> {
    W::new(K_DONE).frame()
}

/// Encode a serving-plane scoring request (proto v3): request id plus the
/// token tensor, reusing the shard codec's `tensor` layout.
pub(crate) fn enc_request(id: u64, tokens: &HostTensor) -> Vec<u8> {
    let mut w = W::new(K_REQUEST);
    w.u64(id);
    enc_tensor(&mut w, tokens);
    w.frame()
}

/// Encode a serving-plane scoring response: request id, the f32 score
/// (bit-exact on the wire), and the server-side enqueue→scored latency.
pub(crate) fn enc_response(id: u64, score: f32, latency_s: f64) -> Vec<u8> {
    let mut w = W::new(K_RESPONSE);
    w.u64(id);
    w.f32(score);
    w.f64(latency_s);
    w.frame()
}

/// Encode a round-end witness broadcast. Public (with
/// [`dec_witness_frame`]) so `tests/transport_parity.rs` can pin the
/// codec roundtrip without the private `Frame` plumbing.
pub fn enc_witness(wr: &WitnessReport) -> Vec<u8> {
    let mut w = W::new(K_WITNESS);
    w.u64(wr.round);
    w.u64(wr.workers);
    w.u64(wr.micro);
    w.u64(wr.requeues);
    w.u64(wr.stragglers);
    w.f64(wr.grad_secs);
    w.f64(wr.reduce_secs);
    w.f64(wr.imbalance);
    w.f64(wr.median_secs);
    w.u64(wr.members.len() as u64);
    for m in &wr.members {
        w.u64(m.id);
        w.u8(m.alive as u8);
        w.u64(m.micro_done);
        w.u64(m.requeued);
        w.u64(m.straggles);
    }
    w.frame()
}

fn dec_witness(r: &mut R) -> Result<WitnessReport> {
    let round = r.u64()?;
    let workers = r.u64()?;
    let micro = r.u64()?;
    let requeues = r.u64()?;
    let stragglers = r.u64()?;
    let grad_secs = r.f64()?;
    let reduce_secs = r.f64()?;
    let imbalance = r.f64()?;
    let median_secs = r.f64()?;
    let n = r.count(33)?; // 4×u64 + u8 per member
    let mut members = Vec::with_capacity(n);
    for _ in 0..n {
        members.push(WitnessMember {
            id: r.u64()?,
            alive: r.u8()? != 0,
            micro_done: r.u64()?,
            requeued: r.u64()?,
            straggles: r.u64()?,
        });
    }
    Ok(WitnessReport {
        round,
        workers,
        micro,
        requeues,
        stragglers,
        grad_secs,
        reduce_secs,
        imbalance,
        median_secs,
        members,
    })
}

/// Decode one full `Witness` frame (length word included) — the inverse
/// of [`enc_witness`], exposed for the parity-suite codec test.
pub fn dec_witness_frame(bytes: &[u8]) -> Result<WitnessReport> {
    let mut rd = bytes;
    match read_frame(&mut rd)? {
        Some(Frame::Witness(w)) => Ok(w),
        other => bail!("expected a Witness frame, got {other:?}"),
    }
}

/// Read one frame. `Ok(None)` means the peer closed the connection
/// cleanly (EOF at a frame boundary); a truncated frame is an error.
/// Crate-visible so the serving plane shares the decode/validation path.
pub(crate) fn read_frame(s: &mut impl Read) -> Result<Option<Frame>> {
    let mut lenb = [0u8; 4];
    match s.read_exact(&mut lenb) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e).context("reading frame length"),
    }
    let len = u32::from_le_bytes(lenb) as usize;
    if len == 0 || len > MAX_FRAME {
        bail!("invalid frame length {len}");
    }
    let mut body = vec![0u8; len];
    // kind byte first, so the rx span can be named; the span then covers
    // the payload transfer + decode (the blocking wait for the *next*
    // frame is the caller's tick_wait, not rx time)
    s.read_exact(&mut body[..1]).context("reading frame kind")?;
    let kind = body[0];
    let _sp = trace::span("wire", span_name(kind, false));
    s.read_exact(&mut body[1..]).context("reading frame body")?;
    obs::wire_in(kind, 4 + len);
    let mut r = R { d: &body, pos: 0 };
    let frame = match r.u8()? {
        K_HELLO => Frame::Hello { proto: r.u32()?, run_id: r.str()? },
        K_WELCOME => Frame::Welcome { member: r.u64()?, round: r.u64()? },
        K_REJECT => Frame::Reject { reason: r.str()? },
        K_STATE => {
            let step = r.u64()?;
            let ns = r.count(4)?;
            let mut snap = Vec::with_capacity(ns);
            for _ in 0..ns {
                snap.push(r.f32()?);
            }
            let nb = r.count(1)?;
            let blob = r.take(nb)?.to_vec();
            Frame::State { step, snap, blob }
        }
        K_SHARD => {
            let round = r.u64()?;
            let seq = r.u64()?;
            let n = r.count(8)?;
            let mut items = Vec::with_capacity(n);
            for _ in 0..n {
                let idx = r.u64()? as usize;
                items.push((idx, dec_tensor(&mut r)?));
            }
            Frame::Shard { round, seq, items }
        }
        K_SHARD_DONE => {
            let round = r.u64()?;
            let seq = r.u64()?;
            let secs = r.f64()?;
            let n = r.count(20)?;
            let mut nodes = Vec::with_capacity(n);
            for _ in 0..n {
                nodes.push(dec_node(&mut r)?);
            }
            Frame::ShardDone { round, seq, secs, nodes }
        }
        K_DONE => Frame::Done,
        K_WITNESS => Frame::Witness(dec_witness(&mut r)?),
        K_REQUEST => {
            let id = r.u64()?;
            let tokens = dec_tensor(&mut r)?;
            Frame::Request { id, tokens }
        }
        K_RESPONSE => {
            let id = r.u64()?;
            let score = r.f32()?;
            let latency_s = r.f64()?;
            Frame::Response { id, score, latency_s }
        }
        k => bail!("unknown frame kind {k}"),
    };
    Ok(Some(frame))
}

// -------------------------------------------------------- TCP coordinator ---

/// Wire tunables for the TCP transport (`[dist]` config / CLI flags).
#[derive(Debug, Clone)]
pub struct WireCfg {
    /// Run identity checked in the join handshake: a worker connecting
    /// with a different run-id is rejected, never silently admitted.
    pub run_id: String,
    /// Wall-clock milliseconds per state-machine tick.
    pub tick_ms: u64,
    /// How long `advance_to_train` waits for `min_workers` members.
    pub join_timeout_s: f64,
    /// How long one round may take before the coordinator gives up (this
    /// is the visible stall when every member departs mid-round).
    pub round_timeout_s: f64,
}

impl Default for WireCfg {
    fn default() -> Self {
        WireCfg {
            run_id: "run".to_string(),
            tick_ms: 5,
            join_timeout_s: 30.0,
            round_timeout_s: 120.0,
        }
    }
}

/// Reader-thread → event-loop message. Crate-visible so the serving
/// plane's server pumps the same event shape from [`reader_loop`].
pub(crate) enum Event {
    Hello { conn: u64, stream: TcpStream, proto: u32, run_id: String },
    Frame { conn: u64, frame: Frame },
    Closed { conn: u64 },
}

/// Per-member in-flight round accounting. `outstanding` counts dispatched
/// shard messages without a `ShardDone` yet; `outstanding == 0` exactly
/// when the round machine has this member's shard marked done.
#[derive(Default)]
struct Pend {
    outstanding: usize,
    secs: f64,
    nodes: Vec<Node<GradNode>>,
    /// Pipelined rounds only: how many of this member's assigned indices
    /// were already handed to the eager reduce (always the full assignment
    /// length at the instant of a delivery). A disconnect then requeues
    /// only `assignment[delivered..]` — delivered leaves are merged and
    /// must never re-execute. Stays 0 on the phased path.
    delivered: usize,
}

/// Coordinator side of the TCP transport: owns the listener, one reader
/// thread per connection feeding an event channel, and the write halves.
/// Connection ids double as member ids in the round machine.
pub struct TcpCoordinator {
    cfg: WireCfg,
    addr: SocketAddr,
    rx: Receiver<Event>,
    /// Kept so the channel never disconnects while readers come and go.
    _tx: Sender<Event>,
    conns: HashMap<u64, TcpStream>,
    /// Latest published (step, round snapshot, checkpoint blob) — streamed
    /// to every late joiner right after `Welcome`.
    state: Option<(u64, Vec<f32>, Vec<u8>)>,
    /// Synthetic events (write failures discovered mid-dispatch) handled
    /// before the channel is polled again.
    queued: VecDeque<Event>,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

impl TcpCoordinator {
    /// Bind `listen` (e.g. `127.0.0.1:0`) and start accepting workers.
    /// Members are admitted lazily, as events are pumped by
    /// `advance_to_train` / `execute_round` — the round machine starts
    /// empty (no pre-joined members, unlike the simulated cluster).
    pub fn bind(listen: &str, cfg: WireCfg) -> Result<Self> {
        let listener =
            TcpListener::bind(listen).with_context(|| format!("binding {listen}"))?;
        let addr = listener.local_addr()?;
        let (tx, rx) = mpsc::channel();
        let stop = Arc::new(AtomicBool::new(false));
        let accept = {
            let stop = stop.clone();
            let tx = tx.clone();
            std::thread::Builder::new()
                .name("ar-accept".to_string())
                .spawn(move || {
                    let next = AtomicUsize::new(0);
                    loop {
                        let stream = match listener.accept() {
                            Ok((s, _)) => s,
                            Err(_) => {
                                if stop.load(Ordering::SeqCst) {
                                    break;
                                }
                                continue;
                            }
                        };
                        if stop.load(Ordering::SeqCst) {
                            break;
                        }
                        let conn = next.fetch_add(1, Ordering::SeqCst) as u64;
                        let tx = tx.clone();
                        let _ = std::thread::Builder::new()
                            .name(format!("ar-conn-{conn}"))
                            .spawn(move || reader_loop(conn, stream, tx));
                    }
                })
                .context("spawning accept thread")?
        };
        Ok(TcpCoordinator {
            cfg,
            addr,
            rx,
            _tx: tx,
            conns: HashMap::new(),
            state: None,
            queued: VecDeque::new(),
            stop,
            accept: Some(accept),
        })
    }

    /// The bound address (port resolved when binding `:0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Next event: synthetic queue first, then the channel, blocking no
    /// later than `deadline`.
    fn next_event(&mut self, deadline: Instant) -> Option<Event> {
        if let Some(e) = self.queued.pop_front() {
            return Some(e);
        }
        let now = Instant::now();
        if now >= deadline {
            return self.rx.try_recv().ok();
        }
        self.rx.recv_timeout(deadline - now).ok()
    }

    /// Validate the handshake and join the member (or reject). A join is
    /// legal at any time; mid-round joiners get no shard until the next
    /// `begin_round`, exactly like the simulated `join()`.
    fn admit(
        &mut self,
        coord: &mut RoundCoordinator,
        conn: u64,
        mut stream: TcpStream,
        proto: u32,
        run_id: &str,
    ) {
        if proto != PROTO_VERSION || run_id != self.cfg.run_id {
            let _ = send_frame(
                &mut stream,
                &enc_reject(&format!(
                    "handshake mismatch: proto {proto} (want {PROTO_VERSION}), \
                     run-id {run_id:?} (want {:?})",
                    self.cfg.run_id
                )),
            );
            return;
        }
        coord.join(conn as usize);
        let mut ok = send_frame(&mut stream, &enc_welcome(conn, coord.round)).is_ok();
        if ok {
            if let Some((step, snap, blob)) = &self.state {
                // the late-joiner stream: latest checkpoint + round state
                ok = send_frame(&mut stream, &enc_state(*step, snap, blob)).is_ok();
            }
        }
        if ok {
            self.conns.insert(conn, stream);
        } else {
            coord.leave(conn as usize);
        }
    }

    /// Event handling outside an armed round (joins, departures; stale
    /// round frames are dropped).
    fn handle_idle_event(&mut self, coord: &mut RoundCoordinator, ev: Event) {
        match ev {
            Event::Hello { conn, stream, proto, run_id } => {
                self.admit(coord, conn, stream, proto, &run_id)
            }
            Event::Closed { conn } => {
                self.conns.remove(&conn);
                coord.leave(conn as usize);
            }
            Event::Frame { .. } => {}
        }
    }

    /// Ship `indices` (plus their token blocks) to member `id` as one
    /// shard message. A write failure is converted into a synthetic
    /// `Closed` so the departure path requeues the work.
    fn dispatch(
        &mut self,
        pend: &mut HashMap<u64, Pend>,
        round: u64,
        seq: &mut u64,
        id: u64,
        indices: &[usize],
        tokens: &[HostTensor],
    ) {
        *seq += 1;
        let buf = enc_shard(round, *seq, indices, tokens);
        let ok = self
            .conns
            .get_mut(&id)
            .map(|s| send_frame(s, &buf).is_ok())
            .unwrap_or(false);
        if ok {
            pend.entry(id).or_default().outstanding += 1;
        } else {
            self.queued.push_back(Event::Closed { conn: id });
        }
    }

    /// A connection died. Completed shards stay (their leaves are final
    /// and the ledger is credited); in-flight work is voided and the
    /// member's remaining assignment goes through the *same* requeue
    /// arithmetic as a simulated departure — the assignment diff around
    /// the departure tells us exactly which suffix each survivor gained,
    /// and that suffix is shipped as a supplemental shard message. On the
    /// phased path `delivered` is 0 and this is exactly `leave()`; on the
    /// pipelined path the member's already-merged prefix stays put and
    /// only the undelivered suffix moves.
    fn handle_disconnect(
        &mut self,
        coord: &mut RoundCoordinator,
        pend: &mut HashMap<u64, Pend>,
        round: u64,
        seq: &mut u64,
        conn: u64,
        tokens: &[HostTensor],
    ) {
        self.conns.remove(&conn);
        let delivered = pend.get(&conn).map(|p| p.delivered).unwrap_or(0);
        if pend.get(&conn).map(|p| p.outstanding > 0).unwrap_or(false) {
            // mid-shard: every undelivered node this member produced is
            // voided — the departure requeues its unmerged suffix, so
            // survivors recompute those leaves (pure execution ⇒
            // identical bits)
            pend.remove(&conn);
        }
        let before: Vec<usize> = coord.assignments().iter().map(|a| a.len()).collect();
        coord.leave_undelivered(conn as usize, delivered);
        for j in 0..coord.assignments().len() {
            let b = before.get(j).copied().unwrap_or(0);
            if coord.assignments()[j].len() > b {
                let extra: Vec<usize> = coord.assignments()[j][b..].to_vec();
                let id = coord.members[j].id as u64;
                self.dispatch(pend, round, seq, id, &extra, tokens);
            }
        }
    }

    /// The one TCP round event loop, shared by the phased and pipelined
    /// paths. With `sink = None` every member's nodes accumulate in its
    /// `Pend` and come back as one flat vec (the phased contract); with a
    /// sink, a member's accumulated nodes drain into it the moment the
    /// member's last outstanding shard lands, and its `delivered` mark
    /// advances so a later disconnect requeues only the unmerged suffix.
    fn round_loop(
        &mut self,
        coord: &mut RoundCoordinator,
        tokens: &[HostTensor],
        mut sink: Option<&mut dyn FnMut(Vec<Node<GradNode>>)>,
    ) -> Result<(Vec<Node<GradNode>>, f64)> {
        let _sp = trace::span(
            "round",
            if sink.is_some() { "tcp_execute_round_eager" } else { "tcp_execute_round" },
        );
        let t0 = Timer::start();
        let round = coord.round;
        let mut seq = 0u64;
        let mut pend: HashMap<u64, Pend> = HashMap::new();
        let initial: Vec<(u64, Vec<usize>)> = coord
            .members
            .iter()
            .enumerate()
            .filter(|(i, m)| m.alive && !coord.assignments()[*i].is_empty())
            .map(|(i, m)| (m.id as u64, coord.assignments()[i].clone()))
            .collect();
        for (id, indices) in &initial {
            self.dispatch(&mut pend, round, &mut seq, *id, indices, tokens);
        }
        let deadline = Instant::now() + Duration::from_secs_f64(self.cfg.round_timeout_s);
        while !coord.all_done() {
            if Instant::now() >= deadline {
                bail!(
                    "transport: round {round} timed out after {:.0}s ({} alive)",
                    self.cfg.round_timeout_s,
                    coord.alive()
                );
            }
            let ev = {
                let _sp = trace::span("wire", "tick_wait");
                self.next_event(deadline)
            };
            let Some(ev) = ev else { continue };
            match ev {
                Event::Hello { conn, stream, proto, run_id } => {
                    self.admit(coord, conn, stream, proto, &run_id);
                }
                Event::Closed { conn } => {
                    self.handle_disconnect(coord, &mut pend, round, &mut seq, conn, tokens);
                }
                Event::Frame { conn, frame } => {
                    if let Frame::ShardDone { round: r, secs, nodes, .. } = frame {
                        if r != round {
                            continue; // stale: a previous round's straggler
                        }
                        let Some(p) = pend.get_mut(&conn) else { continue };
                        if p.outstanding == 0 {
                            continue; // duplicate
                        }
                        p.outstanding -= 1;
                        p.secs += secs;
                        p.nodes.extend(nodes);
                        if p.outstanding == 0 {
                            if let Some(i) = coord
                                .members
                                .iter()
                                .position(|m| m.id as u64 == conn && m.alive)
                            {
                                coord.complete(i, p.secs);
                                if let Some(sink) = sink.as_deref_mut() {
                                    p.delivered = coord.assignments()[i].len();
                                    let drained = std::mem::take(&mut p.nodes);
                                    let spans: Vec<(usize, usize)> =
                                        drained.iter().map(|n| (n.lo, n.len)).collect();
                                    coord.deliver_segments(&spans);
                                    sink(drained);
                                }
                            }
                        }
                    }
                }
            }
        }
        let grad_secs = t0.secs();
        let mut nodes = Vec::new();
        for p in pend.into_values() {
            nodes.extend(p.nodes);
        }
        Ok((nodes, grad_secs))
    }
}

impl Transport for TcpCoordinator {
    /// Wall-clock tick loop: absorb joins/departures between ticks until
    /// the machine reaches an unarmed `RoundTrain`, bailing after
    /// `join_timeout_s` if membership never satisfies `min_workers`.
    fn advance_to_train(&mut self, coord: &mut RoundCoordinator) -> Result<()> {
        let _sp = trace::span("round", "advance_to_train");
        let tick = Duration::from_millis(self.cfg.tick_ms.max(1));
        let deadline = Instant::now() + Duration::from_secs_f64(self.cfg.join_timeout_s);
        let mut next = Instant::now();
        loop {
            while let Some(ev) = self.next_event(next) {
                self.handle_idle_event(coord, ev);
                if Instant::now() >= next {
                    break;
                }
            }
            coord.tick();
            if coord.phase == Phase::RoundTrain && !coord.mid_round() {
                return Ok(());
            }
            if Instant::now() >= deadline {
                bail!(
                    "transport: timed out after {:.0}s waiting for {} member(s) \
                     (phase {:?}, {} alive)",
                    self.cfg.join_timeout_s,
                    coord.cfg.min_workers,
                    coord.phase,
                    coord.alive()
                );
            }
            next += tick;
        }
    }

    /// Dispatch every member's shard over its connection and collect
    /// `ShardDone` nodes until the round machine reports all shards done.
    /// Joins are admitted mid-round (no shard until next round);
    /// disconnects go through [`Self::handle_disconnect`].
    fn execute_round(
        &mut self,
        coord: &mut RoundCoordinator,
        _src: &dyn GradSource,
        tokens: &[HostTensor],
    ) -> Result<(Vec<Node<GradNode>>, f64)> {
        self.round_loop(coord, tokens, None)
    }

    /// Same event loop, but each member's accumulated nodes drain into
    /// `sink` at the instant its `outstanding` count hits zero — upper
    /// tree levels merge on the coordinator thread while remote shards
    /// are still executing.
    fn execute_round_eager(
        &mut self,
        coord: &mut RoundCoordinator,
        _src: &dyn GradSource,
        tokens: &[HostTensor],
        sink: &mut dyn FnMut(Vec<Node<GradNode>>),
    ) -> Result<f64> {
        let (nodes, grad_secs) = self.round_loop(coord, tokens, Some(sink))?;
        debug_assert!(nodes.is_empty(), "eager round left undelivered nodes");
        Ok(grad_secs)
    }

    fn publish_state(&mut self, step: u64, snap: &[f32], blob: &[u8]) -> Result<()> {
        let buf = enc_state(step, snap, blob);
        let dead: Vec<u64> = self
            .conns
            .iter_mut()
            .filter_map(|(&id, s)| send_frame(s, &buf).is_err().then_some(id))
            .collect();
        for id in dead {
            self.conns.remove(&id);
            self.queued.push_back(Event::Closed { conn: id });
        }
        self.state = Some((step, snap.to_vec(), blob.to_vec()));
        Ok(())
    }

    fn wants_state(&self) -> bool {
        true
    }

    /// Broadcast the round-end witness to every live connection. A dead
    /// connection is queued as `Closed` (same pattern as
    /// `publish_state`) so the next round's event pump runs the usual
    /// departure arithmetic.
    fn publish_witness(&mut self, w: &WitnessReport) -> Result<()> {
        let buf = enc_witness(w);
        let dead: Vec<u64> = self
            .conns
            .iter_mut()
            .filter_map(|(&id, s)| send_frame(s, &buf).is_err().then_some(id))
            .collect();
        for id in dead {
            self.conns.remove(&id);
            self.queued.push_back(Event::Closed { conn: id });
        }
        Ok(())
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let done = enc_done();
        for s in self.conns.values_mut() {
            let _ = send_frame(s, &done);
            let _ = s.shutdown(std::net::Shutdown::Both);
        }
        self.conns.clear();
        // wake the blocking accept() so its thread can observe `stop`
        let mut wake = self.addr;
        if wake.ip().is_unspecified() {
            wake.set_ip(IpAddr::V4(Ipv4Addr::LOCALHOST));
        }
        let _ = TcpStream::connect(wake);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for TcpCoordinator {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Per-connection reader: handshake first, then frames, then a `Closed`
/// event on EOF or any wire error — the coordinator treats the three
/// failure modes (crash, network drop, protocol garbage) identically.
/// Crate-visible: the serving plane's accept loop spawns the same reader.
pub(crate) fn reader_loop(conn: u64, mut stream: TcpStream, tx: Sender<Event>) {
    let _ = stream.set_nodelay(true);
    match read_frame(&mut stream) {
        Ok(Some(Frame::Hello { proto, run_id })) => {
            let Ok(wr) = stream.try_clone() else {
                let _ = tx.send(Event::Closed { conn });
                return;
            };
            if tx.send(Event::Hello { conn, stream: wr, proto, run_id }).is_err() {
                return;
            }
        }
        _ => {
            let _ = tx.send(Event::Closed { conn });
            return;
        }
    }
    loop {
        match read_frame(&mut stream) {
            Ok(Some(frame)) => {
                if tx.send(Event::Frame { conn, frame }).is_err() {
                    return;
                }
                // reader threads outlive rounds but not the process;
                // hand rx spans to the sink promptly so a drain on the
                // coordinator thread misses nothing
                trace::flush_thread();
            }
            Ok(None) | Err(_) => {
                let _ = tx.send(Event::Closed { conn });
                return;
            }
        }
    }
}

// -------------------------------------------------------------- TCP worker ---

/// Client-side configuration for [`run_worker`].
#[derive(Debug, Clone)]
pub struct WorkerCfg {
    /// Coordinator address, e.g. `127.0.0.1:7171`.
    pub connect: String,
    /// Must match the coordinator's `WireCfg::run_id`.
    pub run_id: String,
    /// Chaos hook: vanish (drop the connection without a `ShardDone`)
    /// after executing this many microbatches across the whole run — the
    /// mid-round-disconnect tests use it to stand in for a crash.
    pub fail_after_micro: Option<usize>,
    /// Where to append one JSON line per received `Witness` frame
    /// (`dist-demo` workers point this at `runs/witness.jsonl`). `None`
    /// keeps witnesses in-memory only (`WorkerReport::witnesses`).
    pub witness_path: Option<std::path::PathBuf>,
}

/// What a worker saw during its run (returned for tests / logging).
#[derive(Debug, Default)]
pub struct WorkerReport {
    pub member: u64,
    /// Shard messages fully executed.
    pub shards: usize,
    /// Microbatch gradients computed.
    pub micro: usize,
    /// Last `State` broadcast received: (step, round snapshot, blob) —
    /// a late joiner uses this to catch up before its first round.
    pub joined_state: Option<(u64, Vec<f32>, Vec<u8>)>,
    /// Every round-end `Witness` broadcast, in arrival order — the
    /// worker's view of the coordinator's health ledger.
    pub witnesses: Vec<WitnessReport>,
}

/// Worker main loop: handshake, then execute shard messages until the
/// coordinator says `Done` (or goes away). Each shard message feeds its
/// own `TreeAccum` in sorted index order, so the returned nodes are the
/// same maximal aligned subtrees a loopback worker would build.
pub fn run_worker(cfg: &WorkerCfg, src: &dyn GradSource) -> Result<WorkerReport> {
    let mut stream = TcpStream::connect(&cfg.connect)
        .with_context(|| format!("connecting to {}", cfg.connect))?;
    let _ = stream.set_nodelay(true);
    send_frame(&mut stream, &enc_hello(&cfg.run_id))?;
    // Bound the handshake: if the coordinator never processes our Hello
    // (e.g. it shut down between accept and admit), fail instead of
    // blocking on a socket nobody will ever write to again.
    let _ = stream.set_read_timeout(Some(std::time::Duration::from_secs(60)));
    let member = match read_frame(&mut stream)? {
        Some(Frame::Welcome { member, .. }) => member,
        Some(Frame::Reject { reason }) => bail!("coordinator rejected join: {reason}"),
        other => bail!("expected Welcome, got {other:?}"),
    };
    let _ = stream.set_read_timeout(None);
    let mut report = WorkerReport { member, ..WorkerReport::default() };
    loop {
        let Some(frame) = read_frame(&mut stream)? else {
            return Ok(report); // coordinator went away at a frame boundary
        };
        match frame {
            Frame::State { step, snap, blob } => {
                report.joined_state = Some((step, snap, blob));
            }
            Frame::Shard { round, seq, mut items } => {
                // requeued suffixes can arrive out of order; the tree
                // accumulator needs strictly increasing indices
                items.sort_unstable_by_key(|&(i, _)| i);
                let t = Timer::start();
                let mut acc = TreeAccum::new();
                for (i, toks) in &items {
                    if let Some(limit) = cfg.fail_after_micro {
                        if report.micro >= limit {
                            return Ok(report); // simulated crash: no ShardDone
                        }
                    }
                    let (loss, grads) = src.micro_grad(*i, toks)?;
                    acc.push(*i, GradNode { loss, grads });
                    report.micro += 1;
                }
                report.shards += 1;
                send_frame(
                    &mut stream,
                    &enc_shard_done(round, seq, t.secs(), &acc.into_nodes()),
                )?;
            }
            Frame::Witness(w) => {
                if let Some(path) = &cfg.witness_path {
                    append_witness_line(path, &w);
                }
                report.witnesses.push(w);
            }
            Frame::Done => return Ok(report),
            _ => {}
        }
    }
}

/// Append one witness JSON line (best-effort: a full disk must not kill
/// the worker loop — telemetry is never load-bearing). Also used by
/// `demo::drive` for the coordinator/loopback-side `witness.jsonl` and
/// by the fig7 bench.
pub fn append_witness_line(path: &std::path::Path, w: &WitnessReport) {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            let _ = std::fs::create_dir_all(dir);
        }
    }
    if let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(path) {
        let _ = writeln!(f, "{}", w.to_json().to_string());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_witness() -> WitnessReport {
        WitnessReport {
            round: 17,
            workers: 3,
            micro: 24,
            requeues: 2,
            stragglers: 1,
            grad_secs: 0.75,
            reduce_secs: 0.0625,
            imbalance: 1.5,
            median_secs: 0.25,
            members: vec![
                WitnessMember { id: 1, alive: true, micro_done: 9, requeued: 0, straggles: 0 },
                WitnessMember { id: 4, alive: false, micro_done: 7, requeued: 2, straggles: 1 },
            ],
        }
    }

    #[test]
    fn frame_codec_roundtrips_every_kind() {
        let cases: Vec<Vec<u8>> = vec![
            enc_hello("prod-run-7"),
            enc_welcome(3, 42),
            enc_reject("wrong run"),
            enc_state(9, &[1.0, 2.5, -0.0], &[7u8, 0, 255]),
            enc_shard(
                2,
                5,
                &[0, 3],
                &[
                    HostTensor::f32(vec![3], vec![1.5, f32::NAN, -0.0]),
                    HostTensor::i32(vec![2], vec![1, 2]),
                    HostTensor::i32(vec![2], vec![3, 4]),
                    HostTensor::i32(vec![2], vec![-5, 997]),
                ],
            ),
            enc_shard_done(
                2,
                5,
                0.125,
                &[Node {
                    lo: (1 << 25) + 1,
                    len: 1,
                    value: GradNode {
                        loss: 3.25,
                        grads: vec![Mat::from_vec(2, 3, vec![0.0, 1.0, -2.0, 3.5, 4.0, 5.0])],
                    },
                }],
            ),
            enc_witness(&sample_witness()),
            enc_request(77, &HostTensor::i32(vec![2, 3], vec![5, 0, -1, 997, 2, 3])),
            enc_response(77, 3.5, 0.0625),
            enc_done(),
        ];
        for buf in cases {
            let mut rd = &buf[..];
            let f = read_frame(&mut rd).unwrap().expect("frame present");
            match f {
                Frame::Hello { proto, run_id } => {
                    assert_eq!(proto, PROTO_VERSION);
                    assert_eq!(run_id, "prod-run-7");
                }
                Frame::Welcome { member, round } => {
                    assert_eq!((member, round), (3, 42));
                }
                Frame::Reject { reason } => assert_eq!(reason, "wrong run"),
                Frame::State { step, snap, blob } => {
                    assert_eq!(step, 9);
                    assert_eq!(snap[1].to_bits(), 2.5f32.to_bits());
                    assert_eq!(snap[2].to_bits(), (-0.0f32).to_bits());
                    assert_eq!(blob, vec![7u8, 0, 255]);
                }
                Frame::Shard { round, seq, items } => {
                    assert_eq!((round, seq), (2, 5));
                    assert_eq!(items.len(), 2);
                    assert_eq!(items[0].0, 0);
                    // f32 payload survives bit-exactly, NaN and -0.0 included
                    let d = items[0].1.as_f32().unwrap();
                    assert_eq!(d[1].to_bits(), f32::NAN.to_bits());
                    assert_eq!(d[2].to_bits(), (-0.0f32).to_bits());
                    assert_eq!(items[1].0, 3);
                    assert_eq!(items[1].1.as_i32().unwrap(), &[-5, 997]);
                    // indices > 2^24 travel as u64 — exactness is pinned on
                    // the ShardDone case below (node lo = 2^25 + 1)
                }
                Frame::ShardDone { round, seq, secs, nodes } => {
                    assert_eq!((round, seq), (2, 5));
                    assert_eq!(secs.to_bits(), 0.125f64.to_bits());
                    assert_eq!(nodes[0].lo, (1 << 25) + 1);
                    assert_eq!(nodes[0].value.grads[0].data[3].to_bits(), 3.5f32.to_bits());
                }
                Frame::Witness(w) => {
                    // f64 health figures and member rows travel bit-exactly
                    assert_eq!(w, sample_witness());
                }
                Frame::Request { id, tokens } => {
                    assert_eq!(id, 77);
                    assert_eq!(tokens.shape(), &[2, 3]);
                    assert_eq!(tokens.as_i32().unwrap(), &[5, 0, -1, 997, 2, 3]);
                }
                Frame::Response { id, score, latency_s } => {
                    assert_eq!(id, 77);
                    assert_eq!(score.to_bits(), 3.5f32.to_bits());
                    assert_eq!(latency_s.to_bits(), 0.0625f64.to_bits());
                }
                Frame::Done => {}
            }
            // the reader consumed the whole buffer (no trailing garbage)
            assert!(rd.is_empty(), "frame left {} unread bytes", rd.len());
        }
    }

    #[test]
    fn read_frame_rejects_garbage_and_reports_clean_eof() {
        // clean EOF at a frame boundary → None
        let mut empty: &[u8] = &[];
        assert!(read_frame(&mut empty).unwrap().is_none());
        // zero / oversized length words are rejected
        let mut zero: &[u8] = &0u32.to_le_bytes();
        assert!(read_frame(&mut zero).is_err());
        let mut huge: &[u8] = &(u32::MAX).to_le_bytes();
        assert!(read_frame(&mut huge).is_err());
        // truncated body is an error, not a silent EOF
        let mut frame = 10u32.to_le_bytes().to_vec();
        frame.push(K_DONE);
        let mut rd = &frame[..];
        assert!(read_frame(&mut rd).is_err());
        // corrupted count inside a valid frame errors before allocating
        let mut w = W::new(K_STATE);
        w.u64(1);
        w.u64(u64::MAX); // claims 2^64 snapshot words
        let buf = w.frame();
        let mut rd = &buf[..];
        assert!(read_frame(&mut rd).is_err());
        // unknown kind
        let unk = W::new(99).frame();
        let mut rd = &unk[..];
        assert!(read_frame(&mut rd).is_err());
    }

    #[test]
    fn tensor_codec_validates_shape_against_payload() {
        let mut w = W::new(K_SHARD);
        w.u64(1); // round
        w.u64(1); // seq
        w.u64(1); // one item
        w.u64(0); // index
        w.u8(0); // f32 tag
        w.u64(1); // rank
        w.u64(5); // dim 5 ...
        w.u64(2); // ... but only 2 elements
        w.f32(1.0);
        w.f32(2.0);
        let buf = w.frame();
        let mut rd = &buf[..];
        assert!(read_frame(&mut rd).is_err());
    }
}
