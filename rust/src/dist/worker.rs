//! Logical data-parallel workers: each owns a disjoint shard of the
//! round's global microbatch stream, executes its gradients serially (in
//! global index order), and hands back maximal aligned reduction subtrees
//! instead of raw per-microbatch gradients (bounded memory — see
//! [`super::reduce`]).
//!
//! Workers are *logical*: [`run_workers`] fans them out as tasks on the
//! persistent `util::pool`, so a pool width ≥ `dp_workers` runs the
//! shards concurrently while width 1 replays them serially with identical
//! bits. What a microbatch gradient *is* comes from a [`GradSource`]:
//! the trainer plugs in the PJRT `grad_step` executable
//! (`Engine::execute` is `&self`, exactly like the eval fan-out),
//! while the parity tests and the fig7 bench plug in
//! [`SyntheticGradSource`] and need no artifacts at all.

use anyhow::Result;

use crate::linalg::Mat;
use crate::runtime::HostTensor;
use crate::util::{pool, trace, Pcg, Timer};

use super::reduce::{GradNode, Node, TreeAccum};

/// Produces one microbatch's (loss, per-parameter gradients).
///
/// Implementations must be pure in `(index, tokens)` — the determinism
/// contract of the whole subsystem rests on a microbatch gradient being
/// independent of which worker executes it, and when.
pub trait GradSource: Sync {
    fn micro_grad(&self, index: usize, tokens: &HostTensor) -> Result<(f32, Vec<Mat>)>;
}

/// One worker's round output: its maximal aligned subtree roots plus
/// execution accounting for the round coordinator's health ledger.
#[derive(Debug)]
pub struct ShardOut {
    pub nodes: Vec<Node<GradNode>>,
    pub micro_done: usize,
    pub secs: f64,
}

/// Execute one worker's shard. `indices` are global microbatch indices
/// into `tokens`; they are sorted first so requeued (out-of-order) work
/// still feeds the tree accumulator in increasing index order.
pub fn run_shard<S: GradSource + ?Sized>(
    src: &S,
    indices: &[usize],
    tokens: &[HostTensor],
) -> Result<ShardOut> {
    let _sp = trace::span("dist", "shard_compute");
    let t = Timer::start();
    let mut order: Vec<usize> = indices.to_vec();
    order.sort_unstable();
    let mut acc = TreeAccum::new();
    for &i in &order {
        let (loss, grads) = src.micro_grad(i, &tokens[i])?;
        acc.push(i, GradNode { loss, grads });
    }
    Ok(ShardOut { nodes: acc.into_nodes(), micro_done: order.len(), secs: t.secs() })
}

/// Fan every worker's shard out across the pool (one task per worker; an
/// empty assignment is a cheap no-op task). Results come back in worker
/// order; each entry is that worker's own `Result`, so a single failing
/// worker is attributable.
pub fn run_workers<S: GradSource + ?Sized>(
    src: &S,
    assignments: &[Vec<usize>],
    tokens: &[HostTensor],
) -> Vec<Result<ShardOut>> {
    pool::map(assignments.len(), |w| run_shard(src, &assignments[w], tokens))
}

/// Pipelined variant of [`run_workers`]: each worker's [`ShardOut`] is
/// handed to `consume` (always on the calling thread) the moment that
/// shard finishes, instead of being collected into a vec behind the
/// slowest shard — the caller merges early results into the eager reduce
/// while later shards are still running. Delivery order is completion
/// order at pool width > 1 and worker order at width ≤ 1; either way the
/// eager sibling closure makes the merged bits order-invariant.
pub fn run_workers_eager<S: GradSource + ?Sized>(
    src: &S,
    assignments: &[Vec<usize>],
    tokens: &[HostTensor],
    consume: impl FnMut(usize, Result<ShardOut>),
) {
    pool::map_consume(
        assignments.len(),
        |w| run_shard(src, &assignments[w], tokens),
        consume,
    );
}

/// Deterministic stand-in for the `grad_step` executable: pseudo-random
/// gradients seeded from the token content and the global microbatch
/// index, plus an optional fixed slab of dense compute (an `n × n`
/// matmul) emulating the per-microbatch cost of a real backward pass.
///
/// Pure in `(index, tokens)` by construction, so it satisfies the
/// [`GradSource`] contract at every worker count and pool width.
pub struct SyntheticGradSource {
    /// Gradient geometry, one `(rows, cols)` per simulated parameter.
    pub shapes: Vec<(usize, usize)>,
    /// Side length of the per-microbatch busywork matmul (0 = none).
    pub work: usize,
}

impl GradSource for SyntheticGradSource {
    fn micro_grad(&self, index: usize, tokens: &HostTensor) -> Result<(f32, Vec<Mat>)> {
        // FNV-1a over the token block: the gradient depends on the data,
        // not just the index, like a real backward pass would
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for &t in tokens.as_i32()? {
            h = (h ^ t as u64).wrapping_mul(0x0100_0000_01b3);
        }
        let mut rng = Pcg::new(h ^ (index as u64).wrapping_mul(0x9e37_79b9), 0xd157);
        let mut cost = 0.0f32;
        if self.work > 0 {
            let n = self.work;
            // serial inner matmul: the busywork stays inside this worker's
            // task, so per-shard cost is a clean function of shard size
            let a = Mat::from_vec(n, n, rng.normal_vec(n * n, 1.0));
            let prod = pool::with_threads(1, || a.matmul(&a));
            cost = std::hint::black_box(prod.data[0]) * 1e-30;
        }
        let loss = 2.0 + rng.f32() + cost;
        let grads = self
            .shapes
            .iter()
            .map(|&(r, c)| Mat::from_vec(r, c, rng.normal_vec(r * c, 0.1)))
            .collect();
        Ok((loss, grads))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::reduce;

    fn tokens(n: usize) -> Vec<HostTensor> {
        (0..n)
            .map(|i| HostTensor::i32(vec![4], vec![i as i32, 7, 3, i as i32 * 2]))
            .collect()
    }

    fn src() -> SyntheticGradSource {
        SyntheticGradSource { shapes: vec![(3, 5), (4, 1)], work: 0 }
    }

    #[test]
    fn synthetic_source_is_pure() {
        let s = src();
        let toks = tokens(3);
        let (l1, g1) = s.micro_grad(2, &toks[2]).unwrap();
        let (l2, g2) = s.micro_grad(2, &toks[2]).unwrap();
        assert_eq!(l1.to_bits(), l2.to_bits());
        assert_eq!(g1[0].data, g2[0].data);
        // different index or tokens → different draw
        let (l3, _) = s.micro_grad(1, &toks[2]).unwrap();
        assert_ne!(l1.to_bits(), l3.to_bits());
    }

    #[test]
    fn shard_execution_sorts_requeued_indices() {
        let s = src();
        let toks = tokens(8);
        // a worker that picked up requeued index 1 after its own [4..8)
        let out = run_shard(&s, &[4, 5, 6, 7, 1], &toks).unwrap();
        assert_eq!(out.micro_done, 5);
        let spans: Vec<(usize, usize)> =
            out.nodes.iter().map(|n| (n.lo, n.len)).collect();
        assert_eq!(spans, vec![(1, 1), (4, 4)]);
    }

    #[test]
    fn eager_fanout_delivers_every_shard_once_and_matches_phased() {
        let s = src();
        let toks = tokens(7);
        let assignments = vec![vec![0, 1, 2], vec![3, 4], vec![5, 6]];
        let phased = {
            let outs = run_workers(&s, &assignments, &toks);
            let nodes: Vec<_> =
                outs.into_iter().flat_map(|o| o.unwrap().nodes).collect();
            reduce::combine(nodes).unwrap()
        };
        let mut seen = vec![false; assignments.len()];
        let mut er = reduce::EagerReduce::new();
        run_workers_eager(&s, &assignments, &toks, |w, out| {
            assert!(!seen[w], "worker {w} delivered twice");
            seen[w] = true;
            er.offer_all(out.unwrap().nodes);
        });
        assert!(seen.iter().all(|&d| d));
        let got = reduce::fold_blocks(er.finish()).unwrap();
        assert_eq!(got.loss.to_bits(), phased.loss.to_bits());
        for (a, b) in got.grads.iter().zip(&phased.grads) {
            assert_eq!(a.data, b.data);
        }
    }

    #[test]
    fn worker_fanout_matches_single_worker_bitwise() {
        let s = src();
        let toks = tokens(6);
        let single = {
            let outs = run_workers(&s, &[(0..6).collect()], &toks);
            let nodes: Vec<_> =
                outs.into_iter().flat_map(|o| o.unwrap().nodes).collect();
            reduce::combine(nodes).unwrap()
        };
        for assignments in [
            vec![vec![0, 1, 2], vec![3, 4, 5]],
            vec![vec![0], vec![1, 2], vec![3], vec![4, 5]],
            vec![vec![0, 1, 2, 3, 4], vec![], vec![5]],
        ] {
            let outs = run_workers(&s, &assignments, &toks);
            let nodes: Vec<_> =
                outs.into_iter().flat_map(|o| o.unwrap().nodes).collect();
            let got = reduce::combine(nodes).unwrap();
            assert_eq!(got.loss.to_bits(), single.loss.to_bits());
            for (a, b) in got.grads.iter().zip(&single.grads) {
                assert_eq!(a.data, b.data);
            }
        }
    }
}
