//! Minimal property-testing harness (no `proptest` in the offline
//! registry — DESIGN.md §Substitutions).
//!
//! `Check::new(name).runs(N).check(gen, prop)` draws N random inputs from
//! `gen`, asserts `prop` on each, and on failure reports the seed that
//! reproduces it plus a crude shrink (retry with scaled-down inputs where
//! the generator supports it via `Gen::size`).

use crate::util::Pcg;

/// Generation context handed to generators: RNG + a size hint that shrinks
/// on failure replay.
pub struct Gen<'a> {
    pub rng: &'a mut Pcg,
    pub size: usize,
}

impl<'a> Gen<'a> {
    /// Uniform usize in [lo, hi] scaled by the current size hint.
    pub fn dim(&mut self, lo: usize, hi: usize) -> usize {
        let hi = hi.min(lo + self.size);
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.f32() * (hi - lo)
    }

    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        self.rng.normal_vec(n, std)
    }
}

pub struct Check {
    name: &'static str,
    runs: usize,
    base_seed: u64,
}

impl Check {
    pub fn new(name: &'static str) -> Self {
        Check { name, runs: 64, base_seed: 0xa11ce }
    }

    pub fn runs(mut self, n: usize) -> Self {
        self.runs = n;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.base_seed = s;
        self
    }

    /// Draw inputs and check the property. `prop` returns Err(message) on
    /// violation; panics with seed + shrink report.
    pub fn check<T>(
        &self,
        gen: impl Fn(&mut Gen) -> T,
        prop: impl Fn(&T) -> Result<(), String>,
    ) {
        for i in 0..self.runs {
            let seed = self.base_seed.wrapping_add(i as u64);
            let mut rng = Pcg::seeded(seed);
            let mut g = Gen { rng: &mut rng, size: 64 };
            let input = gen(&mut g);
            if let Err(msg) = prop(&input) {
                // shrink: replay the same seed at smaller sizes
                let mut smallest: Option<(usize, String)> = None;
                for size in [1usize, 2, 4, 8, 16, 32] {
                    let mut rng = Pcg::seeded(seed);
                    let mut g = Gen { rng: &mut rng, size };
                    let small = gen(&mut g);
                    if let Err(m) = prop(&small) {
                        smallest = Some((size, m));
                        break;
                    }
                }
                match smallest {
                    Some((size, m)) => panic!(
                        "property {:?} failed (seed {seed}): {msg}\n  \
                         shrunk to size {size}: {m}",
                        self.name
                    ),
                    None => panic!(
                        "property {:?} failed (seed {seed}, size 64): {msg}",
                        self.name
                    ),
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        Check::new("abs-nonneg").runs(32).check(
            |g| g.f32_in(-5.0, 5.0),
            |x| {
                if x.abs() >= 0.0 {
                    Ok(())
                } else {
                    Err("negative abs".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_reports_seed() {
        Check::new("always-false").runs(4).check(
            |g| g.dim(1, 10),
            |_| Err("nope".into()),
        );
    }

    #[test]
    fn dims_respect_bounds() {
        Check::new("dim-bounds").runs(100).check(
            |g| (g.dim(3, 40), g.dim(1, 2)),
            |&(a, b)| {
                if (3..=40).contains(&a) && (1..=2).contains(&b) {
                    Ok(())
                } else {
                    Err(format!("out of bounds: {a}, {b}"))
                }
            },
        );
    }
}
