//! L3 coordinator: the training loop and everything it owns — LR schedule,
//! metrics, memory accounting, checkpointing. See `trainer` for the two
//! execution paths (coordinator vs fused).

pub mod checkpoint;
pub mod memory;
pub mod metrics;
pub mod schedule;
pub mod trainer;

pub use checkpoint::Checkpoint;
pub use memory::{estimate, MemoryBreakdown};
pub use metrics::{MetricsLogger, Summary};
pub use schedule::LrSchedule;
pub use trainer::{run, run_with, Trainer};
