//! The training coordinator — the L3 loop that owns parameters, optimizer
//! state, data, the K-interval refresh schedule, and metrics.
//!
//! Two execution paths (DESIGN.md §1):
//!
//! * **Coordinator** (default): the `grad_step` HLO produces per-layer
//!   gradients; native Rust optimizers (`opt::Slot`) update each parameter.
//!   Per-param routing follows the paper's App. F.2 protocol: matrix
//!   params → candidate optimizer, 1-D params → Adam, lm-head → Adam when
//!   `last_layer_adam` ("Ppl*") else the candidate ("Ppl").
//! * **Fused**: one `train_step_<opt>` executable carries params + states
//!   through each step; rust only schedules, feeds batches, and fires
//!   `refresh_<opt>` every K steps.
//!
//! Gradient accumulation doubles as the simulated data-parallel all-reduce:
//! `workers × grad_accum` microbatches are averaged before the update,
//! reproducing the semantics of synchronous DP without multi-process PJRT
//! (unavailable on this CPU testbed — DESIGN.md §Substitutions). With the
//! `[dist]` section enabled (`dp_workers > 1` or `--dist-sim`) that stream
//! is sharded over N logical workers executing concurrently through the
//! round coordinator, and averaged by the order-deterministic tree
//! all-reduce (`crate::dist`) — same semantics, bitwise invariant across
//! worker counts and pool widths, and measured by `benches/fig7_dp_scaling`.

use anyhow::{anyhow, bail, Context, Result};

use crate::config::{ExecPath, RunConfig};
use crate::data::{bucket_spans, CorpusConfig, SyncBatcher};
use crate::dist::{
    self, GradSource, RoundCoordinator, RoundMode, RoundRecord, Transport, TransportKind,
};
use crate::info;
use crate::linalg::Mat;
use crate::obs;
use crate::opt::{build, Slot};
use crate::runtime::{Engine, HostTensor};
use crate::util::json::{num, Json};
use crate::util::timer::Profile;
use crate::util::{pool, trace, Pcg, Timer};

use super::checkpoint::Checkpoint;
use super::metrics::{MetricsLogger, Summary};
use super::schedule::LrSchedule;

/// Per-parameter routing decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Route {
    Candidate,
    Adam,
}

/// Token batches for the *next* pipelined step, drawn during this step's
/// fused optimizer fan-out (`[dist] round = "pipelined"` only), plus the
/// batcher stream position captured *before* the draw. A checkpoint taken
/// while the stash is live records the pre-draw words, so a resumed run
/// re-draws exactly these batches — keeping checkpoints bitwise identical
/// to the phased path, which has not drawn them yet.
struct Prefetch {
    tokens: Vec<HostTensor>,
    pre_words: (u64, u64),
}

pub struct Trainer {
    pub engine: Engine,
    pub cfg: RunConfig,
    /// Flat parameter list in manifest order.
    pub params: Vec<HostTensor>,
    /// Optimizer slot per parameter (coordinator path).
    slots: Vec<Slot>,
    routes: Vec<Route>,
    /// Fused-path optimizer state tensors (manifest order).
    fused_state: Vec<HostTensor>,
    batcher: SyncBatcher,
    eval_seed: u64,
    pub step: u64,
    pub profile: Profile,
    rng: Pcg,
    /// Fig. 6 instrumentation: (step, param, per-index cos) per refresh.
    pub cos_log: Vec<(u64, String, Vec<f32>)>,
    /// Round coordinator of the simulated DP cluster (None = serial
    /// microbatch loop; `RunConfig.dist` decides).
    dist: Option<RoundCoordinator>,
    /// How rounds execute: in-process loopback (default) or the TCP
    /// coordinator serving remote workers (`[dist] transport = "tcp"`).
    transport: Box<dyn Transport>,
    /// Next step's token batches, pre-drawn inside the pipelined fan-out.
    prefetch: Option<Prefetch>,
}

impl Trainer {
    pub fn new(cfg: RunConfig) -> Result<Self> {
        let engine = Engine::new(&cfg.artifacts)
            .with_context(|| format!("loading artifacts from {}", cfg.artifacts))?;
        Self::with_engine(engine, cfg)
    }

    pub fn with_engine(engine: Engine, cfg: RunConfig) -> Result<Self> {
        // Parallel execution backend width (0 = all cores, 1 = serial).
        // The knob is process-global by design (README §Threading model):
        // the last-constructed trainer wins. Callers needing isolation
        // (tests, side-by-side benches) use pool::with_threads, which is
        // thread-local and takes precedence. Workers are parked threads
        // spawned lazily by the first parallel region; `pool_warmup`
        // moves that spawn cost here, ahead of step 1.
        pool::set_threads(cfg.threads);
        if cfg.pool_warmup {
            pool::warmup();
        }
        let model = engine.manifest.model.clone();
        let mut rng = Pcg::seeded(cfg.seed);

        // -------- parameter init (manifest init_std; own RNG — the init
        // *distribution* matters, not jax's exact draws)
        let mut params = Vec::with_capacity(engine.manifest.params.len());
        for p in &engine.manifest.params {
            let elems: usize = p.shape.iter().product();
            let data = if p.init_std == 0.0 {
                vec![1.0f32; elems] // RMSNorm gains
            } else {
                rng.normal_vec(elems, p.init_std)
            };
            params.push(HostTensor::f32(p.shape.clone(), data));
        }

        // -------- per-param routing + native slots
        // Routing follows the paper's App. F.2 protocol: 1-D params →
        // Adam; lm-head → Adam under `last_layer_adam` (the "Ppl*"/"Mem*"
        // policy, matching `coordinator::memory::estimate`); every other
        // matrix → the candidate. Whether the candidate is a low-rank
        // method comes from the optimizer registry (`Optimizer::low_rank`),
        // not a hard-coded name list — the benches use it to pick the
        // Ppl vs Ppl* protocol per optimizer.
        build(&cfg.optimizer, &cfg.hp)?; // fail fast on unknown names
        let mut routes = Vec::with_capacity(engine.manifest.params.len());
        let mut geoms = Vec::with_capacity(engine.manifest.params.len());
        for p in &engine.manifest.params {
            let is_matrix = p.shape.len() == 2;
            let route = if !is_matrix || (p.name == "lm_head" && cfg.last_layer_adam) {
                Route::Adam
            } else {
                Route::Candidate
            };
            let (rows, cols) = if is_matrix {
                (p.shape[0], p.shape[1])
            } else {
                (1, p.shape[0])
            };
            routes.push(route);
            geoms.push((rows, cols));
        }
        // slot construction is independent per parameter (init draws no
        // RNG), so it fans out across the pool
        let slots = pool::map(routes.len(), |i| -> Result<Slot> {
            let (rows, cols) = geoms[i];
            let opt = match routes[i] {
                Route::Adam => build("adam", &cfg.hp)?,
                Route::Candidate => build(&cfg.optimizer, &cfg.hp)?,
            };
            Ok(Slot::new(opt, rows, cols))
        })
        .into_iter()
        .collect::<Result<Vec<_>>>()?;

        // -------- fused-path state init from the manifest
        let fused_state = if cfg.path == ExecPath::Fused {
            let spec = engine.manifest.optimizer(&cfg.optimizer)?;
            spec.states
                .iter()
                .map(|s| Ok(HostTensor::f32(s.shape.clone(), s.init_data()?)))
                .collect::<Result<Vec<_>>>()?
        } else {
            Vec::new()
        };

        let corpus = CorpusConfig {
            vocab: model.vocab,
            mix: cfg.corpus_mix,
            seed: cfg.corpus_seed,
            ..Default::default()
        };
        let batcher = SyncBatcher::new(corpus, model.batch, model.seq, cfg.seed ^ 0x7ea1);

        let dist = if cfg.dist.enabled() {
            if cfg.path == ExecPath::Fused {
                // the fused train_step_<opt> executable carries the whole
                // step; there is no per-microbatch gradient stream to
                // shard, so silently ignoring [dist] would lie to the user
                bail!(
                    "[dist] is only supported on the coordinator path \
                     (got path = \"fused\" with dp_workers = {} / sim = {})",
                    cfg.dist.dp_workers,
                    cfg.dist.sim
                );
            }
            match cfg.dist.transport {
                TransportKind::Loopback => {
                    info!(
                        "dist: simulated data-parallel cluster — {} worker(s), min {}, \
                         deterministic tree all-reduce",
                        cfg.dist.dp_workers.max(1),
                        cfg.dist.round_cfg().min_workers
                    );
                    Some(cfg.dist.coordinator())
                }
                // over the wire the cluster starts empty: members join via
                // the run-id handshake as worker processes connect
                TransportKind::Tcp => Some(cfg.dist.empty_coordinator()),
            }
        } else {
            None
        };
        let transport: Box<dyn Transport> =
            if cfg.dist.enabled() && cfg.dist.transport == TransportKind::Tcp {
                let t = dist::TcpCoordinator::bind(&cfg.dist.listen, cfg.dist.wire_cfg())?;
                info!(
                    "dist: tcp coordinator listening on {} (run-id {:?}, min {} worker(s))",
                    t.local_addr(),
                    cfg.dist.run_id,
                    cfg.dist.round_cfg().min_workers
                );
                Box::new(t)
            } else {
                Box::new(dist::Loopback)
            };

        Ok(Trainer {
            engine,
            eval_seed: cfg.corpus_seed ^ 0xeeee,
            cfg,
            params,
            slots,
            routes,
            fused_state,
            batcher,
            step: 0,
            profile: Profile::new(),
            rng,
            cos_log: Vec::new(),
            dist,
            transport,
            prefetch: None,
        })
    }

    /// Round log of the simulated DP cluster (empty when disabled).
    pub fn round_log(&self) -> &[RoundRecord] {
        self.dist.as_ref().map(|c| c.log.as_slice()).unwrap_or(&[])
    }

    fn model_batch_tokens(&self) -> u64 {
        let m = &self.engine.manifest.model;
        (m.batch * m.seq) as u64
    }

    fn tokens_input(&mut self) -> HostTensor {
        let m = &self.engine.manifest.model;
        let shape = vec![m.batch, m.seq];
        HostTensor::i32(shape, self.batcher.next())
    }

    /// One optimizer step (one or more microbatches). Returns train loss.
    pub fn train_step(&mut self, lr: f32) -> Result<f32> {
        let _sp = trace::region("train", "train_step");
        self.step += 1;
        match self.cfg.path {
            ExecPath::Coordinator => self.step_coordinator(lr),
            ExecPath::Fused => self.step_fused(lr),
        }
    }

    // ------------------------------------------------- coordinator path ---
    fn step_coordinator(&mut self, lr: f32) -> Result<f32> {
        let micro = self.cfg.grad_accum * self.cfg.workers;
        if self.dist.is_some() && self.cfg.dist.round == RoundMode::Pipelined {
            return self.step_pipelined(micro, lr);
        }
        let (loss, grads) = if self.dist.is_some() {
            self.accumulate_dist(micro)?
        } else {
            self.accumulate_serial(micro)?
        };
        self.optimizer_update(&grads, lr)?;
        Ok(loss)
    }

    /// Pipelined round loop (`[dist] round = "pipelined"`): sibling merges
    /// overlap still-running shards ([`dist::run_round_pipelined_via`]),
    /// the per-parameter ragged fold and optimizer update run as one fused
    /// fan-out (a parameter's refresh/step launches the moment its own
    /// gradient is folded), and the *next* step's token batches are drawn
    /// inside the same region — the engine-legal slice of gradient
    /// double-buffering (real `grad_step` gradients depend on the params
    /// this step is updating, so shard compute itself cannot legally start
    /// early; the data phase can). Scheduling-only: losses, weights, RNG
    /// stream, and checkpoints stay bitwise identical to the phased path
    /// (`rust/tests/dist_parity.rs`).
    fn step_pipelined(&mut self, micro: usize, lr: f32) -> Result<f32> {
        let t_data = Timer::start();
        let token_batches: Vec<HostTensor> = match self.prefetch.take() {
            Some(p) => p.tokens,
            None => {
                let _sp = trace::span("train", "data");
                (0..micro).map(|_| self.tokens_input()).collect()
            }
        };
        self.profile.add("data", t_data.secs());
        self.engine.prepare("grad_step")?;
        let mut coord = self.dist.take().expect("dist coordinator present");
        let out = {
            let src = EngineGradSource { engine: &self.engine, params: &self.params };
            dist::run_round_pipelined_via(&mut *self.transport, &mut coord, &src, &token_batches)
        };
        self.dist = Some(coord);
        let round = out?;
        self.profile.add("dp_grad_exec", round.grad_secs);
        self.profile.add("dp_reduce", round.reduce_secs);
        self.profile.add("dp_reduce_overlap", round.reduce_overlap_secs);
        let loss = round.fold_loss();
        self.optimizer_update_pipelined(&round, micro, lr)?;
        Ok(loss)
    }

    /// Serial microbatch loop: the historical accumulation (left fold in
    /// microbatch order), kept as the non-dist baseline.
    fn accumulate_serial(&mut self, micro: usize) -> Result<(f32, Vec<Mat>)> {
        let _sp = trace::span("train", "grad_serial");
        // compile once up front; the loop then uses the shared-reference
        // entry point, keeping exec-stat accounting in `execute` only
        self.engine.prepare("grad_step")?;
        let mut loss_acc = 0.0f32;
        let mut grads: Vec<Mat> = Vec::new();
        for _ in 0..micro {
            let t_data = Timer::start();
            let tokens = self.tokens_input();
            self.profile.add("data", t_data.secs());
            let mut inputs: Vec<&HostTensor> = Vec::with_capacity(1 + self.params.len());
            inputs.push(&tokens);
            inputs.extend(self.params.iter());
            let t0 = Timer::start();
            let outs = self.engine.execute("grad_step", &inputs)?;
            self.profile.add("grad_exec", t0.secs());
            loss_acc += outs[0].scalar()?;
            // all-reduce: average microbatch grads
            for (i, out) in outs.into_iter().skip(1).enumerate() {
                let g = host_to_mat(out)?;
                if grads.len() <= i {
                    grads.push(g);
                } else {
                    grads[i].ema_(1.0, &g, 1.0);
                }
            }
        }
        if micro > 1 {
            for g in &mut grads {
                *g = g.scale(1.0 / micro as f32);
            }
        }
        Ok((loss_acc / micro as f32, grads))
    }

    /// Data-parallel round: shard the same microbatch stream over the
    /// logical DP workers, execute concurrently, tree-reduce. The token
    /// stream is drawn serially up front — identical batcher state to the
    /// serial path — and the reduced bits are invariant across
    /// `dp_workers` and pool widths (`rust/tests/dist_parity.rs`).
    fn accumulate_dist(&mut self, micro: usize) -> Result<(f32, Vec<Mat>)> {
        let t_data = Timer::start();
        let token_batches: Vec<HostTensor> = {
            let _sp = trace::span("train", "data");
            (0..micro).map(|_| self.tokens_input()).collect()
        };
        self.profile.add("data", t_data.secs());
        self.engine.prepare("grad_step")?;
        let mut coord = self.dist.take().expect("dist coordinator present");
        let out = {
            let src = EngineGradSource { engine: &self.engine, params: &self.params };
            dist::run_round_via(&mut *self.transport, &mut coord, &src, &token_batches)
        };
        self.dist = Some(coord);
        let out = out?;
        self.profile.add("dp_grad_exec", out.grad_secs);
        self.profile.add("dp_reduce", out.reduce_secs);
        Ok((out.loss, out.grads))
    }

    /// Refresh + per-layer optimizer update on already-reduced gradients
    /// (shared by the serial and dist paths).
    fn optimizer_update(&mut self, grads: &[Mat], lr: f32) -> Result<()> {
        // refresh schedule (paper Alg. 4 line 5: t == 1 or t mod K == 0).
        // Seeds are drawn on the coordinator thread, in parameter order,
        // for exactly the slots the serial loop refreshed — the RNG stream
        // is identical for every pool width.
        let k = self.cfg.hp.interval.max(1) as u64;
        let do_refresh = self.step == 1 || self.step % k == 0;
        let seeds: Vec<Option<u64>> = (0..self.params.len())
            .map(|i| {
                if do_refresh && self.routes[i] == Route::Candidate {
                    Some(self.rng.next_u64() ^ (i as u64))
                } else {
                    None
                }
            })
            .collect();

        // Per-layer fan-out: each (slot, param, grad) unit is independent,
        // so refresh → step → weight-apply runs across the pool. Nested
        // linalg regions inside a layer share the same pool (persistent
        // workers adopt the caller's width), so a big decomposition no
        // longer serializes under the fan-out; per-layer arithmetic stays
        // bitwise width-invariant for the matmul/elementwise kernels and
        // the decompositions, with only the chunked reductions regrouping
        // additions between width 1 and widths > 1 (README §Threading).
        struct Unit<'a> {
            slot: &'a mut Slot,
            param: &'a mut HostTensor,
            grad: &'a Mat,
        }
        struct LayerOut {
            cos: Option<(String, Vec<f32>)>,
            /// Worker-side phase accounting, merged into the trainer's
            /// profile at region end (`Profile::absorb`) — width-4 and
            /// width-1 runs account the identical phase set.
            prof: Profile,
            err: Option<String>,
        }
        let t0 = Timer::start();
        let _sp = trace::region("train", "opt_update");
        let step = self.step;
        let names = &self.engine.manifest.params;
        let mut units: Vec<Unit> = self
            .slots
            .iter_mut()
            .zip(self.params.iter_mut().zip(grads.iter()))
            .map(|(slot, (param, grad))| Unit { slot, param, grad })
            .collect();
        let outs: Vec<LayerOut> = pool::map_mut(&mut units, |i, u| {
            let _sp = trace::span("opt", "layer");
            let mut cos = None;
            let mut prof = Profile::new();
            if let Some(seed) = seeds[i] {
                let _rsp = trace::span("opt", "refresh");
                let tr = Timer::start();
                u.slot.refresh(u.grad, seed);
                prof.add("opt_refresh_layer", tr.secs());
                if let Some(c) = u.slot.state.vecs.get("diag_cos") {
                    cos = Some((names[i].name.clone(), c.clone()));
                }
            }
            let ts = Timer::start();
            let delta = u.slot.step(u.grad, step);
            let err = match u.param.as_f32_mut() {
                Ok(w) => {
                    for (wi, &di) in w.iter_mut().zip(&delta.data) {
                        *wi -= lr * di;
                    }
                    None
                }
                Err(e) => Some(format!("{e:#}")),
            };
            prof.add("opt_step_layer", ts.secs());
            LayerOut { cos, prof, err }
        });
        drop(units);
        for (i, out) in outs.into_iter().enumerate() {
            if let Some(e) = out.err {
                bail!("updating param {:?}: {e}", names[i].name);
            }
            // per-layer timings (CPU seconds summed over workers) feed the
            // profile next to the fan-out wall clock below
            self.profile.absorb(&out.prof);
            if let Some((name, cos)) = out.cos {
                self.cos_log.push((self.step, name, cos));
            }
        }
        // cost/memory ledger: measured optimizer-state footprint (f32
        // elements × 4). A gauge, so the latest step wins; refreshes that
        // allocate state lazily are reflected as soon as they land.
        obs::STATE_BYTES.set(self.state_elems() * 4);
        self.profile.add("opt_update", t0.secs());
        Ok(())
    }

    /// The pipelined analogue of [`Self::optimizer_update`]: one fused
    /// pool region whose task `i` folds parameter `i`'s mean gradient out
    /// of the round's maximal blocks ([`dist::EagerRound::fold_param`] —
    /// the identical additions in the identical grouping as the phased
    /// monolithic fold), then refreshes/steps/applies it, so early
    /// parameters' optimizer math runs while later parameters are still
    /// folding. One extra task pre-draws the next step's token batches
    /// (the batcher is touched by that task alone, so the draw sequence
    /// matches the serial data phase exactly). Refresh seeds are pre-drawn
    /// serially on this thread in parameter order — the identical RNG
    /// stream to the phased path at every pool width.
    fn optimizer_update_pipelined(
        &mut self,
        round: &dist::EagerRound,
        micro: usize,
        lr: f32,
    ) -> Result<()> {
        let k = self.cfg.hp.interval.max(1) as u64;
        let do_refresh = self.step == 1 || self.step % k == 0;
        let seeds: Vec<Option<u64>> = (0..self.params.len())
            .map(|i| {
                if do_refresh && self.routes[i] == Route::Candidate {
                    Some(self.rng.next_u64() ^ (i as u64))
                } else {
                    None
                }
            })
            .collect();

        struct LayerOut {
            cos: Option<(String, Vec<f32>)>,
            prof: Profile,
            err: Option<String>,
            /// Seconds from the region epoch to the end of this
            /// parameter's fold — when its optimizer work launched.
            fold_end: f64,
            opt_secs: f64,
        }
        enum FanOut {
            Layer(LayerOut),
            Tokens(Vec<HostTensor>),
        }

        let t0 = Timer::start();
        let _sp = trace::region("train", "opt_update_pipelined");
        let np = self.params.len();
        let step = self.step;
        let names = &self.engine.manifest.params;
        let model = self.engine.manifest.model.clone();
        // the stash must carry the *pre-draw* stream position: a
        // checkpoint taken while it is live restores to re-draw these
        // exact batches (bitwise parity with phased checkpoints)
        let pre_words = self.batcher.rng_words();
        // Disjoint-index raw pointers for the region: task i < np owns
        // slots[i]/params[i] exclusively, task np owns the batcher, and
        // the region retires before any of these fields are touched again.
        let slots_ptr = pool::SendPtr(self.slots.as_mut_ptr());
        let params_ptr = pool::SendPtr(self.params.as_mut_ptr());
        let batcher_ptr = pool::SendPtr(&mut self.batcher as *mut SyncBatcher);
        let epoch = Timer::start();
        let mut outs: Vec<Option<LayerOut>> = (0..np).map(|_| None).collect();
        let mut fetched: Option<Vec<HostTensor>> = None;
        pool::map_consume(
            np + 1,
            |i| {
                if i == np {
                    let _sp = trace::span("train", "data_prefetch");
                    // SAFETY: the only task of this region touching the
                    // batcher; the pointee outlives the region.
                    let batcher = unsafe { &mut *batcher_ptr.0 };
                    let toks = (0..micro)
                        .map(|_| {
                            HostTensor::i32(vec![model.batch, model.seq], batcher.next())
                        })
                        .collect();
                    return FanOut::Tokens(toks);
                }
                let _sp = trace::span("opt", "layer");
                let mut prof = Profile::new();
                let tf = Timer::start();
                let grad = round.fold_param(i);
                prof.add("opt_fold_layer", tf.secs());
                let fold_end = epoch.secs();
                let t_opt = Timer::start();
                // SAFETY: the region hands each index to exactly one task,
                // so these are the only live references to slots[i] /
                // params[i]; i < np = both lengths.
                let slot = unsafe { &mut *slots_ptr.0.add(i) };
                let param = unsafe { &mut *params_ptr.0.add(i) };
                let mut cos = None;
                if let Some(seed) = seeds[i] {
                    let _rsp = trace::span("opt", "refresh");
                    let tr = Timer::start();
                    slot.refresh(&grad, seed);
                    prof.add("opt_refresh_layer", tr.secs());
                    if let Some(c) = slot.state.vecs.get("diag_cos") {
                        cos = Some((names[i].name.clone(), c.clone()));
                    }
                }
                let ts = Timer::start();
                let delta = slot.step(&grad, step);
                let err = match param.as_f32_mut() {
                    Ok(w) => {
                        for (wi, &di) in w.iter_mut().zip(&delta.data) {
                            *wi -= lr * di;
                        }
                        None
                    }
                    Err(e) => Some(format!("{e:#}")),
                };
                prof.add("opt_step_layer", ts.secs());
                FanOut::Layer(LayerOut { cos, prof, err, fold_end, opt_secs: t_opt.secs() })
            },
            |i, out| match out {
                FanOut::Layer(l) => outs[i] = Some(l),
                FanOut::Tokens(toks) => fetched = Some(toks),
            },
        );
        let outs: Vec<LayerOut> =
            outs.into_iter().map(|o| o.expect("fused opt task not executed")).collect();
        // overlap ledger: optimizer seconds that ran while at least one
        // other parameter was still folding — the latency the fused
        // fan-out hid (0 when everything serialized, e.g. width 1)
        let last_fold = outs.iter().fold(0.0f64, |m, o| m.max(o.fold_end));
        let opt_overlap: f64 = outs
            .iter()
            .map(|o| o.opt_secs.min((last_fold - o.fold_end).max(0.0)))
            .sum();
        obs::OPT_OVERLAP_US.add((opt_overlap * 1e6) as u64);
        self.profile.add("opt_overlap", opt_overlap);
        for (i, out) in outs.into_iter().enumerate() {
            if let Some(e) = out.err {
                bail!("updating param {:?}: {e}", names[i].name);
            }
            self.profile.absorb(&out.prof);
            if let Some((name, cos)) = out.cos {
                self.cos_log.push((self.step, name, cos));
            }
        }
        self.prefetch = Some(Prefetch {
            tokens: fetched.expect("prefetch task not executed"),
            pre_words,
        });
        obs::STATE_BYTES.set(self.state_elems() * 4);
        self.profile.add("opt_update", t0.secs());
        Ok(())
    }

    // ------------------------------------------------------- fused path ---
    fn step_fused(&mut self, lr: f32) -> Result<f32> {
        let name = format!("train_step_{}", self.cfg.optimizer);
        let k = self.cfg.hp.interval.max(1) as u64;
        if self.step == 1 || self.step % k == 0 {
            self.refresh_fused()?;
        }
        self.engine.prepare(&name)?;
        let t_data = Timer::start();
        let tokens = self.tokens_input();
        self.profile.add("data", t_data.secs());
        let lr_t = HostTensor::scalar_f32(lr);
        let step_t = HostTensor::scalar_f32(self.step as f32);
        let mut inputs: Vec<&HostTensor> =
            Vec::with_capacity(3 + self.params.len() + self.fused_state.len());
        inputs.push(&tokens);
        inputs.push(&lr_t);
        inputs.push(&step_t);
        inputs.extend(self.params.iter());
        inputs.extend(self.fused_state.iter());
        let t0 = Timer::start();
        let mut outs = self.engine.execute(&name, &inputs)?;
        self.profile.add("fused_exec", t0.secs());
        let loss = outs[0].scalar()?;
        let np = self.params.len();
        let rest = outs.split_off(1 + np);
        self.params = outs.into_iter().skip(1).collect();
        self.fused_state = rest;
        Ok(loss)
    }

    fn refresh_fused(&mut self) -> Result<()> {
        let name = format!("refresh_{}", self.cfg.optimizer);
        if !self.engine.manifest.artifacts.contains_key(&name) {
            return Ok(()); // optimizer without refresh (e.g. adam)
        }
        self.engine.prepare(&name)?;
        let tokens = self.tokens_input();
        let seed = (self.rng.next_u32() & 0x7fff_ffff) as i32;
        let seed_t = HostTensor::scalar_i32(seed);
        let mut inputs: Vec<&HostTensor> =
            Vec::with_capacity(2 + self.params.len() + self.fused_state.len());
        inputs.push(&tokens);
        inputs.push(&seed_t);
        inputs.extend(self.params.iter());
        inputs.extend(self.fused_state.iter());
        let t0 = Timer::start();
        self.fused_state = self.engine.execute(&name, &inputs)?;
        self.profile.add("refresh_exec", t0.secs());
        Ok(())
    }

    // ------------------------------------------------------------- eval ---
    /// Mean loss over `batches` deterministic eval batches (fixed seed →
    /// the same held-out set every call).
    ///
    /// The batch stream is drawn serially (deterministic), then the
    /// batches are *scored* across the pool in bounded [`bucket_spans`]
    /// slices (the same ragged-tail arithmetic the serving batcher uses)
    /// — each task shares the prepared engine read-only, and the losses
    /// combine in batch order, so the mean is identical to the serial
    /// loop at every pool width and any bucket size.
    pub fn eval(&mut self, batches: usize) -> Result<f32> {
        let _sp = trace::region("train", "eval");
        let m = self.engine.manifest.model.clone();
        let corpus = CorpusConfig {
            vocab: m.vocab,
            mix: self.cfg.corpus_mix,
            seed: self.cfg.corpus_seed,
            ..Default::default()
        };
        let mut eval_batcher = SyncBatcher::new(corpus, m.batch, m.seq, self.eval_seed);
        let nb = batches.max(1);
        let t0 = Timer::start();
        let token_batches: Vec<HostTensor> = (0..nb)
            .map(|_| HostTensor::i32(vec![m.batch, m.seq], eval_batcher.next()))
            .collect();
        self.engine.prepare("eval_loss")?;
        let engine = &self.engine;
        let params = &self.params;
        // Bounded fan-out: at most EVAL_BUCKET scorings in flight, however
        // large `batches` is; within a bucket the pool fans out, across
        // buckets the sums append in batch order (bitwise-identical mean).
        const EVAL_BUCKET: usize = 32;
        let mut acc = 0.0f32;
        for (lo, len) in bucket_spans(nb, EVAL_BUCKET) {
            let losses: Vec<Result<f32>> = pool::map(len, |j| {
                let mut inputs: Vec<&HostTensor> = Vec::with_capacity(1 + params.len());
                inputs.push(&token_batches[lo + j]);
                inputs.extend(params.iter());
                let outs = engine.execute("eval_loss", &inputs)?;
                outs[0].scalar()
            });
            for loss in losses {
                acc += loss?;
            }
        }
        self.profile.add("eval", t0.secs());
        Ok(acc / nb as f32)
    }

    // ------------------------------------------------------ checkpoints ---
    /// Snapshot params + optimizer state + step, **plus the RNG/data
    /// stream position** (`trainer.stream`): restoring it makes a resumed
    /// run consume the exact batches and refresh seeds the uninterrupted
    /// run would have, so the loss trajectories match bitwise
    /// (`rust/tests/trainer_e2e.rs`). Per-slot state gathering fans out
    /// over the pool; insertion happens in parameter order.
    pub fn checkpoint(&self) -> Checkpoint {
        use super::checkpoint::u64_to_chunks;

        let mut ck = Checkpoint { step: self.step, ..Default::default() };
        let param_blobs: Vec<Vec<f32>> =
            pool::map(self.params.len(), |i| self.params[i].as_f32().unwrap().to_vec());
        for ((p, spec), blob) in self
            .params
            .iter()
            .zip(&self.engine.manifest.params)
            .zip(param_blobs)
        {
            ck.insert(format!("param.{}", spec.name), p.shape().to_vec(), blob);
        }
        type Entry = (String, Vec<usize>, Vec<f32>);
        let slot_blobs: Vec<Vec<Entry>> = pool::map(self.slots.len(), |i| {
            let slot = &self.slots[i];
            let pname = &self.engine.manifest.params[i].name;
            let mut entries: Vec<Entry> = Vec::new();
            for (k, m) in &slot.state.mats {
                entries.push((
                    format!("state.{pname}.{k}"),
                    vec![m.rows, m.cols],
                    m.data.clone(),
                ));
            }
            for (k, v) in &slot.state.vecs {
                entries.push((format!("state.{pname}.{k}"), vec![v.len()], v.clone()));
            }
            for (k, &s) in &slot.state.scalars {
                entries.push((format!("state.{pname}.{k}"), vec![], vec![s]));
            }
            entries
        });
        for entries in slot_blobs {
            for (name, shape, data) in entries {
                ck.insert(name, shape, data);
            }
        }
        let (rs, ri) = self.rng.state_words();
        // a live prefetch stash means the batcher has already drawn the
        // *next* step's batches — record the captured pre-draw position,
        // so this checkpoint is bit-identical to the phased path's and a
        // resumed run re-draws the stashed batches itself
        let (bs, bi) = match &self.prefetch {
            Some(p) => p.pre_words,
            None => self.batcher.rng_words(),
        };
        let mut stream = Vec::with_capacity(16);
        for w in [rs, ri, bs, bi] {
            stream.extend_from_slice(&u64_to_chunks(w));
        }
        ck.insert("trainer.stream", vec![stream.len()], stream);
        // round state rides next to the stream position, so a resumed DP
        // run continues the same round counter / membership ledger
        if let Some(coord) = &self.dist {
            let snap = coord.snapshot();
            ck.insert("trainer.dist", vec![snap.len()], snap);
        }
        ck
    }

    /// Hand the current checkpoint to the transport for late-joiner
    /// streaming (TCP caches it and replays it to every subsequent join;
    /// loopback ignores it — `wants_state()` is false, so the encode cost
    /// is skipped entirely on the in-process path).
    pub fn publish_state(&mut self, ck: &Checkpoint) -> Result<()> {
        if !self.transport.wants_state() {
            return Ok(());
        }
        let snap = self.dist.as_ref().map(|c| c.snapshot()).unwrap_or_default();
        let blob = ck.encode()?;
        self.transport.publish_state(ck.step, &snap, &blob)
    }

    pub fn restore(&mut self, ck: &Checkpoint) -> Result<()> {
        self.step = ck.step;
        // checkpoints carry the pre-draw stream position (see above), so
        // any stashed prefetch is stale — drop it and re-draw on demand
        self.prefetch = None;
        // Parameters route through the same decoder as the read-only
        // serving loader (`Checkpoint::load_model`) — one shape-checked
        // path, so trainer restore and serve load can't drift.
        self.params = ck.decode_params(&self.engine.manifest.params)?;
        for (i, slot) in self.slots.iter_mut().enumerate() {
            let pname = self.engine.manifest.params[i].name.clone();
            for (k, m) in slot.state.mats.iter_mut() {
                if let Some((_, data)) = ck.tensors.get(&format!("state.{pname}.{k}")) {
                    m.data.copy_from_slice(data);
                }
            }
            for (k, v) in slot.state.vecs.iter_mut() {
                if let Some((_, data)) = ck.tensors.get(&format!("state.{pname}.{k}")) {
                    v.copy_from_slice(data);
                }
            }
            let keys: Vec<&'static str> = slot.state.scalars.keys().copied().collect();
            for k in keys {
                if let Some((_, data)) = ck.tensors.get(&format!("state.{pname}.{k}")) {
                    slot.state.scalars.insert(k, data[0]);
                }
            }
        }
        // RNG/data-stream position (absent in pre-stream checkpoints:
        // those resume with fresh streams — params/state still restore
        // exactly, only batch order differs from the uninterrupted run)
        if let Some((_, data)) = ck.tensors.get("trainer.stream") {
            use super::checkpoint::chunks_to_u64;
            if data.len() == 16 {
                self.rng =
                    Pcg::from_words(chunks_to_u64(&data[0..4]), chunks_to_u64(&data[4..8]));
                self.batcher.set_rng_words((
                    chunks_to_u64(&data[8..12]),
                    chunks_to_u64(&data[12..16]),
                ));
            } else {
                bail!("trainer.stream blob has {} words, expected 16", data.len());
            }
        }
        // round state (present only for DP checkpoints). A non-dist
        // trainer ignores it; a dist trainer missing the blob keeps its
        // fresh coordinator (pre-dist checkpoints stay loadable).
        if let Some((_, data)) = ck.tensors.get("trainer.dist") {
            if self.cfg.dist.enabled() {
                let coord = RoundCoordinator::restore(self.cfg.dist.round_cfg(), data)?;
                // the snapshot's membership would silently override the
                // configured cluster size — same silently-ignored-config
                // class as [dist]+fused, so reject the mismatch instead.
                // Over TCP the roster is wire-dynamic: restored members
                // whose sockets are gone self-heal through the dispatch-
                // failure → Closed → leave() requeue cascade, so the
                // static-cluster check does not apply.
                let want = self.cfg.dist.dp_workers.max(1);
                if self.cfg.dist.transport != TransportKind::Tcp && coord.alive() != want {
                    bail!(
                        "checkpoint restores a {}-worker DP cluster but the \
                         config asks for dp_workers = {want}; resume with the \
                         checkpoint's worker count",
                        coord.alive()
                    );
                }
                self.dist = Some(coord);
            }
        }
        Ok(())
    }

    /// Total optimizer-state elements currently held (Fig. 4 measured
    /// footprint, coordinator path).
    pub fn state_elems(&self) -> u64 {
        self.slots.iter().map(|s| s.state_elems()).sum()
    }

    /// Seed of the deterministic held-out eval stream — exposed so a
    /// serving-side scorer can reconstruct the exact batch sequence
    /// [`Trainer::eval`] consumes (`tests/serve_parity.rs` pins the
    /// serve-vs-eval bitwise equality through it).
    pub fn eval_seed(&self) -> u64 {
        self.eval_seed
    }
}

/// The PJRT-backed [`GradSource`]: one `grad_step` execution per
/// microbatch through the shared-reference engine entry point (the same
/// pattern as the eval fan-out). Pure in `(index, tokens)`: the executable
/// and parameters are fixed for the whole round.
struct EngineGradSource<'a> {
    engine: &'a Engine,
    params: &'a [HostTensor],
}

impl GradSource for EngineGradSource<'_> {
    fn micro_grad(&self, _index: usize, tokens: &HostTensor) -> Result<(f32, Vec<Mat>)> {
        let mut inputs: Vec<&HostTensor> = Vec::with_capacity(1 + self.params.len());
        inputs.push(tokens);
        inputs.extend(self.params.iter());
        let outs = self.engine.execute("grad_step", &inputs)?;
        let mut it = outs.into_iter();
        let loss = it
            .next()
            .ok_or_else(|| anyhow!("grad_step returned no outputs"))?
            .scalar()?;
        let grads = it.map(host_to_mat).collect::<Result<Vec<_>>>()?;
        Ok((loss, grads))
    }
}

fn host_to_mat(t: HostTensor) -> Result<Mat> {
    // consume the tensor: the gradient buffer moves into the Mat with no
    // copy (EXPERIMENTS.md §Perf L3-2)
    let (shape, data) = match t {
        HostTensor::F32 { shape, data } => (shape, data),
        HostTensor::I32 { .. } => bail!("gradient tensor is i32"),
    };
    Ok(match shape.len() {
        2 => Mat::from_vec(shape[0], shape[1], data),
        1 => {
            let n = shape[0];
            Mat::from_vec(1, n, data)
        }
        0 => Mat::from_vec(1, 1, data),
        _ => bail!("unexpected gradient rank {}", shape.len()),
    })
}

/// Run a full configured training job; returns the summary.
pub fn run(cfg: RunConfig) -> Result<Summary> {
    let mut trainer = Trainer::new(cfg.clone())?;
    run_with(&mut trainer)
}

/// Drive an existing trainer through `cfg.steps` with schedule + metrics.
pub fn run_with(trainer: &mut Trainer) -> Result<Summary> {
    let cfg = trainer.cfg.clone();
    let sched = LrSchedule::new(cfg.lr, cfg.steps, cfg.warmup_frac, cfg.min_lr_frac);
    let mut metrics = MetricsLogger::create(&cfg.out_dir)?;
    let batch_tokens =
        trainer.model_batch_tokens() * (cfg.grad_accum * cfg.workers) as u64;
    info!(
        "run: opt={} path={:?} steps={} preset={} ({} params)",
        cfg.optimizer,
        cfg.path,
        cfg.steps,
        trainer.engine.manifest.model.preset,
        trainer.engine.manifest.model.num_params
    );
    for t in 1..=cfg.steps {
        let lr = sched.at(t);
        let loss = trainer.train_step(lr)?;
        let round = trainer.round_log().last().cloned();
        metrics.train_step(t, loss, lr, batch_tokens, round.as_ref())?;
        if t % cfg.log_every.max(1) == 0 || t == 1 {
            info!("step {t:>5}  loss {loss:.4}  lr {lr:.5}");
        }
        if cfg.eval_every > 0 && (t % cfg.eval_every == 0 || t == cfg.steps) {
            let ev = trainer.eval(cfg.eval_batches)?;
            metrics.eval_point(t, ev)?;
            info!("step {t:>5}  eval_loss {ev:.4}  ppl {:.2}", (ev as f64).exp());
        }
        if cfg.ckpt_every > 0 && t % cfg.ckpt_every == 0 {
            let ck = trainer.checkpoint();
            trainer.publish_state(&ck)?;
            ck.save(format!("{}/ckpt_{t}.bin", cfg.out_dir))?;
        }
    }
    let ck = trainer.checkpoint();
    trainer.publish_state(&ck)?;
    ck.save(format!("{}/ckpt_final.bin", cfg.out_dir))?;
    // Fig. 6 data
    if !trainer.cos_log.is_empty() {
        let mut csv = String::from("step,param,index,cos\n");
        for (st, name, cos) in &trainer.cos_log {
            for (i, c) in cos.iter().enumerate() {
                csv.push_str(&format!("{st},{name},{i},{c}\n"));
            }
        }
        std::fs::write(format!("{}/eigen_cos.csv", cfg.out_dir), csv)?;
    }
    let (exec_secs, exec_calls) = trainer.engine.exec_stats();
    info!(
        "done: {:.1}s, {:.0} tok/s; engine: {exec_calls} executions, \
         {exec_secs:.1}s exec+transfer, {:.1}s compile; profile:\n{}",
        metrics.elapsed(),
        metrics.tokens_per_sec(),
        trainer.engine.compile_secs,
        trainer.profile.report()
    );
    // DP round telemetry → summary.json + the Summary round log
    let rounds = trainer.round_log();
    let mut extra: Vec<(&str, Json)> = Vec::new();
    if !rounds.is_empty() {
        extra.push(("dp_rounds", num(rounds.len() as f64)));
        extra.push((
            "dp_requeues",
            num(rounds.iter().map(|r| r.requeues).sum::<u64>() as f64),
        ));
        extra.push((
            "dp_stragglers",
            num(rounds.iter().map(|r| r.stragglers).sum::<u64>() as f64),
        ));
        // per-shard time, not the fan-out wall clock: RoundRecord.grad_secs
        // is the round's slowest *shard*; the wall-clock grad phase is the
        // `dp_grad_exec` profile total (the quantity EXPERIMENTS §fig7 uses)
        extra.push((
            "dp_shard_secs_max",
            num(rounds.iter().map(|r| r.grad_secs).sum::<f64>()),
        ));
        info!(
            "dist: {} round(s), {} requeue(s), {} straggler event(s)",
            rounds.len(),
            rounds.iter().map(|r| r.requeues).sum::<u64>(),
            rounds.iter().map(|r| r.stragglers).sum::<u64>()
        );
    }
    // cost/memory ledger: the optimizer state-bytes gauge plus wire
    // traffic (0/0 for loopback runs) ride along in every summary
    extra.push(("state_bytes", num(obs::STATE_BYTES.get() as f64)));
    // pipelined-round overlap ledger: merge/optimizer microseconds that
    // ran hidden behind still-executing work (0/absent on phased runs)
    let (reduce_ov, opt_ov) = (obs::REDUCE_OVERLAP_US.get(), obs::OPT_OVERLAP_US.get());
    if reduce_ov + opt_ov > 0 {
        extra.push(("dp_reduce_overlap_us", num(reduce_ov as f64)));
        extra.push(("dp_opt_overlap_us", num(opt_ov as f64)));
    }
    let (wire_in, wire_out) = obs::wire_totals();
    if wire_in + wire_out > 0 {
        extra.push(("wire_bytes_in", num(wire_in as f64)));
        extra.push(("wire_bytes_out", num(wire_out as f64)));
    }
    let mut summary = metrics.finish(&cfg.optimizer, extra)?;
    summary.rounds = trainer.round_log().to_vec();
    Ok(summary)
}
