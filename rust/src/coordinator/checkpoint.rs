//! Binary checkpointing of parameters + optimizer state + step counter.
//!
//! Format (little-endian): magic "ARCK" u32-version, then a count-prefixed
//! list of named f32 blobs. Save/restore must round-trip exactly — the
//! resume-equivalence integration test trains 2N steps vs N + resume + N
//! and demands identical parameters.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

const MAGIC: &[u8; 4] = b"ARCK";
const VERSION: u32 = 1;

#[derive(Debug, Default, Clone, PartialEq)]
pub struct Checkpoint {
    pub step: u64,
    /// name → (shape, data)
    pub tensors: BTreeMap<String, (Vec<usize>, Vec<f32>)>,
}

impl Checkpoint {
    pub fn insert(&mut self, name: impl Into<String>, shape: Vec<usize>, data: Vec<f32>) {
        self.tensors.insert(name.into(), (shape, data));
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut w = std::io::BufWriter::new(
            std::fs::File::create(&path)
                .with_context(|| format!("creating {}", path.as_ref().display()))?,
        );
        w.write_all(MAGIC)?;
        w.write_all(&VERSION.to_le_bytes())?;
        w.write_all(&self.step.to_le_bytes())?;
        w.write_all(&(self.tensors.len() as u64).to_le_bytes())?;
        for (name, (shape, data)) in &self.tensors {
            let nb = name.as_bytes();
            w.write_all(&(nb.len() as u32).to_le_bytes())?;
            w.write_all(nb)?;
            w.write_all(&(shape.len() as u32).to_le_bytes())?;
            for &d in shape {
                w.write_all(&(d as u64).to_le_bytes())?;
            }
            w.write_all(&(data.len() as u64).to_le_bytes())?;
            for &x in data {
                w.write_all(&x.to_le_bytes())?;
            }
        }
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let mut r = std::io::BufReader::new(
            std::fs::File::open(&path)
                .with_context(|| format!("opening {}", path.as_ref().display()))?,
        );
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("not a checkpoint file");
        }
        let ver = read_u32(&mut r)?;
        if ver != VERSION {
            bail!("unsupported checkpoint version {ver}");
        }
        let step = read_u64(&mut r)?;
        let count = read_u64(&mut r)? as usize;
        let mut tensors = BTreeMap::new();
        for _ in 0..count {
            let name_len = read_u32(&mut r)? as usize;
            let mut name = vec![0u8; name_len];
            r.read_exact(&mut name)?;
            let ndim = read_u32(&mut r)? as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(read_u64(&mut r)? as usize);
            }
            let len = read_u64(&mut r)? as usize;
            let mut data = Vec::with_capacity(len);
            let mut buf = [0u8; 4];
            for _ in 0..len {
                r.read_exact(&mut buf)?;
                data.push(f32::from_le_bytes(buf));
            }
            tensors.insert(String::from_utf8(name)?, (shape, data));
        }
        Ok(Checkpoint { step, tensors })
    }
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_exact() {
        let mut ck = Checkpoint { step: 42, ..Default::default() };
        ck.insert("w", vec![2, 3], vec![1.0, -2.5, 3.25, 0.0, f32::MIN_POSITIVE, 7.0]);
        ck.insert("state.m", vec![3], vec![0.1, 0.2, 0.3]);
        let path = std::env::temp_dir().join(format!("arck_{}.bin", std::process::id()));
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back, ck);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rejects_garbage() {
        let path = std::env::temp_dir().join(format!("arck_bad_{}.bin", std::process::id()));
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }
}
