//! Binary checkpointing of parameters + optimizer state + step counter.
//!
//! Format (little-endian): magic "ARCK" u32-version, then a count-prefixed
//! list of named f32 blobs. Save/restore must round-trip exactly — the
//! resume-equivalence integration test trains 2N steps vs N + resume + N
//! and demands identical parameters *and* identical losses (the trainer
//! checkpoints its RNG/data-stream position as a `trainer.stream` blob,
//! encoded through [`u64_to_chunks`]).
//!
//! Serialization is off the hot path but not free at lm-head scale, so
//! [`Checkpoint::save`] encodes the per-tensor blobs across `util::pool`
//! and writes them in name order — the file bytes are identical at every
//! pool width (and to the historical serial writer).

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::runtime::{HostTensor, ParamSpec};
use crate::util::pool;

/// Split a u64 into four 16-bit chunks stored as exact small f32 integers
/// (low chunk first). Every chunk is ≤ 65535, well inside f32's exact
/// integer range, so the round trip through the f32 tensor container is
/// lossless on any platform — no NaN-payload games.
pub fn u64_to_chunks(x: u64) -> [f32; 4] {
    std::array::from_fn(|i| ((x >> (16 * i)) & 0xffff) as f32)
}

/// Inverse of [`u64_to_chunks`].
pub fn chunks_to_u64(chunks: &[f32]) -> u64 {
    chunks
        .iter()
        .enumerate()
        .fold(0u64, |acc, (i, &c)| acc | (((c as u64) & 0xffff) << (16 * i)))
}

const MAGIC: &[u8; 4] = b"ARCK";
const VERSION: u32 = 1;

#[derive(Debug, Default, Clone, PartialEq)]
pub struct Checkpoint {
    pub step: u64,
    /// name → (shape, data)
    pub tensors: BTreeMap<String, (Vec<usize>, Vec<f32>)>,
}

impl Checkpoint {
    pub fn insert(&mut self, name: impl Into<String>, shape: Vec<usize>, data: Vec<f32>) {
        self.tensors.insert(name.into(), (shape, data));
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut w = std::io::BufWriter::new(
            std::fs::File::create(&path)
                .with_context(|| format!("creating {}", path.as_ref().display()))?,
        );
        self.encode_into(&mut w)?;
        w.flush()?;
        Ok(())
    }

    /// The checkpoint file bytes, in memory — what the TCP transport
    /// broadcasts to late joiners ([`crate::dist::Transport`]'s `State`
    /// frame). Byte-for-byte what [`Checkpoint::save`] writes.
    pub fn encode(&self) -> Result<Vec<u8>> {
        let mut out = Vec::new();
        self.encode_into(&mut out)?;
        Ok(out)
    }

    fn encode_into(&self, w: &mut impl Write) -> Result<()> {
        w.write_all(MAGIC)?;
        w.write_all(&VERSION.to_le_bytes())?;
        w.write_all(&self.step.to_le_bytes())?;
        w.write_all(&(self.tensors.len() as u64).to_le_bytes())?;
        // Encode tensor blobs across the pool in bounded batches, writing
        // each batch in name order before encoding the next: byte-for-byte
        // the file the serial writer produced, with peak extra memory
        // capped at one batch of blobs instead of the whole checkpoint.
        const SAVE_BATCH: usize = 16;
        let entries: Vec<(&String, &(Vec<usize>, Vec<f32>))> = self.tensors.iter().collect();
        for batch in entries.chunks(SAVE_BATCH) {
            let blobs = pool::map(batch.len(), |i| {
                let (name, (shape, data)) = batch[i];
                encode_entry(name, shape, data)
            });
            for blob in &blobs {
                w.write_all(blob)?;
            }
        }
        Ok(())
    }

    /// Decode the parameter tensors for `specs` (manifest order), shape-
    /// checked against the manifest — the single param decoder behind both
    /// `Trainer::restore` and the read-only serving loader
    /// (`Checkpoint::load_model`), so the trainer and serve paths cannot
    /// drift. Optimizer-state / RNG-stream / dist blobs are never touched.
    pub fn decode_params(&self, specs: &[ParamSpec]) -> Result<Vec<HostTensor>> {
        specs
            .iter()
            .map(|spec| {
                let key = format!("param.{}", spec.name);
                let (shape, data) = self
                    .tensors
                    .get(&key)
                    .ok_or_else(|| anyhow!("checkpoint missing tensor {key:?}"))?;
                if shape != &spec.shape {
                    bail!(
                        "checkpoint shape mismatch for {:?}: file {:?}, manifest {:?}",
                        spec.name,
                        shape,
                        spec.shape
                    );
                }
                Ok(HostTensor::f32(shape.clone(), data.clone()))
            })
            .collect()
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let mut r = std::io::BufReader::new(
            std::fs::File::open(&path)
                .with_context(|| format!("opening {}", path.as_ref().display()))?,
        );
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("not a checkpoint file");
        }
        let ver = read_u32(&mut r)?;
        if ver != VERSION {
            bail!("unsupported checkpoint version {ver}");
        }
        let step = read_u64(&mut r)?;
        let count = read_u64(&mut r)? as usize;
        let mut tensors = BTreeMap::new();
        for _ in 0..count {
            let name_len = read_u32(&mut r)? as usize;
            let mut name = vec![0u8; name_len];
            r.read_exact(&mut name)?;
            let ndim = read_u32(&mut r)? as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(read_u64(&mut r)? as usize);
            }
            let len = read_u64(&mut r)? as usize;
            let mut data = Vec::with_capacity(len);
            let mut buf = [0u8; 4];
            for _ in 0..len {
                r.read_exact(&mut buf)?;
                data.push(f32::from_le_bytes(buf));
            }
            tensors.insert(String::from_utf8(name)?, (shape, data));
        }
        Ok(Checkpoint { step, tensors })
    }
}

/// One named tensor record, exactly as the serial writer laid it out.
fn encode_entry(name: &str, shape: &[usize], data: &[f32]) -> Vec<u8> {
    let mut buf =
        Vec::with_capacity(4 + name.len() + 4 + 8 * shape.len() + 8 + 4 * data.len());
    buf.extend_from_slice(&(name.len() as u32).to_le_bytes());
    buf.extend_from_slice(name.as_bytes());
    buf.extend_from_slice(&(shape.len() as u32).to_le_bytes());
    for &d in shape {
        buf.extend_from_slice(&(d as u64).to_le_bytes());
    }
    buf.extend_from_slice(&(data.len() as u64).to_le_bytes());
    for &x in data {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    buf
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_exact() {
        let mut ck = Checkpoint { step: 42, ..Default::default() };
        ck.insert("w", vec![2, 3], vec![1.0, -2.5, 3.25, 0.0, f32::MIN_POSITIVE, 7.0]);
        ck.insert("state.m", vec![3], vec![0.1, 0.2, 0.3]);
        let path = std::env::temp_dir().join(format!("arck_{}.bin", std::process::id()));
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back, ck);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn encode_matches_save_bytes() {
        let mut ck = Checkpoint { step: 11, ..Default::default() };
        ck.insert("w", vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        ck.insert("state.v", vec![1], vec![-0.5]);
        let path = std::env::temp_dir().join(format!("arck_enc_{}.bin", std::process::id()));
        ck.save(&path).unwrap();
        assert_eq!(ck.encode().unwrap(), std::fs::read(&path).unwrap());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn u64_chunk_codec_roundtrips() {
        for x in [0u64, 1, 0xffff, 0x1_0000, u64::MAX, 0xdead_beef_cafe_f00d] {
            assert_eq!(chunks_to_u64(&u64_to_chunks(x)), x);
        }
    }

    #[test]
    fn save_bytes_identical_at_every_pool_width() {
        let mut ck = Checkpoint { step: 7, ..Default::default() };
        for i in 0..20 {
            ck.insert(format!("t{i}"), vec![i + 1], (0..=i).map(|x| x as f32).collect());
        }
        let dir = std::env::temp_dir();
        let pid = std::process::id();
        let p1 = dir.join(format!("arck_w1_{pid}.bin"));
        let p4 = dir.join(format!("arck_w4_{pid}.bin"));
        crate::util::pool::with_threads(1, || ck.save(&p1).unwrap());
        crate::util::pool::with_threads(4, || ck.save(&p4).unwrap());
        assert_eq!(std::fs::read(&p1).unwrap(), std::fs::read(&p4).unwrap());
        let _ = std::fs::remove_file(&p1);
        let _ = std::fs::remove_file(&p4);
    }

    #[test]
    fn decode_params_shape_checks_and_skips_state() {
        let spec = |name: &str, shape: Vec<usize>| ParamSpec {
            name: name.to_string(),
            shape,
            init_std: 0.0,
        };
        let mut ck = Checkpoint { step: 3, ..Default::default() };
        ck.insert("param.w", vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        ck.insert("param.b", vec![3], vec![0.5, -0.5, 0.25]);
        ck.insert("state.w.m", vec![2, 2], vec![9.0; 4]);
        ck.insert("trainer.stream", vec![16], vec![0.0; 16]);
        let params = ck
            .decode_params(&[spec("w", vec![2, 2]), spec("b", vec![3])])
            .unwrap();
        assert_eq!(params.len(), 2);
        assert_eq!(params[0].shape(), &[2, 2]);
        assert_eq!(params[0].as_f32().unwrap(), &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(params[1].as_f32().unwrap(), &[0.5, -0.5, 0.25]);
        // Missing param and manifest/file shape drift are both hard errors.
        assert!(ck.decode_params(&[spec("missing", vec![1])]).is_err());
        assert!(ck.decode_params(&[spec("w", vec![4])]).is_err());
    }

    #[test]
    fn rejects_garbage() {
        let path = std::env::temp_dir().join(format!("arck_bad_{}.bin", std::process::id()));
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }
}
