//! Analytic optimizer-memory accounting — regenerates Table 1 (state
//! column), Table 3, Table 6, and the Fig. 4 footprint bars.
//!
//! Follows the paper's protocol (Sec. 7.1 / App. F.4): total = weights +
//! Adam states for non-matrix params + candidate-optimizer states for
//! matrix params; "Mem*" additionally routes the lm-head to Adam. BF16 =
//! 2 bytes per element.

use anyhow::Result;

use crate::config::presets::{param_shapes, ModelPreset};
use crate::opt::{build, Hyper};

pub const BYTES_PER_ELEM: u64 = 2; // BF16 (paper App. F.4)

#[derive(Debug, Clone)]
pub struct MemoryBreakdown {
    pub optimizer: String,
    pub weight_bytes: u64,
    pub matrix_state_bytes: u64,
    pub adam_side_bytes: u64,
    pub total_bytes: u64,
}

/// Estimate for one (preset, optimizer, lm-head policy).
pub fn estimate(
    preset: &ModelPreset,
    optimizer: &str,
    hp: &Hyper,
    last_layer_adam: bool,
) -> Result<MemoryBreakdown> {
    let opt = build(optimizer, hp)?;
    let adam = build("adam", hp)?;
    let mut weight_elems: u64 = 0;
    let mut matrix_state: u64 = 0;
    let mut adam_side: u64 = 0;
    for (name, shape) in param_shapes(preset) {
        let elems: u64 = shape.iter().product::<usize>() as u64;
        weight_elems += elems;
        if shape.len() < 2 {
            // non-matrix params → Adam (paper protocol)
            adam_side += 2 * elems;
            continue;
        }
        let (mut r, mut c) = (shape[0], shape[1]);
        if opt.transpose_wide() && r > c {
            std::mem::swap(&mut r, &mut c);
        }
        if name == "lm_head" && last_layer_adam {
            adam_side += adam.state_elems(shape[0], shape[1]);
        } else {
            matrix_state += opt.state_elems(r, c);
        }
    }
    Ok(MemoryBreakdown {
        optimizer: optimizer.to_string(),
        weight_bytes: weight_elems * BYTES_PER_ELEM,
        matrix_state_bytes: matrix_state * BYTES_PER_ELEM,
        adam_side_bytes: adam_side * BYTES_PER_ELEM,
        total_bytes: (weight_elems + matrix_state + adam_side) * BYTES_PER_ELEM,
    })
}

/// The closed-form per-matrix totals of Table 1 (m ≤ n), for the summary
/// row printed by the table1 bench.
pub fn table1_formula(optimizer: &str, m: u64, n: u64, r: u64) -> Option<String> {
    let mn = m * n;
    Some(match optimizer {
        "adam" => format!("3mn = {}", 3 * mn),
        "shampoo" => format!("mn + m² + n² = {}", mn + m * m + n * n),
        "eigen_adam" => format!("3mn + 2m² = {}", 3 * mn + 2 * m * m),
        "soap" => format!("3mn + 2m² + 2n² = {}", 3 * mn + 2 * m * m + 2 * n * n),
        "galore" => format!("mn + 2nr + mr = {}", mn + 2 * n * r + m * r),
        "racs" => format!("mn + m + n + 1 = {}", mn + m + n + 1),
        "alice" => format!(
            "mn + 2nr + mr + n + r² = {}",
            mn + 2 * n * r + m * r + n + r * r
        ),
        "alice0" => format!("mn + 2nr + mr + n = {}", mn + 2 * n * r + m * r + n),
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::preset;

    fn gib(b: u64) -> f64 {
        b as f64 / (1024.0 * 1024.0 * 1024.0)
    }

    #[test]
    fn adam_triples_weight_memory() {
        let p = preset("llama130m").unwrap();
        let hp = Hyper::default();
        let est = estimate(p, "adam", &hp, true).unwrap();
        let ratio = est.total_bytes as f64 / est.weight_bytes as f64;
        assert!((ratio - 3.0).abs() < 0.01, "Adam must 3x memory: {ratio}");
    }

    #[test]
    fn racs_is_sgd_like() {
        let p = preset("llama1b").unwrap();
        let hp = Hyper::default();
        let est = estimate(p, "racs", &hp, true).unwrap();
        // matrix states must be a tiny fraction of the weights
        assert!(
            (est.matrix_state_bytes as f64) < 0.01 * est.weight_bytes as f64
        );
    }

    #[test]
    fn table3_paper_ballpark() {
        // Paper Table 3: Adam Mem* 0.75G @130M, 7.48G @1.3B;
        // RACS 0.43G @130M, 2.98G @1.3B. Architecture arithmetic differs
        // slightly from the authors' — accept ±25%.
        let hp = Hyper { rank: 512, ..Hyper::default() };
        let close = |got: f64, want: f64, tag: &str| {
            assert!(
                (got / want - 1.0).abs() < 0.25,
                "{tag}: got {got:.2}G want {want:.2}G"
            );
        };
        let p130 = preset("llama130m").unwrap();
        close(gib(estimate(p130, "adam", &hp, true).unwrap().total_bytes), 0.75, "adam130");
        close(gib(estimate(p130, "racs", &hp, true).unwrap().total_bytes), 0.43, "racs130");
        let p1b = preset("llama1b").unwrap();
        close(gib(estimate(p1b, "adam", &hp, true).unwrap().total_bytes), 7.48, "adam1b");
        close(gib(estimate(p1b, "racs", &hp, true).unwrap().total_bytes), 2.98, "racs1b");
        close(gib(estimate(p1b, "alice", &hp, true).unwrap().total_bytes), 4.6, "alice1b");
        close(gib(estimate(p1b, "galore", &hp, true).unwrap().total_bytes), 4.43, "galore1b");
    }

    #[test]
    fn ordering_matches_paper() {
        // Adam > Alice > Apollo-mini ≈ RACS for every size
        let hp = Hyper { rank: 256, ..Hyper::default() };
        for name in ["llama60m", "llama130m", "llama350m", "llama1b"] {
            let p = preset(name).unwrap();
            let t = |o: &str| estimate(p, o, &hp, true).unwrap().total_bytes;
            assert!(t("adam") > t("alice"), "{name}");
            assert!(t("alice") > t("racs"), "{name}");
            assert!(t("alice") >= t("alice0"), "{name}");
        }
    }

    #[test]
    fn lm_head_policy_changes_total() {
        let p = preset("llama60m").unwrap();
        let hp = Hyper { rank: 128, ..Hyper::default() };
        let with = estimate(p, "galore", &hp, true).unwrap().total_bytes;
        let without = estimate(p, "galore", &hp, false).unwrap().total_bytes;
        // Adam on the (huge) lm-head costs more than rank-128 GaLore states
        assert!(with > without);
    }

    #[test]
    fn formulas_render() {
        assert!(table1_formula("racs", 512, 2048, 64).unwrap().contains("mn + m + n + 1"));
        assert!(table1_formula("sgd", 1, 1, 1).is_none());
    }
}
