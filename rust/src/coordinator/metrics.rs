//! Run metrics: CSV curves (the Fig. 1/2/5 series), JSONL summaries, and
//! throughput meters (Table 2 TP / effective-TP inputs).

use std::fs::{self, File};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::dist::RoundRecord;
use crate::obs;
use crate::util::json::{num, obj, s, Json};
use crate::util::Timer;

const TRAIN_HEADER: &str =
    "step,loss,lr,tokens,elapsed_s,tokens_per_s,round_secs_median,requeues,wire_bytes";
const EVAL_HEADER: &str = "step,eval_loss,eval_ppl,elapsed_s";

/// Open a CSV for appending; write `header` only when the file is new or
/// empty, so a mid-run `flush` + reopen (crash recovery, long networked
/// runs) never duplicates the header row.
fn open_csv(path: &Path, header: &str) -> Result<BufWriter<File>> {
    let fresh = fs::metadata(path).map(|m| m.len() == 0).unwrap_or(true);
    let f = fs::OpenOptions::new().create(true).append(true).open(path)?;
    let mut w = BufWriter::new(f);
    if fresh {
        writeln!(w, "{header}")?;
    }
    Ok(w)
}

/// Writes train/eval curves and a final summary for one run.
pub struct MetricsLogger {
    dir: PathBuf,
    train_csv: BufWriter<File>,
    eval_csv: BufWriter<File>,
    timer: Timer,
    pub tokens_seen: u64,
    pub last_train_loss: f32,
    pub eval_history: Vec<(usize, f32)>,
}

impl MetricsLogger {
    pub fn create(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        fs::create_dir_all(&dir)
            .with_context(|| format!("creating {}", dir.display()))?;
        let train_csv = open_csv(&dir.join("train.csv"), TRAIN_HEADER)?;
        let eval_csv = open_csv(&dir.join("eval.csv"), EVAL_HEADER)?;
        Ok(MetricsLogger {
            dir,
            train_csv,
            eval_csv,
            timer: Timer::start(),
            tokens_seen: 0,
            last_train_loss: f32::NAN,
            eval_history: Vec::new(),
        })
    }

    /// Log one optimizer step. `round` is the DP round that produced it
    /// (None on the serial path — the witness columns log as zeros);
    /// wire bytes come from the process-wide `obs` counters (0 for
    /// loopback runs, cumulative in+out for TCP).
    pub fn train_step(
        &mut self,
        step: usize,
        loss: f32,
        lr: f32,
        tokens: u64,
        round: Option<&RoundRecord>,
    ) -> Result<()> {
        self.tokens_seen += tokens;
        self.last_train_loss = loss;
        let el = self.timer.secs();
        let tps = self.tokens_seen as f64 / el.max(1e-9);
        let median = round.map(|r| r.median_secs).unwrap_or(0.0);
        let requeues = round.map(|r| r.requeues).unwrap_or(0);
        let (win, wout) = obs::wire_totals();
        writeln!(
            self.train_csv,
            "{step},{loss},{lr},{},{el:.3},{tps:.1},{median},{requeues},{}",
            self.tokens_seen,
            win + wout
        )?;
        Ok(())
    }

    /// Push both curves to disk without closing the logger — callers that
    /// checkpoint mid-run pair this with a later reopen ([`Self::create`]
    /// appends instead of truncating).
    pub fn flush(&mut self) -> Result<()> {
        self.train_csv.flush()?;
        self.eval_csv.flush()?;
        Ok(())
    }

    pub fn eval_point(&mut self, step: usize, eval_loss: f32) -> Result<()> {
        let el = self.timer.secs();
        writeln!(
            self.eval_csv,
            "{step},{eval_loss},{},{el:.3}",
            (eval_loss as f64).exp()
        )?;
        self.eval_history.push((step, eval_loss));
        // Flush both curves at every eval point: a crash, kill, or dropped
        // worker mid-run must not lose the tail of the training trajectory
        // (long networked runs are exactly where this bites).
        self.flush()
    }

    pub fn elapsed(&self) -> f64 {
        self.timer.secs()
    }

    pub fn tokens_per_sec(&self) -> f64 {
        self.tokens_seen as f64 / self.timer.secs().max(1e-9)
    }

    /// Final summary JSON consumed by the bench harness.
    pub fn finish(mut self, optimizer: &str, extra: Vec<(&str, Json)>) -> Result<Summary> {
        self.train_csv.flush()?;
        self.eval_csv.flush()?;
        let final_eval = self.eval_history.last().map(|&(_, l)| l);
        let summary = Summary {
            optimizer: optimizer.to_string(),
            final_eval_loss: final_eval,
            last_train_loss: self.last_train_loss,
            tokens: self.tokens_seen,
            elapsed_s: self.timer.secs(),
            tokens_per_sec: self.tokens_per_sec(),
            eval_history: self.eval_history.clone(),
            rounds: Vec::new(),
        };
        let mut pairs = vec![
            ("optimizer", s(optimizer)),
            ("final_eval_loss", final_eval.map(|l| num(l as f64)).unwrap_or(Json::Null)),
            ("last_train_loss", num(self.last_train_loss as f64)),
            ("tokens", num(self.tokens_seen as f64)),
            ("elapsed_s", num(self.timer.secs())),
            ("tokens_per_sec", num(self.tokens_per_sec())),
            (
                "eval_history",
                Json::Arr(
                    self.eval_history
                        .iter()
                        .map(|&(st, l)| {
                            Json::Arr(vec![num(st as f64), num(l as f64)])
                        })
                        .collect(),
                ),
            ),
        ];
        pairs.extend(extra);
        fs::write(self.dir.join("summary.json"), obj(pairs).to_string())?;
        Ok(summary)
    }
}

/// Parsed result of a finished run (also reconstructable from
/// summary.json — used by the table benches to aggregate runs).
#[derive(Debug, Clone)]
pub struct Summary {
    pub optimizer: String,
    pub final_eval_loss: Option<f32>,
    pub last_train_loss: f32,
    pub tokens: u64,
    pub elapsed_s: f64,
    pub tokens_per_sec: f64,
    pub eval_history: Vec<(usize, f32)>,
    /// Per-round log of the simulated DP cluster (empty for serial runs);
    /// attached by `trainer::run_with` after the CSVs are finalized.
    pub rounds: Vec<RoundRecord>,
}

impl Summary {
    /// First step at which eval loss ≤ target (the paper's speed-up-in-
    /// steps metric, Table 2). None if never reached.
    pub fn steps_to_reach(&self, target: f32) -> Option<usize> {
        self.eval_history
            .iter()
            .find(|&&(_, l)| l <= target)
            .map(|&(s, _)| s)
    }

    /// Effective throughput vs a reference run (Table 2 / App. F.5):
    /// reference tokens ÷ candidate time to reach the reference's final
    /// eval loss. 0.0 when the target is never reached.
    pub fn effective_tokens_per_sec(&self, reference: &Summary) -> f64 {
        let Some(target) = reference.final_eval_loss else {
            return 0.0;
        };
        let Some(step) = self.steps_to_reach(target) else {
            return 0.0;
        };
        let total_steps = self.eval_history.last().map(|&(s, _)| s).unwrap_or(1);
        let frac = step as f64 / total_steps as f64;
        let time_to_target = self.elapsed_s * frac;
        reference.tokens as f64 / time_to_target.max(1e-9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("alice_racs_metrics_{tag}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn writes_csvs_and_summary() {
        let dir = tmpdir("a");
        let mut m = MetricsLogger::create(&dir).unwrap();
        m.train_step(1, 5.0, 0.01, 512, None).unwrap();
        m.train_step(2, 4.5, 0.01, 512, None).unwrap();
        m.eval_point(2, 4.4).unwrap();
        let s = m.finish("adam", vec![]).unwrap();
        assert_eq!(s.tokens, 1024);
        assert_eq!(s.final_eval_loss, Some(4.4));
        let csv = fs::read_to_string(dir.join("train.csv")).unwrap();
        assert!(csv.lines().count() == 3);
        let js = fs::read_to_string(dir.join("summary.json")).unwrap();
        assert!(js.contains("\"optimizer\":\"adam\""));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn eval_point_flushes_curves_to_disk() {
        // the CSVs must be readable right after an eval point — before
        // finish() — so a killed run keeps its trajectory
        let dir = tmpdir("flush");
        let mut m = MetricsLogger::create(&dir).unwrap();
        m.train_step(1, 5.0, 0.01, 512, None).unwrap();
        m.eval_point(1, 4.9).unwrap();
        let train = fs::read_to_string(dir.join("train.csv")).unwrap();
        assert!(train.lines().any(|l| l.starts_with("1,5")), "{train}");
        let eval = fs::read_to_string(dir.join("eval.csv")).unwrap();
        assert!(eval.lines().any(|l| l.starts_with("1,4.9")), "{eval}");
        drop(m);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn witness_columns_follow_the_round_record() {
        let dir = tmpdir("witness");
        let mut m = MetricsLogger::create(&dir).unwrap();
        let r = RoundRecord {
            round: 1,
            workers: 3,
            micro: 6,
            grad_secs: 0.5,
            reduce_secs: 0.01,
            imbalance: 1.2,
            stragglers: 0,
            requeues: 2,
            median_secs: 0.25,
        };
        m.train_step(1, 5.0, 0.01, 512, Some(&r)).unwrap();
        m.flush().unwrap();
        let csv = fs::read_to_string(dir.join("train.csv")).unwrap();
        let row = csv.lines().nth(1).unwrap();
        let cols: Vec<&str> = row.split(',').collect();
        assert_eq!(cols.len(), 9, "{row}");
        assert_eq!(cols[6], "0.25", "round_secs_median column");
        assert_eq!(cols[7], "2", "requeues column");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn reopen_appends_without_duplicate_header() {
        // mid-run flush + reopen: the second logger appends rows, the
        // header appears exactly once, and every line stays parseable
        let dir = tmpdir("reopen");
        let mut m = MetricsLogger::create(&dir).unwrap();
        m.train_step(1, 5.0, 0.01, 512, None).unwrap();
        m.flush().unwrap();
        drop(m);
        let mut m2 = MetricsLogger::create(&dir).unwrap();
        m2.train_step(2, 4.5, 0.01, 512, None).unwrap();
        m2.flush().unwrap();
        drop(m2);
        let csv = fs::read_to_string(dir.join("train.csv")).unwrap();
        let headers = csv.lines().filter(|l| l.starts_with("step,")).count();
        assert_eq!(headers, 1, "header must not duplicate:\n{csv}");
        let n_cols = csv.lines().next().unwrap().split(',').count();
        for line in csv.lines().skip(1) {
            assert_eq!(line.split(',').count(), n_cols, "ragged row {line:?}");
        }
        assert!(csv.lines().any(|l| l.starts_with("1,")));
        assert!(csv.lines().any(|l| l.starts_with("2,")));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn steps_to_reach_finds_crossing() {
        let s = Summary {
            optimizer: "x".into(),
            final_eval_loss: Some(3.0),
            last_train_loss: 3.0,
            tokens: 1000,
            elapsed_s: 10.0,
            tokens_per_sec: 100.0,
            eval_history: vec![(10, 5.0), (20, 4.0), (30, 3.0)],
            rounds: Vec::new(),
        };
        assert_eq!(s.steps_to_reach(4.0), Some(20));
        assert_eq!(s.steps_to_reach(2.0), None);
    }

    #[test]
    fn effective_tp_rewards_fast_convergence() {
        let slow = Summary {
            optimizer: "adam".into(),
            final_eval_loss: Some(4.0),
            last_train_loss: 4.0,
            tokens: 10_000,
            elapsed_s: 100.0,
            tokens_per_sec: 100.0,
            eval_history: vec![(50, 4.5), (100, 4.0)],
            rounds: Vec::new(),
        };
        let fast = Summary {
            optimizer: "alice".into(),
            final_eval_loss: Some(3.5),
            last_train_loss: 3.5,
            tokens: 10_000,
            elapsed_s: 100.0,
            tokens_per_sec: 100.0,
            eval_history: vec![(50, 4.0), (100, 3.5)],
            rounds: Vec::new(),
        };
        // fast reaches 4.0 at half its run → effective TP = 10000/50 = 200
        let etp = fast.effective_tokens_per_sec(&slow);
        assert!((etp - 200.0).abs() < 1.0, "{etp}");
        // the reference against itself = its own TP
        let self_etp = slow.effective_tokens_per_sec(&slow);
        assert!((self_etp - 100.0).abs() < 1.0);
    }
}
