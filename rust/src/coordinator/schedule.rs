//! Learning-rate schedule: linear warmup over the first `warmup_frac` of
//! training, then cosine decay to `min_lr_frac · lr` (paper App. F.2:
//! "first 10% warm-up, cosine decay to 10% of the original LR").

#[derive(Debug, Clone)]
pub struct LrSchedule {
    pub base: f32,
    pub total_steps: usize,
    pub warmup_steps: usize,
    pub min_frac: f32,
}

impl LrSchedule {
    pub fn new(base: f32, total_steps: usize, warmup_frac: f32, min_frac: f32) -> Self {
        let warmup_steps = ((total_steps as f32) * warmup_frac).round() as usize;
        LrSchedule { base, total_steps: total_steps.max(1), warmup_steps, min_frac }
    }

    /// LR at 1-based step `t`.
    pub fn at(&self, t: usize) -> f32 {
        let t = t.min(self.total_steps);
        if self.warmup_steps > 0 && t <= self.warmup_steps {
            return self.base * t as f32 / self.warmup_steps as f32;
        }
        let span = (self.total_steps - self.warmup_steps).max(1) as f32;
        let progress = (t - self.warmup_steps) as f32 / span;
        let cos = 0.5 * (1.0 + (std::f32::consts::PI * progress).cos());
        let floor = self.base * self.min_frac;
        floor + (self.base - floor) * cos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_ramps_linearly() {
        let s = LrSchedule::new(0.02, 100, 0.1, 0.1);
        assert!((s.at(5) - 0.01).abs() < 1e-6);
        assert!((s.at(10) - 0.02).abs() < 1e-6);
    }

    #[test]
    fn cosine_decays_to_floor() {
        let s = LrSchedule::new(0.02, 100, 0.1, 0.1);
        assert!((s.at(100) - 0.002).abs() < 1e-5);
        // midpoint between peak and floor
        let mid = s.at(55);
        assert!(mid < 0.02 && mid > 0.002);
    }

    #[test]
    fn monotone_after_warmup() {
        let s = LrSchedule::new(0.01, 200, 0.05, 0.1);
        let mut prev = f32::MAX;
        for t in 11..=200 {
            let lr = s.at(t);
            assert!(lr <= prev + 1e-9, "non-monotone at {t}");
            prev = lr;
        }
    }

    #[test]
    fn zero_warmup_ok() {
        let s = LrSchedule::new(0.01, 50, 0.0, 0.5);
        assert!((s.at(1) - 0.01).abs() < 1e-3);
    }
}
