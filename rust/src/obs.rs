//! Process-wide counter/gauge registry — the run's cost ledger.
//!
//! Companion to the span tracer ([`util::trace`](crate::util::trace)):
//! spans tell you *where time goes*, these counters tell you *how much
//! work happened* — wire bytes in/out per frame kind, shard requeues,
//! sketch-vs-anchor refresh decisions, Jacobi eigensweeps actually
//! consumed (vs the budget), pool region dispatches, and a gauge for
//! the resident optimizer state in bytes (derived from the existing
//! `state_elems` accounting, × 4 bytes/f32). Counters are always on:
//! one relaxed `fetch_add` per increment, at call sites that are never
//! inner loops (per frame, per requeue, per sweep, per region). The
//! trainer summary and the witness/metrics columns read them via
//! [`wire_totals`]/[`snapshot`].
//!
//! Counters are observational only — nothing reads them back into
//! control flow, so they can never perturb numerics (same contract as
//! tracing; pinned by the parity suites).

use std::sync::atomic::{AtomicU64, Ordering};

/// A named monotonic counter (or gauge, via [`Counter::set`]).
pub struct Counter {
    name: &'static str,
    v: AtomicU64,
}

impl Counter {
    pub const fn new(name: &'static str) -> Self {
        Counter { name, v: AtomicU64::new(0) }
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn incr(&self) {
        self.add(1);
    }

    /// Gauge-style overwrite (used by the state-bytes gauge).
    pub fn set(&self, v: u64) {
        self.v.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    pub fn reset(&self) {
        self.set(0);
    }
}

/// Shards put back on the queue after a member died mid-round.
pub static REQUEUES: Counter = Counter::new("dist.requeues");
/// Subspace refreshes served by the randomized sketch path.
pub static REFRESH_SKETCH: Counter = Counter::new("opt.refresh_sketch");
/// Subspace refreshes served by the exact (anchor) eigensolve.
pub static REFRESH_ANCHOR: Counter = Counter::new("opt.refresh_anchor");
/// Jacobi sweeps actually executed across all `jacobi_eigh*` calls
/// (early-out on convergence makes this less than calls × budget).
pub static EIGENSWEEPS: Counter = Counter::new("linalg.eigensweeps");
/// Pool fan-out regions dispatched (`pool::run` and friends).
pub static POOL_DISPATCHES: Counter = Counter::new("pool.dispatches");
/// Gauge: resident optimizer state, bytes (`state_elems() * 4`).
pub static STATE_BYTES: Counter = Counter::new("opt.state_bytes");

static ALL: &[&Counter] =
    &[&REQUEUES, &REFRESH_SKETCH, &REFRESH_ANCHOR, &EIGENSWEEPS, &POOL_DISPATCHES, &STATE_BYTES];

/// Wire-byte accounting is per frame kind; kinds are the one-byte tags
/// of `dist/transport.rs` (1..=8 today), clamped into this table.
pub const FRAME_KINDS: usize = 16;

#[allow(clippy::declare_interior_mutable_const)]
const ZERO: AtomicU64 = AtomicU64::new(0);
static WIRE_IN: [AtomicU64; FRAME_KINDS] = [ZERO; FRAME_KINDS];
static WIRE_OUT: [AtomicU64; FRAME_KINDS] = [ZERO; FRAME_KINDS];

/// Human name for a transport frame-kind byte.
pub fn kind_name(kind: u8) -> &'static str {
    match kind {
        1 => "HELLO",
        2 => "WELCOME",
        3 => "REJECT",
        4 => "STATE",
        5 => "SHARD",
        6 => "SHARD_DONE",
        7 => "DONE",
        8 => "WITNESS",
        _ => "UNKNOWN",
    }
}

#[inline]
fn slot(kind: u8) -> usize {
    (kind as usize).min(FRAME_KINDS - 1)
}

/// Account `bytes` of a sent frame of `kind` (whole frame incl. header).
#[inline]
pub fn wire_out(kind: u8, bytes: usize) {
    WIRE_OUT[slot(kind)].fetch_add(bytes as u64, Ordering::Relaxed);
}

/// Account `bytes` of a received frame of `kind`.
#[inline]
pub fn wire_in(kind: u8, bytes: usize) {
    WIRE_IN[slot(kind)].fetch_add(bytes as u64, Ordering::Relaxed);
}

/// Total wire bytes `(in, out)` across all frame kinds.
pub fn wire_totals() -> (u64, u64) {
    let sum = |t: &[AtomicU64; FRAME_KINDS]| t.iter().map(|c| c.load(Ordering::Relaxed)).sum();
    (sum(&WIRE_IN), sum(&WIRE_OUT))
}

/// Every non-zero counter/gauge plus per-kind wire bytes, name-sorted —
/// the summary ledger the trainer prints and tests assert on.
pub fn snapshot() -> Vec<(String, u64)> {
    let mut out: Vec<(String, u64)> = Vec::new();
    for c in ALL {
        if c.get() != 0 {
            out.push((c.name().to_string(), c.get()));
        }
    }
    for k in 0..FRAME_KINDS {
        let (i, o) = (
            WIRE_IN[k].load(Ordering::Relaxed),
            WIRE_OUT[k].load(Ordering::Relaxed),
        );
        if i != 0 {
            out.push((format!("wire.in.{}", kind_name(k as u8)), i));
        }
        if o != 0 {
            out.push((format!("wire.out.{}", kind_name(k as u8)), o));
        }
    }
    out.sort();
    out
}

/// One-line-per-entry rendering of [`snapshot`].
pub fn report() -> String {
    let mut s = String::new();
    for (name, v) in snapshot() {
        s.push_str(&format!("{name:<24} {v}\n"));
    }
    s
}

/// Zero everything — test isolation only (the registry is process-wide).
pub fn reset_all() {
    for c in ALL {
        c.reset();
    }
    for k in 0..FRAME_KINDS {
        WIRE_IN[k].store(0, Ordering::Relaxed);
        WIRE_OUT[k].store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_add_get() {
        let c = Counter::new("t");
        c.add(3);
        c.incr();
        assert_eq!(c.get(), 4);
        c.set(7);
        assert_eq!(c.get(), 7);
        c.reset();
        assert_eq!(c.get(), 0);
    }

    #[test]
    fn kind_names_cover_protocol() {
        for k in 1..=8u8 {
            assert_ne!(kind_name(k), "UNKNOWN");
        }
        assert_eq!(kind_name(0), "UNKNOWN");
        assert_eq!(kind_name(9), "UNKNOWN");
    }

    #[test]
    fn wire_accounting_by_kind() {
        // other tests in the binary also bump wire counters; assert on
        // deltas of an otherwise-unused kind slot (15 = UNKNOWN clamp)
        let before_in = {
            let (i, _) = wire_totals();
            i
        };
        wire_in(15, 10);
        wire_in(15, 5);
        wire_out(15, 7);
        let (i, o) = wire_totals();
        assert!(i >= before_in + 15);
        assert!(o >= 7);
        let snap = snapshot();
        assert!(snap.iter().any(|(n, _)| n == "wire.in.UNKNOWN"));
    }

    #[test]
    fn snapshot_sorted_nonzero() {
        REQUEUES.add(1);
        let snap = snapshot();
        assert!(snap.iter().any(|(n, _)| n == "dist.requeues"));
        let names: Vec<&String> = snap.iter().map(|(n, _)| n).collect();
        let mut sorted = names.clone();
        sorted.sort();
        assert_eq!(names, sorted);
    }
}
