//! Process-wide counter/gauge registry — the run's cost ledger.
//!
//! Companion to the span tracer ([`util::trace`](crate::util::trace)):
//! spans tell you *where time goes*, these counters tell you *how much
//! work happened* — wire bytes in/out per frame kind, shard requeues,
//! sketch-vs-anchor refresh decisions, Jacobi eigensweeps actually
//! consumed (vs the budget), pool region dispatches, and a gauge for
//! the resident optimizer state in bytes (derived from the existing
//! `state_elems` accounting, × 4 bytes/f32). Counters are always on:
//! one relaxed `fetch_add` per increment, at call sites that are never
//! inner loops (per frame, per requeue, per sweep, per region). The
//! trainer summary and the witness/metrics columns read them via
//! [`wire_totals`]/[`snapshot`].
//!
//! Counters are observational only — nothing reads them back into
//! control flow, so they can never perturb numerics (same contract as
//! tracing; pinned by the parity suites).

use std::sync::atomic::{AtomicU64, Ordering};

/// A named monotonic counter (or gauge, via [`Counter::set`]).
pub struct Counter {
    name: &'static str,
    v: AtomicU64,
}

impl Counter {
    pub const fn new(name: &'static str) -> Self {
        Counter { name, v: AtomicU64::new(0) }
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn incr(&self) {
        self.add(1);
    }

    /// Gauge-style overwrite (used by the state-bytes gauge).
    pub fn set(&self, v: u64) {
        self.v.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    pub fn reset(&self) {
        self.set(0);
    }
}

/// Shards put back on the queue after a member died mid-round.
pub static REQUEUES: Counter = Counter::new("dist.requeues");
/// Subspace refreshes served by the randomized sketch path.
pub static REFRESH_SKETCH: Counter = Counter::new("opt.refresh_sketch");
/// Subspace refreshes served by the exact (anchor) eigensolve.
pub static REFRESH_ANCHOR: Counter = Counter::new("opt.refresh_anchor");
/// Jacobi sweeps actually executed across all `jacobi_eigh*` calls
/// (early-out on convergence makes this less than calls × budget).
pub static EIGENSWEEPS: Counter = Counter::new("linalg.eigensweeps");
/// Pool fan-out regions dispatched (`pool::run` and friends).
pub static POOL_DISPATCHES: Counter = Counter::new("pool.dispatches");
/// Gauge: resident optimizer state, bytes (`state_elems() * 4`).
pub static STATE_BYTES: Counter = Counter::new("opt.state_bytes");
/// Scoring requests admitted to the serving queue (any ingress: loopback
/// submit or TCP `Request` frame).
pub static SERVE_REQUESTS: Counter = Counter::new("serve.requests");
/// Request payload bytes admitted (token tensors, 4 bytes/element).
pub static SERVE_REQ_BYTES: Counter = Counter::new("serve.request_bytes");
/// Serving batches dispatched across the pool (see [`serve_fill`]).
pub static SERVE_BATCHES: Counter = Counter::new("serve.batches");
/// Gauge: requests still waiting in the serve queue after the most
/// recent enqueue/dispatch.
pub static SERVE_QUEUE_DEPTH: Counter = Counter::new("serve.queue_depth");
/// Scoring requests shed at ingress because the queue sat at
/// `max_queue_depth` (typed reject, never a silent drop).
pub static SERVE_REJECTS: Counter = Counter::new("serve.rejects");
/// Pipelined rounds: microseconds of sibling-merge work that ran while
/// shards were still executing (the reduce latency the overlap hid).
pub static REDUCE_OVERLAP_US: Counter = Counter::new("dist.reduce_overlap_us");
/// Pipelined steps: microseconds of per-parameter optimizer work that ran
/// while other parameters' gradients were still folding.
pub static OPT_OVERLAP_US: Counter = Counter::new("dist.opt_overlap_us");

static ALL: &[&Counter] = &[
    &REQUEUES,
    &REFRESH_SKETCH,
    &REFRESH_ANCHOR,
    &EIGENSWEEPS,
    &POOL_DISPATCHES,
    &STATE_BYTES,
    &SERVE_REQUESTS,
    &SERVE_REQ_BYTES,
    &SERVE_BATCHES,
    &SERVE_QUEUE_DEPTH,
    &SERVE_REJECTS,
    &REDUCE_OVERLAP_US,
    &OPT_OVERLAP_US,
];

/// Wire-byte accounting is per frame kind; kinds are the one-byte tags
/// of `dist/transport.rs` (1..=10 today), clamped into this table.
pub const FRAME_KINDS: usize = 16;

#[allow(clippy::declare_interior_mutable_const)]
const ZERO: AtomicU64 = AtomicU64::new(0);
static WIRE_IN: [AtomicU64; FRAME_KINDS] = [ZERO; FRAME_KINDS];
static WIRE_OUT: [AtomicU64; FRAME_KINDS] = [ZERO; FRAME_KINDS];

/// Human name for a transport frame-kind byte.
pub fn kind_name(kind: u8) -> &'static str {
    match kind {
        1 => "HELLO",
        2 => "WELCOME",
        3 => "REJECT",
        4 => "STATE",
        5 => "SHARD",
        6 => "SHARD_DONE",
        7 => "DONE",
        8 => "WITNESS",
        9 => "REQUEST",
        10 => "RESPONSE",
        _ => "UNKNOWN",
    }
}

/// Batch-fill histogram resolution: dispatched batches are bucketed by
/// fill fraction (`len / max_batch`) into eighths; the top bucket is
/// exactly-full batches.
pub const FILL_BUCKETS: usize = 8;

static SERVE_FILL: [AtomicU64; FILL_BUCKETS] = [ZERO; FILL_BUCKETS];

/// Account one dispatched serving batch of `len` requests under a
/// `max_batch` cap: bumps [`SERVE_BATCHES`] and the fill histogram
/// (bucket `ceil(8 · len/max)`, clamped).
pub fn serve_fill(len: usize, max_batch: usize) {
    SERVE_BATCHES.incr();
    let max = max_batch.max(1);
    let idx = (len * FILL_BUCKETS).div_ceil(max).clamp(1, FILL_BUCKETS) - 1;
    SERVE_FILL[idx].fetch_add(1, Ordering::Relaxed);
}

/// The fill histogram — bucket `i` counts batches with fill fraction in
/// `(i/8, (i+1)/8]` (so the last bucket is exactly-full dispatches).
pub fn serve_fill_snapshot() -> [u64; FILL_BUCKETS] {
    std::array::from_fn(|i| SERVE_FILL[i].load(Ordering::Relaxed))
}

#[inline]
fn slot(kind: u8) -> usize {
    (kind as usize).min(FRAME_KINDS - 1)
}

/// Account `bytes` of a sent frame of `kind` (whole frame incl. header).
#[inline]
pub fn wire_out(kind: u8, bytes: usize) {
    WIRE_OUT[slot(kind)].fetch_add(bytes as u64, Ordering::Relaxed);
}

/// Account `bytes` of a received frame of `kind`.
#[inline]
pub fn wire_in(kind: u8, bytes: usize) {
    WIRE_IN[slot(kind)].fetch_add(bytes as u64, Ordering::Relaxed);
}

/// Total wire bytes `(in, out)` across all frame kinds.
pub fn wire_totals() -> (u64, u64) {
    let sum = |t: &[AtomicU64; FRAME_KINDS]| t.iter().map(|c| c.load(Ordering::Relaxed)).sum();
    (sum(&WIRE_IN), sum(&WIRE_OUT))
}

/// Every non-zero counter/gauge plus per-kind wire bytes, name-sorted —
/// the summary ledger the trainer prints and tests assert on.
pub fn snapshot() -> Vec<(String, u64)> {
    let mut out: Vec<(String, u64)> = Vec::new();
    for c in ALL {
        if c.get() != 0 {
            out.push((c.name().to_string(), c.get()));
        }
    }
    for k in 0..FRAME_KINDS {
        let (i, o) = (
            WIRE_IN[k].load(Ordering::Relaxed),
            WIRE_OUT[k].load(Ordering::Relaxed),
        );
        if i != 0 {
            out.push((format!("wire.in.{}", kind_name(k as u8)), i));
        }
        if o != 0 {
            out.push((format!("wire.out.{}", kind_name(k as u8)), o));
        }
    }
    for (i, c) in SERVE_FILL.iter().enumerate() {
        let v = c.load(Ordering::Relaxed);
        if v != 0 {
            out.push((format!("serve.fill.{}of{}", i + 1, FILL_BUCKETS), v));
        }
    }
    out.sort();
    out
}

/// One-line-per-entry rendering of [`snapshot`].
pub fn report() -> String {
    let mut s = String::new();
    for (name, v) in snapshot() {
        s.push_str(&format!("{name:<24} {v}\n"));
    }
    s
}

/// Zero everything — test isolation only (the registry is process-wide).
pub fn reset_all() {
    for c in ALL {
        c.reset();
    }
    for k in 0..FRAME_KINDS {
        WIRE_IN[k].store(0, Ordering::Relaxed);
        WIRE_OUT[k].store(0, Ordering::Relaxed);
    }
    for c in &SERVE_FILL {
        c.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_add_get() {
        let c = Counter::new("t");
        c.add(3);
        c.incr();
        assert_eq!(c.get(), 4);
        c.set(7);
        assert_eq!(c.get(), 7);
        c.reset();
        assert_eq!(c.get(), 0);
    }

    #[test]
    fn kind_names_cover_protocol() {
        for k in 1..=10u8 {
            assert_ne!(kind_name(k), "UNKNOWN");
        }
        assert_eq!(kind_name(0), "UNKNOWN");
        assert_eq!(kind_name(11), "UNKNOWN");
    }

    #[test]
    fn serve_fill_buckets_by_fraction() {
        let before = serve_fill_snapshot();
        let batches = SERVE_BATCHES.get();
        serve_fill(1, 8); // 1/8 full → bucket 0
        serve_fill(8, 8); // exactly full → bucket 7
        serve_fill(5, 8); // 5/8 full → bucket 4
        serve_fill(3, 0); // max clamped to 1 → overfull clamps to top
        let after = serve_fill_snapshot();
        // ≥ deltas: other tests in this binary may bump the process-wide
        // histogram concurrently
        assert!(after[0] >= before[0] + 1);
        assert!(after[4] >= before[4] + 1);
        assert!(after[7] >= before[7] + 2);
        assert!(SERVE_BATCHES.get() >= batches + 4);
    }

    #[test]
    fn wire_accounting_by_kind() {
        // other tests in the binary also bump wire counters; assert on
        // deltas of an otherwise-unused kind slot (15 = UNKNOWN clamp)
        let before_in = {
            let (i, _) = wire_totals();
            i
        };
        wire_in(15, 10);
        wire_in(15, 5);
        wire_out(15, 7);
        let (i, o) = wire_totals();
        assert!(i >= before_in + 15);
        assert!(o >= 7);
        let snap = snapshot();
        assert!(snap.iter().any(|(n, _)| n == "wire.in.UNKNOWN"));
    }

    #[test]
    fn snapshot_sorted_nonzero() {
        REQUEUES.add(1);
        let snap = snapshot();
        assert!(snap.iter().any(|(n, _)| n == "dist.requeues"));
        let names: Vec<&String> = snap.iter().map(|(n, _)| n).collect();
        let mut sorted = names.clone();
        sorted.sort();
        assert_eq!(names, sorted);
    }
}
