//! `alice-racs` — launcher CLI for the training coordinator and the
//! table/figure benchmark harness. See `cli.rs` for commands.

fn main() {
    if let Err(e) = alice_racs::cli::main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
