//! Kronecker-product utilities (paper App. B.2).
//!
//! Only used by the `fisher` library for small-scale verification of the
//! structural identities — (A ⊗ B) Vec(C) = Vec(B C Aᵀ), square-root
//! factorization, block-diagonal assembly — never on the training path
//! (there the identities are applied implicitly, which is the whole point).

use super::mat::Mat;
use super::simd;

/// Dense Kronecker product A ⊗ B. O((ma·mb)·(na·nb)) memory — test use only.
pub fn kron(a: &Mat, b: &Mat) -> Mat {
    let (ma, na) = (a.rows, a.cols);
    let (mb, nb) = (b.rows, b.cols);
    Mat::from_fn(ma * mb, na * nb, |i, j| {
        a.at(i / mb, j / nb) * b.at(i % mb, j % nb)
    })
}

/// Column-stacking vectorization Vec(C) (paper Sec. 2.1: stack columns).
/// One strided gather per column — the same helper the QR working-set
/// loads use.
pub fn vec_cols(c: &Mat) -> Vec<f32> {
    let mut out = vec![0.0; c.rows * c.cols];
    if c.rows > 0 {
        for (j, dst) in out.chunks_mut(c.rows).enumerate() {
            simd::gather_stride(dst, &c.data[j..], c.cols);
        }
    }
    out
}

/// Inverse of `vec_cols`: Mat(v) with given rows/cols (strided scatter
/// per column).
pub fn mat_cols(v: &[f32], rows: usize, cols: usize) -> Mat {
    assert_eq!(v.len(), rows * cols);
    let mut m = Mat::zeros(rows, cols);
    if rows > 0 {
        for (j, src) in v.chunks(rows).enumerate() {
            simd::scatter_stride(&mut m.data[j..], cols, src);
        }
    }
    m
}

/// Block-diagonal assembly Diag_B(M₁, …, Mₙ).
pub fn block_diag(blocks: &[Mat]) -> Mat {
    let rows: usize = blocks.iter().map(|b| b.rows).sum();
    let cols: usize = blocks.iter().map(|b| b.cols).sum();
    let mut out = Mat::zeros(rows, cols);
    let (mut ro, mut co) = (0, 0);
    for b in blocks {
        for i in 0..b.rows {
            for j in 0..b.cols {
                *out.at_mut(ro + i, co + j) = b.at(i, j);
            }
        }
        ro += b.rows;
        co += b.cols;
    }
    out
}

/// Diag_v(v): expand a vector to a diagonal matrix.
pub fn diag_v(v: &[f32]) -> Mat {
    let n = v.len();
    let mut m = Mat::zeros(n, n);
    for i in 0..n {
        *m.at_mut(i, i) = v[i];
    }
    m
}

/// Diag_M(M): stack the elements of M column-wise into a big pure-diagonal
/// matrix (paper App. A example).
pub fn diag_m(m: &Mat) -> Mat {
    diag_v(&vec_cols(m))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg;

    #[test]
    fn kron_identity_property() {
        // (A ⊗ B) Vec(C) == Vec(B C Aᵀ) — Eq. 24
        let mut rng = Pcg::seeded(21);
        let a = Mat::from_vec(3, 3, rng.normal_vec(9, 1.0));
        let b = Mat::from_vec(2, 2, rng.normal_vec(4, 1.0));
        let c = Mat::from_vec(2, 3, rng.normal_vec(6, 1.0));
        let lhs = kron(&a, &b).matvec(&vec_cols(&c));
        let rhs = vec_cols(&b.matmul(&c).matmul_nt(&a));
        for (x, y) in lhs.iter().zip(&rhs) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }

    #[test]
    fn vec_mat_roundtrip() {
        let c = Mat::from_fn(3, 4, |i, j| (i * 10 + j) as f32);
        let v = vec_cols(&c);
        let back = mat_cols(&v, 3, 4);
        assert_eq!(back.data, c.data);
    }

    #[test]
    fn block_diag_shape() {
        let m1 = Mat::eye(2);
        let m2 = Mat::from_vec(1, 1, vec![5.0]);
        let bd = block_diag(&[m1, m2]);
        assert_eq!((bd.rows, bd.cols), (3, 3));
        assert_eq!(bd.at(2, 2), 5.0);
        assert_eq!(bd.at(0, 2), 0.0);
    }

    #[test]
    fn diag_m_matches_paper_example() {
        // App. A: Diag_M([[a11,a12],[a21,a22]]) = diag(a11,a21,a12,a22)
        let m = Mat::from_vec(2, 2, vec![1., 2., 3., 4.]); // rows: [1,2],[3,4]
        let d = diag_m(&m);
        assert_eq!(d.diag(), vec![1., 3., 2., 4.]);
    }
}
