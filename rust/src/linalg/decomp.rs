//! Decompositions: Householder-style MGS QR, Jacobi eigendecomposition,
//! subspace iteration (paper Algorithm 10), Newton-Schulz roots (App. B.8).
//!
//! These are the substrate for the native optimizer suite (Eigen-Adam /
//! SOAP / Shampoo / GaLore / Alice refreshes) and for the `fisher` library.
//! Validated against known decompositions and reconstruction identities in
//! the unit tests below plus property tests in `testing`.

use crate::util::Pcg;

use super::mat::Mat;

const EPS: f32 = 1e-8;

/// Modified Gram-Schmidt with re-orthogonalization. Returns Q (m x r) with
/// orthonormal columns; degenerate input columns fall back to canonical
/// directions projected off the accepted prefix (so Q is always full rank).
pub fn mgs_qr(a: &Mat) -> Mat {
    let (m, r) = (a.rows, a.cols);
    assert!(r <= m, "mgs_qr needs tall input, got {m}x{r}");
    let mut q = Mat::zeros(m, r);
    for j in 0..r {
        let mut v = a.col_vec(j);
        for pass in 0..2 {
            let _ = pass;
            for jj in 0..j {
                let qc = q.col_vec(jj);
                let dot: f32 = qc.iter().zip(&v).map(|(a, b)| a * b).sum();
                for (vi, qi) in v.iter_mut().zip(&qc) {
                    *vi -= dot * qi;
                }
            }
        }
        let nrm = v.iter().map(|x| x * x).sum::<f32>().sqrt();
        if nrm > 1e-6 {
            for vi in &mut v {
                *vi /= nrm;
            }
        } else {
            // canonical fallback
            let mut fb = vec![0.0f32; m];
            fb[j % m] = 1.0;
            for jj in 0..j {
                let qc = q.col_vec(jj);
                let dot: f32 = qc.iter().zip(&fb).map(|(a, b)| a * b).sum();
                for (fi, qi) in fb.iter_mut().zip(&qc) {
                    *fi -= dot * qi;
                }
            }
            let fn_ = fb.iter().map(|x| x * x).sum::<f32>().sqrt() + EPS;
            v = fb.into_iter().map(|x| x / fn_).collect();
        }
        q.set_col(j, &v);
    }
    q
}

/// Cyclic Jacobi eigendecomposition of a symmetric matrix.
/// Returns (V, λ) with columns of V sorted by descending eigenvalue:
/// A = V diag(λ) Vᵀ.
pub fn jacobi_eigh(a: &Mat, sweeps: usize) -> (Mat, Vec<f32>) {
    let n = a.rows;
    assert_eq!(n, a.cols);
    let mut w = a.clone();
    w.symmetrize_();
    let mut v = Mat::eye(n);
    for _ in 0..sweeps {
        let mut off = 0.0f32;
        for p in 0..n {
            for q in (p + 1)..n {
                off += w.at(p, q) * w.at(p, q);
            }
        }
        if off.sqrt() < 1e-9 * (1.0 + w.fro_norm()) {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = w.at(p, q);
                if apq.abs() < 1e-12 {
                    continue;
                }
                let app = w.at(p, p);
                let aqq = w.at(q, q);
                let theta = 0.5 * (aqq - app) / apq;
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // rotate rows/cols p, q of w
                for k in 0..n {
                    let wkp = w.at(k, p);
                    let wkq = w.at(k, q);
                    *w.at_mut(k, p) = c * wkp - s * wkq;
                    *w.at_mut(k, q) = s * wkp + c * wkq;
                }
                for k in 0..n {
                    let wpk = w.at(p, k);
                    let wqk = w.at(q, k);
                    *w.at_mut(p, k) = c * wpk - s * wqk;
                    *w.at_mut(q, k) = s * wpk + c * wqk;
                }
                for k in 0..n {
                    let vkp = v.at(k, p);
                    let vkq = v.at(k, q);
                    *v.at_mut(k, p) = c * vkp - s * vkq;
                    *v.at_mut(k, q) = s * vkp + c * vkq;
                }
            }
        }
    }
    let mut lam: Vec<f32> = (0..n).map(|i| w.at(i, i)).collect();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| lam[j].partial_cmp(&lam[i]).unwrap());
    let vs = Mat::from_fn(n, n, |i, j| v.at(i, order[j]));
    lam = order.iter().map(|&i| lam[i]).collect();
    (vs, lam)
}

/// Subspace iteration (paper Algorithm 10): top-r eigenpairs of symmetric
/// `a`, warm-started at `u0` (m x r). The small r x r Rayleigh problem is
/// solved by Jacobi, as the paper's last two lines do with EVD.
pub fn subspace_iter(a: &Mat, u0: &Mat, iters: usize) -> (Mat, Vec<f32>) {
    let mut u = u0.clone();
    for _ in 0..iters.max(1) {
        u = mgs_qr(&a.matmul(&u));
    }
    let small = u.matmul_tn(&a.matmul(&u)); // Uᵀ A U
    let (w, lam) = jacobi_eigh(&small, 30);
    (u.matmul(&w), lam)
}

/// Orthonormal complement of U (m x r) → (m x (m-r)); the paper's `QR(U)`
/// (Algorithm 2 line 4). Deterministic construction from canonical vectors.
pub fn complete_basis(u: &Mat) -> Mat {
    let (m, r) = (u.rows, u.cols);
    assert!(r <= m);
    if r == m {
        return Mat::zeros(m, 0);
    }
    // Project ALL canonical vectors off U, pick the (m - r) with the largest
    // residuals, then MGS them (fallback covers degeneracies).
    let mut resid = Mat::eye(m); // columns e_k
    let ut_e = u.transpose(); // (r x m): column k of resid needs U (Uᵀ e_k)
    for k in 0..m {
        // e_k - U (Uᵀ e_k); Uᵀ e_k is column k of Uᵀ = row k of U
        let coeff: Vec<f32> = (0..r).map(|j| u.at(k, j)).collect();
        let corr = // U @ coeff
            (0..m).map(|i| {
                (0..r).map(|j| u.at(i, j) * coeff[j]).sum::<f32>()
            }).collect::<Vec<f32>>();
        for i in 0..m {
            *resid.at_mut(i, k) -= corr[i];
        }
    }
    let _ = ut_e;
    let mut norms: Vec<(usize, f32)> = (0..m)
        .map(|k| {
            let n: f32 = (0..m).map(|i| resid.at(i, k).powi(2)).sum();
            (k, n)
        })
        .collect();
    norms.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    let picked: Vec<usize> = norms[..m - r].iter().map(|&(k, _)| k).collect();
    let cand = Mat::from_fn(m, m - r, |i, j| resid.at(i, picked[j]));
    mgs_qr(&cand)
}

/// One Newton-Schulz step (App. B.8).
pub fn ns_step(y: &Mat, z: &Mat) -> (Mat, Mat) {
    let n = y.rows;
    let mut t = Mat::eye(n).scale(3.0);
    let zy = z.matmul(y);
    t = t.sub(&zy);
    (y.matmul(&t).scale(0.5), t.matmul(z).scale(0.5))
}

/// Newton-Schulz: (√A, A^-½) for SPD A.
pub fn newton_schulz(a: &Mat, iters: usize) -> (Mat, Mat) {
    let fro = a.fro_norm() + EPS;
    let mut y = a.scale(1.0 / fro);
    let mut z = Mat::eye(a.rows);
    for _ in 0..iters {
        let (y2, z2) = ns_step(&y, &z);
        y = y2;
        z = z2;
    }
    (y.scale(fro.sqrt()), z.scale(1.0 / fro.sqrt()))
}

/// Whitening operator (Sec. 3.3): (GGᵀ)^-½ G. Expects rows <= cols.
pub fn whiten(g: &Mat, iters: usize) -> Mat {
    let m = g.rows;
    let mut a = g.matmul_nt(g);
    for i in 0..m {
        *a.at_mut(i, i) += 1e-4;
    }
    let (_, inv_sqrt) = newton_schulz(&a, iters);
    inv_sqrt.matmul(g)
}

/// A^-¼ via nested Newton-Schulz (Shampoo roots).
pub fn inv_fourth_root(a: &Mat, iters: usize) -> Mat {
    let (mut sqrt_a, _) = newton_schulz(a, iters);
    sqrt_a.symmetrize_();
    for i in 0..a.rows {
        *sqrt_a.at_mut(i, i) += 1e-6;
    }
    let (_, inv_sqrt) = newton_schulz(&sqrt_a, iters);
    inv_sqrt
}

/// Random orthonormal m x r (Gaussian + QR) — test helper and the
/// "gaussian" switching ablation.
pub fn random_orthonormal(m: usize, r: usize, rng: &mut Pcg) -> Mat {
    let g = Mat::from_vec(m, r, rng.normal_vec(m * r, 1.0));
    mgs_qr(&g)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spd(n: usize, seed: u64) -> Mat {
        let mut rng = Pcg::seeded(seed);
        let b = Mat::from_vec(n, n, rng.normal_vec(n * n, 1.0));
        let mut a = b.matmul_nt(&b);
        for i in 0..n {
            *a.at_mut(i, i) += 0.5;
        }
        a
    }

    fn ortho_err(q: &Mat) -> f32 {
        let qtq = q.matmul_tn(q);
        qtq.sub(&Mat::eye(q.cols)).max_abs()
    }

    #[test]
    fn qr_orthonormal() {
        let mut rng = Pcg::seeded(5);
        let a = Mat::from_vec(30, 8, rng.normal_vec(240, 1.0));
        let q = mgs_qr(&a);
        assert!(ortho_err(&q) < 1e-4);
    }

    #[test]
    fn qr_handles_rank_deficiency() {
        // two identical columns: second must fall back, Q stays orthonormal
        let mut rng = Pcg::seeded(6);
        let c = rng.normal_vec(20, 1.0);
        let mut data = c.clone();
        data.extend_from_slice(&c);
        let a = Mat::from_vec(20, 2, {
            // interleave into row-major (20 x 2)
            let mut v = vec![0.0; 40];
            for i in 0..20 {
                v[2 * i] = c[i];
                v[2 * i + 1] = c[i];
            }
            v
        });
        let _ = data;
        let q = mgs_qr(&a);
        assert!(ortho_err(&q) < 1e-3);
    }

    #[test]
    fn jacobi_reconstructs() {
        let a = spd(12, 1);
        let (v, lam) = jacobi_eigh(&a, 30);
        assert!(ortho_err(&v) < 1e-4);
        // V diag(lam) Vᵀ == A
        let mut vd = v.clone();
        for i in 0..v.rows {
            for j in 0..v.cols {
                *vd.at_mut(i, j) *= lam[j];
            }
        }
        let rec = vd.matmul_nt(&v);
        assert!(rec.sub(&a).max_abs() < 1e-3 * a.max_abs());
        // sorted descending
        for w in lam.windows(2) {
            assert!(w[0] >= w[1] - 1e-5);
        }
    }

    #[test]
    fn subspace_finds_top_eigs() {
        let a = spd(16, 2);
        let (vf, lf) = jacobi_eigh(&a, 40);
        let _ = vf;
        let mut rng = Pcg::seeded(7);
        let u0 = random_orthonormal(16, 4, &mut rng);
        let (u, lam) = subspace_iter(&a, &u0, 25);
        assert!(ortho_err(&u) < 1e-3);
        for (got, want) in lam.iter().zip(&lf[..4]) {
            assert!((got - want).abs() < 1e-2 * want.abs().max(1.0),
                    "{got} vs {want}");
        }
    }

    #[test]
    fn complete_basis_is_complement() {
        let mut rng = Pcg::seeded(9);
        let u = random_orthonormal(14, 5, &mut rng);
        let uc = complete_basis(&u);
        assert_eq!(uc.cols, 9);
        assert!(ortho_err(&uc) < 1e-3);
        // Uᵀ U_c == 0
        let cross = u.matmul_tn(&uc);
        assert!(cross.max_abs() < 1e-3);
    }

    #[test]
    fn newton_schulz_roots() {
        let a = spd(10, 3);
        let (sq, isq) = newton_schulz(&a, 30);
        assert!(sq.matmul(&sq).sub(&a).max_abs() < 1e-2 * a.max_abs());
        let ident = isq.matmul(&a).matmul(&isq);
        assert!(ident.sub(&Mat::eye(10)).max_abs() < 1e-2);
    }

    #[test]
    fn whiten_orthogonalizes() {
        let mut rng = Pcg::seeded(4);
        let g = Mat::from_vec(8, 24, rng.normal_vec(192, 1.0));
        let w = whiten(&g, 30);
        let wwt = w.matmul_nt(&w);
        assert!(wwt.sub(&Mat::eye(8)).max_abs() < 5e-2);
    }

    #[test]
    fn inv_fourth_root_property() {
        let a = spd(8, 8);
        let r = inv_fourth_root(&a, 30);
        // (A^-¼)⁴ A ≈ I
        let r2 = r.matmul(&r);
        let r4 = r2.matmul(&r2);
        let ident = r4.matmul(&a);
        assert!(ident.sub(&Mat::eye(8)).max_abs() < 5e-2,
                "err {}", ident.sub(&Mat::eye(8)).max_abs());
    }
}
