//! Decompositions: MGS QR, Jacobi eigendecomposition, subspace iteration
//! (paper Algorithm 10), Newton-Schulz roots (App. B.8).
//!
//! These are the substrate for the native optimizer suite (Eigen-Adam /
//! SOAP / Shampoo / GaLore / Alice refreshes) and for the `fisher` library.
//! Validated against known decompositions and reconstruction identities in
//! the unit tests below plus property tests in `testing`.
//!
//! # Threading
//!
//! The periodic subspace refreshes dominate wall clock at lm-head scale
//! (ROADMAP "Parallel decompositions"), so both workhorses fan out over
//! `util::pool`:
//!
//! * [`mgs_qr`] is right-looking: each step normalizes one column and
//!   projects it out of every trailing column — the projections are
//!   independent per column and fan out once the trailing work crosses
//!   [`QR_PAR_MIN_WORK`]. A full second pass re-orthogonalizes (MGS2).
//! * [`jacobi_eigh`] dispatches on size: the serial cyclic sweep
//!   ([`jacobi_eigh_serial`]) below [`JACOBI_PAR_MIN_N`], parallel-ordered
//!   (Brent-Luk) sweeps ([`jacobi_eigh_rounds`]) up to
//!   [`JACOBI_BLOCKED_MIN_N`] — a round-robin schedule partitions each
//!   sweep into rounds of disjoint pivot pairs; per round, all rotation
//!   angles come from the round-start matrix and the column/row update
//!   phases fan out over row blocks / pairs — and the **blocked two-sided
//!   variant** ([`jacobi_eigh_blocked`]) at and above it: the matrix is
//!   partitioned into [`JACOBI_TILE`]-edge tiles, the same Brent-Luk
//!   schedule runs over *tile pairs*, each 2b x 2b pivot subproblem is
//!   solved hot in cache by the shared serial kernel, and the accumulated
//!   block rotations are applied through the `linalg::simd` matmul
//!   microkernel — O(n·b) memory traffic per tile rotation instead of the
//!   flat path's O(n) per element rotation (of which a round holds n/2,
//!   streaming the whole O(n²) working set per round), which is what
//!   makes n ≥ 2k refreshes tractable.
//!
//! Determinism: every fan-out writes disjoint data with a fixed per-element
//! float-op order, algorithm selection and partitioning (including the
//! tile schedule) are pure functions of the input shape, and the remaining
//! reductions (norms, dot products) run whole-slice on whichever thread
//! owns the step — so all decompositions are **bitwise identical at every
//! pool width**, width 1 (the serial baseline) included.
//! `rust/tests/decomp_parity.rs` pins this down. The inner loops (column
//! norms/dots/projections, both rotation phases, the tile-rotation
//! products) route through `linalg::simd`; the reductions there use a
//! fixed lane tree that depends only on the slice length, so the width
//! contract holds per feature setting, with scalar↔simd drift ulp-bounded
//! (`tests/simd_parity.rs`). The convergence check stays a plain serial
//! sum under every setting — the early exit is part of the contract — and
//! accumulates in f64 so it cannot silently defer at n ≥ 2k.
//!
//! # Numerical robustness
//!
//! The eigen path feeds on GGᵀ, whose scale tracks the *squared* gradient
//! scale and can carry non-finite entries after a blowup, so (ISSUE 5):
//!
//! * every `jacobi_eigh*` entry point sanitizes its working copy — any
//!   NaN/inf entry is zeroed ([`symmetric_finite`]) so a decomposition
//!   never panics mid-run and always returns an orthonormal basis with
//!   finite eigenvalues (degraded is recoverable at the next refresh;
//!   a panicked trainer is not);
//! * ordering uses `f32::total_cmp` (never `partial_cmp().unwrap()`);
//! * the pivot-skip test is **relative** to the input's magnitude
//!   ([`PIVOT_REL_TOL`] x `max_abs`), so tiny-scale late-training GGᵀ
//!   rotates exactly like its unit-scale rescaling instead of no-opping
//!   a whole refresh against an absolute cutoff; the degenerate-column
//!   test in [`mgs_qr`] is scale-relative for the same reason.

use crate::obs;
use crate::util::pool::{self, SendPtr};
use crate::util::{trace, Pcg};

use super::mat::Mat;
use super::simd;

const EPS: f32 = 1e-8;

/// Below this many trailing-projection elements (rows x trailing columns)
/// an MGS step stays on the calling thread. 4x higher with the `simd`
/// feature — the projections get ~4-8x cheaper per element, so the
/// break-even trailing block is larger.
const QR_PAR_MIN_WORK: usize = if cfg!(feature = "simd") { 1 << 16 } else { 1 << 14 };

/// Dimension at which `jacobi_eigh` switches from the serial cyclic sweep
/// to parallel-ordered rounds. Below it the rotation count is too small to
/// amortize even the persistent pool's ~µs dispatch.
const JACOBI_PAR_MIN_N: usize = 96;

/// Dimension at which `jacobi_eigh` switches from the flat Brent-Luk
/// rounds to the blocked two-sided variant. At n = 1024 the f32 working
/// set is 4 MiB — past L2 on the deployment targets — and the flat
/// rounds stream the whole matrix once per *element* rotation round; the
/// blocked path streams O(n·b) per *tile* rotation instead.
const JACOBI_BLOCKED_MIN_N: usize = 1024;

/// Tile edge b of the blocked two-sided Jacobi: a 2b x 2b pivot
/// subproblem is 128² f32 = 64 KiB — hot in L1/L2 while the serial
/// kernel iterates it — and the (rows x 2b) @ (2b x 2b) rotation
/// products map straight onto the packed matmul microkernel's geometry.
const JACOBI_TILE: usize = 64;

/// Cap on serial cyclic sweeps spent on one 2b x 2b pivot subproblem
/// (with early exit once every pivot sits below threshold). The
/// subproblem does not need full convergence — each outer sweep revisits
/// every tile pair — so a small fixed cap keeps the schedule, and with it
/// the float-op order, a pure function of the data.
const TILE_PAIR_SWEEPS: usize = 8;

/// Pivot-skip threshold, **relative** to the input's largest magnitude.
/// Rotations with |a_pq| below `PIVOT_REL_TOL * max_abs(A)` contribute
/// nothing at f32 precision but cost a full O(n) (or O(b)) update. The
/// old absolute `1e-12` cutoff silently no-opped whole refreshes for
/// tiny-scale GGᵀ (late-training gradients ~1e-4 square to entries
/// ~1e-8 and below — ISSUE 5); a relative threshold rotates a scaled
/// matrix exactly like its unit-scale version.
const PIVOT_REL_TOL: f32 = 1e-12;

/// Row-block grain (rows per task) for the Jacobi column-update phases.
const JACOBI_ROW_BLK: usize = 32;

/// Modified Gram-Schmidt with a full re-orthogonalization pass (MGS2).
/// Returns Q (m x r) with orthonormal columns; degenerate input columns
/// fall back to canonical directions projected off the accepted prefix
/// (so Q is always full rank).
pub fn mgs_qr(a: &Mat) -> Mat {
    let _sp = trace::region("linalg", "mgs_qr");
    let (m, r) = (a.rows, a.cols);
    assert!(r <= m, "mgs_qr needs tall input, got {m}x{r}");
    // column-major working set: the right-looking updates own whole
    // columns, so each fan-out task gets a contiguous &mut buffer
    let mut cols: Vec<Vec<f32>> = (0..r).map(|j| a.col_vec(j)).collect();
    mgs_pass(&mut cols, m);
    mgs_pass(&mut cols, m); // second pass restores orthonormality ("twice is enough")
    let mut q = Mat::zeros(m, r);
    for (j, c) in cols.iter().enumerate() {
        q.set_col(j, c);
    }
    q
}

/// One right-looking MGS sweep over `cols`. Step j normalizes column j
/// (serial — identical on every pool width), then projects it out of all
/// trailing columns; the projections touch disjoint columns with a fixed
/// per-column float-op order, so the fan-out is bitwise width-invariant.
fn mgs_pass(cols: &mut [Vec<f32>], m: usize) {
    let r = cols.len();
    // Degenerate-column test, relative to the pass input's scale: a
    // tiny-scale refresh input (GGᵀ U with late-training gradients) must
    // orthogonalize like its unit-scale rescaling, not collapse every
    // column onto the canonical fallback against an absolute cutoff.
    // `max` is order-insensitive, so the threshold is width-invariant.
    let scale = cols.iter().map(|c| simd::max_abs(c)).fold(0.0f32, f32::max);
    let tol = 1e-6 * scale;
    for j in 0..r {
        let nrm = simd::sum_sq(&cols[j]).sqrt();
        if nrm > tol {
            for x in &mut cols[j] {
                *x /= nrm;
            }
        } else {
            // canonical fallback projected off the accepted prefix
            let mut fb = vec![0.0f32; m];
            fb[j % m] = 1.0;
            for jj in 0..j {
                let dot = simd::dot(&cols[jj], &fb);
                simd::axpy(&mut fb, -dot, &cols[jj]);
            }
            let fn_ = simd::sum_sq(&fb).sqrt() + EPS;
            for x in &mut fb {
                *x /= fn_;
            }
            cols[j] = fb;
        }
        let (head, tail) = cols.split_at_mut(j + 1);
        if tail.is_empty() {
            continue;
        }
        let qj = &head[j];
        let project = |c: &mut Vec<f32>| {
            let dot = simd::dot(qj, c);
            simd::axpy(c, -dot, qj);
        };
        if m * tail.len() >= QR_PAR_MIN_WORK {
            pool::map_mut(tail, |_, c| project(c));
        } else {
            for c in tail.iter_mut() {
                project(c);
            }
        }
    }
}

/// Eigendecomposition of a symmetric matrix: (V, λ) with columns of V
/// sorted by descending eigenvalue, A = V diag(λ) Vᵀ. Dispatches on size
/// (a pure function of `n` — part of the determinism contract):
///
/// | n | path |
/// | --- | --- |
/// | n < [`JACOBI_PAR_MIN_N`] (96) | [`jacobi_eigh_serial`] — cyclic sweeps |
/// | 96 ≤ n < [`JACOBI_BLOCKED_MIN_N`] (1024) | [`jacobi_eigh_rounds`] — flat Brent-Luk |
/// | n ≥ 1024 | [`jacobi_eigh_blocked`] — Brent-Luk over b = 64 tiles |
///
/// Every entry point sanitizes non-finite input (see
/// [`symmetric_finite`]) — a gradient blowup must not panic a refresh.
pub fn jacobi_eigh(a: &Mat, sweeps: usize) -> (Mat, Vec<f32>) {
    if a.rows < JACOBI_PAR_MIN_N {
        // span here, not in the serial body: the serial kernel doubles
        // as the blocked path's per-tile subproblem solver, where a
        // span per tile pair would swamp the trace
        let _sp = trace::span("linalg", "jacobi_eigh_serial");
        jacobi_eigh_serial(a, sweeps)
    } else if a.rows < JACOBI_BLOCKED_MIN_N {
        jacobi_eigh_rounds(a, sweeps)
    } else {
        jacobi_eigh_blocked(a, sweeps)
    }
}

/// Shared prologue of every `jacobi_eigh*` entry point: symmetrized
/// working copy with any non-finite entry zeroed. GGᵀ carries NaN/inf
/// after a gradient blowup, and decomposing it must not panic the
/// trainer mid-run (ISSUE 5) — the solver operates on the sanitized
/// matrix and still returns an orthonormal basis with finite
/// eigenvalues. A degraded basis is recoverable at the next refresh; a
/// poisoned sort comparison is a panic.
fn symmetric_finite(a: &Mat) -> Mat {
    let mut w = a.clone();
    w.symmetrize_();
    if !w.is_finite() {
        for x in w.data.iter_mut() {
            if !x.is_finite() {
                *x = 0.0;
            }
        }
    }
    w
}

/// Relative pivot-skip threshold for one decomposition, computed once at
/// entry (orthogonal similarity preserves the spectrum's scale, so a
/// single evaluation covers every sweep). `max` is order-insensitive, so
/// the pooled reduction keeps the threshold — and with it the rotation
/// schedule — bitwise width-invariant.
fn pivot_threshold(w: &Mat) -> f32 {
    PIVOT_REL_TOL * w.max_abs()
}

/// One cyclic Jacobi sweep over a dense row-major m x m buffer `s`,
/// accumulating the column rotations into `v` (m x m, V ← V J). This is
/// the shared serial kernel: [`jacobi_eigh_serial`] runs it on the full
/// matrix, the blocked path runs it on each gathered 2b x 2b pivot
/// subproblem, hot in cache. Pivots at or below `tol` are skipped (`<=`,
/// so a zero pivot is skipped even when `tol` is 0 — [`rotation`] is
/// undefined at a_pq = 0). Returns whether any rotation fired.
fn cyclic_sweep(s: &mut [f32], v: &mut [f32], m: usize, tol: f32) -> bool {
    let mut rotated = false;
    for p in 0..m {
        for q in (p + 1)..m {
            let apq = s[p * m + q];
            if apq.abs() <= tol {
                continue;
            }
            rotated = true;
            let (c, sn) = rotation(s[p * m + p], s[q * m + q], apq);
            // rotate cols, then rows, then the accumulated basis —
            // exactly the historical serial kernel's float-op order
            for k in 0..m {
                let skp = s[k * m + p];
                let skq = s[k * m + q];
                s[k * m + p] = c * skp - sn * skq;
                s[k * m + q] = sn * skp + c * skq;
            }
            for k in 0..m {
                let spk = s[p * m + k];
                let sqk = s[q * m + k];
                s[p * m + k] = c * spk - sn * sqk;
                s[q * m + k] = sn * spk + c * sqk;
            }
            for k in 0..m {
                let vkp = v[k * m + p];
                let vkq = v[k * m + q];
                v[k * m + p] = c * vkp - sn * vkq;
                v[k * m + q] = sn * vkp + c * vkq;
            }
        }
    }
    rotated
}

/// Cyclic Jacobi eigendecomposition — the historical serial kernel, kept
/// as the baseline for the large-n parallel paths (benches compare all
/// three) and reused verbatim on the blocked path's pivot subproblems.
pub fn jacobi_eigh_serial(a: &Mat, sweeps: usize) -> (Mat, Vec<f32>) {
    let n = a.rows;
    assert_eq!(n, a.cols);
    let mut w = symmetric_finite(a);
    let mut v = Mat::eye(n);
    let tol = pivot_threshold(&w);
    for _ in 0..sweeps {
        if off_diag_small(&w) {
            break;
        }
        obs::EIGENSWEEPS.incr();
        cyclic_sweep(&mut w.data, &mut v.data, n, tol);
    }
    sort_eigh(w, v)
}

/// Jacobi rotation (c, s) annihilating the (p, q) element, given the
/// diagonal pair and the off-diagonal value.
#[inline]
fn rotation(app: f32, aqq: f32, apq: f32) -> (f32, f32) {
    let theta = 0.5 * (aqq - app) / apq;
    let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
    let c = 1.0 / (t * t + 1.0).sqrt();
    (c, t * c)
}

/// Off-diagonal and full squared Frobenius norms, accumulated serially in
/// **f64**: at n ≥ 2k the f32 left-fold over n²/2 squares loses enough
/// low bits to defer (or falsely trigger) the early exit — ISSUE 5. The
/// sums stay single-pass serial on every width (never the pooled
/// reductions): the early exit must be bitwise width-invariant, and the
/// pooled `fro_norm` regroups additions when the matrix is large.
fn off_fro_sq(w: &Mat) -> (f64, f64) {
    let n = w.rows;
    let mut off = 0.0f64;
    for p in 0..n {
        for q in (p + 1)..n {
            let x = w.at(p, q) as f64;
            off += x * x;
        }
    }
    let mut fro = 0.0f64;
    for &x in &w.data {
        fro += x as f64 * x as f64;
    }
    (off, fro)
}

/// Convergence check shared by all three Jacobi variants. Relative — a
/// tiny-scale matrix converges by the same criterion as its unit-scale
/// rescaling (the old `1 + fro` offset declared tiny inputs converged on
/// arrival). A zero matrix is trivially converged (`0 <= 0`).
fn off_diag_small(w: &Mat) -> bool {
    let (off, fro) = off_fro_sq(w);
    off.sqrt() <= 1e-9 * fro.sqrt()
}

/// Round-robin (circle method) pivot schedule: `n_rounds` rounds of
/// mutually disjoint (p, q) pairs covering every unordered pair exactly
/// once. A pure function of `n` — the schedule, and with it the float-op
/// order of a parallel sweep, never depends on the pool width.
fn jacobi_rounds(n: usize) -> Vec<Vec<(usize, usize)>> {
    let m = n + (n & 1); // pad odd n with a bye slot that pairs skip
    let mut circ: Vec<usize> = (0..m).collect();
    let mut rounds = Vec::with_capacity(m - 1);
    for _ in 0..m - 1 {
        let mut pairs = Vec::with_capacity(m / 2);
        for i in 0..m / 2 {
            let (a, b) = (circ[i], circ[m - 1 - i]);
            if a < n && b < n {
                pairs.push((a.min(b), a.max(b)));
            }
        }
        pairs.sort_unstable();
        rounds.push(pairs);
        circ[1..].rotate_right(1);
    }
    rounds
}

/// Parallel-ordered (Brent-Luk) Jacobi: each sweep walks the round-robin
/// schedule; per round all rotation angles come from the round-start
/// matrix and the update W ← Jᵀ (W J) (J = direct sum of the round's
/// rotations) is applied in two phases — columns, then rows — each fanned
/// out over disjoint data. Public as the mid-size baseline the blocked
/// path is benchmarked against (fig3/fig6 blocked-vs-rounds sections).
pub fn jacobi_eigh_rounds(a: &Mat, sweeps: usize) -> (Mat, Vec<f32>) {
    let _sp = trace::region("linalg", "jacobi_eigh_rounds");
    let n = a.rows;
    assert_eq!(n, a.cols);
    let mut w = symmetric_finite(a);
    let mut v = Mat::eye(n);
    let tol = pivot_threshold(&w);
    let rounds = jacobi_rounds(n);
    for _ in 0..sweeps {
        if off_diag_small(&w) {
            break;
        }
        obs::EIGENSWEEPS.incr();
        for pairs in &rounds {
            // angles from the round-start matrix; serial — O(n) per round
            let rot: Vec<Option<(f32, f32)>> = pairs
                .iter()
                .map(|&(p, q)| {
                    let apq = w.at(p, q);
                    if apq.abs() <= tol {
                        return None;
                    }
                    Some(rotation(w.at(p, p), w.at(q, q), apq))
                })
                .collect();
            if rot.iter().all(|r| r.is_none()) {
                continue;
            }
            // column phase: W ← W J. Each row is owned by exactly one
            // task and applies the rotations in pair order — disjoint
            // writes, fixed order, bitwise width-invariant.
            apply_col_rotations(&mut w.data, n, pairs, &rot);
            // row phase: W ← Jᵀ W. Pairs own disjoint row pairs.
            let base = SendPtr(w.data.as_mut_ptr());
            pool::run(pairs.len(), |t| {
                if let Some((c, s)) = rot[t] {
                    let (p, q) = pairs[t];
                    // SAFETY: rounds hold each index in at most one pair,
                    // so rows p and q are touched by this task alone.
                    let rp = unsafe { std::slice::from_raw_parts_mut(base.0.add(p * n), n) };
                    let rq = unsafe { std::slice::from_raw_parts_mut(base.0.add(q * n), n) };
                    simd::rot2(rp, rq, c, s);
                }
            });
            // eigenvector phase: V ← V J, columns only.
            apply_col_rotations(&mut v.data, n, pairs, &rot);
        }
    }
    sort_eigh(w, v)
}

/// Apply one round's column rotations to a row-major n-column buffer,
/// fanning row blocks out over the pool. Within a block the kernel layer
/// picks the loop order (row-outer scalar, 8-row-strip SIMD) — the
/// round's pairs are disjoint, so every order writes the same bits.
fn apply_col_rotations(
    data: &mut [f32],
    n: usize,
    pairs: &[(usize, usize)],
    rot: &[Option<(f32, f32)>],
) {
    pool::for_each_chunk_mut(data, JACOBI_ROW_BLK * n, |_, rows| {
        simd::rot_cols_block(rows, n, pairs, rot);
    });
}

// ------------------------------------------------- blocked two-sided ---

/// Tile partition of [0, n): `(start, len)` per tile, every tile
/// [`JACOBI_TILE`] wide except a ragged tail. A pure function of `n` —
/// the tile schedule never depends on the pool width.
fn tile_ranges(n: usize) -> Vec<(usize, usize)> {
    (0..n.div_ceil(JACOBI_TILE))
        .map(|t| {
            let lo = t * JACOBI_TILE;
            (lo, JACOBI_TILE.min(n - lo))
        })
        .collect()
}

/// Accumulated orthogonal rotation of one tile-pair pivot subproblem:
/// the dense m x m factor Q (m = bᵢ + bⱼ ≤ 2·[`JACOBI_TILE`]), plus its
/// transpose materialized once so the row phase streams contiguous rows.
struct TileRot {
    m: usize,
    q: Vec<f32>,
    qt: Vec<f32>,
}

/// Solve the 2b x 2b pivot subproblem of tile pair (I, J) from the
/// round-start matrix: gather S = W[I∪J, I∪J] into a contiguous buffer
/// (two row/column bands), run the shared serial kernel on it hot in
/// cache, and return the accumulated rotation. `None` when every pivot
/// already sits below threshold (the rotation would be the identity).
fn solve_tile_pair(
    w: &Mat,
    ti: (usize, usize),
    tj: (usize, usize),
    tol: f32,
) -> Option<TileRot> {
    let (i0, bi) = ti;
    let (j0, bj) = tj;
    let m = bi + bj;
    let n = w.cols;
    let mut q = vec![0.0f32; m * m];
    for l in 0..m {
        q[l * m + l] = 1.0;
    }
    let rotated = pool::with_scratch(m * m, |s| {
        for l in 0..m {
            let gr = if l < bi { i0 + l } else { j0 + (l - bi) };
            let srow = &w.data[gr * n..(gr + 1) * n];
            let drow = &mut s[l * m..(l + 1) * m];
            drow[..bi].copy_from_slice(&srow[i0..i0 + bi]);
            drow[bi..].copy_from_slice(&srow[j0..j0 + bj]);
        }
        let mut rotated = false;
        for _ in 0..TILE_PAIR_SWEEPS {
            if !cyclic_sweep(s, &mut q, m, tol) {
                break;
            }
            rotated = true;
        }
        rotated
    });
    if !rotated {
        return None;
    }
    let mut qt = vec![0.0f32; m * m];
    for r in 0..m {
        for c in 0..m {
            qt[c * m + r] = q[r * m + c];
        }
    }
    Some(TileRot { m, q, qt })
}

/// W ← W · diag(Q₁ … Q_k): one round's tile-pair **column** rotations on
/// a row-major n-column buffer. Row blocks fan out over the pool; per
/// block and pair, the [I|J] column stripe is gathered into scratch and
/// multiplied by Q through the `linalg::simd` matmul microkernel —
/// O(rows · b) traffic per pair instead of streaming all n columns. The
/// round's pairs own disjoint columns and each element accumulates in
/// ascending-k order inside the kernel, so the result is bitwise
/// identical at every pool width.
fn apply_tile_col_rotations(
    data: &mut [f32],
    n: usize,
    tiles: &[(usize, usize)],
    pairs: &[(usize, usize)],
    rot: &[Option<TileRot>],
) {
    pool::for_each_chunk_mut(data, JACOBI_ROW_BLK * n, |_, rows| {
        let nrows = rows.len() / n;
        for (t, r) in rot.iter().enumerate() {
            let Some(tr) = r else { continue };
            let (i0, bi) = tiles[pairs[t].0];
            let (j0, bj) = tiles[pairs[t].1];
            let m = tr.m;
            pool::with_scratch(2 * nrows * m, |buf| {
                let (x, y) = buf.split_at_mut(nrows * m);
                for (ri, row) in rows.chunks(n).enumerate() {
                    x[ri * m..ri * m + bi].copy_from_slice(&row[i0..i0 + bi]);
                    x[ri * m + bi..(ri + 1) * m].copy_from_slice(&row[j0..j0 + bj]);
                }
                simd::matmul_into(&mut y[..nrows * m], x, &tr.q, m, m);
                for (ri, row) in rows.chunks_mut(n).enumerate() {
                    row[i0..i0 + bi].copy_from_slice(&y[ri * m..ri * m + bi]);
                    row[j0..j0 + bj].copy_from_slice(&y[ri * m + bi..(ri + 1) * m]);
                }
            });
        }
    });
}

/// W ← diag(Q)ᵀ · W: one round's tile-pair **row** rotations. Each pair
/// owns its two disjoint row bands, so the pairs themselves fan out; the
/// band update is one (2b x 2b) @ (2b x n) product through the packed
/// microkernel, touching O(n·b) memory per pair.
fn apply_tile_row_rotations(
    data: &mut [f32],
    n: usize,
    tiles: &[(usize, usize)],
    pairs: &[(usize, usize)],
    rot: &[Option<TileRot>],
) {
    let base = SendPtr(data.as_mut_ptr());
    pool::run(pairs.len(), |t| {
        let Some(tr) = &rot[t] else { return };
        let (i0, bi) = tiles[pairs[t].0];
        let (j0, bj) = tiles[pairs[t].1];
        let m = tr.m;
        // SAFETY: rounds hold each tile in at most one pair, so the two
        // row bands are touched by this task alone.
        let band_i = unsafe { std::slice::from_raw_parts_mut(base.0.add(i0 * n), bi * n) };
        let band_j = unsafe { std::slice::from_raw_parts_mut(base.0.add(j0 * n), bj * n) };
        pool::with_scratch(m * n, |src| {
            src[..bi * n].copy_from_slice(band_i);
            src[bi * n..].copy_from_slice(band_j);
            simd::matmul_into(band_i, &tr.qt[..bi * m], src, m, n);
            simd::matmul_into(band_j, &tr.qt[bi * m..], src, m, n);
        });
    });
}

/// Blocked two-sided Jacobi for huge n (dispatched at n ≥
/// [`JACOBI_BLOCKED_MIN_N`]; public so the parity tests and the
/// blocked-vs-rounds benches can pin the kernel at any size). The matrix
/// is partitioned into [`JACOBI_TILE`]-edge tiles and each sweep walks
/// the Brent-Luk round-robin schedule over *tile pairs*: per round the
/// 2b x 2b pivot subproblems are solved concurrently from the
/// round-start matrix (shared serial kernel, hot in cache), then the
/// accumulated block rotations are applied as W ← Qᵀ (W Q), V ← V Q in
/// fanned-out column / row phases — O(n·b) memory traffic per tile
/// rotation instead of the flat path's O(n) per element rotation, of
/// which there are b² per tile pair.
///
/// Width contract: the tile schedule is a pure function of n, a round's
/// pairs own disjoint tiles (disjoint reads in the solve phase, disjoint
/// writes in both update phases), and every kernel accumulates in a
/// fixed per-element order — bitwise identical at every pool width, per
/// feature setting (`tests/decomp_parity.rs`).
pub fn jacobi_eigh_blocked(a: &Mat, sweeps: usize) -> (Mat, Vec<f32>) {
    let n = a.rows;
    assert_eq!(n, a.cols);
    let tiles = tile_ranges(n);
    if tiles.len() < 2 {
        // a single tile has no pairs to schedule — the serial kernel IS
        // the subproblem solver at that size
        return jacobi_eigh_serial(a, sweeps);
    }
    let _sp = trace::region("linalg", "jacobi_eigh_blocked");
    let mut w = symmetric_finite(a);
    let mut v = Mat::eye(n);
    let tol = pivot_threshold(&w);
    let rounds = jacobi_rounds(tiles.len());
    for _ in 0..sweeps {
        if off_diag_small(&w) {
            break;
        }
        obs::EIGENSWEEPS.incr();
        for pairs in &rounds {
            // pivot phase: independent 2b x 2b solves off the
            // round-start matrix — disjoint tiles, shared reads
            let rot: Vec<Option<TileRot>> = pool::map(pairs.len(), |t| {
                solve_tile_pair(&w, tiles[pairs[t].0], tiles[pairs[t].1], tol)
            });
            if rot.iter().all(|r| r.is_none()) {
                continue;
            }
            // column phase: W ← W · diag(Q), row blocks fan out
            apply_tile_col_rotations(&mut w.data, n, &tiles, pairs, &rot);
            // row phase: W ← diag(Q)ᵀ · W, pairs own disjoint bands
            apply_tile_row_rotations(&mut w.data, n, &tiles, pairs, &rot);
            // eigenvector phase: V ← V · diag(Q), columns only
            apply_tile_col_rotations(&mut v.data, n, &tiles, pairs, &rot);
        }
    }
    sort_eigh(w, v)
}

/// Shared epilogue: read eigenvalues off the diagonal and sort
/// descending. `total_cmp`, not `partial_cmp().unwrap()` — the sort must
/// never panic on data-derived floats (and the entry guards keep λ
/// finite anyway).
fn sort_eigh(w: Mat, v: Mat) -> (Mat, Vec<f32>) {
    let n = w.rows;
    let lam: Vec<f32> = (0..n).map(|i| w.at(i, i)).collect();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| lam[j].total_cmp(&lam[i]));
    let vs = Mat::from_fn(n, n, |i, j| v.at(i, order[j]));
    let lam = order.iter().map(|&i| lam[i]).collect();
    (vs, lam)
}

/// Subspace iteration (paper Algorithm 10): top-r eigenpairs of symmetric
/// `a`, warm-started at `u0` (m x r). The small r x r Rayleigh problem is
/// solved by Jacobi, as the paper's last two lines do with EVD.
pub fn subspace_iter(a: &Mat, u0: &Mat, iters: usize) -> (Mat, Vec<f32>) {
    let mut u = u0.clone();
    for _ in 0..iters.max(1) {
        u = mgs_qr(&a.matmul(&u));
    }
    let small = u.matmul_tn(&a.matmul(&u)); // Uᵀ A U
    let (w, lam) = jacobi_eigh(&small, 30);
    (u.matmul(&w), lam)
}

/// Orthonormal complement of U (m x r) → (m x (m-r)); the paper's `QR(U)`
/// (Algorithm 2 line 4). Deterministic construction from canonical vectors.
pub fn complete_basis(u: &Mat) -> Mat {
    let (m, r) = (u.rows, u.cols);
    assert!(r <= m);
    if r == m {
        return Mat::zeros(m, 0);
    }
    // Project ALL canonical vectors off U, pick the (m - r) with the largest
    // residuals, then MGS them (fallback covers degeneracies).
    let mut resid = Mat::eye(m); // columns e_k
    for k in 0..m {
        // e_k - U (Uᵀ e_k); Uᵀ e_k is column k of Uᵀ = row k of U
        let coeff: Vec<f32> = (0..r).map(|j| u.at(k, j)).collect();
        let corr = // U @ coeff
            (0..m).map(|i| {
                (0..r).map(|j| u.at(i, j) * coeff[j]).sum::<f32>()
            }).collect::<Vec<f32>>();
        for i in 0..m {
            *resid.at_mut(i, k) -= corr[i];
        }
    }
    let mut norms: Vec<(usize, f32)> = (0..m)
        .map(|k| {
            let n: f32 = (0..m).map(|i| resid.at(i, k).powi(2)).sum();
            (k, n)
        })
        .collect();
    // total_cmp: residual norms derive from U's data, which a blown-up
    // refresh can make non-finite — ordering must not panic on it
    norms.sort_by(|a, b| b.1.total_cmp(&a.1));
    let picked: Vec<usize> = norms[..m - r].iter().map(|&(k, _)| k).collect();
    let cand = Mat::from_fn(m, m - r, |i, j| resid.at(i, picked[j]));
    mgs_qr(&cand)
}

/// One Newton-Schulz step (App. B.8).
pub fn ns_step(y: &Mat, z: &Mat) -> (Mat, Mat) {
    let n = y.rows;
    let mut t = Mat::eye(n).scale(3.0);
    let zy = z.matmul(y);
    t = t.sub(&zy);
    (y.matmul(&t).scale(0.5), t.matmul(z).scale(0.5))
}

/// Newton-Schulz: (√A, A^-½) for SPD A.
pub fn newton_schulz(a: &Mat, iters: usize) -> (Mat, Mat) {
    let fro = a.fro_norm() + EPS;
    let mut y = a.scale(1.0 / fro);
    let mut z = Mat::eye(a.rows);
    for _ in 0..iters {
        let (y2, z2) = ns_step(&y, &z);
        y = y2;
        z = z2;
    }
    (y.scale(fro.sqrt()), z.scale(1.0 / fro.sqrt()))
}

/// Whitening operator (Sec. 3.3): (GGᵀ)^-½ G. Expects rows <= cols.
pub fn whiten(g: &Mat, iters: usize) -> Mat {
    let m = g.rows;
    let mut a = g.matmul_nt(g);
    for i in 0..m {
        *a.at_mut(i, i) += 1e-4;
    }
    let (_, inv_sqrt) = newton_schulz(&a, iters);
    inv_sqrt.matmul(g)
}

/// A^-¼ via nested Newton-Schulz (Shampoo roots).
pub fn inv_fourth_root(a: &Mat, iters: usize) -> Mat {
    let (mut sqrt_a, _) = newton_schulz(a, iters);
    sqrt_a.symmetrize_();
    for i in 0..a.rows {
        *sqrt_a.at_mut(i, i) += 1e-6;
    }
    let (_, inv_sqrt) = newton_schulz(&sqrt_a, iters);
    inv_sqrt
}

/// Random orthonormal m x r (Gaussian + QR) — test helper and the
/// "gaussian" switching ablation.
pub fn random_orthonormal(m: usize, r: usize, rng: &mut Pcg) -> Mat {
    let g = Mat::from_vec(m, r, rng.normal_vec(m * r, 1.0));
    mgs_qr(&g)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spd(n: usize, seed: u64) -> Mat {
        let mut rng = Pcg::seeded(seed);
        let b = Mat::from_vec(n, n, rng.normal_vec(n * n, 1.0));
        let mut a = b.matmul_nt(&b);
        for i in 0..n {
            *a.at_mut(i, i) += 0.5;
        }
        a
    }

    fn ortho_err(q: &Mat) -> f32 {
        let qtq = q.matmul_tn(q);
        qtq.sub(&Mat::eye(q.cols)).max_abs()
    }

    #[test]
    fn qr_orthonormal() {
        let mut rng = Pcg::seeded(5);
        let a = Mat::from_vec(30, 8, rng.normal_vec(240, 1.0));
        let q = mgs_qr(&a);
        assert!(ortho_err(&q) < 1e-4);
    }

    #[test]
    fn qr_handles_rank_deficiency() {
        // two identical columns: second must fall back, Q stays orthonormal
        let mut rng = Pcg::seeded(6);
        let c = rng.normal_vec(20, 1.0);
        let a = Mat::from_vec(20, 2, {
            // interleave into row-major (20 x 2)
            let mut v = vec![0.0; 40];
            for i in 0..20 {
                v[2 * i] = c[i];
                v[2 * i + 1] = c[i];
            }
            v
        });
        let q = mgs_qr(&a);
        assert!(ortho_err(&q) < 1e-3);
    }

    #[test]
    fn qr_spans_the_input() {
        // Q Qᵀ a == a for full-rank tall input (same column span)
        let mut rng = Pcg::seeded(15);
        let a = Mat::from_vec(25, 6, rng.normal_vec(150, 1.0));
        let q = mgs_qr(&a);
        let rec = q.matmul(&q.matmul_tn(&a));
        assert!(rec.sub(&a).max_abs() < 1e-3 * (1.0 + a.max_abs()));
    }

    #[test]
    fn jacobi_reconstructs() {
        let a = spd(12, 1);
        let (v, lam) = jacobi_eigh(&a, 30);
        assert!(ortho_err(&v) < 1e-4);
        // V diag(lam) Vᵀ == A
        let mut vd = v.clone();
        for i in 0..v.rows {
            for j in 0..v.cols {
                *vd.at_mut(i, j) *= lam[j];
            }
        }
        let rec = vd.matmul_nt(&v);
        assert!(rec.sub(&a).max_abs() < 1e-3 * a.max_abs());
        // sorted descending
        for w in lam.windows(2) {
            assert!(w[0] >= w[1] - 1e-5);
        }
    }

    #[test]
    fn parallel_ordered_jacobi_matches_cyclic() {
        // above the dispatch threshold the rounds path takes over; its
        // eigenvalues must agree with the serial cyclic baseline
        let n = JACOBI_PAR_MIN_N + 4;
        let a = spd(n, 13);
        let (v, lam) = jacobi_eigh(&a, 30);
        let (_, lam_serial) = jacobi_eigh_serial(&a, 30);
        assert!(ortho_err(&v) < 1e-3);
        let scale = lam_serial[0].abs().max(1.0);
        for (got, want) in lam.iter().zip(&lam_serial) {
            assert!((got - want).abs() < 1e-2 * scale, "{got} vs {want}");
        }
        // reconstruction on the parallel path
        let mut vd = v.clone();
        for i in 0..v.rows {
            for j in 0..v.cols {
                *vd.at_mut(i, j) *= lam[j];
            }
        }
        let rec = vd.matmul_nt(&v);
        assert!(rec.sub(&a).max_abs() < 1e-3 * a.max_abs());
    }

    #[test]
    fn tile_ranges_partition_exactly() {
        for n in [65usize, 128, 130, 160, 1024, 1091] {
            let tiles = tile_ranges(n);
            let mut next = 0;
            for &(lo, len) in &tiles {
                assert_eq!(lo, next, "tiles must be contiguous at n = {n}");
                assert!(len > 0 && len <= JACOBI_TILE);
                next = lo + len;
            }
            assert_eq!(next, n, "tiles must cover [0, n) at n = {n}");
            assert_eq!(tiles.len(), n.div_ceil(JACOBI_TILE));
        }
    }

    #[test]
    fn blocked_two_tile_edge_matches_serial() {
        // nt = 2 (80 = one full tile + a 16-wide tail): the single tile
        // pair spans the whole matrix, so the pivot subproblem IS the
        // matrix — the degenerate edge the ragged multi-tile sizes in
        // `tests/decomp_parity.rs` (130/160) don't reach
        let a = spd(80, 17);
        let (vb, lam_b) = jacobi_eigh_blocked(&a, 30);
        let (_, lam_s) = jacobi_eigh_serial(&a, 30);
        assert!(ortho_err(&vb) < 1e-3);
        let scale = lam_s[0].abs().max(1.0);
        for (got, want) in lam_b.iter().zip(&lam_s) {
            assert!((got - want).abs() < 1e-2 * scale, "{got} vs {want}");
        }
    }

    #[test]
    fn blocked_single_tile_falls_back_to_serial() {
        let a = spd(20, 18);
        let (vb, lb) = jacobi_eigh_blocked(&a, 30);
        let (vs, ls) = jacobi_eigh_serial(&a, 30);
        assert_eq!(vb.data, vs.data);
        assert_eq!(lb, ls);
    }

    #[test]
    fn non_finite_guard_is_exactly_sanitization() {
        // the guard's *semantics* (no-panic + orthonormality across
        // dispatch paths lives in `tests/decomp_parity.rs`): zeroing
        // exactly the contaminated symmetrized slots — the result is
        // bitwise the decomposition of that sanitized matrix
        let mut a = spd(12, 19);
        *a.at_mut(2, 5) = f32::NAN;
        *a.at_mut(7, 1) = f32::INFINITY;
        let (v, lam) = jacobi_eigh(&a, 30);
        let mut clean = a.clone();
        clean.symmetrize_();
        for x in clean.data.iter_mut() {
            if !x.is_finite() {
                *x = 0.0;
            }
        }
        let (vc, lc) = jacobi_eigh(&clean, 30);
        assert_eq!(v.data, vc.data);
        assert_eq!(lam, lc);
    }

    #[test]
    fn tiny_scale_spd_converges_on_the_serial_path() {
        // late-training GGᵀ scale: entries ~1e-12 sat below the old
        // absolute 1e-12 pivot cutoff, so refreshes no-opped to a stale
        // basis; the relative threshold must rotate like unit scale.
        // n = 12 pins the serial dispatch path (the rounds path lives in
        // `tests/decomp_parity.rs`).
        let a = spd(12, 21).scale(1e-12);
        let (v, lam) = jacobi_eigh(&a, 30);
        assert!(ortho_err(&v) < 1e-3);
        assert!(
            v.sub(&Mat::eye(12)).max_abs() > 0.1,
            "tiny-scale refresh must actually rotate the basis"
        );
        let mut vd = v.clone();
        for i in 0..v.rows {
            for j in 0..v.cols {
                *vd.at_mut(i, j) *= lam[j];
            }
        }
        let rec = vd.matmul_nt(&v);
        assert!(rec.sub(&a).max_abs() < 2e-3 * a.max_abs());
    }

    #[test]
    fn tiny_scale_qr_still_orthogonalizes() {
        let mut rng = Pcg::seeded(22);
        let a = Mat::from_vec(30, 8, rng.normal_vec(240, 1.0)).scale(1e-12);
        let q = mgs_qr(&a);
        assert!(ortho_err(&q) < 1e-4);
        // the columns must span the input, not the canonical fallback —
        // relative tolerance, or a zero Q would pass at this scale
        let rec = q.matmul(&q.matmul_tn(&a));
        assert!(rec.sub(&a).max_abs() < 1e-3 * a.max_abs());
    }

    #[test]
    fn off_fro_accumulates_in_f64() {
        // small n: exact agreement with hand-computed f64 sums
        let a = Mat::from_vec(3, 3, vec![2.0, 0.5, -1.0, 0.5, 3.0, 0.25, -1.0, 0.25, 4.0]);
        let (off, fro) = off_fro_sq(&a);
        let want_off = 0.25f64 + 1.0 + 0.0625;
        let want_fro = 4.0f64 + 9.0 + 16.0 + 2.0 * want_off;
        assert!((off - want_off).abs() < 1e-12);
        assert!((fro - want_fro).abs() < 1e-12);
    }

    #[test]
    fn convergence_check_sane_at_n_2048() {
        // pure diagonal: trivially converged, and the n²/2-term serial
        // f64 sum neither overflows nor drags (the f32 left-fold lost
        // low bits at exactly this size — ISSUE 5)
        let diag = Mat::from_fn(2048, 2048, |i, j| if i == j { 2.0 } else { 0.0 });
        assert!(off_diag_small(&diag));
        // uniform 1e-3 off-diagonal mass is far from converged
        let noisy = Mat::from_fn(2048, 2048, |i, j| if i == j { 2.0 } else { 1e-3 });
        assert!(!off_diag_small(&noisy));
    }

    #[test]
    fn round_schedule_covers_every_pair_once() {
        for n in [2usize, 5, 8, 13, 96] {
            let rounds = jacobi_rounds(n);
            let mut seen = vec![false; n * n];
            for pairs in &rounds {
                let mut used = vec![false; n];
                for &(p, q) in pairs {
                    assert!(p < q && q < n);
                    assert!(!used[p] && !used[q], "pair indices clash in a round");
                    used[p] = true;
                    used[q] = true;
                    assert!(!seen[p * n + q], "pair ({p},{q}) scheduled twice");
                    seen[p * n + q] = true;
                }
            }
            let covered = seen.iter().filter(|&&b| b).count();
            assert_eq!(covered, n * (n - 1) / 2, "n = {n}");
        }
    }

    #[test]
    fn subspace_finds_top_eigs() {
        let a = spd(16, 2);
        let (vf, lf) = jacobi_eigh(&a, 40);
        let _ = vf;
        let mut rng = Pcg::seeded(7);
        let u0 = random_orthonormal(16, 4, &mut rng);
        let (u, lam) = subspace_iter(&a, &u0, 25);
        assert!(ortho_err(&u) < 1e-3);
        for (got, want) in lam.iter().zip(&lf[..4]) {
            assert!((got - want).abs() < 1e-2 * want.abs().max(1.0),
                    "{got} vs {want}");
        }
    }

    #[test]
    fn complete_basis_is_complement() {
        let mut rng = Pcg::seeded(9);
        let u = random_orthonormal(14, 5, &mut rng);
        let uc = complete_basis(&u);
        assert_eq!(uc.cols, 9);
        assert!(ortho_err(&uc) < 1e-3);
        // Uᵀ U_c == 0
        let cross = u.matmul_tn(&uc);
        assert!(cross.max_abs() < 1e-3);
    }

    #[test]
    fn newton_schulz_roots() {
        let a = spd(10, 3);
        let (sq, isq) = newton_schulz(&a, 30);
        assert!(sq.matmul(&sq).sub(&a).max_abs() < 1e-2 * a.max_abs());
        let ident = isq.matmul(&a).matmul(&isq);
        assert!(ident.sub(&Mat::eye(10)).max_abs() < 1e-2);
    }

    #[test]
    fn whiten_orthogonalizes() {
        let mut rng = Pcg::seeded(4);
        let g = Mat::from_vec(8, 24, rng.normal_vec(192, 1.0));
        let w = whiten(&g, 30);
        let wwt = w.matmul_nt(&w);
        assert!(wwt.sub(&Mat::eye(8)).max_abs() < 5e-2);
    }

    #[test]
    fn inv_fourth_root_property() {
        let a = spd(8, 8);
        let r = inv_fourth_root(&a, 30);
        // (A^-¼)⁴ A ≈ I
        let r2 = r.matmul(&r);
        let r4 = r2.matmul(&r2);
        let ident = r4.matmul(&a);
        assert!(ident.sub(&Mat::eye(8)).max_abs() < 5e-2,
                "err {}", ident.sub(&Mat::eye(8)).max_abs());
    }
}
