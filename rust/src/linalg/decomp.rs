//! Decompositions: MGS QR, Jacobi eigendecomposition, subspace iteration
//! (paper Algorithm 10), Newton-Schulz roots (App. B.8).
//!
//! These are the substrate for the native optimizer suite (Eigen-Adam /
//! SOAP / Shampoo / GaLore / Alice refreshes) and for the `fisher` library.
//! Validated against known decompositions and reconstruction identities in
//! the unit tests below plus property tests in `testing`.
//!
//! # Threading
//!
//! The periodic subspace refreshes dominate wall clock at lm-head scale
//! (ROADMAP "Parallel decompositions"), so both workhorses fan out over
//! `util::pool`:
//!
//! * [`mgs_qr`] is right-looking: each step normalizes one column and
//!   projects it out of every trailing column — the projections are
//!   independent per column and fan out once the trailing work crosses
//!   [`QR_PAR_MIN_WORK`]. A full second pass re-orthogonalizes (MGS2).
//! * [`jacobi_eigh`] switches at [`JACOBI_PAR_MIN_N`] from the serial
//!   cyclic sweep ([`jacobi_eigh_serial`]) to parallel-ordered (Brent-Luk)
//!   sweeps: a round-robin schedule partitions each sweep into rounds of
//!   disjoint pivot pairs; per round, all rotation angles come from the
//!   round-start matrix and the column/row update phases fan out over
//!   row blocks / pairs.
//!
//! Determinism: every fan-out writes disjoint data with a fixed per-element
//! float-op order, algorithm selection and partitioning are pure functions
//! of the input shape, and the remaining reductions (norms, dot products)
//! run whole-slice on whichever thread owns the step — so both
//! decompositions are **bitwise identical at every pool width**, width 1
//! (the serial baseline) included. `rust/tests/decomp_parity.rs` pins this
//! down. The inner loops (column norms/dots/projections, both rotation
//! phases) route through `linalg::simd`; the reductions there use a fixed
//! lane tree that depends only on the slice length, so the width contract
//! holds per feature setting, with scalar↔simd drift ulp-bounded
//! (`tests/simd_parity.rs`). The convergence check stays a plain serial
//! sum under every setting — the early exit is part of the contract.

use crate::util::pool::{self, SendPtr};
use crate::util::Pcg;

use super::mat::Mat;
use super::simd;

const EPS: f32 = 1e-8;

/// Below this many trailing-projection elements (rows x trailing columns)
/// an MGS step stays on the calling thread. 4x higher with the `simd`
/// feature — the projections get ~4-8x cheaper per element, so the
/// break-even trailing block is larger.
const QR_PAR_MIN_WORK: usize = if cfg!(feature = "simd") { 1 << 16 } else { 1 << 14 };

/// Dimension at which `jacobi_eigh` switches from the serial cyclic sweep
/// to parallel-ordered rounds. Below it the rotation count is too small to
/// amortize even the persistent pool's ~µs dispatch.
const JACOBI_PAR_MIN_N: usize = 96;

/// Row-block grain (rows per task) for the Jacobi column-update phases.
const JACOBI_ROW_BLK: usize = 32;

/// Modified Gram-Schmidt with a full re-orthogonalization pass (MGS2).
/// Returns Q (m x r) with orthonormal columns; degenerate input columns
/// fall back to canonical directions projected off the accepted prefix
/// (so Q is always full rank).
pub fn mgs_qr(a: &Mat) -> Mat {
    let (m, r) = (a.rows, a.cols);
    assert!(r <= m, "mgs_qr needs tall input, got {m}x{r}");
    // column-major working set: the right-looking updates own whole
    // columns, so each fan-out task gets a contiguous &mut buffer
    let mut cols: Vec<Vec<f32>> = (0..r).map(|j| a.col_vec(j)).collect();
    mgs_pass(&mut cols, m);
    mgs_pass(&mut cols, m); // second pass restores orthonormality ("twice is enough")
    let mut q = Mat::zeros(m, r);
    for (j, c) in cols.iter().enumerate() {
        q.set_col(j, c);
    }
    q
}

/// One right-looking MGS sweep over `cols`. Step j normalizes column j
/// (serial — identical on every pool width), then projects it out of all
/// trailing columns; the projections touch disjoint columns with a fixed
/// per-column float-op order, so the fan-out is bitwise width-invariant.
fn mgs_pass(cols: &mut [Vec<f32>], m: usize) {
    let r = cols.len();
    for j in 0..r {
        let nrm = simd::sum_sq(&cols[j]).sqrt();
        if nrm > 1e-6 {
            for x in &mut cols[j] {
                *x /= nrm;
            }
        } else {
            // canonical fallback projected off the accepted prefix
            let mut fb = vec![0.0f32; m];
            fb[j % m] = 1.0;
            for jj in 0..j {
                let dot = simd::dot(&cols[jj], &fb);
                simd::axpy(&mut fb, -dot, &cols[jj]);
            }
            let fn_ = simd::sum_sq(&fb).sqrt() + EPS;
            for x in &mut fb {
                *x /= fn_;
            }
            cols[j] = fb;
        }
        let (head, tail) = cols.split_at_mut(j + 1);
        if tail.is_empty() {
            continue;
        }
        let qj = &head[j];
        let project = |c: &mut Vec<f32>| {
            let dot = simd::dot(qj, c);
            simd::axpy(c, -dot, qj);
        };
        if m * tail.len() >= QR_PAR_MIN_WORK {
            pool::map_mut(tail, |_, c| project(c));
        } else {
            for c in tail.iter_mut() {
                project(c);
            }
        }
    }
}

/// Eigendecomposition of a symmetric matrix: (V, λ) with columns of V
/// sorted by descending eigenvalue, A = V diag(λ) Vᵀ. Dispatches on size:
/// serial cyclic Jacobi below [`JACOBI_PAR_MIN_N`], parallel-ordered
/// Jacobi rounds at and above it.
pub fn jacobi_eigh(a: &Mat, sweeps: usize) -> (Mat, Vec<f32>) {
    if a.rows < JACOBI_PAR_MIN_N {
        jacobi_eigh_serial(a, sweeps)
    } else {
        jacobi_eigh_rounds(a, sweeps)
    }
}

/// Cyclic Jacobi eigendecomposition — the historical serial kernel, kept
/// as the baseline for the large-n parallel path (benches compare both).
pub fn jacobi_eigh_serial(a: &Mat, sweeps: usize) -> (Mat, Vec<f32>) {
    let n = a.rows;
    assert_eq!(n, a.cols);
    let mut w = a.clone();
    w.symmetrize_();
    let mut v = Mat::eye(n);
    for _ in 0..sweeps {
        if off_diag_small(&w) {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = w.at(p, q);
                if apq.abs() < 1e-12 {
                    continue;
                }
                let (c, s) = rotation(w.at(p, p), w.at(q, q), apq);
                // rotate rows/cols p, q of w
                for k in 0..n {
                    let wkp = w.at(k, p);
                    let wkq = w.at(k, q);
                    *w.at_mut(k, p) = c * wkp - s * wkq;
                    *w.at_mut(k, q) = s * wkp + c * wkq;
                }
                for k in 0..n {
                    let wpk = w.at(p, k);
                    let wqk = w.at(q, k);
                    *w.at_mut(p, k) = c * wpk - s * wqk;
                    *w.at_mut(q, k) = s * wpk + c * wqk;
                }
                for k in 0..n {
                    let vkp = v.at(k, p);
                    let vkq = v.at(k, q);
                    *v.at_mut(k, p) = c * vkp - s * vkq;
                    *v.at_mut(k, q) = s * vkp + c * vkq;
                }
            }
        }
    }
    sort_eigh(w, v)
}

/// Jacobi rotation (c, s) annihilating the (p, q) element, given the
/// diagonal pair and the off-diagonal value.
#[inline]
fn rotation(app: f32, aqq: f32, apq: f32) -> (f32, f32) {
    let theta = 0.5 * (aqq - app) / apq;
    let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
    let c = 1.0 / (t * t + 1.0).sqrt();
    (c, t * c)
}

/// Convergence check shared by both Jacobi variants. Single-pass serial
/// sums (never the pooled reductions): the early exit must be bitwise
/// width-invariant, and the pooled `fro_norm` regroups additions when the
/// matrix is large and the width is > 1.
fn off_diag_small(w: &Mat) -> bool {
    let n = w.rows;
    let mut off = 0.0f32;
    for p in 0..n {
        for q in (p + 1)..n {
            off += w.at(p, q) * w.at(p, q);
        }
    }
    let mut fro = 0.0f32;
    for &x in &w.data {
        fro += x * x;
    }
    off.sqrt() < 1e-9 * (1.0 + fro.sqrt())
}

/// Round-robin (circle method) pivot schedule: `n_rounds` rounds of
/// mutually disjoint (p, q) pairs covering every unordered pair exactly
/// once. A pure function of `n` — the schedule, and with it the float-op
/// order of a parallel sweep, never depends on the pool width.
fn jacobi_rounds(n: usize) -> Vec<Vec<(usize, usize)>> {
    let m = n + (n & 1); // pad odd n with a bye slot that pairs skip
    let mut circ: Vec<usize> = (0..m).collect();
    let mut rounds = Vec::with_capacity(m - 1);
    for _ in 0..m - 1 {
        let mut pairs = Vec::with_capacity(m / 2);
        for i in 0..m / 2 {
            let (a, b) = (circ[i], circ[m - 1 - i]);
            if a < n && b < n {
                pairs.push((a.min(b), a.max(b)));
            }
        }
        pairs.sort_unstable();
        rounds.push(pairs);
        circ[1..].rotate_right(1);
    }
    rounds
}

/// Parallel-ordered (Brent-Luk) Jacobi: each sweep walks the round-robin
/// schedule; per round all rotation angles come from the round-start
/// matrix and the update W ← Jᵀ (W J) (J = direct sum of the round's
/// rotations) is applied in two phases — columns, then rows — each fanned
/// out over disjoint data.
fn jacobi_eigh_rounds(a: &Mat, sweeps: usize) -> (Mat, Vec<f32>) {
    let n = a.rows;
    assert_eq!(n, a.cols);
    let mut w = a.clone();
    w.symmetrize_();
    let mut v = Mat::eye(n);
    let rounds = jacobi_rounds(n);
    for _ in 0..sweeps {
        if off_diag_small(&w) {
            break;
        }
        for pairs in &rounds {
            // angles from the round-start matrix; serial — O(n) per round
            let rot: Vec<Option<(f32, f32)>> = pairs
                .iter()
                .map(|&(p, q)| {
                    let apq = w.at(p, q);
                    if apq.abs() < 1e-12 {
                        return None;
                    }
                    Some(rotation(w.at(p, p), w.at(q, q), apq))
                })
                .collect();
            if rot.iter().all(|r| r.is_none()) {
                continue;
            }
            // column phase: W ← W J. Each row is owned by exactly one
            // task and applies the rotations in pair order — disjoint
            // writes, fixed order, bitwise width-invariant.
            apply_col_rotations(&mut w.data, n, pairs, &rot);
            // row phase: W ← Jᵀ W. Pairs own disjoint row pairs.
            let base = SendPtr(w.data.as_mut_ptr());
            pool::run(pairs.len(), |t| {
                if let Some((c, s)) = rot[t] {
                    let (p, q) = pairs[t];
                    // SAFETY: rounds hold each index in at most one pair,
                    // so rows p and q are touched by this task alone.
                    let rp = unsafe { std::slice::from_raw_parts_mut(base.0.add(p * n), n) };
                    let rq = unsafe { std::slice::from_raw_parts_mut(base.0.add(q * n), n) };
                    simd::rot2(rp, rq, c, s);
                }
            });
            // eigenvector phase: V ← V J, columns only.
            apply_col_rotations(&mut v.data, n, pairs, &rot);
        }
    }
    sort_eigh(w, v)
}

/// Apply one round's column rotations to a row-major n-column buffer,
/// fanning row blocks out over the pool. Within a block the kernel layer
/// picks the loop order (row-outer scalar, 8-row-strip SIMD) — the
/// round's pairs are disjoint, so every order writes the same bits.
fn apply_col_rotations(
    data: &mut [f32],
    n: usize,
    pairs: &[(usize, usize)],
    rot: &[Option<(f32, f32)>],
) {
    pool::for_each_chunk_mut(data, JACOBI_ROW_BLK * n, |_, rows| {
        simd::rot_cols_block(rows, n, pairs, rot);
    });
}

/// Shared epilogue: read eigenvalues off the diagonal and sort descending.
fn sort_eigh(w: Mat, v: Mat) -> (Mat, Vec<f32>) {
    let n = w.rows;
    let lam: Vec<f32> = (0..n).map(|i| w.at(i, i)).collect();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| lam[j].partial_cmp(&lam[i]).unwrap());
    let vs = Mat::from_fn(n, n, |i, j| v.at(i, order[j]));
    let lam = order.iter().map(|&i| lam[i]).collect();
    (vs, lam)
}

/// Subspace iteration (paper Algorithm 10): top-r eigenpairs of symmetric
/// `a`, warm-started at `u0` (m x r). The small r x r Rayleigh problem is
/// solved by Jacobi, as the paper's last two lines do with EVD.
pub fn subspace_iter(a: &Mat, u0: &Mat, iters: usize) -> (Mat, Vec<f32>) {
    let mut u = u0.clone();
    for _ in 0..iters.max(1) {
        u = mgs_qr(&a.matmul(&u));
    }
    let small = u.matmul_tn(&a.matmul(&u)); // Uᵀ A U
    let (w, lam) = jacobi_eigh(&small, 30);
    (u.matmul(&w), lam)
}

/// Orthonormal complement of U (m x r) → (m x (m-r)); the paper's `QR(U)`
/// (Algorithm 2 line 4). Deterministic construction from canonical vectors.
pub fn complete_basis(u: &Mat) -> Mat {
    let (m, r) = (u.rows, u.cols);
    assert!(r <= m);
    if r == m {
        return Mat::zeros(m, 0);
    }
    // Project ALL canonical vectors off U, pick the (m - r) with the largest
    // residuals, then MGS them (fallback covers degeneracies).
    let mut resid = Mat::eye(m); // columns e_k
    for k in 0..m {
        // e_k - U (Uᵀ e_k); Uᵀ e_k is column k of Uᵀ = row k of U
        let coeff: Vec<f32> = (0..r).map(|j| u.at(k, j)).collect();
        let corr = // U @ coeff
            (0..m).map(|i| {
                (0..r).map(|j| u.at(i, j) * coeff[j]).sum::<f32>()
            }).collect::<Vec<f32>>();
        for i in 0..m {
            *resid.at_mut(i, k) -= corr[i];
        }
    }
    let mut norms: Vec<(usize, f32)> = (0..m)
        .map(|k| {
            let n: f32 = (0..m).map(|i| resid.at(i, k).powi(2)).sum();
            (k, n)
        })
        .collect();
    norms.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    let picked: Vec<usize> = norms[..m - r].iter().map(|&(k, _)| k).collect();
    let cand = Mat::from_fn(m, m - r, |i, j| resid.at(i, picked[j]));
    mgs_qr(&cand)
}

/// One Newton-Schulz step (App. B.8).
pub fn ns_step(y: &Mat, z: &Mat) -> (Mat, Mat) {
    let n = y.rows;
    let mut t = Mat::eye(n).scale(3.0);
    let zy = z.matmul(y);
    t = t.sub(&zy);
    (y.matmul(&t).scale(0.5), t.matmul(z).scale(0.5))
}

/// Newton-Schulz: (√A, A^-½) for SPD A.
pub fn newton_schulz(a: &Mat, iters: usize) -> (Mat, Mat) {
    let fro = a.fro_norm() + EPS;
    let mut y = a.scale(1.0 / fro);
    let mut z = Mat::eye(a.rows);
    for _ in 0..iters {
        let (y2, z2) = ns_step(&y, &z);
        y = y2;
        z = z2;
    }
    (y.scale(fro.sqrt()), z.scale(1.0 / fro.sqrt()))
}

/// Whitening operator (Sec. 3.3): (GGᵀ)^-½ G. Expects rows <= cols.
pub fn whiten(g: &Mat, iters: usize) -> Mat {
    let m = g.rows;
    let mut a = g.matmul_nt(g);
    for i in 0..m {
        *a.at_mut(i, i) += 1e-4;
    }
    let (_, inv_sqrt) = newton_schulz(&a, iters);
    inv_sqrt.matmul(g)
}

/// A^-¼ via nested Newton-Schulz (Shampoo roots).
pub fn inv_fourth_root(a: &Mat, iters: usize) -> Mat {
    let (mut sqrt_a, _) = newton_schulz(a, iters);
    sqrt_a.symmetrize_();
    for i in 0..a.rows {
        *sqrt_a.at_mut(i, i) += 1e-6;
    }
    let (_, inv_sqrt) = newton_schulz(&sqrt_a, iters);
    inv_sqrt
}

/// Random orthonormal m x r (Gaussian + QR) — test helper and the
/// "gaussian" switching ablation.
pub fn random_orthonormal(m: usize, r: usize, rng: &mut Pcg) -> Mat {
    let g = Mat::from_vec(m, r, rng.normal_vec(m * r, 1.0));
    mgs_qr(&g)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spd(n: usize, seed: u64) -> Mat {
        let mut rng = Pcg::seeded(seed);
        let b = Mat::from_vec(n, n, rng.normal_vec(n * n, 1.0));
        let mut a = b.matmul_nt(&b);
        for i in 0..n {
            *a.at_mut(i, i) += 0.5;
        }
        a
    }

    fn ortho_err(q: &Mat) -> f32 {
        let qtq = q.matmul_tn(q);
        qtq.sub(&Mat::eye(q.cols)).max_abs()
    }

    #[test]
    fn qr_orthonormal() {
        let mut rng = Pcg::seeded(5);
        let a = Mat::from_vec(30, 8, rng.normal_vec(240, 1.0));
        let q = mgs_qr(&a);
        assert!(ortho_err(&q) < 1e-4);
    }

    #[test]
    fn qr_handles_rank_deficiency() {
        // two identical columns: second must fall back, Q stays orthonormal
        let mut rng = Pcg::seeded(6);
        let c = rng.normal_vec(20, 1.0);
        let a = Mat::from_vec(20, 2, {
            // interleave into row-major (20 x 2)
            let mut v = vec![0.0; 40];
            for i in 0..20 {
                v[2 * i] = c[i];
                v[2 * i + 1] = c[i];
            }
            v
        });
        let q = mgs_qr(&a);
        assert!(ortho_err(&q) < 1e-3);
    }

    #[test]
    fn qr_spans_the_input() {
        // Q Qᵀ a == a for full-rank tall input (same column span)
        let mut rng = Pcg::seeded(15);
        let a = Mat::from_vec(25, 6, rng.normal_vec(150, 1.0));
        let q = mgs_qr(&a);
        let rec = q.matmul(&q.matmul_tn(&a));
        assert!(rec.sub(&a).max_abs() < 1e-3 * (1.0 + a.max_abs()));
    }

    #[test]
    fn jacobi_reconstructs() {
        let a = spd(12, 1);
        let (v, lam) = jacobi_eigh(&a, 30);
        assert!(ortho_err(&v) < 1e-4);
        // V diag(lam) Vᵀ == A
        let mut vd = v.clone();
        for i in 0..v.rows {
            for j in 0..v.cols {
                *vd.at_mut(i, j) *= lam[j];
            }
        }
        let rec = vd.matmul_nt(&v);
        assert!(rec.sub(&a).max_abs() < 1e-3 * a.max_abs());
        // sorted descending
        for w in lam.windows(2) {
            assert!(w[0] >= w[1] - 1e-5);
        }
    }

    #[test]
    fn parallel_ordered_jacobi_matches_cyclic() {
        // above the dispatch threshold the rounds path takes over; its
        // eigenvalues must agree with the serial cyclic baseline
        let n = JACOBI_PAR_MIN_N + 4;
        let a = spd(n, 13);
        let (v, lam) = jacobi_eigh(&a, 30);
        let (_, lam_serial) = jacobi_eigh_serial(&a, 30);
        assert!(ortho_err(&v) < 1e-3);
        let scale = lam_serial[0].abs().max(1.0);
        for (got, want) in lam.iter().zip(&lam_serial) {
            assert!((got - want).abs() < 1e-2 * scale, "{got} vs {want}");
        }
        // reconstruction on the parallel path
        let mut vd = v.clone();
        for i in 0..v.rows {
            for j in 0..v.cols {
                *vd.at_mut(i, j) *= lam[j];
            }
        }
        let rec = vd.matmul_nt(&v);
        assert!(rec.sub(&a).max_abs() < 1e-3 * a.max_abs());
    }

    #[test]
    fn round_schedule_covers_every_pair_once() {
        for n in [2usize, 5, 8, 13, 96] {
            let rounds = jacobi_rounds(n);
            let mut seen = vec![false; n * n];
            for pairs in &rounds {
                let mut used = vec![false; n];
                for &(p, q) in pairs {
                    assert!(p < q && q < n);
                    assert!(!used[p] && !used[q], "pair indices clash in a round");
                    used[p] = true;
                    used[q] = true;
                    assert!(!seen[p * n + q], "pair ({p},{q}) scheduled twice");
                    seen[p * n + q] = true;
                }
            }
            let covered = seen.iter().filter(|&&b| b).count();
            assert_eq!(covered, n * (n - 1) / 2, "n = {n}");
        }
    }

    #[test]
    fn subspace_finds_top_eigs() {
        let a = spd(16, 2);
        let (vf, lf) = jacobi_eigh(&a, 40);
        let _ = vf;
        let mut rng = Pcg::seeded(7);
        let u0 = random_orthonormal(16, 4, &mut rng);
        let (u, lam) = subspace_iter(&a, &u0, 25);
        assert!(ortho_err(&u) < 1e-3);
        for (got, want) in lam.iter().zip(&lf[..4]) {
            assert!((got - want).abs() < 1e-2 * want.abs().max(1.0),
                    "{got} vs {want}");
        }
    }

    #[test]
    fn complete_basis_is_complement() {
        let mut rng = Pcg::seeded(9);
        let u = random_orthonormal(14, 5, &mut rng);
        let uc = complete_basis(&u);
        assert_eq!(uc.cols, 9);
        assert!(ortho_err(&uc) < 1e-3);
        // Uᵀ U_c == 0
        let cross = u.matmul_tn(&uc);
        assert!(cross.max_abs() < 1e-3);
    }

    #[test]
    fn newton_schulz_roots() {
        let a = spd(10, 3);
        let (sq, isq) = newton_schulz(&a, 30);
        assert!(sq.matmul(&sq).sub(&a).max_abs() < 1e-2 * a.max_abs());
        let ident = isq.matmul(&a).matmul(&isq);
        assert!(ident.sub(&Mat::eye(10)).max_abs() < 1e-2);
    }

    #[test]
    fn whiten_orthogonalizes() {
        let mut rng = Pcg::seeded(4);
        let g = Mat::from_vec(8, 24, rng.normal_vec(192, 1.0));
        let w = whiten(&g, 30);
        let wwt = w.matmul_nt(&w);
        assert!(wwt.sub(&Mat::eye(8)).max_abs() < 5e-2);
    }

    #[test]
    fn inv_fourth_root_property() {
        let a = spd(8, 8);
        let r = inv_fourth_root(&a, 30);
        // (A^-¼)⁴ A ≈ I
        let r2 = r.matmul(&r);
        let r4 = r2.matmul(&r2);
        let ident = r4.matmul(&a);
        assert!(ident.sub(&Mat::eye(8)).max_abs() < 5e-2,
                "err {}", ident.sub(&Mat::eye(8)).max_abs());
    }
}
