//! Dense row-major f32 matrix with the operations the optimizer suite
//! needs. Hot paths (`matmul`, `matmul_tn`, `matmul_nt`) are blocked for
//! cache locality — see EXPERIMENTS.md §Perf for measurements.

use std::fmt;

/// Row-major dense matrix.
#[derive(Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Mat[{}x{}]", self.rows, self.cols)
    }
}

/// Cache block edge for the matmul kernels (f32: 64*64*4 = 16 KiB/tile).
const BLK: usize = 64;

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(rows * cols, data.len(), "shape/data mismatch");
        Mat { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        &mut self.data[i * self.cols + j]
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn col_vec(&self, j: usize) -> Vec<f32> {
        (0..self.rows).map(|i| self.at(i, j)).collect()
    }

    pub fn set_col(&mut self, j: usize, v: &[f32]) {
        assert_eq!(v.len(), self.rows);
        for i in 0..self.rows {
            *self.at_mut(i, j) = v[i];
        }
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        t
    }

    // ---------------------------------------------------------- matmul ---
    /// C = A @ B, blocked i-k-j loop (unit-stride inner loop).
    pub fn matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows, "matmul {self:?} @ {b:?}");
        let (m, k, n) = (self.rows, self.cols, b.cols);
        let mut c = Mat::zeros(m, n);
        for i0 in (0..m).step_by(BLK) {
            for k0 in (0..k).step_by(BLK) {
                for j0 in (0..n).step_by(BLK) {
                    let i1 = (i0 + BLK).min(m);
                    let k1 = (k0 + BLK).min(k);
                    let j1 = (j0 + BLK).min(n);
                    for i in i0..i1 {
                        let arow = &self.data[i * k..(i + 1) * k];
                        let crow = &mut c.data[i * n..(i + 1) * n];
                        for kk in k0..k1 {
                            let a = arow[kk];
                            if a == 0.0 {
                                continue;
                            }
                            let brow = &b.data[kk * n..(kk + 1) * n];
                            for j in j0..j1 {
                                crow[j] += a * brow[j];
                            }
                        }
                    }
                }
            }
        }
        c
    }

    /// C = Aᵀ @ B without materializing Aᵀ (A is self).
    pub fn matmul_tn(&self, b: &Mat) -> Mat {
        assert_eq!(self.rows, b.rows, "matmul_tn {self:?} ᵀ@ {b:?}");
        let (k, m, n) = (self.rows, self.cols, b.cols);
        let mut c = Mat::zeros(m, n);
        for kk in 0..k {
            let arow = &self.data[kk * m..(kk + 1) * m];
            let brow = &b.data[kk * n..(kk + 1) * n];
            for i in 0..m {
                let a = arow[i];
                if a == 0.0 {
                    continue;
                }
                let crow = &mut c.data[i * n..(i + 1) * n];
                for j in 0..n {
                    crow[j] += a * brow[j];
                }
            }
        }
        c
    }

    /// C = A @ Bᵀ without materializing Bᵀ.
    pub fn matmul_nt(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.cols, "matmul_nt {self:?} @ᵀ {b:?}");
        let (m, k, n) = (self.rows, self.cols, b.rows);
        let mut c = Mat::zeros(m, n);
        for i in 0..m {
            let arow = &self.data[i * k..(i + 1) * k];
            let crow = &mut c.data[i * n..(i + 1) * n];
            for j in 0..n {
                let brow = &b.data[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for kk in 0..k {
                    acc += arow[kk] * brow[kk];
                }
                crow[j] = acc;
            }
        }
        c
    }

    /// y = A @ x.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(self.cols, x.len());
        (0..self.rows)
            .map(|i| {
                let row = self.row(i);
                row.iter().zip(x).map(|(a, b)| a * b).sum()
            })
            .collect()
    }

    // ------------------------------------------------------ elementwise ---
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    pub fn zip(&self, other: &Mat, f: impl Fn(f32, f32) -> f32) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(&a, &b)| f(a, b))
                .collect(),
        }
    }

    pub fn scale(&self, s: f32) -> Mat {
        self.map(|x| x * s)
    }

    pub fn add(&self, other: &Mat) -> Mat {
        self.zip(other, |a, b| a + b)
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        self.zip(other, |a, b| a - b)
    }

    /// self ← a*self + b*other (EMA update, in place, no allocation).
    pub fn ema_(&mut self, a: f32, other: &Mat, b: f32) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (x, &y) in self.data.iter_mut().zip(&other.data) {
            *x = a * *x + b * y;
        }
    }

    pub fn fro_norm(&self) -> f32 {
        self.data.iter().map(|&x| x * x).sum::<f32>().sqrt()
    }

    pub fn fro_norm_sq(&self) -> f32 {
        self.data.iter().map(|&x| x * x).sum::<f32>()
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// Squared column l2 norms (the `S` of the normalization operator,
    /// Sec. 3.3).
    pub fn col_sq_norms(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.cols];
        for i in 0..self.rows {
            let row = self.row(i);
            for (o, &x) in out.iter_mut().zip(row) {
                *o += x * x;
            }
        }
        out
    }

    /// Squared row l2 norms.
    pub fn row_sq_norms(&self) -> Vec<f32> {
        (0..self.rows)
            .map(|i| self.row(i).iter().map(|&x| x * x).sum())
            .collect()
    }

    pub fn diag(&self) -> Vec<f32> {
        (0..self.rows.min(self.cols)).map(|i| self.at(i, i)).collect()
    }

    /// Symmetrize in place: (A + Aᵀ)/2.
    pub fn symmetrize_(&mut self) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                let avg = 0.5 * (self.at(i, j) + self.at(j, i));
                *self.at_mut(i, j) = avg;
                *self.at_mut(j, i) = avg;
            }
        }
    }

    /// First `r` columns as a new matrix.
    pub fn take_cols(&self, r: usize) -> Mat {
        assert!(r <= self.cols);
        Mat::from_fn(self.rows, r, |i, j| self.at(i, j))
    }

    /// Horizontal concatenation.
    pub fn hcat(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows);
        Mat::from_fn(self.rows, self.cols + other.cols, |i, j| {
            if j < self.cols {
                self.at(i, j)
            } else {
                other.at(i, j - self.cols)
            }
        })
    }

    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn approx(a: &Mat, b: &Mat, tol: f32) -> bool {
        a.rows == b.rows
            && a.cols == b.cols
            && a.data
                .iter()
                .zip(&b.data)
                .all(|(x, y)| (x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())))
    }

    #[test]
    fn matmul_identity() {
        let a = Mat::from_fn(5, 7, |i, j| (i * 7 + j) as f32);
        assert!(approx(&a.matmul(&Mat::eye(7)), &a, 1e-6));
        assert!(approx(&Mat::eye(5).matmul(&a), &a, 1e-6));
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Mat::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_blocked_matches_naive_large() {
        // exercise the blocked path across block boundaries
        let mut rng = crate::util::Pcg::seeded(11);
        let a = Mat::from_vec(70, 130, rng.normal_vec(70 * 130, 1.0));
        let b = Mat::from_vec(130, 90, rng.normal_vec(130 * 90, 1.0));
        let c = a.matmul(&b);
        let mut naive = Mat::zeros(70, 90);
        for i in 0..70 {
            for j in 0..90 {
                let mut acc = 0.0;
                for k in 0..130 {
                    acc += a.at(i, k) * b.at(k, j);
                }
                *naive.at_mut(i, j) = acc;
            }
        }
        assert!(approx(&c, &naive, 1e-4));
    }

    #[test]
    fn matmul_tn_nt_match_transpose() {
        let mut rng = crate::util::Pcg::seeded(3);
        let a = Mat::from_vec(20, 30, rng.normal_vec(600, 1.0));
        let b = Mat::from_vec(20, 10, rng.normal_vec(200, 1.0));
        assert!(approx(&a.matmul_tn(&b), &a.transpose().matmul(&b), 1e-4));
        let c = Mat::from_vec(40, 30, rng.normal_vec(1200, 1.0));
        assert!(approx(&a.matmul_nt(&c), &a.matmul(&c.transpose()), 1e-4));
    }

    #[test]
    fn norms_and_reductions() {
        let a = Mat::from_vec(2, 2, vec![3., 0., 0., 4.]);
        assert!((a.fro_norm() - 5.0).abs() < 1e-6);
        assert_eq!(a.col_sq_norms(), vec![9.0, 16.0]);
        assert_eq!(a.row_sq_norms(), vec![9.0, 16.0]);
        assert_eq!(a.diag(), vec![3.0, 4.0]);
    }

    #[test]
    fn ema_inplace() {
        let mut a = Mat::from_vec(1, 3, vec![1., 1., 1.]);
        let b = Mat::from_vec(1, 3, vec![2., 4., 6.]);
        a.ema_(0.5, &b, 0.5);
        assert_eq!(a.data, vec![1.5, 2.5, 3.5]);
    }

    #[test]
    fn hcat_take_cols() {
        let a = Mat::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let b = Mat::from_vec(2, 1, vec![9., 8.]);
        let c = a.hcat(&b);
        assert_eq!(c.cols, 3);
        assert_eq!(c.at(0, 2), 9.0);
        assert_eq!(c.take_cols(2).data, a.data);
    }

    #[test]
    fn transpose_involution() {
        let a = Mat::from_fn(3, 5, |i, j| (i + 2 * j) as f32);
        assert!(approx(&a.transpose().transpose(), &a, 0.0));
    }
}
