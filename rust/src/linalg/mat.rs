//! Dense row-major f32 matrix with the operations the optimizer suite
//! needs. Hot paths (`matmul`, `matmul_tn`, `matmul_nt`) are blocked for
//! cache locality — see EXPERIMENTS.md §Perf for measurements.
//!
//! # Threading
//!
//! The matmul family, `transpose`, and the elementwise/reduction family
//! fan out over `util::pool` when the work is large enough
//! ([`PAR_MIN_FLOPS`] / [`PAR_CHUNK`]). Determinism contract:
//!
//! * `matmul` / `matmul_tn` / `matmul_nt` / `transpose` and every
//!   elementwise op partition the *output* by row block or fixed-size
//!   chunk; each element's float-op order matches the serial loop, so
//!   results are **bitwise identical for every thread count**.
//! * Reductions (`fro_norm*`, `col_sq_norms`) combine fixed-size partial
//!   sums in partition order when parallel — deterministic for any pool
//!   width > 1, and exactly the historical single-pass order at width 1.
//!   (`max_abs` and `row_sq_norms` are order-insensitive / per-row, so
//!   they too are bitwise stable.)
//!
//! # SIMD
//!
//! The inner loops route through `linalg::simd`: scalar (the historical
//! loops) without the `simd` cargo feature, 8-lane tiled kernels with it.
//! `matmul` additionally swaps its whole block kernel for a packed
//! register-blocked microkernel ([`simd::matmul_block_packed`]; the
//! blocked-Jacobi tile rotations in `linalg::decomp` ride the same
//! microkernel through [`simd::matmul_into`]). Per
//! feature setting every guarantee above is unchanged — the width
//! contract is about partitioning and per-element op order, and neither
//! depends on the lane count. Scalar↔simd drift is ulp-bounded and pinned
//! by `tests/simd_parity.rs`; the vertical (elementwise) kernels don't
//! drift at all. (`map`/`zip` take arbitrary closures, which no lane
//! kernel can see through — they keep the chunked pool fan-out only,
//! while `scale`/`add`/`sub`/`ema_` route through dedicated kernels.)

use std::fmt;

use crate::util::pool;

use super::simd;

/// Row-major dense matrix.
#[derive(Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Mat[{}x{}]", self.rows, self.cols)
    }
}

/// Cache block edge for the matmul kernels (f32: 64*64*4 = 16 KiB/tile).
/// Doubles as the row-block grain of the parallel partitioning.
const BLK: usize = 64;

/// Below this many multiply-adds a matmul-family kernel stays on the
/// calling thread. The persistent pool dispatches in ~µs (queue push +
/// wake of parked workers), so the bar is 4x lower than under the old
/// per-region `thread::scope` spawning — medium matrices now fan out.
/// With the `simd` feature the per-element cost drops ~4-8x, so the
/// break-even work size rises 4x (thresholds are per-feature constants —
/// never runtime state — keeping partitioning a pure function of shape).
const PAR_MIN_FLOPS: usize = if cfg!(feature = "simd") { 1 << 19 } else { 1 << 17 };

/// Below this many elements the elementwise/reduction family stays on the
/// calling thread (same dispatch-cost argument as [`PAR_MIN_FLOPS`]).
const PAR_MIN_ELEMS: usize = if cfg!(feature = "simd") { 1 << 18 } else { 1 << 16 };

/// Elementwise/reduction chunk grain (elements). Fixed, so partials
/// combine identically for every pool width.
const PAR_CHUNK: usize = 1 << 14;

/// Chunk grain for elementwise ops: one chunk (= inline serial) below the
/// dispatch threshold, fixed [`PAR_CHUNK`] pieces above it. Elementwise
/// results are bitwise independent of the grain.
fn elem_grain(len: usize) -> usize {
    if len < PAR_MIN_ELEMS {
        len.max(1)
    } else {
        PAR_CHUNK
    }
}

/// Chunked sum of squares: serial single pass at width 1 (historical
/// behavior) and below the dispatch threshold, fixed-chunk partials
/// combined in order otherwise. (Callers that need bitwise width
/// invariance — the decomposition convergence checks — keep their own
/// serial sums instead; see `linalg::decomp`.)
fn sum_sq(data: &[f32]) -> f32 {
    if pool::threads() <= 1 || data.len() < PAR_MIN_ELEMS {
        return simd::sum_sq(data);
    }
    let n = data.len().div_ceil(PAR_CHUNK);
    let parts = pool::map(n, |i| {
        let lo = i * PAR_CHUNK;
        let hi = (lo + PAR_CHUNK).min(data.len());
        simd::sum_sq(&data[lo..hi])
    });
    parts.iter().sum()
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(rows * cols, data.len(), "shape/data mismatch");
        Mat { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        &mut self.data[i * self.cols + j]
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Column j as a contiguous vector (strided gather — the QR working
    /// set and `kron::vec_cols` share the same helper).
    pub fn col_vec(&self, j: usize) -> Vec<f32> {
        let mut out = vec![0.0; self.rows];
        if self.rows > 0 {
            simd::gather_stride(&mut out, &self.data[j..], self.cols);
        }
        out
    }

    /// Write `v` into column j (strided scatter).
    pub fn set_col(&mut self, j: usize, v: &[f32]) {
        assert_eq!(v.len(), self.rows);
        if self.rows > 0 {
            let cols = self.cols;
            simd::scatter_stride(&mut self.data[j..], cols, v);
        }
    }

    pub fn transpose(&self) -> Mat {
        let (m, n) = (self.rows, self.cols);
        let mut t = Mat::zeros(n, m);
        if m == 0 || n == 0 {
            return t;
        }
        // output rows (= input columns) partition; pure writes, so any
        // pool width produces identical bytes
        let rows_per = if m * n < PAR_MIN_ELEMS { n } else { BLK };
        pool::for_each_chunk_mut(&mut t.data, rows_per * m, |bi, trows| {
            let j0 = bi * rows_per;
            for (rj, trow) in trows.chunks_mut(m).enumerate() {
                let j = j0 + rj;
                for (i, ti) in trow.iter_mut().enumerate() {
                    *ti = self.data[i * n + j];
                }
            }
        });
        t
    }

    // ---------------------------------------------------------- matmul ---
    /// One output row-block of C = A @ B: rows [i0, i0 + nrows) with the
    /// same blocked k0-major / j0-inner loop order as the historical
    /// serial kernel, so per-element accumulation order never changes.
    fn matmul_block(&self, b: &Mat, i0: usize, crows: &mut [f32]) {
        let (k, n) = (self.cols, b.cols);
        let i1 = i0 + crows.len() / n;
        for k0 in (0..k).step_by(BLK) {
            let k1 = (k0 + BLK).min(k);
            for j0 in (0..n).step_by(BLK) {
                let j1 = (j0 + BLK).min(n);
                for i in i0..i1 {
                    let arow = &self.data[i * k..(i + 1) * k];
                    let crow = &mut crows[(i - i0) * n..(i - i0 + 1) * n];
                    for kk in k0..k1 {
                        let a = arow[kk];
                        if a == 0.0 {
                            continue;
                        }
                        let brow = &b.data[kk * n..(kk + 1) * n];
                        for j in j0..j1 {
                            crow[j] += a * brow[j];
                        }
                    }
                }
            }
        }
    }

    /// C = A @ B, blocked i-k-j loop (unit-stride inner loop); row blocks
    /// of C fan out over the pool. On the SIMD path each row-block task
    /// runs the packed 8-wide microkernel instead (selected once per
    /// call, on the submitting thread, so a whole product is always one
    /// kernel family).
    pub fn matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows, "matmul {self:?} @ {b:?}");
        let (m, k, n) = (self.rows, self.cols, b.cols);
        let mut c = Mat::zeros(m, n);
        if m == 0 || n == 0 {
            return c;
        }
        let packed = simd::active();
        let rows_per = if m * k * n < PAR_MIN_FLOPS { m } else { BLK };
        pool::for_each_chunk_mut(&mut c.data, rows_per * n, |bi, crows| {
            let i0 = bi * rows_per;
            if packed {
                let i1 = i0 + crows.len() / n;
                simd::matmul_block_packed(crows, &self.data[i0 * k..i1 * k], &b.data, k, n);
            } else {
                self.matmul_block(b, i0, crows);
            }
        });
        c
    }

    /// C = Aᵀ @ B without materializing Aᵀ (A is self). Row blocks of C
    /// fan out; each element still accumulates in ascending-k order,
    /// matching the historical kk-outer serial loop bit for bit.
    pub fn matmul_tn(&self, b: &Mat) -> Mat {
        assert_eq!(self.rows, b.rows, "matmul_tn {self:?} ᵀ@ {b:?}");
        let (k, m, n) = (self.rows, self.cols, b.cols);
        let mut c = Mat::zeros(m, n);
        if m == 0 || n == 0 {
            return c;
        }
        let rows_per = if k * m * n < PAR_MIN_FLOPS { m } else { BLK };
        pool::for_each_chunk_mut(&mut c.data, rows_per * n, |bi, crows| {
            let i0 = bi * rows_per;
            let i1 = i0 + crows.len() / n;
            for kk in 0..k {
                let arow = &self.data[kk * m..(kk + 1) * m];
                let brow = &b.data[kk * n..(kk + 1) * n];
                for i in i0..i1 {
                    let a = arow[i];
                    if a == 0.0 {
                        continue;
                    }
                    let crow = &mut crows[(i - i0) * n..(i - i0 + 1) * n];
                    simd::axpy(crow, a, brow);
                }
            }
        });
        c
    }

    /// C = A @ Bᵀ without materializing Bᵀ. Independent dot products per
    /// output element; row blocks fan out.
    pub fn matmul_nt(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.cols, "matmul_nt {self:?} @ᵀ {b:?}");
        let (m, k, n) = (self.rows, self.cols, b.rows);
        let mut c = Mat::zeros(m, n);
        if m == 0 || n == 0 {
            return c;
        }
        let rows_per = if m * k * n < PAR_MIN_FLOPS { m } else { BLK };
        pool::for_each_chunk_mut(&mut c.data, rows_per * n, |bi, crows| {
            let i0 = bi * rows_per;
            for (ri, crow) in crows.chunks_mut(n).enumerate() {
                let arow = &self.data[(i0 + ri) * k..(i0 + ri + 1) * k];
                for (j, cj) in crow.iter_mut().enumerate() {
                    *cj = simd::dot(arow, &b.data[j * k..(j + 1) * k]);
                }
            }
        });
        c
    }

    /// y = A @ x.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(self.cols, x.len());
        (0..self.rows).map(|i| simd::dot(self.row(i), x)).collect()
    }

    // ------------------------------------------------------ elementwise ---
    pub fn map(&self, f: impl Fn(f32) -> f32 + Sync) -> Mat {
        let mut out = Mat::zeros(self.rows, self.cols);
        let grain = elem_grain(out.data.len());
        pool::for_each_chunk_mut(&mut out.data, grain, |ci, chunk| {
            let lo = ci * grain;
            for (o, &x) in chunk.iter_mut().zip(&self.data[lo..lo + chunk.len()]) {
                *o = f(x);
            }
        });
        out
    }

    pub fn zip(&self, other: &Mat, f: impl Fn(f32, f32) -> f32 + Sync) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let mut out = Mat::zeros(self.rows, self.cols);
        let grain = elem_grain(out.data.len());
        pool::for_each_chunk_mut(&mut out.data, grain, |ci, chunk| {
            let lo = ci * grain;
            for ((o, &a), &b) in chunk
                .iter_mut()
                .zip(&self.data[lo..lo + chunk.len()])
                .zip(&other.data[lo..lo + chunk.len()])
            {
                *o = f(a, b);
            }
        });
        out
    }

    pub fn scale(&self, s: f32) -> Mat {
        let mut out = Mat::zeros(self.rows, self.cols);
        let grain = elem_grain(out.data.len());
        pool::for_each_chunk_mut(&mut out.data, grain, |ci, chunk| {
            let lo = ci * grain;
            simd::scale_into(chunk, &self.data[lo..lo + chunk.len()], s);
        });
        out
    }

    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let mut out = Mat::zeros(self.rows, self.cols);
        let grain = elem_grain(out.data.len());
        pool::for_each_chunk_mut(&mut out.data, grain, |ci, chunk| {
            let lo = ci * grain;
            let hi = lo + chunk.len();
            simd::add_into(chunk, &self.data[lo..hi], &other.data[lo..hi]);
        });
        out
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let mut out = Mat::zeros(self.rows, self.cols);
        let grain = elem_grain(out.data.len());
        pool::for_each_chunk_mut(&mut out.data, grain, |ci, chunk| {
            let lo = ci * grain;
            let hi = lo + chunk.len();
            simd::sub_into(chunk, &self.data[lo..hi], &other.data[lo..hi]);
        });
        out
    }

    /// self ← a*self + b*other (EMA update, in place, no allocation).
    pub fn ema_(&mut self, a: f32, other: &Mat, b: f32) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let rhs = &other.data;
        let grain = elem_grain(rhs.len());
        pool::for_each_chunk_mut(&mut self.data, grain, |ci, chunk| {
            let lo = ci * grain;
            simd::ema(chunk, a, &rhs[lo..lo + chunk.len()], b);
        });
    }

    pub fn fro_norm(&self) -> f32 {
        sum_sq(&self.data).sqrt()
    }

    pub fn fro_norm_sq(&self) -> f32 {
        sum_sq(&self.data)
    }

    pub fn max_abs(&self) -> f32 {
        if pool::threads() <= 1 || self.data.len() < PAR_MIN_ELEMS {
            return simd::max_abs(&self.data);
        }
        let n = self.data.len().div_ceil(PAR_CHUNK);
        let parts = pool::map(n, |i| {
            let lo = i * PAR_CHUNK;
            let hi = (lo + PAR_CHUNK).min(self.data.len());
            simd::max_abs(&self.data[lo..hi])
        });
        parts.iter().fold(0.0f32, |m, &x| m.max(x))
    }

    /// Squared column l2 norms (the `S` of the normalization operator,
    /// Sec. 3.3).
    pub fn col_sq_norms(&self) -> Vec<f32> {
        if pool::threads() <= 1 || self.rows * self.cols < PAR_MIN_ELEMS {
            let mut out = vec![0.0f32; self.cols];
            for i in 0..self.rows {
                simd::sq_accum(&mut out, self.row(i));
            }
            return out;
        }
        let nb = self.rows.div_ceil(BLK);
        let parts = pool::map(nb, |bi| {
            let mut out = vec![0.0f32; self.cols];
            for i in bi * BLK..((bi + 1) * BLK).min(self.rows) {
                simd::sq_accum(&mut out, self.row(i));
            }
            out
        });
        let mut out = vec![0.0f32; self.cols];
        for part in parts {
            // block-ascending combine: deterministic for any pool width
            for (o, v) in out.iter_mut().zip(part) {
                *o += v;
            }
        }
        out
    }

    /// Squared row l2 norms.
    pub fn row_sq_norms(&self) -> Vec<f32> {
        if pool::threads() <= 1 || self.rows * self.cols < PAR_MIN_ELEMS {
            return (0..self.rows).map(|i| simd::sum_sq(self.row(i))).collect();
        }
        let nb = self.rows.div_ceil(BLK);
        let parts = pool::map(nb, |bi| {
            (bi * BLK..((bi + 1) * BLK).min(self.rows))
                .map(|i| simd::sum_sq(self.row(i)))
                .collect::<Vec<f32>>()
        });
        parts.concat()
    }

    pub fn diag(&self) -> Vec<f32> {
        (0..self.rows.min(self.cols)).map(|i| self.at(i, i)).collect()
    }

    /// Symmetrize in place: (A + Aᵀ)/2.
    pub fn symmetrize_(&mut self) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                let avg = 0.5 * (self.at(i, j) + self.at(j, i));
                *self.at_mut(i, j) = avg;
                *self.at_mut(j, i) = avg;
            }
        }
    }

    /// First `r` columns as a new matrix.
    pub fn take_cols(&self, r: usize) -> Mat {
        assert!(r <= self.cols);
        Mat::from_fn(self.rows, r, |i, j| self.at(i, j))
    }

    /// Horizontal concatenation.
    pub fn hcat(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows);
        Mat::from_fn(self.rows, self.cols + other.cols, |i, j| {
            if j < self.cols {
                self.at(i, j)
            } else {
                other.at(i, j - self.cols)
            }
        })
    }

    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::pool;

    fn approx(a: &Mat, b: &Mat, tol: f32) -> bool {
        a.rows == b.rows
            && a.cols == b.cols
            && a.data
                .iter()
                .zip(&b.data)
                .all(|(x, y)| (x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())))
    }

    #[test]
    fn matmul_identity() {
        let a = Mat::from_fn(5, 7, |i, j| (i * 7 + j) as f32);
        assert!(approx(&a.matmul(&Mat::eye(7)), &a, 1e-6));
        assert!(approx(&Mat::eye(5).matmul(&a), &a, 1e-6));
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Mat::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_blocked_matches_naive_large() {
        // exercise the blocked path across block boundaries
        let mut rng = crate::util::Pcg::seeded(11);
        let a = Mat::from_vec(70, 130, rng.normal_vec(70 * 130, 1.0));
        let b = Mat::from_vec(130, 90, rng.normal_vec(130 * 90, 1.0));
        let c = a.matmul(&b);
        let mut naive = Mat::zeros(70, 90);
        for i in 0..70 {
            for j in 0..90 {
                let mut acc = 0.0;
                for k in 0..130 {
                    acc += a.at(i, k) * b.at(k, j);
                }
                *naive.at_mut(i, j) = acc;
            }
        }
        assert!(approx(&c, &naive, 1e-4));
    }

    #[test]
    fn matmul_tn_nt_match_transpose() {
        let mut rng = crate::util::Pcg::seeded(3);
        let a = Mat::from_vec(20, 30, rng.normal_vec(600, 1.0));
        let b = Mat::from_vec(20, 10, rng.normal_vec(200, 1.0));
        assert!(approx(&a.matmul_tn(&b), &a.transpose().matmul(&b), 1e-4));
        let c = Mat::from_vec(40, 30, rng.normal_vec(1200, 1.0));
        assert!(approx(&a.matmul_nt(&c), &a.matmul(&c.transpose()), 1e-4));
    }

    #[test]
    fn matmul_family_bitwise_stable_across_widths() {
        // the determinism contract: identical bytes at widths 1, 2, 4
        let mut rng = crate::util::Pcg::seeded(77);
        let a = Mat::from_vec(129, 65, rng.normal_vec(129 * 65, 1.0));
        let b = Mat::from_vec(65, 131, rng.normal_vec(65 * 131, 1.0));
        let tall = Mat::from_vec(129, 70, rng.normal_vec(129 * 70, 1.0));
        let wide = Mat::from_vec(90, 65, rng.normal_vec(90 * 65, 1.0));
        let base = pool::with_threads(1, || {
            (a.matmul(&b), a.matmul_tn(&tall), a.matmul_nt(&wide), a.transpose())
        });
        for width in [2, 4] {
            let got = pool::with_threads(width, || {
                (a.matmul(&b), a.matmul_tn(&tall), a.matmul_nt(&wide), a.transpose())
            });
            assert_eq!(base.0.data, got.0.data, "matmul width {width}");
            assert_eq!(base.1.data, got.1.data, "matmul_tn width {width}");
            assert_eq!(base.2.data, got.2.data, "matmul_nt width {width}");
            assert_eq!(base.3.data, got.3.data, "transpose width {width}");
        }
    }

    #[test]
    fn norms_and_reductions() {
        let a = Mat::from_vec(2, 2, vec![3., 0., 0., 4.]);
        assert!((a.fro_norm() - 5.0).abs() < 1e-6);
        assert_eq!(a.col_sq_norms(), vec![9.0, 16.0]);
        assert_eq!(a.row_sq_norms(), vec![9.0, 16.0]);
        assert_eq!(a.diag(), vec![3.0, 4.0]);
    }

    #[test]
    fn reductions_parallel_close_to_serial() {
        // 600*450 = 270k elements: above PAR_MIN_ELEMS, so width 4 takes
        // the chunked paths
        let mut rng = crate::util::Pcg::seeded(21);
        let a = Mat::from_vec(600, 450, rng.normal_vec(600 * 450, 1.0));
        let serial = pool::with_threads(1, || {
            (a.fro_norm_sq(), a.max_abs(), a.col_sq_norms(), a.row_sq_norms())
        });
        let par = pool::with_threads(4, || {
            (a.fro_norm_sq(), a.max_abs(), a.col_sq_norms(), a.row_sq_norms())
        });
        let rel = (serial.0 - par.0).abs() / serial.0.max(1e-12);
        assert!(rel < 1e-4, "fro_norm_sq rel err {rel}");
        assert_eq!(serial.1, par.1, "max_abs is order-insensitive");
        for (s, p) in serial.2.iter().zip(&par.2) {
            assert!((s - p).abs() <= 1e-4 * (1.0 + s.abs()), "col {s} vs {p}");
        }
        assert_eq!(serial.3, par.3, "row_sq_norms is per-row");
    }

    #[test]
    fn ema_inplace() {
        let mut a = Mat::from_vec(1, 3, vec![1., 1., 1.]);
        let b = Mat::from_vec(1, 3, vec![2., 4., 6.]);
        a.ema_(0.5, &b, 0.5);
        assert_eq!(a.data, vec![1.5, 2.5, 3.5]);
    }

    #[test]
    fn elementwise_bitwise_stable_across_widths() {
        let mut rng = crate::util::Pcg::seeded(23);
        // above PAR_MIN_ELEMS and a non-multiple of PAR_CHUNK: multiple
        // chunks with a ragged tail
        let n = super::PAR_MIN_ELEMS + 3 * super::PAR_CHUNK + 17;
        let a = Mat::from_vec(1, n, rng.normal_vec(n, 1.0));
        let b = Mat::from_vec(1, n, rng.normal_vec(n, 1.0));
        let base = pool::with_threads(1, || {
            let mut e = a.clone();
            e.ema_(0.9, &b, 0.1);
            (a.map(|x| x.tanh()), a.zip(&b, |x, y| x * y + 1.0), e)
        });
        let par = pool::with_threads(4, || {
            let mut e = a.clone();
            e.ema_(0.9, &b, 0.1);
            (a.map(|x| x.tanh()), a.zip(&b, |x, y| x * y + 1.0), e)
        });
        assert_eq!(base.0.data, par.0.data);
        assert_eq!(base.1.data, par.1.data);
        assert_eq!(base.2.data, par.2.data);
    }

    #[test]
    fn dedicated_elementwise_matches_map_zip() {
        // scale/add/sub moved off the generic map/zip closures onto the
        // simd kernels; same bytes out under every feature setting
        let mut rng = crate::util::Pcg::seeded(31);
        let a = Mat::from_vec(9, 13, rng.normal_vec(117, 1.0));
        let b = Mat::from_vec(9, 13, rng.normal_vec(117, 1.0));
        assert_eq!(a.scale(2.5).data, a.map(|x| x * 2.5).data);
        assert_eq!(a.add(&b).data, a.zip(&b, |x, y| x + y).data);
        assert_eq!(a.sub(&b).data, a.zip(&b, |x, y| x - y).data);
    }

    #[test]
    fn col_vec_set_col_roundtrip() {
        let mut m = Mat::from_fn(5, 4, |i, j| (i * 4 + j) as f32);
        let c2 = m.col_vec(2);
        assert_eq!(c2, vec![2.0, 6.0, 10.0, 14.0, 18.0]);
        m.set_col(1, &c2);
        for (i, &v) in c2.iter().enumerate() {
            assert_eq!(m.at(i, 1), v);
        }
        // degenerate: zero-row matrices must not slice out of bounds
        let mut e = Mat::zeros(0, 3);
        assert!(e.col_vec(2).is_empty());
        e.set_col(2, &[]);
    }

    #[test]
    fn hcat_take_cols() {
        let a = Mat::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let b = Mat::from_vec(2, 1, vec![9., 8.]);
        let c = a.hcat(&b);
        assert_eq!(c.cols, 3);
        assert_eq!(c.at(0, 2), 9.0);
        assert_eq!(c.take_cols(2).data, a.data);
    }

    #[test]
    fn transpose_involution() {
        let a = Mat::from_fn(3, 5, |i, j| (i + 2 * j) as f32);
        assert!(approx(&a.transpose().transpose(), &a, 0.0));
    }

    #[test]
    fn degenerate_shapes() {
        let e = Mat::zeros(0, 5);
        assert_eq!(e.transpose().rows, 5);
        assert_eq!(e.matmul(&Mat::zeros(5, 3)).data.len(), 0);
        let r = Mat::from_vec(1, 4, vec![1., 2., 3., 4.]);
        let c = Mat::from_vec(4, 1, vec![1., 1., 1., 1.]);
        assert_eq!(r.matmul(&c).data, vec![10.0]);
    }
}
