//! Randomized range finder for the sketched subspace refresh
//! (ISSUE 6 / ROADMAP "Sketched subspace refresh").
//!
//! The eigen-refresh optimizers (Alice, Eigen-Adam, SOAP) only ever
//! consume r ≪ n leading directions of a symmetric PSD operator A
//! (GGᵀ, or its tracked reconstruction, or a stored EMA), yet the exact
//! path eigendecomposes the full n×n matrix — O(sweeps · n³) — just to
//! keep that basis fresh. The Halko-style randomized range finder here
//! delivers the same leading subspace from (q + 2) thin applications of
//! A to an n×(r+p) block:
//!
//! 1. seeded Gaussian sketch Ω (n×s, s = r + p oversampled columns),
//!    warm-started from the previous basis columns;
//! 2. Y = A·Ω, orthonormalized by [`mgs_qr`], then `q` power iterations
//!    Q ← qr(A·Q) to sharpen the spectral gap;
//! 3. the s×s projected eigenproblem B = Qᵀ(A·Q), solved by the
//!    existing serial Jacobi kernel ([`jacobi_eigh_serial`] — s is
//!    pivot-subproblem-sized, the parallel paths would be overhead);
//! 4. U = Q·W, truncated to the leading r columns.
//!
//! `A` is passed as an *operator* (`&dyn Fn(&Mat) -> Mat` applying A to
//! a thin block), so callers whose A is itself a product — Alice's
//! β₃·U(Q̃(UᵀX)) + (1−β₃)·G(GᵀX) — never materialize an n×n matrix at
//! all: the sketch path costs O(n·m·s·(q+2)) against the exact path's
//! O(n²·m + sweeps·n³).
//!
//! # Determinism
//!
//! Ω is drawn serially on the calling thread from a [`Pcg`] stream
//! derived from the caller's seed (the coordinator draws refresh seeds
//! on its own thread, like every existing refresh), and every stage —
//! the pool-parallel `matmul` family, [`mgs_qr`], the serial Jacobi
//! kernel — is bitwise width-invariant, so sketched bases are **bitwise
//! identical at every pool width** per feature setting
//! (`tests/decomp_parity.rs`).
//!
//! # Numerical robustness
//!
//! Every operator application is sanitized like the exact solver's
//! entry guard (ISSUE 5): non-finite entries in A·X (a blown-up G or a
//! poisoned EMA) are zeroed before orthonormalization, and warm-start
//! columns carrying non-finite values are skipped in favor of the
//! Gaussian draw — a sketched refresh never panics and always returns
//! an orthonormal basis with finite eigenvalues.

use crate::util::{trace, Pcg};

use super::decomp::{jacobi_eigh_serial, mgs_qr};
use super::mat::Mat;

/// Geometry of one sketched refresh: target rank, oversampling columns,
/// power iterations, and the sweep budget of the projected eigenproblem.
/// Built from `opt::Hyper` via `Hyper::sketch_spec`.
#[derive(Debug, Clone, Copy)]
pub struct SketchSpec {
    /// Leading directions the caller consumes (columns of the result).
    pub rank: usize,
    /// Extra sketch columns p — the classic range-finder accuracy knob.
    pub oversample: usize,
    /// Power iterations q sharpening the spectral gap (0 = plain sketch).
    pub power_iters: usize,
    /// Jacobi sweeps for the (r+p)×(r+p) projected eigenproblem.
    pub sweeps: usize,
}

/// Zero any non-finite entry of a freshly applied block — the sketch
/// path's analogue of the exact solver's `symmetric_finite` entry guard.
fn finite_block(mut y: Mat) -> Mat {
    if !y.is_finite() {
        for v in y.data.iter_mut() {
            if !v.is_finite() {
                *v = 0.0;
            }
        }
    }
    y
}

/// Apply the operator and orthonormalize the result. `mgs_qr`'s
/// degenerate-column fallback covers a sanitized-to-zero block.
fn orthonormal_range(apply: &dyn Fn(&Mat) -> Mat, x: &Mat) -> Mat {
    mgs_qr(&finite_block(apply(x)))
}

/// Leading eigenpairs of a symmetric PSD operator on ℝⁿ via the
/// randomized range finder: returns (U, λ) with U n×r orthonormal and λ
/// the r leading Rayleigh–Ritz values, descending. `apply` must map an
/// n×k block X to A·X; `warm` (previous basis, n×·) seeds the leading
/// sketch columns so successive refreshes track a drifting subspace.
pub fn sketched_eigh(
    n: usize,
    apply: &dyn Fn(&Mat) -> Mat,
    warm: Option<&Mat>,
    spec: &SketchSpec,
    seed: u64,
) -> (Mat, Vec<f32>) {
    let _sp = trace::region("linalg", "sketched_eigh");
    assert!(n > 0, "sketched_eigh needs a non-empty operator");
    let r = spec.rank.clamp(1, n);
    let s = (r + spec.oversample).min(n);
    // Ω: serial draw on the calling thread — width-invariant by
    // construction, like every coordinator-side refresh seed
    let mut rng = Pcg::seeded(seed.wrapping_mul(0x9e37_79b9_7f4a_7c15).wrapping_add(0x5ce7));
    let mut omega = Mat::from_vec(n, s, rng.normal_vec(n * s, 1.0));
    if let Some(w) = warm {
        if w.rows == n {
            // previous basis columns replace the leading sketch columns;
            // a poisoned column falls back to its Gaussian draw
            for j in 0..w.cols.min(s) {
                let col = w.col_vec(j);
                if col.iter().all(|x| x.is_finite()) {
                    omega.set_col(j, &col);
                }
            }
        }
    }
    let mut q = orthonormal_range(apply, &omega);
    for _ in 0..spec.power_iters {
        q = orthonormal_range(apply, &q);
    }
    // projected s×s eigenproblem off one final application
    let aq = finite_block(apply(&q));
    let mut b = q.matmul_tn(&aq);
    b.symmetrize_();
    let (w, lam) = jacobi_eigh_serial(&b, spec.sweeps.max(1));
    let u = q.matmul(&w);
    if r == s {
        (u, lam)
    } else {
        (u.take_cols(r), lam[..r].to_vec())
    }
}

/// [`sketched_eigh`] over an explicit symmetric matrix (the stored-EMA
/// refreshes of Eigen-Adam / SOAP, and the test/bench harnesses).
pub fn sketched_eigh_mat(
    a: &Mat,
    warm: Option<&Mat>,
    spec: &SketchSpec,
    seed: u64,
) -> (Mat, Vec<f32>) {
    assert_eq!(a.rows, a.cols, "sketched_eigh_mat needs a square operator");
    sketched_eigh(a.rows, &|x| a.matmul(x), warm, spec, seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{jacobi_eigh, random_orthonormal};

    fn spec(rank: usize) -> SketchSpec {
        SketchSpec { rank, oversample: 4, power_iters: 2, sweeps: 30 }
    }

    fn ortho_err(q: &Mat) -> f32 {
        q.matmul_tn(q).sub(&Mat::eye(q.cols)).max_abs()
    }

    /// Planted low-rank-plus-noise PSD: B Bᵀ dominant on r directions.
    fn planted(n: usize, r: usize, seed: u64) -> Mat {
        let mut rng = Pcg::seeded(seed);
        let b = Mat::from_vec(n, r, rng.normal_vec(n * r, 1.0));
        let e = Mat::from_vec(n, n, rng.normal_vec(n * n, 1.0));
        b.matmul_nt(&b).scale(4.0).add(&e.matmul_nt(&e).scale(1e-3 / n as f32))
    }

    #[test]
    fn recovers_planted_eigenvalues() {
        let (n, r) = (60, 5);
        let a = planted(n, r, 11);
        let (u, lam) = sketched_eigh_mat(&a, None, &spec(r), 3);
        assert_eq!((u.rows, u.cols), (n, r));
        assert!(ortho_err(&u) < 1e-3);
        let (_, lam_exact) = jacobi_eigh(&a, 40);
        for (got, want) in lam.iter().zip(&lam_exact[..r]) {
            assert!(
                (got - want).abs() < 2e-2 * want.abs().max(1.0),
                "sketched λ {got} vs exact {want}"
            );
        }
    }

    #[test]
    fn oversample_clamps_to_n() {
        // r + p past n must clamp instead of panicking the QR
        let a = planted(10, 3, 12);
        let s = SketchSpec { rank: 8, oversample: 16, power_iters: 1, sweeps: 30 };
        let (u, lam) = sketched_eigh_mat(&a, None, &s, 4);
        assert_eq!((u.rows, u.cols), (10, 8));
        assert_eq!(lam.len(), 8);
        assert!(ortho_err(&u) < 1e-3);
    }

    #[test]
    fn warm_start_skips_poisoned_columns() {
        let a = planted(40, 4, 13);
        let mut rng = Pcg::seeded(14);
        let mut warm = random_orthonormal(40, 4, &mut rng);
        *warm.at_mut(3, 2) = f32::NAN;
        let (u, lam) = sketched_eigh_mat(&a, Some(&warm), &spec(4), 5);
        assert!(u.is_finite());
        assert!(lam.iter().all(|l| l.is_finite()));
        assert!(ortho_err(&u) < 1e-3);
    }

    #[test]
    fn non_finite_operator_is_sanitized() {
        let mut a = planted(40, 4, 15);
        *a.at_mut(2, 7) = f32::NAN;
        *a.at_mut(30, 1) = f32::NEG_INFINITY;
        let (u, lam) = sketched_eigh_mat(&a, None, &spec(4), 6);
        assert!(u.is_finite(), "sketch must sanitize a poisoned operator");
        assert!(lam.iter().all(|l| l.is_finite()));
        assert!(ortho_err(&u) < 1e-3);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = planted(30, 3, 16);
        let (u1, l1) = sketched_eigh_mat(&a, None, &spec(3), 9);
        let (u2, l2) = sketched_eigh_mat(&a, None, &spec(3), 9);
        assert_eq!(u1.data, u2.data);
        assert_eq!(l1, l2);
        let (u3, _) = sketched_eigh_mat(&a, None, &spec(3), 10);
        assert_ne!(u1.data, u3.data, "different seeds draw different sketches");
    }
}
